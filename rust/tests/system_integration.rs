//! Cross-module integration tests that need no PJRT artifacts: the
//! trained-model -> FPGA-simulator -> metrics path, the DSE end-to-end
//! flow on a real (tiny) sweep, and serving through the coordinator.

use bayes_rnn_fpga::config::{ArchConfig, Task};
use bayes_rnn_fpga::coordinator::{BatchPolicy, Engine, Server, ServerConfig};
use bayes_rnn_fpga::data;
use bayes_rnn_fpga::dse::space::reuse_search;
use bayes_rnn_fpga::dse::{LookupTable, OptMode, Optimizer};
use bayes_rnn_fpga::fpga::accel::Accelerator;
use bayes_rnn_fpga::fpga::pipeline::PipelineSim;
use bayes_rnn_fpga::hwmodel::ZC706;
use bayes_rnn_fpga::train::eval::{eval_anomaly, ModelPredictor};
use bayes_rnn_fpga::train::sweep::{self, SweepOpts};
use bayes_rnn_fpga::train::{NativeTrainer, TrainOpts};

/// Train a small AE, quantise it onto the accelerator, and verify the
/// fixed-point design still separates anomalies (the Table I story).
#[test]
fn quantized_accelerator_preserves_anomaly_detection() {
    let cfg = ArchConfig::new(Task::Anomaly, 16, 1, "NN");
    let (train, test) = data::anomaly_splits(4);
    let tr = train.subset(&(0..128.min(train.n)).collect::<Vec<_>>());
    let mut trainer = NativeTrainer::new(
        cfg.clone(),
        TrainOpts { epochs: 12, batch: 32, lr: 1e-2, seed: 0 },
    );
    trainer.fit(&tr);
    let te = test.subset(&(0..120).collect::<Vec<_>>());

    let mut float_pred = ModelPredictor::new(&trainer.model, 3);
    let float_rep = eval_anomaly(&mut float_pred, &te, 1);

    let reuse = reuse_search(&cfg, &ZC706).expect("fits");
    let mut accel = Accelerator::new(&cfg, &trainer.model.params, reuse, 3);
    let fixed_rep = eval_anomaly(&mut accel, &te, 1);

    assert!(float_rep.auc > 0.8, "float auc {}", float_rep.auc);
    assert!(
        (fixed_rep.auc - float_rep.auc).abs() < 0.08,
        "quantisation must preserve AUC: float {} fixed {}",
        float_rep.auc,
        fixed_rep.auc
    );
}

/// Sweep -> lookup -> optimizer: the full Fig. 7 loop at toy scale.
#[test]
fn dse_end_to_end() {
    let opts = SweepOpts {
        epochs: 3,
        train_subset: 64,
        test_subset: 80,
        noise_subset: 10,
        mc_samples: 3,
        // Small per-precision fixed-point window: enough to populate
        // the accuracy@q* columns the optimizer's Q axis reads.
        quant_subset: 24,
        ..Default::default()
    };
    let mut table = LookupTable::new();
    sweep::run(Task::Classify, &opts, &mut table, |_, _, _| {});
    assert!(!table.entries.is_empty());

    let opt = Optimizer::new(&ZC706, &table);
    assert!(
        opt.precisions.len() >= 3,
        "the DSE must search at least 3 bitwidths"
    );
    let lat = opt.optimize(Task::Classify, OptMode::Latency).expect("latency");
    assert!(!lat.arch.is_bayesian(), "Opt-Latency picks pointwise");
    assert_eq!(lat.s, 1);
    let acc = opt
        .optimize(Task::Classify, OptMode::Metric("accuracy"))
        .expect("accuracy");
    assert!(acc.fpga_latency_ms >= lat.fpga_latency_ms);
    // Every chosen design must actually fit the chip at its chosen
    // precision, and report that precision + resource estimate.
    for c in [&lat, &acc] {
        assert!(c.resources.dsps <= ZC706.dsps as f64 * 1.05);
        assert!(!c.precision.name().is_empty());
    }
    // The quality mode picked a precision whose accuracy was measured
    // (the sweep writes accuracy@q* columns), so the report can show
    // the quantised accuracy of the chosen format.
    let measured = acc.quant_metric("accuracy").is_some()
        || acc.precision.name() == "q16";
    assert!(measured, "chosen precision must have measured accuracy");
}

/// Functional + timing sims agree with the deployment story: serving via
/// the coordinator produces valid predictions and hardware latencies
/// consistent with the cycle simulator.
#[test]
fn serve_through_fpga_simulator() {
    let mut cfg = ArchConfig::new(Task::Classify, 8, 2, "YN");
    cfg.seq_len = data::T;
    let (train, test) = data::splits(6);
    let mut trainer = NativeTrainer::new(
        cfg.clone(),
        TrainOpts { epochs: 4, batch: 32, lr: 5e-3, seed: 1 },
    );
    trainer.fit(&train.subset(&(0..96).collect::<Vec<_>>()));
    let model = trainer.model;
    let reuse = reuse_search(&cfg, &ZC706).expect("fits");
    let s = 8;

    let expected_ms =
        PipelineSim::new(&cfg, reuse).simulate_ms(1, s, ZC706.clock_hz);

    let cfg2 = cfg.clone();
    let params = model.params.tensors.clone();
    let mut server = Server::start(
        move || {
            let m = bayes_rnn_fpga::nn::model::Model::new(
                cfg2.clone(),
                bayes_rnn_fpga::nn::Params { tensors: params.clone() },
            );
            Engine::fpga(&cfg2, &m, reuse, s, 11)
        },
        ServerConfig { policy: BatchPolicy::stream(), queue_depth: 64 },
    );
    let receivers: Vec<_> = (0..10)
        .map(|i| server.submit(test.beat(i).to_vec()))
        .collect();
    for rx in receivers {
        let resp = rx.recv().unwrap();
        let p = &resp.prediction;
        assert_eq!(p.mean.len(), 4);
        assert!((p.mean.iter().sum::<f32>() - 1.0).abs() < 1e-3);
        assert!((p.model_latency_ms - expected_ms).abs() < 1e-9);
    }
    let summary = server.join();
    assert_eq!(summary.served, 10);
}
