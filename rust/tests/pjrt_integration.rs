//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These require `make artifacts` to have run (they skip politely when
//! the manifest is absent, e.g. in a bare checkout). They are the
//! cross-layer correctness signal: the L2 JAX model lowered to HLO and
//! executed from Rust must agree with the native Rust engine, which in
//! turn was checked against finite differences and the Pallas/ref pytest
//! suite — closing the loop across all three layers.

use std::path::{Path, PathBuf};

use bayes_rnn_fpga::config::{ArchConfig, Task};
use bayes_rnn_fpga::data;
use bayes_rnn_fpga::nn::model::{Masks, Model};
use bayes_rnn_fpga::nn::{AdamHp, AdamState, Params};
use bayes_rnn_fpga::rng::Rng;
use bayes_rnn_fpga::runtime::{HostValue, Runtime};
use bayes_rnn_fpga::tensor::Tensor;
use bayes_rnn_fpga::train::PjrtTrainer;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn forward_via_pjrt(
    rt: &mut Runtime,
    artifact: &str,
    params: &Params,
    xs: &Tensor,
    masks: &Masks,
) -> Tensor {
    let mut args: Vec<HostValue> = params
        .tensors
        .iter()
        .map(|t| HostValue::F32(t.clone()))
        .collect();
    args.push(HostValue::F32(xs.clone()));
    for m in &masks.tensors {
        args.push(HostValue::F32(m.clone()));
    }
    let exe = rt.load(artifact).expect("compile");
    exe.run(&args).expect("execute").remove(0)
}

#[test]
fn pjrt_forward_matches_native_engine() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    };
    let mut rt = Runtime::new(&dir).unwrap();
    for arch_name in ["classify_h8_nl1_N", "anomaly_h16_nl2_YNYN"] {
        let meta = rt.manifest.forward_for(arch_name, 30).unwrap().clone();
        let cfg = meta.arch();
        let mut rng = Rng::new(42);
        let params = Params::init(&cfg, &mut rng);
        let model = Model::new(cfg.clone(), params.clone());

        // One beat replicated over 30 rows, fixed masks: both paths see
        // identical inputs, so outputs must agree to f32 tolerance.
        let beats = data::generate(1, 9);
        let mut xs = Vec::new();
        for _ in 0..30 {
            xs.extend_from_slice(beats.beat(0));
        }
        let masks = Masks::sample(&cfg, 30, &mut rng);
        let native = model.forward(&xs, 30, &masks);
        let pjrt_out = forward_via_pjrt(
            &mut rt,
            &meta.name,
            &params,
            &Tensor::new(vec![30, cfg.seq_len, cfg.input_dim], xs.clone()),
            &masks,
        );
        assert_eq!(pjrt_out.data.len(), native.len(), "{arch_name}");
        let max_diff = pjrt_out
            .data
            .iter()
            .zip(&native)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(
            max_diff < 2e-3,
            "{arch_name}: PJRT vs native diverged by {max_diff}"
        );
    }
}

#[test]
fn pjrt_classifier_probs_are_distributions() {
    let Some(dir) = artifacts_dir() else {
        return;
    };
    let mut rt = Runtime::new(&dir).unwrap();
    let meta = rt.manifest.forward_for("classify_h8_nl3_YNY", 30).unwrap().clone();
    let cfg = meta.arch();
    let mut rng = Rng::new(1);
    let params = Params::init(&cfg, &mut rng);
    let beats = data::generate(1, 3);
    let mut xs = Vec::new();
    for _ in 0..30 {
        xs.extend_from_slice(beats.beat(0));
    }
    let masks = Masks::sample(&cfg, 30, &mut rng);
    let out = forward_via_pjrt(
        &mut rt,
        &meta.name,
        &params,
        &Tensor::new(vec![30, cfg.seq_len, 1], xs),
        &masks,
    );
    assert_eq!(out.shape, vec![30, 4]);
    for r in 0..30 {
        let s: f32 = out.row(r).iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "row {r} sums to {s}");
        assert!(out.row(r).iter().all(|&p| p >= 0.0));
    }
    // MCD across rows: different masks must disagree somewhere.
    assert!((1..30).any(|r| out.row(r) != out.row(0)));
}

#[test]
fn pjrt_train_step_matches_native_adam() {
    let Some(dir) = artifacts_dir() else {
        return;
    };
    let mut rt = Runtime::new(&dir).unwrap();
    let arch = "classify_h8_nl1_N";
    let batch = 64;
    let lr = 1e-3;
    let mut trainer = PjrtTrainer::new(&mut rt, arch, batch, lr, 7).unwrap();
    let cfg = trainer.cfg.clone();

    // Mirror state into a native model.
    let mut native = Model::new(cfg.clone(), trainer.params.clone());
    let mut state = AdamState::new(&native.params);
    let hp = AdamHp { lr, ..Default::default() };

    let train = data::generate(batch, 5);
    // Native side must see the same masks the PjrtTrainer samples: the
    // trainer's RNG stream is deterministic (seed 7 after init), so we
    // regenerate it the same way.
    let mut mask_rng = {
        // PjrtTrainer::new consumed some of the stream for init; replay.
        let mut r = Rng::new(7);
        let _ = Params::init(&cfg, &mut r);
        r
    };
    for step in 0..3 {
        let masks = Masks::sample(&cfg, batch, &mut mask_rng);
        let native_loss = native.train_step(
            &hp,
            &mut state,
            &train.x,
            &train.y,
            &masks,
        );
        let pjrt_loss = trainer.step_batch(&train.x, &train.y).unwrap();
        let rel = (native_loss - pjrt_loss).abs()
            / native_loss.abs().max(1e-6);
        assert!(
            rel < 5e-2,
            "step {step}: native loss {native_loss} vs pjrt {pjrt_loss}"
        );
    }
    // Parameters after 3 steps must still track closely.
    let max_diff: f32 = native
        .params
        .tensors
        .iter()
        .zip(&trainer.params.tensors)
        .map(|(a, b)| a.max_abs_diff(b))
        .fold(0.0, f32::max);
    assert!(max_diff < 5e-3, "params diverged by {max_diff}");
}

#[test]
fn pjrt_training_reduces_loss() {
    let Some(dir) = artifacts_dir() else {
        return;
    };
    let mut rt = Runtime::new(&dir).unwrap();
    let mut trainer =
        PjrtTrainer::new(&mut rt, "classify_h8_nl1_N", 64, 3e-3, 0).unwrap();
    let train = data::generate(128, 1);
    trainer.fit(&train, 6).unwrap();
    let first = trainer.loss_history[0];
    let last = *trainer.loss_history.last().unwrap();
    assert!(last < first * 0.9, "PJRT training: {first} -> {last}");
}

#[test]
fn executable_rejects_bad_shapes() {
    let Some(dir) = artifacts_dir() else {
        return;
    };
    let mut rt = Runtime::new(&dir).unwrap();
    let meta = rt.manifest.forward_for("classify_h8_nl1_N", 1).unwrap().clone();
    let cfg = meta.arch();
    let params = Params::init(&cfg, &mut Rng::new(0));
    // Wrong xs shape (rows=2 instead of 1) must be caught by the ABI
    // check, not by an XLA crash.
    let mut args: Vec<HostValue> = params
        .tensors
        .iter()
        .map(|t| HostValue::F32(t.clone()))
        .collect();
    args.push(HostValue::F32(Tensor::zeros(&[2, cfg.seq_len, 1])));
    for s in cfg.mask_shapes(1) {
        args.push(HostValue::F32(Tensor::ones(&s)));
    }
    let exe = rt.load(&meta.name).unwrap();
    let err = exe.run(&args).unwrap_err();
    assert!(err.to_string().contains("shape"), "{err}");
}
