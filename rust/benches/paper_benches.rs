//! Paper-reproduction bench harness (`cargo bench`, harness = false —
//! criterion is unavailable offline, so this is a self-contained runner).
//!
//! One section per table/figure of the paper's evaluation:
//!
//!   fig8      anomaly-detection DSE sweep (ROC/AUC/AP/ACC per arch)
//!   fig9      classification DSE sweep (ACC/AP/AR/entropy per arch)
//!   fig10     metric vs number of MC samples S
//!   table1    float vs 16-bit fixed point, best anomaly model (3 retrains)
//!   table2    float vs 16-bit fixed point, best classifier (3 retrains)
//!   table3    resource utilisation + resource-model accuracy
//!   table4    FPGA vs CPU vs GPU latency / power / energy (batch 50/200)
//!   table5    optimisation framework, anomaly modes
//!   table6    optimisation framework, classification modes
//!   ablation  latency model vs cycle-accurate simulation error
//!   perf      L3 hot-path microbenchmarks (engine step, serve overhead)
//!   kernels   scalar vs blocked vs simd kernel backends: per-backend
//!             MVM MMAC/s (fx + f32) with a bit-identity drift gate
//!             (exit 1), packed-weight bytes/MAC per format, and
//!             accelerator beats/s at S in {10, 30, 100}; one-line JSON
//!             to bench_results/kernel_microbench.json (docs/kernels.md)
//!   maskgen   dropout-mask generation layer: word-level LFSR fill vs
//!             the legacy bit-by-bit loop (Mbit/s + drift gate), and
//!             the seed-indexed mask bank's hit rate / speedup on a
//!             repeated-seed workload with a bank-on/off bit-identity
//!             gate; one-line JSON to bench_results/maskgen.json
//!             (docs/kernels.md §Mask bank)
//!   precision quantisation axis (docs/quantization.md): accuracy +
//!             simulated beats/s + modelled latency/DSPs at q8/q12/q16,
//!             one-line JSON to bench_results/precision.json; any
//!             checksum drift of the parametric Q6.10 path vs the
//!             legacy constructor / scalar loop hard-fails (exit 1)
//!
//! Filter by passing section names: `cargo bench -- table4 ablation`.
//! Paper reference values are printed alongside for eyeball comparison;
//! EXPERIMENTS.md records a full run.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use bayes_rnn_fpga::config::{ArchConfig, Task};
use bayes_rnn_fpga::data;
use bayes_rnn_fpga::dse::space::reuse_search;
use bayes_rnn_fpga::dse::{LookupTable, Optimizer};
use bayes_rnn_fpga::fpga::accel::Accelerator;
use bayes_rnn_fpga::fpga::pipeline::PipelineSim;
use bayes_rnn_fpga::hwmodel::resource::{ResourceModel, ReuseFactors};
use bayes_rnn_fpga::hwmodel::{GpuModel, LatencyModel, PowerModel, ZC706};
use bayes_rnn_fpga::metrics;
use bayes_rnn_fpga::nn::model::Model;
use bayes_rnn_fpga::nn::Params;
use bayes_rnn_fpga::rng::Rng;
use bayes_rnn_fpga::runtime::{HostValue, Runtime};
use bayes_rnn_fpga::tensor::Tensor;
use bayes_rnn_fpga::train::eval::{
    eval_anomaly, eval_classify, ModelPredictor,
};
use bayes_rnn_fpga::train::sweep::{self, SweepOpts};
use bayes_rnn_fpga::train::{NativeTrainer, TrainOpts};

fn main() {
    let args: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with("--"))
        .collect();
    let want = |name: &str| args.is_empty() || args.iter().any(|a| a == name);
    let t0 = Instant::now();

    // Sweeps feed figs 8/9 AND tables 5/6; build lazily, reuse.
    let mut anomaly_table: Option<LookupTable> = None;
    let mut classify_table: Option<LookupTable> = None;

    if want("fig8") {
        anomaly_table = Some(fig8());
    }
    if want("fig9") {
        classify_table = Some(fig9());
    }
    if want("fig10") {
        fig10();
    }
    if want("table1") {
        table_quant(Task::Anomaly);
    }
    if want("table2") {
        table_quant(Task::Classify);
    }
    if want("table3") {
        table3();
    }
    if want("table4") {
        table4();
    }
    if want("table5") {
        let table = anomaly_table.take().unwrap_or_else(quick_anomaly_table);
        table56(Task::Anomaly, &table);
    }
    if want("table6") {
        let table =
            classify_table.take().unwrap_or_else(quick_classify_table);
        table56(Task::Classify, &table);
    }
    if want("ablation") {
        ablation_latency_model();
    }
    if want("cells") {
        ablation_cells();
    }
    if want("dropout") {
        ablation_dropout_rates();
    }
    if want("openloop") {
        openloop_serving();
    }
    if want("perf") {
        perf();
    }
    if want("kernels") {
        kernels_bench();
    }
    if want("maskgen") {
        maskgen_bench();
    }
    if want("precision") {
        precision_bench();
    }
    println!("\n[bench] total wall time {:.1}s", t0.elapsed().as_secs_f64());
}

fn banner(s: &str) {
    println!("\n================================================================");
    println!("{s}");
    println!("================================================================");
}

/// Process RSS/CPU block for bench JSON (`null` off-Linux, where
/// /proc/self is unavailable) — lets perf-trajectory diffs catch
/// memory regressions alongside throughput ones.
fn proc_json() -> String {
    match bayes_rnn_fpga::obs::proc_sample() {
        Some(p) => format!(
            "{{\"rss_bytes\":{},\"cpu_seconds\":{:.3}}}",
            p.rss_bytes, p.cpu_seconds
        ),
        None => "null".into(),
    }
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

// ---------------------------------------------------------------------------
// Figs. 8/9: algorithmic DSE sweeps.
// ---------------------------------------------------------------------------

fn sweep_opts() -> SweepOpts {
    SweepOpts {
        epochs: 20,
        train_subset: 320,
        test_subset: 300,
        noise_subset: 30,
        mc_samples: 10,
        ..Default::default()
    }
}

fn fig8() -> LookupTable {
    banner(
        "Fig. 8 — anomaly DSE: ROC/AUC/AP/ACC per architecture\n\
         paper: Pareto-optimal nets are at least partially Bayesian;\n\
         best = {H=16, NL=2, B=YNYN} with AUC/AP/ACC ~ 0.98/0.96/0.95",
    );
    let mut table = LookupTable::new();
    let t0 = Instant::now();
    sweep::run(Task::Anomaly, &sweep_opts(), &mut table, |d, t, n| {
        println!("  [{d}/{t}] swept {n}");
    });
    println!("\n{:<26} {:>7} {:>7} {:>7}", "arch", "AUC", "AP", "ACC");
    let mut rows: Vec<_> = table.for_task(Task::Anomaly);
    rows.sort_by(|a, b| {
        b.metrics["auc"].partial_cmp(&a.metrics["auc"]).unwrap()
    });
    for e in &rows {
        println!(
            "{:<26} {:>7.3} {:>7.3} {:>7.3}",
            e.name, e.metrics["auc"], e.metrics["ap"], e.metrics["accuracy"]
        );
    }
    let best = rows.first().expect("non-empty sweep");
    let best_is_bayesian = best.bayes.contains('Y');
    println!(
        "\nbest by AUC: {} (Bayesian: {best_is_bayesian}) — paper found the \
         Pareto front at least partially Bayesian; sweep took {:.0}s",
        best.name,
        t0.elapsed().as_secs_f64()
    );
    table
}

fn fig9() -> LookupTable {
    banner(
        "Fig. 9 — classification DSE: ACC/AP/AR/entropy per architecture\n\
         paper: best = {H=8, NL=3, B=YNY}, ACC ~0.92, partially Bayesian\n\
         nets dominate",
    );
    let mut table = LookupTable::new();
    let t0 = Instant::now();
    sweep::run(Task::Classify, &sweep_opts(), &mut table, |d, t, n| {
        println!("  [{d}/{t}] swept {n}");
    });
    println!(
        "\n{:<26} {:>7} {:>7} {:>7} {:>9}",
        "arch", "ACC", "AP", "AR", "H [nats]"
    );
    let mut rows: Vec<_> = table.for_task(Task::Classify);
    rows.sort_by(|a, b| {
        b.metrics["accuracy"].partial_cmp(&a.metrics["accuracy"]).unwrap()
    });
    for e in &rows {
        println!(
            "{:<26} {:>7.3} {:>7.3} {:>7.3} {:>9.3}",
            e.name,
            e.metrics["accuracy"],
            e.metrics["ap"],
            e.metrics["ar"],
            e.metrics["entropy"]
        );
    }
    println!("sweep took {:.0}s", t0.elapsed().as_secs_f64());
    table
}

fn quick_anomaly_table() -> LookupTable {
    let mut t = LookupTable::new();
    let mut o = sweep_opts();
    o.epochs = 10;
    o.test_subset = 200;
    sweep::run(Task::Anomaly, &o, &mut t, |_, _, _| {});
    t
}

fn quick_classify_table() -> LookupTable {
    let mut t = LookupTable::new();
    let mut o = sweep_opts();
    o.epochs = 10;
    o.test_subset = 200;
    sweep::run(Task::Classify, &o, &mut t, |_, _, _| {});
    t
}

// ---------------------------------------------------------------------------
// Fig. 10: metric vs number of MC samples.
// ---------------------------------------------------------------------------

fn fig10() {
    banner(
        "Fig. 10 — software metrics vs MC samples S (1 -> 30 -> 100)\n\
         paper: S beyond ~30 gives diminishing returns",
    );
    // (a) anomaly best arch.
    {
        let cfg = ArchConfig::new(Task::Anomaly, 16, 2, "YNYN");
        let (train, test) = data::anomaly_splits(0);
        let mut tr = NativeTrainer::new(
            cfg,
            TrainOpts { epochs: 40, batch: 64, lr: 1e-2, seed: 0 },
        );
        tr.fit(&train);
        let te = test.subset(&(0..250).collect::<Vec<_>>());
        println!("anomaly {:<6} {:>7} {:>7} {:>7}", "S", "AUC", "AP", "ACC");
        for s in [1usize, 10, 30, 100] {
            let mut p = ModelPredictor::new(&tr.model, 5);
            let rep = eval_anomaly(&mut p, &te, s);
            println!(
                "        {:<6} {:>7.3} {:>7.3} {:>7.3}",
                s, rep.auc, rep.ap, rep.accuracy
            );
        }
    }
    // (b) classification best arch.
    {
        let cfg = ArchConfig::new(Task::Classify, 8, 3, "YNY");
        let (train, test) = data::splits(0);
        let mut tr = NativeTrainer::new(
            cfg,
            TrainOpts { epochs: 30, batch: 64, lr: 5e-3, seed: 0 },
        );
        tr.fit(&train);
        let te = test.subset(&(0..250).collect::<Vec<_>>());
        let noise = data::gaussian_noise(30, 0);
        println!(
            "classify {:<5} {:>7} {:>7} {:>7} {:>9}",
            "S", "ACC", "AP", "AR", "H [nats]"
        );
        for s in [1usize, 10, 30, 100] {
            let mut p = ModelPredictor::new(&tr.model, 5);
            let rep = eval_classify(&mut p, &te, &noise, s);
            println!(
                "         {:<5} {:>7.3} {:>7.3} {:>7.3} {:>9.3}",
                s, rep.accuracy, rep.ap, rep.ar, rep.noise_entropy
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Tables I/II: float vs fixed point over 3 retrains.
// ---------------------------------------------------------------------------

fn table_quant(task: Task) {
    let (cfg, title, paper) = match task {
        Task::Anomaly => (
            ArchConfig::new(Task::Anomaly, 16, 2, "YNYN"),
            "Table I — float vs 16-bit fixed point, best anomaly model",
            "paper: ACC 0.95+/-.01 | AP 0.96->0.97 | AUC 0.98 (quantisation \
             preserves quality)",
        ),
        Task::Classify => (
            ArchConfig::new(Task::Classify, 8, 3, "YNY"),
            "Table II — float vs 16-bit fixed point, best classifier",
            "paper: ACC 0.92 | AP 0.68 | AR 0.65 | entropy 0.36->0.38 nats",
        ),
    };
    banner(&format!("{title}\n{paper}"));
    let s = 30;
    let retrains = 3;
    let mut float_vals: BTreeMap<&str, Vec<f64>> = BTreeMap::new();
    let mut fixed_vals: BTreeMap<&str, Vec<f64>> = BTreeMap::new();
    for seed in 0..retrains {
        let (reports_f, reports_q): (Vec<(&str, f64)>, Vec<(&str, f64)>) =
            match task {
                Task::Anomaly => {
                    let (train, test) = data::anomaly_splits(0);
                    let mut tr = NativeTrainer::new(
                        cfg.clone(),
                        TrainOpts {
                            epochs: 40,
                            batch: 64,
                            lr: 1e-2,
                            seed,
                        },
                    );
                    tr.fit(&train);
                    let te = test.subset(&(0..250).collect::<Vec<_>>());
                    let mut p = ModelPredictor::new(&tr.model, seed + 5);
                    let f = eval_anomaly(&mut p, &te, s);
                    let reuse = reuse_search(&cfg, &ZC706).unwrap();
                    let mut acc = Accelerator::new(
                        &cfg,
                        &tr.model.params,
                        reuse,
                        seed,
                    );
                    let te_q = test.subset(&(0..150).collect::<Vec<_>>());
                    let q = eval_anomaly(&mut acc, &te_q, s);
                    (
                        vec![
                            ("accuracy", f.accuracy),
                            ("ap", f.ap),
                            ("auc", f.auc),
                        ],
                        vec![
                            ("accuracy", q.accuracy),
                            ("ap", q.ap),
                            ("auc", q.auc),
                        ],
                    )
                }
                Task::Classify => {
                    let (train, test) = data::splits(0);
                    let mut tr = NativeTrainer::new(
                        cfg.clone(),
                        TrainOpts {
                            epochs: 30,
                            batch: 64,
                            lr: 5e-3,
                            seed,
                        },
                    );
                    tr.fit(&train);
                    let te = test.subset(&(0..250).collect::<Vec<_>>());
                    let noise = data::gaussian_noise(30, seed);
                    let mut p = ModelPredictor::new(&tr.model, seed + 5);
                    let f = eval_classify(&mut p, &te, &noise, s);
                    let reuse = reuse_search(&cfg, &ZC706).unwrap();
                    let mut acc = Accelerator::new(
                        &cfg,
                        &tr.model.params,
                        reuse,
                        seed,
                    );
                    let te_q = test.subset(&(0..150).collect::<Vec<_>>());
                    let q = eval_classify(&mut acc, &te_q, &noise, s);
                    (
                        vec![
                            ("accuracy", f.accuracy),
                            ("ap", f.ap),
                            ("ar", f.ar),
                            ("entropy", f.noise_entropy),
                        ],
                        vec![
                            ("accuracy", q.accuracy),
                            ("ap", q.ap),
                            ("ar", q.ar),
                            ("entropy", q.noise_entropy),
                        ],
                    )
                }
            };
        for (k, v) in reports_f {
            float_vals.entry(k).or_default().push(v);
        }
        for (k, v) in reports_q {
            fixed_vals.entry(k).or_default().push(v);
        }
        println!("  retrain {} done", seed + 1);
    }
    println!("\n{:<16} {:>18} {:>18}", "metric", "floating-point", "fixed-point");
    for (k, fv) in &float_vals {
        let (fm, fs) = metrics::mean_std(fv);
        let (qm, qs) = metrics::mean_std(&fixed_vals[k]);
        println!(
            "{:<16} {:>10.3} ±{:>5.3} {:>10.3} ±{:>5.3}",
            k, fm, fs, qm, qs
        );
    }
}

// ---------------------------------------------------------------------------
// Table III: resource utilisation + model accuracy.
// ---------------------------------------------------------------------------

fn table3() {
    banner(
        "Table III — resource utilisation, best architectures on ZC706\n\
         paper: anomaly 758 DSP used vs 754 modelled; classification 898 vs\n\
         915 — resource model >= 98% accurate",
    );
    for (cfg, label) in [
        (
            ArchConfig::new(Task::Anomaly, 16, 2, "YNYN"),
            "Anomaly  H=16 NL=2 B=YNYN",
        ),
        (
            ArchConfig::new(Task::Classify, 8, 3, "YNY"),
            "Classify H=8  NL=3 B=YNY ",
        ),
    ] {
        let reuse = reuse_search(&cfg, &ZC706).expect("fits");
        let params = Params::init(&cfg, &mut Rng::new(0));
        let accel = Accelerator::new(&cfg, &params, reuse, 0);
        let syn = accel.resources_synthesized();
        let est = accel.resources_estimated();
        let err = (syn.dsps - est.dsps).abs() / syn.dsps * 100.0;
        let u = syn.utilization(&ZC706);
        println!(
            "\n{label}  R={{x:{},h:{},d:{}}}",
            reuse.rx, reuse.rh, reuse.rd
        );
        println!(
            "  available LUT {:>7}  FF {:>7}  BRAM {:>5}  DSP {:>5}",
            ZC706.luts, ZC706.ffs, ZC706.brams, ZC706.dsps
        );
        println!(
            "  used      LUT {:>7.0}  FF {:>7.0}  BRAM {:>5.0}  DSP {:>5.0}",
            syn.luts, syn.ffs, syn.brams, syn.dsps
        );
        println!(
            "  utilised  LUT {:>6.1}%  FF {:>6.1}%  BRAM {:>4.1}%  DSP {:>4.1}%",
            u[0], u[1], u[2], u[3]
        );
        println!(
            "  DSP model estimate {:.0} vs synthesised {:.0} -> {:.2}% error \
             (paper: <2%)",
            est.dsps, syn.dsps, err
        );
    }
}

// ---------------------------------------------------------------------------
// Table IV: FPGA vs CPU vs GPU.
// ---------------------------------------------------------------------------

fn table4() {
    banner(
        "Table IV — latency/power/energy: FPGA vs CPU vs GPU, S=30\n\
         paper (anomaly b=50):  FPGA 41.3 ms / 3.44 W / 0.005 J\n\
               CPU 4011 ms / 15 W / 2.01 J   GPU 379.8 ms / 69 W / 0.53 J",
    );
    let artifacts = Path::new("artifacts");
    let mut runtime = Runtime::new(artifacts).ok();
    if runtime.is_none() {
        println!("(artifacts missing: CPU column will be skipped — run `make artifacts`)");
    }
    for (cfg, reuse) in [
        (
            ArchConfig::new(Task::Anomaly, 16, 2, "YNYN"),
            reuse_search(
                &ArchConfig::new(Task::Anomaly, 16, 2, "YNYN"),
                &ZC706,
            )
            .unwrap(),
        ),
        (
            ArchConfig::new(Task::Classify, 8, 3, "YNY"),
            reuse_search(
                &ArchConfig::new(Task::Classify, 8, 3, "YNY"),
                &ZC706,
            )
            .unwrap(),
        ),
    ] {
        let s = 30;
        let res = ResourceModel::estimate(&cfg, &reuse);
        let fpga_w = PowerModel::fpga_watts(&res);
        println!(
            "\n{}  R={{x:{},h:{},d:{}}}  FPGA power {:.2} W",
            cfg.name(),
            reuse.rx,
            reuse.rh,
            reuse.rd,
            fpga_w
        );
        println!(
            "{:>6} | {:>12} {:>12} {:>12} | {:>10} {:>10} {:>10}",
            "batch", "FPGA [ms]", "CPU [ms]", "GPU [ms]", "FPGA [J]",
            "CPU [J]", "GPU [J]"
        );
        for batch in [50usize, 200] {
            let sim = PipelineSim::new(&cfg, reuse);
            let fpga_ms = sim.simulate_ms(batch, s, ZC706.clock_hz);
            let gpu_ms = GpuModel::latency_ms(&cfg, batch, s);
            // CPU: measured PJRT wallclock on the batched fwd artifact.
            let cpu_ms = runtime.as_mut().and_then(|rt| {
                measure_cpu_ms(rt, &cfg, batch, s).ok()
            });
            let fpga_j = PowerModel::joules_per_sample(fpga_w, fpga_ms, batch);
            let gpu_j = PowerModel::joules_per_sample(
                PowerModel::gpu_watts(),
                gpu_ms,
                batch,
            );
            let cpu_j = cpu_ms.map(|ms| {
                PowerModel::joules_per_sample(PowerModel::cpu_watts(), ms, batch)
            });
            println!(
                "{:>6} | {:>12.2} {:>12} {:>12.2} | {:>10.4} {:>10} {:>10.4}",
                batch,
                fpga_ms,
                cpu_ms
                    .map(|v| format!("{v:.2}"))
                    .unwrap_or_else(|| "n/a".into()),
                gpu_ms,
                fpga_j,
                cpu_j
                    .map(|v| format!("{v:.4}"))
                    .unwrap_or_else(|| "n/a".into()),
                gpu_j
            );
        }
    }
    println!(
        "\nShape to check vs the paper: FPGA fastest and ~100x more \
         energy-efficient than GPU; GPU latency nearly batch-insensitive \
         (launch-bound); CPU slowest at large batch."
    );
}

/// Measured PJRT-CPU latency for a batched Bayesian inference
/// (rows = batch * S, matching the paper's PyTorch batching).
fn measure_cpu_ms(
    rt: &mut Runtime,
    cfg: &ArchConfig,
    batch: usize,
    s: usize,
) -> anyhow::Result<f64> {
    let rows = batch * s;
    let name = format!("{}.fwd_n{rows}", cfg.name());
    let meta = rt
        .manifest
        .find(&name)
        .ok_or_else(|| anyhow::anyhow!("no artifact {name}"))?
        .clone();
    let params = Params::init(cfg, &mut Rng::new(0));
    let beats = data::generate(batch, 3);
    let mut xs = Vec::with_capacity(rows * cfg.seq_len);
    for b in 0..batch {
        for _ in 0..s {
            xs.extend_from_slice(beats.beat(b));
        }
    }
    let masks =
        bayes_rnn_fpga::nn::model::Masks::sample(cfg, rows, &mut Rng::new(1));
    let mut args: Vec<HostValue> = params
        .tensors
        .iter()
        .map(|t| HostValue::F32(t.clone()))
        .collect();
    args.push(HostValue::F32(Tensor::new(
        vec![rows, cfg.seq_len, cfg.input_dim],
        xs,
    )));
    for m in &masks.tensors {
        args.push(HostValue::F32(m.clone()));
    }
    let exe = rt.load(&meta.name)?;
    // Warm-up once, then time.
    exe.run(&args)?;
    let t0 = Instant::now();
    exe.run(&args)?;
    Ok(t0.elapsed().as_secs_f64() * 1e3)
}

// ---------------------------------------------------------------------------
// Tables V/VI: optimisation framework.
// ---------------------------------------------------------------------------

fn table56(task: Task, lookup: &LookupTable) {
    let (title, paper) = match task {
        Task::Anomaly => (
            "Table V — optimisation framework, anomaly detection",
            "paper: Opt-Latency -> {8,1,NN} 6.94 ms; Opt-Acc/AP/AUC -> \
             {16,2,YNYN} 165 ms, ACC 0.96 AP 0.98 AUC 0.99",
        ),
        Task::Classify => (
            "Table VI — optimisation framework, classification",
            "paper: Opt-Latency -> {8,1,N} 3.44 ms; Opt-Accuracy -> \
             {8,3,NYN} 0.93; Opt-Precision -> {8,3,YNY} 0.69; Opt-Recall \
             -> {8,2,YN} 0.67; Opt-Entropy -> {8,3,YNN} 0.60 nats",
        ),
    };
    banner(&format!("{title}\n{paper}"));
    let mut opt = Optimizer::new(&ZC706, lookup);
    opt.batch = 200;
    opt.mc_samples = 30;
    println!(
        "{:<14} {:>18} {:>12} {:>4} {:>11} {:>11}  metrics",
        "Mode", "A:{H,NL,B}", "R:{x,h,d}", "S", "FPGA [ms]", "GPU [ms]"
    );
    for mode in Optimizer::modes_for(task) {
        match opt.optimize(task, mode) {
            Some(c) => {
                let metr: Vec<String> = c
                    .metrics
                    .iter()
                    .map(|(k, v)| format!("{k}={v:.3}"))
                    .collect();
                println!(
                    "{:<14} {:>18} {:>12} {:>4} {:>11.2} {:>11.2}  {}",
                    c.mode,
                    format!(
                        "{{{},{},{}}}",
                        c.arch.hidden,
                        c.arch.nl,
                        c.arch.bayes_str()
                    ),
                    format!(
                        "{{{},{},{}}}",
                        c.reuse.rx, c.reuse.rh, c.reuse.rd
                    ),
                    c.s,
                    c.fpga_latency_ms,
                    c.gpu_latency_ms,
                    metr.join(" ")
                );
            }
            None => {
                println!("{:<14} (no feasible configuration)", mode.name())
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Ablation: latency model vs cycle-accurate simulation (the paper's
// 2.26% / 2.13% model-error check).
// ---------------------------------------------------------------------------

fn ablation_latency_model() {
    banner(
        "Ablation — analytic latency model vs cycle-accurate simulation\n\
         paper: prediction error 2.26% (anomaly) / 2.13% (classification)",
    );
    println!(
        "{:<26} {:>6} {:>4} {:>12} {:>12} {:>8}",
        "arch", "batch", "S", "sim [cyc]", "model [cyc]", "err %"
    );
    for (cfg, reuse) in [
        (
            ArchConfig::new(Task::Anomaly, 16, 2, "YNYN"),
            ReuseFactors::new(16, 5, 16),
        ),
        (
            ArchConfig::new(Task::Classify, 8, 3, "YNY"),
            ReuseFactors::new(12, 1, 1),
        ),
        (
            ArchConfig::new(Task::Classify, 8, 1, "N"),
            ReuseFactors::new(2, 1, 1),
        ),
        (
            ArchConfig::new(Task::Anomaly, 8, 1, "NN"),
            ReuseFactors::new(4, 2, 4),
        ),
    ] {
        for (batch, s) in [(1usize, 1usize), (50, 30), (200, 30)] {
            let sim = PipelineSim::new(&cfg, reuse);
            let rep = sim.simulate(batch, s);
            println!(
                "{:<26} {:>6} {:>4} {:>12} {:>12} {:>8.2}",
                cfg.name(),
                batch,
                s,
                rep.cycles,
                rep.model_cycles,
                rep.model_error * 100.0
            );
        }
    }
    // Cross-check with the closed-form used by the DSE.
    let cfg = ArchConfig::new(Task::Classify, 8, 3, "YNY");
    let r = ReuseFactors::new(12, 1, 1);
    println!(
        "\nclosed-form batch_ms (DSE path): {:.2} ms vs paper 25.23 ms",
        LatencyModel::batch_ms(&cfg, &r, 50, 30, ZC706.clock_hz)
    );
}

// ---------------------------------------------------------------------------
// Extension ablations (paper Sec. III-A note + future work).
// ---------------------------------------------------------------------------

/// GRU vs LSTM engines at matched (I, H, R): resources + numerics drift.
fn ablation_cells() {
    banner(
        "Ablation — recurrent cell: LSTM vs GRU engines (paper: 'a similar\n\
         design logic can be used for other recurrent units such as the\n\
         gated recurrent unit')",
    );
    use bayes_rnn_fpga::fpga::engine::LstmEngine;
    use bayes_rnn_fpga::fpga::gru::GruEngine;
    use bayes_rnn_fpga::fixedpoint::Fx16;
    let mut rng = Rng::new(0);
    println!(
        "{:>4} {:>4} {:>4} | {:>10} {:>10} | {:>10} {:>10}",
        "I", "H", "R", "LSTM DSPs", "GRU DSPs", "LSTM us/st", "GRU us/st"
    );
    for (idim, hdim, r) in [(1usize, 8usize, 1usize), (8, 16, 2), (16, 32, 4)]
    {
        let rt = |rng: &mut Rng, shape: &[usize]| {
            Tensor::from_fn(shape, |_| rng.normal_scaled(0.0, 0.3) as f32)
        };
        let lwx = rt(&mut rng, &[4, idim, hdim]);
        let lwh = rt(&mut rng, &[4, hdim, hdim]);
        let lb = rt(&mut rng, &[4, hdim]);
        let gwx = rt(&mut rng, &[3, idim, hdim]);
        let gwh = rt(&mut rng, &[3, hdim, hdim]);
        let gb = rt(&mut rng, &[3, hdim]);
        let mut lstm = LstmEngine::new(&lwx, &lwh, &lb, r, r, true);
        let mut gru = GruEngine::new(&gwx, &gwh, &gb, r, r, true);
        let x: Vec<Fx16> = (0..idim)
            .map(|i| Fx16::from_f32((i as f32 * 0.4).sin()))
            .collect();
        let iters = 3000;
        let t0 = Instant::now();
        for _ in 0..iters {
            lstm.step(&x);
        }
        let lstm_us = t0.elapsed().as_secs_f64() / iters as f64 * 1e6;
        let t1 = Instant::now();
        for _ in 0..iters {
            gru.step(&x);
        }
        let gru_us = t1.elapsed().as_secs_f64() / iters as f64 * 1e6;
        println!(
            "{:>4} {:>4} {:>4} | {:>10} {:>10} | {:>10.2} {:>10.2}",
            idim,
            hdim,
            r,
            lstm.dsps_synthesized(),
            gru.dsps_synthesized(),
            lstm_us,
            gru_us
        );
    }
    println!("GRU: 3 gates + 16-bit tail => ~25% fewer DSPs, fewer mask bits.");
}

/// Variable dropout rates in hardware (paper future work): rate accuracy
/// + the accuracy/uncertainty trade-off p controls.
fn ablation_dropout_rates() {
    banner(
        "Ablation — programmable dropout rates (paper future work:\n\
         'supporting a wide variety of dropout rates in hardware')",
    );
    use bayes_rnn_fpga::lfsr::VariableSampler;
    println!("{:>8} {:>12} {:>12}", "p req.", "p realised", "p measured");
    for &p in &[0.0625f64, 0.125, 0.25, 0.375, 0.5] {
        let mut s = VariableSampler::new(7, 8, p);
        let n = 100_000;
        let zeros = (0..n).filter(|_| s.sample() == 0.0).count();
        println!(
            "{:>8.4} {:>12.4} {:>12.4}",
            p,
            s.effective_p(),
            zeros as f64 / n as f64
        );
    }
    // Algorithmic effect: entropy/accuracy vs p on a trained classifier.
    let (train, test) = data::splits(0);
    let te = test.subset(&(0..200).collect::<Vec<_>>());
    let noise = data::gaussian_noise(30, 0);
    println!(
        "\n{:>8} {:>9} {:>9}  (classifier H=8 NL=2 B=YY, S=20)",
        "p", "ACC", "H [nats]"
    );
    for &p in &[0.0f32, 0.0625, 0.125, 0.25] {
        let mut cfg = ArchConfig::new(Task::Classify, 8, 2, "YY");
        cfg.dropout_p = p;
        let mut tr = NativeTrainer::new(
            cfg.clone(),
            TrainOpts { epochs: 15, batch: 64, lr: 5e-3, seed: 0 },
        );
        tr.fit(&train);
        let mut pr = ModelPredictor::new(&tr.model, 3);
        let rep = eval_classify(&mut pr, &te, &noise, 20);
        println!("{:>8.4} {:>9.3} {:>9.3}", p, rep.accuracy, rep.noise_entropy);
    }
    println!("Higher p trades accuracy for uncertainty (calibration) — the\n\
              trade-off the paper fixes at p = 1/8 for hardware reasons.");
}

/// Open-loop Poisson serving: latency vs offered load on the FPGA engine.
fn openloop_serving() {
    banner(
        "Open-loop serving — Poisson arrivals through the coordinator\n\
         (latency knee as offered load approaches engine capacity)",
    );
    use bayes_rnn_fpga::coordinator::loadgen::{replay, PoissonTrace};
    use bayes_rnn_fpga::coordinator::{
        BatchPolicy, Engine, Server, ServerConfig,
    };
    let cfg = ArchConfig::new(Task::Classify, 8, 3, "YNY");
    let (train, test) = data::splits(0);
    let mut tr = NativeTrainer::new(
        cfg.clone(),
        TrainOpts { epochs: 8, batch: 64, lr: 5e-3, seed: 0 },
    );
    tr.fit(&train);
    let params = tr.model.params.tensors.clone();
    let reuse = reuse_search(&cfg, &ZC706).unwrap();
    let s = 30;
    println!(
        "{:>12} {:>10} {:>10} {:>10}",
        "load [req/s]", "p50 [ms]", "p99 [ms]", "served/s"
    );
    for rate in [50.0f64, 200.0, 800.0] {
        let c2 = cfg.clone();
        let p2 = params.clone();
        let mut server = Server::start(
            move || {
                let m = Model::new(
                    c2.clone(),
                    Params { tensors: p2.clone() },
                );
                Engine::fpga(&c2, &m, reuse, s, 3)
            },
            ServerConfig {
                policy: BatchPolicy::stream(),
                queue_depth: 1024,
            },
        );
        let n = (rate * 1.2).max(40.0) as usize; // ~1.2 s of traffic
        let trace = PoissonTrace::generate(rate, n, &test, 5);
        let t0 = Instant::now();
        let receivers = replay(&trace, &mut server, &test);
        for rx in receivers {
            rx.recv().unwrap();
        }
        let wall = t0.elapsed();
        let mut summary = server.join();
        println!(
            "{:>12.0} {:>10.2} {:>10.2} {:>10.1}",
            rate,
            summary.e2e.percentile_ms(50.0),
            summary.e2e.percentile_ms(99.0),
            summary.served as f64 / wall.as_secs_f64()
        );
    }
}

// ---------------------------------------------------------------------------
// Perf microbenches (EXPERIMENTS.md §Perf).
// ---------------------------------------------------------------------------

/// Multi-backend kernel-layer microbench (docs/kernels.md §Backends):
/// per-backend raw MVM MMAC/s (scalar | blocked | simd, fixed point
/// and f32) with a checksum whose bit-identity check exits non-zero on
/// drift; packed-weight bytes/MAC per format (>= 2x reduction at q8 is
/// hard-asserted); then the accelerator-level MC-batch comparison —
/// per-backend `predict_seeded` beats/s at S in {10, 30, 100}, bits
/// re-checked. Writes one single-line JSON summary to
/// bench_results/kernel_microbench.json (wired into the CI bench
/// gate).
fn kernels_bench() {
    use bayes_rnn_fpga::fixedpoint::{Fx16, MacAcc, QFormat};
    use bayes_rnn_fpga::kernels::{KernelBackend, PackedWeights};

    banner("Kernels — scalar vs blocked vs simd compute layer");
    let iters = env_usize("REPRO_BENCH_KERNEL_ITERS", 60).max(1);

    // 1. Raw fixed-point MVM: one h128 gate matmul, 100 sample rows,
    //    per backend, with a drift gate on the finished checksums.
    let (in_dim, out_dim, rows) = (128usize, 128usize, 100usize);
    let mut rng = Rng::new(7);
    let w: Vec<Fx16> = (0..in_dim * out_dim)
        .map(|_| Fx16::from_f32(rng.normal_scaled(0.0, 0.3) as f32))
        .collect();
    let x: Vec<Fx16> = (0..rows * in_dim)
        .map(|_| Fx16::from_f32(rng.normal() as f32))
        .collect();
    let checksum_fx = |acc: &[MacAcc]| -> i64 {
        acc.iter().map(|a| a.finish(Fx16::ZERO).0 as i64).sum()
    };
    let mut mvm_json = Vec::new();
    let mut fx_checksums = Vec::new();
    for backend in KernelBackend::ALL {
        let kernel = backend.kernel();
        let mut acc = vec![MacAcc::new(); rows * out_dim];
        let t0 = Instant::now();
        for _ in 0..iters {
            for a in acc.iter_mut() {
                *a = MacAcc::new();
            }
            kernel.mvm_fx(
                &w, in_dim, out_dim, rows, &x, in_dim, None, &mut acc,
                out_dim,
            );
        }
        let dt = t0.elapsed().as_secs_f64();
        let mmacs = (iters * rows * in_dim * out_dim) as f64 / dt / 1e6;
        let ck = checksum_fx(&acc);
        println!(
            "mvm_fx  {:<8} {in_dim}x{out_dim} x {rows} rows: \
             {mmacs:>7.0} MMAC/s  checksum {ck}",
            backend.name()
        );
        fx_checksums.push((backend.name(), ck));
        mvm_json.push(format!(
            "{{\"backend\":\"{}\",\"fx_mmacs\":{mmacs:.1},\
             \"fx_checksum\":{ck}}}",
            backend.name()
        ));
    }
    if fx_checksums.iter().any(|&(_, c)| c != fx_checksums[0].1) {
        eprintln!(
            "FATAL: kernel backend checksum drift — {fx_checksums:?}"
        );
        std::process::exit(1);
    }

    // 1b. f32 MVM at h64 — the ISSUE 5 simd-vs-blocked record point
    //     (>= 1.5x is recorded, not hard-gated).
    let (fi, fo, fr) = (64usize, 64usize, 100usize);
    let wf: Vec<f32> = (0..fi * fo).map(|_| rng.normal() as f32).collect();
    let xf: Vec<f32> = (0..fr * fi).map(|_| rng.normal() as f32).collect();
    let f32_iters = iters * 4;
    let mut f32_rates = Vec::new();
    for backend in KernelBackend::ALL {
        let kernel = backend.kernel();
        let mut out = vec![0f32; fr * fo];
        let t0 = Instant::now();
        for _ in 0..f32_iters {
            out.fill(0.0);
            kernel.mvm_f32(&wf, fi, fo, fr, &xf, fi, None, &mut out, fo);
        }
        let dt = t0.elapsed().as_secs_f64();
        let mmacs = (f32_iters * fr * fi * fo) as f64 / dt / 1e6;
        println!(
            "mvm_f32 {:<8} {fi}x{fo} x {fr} rows: {mmacs:>7.0} MMAC/s",
            backend.name()
        );
        f32_rates.push((backend.name(), mmacs));
    }
    let simd_vs_blocked_f32 = f32_rates[2].1 / f32_rates[1].1.max(1e-9);
    println!(
        "simd vs blocked (f32 h64): {simd_vs_blocked_f32:.2}x  {}",
        if simd_vs_blocked_f32 >= 1.5 {
            "PASS (>=1.5x)"
        } else {
            "WARN (<1.5x, recorded)"
        }
    );

    // 1c. Packed-weight bandwidth: bytes/MAC per format. The q8 i8
    //     plane must at least halve the Fx16 baseline's 2 bytes/MAC
    //     (ISSUE 5 acceptance — hard gate).
    let mut packed_json = Vec::new();
    for fmt in [QFormat::Q8_ACT, QFormat::Q12_ACT, QFormat::Q16_ACT] {
        let wq: Vec<Fx16> = w.iter().map(|v| fmt.quantize(v.to_f32())).collect();
        let p = PackedWeights::pack(&wq, in_dim, out_dim, fmt);
        let bpm = p.bytes_per_weight();
        println!(
            "packed  {:<8} {:>4.1} bytes/MAC (Fx16 baseline 2.0, f32 4.0)",
            fmt.name(),
            bpm
        );
        packed_json
            .push(format!("{{\"format\":\"{}\",\"bytes_per_mac\":{bpm:.2}}}", fmt.name()));
        if fmt == QFormat::Q8_ACT && bpm > 1.0 {
            eprintln!("FATAL: q8 packing must halve weight bytes/MAC, got {bpm}");
            std::process::exit(1);
        }
    }

    // 2. Accelerator MC batching: per-backend predict_seeded beats/s
    //    (scalar = the legacy per-sample loop) at S in {10, 30, 100}.
    let mut cfg = ArchConfig::new(Task::Classify, 32, 2, "YY");
    cfg.seq_len = 64;
    let params = Params::init(&cfg, &mut Rng::new(1));
    let reuse = ReuseFactors::new(1, 1, 1);
    let beat: Vec<f32> =
        (0..cfg.seq_len).map(|i| (i as f32 * 0.23).sin()).collect();
    let s_max = env_usize("REPRO_BENCH_KERNEL_SMAX", 100);
    let mut points = Vec::new();
    let mut speedup_s100 = 0f64;
    let mut simd_speedup_s100 = 0f64;
    for s in [10usize, 30, 100] {
        if s > s_max {
            continue;
        }
        let beats = if s >= 100 { 4 } else { 8 };
        let mut rates = Vec::new();
        let mut ref_samples: Option<Vec<f32>> = None;
        for backend in KernelBackend::ALL {
            let mut acc = Accelerator::new(&cfg, &params, reuse, 9);
            acc.set_kernel_backend(backend);
            if backend == KernelBackend::Scalar {
                acc.scalar_reference = true; // full legacy cost model
            }
            // Warm + bit-identity gate.
            let samples = acc.predict_seeded(&beat, 0, 0, s).samples;
            if let Some(want) = &ref_samples {
                if &samples != want {
                    eprintln!(
                        "FATAL: {} backend drifted from scalar at S={s}",
                        backend.name()
                    );
                    std::process::exit(1);
                }
            } else {
                ref_samples = Some(samples);
            }
            let t0 = Instant::now();
            for r in 0..beats {
                let _ = acc.predict_seeded(&beat, r as u64, 0, s);
            }
            rates.push(beats as f64 / t0.elapsed().as_secs_f64());
        }
        let (rate_s, rate_b, rate_v) = (rates[0], rates[1], rates[2]);
        let speedup = rate_b / rate_s.max(1e-12);
        let simd_speedup = rate_v / rate_b.max(1e-12);
        if s == 100 {
            speedup_s100 = speedup;
            simd_speedup_s100 = simd_speedup;
        }
        println!(
            "predict S={s:<4} scalar {rate_s:>8.1}  blocked {rate_b:>8.1}  \
             simd {rate_v:>8.1} beats/s   blocked/scalar {speedup:.2}x  \
             simd/blocked {simd_speedup:.2}x"
        );
        points.push(format!(
            "{{\"s\":{s},\"scalar_beats_per_s\":{rate_s:.3},\
             \"blocked_beats_per_s\":{rate_b:.3},\
             \"simd_beats_per_s\":{rate_v:.3},\
             \"speedup\":{speedup:.3},\
             \"simd_vs_blocked\":{simd_speedup:.3}}}"
        ));
    }
    if s_max >= 100 {
        println!(
            "blocked vs scalar @ S=100: {speedup_s100:.2}x  {}",
            if speedup_s100 >= 2.0 { "PASS (>=2x)" } else { "WARN (<2x)" }
        );
    }

    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("bench_results");
    std::fs::create_dir_all(&dir).expect("create bench_results/");
    // The S=100 speedups only exist when the S=100 point ran (smoke
    // runs cap SMAX lower): emit null, not a fake 0.000, so downstream
    // diffs don't read a skipped point as a catastrophic regression.
    let (s100, simd_s100) = if s_max >= 100 {
        (
            format!("{speedup_s100:.3}"),
            format!("{simd_speedup_s100:.3}"),
        )
    } else {
        ("null".into(), "null".into())
    };
    let line = format!(
        "{{\"scenario\":\"kernel_microbench\",\"arch\":\"{}\",\
         \"backends\":[{}],\"bits_ok\":true,\
         \"simd_vs_blocked_f32_h64\":{simd_vs_blocked_f32:.3},\
         \"packed\":[{}],\"points\":[{}],\
         \"speedup_s100\":{s100},\"simd_speedup_s100\":{simd_s100},\
         \"proc\":{}}}",
        cfg.name(),
        mvm_json.join(","),
        packed_json.join(","),
        points.join(","),
        proc_json()
    );
    let path = dir.join("kernel_microbench.json");
    std::fs::write(&path, format!("{line}\n")).expect("write summary");
    println!("  -> {}", path.display());
    // Committed trajectory copy at the repo root (scripts/bench-compare
    // checks it against the saved baseline).
    let committed =
        Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_kernels.json");
    std::fs::write(&committed, format!("{line}\n"))
        .expect("write BENCH_kernels.json");
    println!("  -> {}", committed.display());
}

/// Mask-generation scenario (ISSUE 8): (1) word-level LFSR fill
/// (`keep_word`) vs the legacy bit-by-bit closure fill over identical
/// bitplanes — throughput in Mbit/s with an exact plane-checksum drift
/// gate; (2) the seed-indexed mask bank on a repeated-seed workload
/// (the same request replayed): bank-off vs bank-warm beats/s, hit
/// rate, and the mask-generation share of a blocked predict recovered
/// by the bank. Bank on/off sample sets must be bit-identical — any
/// drift exits 1. One-line JSON to bench_results/maskgen.json plus the
/// committed BENCH_maskgen.json trajectory copy.
fn maskgen_bench() {
    use bayes_rnn_fpga::kernels::{BitPlanes, MaskBank};
    use bayes_rnn_fpga::lfsr::BernoulliSampler;
    use std::sync::Arc;

    banner("Maskgen — word-level RNG + seed-indexed mask bank");
    let iters = env_usize("REPRO_BENCH_MASKGEN_ITERS", 40).max(1);
    let s_max = env_usize("REPRO_BENCH_MASKGEN_SMAX", 30).max(2);

    // 1. Word-fill vs bit-fill, same sampler seeds, same planes: the
    //    PR 5 draw-order contract says the bits are identical, so an
    //    exact FNV checksum over the row words gates drift.
    let (rows, width) = (64usize, 512usize);
    let total_bits = (iters * rows * width) as f64;
    let checksum = |p: &BitPlanes| -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        for r in 0..p.rows() {
            for &w in p.row_words(r) {
                h = (h ^ w).wrapping_mul(0x100000001b3);
            }
        }
        h
    };
    let mut bit_planes = BitPlanes::ones(rows, width);
    let t0 = Instant::now();
    for i in 0..iters {
        let mut s = BernoulliSampler::new(0x5EED ^ i as u64);
        for r in 0..rows {
            bit_planes.fill_row(r, || s.sample() != 0.0);
        }
    }
    let bit_rate = total_bits / t0.elapsed().as_secs_f64() / 1e6;
    let mut word_planes = BitPlanes::ones(rows, width);
    let t0 = Instant::now();
    for i in 0..iters {
        let mut s = BernoulliSampler::new(0x5EED ^ i as u64);
        for r in 0..rows {
            word_planes.fill_row_words(r, |n| s.keep_word(n));
        }
    }
    let word_rate = total_bits / t0.elapsed().as_secs_f64() / 1e6;
    let (ck_bit, ck_word) =
        (checksum(&bit_planes), checksum(&word_planes));
    let fill_speedup = word_rate / bit_rate.max(1e-9);
    println!(
        "fill    bit {bit_rate:>8.1} Mbit/s   word {word_rate:>8.1} \
         Mbit/s   word/bit {fill_speedup:.2}x   checksum {ck_word:#018x}"
    );
    if ck_bit != ck_word {
        eprintln!(
            "FATAL: word-fill drifted from bit-fill \
             ({ck_bit:#018x} vs {ck_word:#018x})"
        );
        std::process::exit(1);
    }

    // 2. Bank off/on on a repeated-seed workload: the same request
    //    (fixed req_seed) replayed against the blocked path. Warm-bank
    //    passes skip the LFSR mask generation entirely; the throughput
    //    delta IS the mask-gen share of a blocked predict.
    let mut cfg = ArchConfig::new(Task::Classify, 32, 2, "YY");
    cfg.seq_len = 64;
    let params = Params::init(&cfg, &mut Rng::new(1));
    let reuse = ReuseFactors::new(1, 1, 1);
    let beat: Vec<f32> =
        (0..cfg.seq_len).map(|i| (i as f32 * 0.23).sin()).collect();
    let beats = (iters / 4).max(2);

    let mut off = Accelerator::new(&cfg, &params, reuse, 9);
    let want = off.predict_seeded(&beat, 0, 0, s_max).samples; // warm
    let t0 = Instant::now();
    for _ in 0..beats {
        let _ = off.predict_seeded(&beat, 0, 0, s_max);
    }
    let rate_off = beats as f64 / t0.elapsed().as_secs_f64();

    let bank = Arc::new(MaskBank::new(8 << 20));
    let mut on = Accelerator::new(&cfg, &params, reuse, 9);
    on.set_mask_bank(Some(Arc::clone(&bank)));
    let cold = on.predict_seeded(&beat, 0, 0, s_max).samples;
    if cold != want {
        eprintln!("FATAL: bank-cold samples drifted from bank-off");
        std::process::exit(1);
    }
    let t0 = Instant::now();
    for _ in 0..beats {
        let _ = on.predict_seeded(&beat, 0, 0, s_max);
    }
    let rate_on = beats as f64 / t0.elapsed().as_secs_f64();
    let warm = on.predict_seeded(&beat, 0, 0, s_max).samples;
    if warm != want {
        eprintln!("FATAL: bank-warm samples drifted from bank-off");
        std::process::exit(1);
    }
    let st = bank.stats();
    let hit_rate =
        st.hits as f64 / (st.hits + st.misses).max(1) as f64;
    let bank_speedup = rate_on / rate_off.max(1e-12);
    let mask_frac = (1.0 - rate_off / rate_on.max(1e-12)).max(0.0);
    println!(
        "predict S={s_max:<4} bank off {rate_off:>8.2} beats/s   \
         warm {rate_on:>8.2} beats/s   speedup {bank_speedup:.2}x   \
         mask-gen share ~{:.1}%",
        mask_frac * 100.0
    );
    println!(
        "bank    hits {}  misses {}  hit rate {hit_rate:.3}  \
         resident {:.1} KiB",
        st.hits,
        st.misses,
        st.resident_bytes as f64 / 1024.0
    );

    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("bench_results");
    std::fs::create_dir_all(&dir).expect("create bench_results/");
    let line = format!(
        "{{\"scenario\":\"maskgen\",\"arch\":\"{}\",\"iters\":{iters},\
         \"s\":{s_max},\"bitfill_mbits_per_s\":{bit_rate:.1},\
         \"wordfill_mbits_per_s\":{word_rate:.1},\
         \"wordfill_speedup\":{fill_speedup:.3},\
         \"mask_checksum\":\"{ck_word:#018x}\",\"bits_ok\":true,\
         \"bank\":{{\"off_beats_per_s\":{rate_off:.3},\
         \"on_beats_per_s\":{rate_on:.3},\"speedup\":{bank_speedup:.3},\
         \"hits\":{},\"misses\":{},\"hit_rate\":{hit_rate:.4},\
         \"resident_bytes\":{}}},\"mask_cost_frac\":{mask_frac:.4},\
         \"proc\":{}}}",
        cfg.name(),
        st.hits,
        st.misses,
        st.resident_bytes,
        proc_json()
    );
    let path = dir.join("maskgen.json");
    std::fs::write(&path, format!("{line}\n")).expect("write summary");
    println!("  -> {}", path.display());
    let committed =
        Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_maskgen.json");
    std::fs::write(&committed, format!("{line}\n"))
        .expect("write BENCH_maskgen.json");
    println!("  -> {}", committed.display());
}

/// Precision-axis scenario (ISSUE 4 satellite): quality and speed vs
/// bitwidth. Trains one Bayesian classifier, then for each format in
/// the DSE's precision space measures (a) fixed-point accuracy/AP on a
/// held-out window, (b) simulated-engine throughput in beats/s at
/// S = 20, and (c) the modelled deployment latency + DSP footprint at
/// that format's constraint-solved reuse. Before any of that it
/// re-checks the Q6.10 contract: the parametric q16 path must be
/// bit-identical (checksum-exact) to the legacy constructor AND the
/// legacy per-sample scalar loop — any drift exits non-zero.
fn precision_bench() {
    use bayes_rnn_fpga::dse::{precision_space, reuse_search_q};
    use bayes_rnn_fpga::fixedpoint::Precision;

    banner(
        "Precision — quantisation as a co-design axis (q8/q12/q16)\n\
         quality vs DSP/latency, Q6.10 checksum drift hard-fails",
    );
    // A DSP-constrained net (II > 1 at q16): the packed formats' freed
    // budget buys lower reuse, so the latency column actually moves.
    // Scale knobs (CI smoke uses small values, like serve_fleet's
    // REPRO_BENCH_* convention): full run by default.
    let epochs = env_usize("REPRO_BENCH_PRECISION_EPOCHS", 10);
    let eval_beats = env_usize("REPRO_BENCH_PRECISION_BEATS", 96);
    let s = env_usize("REPRO_BENCH_PRECISION_SAMPLES", 16).max(2);
    let cfg = ArchConfig::new(Task::Classify, 32, 2, "YY");
    let (train, test) = data::splits(0);
    let mut tr = NativeTrainer::new(
        cfg.clone(),
        TrainOpts { epochs, batch: 64, lr: 5e-3, seed: 0 },
    );
    tr.fit(&train.subset(&(0..256).collect::<Vec<_>>()));
    let te = test.subset(&(0..eval_beats.clamp(8, test.n)).collect::<Vec<_>>());
    let noise = data::gaussian_noise(16, 0);
    let beat: Vec<f32> =
        (0..cfg.seq_len).map(|i| (i as f32 * 0.23).sin()).collect();

    // --- Q6.10 drift gate -------------------------------------------
    let reuse16 = reuse_search(&cfg, &ZC706).expect("fits at q16");
    let checksum = |samples: &[f32]| -> f64 {
        samples.iter().map(|&v| v as f64).sum()
    };
    let mut legacy = Accelerator::new(&cfg, &tr.model.params, reuse16, 9);
    let want = legacy.predict_seeded(&beat, 3, 0, s);
    let mut parametric = Accelerator::with_precision(
        &cfg,
        &tr.model.params,
        reuse16,
        9,
        Precision::q16(),
    );
    let got = parametric.predict_seeded(&beat, 3, 0, s);
    let mut scalar = Accelerator::new(&cfg, &tr.model.params, reuse16, 9);
    scalar.scalar_reference = true;
    let scal = scalar.predict_seeded(&beat, 3, 0, s);
    if got.samples != want.samples || scal.samples != want.samples {
        eprintln!(
            "FATAL: Q6.10 checksum drift — parametric {:.9} / legacy \
             {:.9} / scalar {:.9}",
            checksum(&got.samples),
            checksum(&want.samples),
            checksum(&scal.samples)
        );
        std::process::exit(1);
    }
    println!(
        "Q6.10 bit-exactness: parametric == legacy == scalar \
         (checksum {:.6}) PASS",
        checksum(&want.samples)
    );

    // --- per-format quality + speed ---------------------------------
    println!(
        "\n{:>5} {:>12} {:>7} {:>8} {:>8} {:>10} {:>12}",
        "Q", "R:{x,h,d}", "DSP", "ACC", "AP", "beats/s", "model [ms]"
    );
    let mut points = Vec::new();
    for prec in precision_space() {
        let reuse = reuse_search_q(&cfg, &ZC706, &prec).expect("fits");
        let mut acc = Accelerator::with_precision(
            &cfg,
            &tr.model.params,
            reuse,
            9,
            prec.clone(),
        );
        let rep = eval_classify(&mut acc, &te, &noise, s);
        // Simulated-engine throughput: blocked predict_seeded beats/s.
        let bench_beats = 8;
        let t0 = Instant::now();
        for r in 0..bench_beats {
            let _ = acc.predict_seeded(&beat, r as u64, 0, s);
        }
        let beats_per_s = bench_beats as f64 / t0.elapsed().as_secs_f64();
        // Modelled deployment latency + footprint at this format (the
        // format enters through its constraint-solved reuse).
        let est = ResourceModel::estimate_q(&cfg, &reuse, &prec);
        let model_ms = LatencyModel::batch_ms(&cfg, &reuse, 50, s, ZC706.clock_hz);
        println!(
            "{:>5} {:>12} {:>7.0} {:>8.3} {:>8.3} {:>10.1} {:>12.2}",
            prec.name(),
            format!("{{{},{},{}}}", reuse.rx, reuse.rh, reuse.rd),
            est.dsps,
            rep.accuracy,
            rep.ap,
            beats_per_s,
            model_ms
        );
        points.push(format!(
            "{{\"precision\":\"{}\",\"reuse\":[{},{},{}],\
             \"dsps\":{:.1},\"accuracy\":{:.4},\"ap\":{:.4},\
             \"beats_per_s\":{:.3},\"model_ms\":{:.4}}}",
            prec.name(),
            reuse.rx,
            reuse.rh,
            reuse.rd,
            est.dsps,
            rep.accuracy,
            rep.ap,
            beats_per_s,
            model_ms
        ));
    }

    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("bench_results");
    std::fs::create_dir_all(&dir).expect("create bench_results/");
    let line = format!(
        "{{\"scenario\":\"precision\",\"arch\":\"{}\",\"samples\":{s},\
         \"q16_checksum\":{:.6},\"q16_bits_ok\":true,\"points\":[{}],\
         \"proc\":{}}}",
        cfg.name(),
        checksum(&want.samples),
        points.join(","),
        proc_json()
    );
    let path = dir.join("precision.json");
    std::fs::write(&path, format!("{line}\n")).expect("write summary");
    println!("  -> {}", path.display());
    // Committed trajectory copy at the repo root (scripts/bench-compare
    // checks it against the saved baseline).
    let committed =
        Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_precision.json");
    std::fs::write(&committed, format!("{line}\n"))
        .expect("write BENCH_precision.json");
    println!("  -> {}", committed.display());
}

fn perf() {
    banner("Perf — L3 hot-path microbenchmarks");
    // 1. Fixed-point LSTM engine step throughput.
    {
        let cfg = ArchConfig::new(Task::Classify, 16, 2, "NN");
        let params = Params::init(&cfg, &mut Rng::new(0));
        let mut accel =
            Accelerator::new(&cfg, &params, ReuseFactors::new(1, 1, 1), 0);
        let beat: Vec<f32> = (0..cfg.seq_len)
            .map(|i| (i as f32 * 0.21).sin())
            .collect();
        let iters = 200;
        let t0 = Instant::now();
        for _ in 0..iters {
            accel.run_pass(&beat);
        }
        let dt = t0.elapsed().as_secs_f64();
        let steps = iters * cfg.seq_len * cfg.num_lstm_layers();
        let macs_per_step = 4 * (16 * 16 + 16 * 16);
        println!(
            "fixed-point engine: {:.1} us/pass, {:.1} M cell-steps/s, \
             {:.0} MMAC/s",
            dt / iters as f64 * 1e6,
            steps as f64 / dt / 1e6,
            (steps * macs_per_step) as f64 / dt / 1e6
        );
    }
    // 2. Float engine forward throughput (batch row scaling).
    {
        let cfg = ArchConfig::new(Task::Classify, 16, 2, "NN");
        let model = Model::init(cfg.clone(), &mut Rng::new(0));
        let masks = bayes_rnn_fpga::nn::model::Masks::ones(&cfg, 30);
        let mut xs = Vec::new();
        let beats = data::generate(1, 0);
        for _ in 0..30 {
            xs.extend_from_slice(beats.beat(0));
        }
        let iters = 40;
        let t0 = Instant::now();
        for _ in 0..iters {
            let _ = model.forward(&xs, 30, &masks);
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "float engine fwd (30 rows x T140): {:.2} ms/call",
            dt / iters as f64 * 1e3
        );
    }
    // 3. Coordinator round-trip overhead (stream policy, trivial engine).
    {
        use bayes_rnn_fpga::coordinator::{
            BatchPolicy, Engine, Server, ServerConfig,
        };
        let mut cfg = ArchConfig::new(Task::Classify, 8, 1, "N");
        cfg.seq_len = 20;
        let model = Model::init(cfg.clone(), &mut Rng::new(0));
        let c2 = cfg.clone();
        let p = model.params.tensors.clone();
        let mut server = Server::start(
            move || {
                let m = Model::new(
                    c2.clone(),
                    Params { tensors: p.clone() },
                );
                Engine::fpga(&c2, &m, ReuseFactors::new(1, 1, 1), 1, 0)
            },
            ServerConfig {
                policy: BatchPolicy::stream(),
                queue_depth: 512,
            },
        );
        let n = 2000;
        let beat = vec![0.1f32; 20];
        let t0 = Instant::now();
        let rxs: Vec<_> =
            (0..n).map(|_| server.submit(beat.clone())).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        let mut summary = server.join();
        println!(
            "coordinator: {:.1} req/s end-to-end, e2e p50 {:.3} ms \
             (queue+dispatch overhead on a {:.0} us engine)",
            n as f64 / dt,
            summary.e2e.percentile_ms(50.0),
            summary.engine.mean_ms() * 1e3
        );
    }
}
