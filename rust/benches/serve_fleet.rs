//! Process-based serving-fleet bench harness (`cargo bench --bench
//! serve_fleet`, harness = false).
//!
//! Runs the *release binary* (`repro serve --json ...`) as a subprocess
//! per scenario — measuring the real end-to-end serving path, process
//! startup excluded from throughput (the binary times itself) — and
//! writes one single-line JSON summary per scenario under the gitignored
//! `bench_results/` directory:
//!
//!   baseline        1 engine, round-robin, FPGA-sim
//!   fan_out         4 engines, round-robin
//!   fleet_scaling   1/2/4/8 engines, least-loaded
//!   mc_shard        1/2/4 engines, MC-shard sample parallelism
//!
//! Checks printed at the end:
//!   * fan-out and 4-way MC-shard throughput vs. baseline (target ≥ 2x),
//!   * MC-shard prediction checksums vs. baseline (must match to 1e-3 —
//!     the sample-seeding invariant). A numeric mismatch exits non-zero;
//!     a missed throughput target only warns (machine-dependent).
//!
//! Env: REPRO_BIN overrides the binary path; REPRO_BENCH_REQUESTS and
//! REPRO_BENCH_SAMPLES scale the load (defaults 64 requests, S = 24).

use std::path::{Path, PathBuf};
use std::process::Command;

use bayes_rnn_fpga::jsonio::{self, Json};

const ARCH: &str = "classify_h8_nl1_Y";

fn manifest_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn find_binary() -> PathBuf {
    if let Ok(p) = std::env::var("REPRO_BIN") {
        return PathBuf::from(p);
    }
    let bin = manifest_dir().join("target/release/repro");
    if !bin.exists() {
        eprintln!("release binary missing; running `cargo build --release`");
        let status = Command::new("cargo")
            .args(["build", "--release", "--bin", "repro"])
            .current_dir(manifest_dir())
            .status()
            .expect("spawn cargo build");
        assert!(status.success(), "cargo build --release failed");
    }
    bin
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// One `repro serve --json` run, parsed.
struct Run {
    engines: usize,
    router: String,
    json_line: String,
    served: usize,
    rejected: usize,
    throughput: f64,
    e2e_p99_ms: f64,
    pred_checksum: f64,
    unc_checksum: f64,
}

fn serve(
    bin: &Path,
    engines: usize,
    router: &str,
    requests: usize,
    samples: usize,
) -> Run {
    let out = Command::new(bin)
        .args([
            "serve",
            "--arch",
            ARCH,
            "--engines",
            &engines.to_string(),
            "--router",
            router,
            "--backend",
            "fpga",
            "--requests",
            &requests.to_string(),
            "--samples",
            &samples.to_string(),
            "--json",
        ])
        .output()
        .expect("spawn repro serve");
    assert!(
        out.status.success(),
        "repro serve failed (engines={engines} router={router}):\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    let line = stdout
        .lines()
        .rev()
        .find(|l| l.trim_start().starts_with('{'))
        .unwrap_or_else(|| panic!("no JSON line in output:\n{stdout}"))
        .trim()
        .to_string();
    let j = jsonio::parse(&line).expect("parse serve JSON");
    let f = |key: &str| -> f64 {
        j.get(key).and_then(Json::as_f64).unwrap_or_else(|| {
            panic!("missing numeric field {key:?} in {line}")
        })
    };
    let e2e_p99_ms = j
        .get("e2e_ms")
        .and_then(|o| o.get("p99"))
        .and_then(Json::as_f64)
        .expect("e2e_ms.p99");
    Run {
        engines,
        router: router.to_string(),
        json_line: line.clone(),
        served: f("served") as usize,
        rejected: f("rejected") as usize,
        throughput: f("throughput_rps"),
        e2e_p99_ms,
        pred_checksum: f("pred_checksum"),
        unc_checksum: f("unc_checksum"),
    }
}

fn write_scenario(dir: &Path, name: &str, line: &str) {
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, format!("{line}\n")).expect("write summary");
    println!("  -> {}", path.display());
}

/// Wrap several runs into one single-line JSON scenario summary.
fn points_summary(name: &str, runs: &[&Run], extra: &str) -> String {
    let points: Vec<String> = runs
        .iter()
        .map(|r| {
            format!(
                "{{\"engines\":{},\"router\":\"{}\",\"served\":{},\
                 \"rejected\":{},\"throughput_rps\":{:.3},\
                 \"e2e_p99_ms\":{:.4}}}",
                r.engines,
                r.router,
                r.served,
                r.rejected,
                r.throughput,
                r.e2e_p99_ms
            )
        })
        .collect();
    format!(
        "{{\"scenario\":\"{name}\",\"arch\":\"{ARCH}\",\"points\":[{}]{}}}",
        points.join(","),
        extra
    )
}

fn main() {
    let bin = find_binary();
    let requests = env_usize("REPRO_BENCH_REQUESTS", 64);
    let samples = env_usize("REPRO_BENCH_SAMPLES", 24);
    let results = manifest_dir().join("bench_results");
    std::fs::create_dir_all(&results).expect("create bench_results/");
    println!(
        "serve_fleet harness: {} requests, S={samples}, arch {ARCH}",
        requests
    );

    // --- baseline: one FPGA-sim engine, streamed ---
    println!("[baseline] 1 engine, rr");
    let baseline = serve(&bin, 1, "rr", requests, samples);
    write_scenario(&results, "baseline", &baseline.json_line);

    // --- fan-out: 4 engines, whole-request round-robin ---
    println!("[fan_out] 4 engines, rr");
    let fan_out = serve(&bin, 4, "rr", requests, samples);
    write_scenario(&results, "fan_out", &fan_out.json_line);

    // --- fleet-scaling: throughput trajectory over engine count ---
    let mut scaling = Vec::new();
    for n in [1usize, 2, 4, 8] {
        println!("[fleet_scaling] {n} engines, least-loaded");
        scaling.push(serve(&bin, n, "least-loaded", requests, samples));
    }
    let refs: Vec<&Run> = scaling.iter().collect();
    write_scenario(
        &results,
        "fleet_scaling",
        &points_summary("fleet_scaling", &refs, ""),
    );

    // --- MC-shard sweep: split S across 1/2/4 engines ---
    let mut shard = Vec::new();
    for n in [1usize, 2, 4] {
        println!("[mc_shard] {n} engines, mc-shard");
        shard.push(serve(&bin, n, "mc-shard", requests, samples));
    }
    let mut worst_pred = 0f64;
    let mut worst_unc = 0f64;
    for r in &shard {
        worst_pred = worst_pred
            .max((r.pred_checksum - baseline.pred_checksum).abs());
        worst_unc =
            worst_unc.max((r.unc_checksum - baseline.unc_checksum).abs());
    }
    let numerics_ok = worst_pred < 1e-3 && worst_unc < 1e-3;
    let refs: Vec<&Run> = shard.iter().collect();
    let extra = format!(
        ",\"baseline_pred_checksum\":{:.6},\"max_pred_delta\":{:.6},\
         \"max_unc_delta\":{:.6},\"numerics_match\":{}",
        baseline.pred_checksum, worst_pred, worst_unc, numerics_ok
    );
    write_scenario(
        &results,
        "mc_shard",
        &points_summary("mc_shard", &refs, &extra),
    );

    // --- report ---
    println!("\nscenario           engines  served  rejected   req/s   vs base");
    let mut rows: Vec<(&str, &Run)> = vec![
        ("baseline", &baseline),
        ("fan_out", &fan_out),
    ];
    for r in &scaling {
        rows.push(("fleet_scaling", r));
    }
    for r in &shard {
        rows.push(("mc_shard", r));
    }
    for (name, r) in &rows {
        println!(
            "{name:<18} {:>7} {:>7} {:>9} {:>8.1} {:>8.2}x",
            r.engines,
            r.served,
            r.rejected,
            r.throughput,
            r.throughput / baseline.throughput.max(1e-9)
        );
    }

    let fan_ratio = fan_out.throughput / baseline.throughput.max(1e-9);
    let shard4 = shard.last().expect("mc-shard runs");
    let shard_ratio = shard4.throughput / baseline.throughput.max(1e-9);
    println!(
        "\nfan-out speedup  {fan_ratio:.2}x  {}",
        if fan_ratio >= 2.0 { "PASS (>=2x)" } else { "WARN (<2x)" }
    );
    println!(
        "mc-shard speedup {shard_ratio:.2}x  {}",
        if shard_ratio >= 2.0 { "PASS (>=2x)" } else { "WARN (<2x)" }
    );
    println!(
        "mc-shard numerics vs single engine: max |Δpred| {worst_pred:.2e}, \
         max |Δstd| {worst_unc:.2e}  {}",
        if numerics_ok { "PASS" } else { "FAIL" }
    );
    if !numerics_ok {
        // Sample-seeding invariant broken — that is a correctness bug.
        std::process::exit(1);
    }
}
