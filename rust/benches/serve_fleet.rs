//! Process-based serving-fleet bench harness (`cargo bench --bench
//! serve_fleet`, harness = false).
//!
//! Runs the *release binary* (`repro serve --json ...`) as a subprocess
//! per scenario — measuring the real end-to-end serving path, process
//! startup excluded from throughput (the binary times itself) — and
//! writes one single-line JSON summary per scenario under the gitignored
//! `bench_results/` directory:
//!
//!   baseline        1 engine, round-robin, FPGA-sim
//!   fan_out         4 engines, round-robin
//!   fleet_scaling   1/2/4/8 engines, least-loaded
//!   mc_shard        1/2/4 engines, MC-shard sample parallelism
//!   adaptive_mc     1 engine rr + 4 engines mc-shard with the adaptive
//!                   early-exit controller, vs. the fixed-S baseline
//!                   (mean samples used, samples-saved %, mean rounds,
//!                   tier counts). Continuation rounds are dispatched by
//!                   the fleet's adaptive coordinator thread, so e2e
//!                   latencies are completion-timed — submit-all-then-
//!                   wait no longer serialises multi-round requests
//!                   head-of-line (ROADMAP PR 3 finding a)
//!   mc_batch        blocked MC-sample batching (--kernel blocked, the
//!                   default) vs. the legacy per-sample scalar path
//!                   (--kernel scalar) at S in {10, 30, 100}: beats/s
//!                   each, speedup, and a bit-identity check on the
//!                   prediction checksums (docs/kernels.md)
//!   stream          session-stateful streaming (--stream): chunked
//!                   serving with resident MC lane state vs. one-shot,
//!                   at S in {10, 30}; plus a zero-byte-budget thrash
//!                   run that must still match bitwise while paying
//!                   eviction/replay rebuilds (docs/serving.md
//!                   §Streaming sessions)
//!   chaos           3-engine mc-shard run with one engine chaos-killed
//!                   (--chaos kill=e1@5ms) vs. fault-free: all requests
//!                   must still be served and the merged checksums must
//!                   match bitwise (docs/serving.md §Fault tolerance)
//!
//! Every run passes `--obs`, so scenario points carry the per-stage
//! (queue / batch-form / compute / merge) p99 breakdown, and the
//! headline scenarios are additionally written to the *committed*
//! `BENCH_serve.json` at the repo root — the serving-perf trajectory
//! diffable across PRs (docs/observability.md §Perf trajectory).
//!
//! Checks printed at the end:
//!   * fan-out and 4-way MC-shard throughput vs. baseline (target ≥ 2x),
//!   * MC-shard prediction checksums vs. baseline (must match to 1e-3 —
//!     the sample-seeding invariant). A numeric mismatch exits non-zero;
//!     a missed throughput target only warns (machine-dependent),
//!   * adaptive-MC accounting: tier counts must cover every request and
//!     mean samples must respect the [s_min, S] envelope (hard FAIL).
//!
//! Env: REPRO_BIN overrides the binary path; REPRO_BENCH_REQUESTS and
//! REPRO_BENCH_SAMPLES scale the load (defaults 64 requests, S = 24).

use std::path::{Path, PathBuf};
use std::process::Command;

use bayes_rnn_fpga::jsonio::{self, Json};

const ARCH: &str = "classify_h8_nl1_Y";
/// The MC-batch comparison uses a paper-sized model: bigger gate
/// matrices make the weight-fetch amortisation visible (h8 fits in L1
/// and mostly measures loop overhead).
const MC_BATCH_ARCH: &str = "classify_h32_nl2_YY";

fn manifest_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn find_binary() -> PathBuf {
    if let Ok(p) = std::env::var("REPRO_BIN") {
        return PathBuf::from(p);
    }
    let bin = manifest_dir().join("target/release/repro");
    if !bin.exists() {
        eprintln!("release binary missing; running `cargo build --release`");
        let status = Command::new("cargo")
            .args(["build", "--release", "--bin", "repro"])
            .current_dir(manifest_dir())
            .status()
            .expect("spawn cargo build");
        assert!(status.success(), "cargo build --release failed");
    }
    bin
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Adaptive-MC accounting parsed from the serve JSON's nested
/// `"adaptive"` object.
struct AdaptiveStats {
    mean_samples: f64,
    samples_saved_pct: f64,
    /// Mean sequential sampling rounds per request (coordinator-driven).
    mean_rounds: f64,
    converged: usize,
    accept: usize,
    defer: usize,
    abstain: usize,
}

/// Per-stage p99 latencies parsed from the serve JSON's nested
/// `"obs"."stages"` object (0.0 when a stage recorded nothing).
#[derive(Default)]
struct StageP99s {
    queue_ms: f64,
    batch_ms: f64,
    compute_ms: f64,
    merge_ms: f64,
}

/// One `repro serve --json` run, parsed.
struct Run {
    engines: usize,
    router: String,
    json_line: String,
    served: usize,
    rejected: usize,
    throughput: f64,
    e2e_p50_ms: f64,
    e2e_p99_ms: f64,
    stages: StageP99s,
    pred_checksum: f64,
    unc_checksum: f64,
    adaptive: Option<AdaptiveStats>,
}

fn serve(
    bin: &Path,
    arch: &str,
    engines: usize,
    router: &str,
    requests: usize,
    samples: usize,
    extra: &[&str],
) -> Run {
    let mut argv = vec![
        "serve".to_string(),
        "--arch".to_string(),
        arch.to_string(),
        "--engines".to_string(),
        engines.to_string(),
        "--router".to_string(),
        router.to_string(),
        "--backend".to_string(),
        "fpga".to_string(),
        "--requests".to_string(),
        requests.to_string(),
        "--samples".to_string(),
        samples.to_string(),
        "--json".to_string(),
        // Stage-latency breakdown rides into every scenario summary
        // (and into the committed BENCH_serve.json trajectory).
        "--obs".to_string(),
    ];
    argv.extend(extra.iter().map(|s| s.to_string()));
    let out = Command::new(bin)
        .args(&argv)
        .output()
        .expect("spawn repro serve");
    assert!(
        out.status.success(),
        "repro serve failed (engines={engines} router={router}):\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    let line = stdout
        .lines()
        .rev()
        .find(|l| l.trim_start().starts_with('{'))
        .unwrap_or_else(|| panic!("no JSON line in output:\n{stdout}"))
        .trim()
        .to_string();
    let j = jsonio::parse(&line).expect("parse serve JSON");
    let f = |key: &str| -> f64 {
        j.get(key).and_then(Json::as_f64).unwrap_or_else(|| {
            panic!("missing numeric field {key:?} in {line}")
        })
    };
    let e2e_p50_ms = j
        .get("e2e_ms")
        .and_then(|o| o.get("p50"))
        .and_then(Json::as_f64)
        .expect("e2e_ms.p50");
    let e2e_p99_ms = j
        .get("e2e_ms")
        .and_then(|o| o.get("p99"))
        .and_then(Json::as_f64)
        .expect("e2e_ms.p99");
    let stage_p99 = |name: &str| -> f64 {
        j.get("obs")
            .and_then(|o| o.get("stages"))
            .and_then(|s| s.get(name))
            .and_then(|h| h.get("p99"))
            .and_then(Json::as_f64)
            .unwrap_or(0.0)
    };
    let stages = StageP99s {
        queue_ms: stage_p99("queue"),
        batch_ms: stage_p99("batch"),
        compute_ms: stage_p99("compute"),
        merge_ms: stage_p99("merge"),
    };
    let adaptive = j.get("adaptive").map(|a| {
        let g = |key: &str| -> f64 {
            a.get(key).and_then(Json::as_f64).unwrap_or_else(|| {
                panic!("adaptive object missing {key:?} in {line}")
            })
        };
        let tiers = a.get("tiers").expect("adaptive.tiers");
        let t = |key: &str| -> usize {
            tiers.get(key).and_then(Json::as_usize).unwrap_or_else(|| {
                panic!("adaptive.tiers missing {key:?} in {line}")
            })
        };
        AdaptiveStats {
            mean_samples: g("mean_samples"),
            samples_saved_pct: g("samples_saved_pct"),
            // Optional for replay of pre-rounds-tracking JSON.
            mean_rounds: a
                .get("mean_rounds")
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
            converged: g("converged") as usize,
            accept: t("accept"),
            defer: t("defer"),
            abstain: t("abstain"),
        }
    });
    Run {
        engines,
        router: router.to_string(),
        json_line: line.clone(),
        served: f("served") as usize,
        rejected: f("rejected") as usize,
        throughput: f("throughput_rps"),
        e2e_p50_ms,
        e2e_p99_ms,
        stages,
        pred_checksum: f("pred_checksum"),
        unc_checksum: f("unc_checksum"),
        adaptive,
    }
}

fn write_scenario(dir: &Path, name: &str, line: &str) {
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, format!("{line}\n")).expect("write summary");
    println!("  -> {}", path.display());
}

/// Overwrite the committed repo-root copy of a scenario line so the
/// perf trajectory is diffable in git and `scripts/bench-compare` has
/// a baseline to check against (docs/observability.md §Perf
/// trajectory).
fn commit_bench(file: &str, line: &str) {
    let path = manifest_dir().join(file);
    std::fs::write(&path, format!("{line}\n"))
        .expect("write committed bench file");
    println!("  -> {}", path.display());
}

/// One run as a JSON point: throughput + e2e percentiles + the
/// per-stage p99 breakdown from the obs layer.
fn point_json(r: &Run) -> String {
    format!(
        "{{\"engines\":{},\"router\":\"{}\",\"served\":{},\
         \"rejected\":{},\"throughput_rps\":{:.3},\
         \"e2e_p50_ms\":{:.4},\"e2e_p99_ms\":{:.4},\
         \"stage_p99_ms\":{{\"queue\":{:.4},\"batch\":{:.4},\
         \"compute\":{:.4},\"merge\":{:.4}}}}}",
        r.engines,
        r.router,
        r.served,
        r.rejected,
        r.throughput,
        r.e2e_p50_ms,
        r.e2e_p99_ms,
        r.stages.queue_ms,
        r.stages.batch_ms,
        r.stages.compute_ms,
        r.stages.merge_ms
    )
}

/// Wrap several runs into one single-line JSON scenario summary.
fn points_summary(name: &str, runs: &[&Run], extra: &str) -> String {
    let points: Vec<String> =
        runs.iter().map(|r| point_json(r)).collect();
    format!(
        "{{\"scenario\":\"{name}\",\"arch\":\"{ARCH}\",\"points\":[{}]{}}}",
        points.join(","),
        extra
    )
}

fn main() {
    let bin = find_binary();
    let requests = env_usize("REPRO_BENCH_REQUESTS", 64);
    let samples = env_usize("REPRO_BENCH_SAMPLES", 24);
    let results = manifest_dir().join("bench_results");
    std::fs::create_dir_all(&results).expect("create bench_results/");
    println!(
        "serve_fleet harness: {} requests, S={samples}, arch {ARCH}",
        requests
    );

    // --- baseline: one FPGA-sim engine, streamed ---
    println!("[baseline] 1 engine, rr");
    let baseline = serve(&bin, ARCH, 1, "rr", requests, samples, &[]);
    write_scenario(&results, "baseline", &baseline.json_line);

    // --- fan-out: 4 engines, whole-request round-robin ---
    println!("[fan_out] 4 engines, rr");
    let fan_out = serve(&bin, ARCH, 4, "rr", requests, samples, &[]);
    write_scenario(&results, "fan_out", &fan_out.json_line);

    // --- fleet-scaling: throughput trajectory over engine count ---
    let mut scaling = Vec::new();
    for n in [1usize, 2, 4, 8] {
        println!("[fleet_scaling] {n} engines, least-loaded");
        scaling.push(serve(&bin, ARCH, n, "least-loaded", requests, samples, &[]));
    }
    let refs: Vec<&Run> = scaling.iter().collect();
    write_scenario(
        &results,
        "fleet_scaling",
        &points_summary("fleet_scaling", &refs, ""),
    );

    // --- MC-shard sweep: split S across 1/2/4 engines ---
    let mut shard = Vec::new();
    for n in [1usize, 2, 4] {
        println!("[mc_shard] {n} engines, mc-shard");
        shard.push(serve(&bin, ARCH, n, "mc-shard", requests, samples, &[]));
    }
    let mut worst_pred = 0f64;
    let mut worst_unc = 0f64;
    for r in &shard {
        worst_pred = worst_pred
            .max((r.pred_checksum - baseline.pred_checksum).abs());
        worst_unc =
            worst_unc.max((r.unc_checksum - baseline.unc_checksum).abs());
    }
    let numerics_ok = worst_pred < 1e-3 && worst_unc < 1e-3;
    let refs: Vec<&Run> = shard.iter().collect();
    let extra = format!(
        ",\"baseline_pred_checksum\":{:.6},\"max_pred_delta\":{:.6},\
         \"max_unc_delta\":{:.6},\"numerics_match\":{}",
        baseline.pred_checksum, worst_pred, worst_unc, numerics_ok
    );
    write_scenario(
        &results,
        "mc_shard",
        &points_summary("mc_shard", &refs, &extra),
    );

    // --- adaptive MC: early-exit controller vs. the fixed-S baseline ---
    let s_min = 4usize.min(samples);
    let adaptive_flags: Vec<String> = vec![
        "--adaptive-mc".into(),
        "--target-ci".into(),
        "0.05".into(),
        "--s-min".into(),
        s_min.to_string(),
    ];
    let flag_refs: Vec<&str> =
        adaptive_flags.iter().map(String::as_str).collect();
    let mut adaptive_runs = Vec::new();
    for (n, router) in [(1usize, "rr"), (4, "mc-shard")] {
        println!("[adaptive_mc] {n} engines, {router}, target-ci 0.05");
        adaptive_runs
            .push(serve(&bin, ARCH, n, router, requests, samples, &flag_refs));
    }
    let mut adaptive_ok = true;
    let adaptive_points: Vec<String> = adaptive_runs
        .iter()
        .map(|r| {
            let a = r
                .adaptive
                .as_ref()
                .expect("--adaptive-mc run must report adaptive stats");
            // Accounting invariants: every served request is tiered,
            // the sample budget respects the envelope, and the
            // coordinator reported at least one round per request.
            adaptive_ok &= a.accept + a.defer + a.abstain == r.served;
            adaptive_ok &= a.mean_samples >= s_min as f64 - 1e-9
                && a.mean_samples <= samples as f64 + 1e-9;
            adaptive_ok &= a.mean_rounds >= 1.0 - 1e-9;
            format!(
                "{{\"engines\":{},\"router\":\"{}\",\"served\":{},\
                 \"mean_samples\":{:.3},\"samples_saved_pct\":{:.2},\
                 \"mean_rounds\":{:.3},\"converged\":{},\
                 \"tiers\":{{\"accept\":{},\
                 \"defer\":{},\"abstain\":{}}},\
                 \"throughput_rps\":{:.3},\"e2e_p99_ms\":{:.4}}}",
                r.engines,
                r.router,
                r.served,
                a.mean_samples,
                a.samples_saved_pct,
                a.mean_rounds,
                a.converged,
                a.accept,
                a.defer,
                a.abstain,
                r.throughput,
                r.e2e_p99_ms
            )
        })
        .collect();
    let adaptive_line = format!(
        "{{\"scenario\":\"adaptive_mc\",\"source\":\"serve_fleet\",\
         \"arch\":\"{ARCH}\",\
         \"fixed_s\":{samples},\"s_min\":{s_min},\
         \"target_ci\":0.05,\"baseline_throughput_rps\":{:.3},\
         \"baseline_e2e_p99_ms\":{:.4},\"points\":[{}],\
         \"accounting_ok\":{}}}",
        baseline.throughput,
        baseline.e2e_p99_ms,
        adaptive_points.join(","),
        adaptive_ok
    );
    write_scenario(&results, "adaptive_mc", &adaptive_line);
    commit_bench("BENCH_adaptive_mc.json", &adaptive_line);

    // --- mc_batch: blocked MC batching vs the scalar per-sample path ---
    // One FPGA-sim engine, round-robin; the blocked path computes all of
    // a request's S samples (and batch-mates) in one kernel call, the
    // scalar path walks the weights once per sample. Outputs must be
    // bit-identical (checksums printed with 6 decimals must match
    // exactly); acceptance targets >= 2x beats/s at S = 100.
    let mut mcb_points = Vec::new();
    let mut mcb_bits_ok = true;
    let mut speedup_s100 = 0f64;
    for s in [10usize, 30, 100] {
        // Bound wall time at the large-S points.
        let reqs = if s >= 100 {
            requests.min(24)
        } else if s >= 30 {
            requests.min(48)
        } else {
            requests
        };
        println!("[mc_batch] S={s}, {reqs} requests, scalar kernel");
        let scalar = serve(
            &bin, MC_BATCH_ARCH, 1, "rr", reqs, s, &["--kernel", "scalar"],
        );
        println!("[mc_batch] S={s}, {reqs} requests, blocked kernel");
        let blocked = serve(
            &bin, MC_BATCH_ARCH, 1, "rr", reqs, s, &["--kernel", "blocked"],
        );
        let speedup =
            blocked.throughput / scalar.throughput.max(1e-9);
        if s == 100 {
            speedup_s100 = speedup;
        }
        // One beat per request: throughput_rps is beats/s.
        let bits_ok = (blocked.pred_checksum - scalar.pred_checksum).abs()
            < 1e-9
            && (blocked.unc_checksum - scalar.unc_checksum).abs() < 1e-9;
        mcb_bits_ok &= bits_ok;
        mcb_points.push(format!(
            "{{\"s\":{s},\"requests\":{reqs},\
             \"scalar_beats_per_s\":{:.3},\"blocked_beats_per_s\":{:.3},\
             \"speedup\":{:.3},\"bits_match\":{}}}",
            scalar.throughput, blocked.throughput, speedup, bits_ok
        ));
        println!(
            "  S={s:<4} scalar {:.1} beats/s  blocked {:.1} beats/s  \
             speedup {speedup:.2}x  bits {}",
            scalar.throughput,
            blocked.throughput,
            if bits_ok { "MATCH" } else { "MISMATCH" }
        );
    }
    let mcb_line = format!(
        "{{\"scenario\":\"mc_batch\",\"source\":\"serve_fleet\",\
         \"arch\":\"{MC_BATCH_ARCH}\",\
         \"points\":[{}],\"speedup_s100\":{:.3},\
         \"bits_match\":{}}}",
        mcb_points.join(","),
        speedup_s100,
        mcb_bits_ok
    );
    write_scenario(&results, "mc_batch", &mcb_line);
    commit_bench("BENCH_mc_batch.json", &mcb_line);

    // --- stream: resident session chunks vs one-shot + thrash cost ---
    // Each run opens `sessions` streaming sessions of 4 beats;
    // `--stream N` splits every session's signal into N chunks.
    // One-shot (--stream 1) is the reference; chunked serving over
    // resident lane state must reproduce its checksums exactly (the
    // bitwise streaming contract) while paying only O(chunk) per
    // decision. The thrash run caps the session table at 0 bytes, so
    // every resume is an eviction miss rebuilt by replay — same bits,
    // rebuild cost charged to chunk latency.
    let stream_field = |r: &Run, key: &str| -> f64 {
        let j = jsonio::parse(&r.json_line).expect("re-parse serve JSON");
        j.get("stream")
            .and_then(|s| s.get(key))
            .and_then(Json::as_f64)
            .unwrap_or_else(|| {
                panic!("missing stream.{key} in {}", r.json_line)
            })
    };
    let sessions = requests.min(16);
    let mut stream_points = Vec::new();
    let mut stream_bits_ok = true;
    let mut stream_replays_ok = true;
    for s in [10usize, 30] {
        println!("[stream] S={s}, {sessions} sessions, one-shot");
        let oneshot = serve(
            &bin,
            ARCH,
            1,
            "affinity",
            sessions,
            s,
            &["--stream", "1", "--stream-beats", "4"],
        );
        println!("[stream] S={s}, {sessions} sessions, 4 chunks resident");
        let resident = serve(
            &bin,
            ARCH,
            1,
            "affinity",
            sessions,
            s,
            &["--stream", "4", "--stream-beats", "4", "--session-mb", "8"],
        );
        println!("[stream] S={s}, {sessions} sessions, 4 chunks thrash");
        let thrash = serve(
            &bin,
            ARCH,
            1,
            "affinity",
            sessions,
            s,
            &["--stream", "4", "--stream-beats", "4", "--session-mb", "0"],
        );
        let bits_ok = (resident.pred_checksum - oneshot.pred_checksum)
            .abs()
            < 1e-9
            && (resident.unc_checksum - oneshot.unc_checksum).abs() < 1e-9
            && (thrash.pred_checksum - oneshot.pred_checksum).abs() < 1e-9
            && (thrash.unc_checksum - oneshot.unc_checksum).abs() < 1e-9;
        stream_bits_ok &= bits_ok;
        let resident_rebuilds = stream_field(&resident, "replay_rebuilds");
        let thrash_rebuilds = stream_field(&thrash, "replay_rebuilds");
        // Resident serving never rebuilds; a 0-byte budget must rebuild
        // every post-first chunk (3 per session here).
        stream_replays_ok &= resident_rebuilds == 0.0
            && thrash_rebuilds >= sessions as f64;
        stream_points.push(format!(
            "{{\"s\":{s},\"sessions\":{sessions},\"beats\":4,\
             \"chunks\":4,\
             \"oneshot_rps\":{:.3},\"resident_rps\":{:.3},\
             \"thrash_rps\":{:.3},\
             \"oneshot_e2e_p50_ms\":{:.4},\
             \"resident_e2e_p50_ms\":{:.4},\
             \"thrash_e2e_p50_ms\":{:.4},\
             \"resident_replay_rebuilds\":{},\
             \"thrash_replay_rebuilds\":{},\"bits_match\":{}}}",
            oneshot.throughput,
            resident.throughput,
            thrash.throughput,
            oneshot.e2e_p50_ms,
            resident.e2e_p50_ms,
            thrash.e2e_p50_ms,
            resident_rebuilds as usize,
            thrash_rebuilds as usize,
            bits_ok
        ));
        println!(
            "  S={s:<4} chunk-p50 resident {:.3} ms  thrash {:.3} ms  \
             rebuilds {}/{}  bits {}",
            resident.e2e_p50_ms,
            thrash.e2e_p50_ms,
            resident_rebuilds as usize,
            thrash_rebuilds as usize,
            if bits_ok { "MATCH" } else { "MISMATCH" }
        );
    }
    let stream_line = format!(
        "{{\"scenario\":\"stream\",\"source\":\"serve_fleet\",\
         \"arch\":\"{ARCH}\",\"points\":[{}],\
         \"bits_match\":{stream_bits_ok},\
         \"replay_accounting_ok\":{stream_replays_ok}}}",
        stream_points.join(",")
    );
    write_scenario(&results, "stream", &stream_line);
    commit_bench("BENCH_stream.json", &stream_line);

    // --- chaos: kill one of three mc-shard engines mid-run ---
    // The fault-tolerance plane (docs/serving.md §Fault tolerance)
    // must re-dispatch the dead engine's shards onto survivors with
    // the merged outputs bit-identical to the fault-free run, and
    // every request still served.
    let chaos_reqs = requests.min(32);
    println!("[chaos] 3 engines, mc-shard, fault-free reference");
    let clean =
        serve(&bin, ARCH, 3, "mc-shard", chaos_reqs, samples, &[]);
    println!("[chaos] 3 engines, mc-shard, kill=e1@5ms");
    let chaotic = serve(
        &bin,
        ARCH,
        3,
        "mc-shard",
        chaos_reqs,
        samples,
        &["--chaos", "kill=e1@5ms"],
    );
    let chaos_bits_ok = (chaotic.pred_checksum - clean.pred_checksum)
        .abs()
        < 1e-9
        && (chaotic.unc_checksum - clean.unc_checksum).abs() < 1e-9;
    let chaos_served_ok = chaotic.served == clean.served
        && chaotic.served == chaos_reqs;
    let chaos_line = format!(
        "{{\"scenario\":\"chaos\",\"source\":\"serve_fleet\",\
         \"arch\":\"{ARCH}\",\"engines\":3,\"plan\":\"kill=e1@5ms\",\
         \"requests\":{chaos_reqs},\"clean_rps\":{:.3},\
         \"chaotic_rps\":{:.3},\"served\":{},\
         \"bits_match\":{chaos_bits_ok},\
         \"all_served\":{chaos_served_ok}}}",
        clean.throughput, chaotic.throughput, chaotic.served
    );
    write_scenario(&results, "chaos", &chaos_line);

    // --- committed perf trajectory: BENCH_serve.json at the repo root ---
    // One line covering the headline scenarios (with the obs stage
    // breakdown), overwritten by every `cargo bench --bench serve_fleet`
    // run and committed so serving-perf history is diffable in git
    // (docs/observability.md §Perf trajectory). Machine-dependent
    // absolute numbers; the within-file ratios are the signal.
    let trajectory = format!(
        "{{\"scenario\":\"serve_perf_trajectory\",\
         \"source\":\"serve_fleet\",\"arch\":\"{ARCH}\",\
         \"requests\":{requests},\"samples\":{samples},\
         \"baseline\":{},\"fan_out\":{},\"fleet_scaling\":[{}]}}",
        point_json(&baseline),
        point_json(&fan_out),
        scaling
            .iter()
            .map(point_json)
            .collect::<Vec<_>>()
            .join(",")
    );
    let traj_path = manifest_dir().join("BENCH_serve.json");
    std::fs::write(&traj_path, format!("{trajectory}\n"))
        .expect("write BENCH_serve.json");
    println!("  -> {}", traj_path.display());

    // --- report ---
    println!("\nscenario           engines  served  rejected   req/s   vs base");
    let mut rows: Vec<(&str, &Run)> = vec![
        ("baseline", &baseline),
        ("fan_out", &fan_out),
    ];
    for r in &scaling {
        rows.push(("fleet_scaling", r));
    }
    for r in &shard {
        rows.push(("mc_shard", r));
    }
    for r in &adaptive_runs {
        rows.push(("adaptive_mc", r));
    }
    for (name, r) in &rows {
        println!(
            "{name:<18} {:>7} {:>7} {:>9} {:>8.1} {:>8.2}x",
            r.engines,
            r.served,
            r.rejected,
            r.throughput,
            r.throughput / baseline.throughput.max(1e-9)
        );
    }

    let fan_ratio = fan_out.throughput / baseline.throughput.max(1e-9);
    let shard4 = shard.last().expect("mc-shard runs");
    let shard_ratio = shard4.throughput / baseline.throughput.max(1e-9);
    println!(
        "\nfan-out speedup  {fan_ratio:.2}x  {}",
        if fan_ratio >= 2.0 { "PASS (>=2x)" } else { "WARN (<2x)" }
    );
    println!(
        "mc-shard speedup {shard_ratio:.2}x  {}",
        if shard_ratio >= 2.0 { "PASS (>=2x)" } else { "WARN (<2x)" }
    );
    println!(
        "mc-shard numerics vs single engine: max |Δpred| {worst_pred:.2e}, \
         max |Δstd| {worst_unc:.2e}  {}",
        if numerics_ok { "PASS" } else { "FAIL" }
    );
    for r in &adaptive_runs {
        let a = r.adaptive.as_ref().expect("adaptive stats");
        println!(
            "adaptive-mc [{} engines, {}]: mean samples {:.2}/{} \
             ({:.1}% saved, {:.2} rounds)  tiers accept {} / defer {} / \
             abstain {}",
            r.engines,
            r.router,
            a.mean_samples,
            samples,
            a.samples_saved_pct,
            a.mean_rounds,
            a.accept,
            a.defer,
            a.abstain
        );
    }
    println!(
        "adaptive-mc accounting (tiers cover requests, samples within \
         [{s_min}, {samples}], rounds >= 1, e2e completion-timed): {}",
        if adaptive_ok { "PASS" } else { "FAIL" }
    );
    println!(
        "mc-batch blocked vs scalar @ S=100: {speedup_s100:.2}x  {}",
        if speedup_s100 >= 2.0 { "PASS (>=2x)" } else { "WARN (<2x)" }
    );
    println!(
        "mc-batch bit-identity (blocked == scalar checksums): {}",
        if mcb_bits_ok { "PASS" } else { "FAIL" }
    );
    println!(
        "stream bit-identity (chunked == one-shot, resident and \
         thrash): {}",
        if stream_bits_ok { "PASS" } else { "FAIL" }
    );
    println!(
        "stream replay accounting (resident 0 rebuilds, thrash \
         rebuilds every evicted chunk): {}",
        if stream_replays_ok { "PASS" } else { "FAIL" }
    );
    println!(
        "chaos recovery (engine killed, all served, bits match \
         fault-free): {}",
        if chaos_bits_ok && chaos_served_ok { "PASS" } else { "FAIL" }
    );
    if !numerics_ok
        || !adaptive_ok
        || !mcb_bits_ok
        || !stream_bits_ok
        || !stream_replays_ok
        || !chaos_bits_ok
        || !chaos_served_ok
    {
        // Sample-seeding invariant, adaptive accounting, blocked-kernel
        // bit-identity, the streaming bitwise contract or chaos
        // recovery broken — correctness bugs, not perf regressions.
        std::process::exit(1);
    }
}
