//! Offline drop-in subset of [dtolnay/anyhow](https://github.com/dtolnay/anyhow).
//!
//! The reproduction container has no crates.io access, so the crate's one
//! external dependency is vendored as the minimal API surface the tree
//! actually uses: [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]/
//! [`ensure!`] macros and the [`Context`] extension trait (on both
//! `Result` and `Option`). Error values carry a root message plus a stack
//! of context strings; `{}` shows the outermost context, `{:#}` the full
//! chain separated by `": "`, and `{:?}` an anyhow-style report — the
//! three renderings the codebase relies on.

use std::fmt;

/// Dynamic error with a context chain (message-only — no backtraces, no
/// downcasting; nothing in this tree uses either).
pub struct Error {
    /// Root cause message.
    msg: String,
    /// Contexts, innermost first (pushed by [`Context::context`]).
    contexts: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { msg: message.to_string(), contexts: Vec::new() }
    }

    /// Attach a higher-level context (outermost last).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.contexts.push(context.to_string());
        self
    }

    /// The chain outermost-first, ending at the root cause.
    fn chain(&self) -> impl Iterator<Item = &str> {
        self.contexts
            .iter()
            .rev()
            .map(String::as_str)
            .chain(std::iter::once(self.msg.as_str()))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain on one line.
            let joined: Vec<&str> = self.chain().collect();
            write!(f, "{}", joined.join(": "))
        } else {
            // `{}`: outermost context only (anyhow semantics).
            let outer = self.contexts.last().map(String::as_str);
            write!(f, "{}", outer.unwrap_or(&self.msg))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut chain = self.chain();
        write!(f, "{}", chain.next().unwrap_or(""))?;
        let rest: Vec<&str> = chain.collect();
        if !rest.is_empty() {
            write!(f, "\n\nCaused by:")?;
            if rest.len() == 1 {
                write!(f, "\n    {}", rest[0])?;
            } else {
                for (i, c) in rest.iter().enumerate() {
                    write!(f, "\n    {i}: {c}")?;
                }
            }
        }
        Ok(())
    }
}

// Like real anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes the blanket `From` below
// coherent with the reflexive `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Self {
        // Flatten the source chain into context strings so `{:#}` and
        // `{:?}` keep showing causes.
        let mut contexts = Vec::new();
        let top = err.to_string();
        let mut source = err.source();
        let mut msgs = Vec::new();
        while let Some(s) = source {
            msgs.push(s.to_string());
            source = s.source();
        }
        // Innermost cause becomes the root message.
        let msg = msgs.pop().unwrap_or_else(|| top.clone());
        if msg != top {
            contexts.extend(msgs.into_iter().rev());
            contexts.push(top);
        }
        Self { msg, contexts }
    }
}

/// `anyhow::Result<T>` — plain `Result` defaulting the error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T, Error>;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("Condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_shows_outermost_context() {
        let e: Error = Error::msg("root").context("mid").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: mid: root");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
        assert!(dbg.contains("root"), "{dbg}");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let n: u32 = "notanum".parse()?;
            Ok(n)
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert!(format!("{e:#}").contains("gone"));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
    }

    #[test]
    fn macros() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let s = 4usize;
        let e = anyhow!("no fwd_n{s} artifact");
        assert_eq!(e.to_string(), "no fwd_n4 artifact");
        let msg = String::from("from a value");
        let e = anyhow!(msg);
        assert_eq!(e.to_string(), "from a value");

        fn f(flag: bool) -> Result<()> {
            ensure!(flag, "flag was {flag}");
            bail!("always fails after ensure")
        }
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
        assert_eq!(
            f(true).unwrap_err().to_string(),
            "always fails after ensure"
        );
    }
}
