//! Latency model (paper Sec. IV-C).
//!
//! The pipelined design is governed by the initiation interval II — the
//! cycles before a unit accepts new input:
//!
//! ```text
//! II         = max_i II_i
//! Lat_i      = II * T + (IL_i - II)
//! Lat_design = II * T + (IL - II) * NL        (classifier / encoder)
//! Lat_AE     = Lat_design * 2                 (decoder waits for h_T)
//! ```
//!
//! II_i is set by the slowest time-multiplexed engine of layer i: the
//! x-path MVM needs R_x cycles, the h-path R_h (the recurrent data
//! dependency means h-path II bounds the timestep loop). IL_i adds the
//! pipeline fill depth: the MVM adder tree, the activation LUT read and
//! the 3-stage tail.
//!
//! Precision does NOT enter here directly: at a fixed reuse R the II is
//! R regardless of operand width — INT8 DSP packing halves the *slice
//! count* (the resource model's `estimate_q`), and the constraint
//! solver (`dse::space::reuse_search_q`) converts that freed budget
//! into a lower feasible R, which is where narrow formats gain latency
//! (`docs/quantization.md`). Crediting both halved DSPs and halved II
//! at the same R would double-count the packing.
//!
//! Multi-sample / multi-beat streaming: consecutive MC samples and batch
//! elements follow each other through the same pipeline at the sample
//! interval II*T (sample-wise pipelining, Fig. 4/5), so a batch of B
//! beats with S MC samples each costs ~II*T*S*B cycles plus one pipeline
//! drain.

use super::resource::ReuseFactors;
use crate::config::ArchConfig;

/// Per-layer timing: initiation interval + iteration latency.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerTiming {
    pub ii: u64,
    pub il: u64,
}

pub struct LatencyModel;

impl LatencyModel {
    /// Pipeline-depth constants (cycles): activation LUT read and the
    /// elementwise tail (f*c + i*g, tanh, o*).
    const ACT_LUT_CYCLES: u64 = 2;
    const TAIL_CYCLES: u64 = 3;

    /// Timing of one LSTM layer. Format-independent at fixed reuse —
    /// see the module docs for how precision reaches latency (through
    /// the constraint-solved reuse, not the II formula).
    pub fn lstm_timing(
        idim: usize,
        hdim: usize,
        r: &ReuseFactors,
    ) -> LayerTiming {
        // II: both MVM paths run in parallel; the engine accepts a new
        // timestep every max(R_x, R_h) cycles (the h recurrence cannot be
        // hidden). The tail is II=1 and never binds.
        let ii = r.rx.max(r.rh) as u64;
        // IL: II + adder-tree depth + LUT + tail.
        let tree = (usize::BITS - (idim.max(hdim)).leading_zeros()) as u64;
        let il = ii + tree + Self::ACT_LUT_CYCLES + Self::TAIL_CYCLES;
        LayerTiming { ii, il }
    }

    /// Design II = max over layers (the paper balances all IIs to this).
    pub fn design_timing(cfg: &ArchConfig, r: &ReuseFactors) -> LayerTiming {
        let mut ii = 1;
        let mut il = 0;
        for (idim, hdim) in cfg.lstm_dims() {
            let t = Self::lstm_timing(idim, hdim, r);
            ii = ii.max(t.ii);
            il = il.max(t.il);
        }
        LayerTiming { ii, il }
    }

    /// End-to-end cycles for ONE pass (one beat, one MC sample) through
    /// the design: `II*T + (IL-II)*NL`, doubled for the autoencoder since
    /// the decoder can only start on the completed bottleneck.
    pub fn single_pass_cycles(cfg: &ArchConfig, r: &ReuseFactors) -> u64 {
        let t = Self::design_timing(cfg, r);
        let nl = cfg.nl as u64;
        let seq = cfg.seq_len as u64;
        let half = t.ii * seq + (t.il - t.ii) * nl;
        match cfg.task {
            crate::config::Task::Anomaly => half * 2,
            crate::config::Task::Classify => half,
        }
    }

    /// Cycles for a batch of `batch` beats, `s` MC samples each, streamed
    /// through the pipeline back-to-back: the sample interval is II*T (the
    /// encoder must finish a sequence before the next enters the same
    /// engine), with one pipeline drain at the end.
    pub fn batch_cycles(
        cfg: &ArchConfig,
        r: &ReuseFactors,
        batch: usize,
        s: usize,
    ) -> u64 {
        let t = Self::design_timing(cfg, r);
        let seq = cfg.seq_len as u64;
        let passes = (batch * s) as u64;
        let interval = t.ii * seq;
        // Passes enter the pipeline every `interval` cycles; the last one
        // still pays the full single-pass latency (which already contains
        // its own first interval plus the fill/drain terms).
        interval * passes.saturating_sub(1)
            + Self::single_pass_cycles(cfg, r)
    }

    /// Milliseconds at the given clock.
    pub fn batch_ms(
        cfg: &ArchConfig,
        r: &ReuseFactors,
        batch: usize,
        s: usize,
        clock_hz: f64,
    ) -> f64 {
        Self::batch_cycles(cfg, r, batch, s) as f64 / clock_hz * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArchConfig, Task};
    use crate::hwmodel::ZC706;

    #[test]
    fn ii_is_max_reuse() {
        let t = LatencyModel::lstm_timing(16, 16, &ReuseFactors::new(16, 5, 1));
        assert_eq!(t.ii, 16);
        let t2 = LatencyModel::lstm_timing(8, 8, &ReuseFactors::new(3, 12, 1));
        assert_eq!(t2.ii, 12);
        assert!(t.il > t.ii);
    }

    #[test]
    fn autoencoder_doubles() {
        let ae = ArchConfig::new(Task::Anomaly, 16, 2, "NNNN");
        let cls = ArchConfig::new(Task::Classify, 16, 2, "NN");
        let r = ReuseFactors::new(4, 4, 4);
        let a = LatencyModel::single_pass_cycles(&ae, &r);
        let c = LatencyModel::single_pass_cycles(&cls, &r);
        assert_eq!(a, 2 * c);
    }

    #[test]
    fn deeper_nets_cost_only_fill_latency() {
        // Timestep pipelining: adding layers adds (IL-II) per layer, not
        // II*T — the paper's Table VI observation that NL=2 and NL=3 have
        // nearly identical latency.
        let c1 = ArchConfig::new(Task::Classify, 8, 1, "N");
        let c3 = ArchConfig::new(Task::Classify, 8, 3, "NNN");
        let r = ReuseFactors::new(12, 1, 1);
        let l1 = LatencyModel::single_pass_cycles(&c1, &r);
        let l3 = LatencyModel::single_pass_cycles(&c3, &r);
        assert!(l3 > l1);
        assert!(
            (l3 - l1) < l1 / 10,
            "extra layers must be cheap: {l1} vs {l3}"
        );
    }

    #[test]
    fn batch_scales_linearly_in_steady_state() {
        let cfg = ArchConfig::new(Task::Classify, 8, 3, "YNY");
        let r = ReuseFactors::new(12, 1, 1);
        let b50 = LatencyModel::batch_cycles(&cfg, &r, 50, 30);
        let b200 = LatencyModel::batch_cycles(&cfg, &r, 200, 30);
        let ratio = b200 as f64 / b50 as f64;
        assert!((ratio - 4.0).abs() < 0.05, "ratio {ratio}");
    }

    #[test]
    fn paper_scale_sanity_table4() {
        // Classifier H=8, NL=3, Rx=12, Rh=1, batch 50, S=30 at 100 MHz:
        // the paper reports 25.23 ms. II = 12 -> 12*140*1500 = 25.2 Mcycles
        // = 25.2 ms. Our model must land within a few percent.
        let cfg = ArchConfig::new(Task::Classify, 8, 3, "YNY");
        let r = ReuseFactors::new(12, 1, 1);
        let ms = LatencyModel::batch_ms(&cfg, &r, 50, 30, ZC706.clock_hz);
        assert!(
            (ms - 25.23).abs() / 25.23 < 0.05,
            "model {ms} ms vs paper 25.23 ms"
        );
    }

    /// Precision reaches latency through the constraint-solved reuse
    /// (INT8 packing frees DSPs; `reuse_search_q` spends them on lower
    /// R), NOT through the II formula — at a fixed reuse the timing is
    /// format-independent by design (crediting both halved DSPs and
    /// halved II would double-count the packing).
    #[test]
    fn precision_gains_latency_via_reuse_not_ii() {
        use crate::dse::space::reuse_search_q;
        use crate::fixedpoint::Precision;
        // DSP-constrained net: II > 1 at q16.
        let cfg = ArchConfig::new(Task::Classify, 32, 3, "YYY");
        let r16 = reuse_search_q(&cfg, &ZC706, &Precision::q16()).unwrap();
        let r8 = reuse_search_q(&cfg, &ZC706, &Precision::q8()).unwrap();
        let t16 = LatencyModel::design_timing(&cfg, &r16);
        let t8 = LatencyModel::design_timing(&cfg, &r8);
        assert!(t16.ii > 1, "premise: DSP-constrained at 16 bit");
        assert!(t8.ii < t16.ii, "packed DSPs buy a lower feasible reuse");
        let ms16 =
            LatencyModel::batch_ms(&cfg, &r16, 50, 30, ZC706.clock_hz);
        let ms8 = LatencyModel::batch_ms(&cfg, &r8, 50, 30, ZC706.clock_hz);
        assert!(ms8 < 0.75 * ms16, "{ms8} !< 0.75 * {ms16}");
    }

    #[test]
    fn single_sample_much_faster() {
        let cfg = ArchConfig::new(Task::Classify, 8, 1, "N");
        let r = ReuseFactors::new(2, 1, 1);
        let s1 = LatencyModel::batch_ms(&cfg, &r, 50, 1, ZC706.clock_hz);
        let s30 = LatencyModel::batch_ms(&cfg, &r, 50, 30, ZC706.clock_hz);
        assert!(s30 / s1 > 20.0, "MC sampling dominates: {s1} vs {s30}");
    }
}
