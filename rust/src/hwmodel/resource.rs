//! Resource model (paper Sec. IV-B).
//!
//! DSPs are the bottleneck resource. For LSTM layer i with input I_i,
//! hidden H_i and reuse factors (R_x, R_h):
//!
//! ```text
//! DSP_i      = 4*I_i*H_i / R_x  +  4*H_i^2 / R_h  +  4*H_i
//! DSP_design = sum_i DSP_i + DSP_d   <=   DSP_total
//! DSP_d      = H_L*O*T / R_d   (autoencoder: temporal dense)
//!            = H_L*O   / R_d   (classifier)
//! ```
//!
//! The `4*H_i` term is the LSTM tail: `f_t * c_{t-1}` needs two cascaded
//! Xilinx DSPs per multiplier on the 32-bit c path plus `i_t * g_t` and
//! `o_t * tanh(c_t)`. The paper adds 5% slack to DSP_total because HLS
//! replaces some multipliers with fabric logic.
//!
//! LUT/FF/BRAM estimators are calibrated against Table III.

use crate::config::{ArchConfig, Task};
use super::Platform;

/// Reuse factors R = {R_x, R_h, R_d} (Sec. IV-A: hardware parameters).
/// A reuse factor of R means each physical multiplier is time-multiplexed
/// R times per MVM, cutting DSPs by 1/R and raising II to >= R.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReuseFactors {
    pub rx: usize,
    pub rh: usize,
    pub rd: usize,
}

impl ReuseFactors {
    pub fn new(rx: usize, rh: usize, rd: usize) -> Self {
        assert!(rx >= 1 && rh >= 1 && rd >= 1, "reuse factors are >= 1");
        Self { rx, rh, rd }
    }
}

/// Full resource estimate for one accelerator configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResourceEstimate {
    pub dsps: f64,
    pub luts: f64,
    pub ffs: f64,
    pub brams: f64,
}

impl ResourceEstimate {
    pub fn fits(&self, platform: &Platform) -> bool {
        // The 5% DSP slack from the paper: HLS converts some multipliers
        // to fabric logic, so a design may "fit" slightly above DSP_total.
        self.dsps <= platform.dsps as f64 * 1.05
            && self.luts <= platform.luts as f64
            && self.brams <= platform.brams as f64
            && self.ffs <= platform.ffs as f64
    }

    pub fn utilization(&self, platform: &Platform) -> [f64; 4] {
        [
            self.luts / platform.luts as f64 * 100.0,
            self.ffs / platform.ffs as f64 * 100.0,
            self.brams / platform.brams as f64 * 100.0,
            self.dsps / platform.dsps as f64 * 100.0,
        ]
    }
}

/// The analytic resource model.
pub struct ResourceModel;

impl ResourceModel {
    /// DSPs of LSTM layer i (continuous, as in the paper's formula).
    pub fn lstm_dsps(idim: usize, hdim: usize, r: &ReuseFactors) -> f64 {
        let mvm_x = 4.0 * idim as f64 * hdim as f64 / r.rx as f64;
        let mvm_h = 4.0 * (hdim * hdim) as f64 / r.rh as f64;
        let tail = 4.0 * hdim as f64;
        mvm_x + mvm_h + tail
    }

    /// DSPs of the final dense layer.
    pub fn dense_dsps(cfg: &ArchConfig, r: &ReuseFactors) -> f64 {
        let (f, o) = cfg.dense_dims();
        match cfg.task {
            // Temporal dense applies over all T steps in the pipeline.
            Task::Anomaly => {
                (f * o * cfg.seq_len) as f64 / r.rd as f64
            }
            Task::Classify => (f * o) as f64 / r.rd as f64,
        }
    }

    /// Whole-design estimate (Sec. IV-B formulas + Table III-calibrated
    /// LUT/FF/BRAM coefficients).
    pub fn estimate(cfg: &ArchConfig, r: &ReuseFactors) -> ResourceEstimate {
        let mut dsps = 0.0;
        let mut luts = 8_000.0; // AXI/DMA + control plumbing
        let mut ffs = 10_000.0;
        let mut brams = 4.0; // I/O FIFOs
        for (l, (idim, hdim)) in cfg.lstm_dims().iter().enumerate() {
            dsps += Self::lstm_dsps(*idim, *hdim, r);
            // On-chip weights become registers/LUTs when synthesised
            // (Sec. III-A: "weights and biases are mapped on-chip ...
            // into registers"), so LUT/FF scale with weight count and
            // with the unrolled MVM adder trees.
            let weights = (4 * idim * hdim + 4 * hdim * hdim + 4 * hdim) as f64;
            luts += weights * 9.5;
            ffs += weights * 10.0;
            // Activation LUTs: 2 BRAM-backed tables (sigmoid + tanh) per
            // engine, plus h/c stream buffers per timestep pipe stage.
            brams += 6.0 + (*hdim as f64 / 16.0).ceil() * 2.0;
            // Bernoulli sampler (3 LFSRs + SIPO + FIFO) per Bayesian layer.
            if cfg.bayes[l] {
                luts += 220.0;
                ffs += 180.0;
                brams += 1.0; // mask FIFO
            }
        }
        dsps += Self::dense_dsps(cfg, r);
        let (f, o) = cfg.dense_dims();
        luts += (f * o) as f64 * 9.5;
        ffs += (f * o) as f64 * 10.0;
        ResourceEstimate { dsps, luts, ffs, brams }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArchConfig, Task};
    use crate::hwmodel::ZC706;

    #[test]
    fn formula_terms_match_paper() {
        // Single layer I=16, H=16, Rx=16, Rh=5:
        // 4*16*16/16 = 64; 4*256/5 = 204.8; tail 64.
        let d = ResourceModel::lstm_dsps(16, 16, &ReuseFactors::new(16, 5, 1));
        assert!((d - (64.0 + 204.8 + 64.0)).abs() < 1e-9);
    }

    #[test]
    fn dense_term_autoencoder_vs_classifier() {
        let ae = ArchConfig::new(Task::Anomaly, 16, 2, "YNYN");
        let r = ReuseFactors::new(16, 5, 16);
        // H_L * O * T / R_d = 16*1*140/16 = 140.
        assert!((ResourceModel::dense_dsps(&ae, &r) - 140.0).abs() < 1e-9);
        let cls = ArchConfig::new(Task::Classify, 8, 3, "YNY");
        let rc = ReuseFactors::new(12, 1, 1);
        // H_L * O / R_d = 8*4 = 32.
        assert!((ResourceModel::dense_dsps(&cls, &rc) - 32.0).abs() < 1e-9);
    }

    #[test]
    fn higher_reuse_fewer_dsps() {
        let cfg = ArchConfig::new(Task::Classify, 8, 3, "YNY");
        let lo = ResourceModel::estimate(&cfg, &ReuseFactors::new(1, 1, 1));
        let hi = ResourceModel::estimate(&cfg, &ReuseFactors::new(8, 8, 8));
        assert!(hi.dsps < lo.dsps);
        // Tail DSPs (4H per layer) are reuse-independent.
        assert!(hi.dsps >= (4 * 8 * 3) as f64);
    }

    #[test]
    fn paper_classifier_fits_zc706() {
        // The paper's classifier point (H=8, NL=3) with its reported reuse
        // factors must fit the chip under the 5% HLS slack.
        let cfg = ArchConfig::new(Task::Classify, 8, 3, "YNY");
        let est = ResourceModel::estimate(&cfg, &ReuseFactors::new(12, 1, 1));
        assert!(est.fits(&ZC706), "dsps = {}", est.dsps);
        assert!(est.dsps > 700.0, "should be near-full: {}", est.dsps);
    }

    #[test]
    fn bayesian_layers_cost_extra_fabric() {
        let b = ArchConfig::new(Task::Classify, 8, 3, "YYY");
        let p = ArchConfig::new(Task::Classify, 8, 3, "NNN");
        let r = ReuseFactors::new(4, 4, 1);
        let eb = ResourceModel::estimate(&b, &r);
        let ep = ResourceModel::estimate(&p, &r);
        assert!(eb.luts > ep.luts);
        assert!(eb.brams > ep.brams);
        assert_eq!(eb.dsps, ep.dsps, "samplers use no DSPs");
    }

    #[test]
    fn utilization_percentages() {
        let est = ResourceEstimate {
            dsps: 450.0,
            luts: 109_500.0,
            ffs: 43_700.0,
            brams: 54.5,
        };
        let u = est.utilization(&ZC706);
        assert!((u[0] - 50.0).abs() < 1e-9);
        assert!((u[1] - 10.0).abs() < 1e-9);
        assert!((u[2] - 10.0).abs() < 1e-9);
        assert!((u[3] - 50.0).abs() < 1e-9);
    }
}
