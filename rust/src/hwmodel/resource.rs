//! Resource model (paper Sec. IV-B), precision-aware.
//!
//! DSPs are the bottleneck resource. For LSTM layer i with input I_i,
//! hidden H_i, reuse factors (R_x, R_h) and per-layer DSP packing P_i
//! (two ≤ 8-bit MACs per DSP48 slice, one otherwise — the INT8 packing
//! Fan et al., arXiv:2105.09163, exploit):
//!
//! ```text
//! DSP_i      = 4*I_i*H_i / (R_x*P_i)  +  4*H_i^2 / (R_h*P_i)  +  4*H_i
//! DSP_design = sum_i DSP_i + DSP_d   <=   DSP_total
//! DSP_d      = H_L*O*T / (R_d*P_d)   (autoencoder: temporal dense)
//!            = H_L*O   / (R_d*P_d)   (classifier)
//! ```
//!
//! The `4*H_i` term is the LSTM tail: `f_t * c_{t-1}` needs two cascaded
//! Xilinx DSPs per multiplier on the widened c path plus `i_t * g_t` and
//! `o_t * tanh(c_t)`; the cell path stays wide at every activation
//! format, so the tail does not scale with precision. The paper adds 5%
//! slack to DSP_total because HLS replaces some multipliers with fabric
//! logic.
//!
//! LUT/FF/BRAM estimators are calibrated against Table III at the
//! paper's 16-bit instance; on-chip weight fabric and the activation
//! tables scale with the word width (`docs/quantization.md`).

use crate::config::{ArchConfig, Task};
use crate::fixedpoint::Precision;
use super::Platform;

/// Reuse factors R = {R_x, R_h, R_d} (Sec. IV-A: hardware parameters).
/// A reuse factor of R means each physical multiplier is time-multiplexed
/// R times per MVM, cutting DSPs by 1/R and raising II to >= R.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReuseFactors {
    pub rx: usize,
    pub rh: usize,
    pub rd: usize,
}

impl ReuseFactors {
    pub fn new(rx: usize, rh: usize, rd: usize) -> Self {
        assert!(rx >= 1 && rh >= 1 && rd >= 1, "reuse factors are >= 1");
        Self { rx, rh, rd }
    }
}

/// Full resource estimate for one accelerator configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResourceEstimate {
    pub dsps: f64,
    pub luts: f64,
    pub ffs: f64,
    pub brams: f64,
}

impl ResourceEstimate {
    pub fn fits(&self, platform: &Platform) -> bool {
        // The 5% DSP slack from the paper: HLS converts some multipliers
        // to fabric logic, so a design may "fit" slightly above DSP_total.
        self.dsps <= platform.dsps as f64 * 1.05
            && self.luts <= platform.luts as f64
            && self.brams <= platform.brams as f64
            && self.ffs <= platform.ffs as f64
    }

    pub fn utilization(&self, platform: &Platform) -> [f64; 4] {
        [
            self.luts / platform.luts as f64 * 100.0,
            self.ffs / platform.ffs as f64 * 100.0,
            self.brams / platform.brams as f64 * 100.0,
            self.dsps / platform.dsps as f64 * 100.0,
        ]
    }
}

/// The analytic resource model.
pub struct ResourceModel;

impl ResourceModel {
    /// DSPs of LSTM layer i (continuous, as in the paper's formula) at
    /// the 16-bit reference precision.
    pub fn lstm_dsps(idim: usize, hdim: usize, r: &ReuseFactors) -> f64 {
        Self::lstm_dsps_packed(idim, hdim, r, 1)
    }

    /// DSPs of LSTM layer i with `pack` MACs per DSP slice (2 at ≤ 8-bit
    /// operands). The 4H tail runs on the widened cell path and does not
    /// pack.
    pub fn lstm_dsps_packed(
        idim: usize,
        hdim: usize,
        r: &ReuseFactors,
        pack: u64,
    ) -> f64 {
        let pack = pack as f64;
        let mvm_x = 4.0 * idim as f64 * hdim as f64 / (r.rx as f64 * pack);
        let mvm_h = 4.0 * (hdim * hdim) as f64 / (r.rh as f64 * pack);
        let tail = 4.0 * hdim as f64;
        mvm_x + mvm_h + tail
    }

    /// DSPs of the final dense layer at the 16-bit reference precision.
    pub fn dense_dsps(cfg: &ArchConfig, r: &ReuseFactors) -> f64 {
        Self::dense_dsps_packed(cfg, r, 1)
    }

    /// DSPs of the final dense layer with `pack` MACs per DSP slice.
    pub fn dense_dsps_packed(
        cfg: &ArchConfig,
        r: &ReuseFactors,
        pack: u64,
    ) -> f64 {
        let (f, o) = cfg.dense_dims();
        let div = r.rd as f64 * pack as f64;
        match cfg.task {
            // Temporal dense applies over all T steps in the pipeline.
            Task::Anomaly => (f * o * cfg.seq_len) as f64 / div,
            Task::Classify => (f * o) as f64 / div,
        }
    }

    /// Whole-design estimate at the paper's 16-bit precision
    /// (numerically identical to `estimate_q` with `Precision::q16()`).
    pub fn estimate(cfg: &ArchConfig, r: &ReuseFactors) -> ResourceEstimate {
        Self::estimate_q(cfg, r, &Precision::q16())
    }

    /// Whole-design estimate (Sec. IV-B formulas + Table III-calibrated
    /// LUT/FF/BRAM coefficients) at an explicit precision: MVM DSPs pack
    /// at ≤ 8 bit, weight-register fabric and the activation tables /
    /// stream buffers scale with the activation word width.
    pub fn estimate_q(
        cfg: &ArchConfig,
        r: &ReuseFactors,
        precision: &Precision,
    ) -> ResourceEstimate {
        let mut dsps = 0.0;
        let mut luts = 8_000.0; // AXI/DMA + control plumbing
        let mut ffs = 10_000.0;
        let mut brams = 4.0; // I/O FIFOs
        for (l, (idim, hdim)) in cfg.lstm_dims().iter().enumerate() {
            let spec = precision.spec_for(l);
            let bits = spec.act.total_bits as f64;
            let scale = bits / 16.0;
            dsps += Self::lstm_dsps_packed(
                *idim,
                *hdim,
                r,
                spec.act.macs_per_dsp(),
            );
            // On-chip weights become registers/LUTs when synthesised
            // (Sec. III-A: "weights and biases are mapped on-chip ...
            // into registers"), so LUT/FF scale with weight count, the
            // unrolled MVM adder trees — and the word width.
            let weights = (4 * idim * hdim + 4 * hdim * hdim + 4 * hdim) as f64;
            luts += weights * 9.5 * scale;
            ffs += weights * 10.0 * scale;
            // Activation LUTs: 2 BRAM-backed tables (sigmoid + tanh) per
            // engine — word width scales their footprint — plus h/c
            // stream buffers per timestep pipe stage.
            brams += 2.0 + 4.0 * scale + (*hdim as f64 * bits / 256.0).ceil() * 2.0;
            // Bernoulli sampler (3 LFSRs + SIPO + FIFO) per Bayesian
            // layer; mask bits are width-independent (1 bit per DX).
            if cfg.bayes[l] {
                luts += 220.0;
                ffs += 180.0;
                brams += 1.0; // mask FIFO
            }
        }
        let dense_bits = precision.default.act.total_bits as f64;
        dsps += Self::dense_dsps_packed(
            cfg,
            r,
            precision.default.act.macs_per_dsp(),
        );
        let (f, o) = cfg.dense_dims();
        luts += (f * o) as f64 * 9.5 * (dense_bits / 16.0);
        ffs += (f * o) as f64 * 10.0 * (dense_bits / 16.0);
        ResourceEstimate { dsps, luts, ffs, brams }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArchConfig, Task};
    use crate::hwmodel::ZC706;

    #[test]
    fn formula_terms_match_paper() {
        // Single layer I=16, H=16, Rx=16, Rh=5:
        // 4*16*16/16 = 64; 4*256/5 = 204.8; tail 64.
        let d = ResourceModel::lstm_dsps(16, 16, &ReuseFactors::new(16, 5, 1));
        assert!((d - (64.0 + 204.8 + 64.0)).abs() < 1e-9);
    }

    #[test]
    fn dense_term_autoencoder_vs_classifier() {
        let ae = ArchConfig::new(Task::Anomaly, 16, 2, "YNYN");
        let r = ReuseFactors::new(16, 5, 16);
        // H_L * O * T / R_d = 16*1*140/16 = 140.
        assert!((ResourceModel::dense_dsps(&ae, &r) - 140.0).abs() < 1e-9);
        let cls = ArchConfig::new(Task::Classify, 8, 3, "YNY");
        let rc = ReuseFactors::new(12, 1, 1);
        // H_L * O / R_d = 8*4 = 32.
        assert!((ResourceModel::dense_dsps(&cls, &rc) - 32.0).abs() < 1e-9);
    }

    #[test]
    fn higher_reuse_fewer_dsps() {
        let cfg = ArchConfig::new(Task::Classify, 8, 3, "YNY");
        let lo = ResourceModel::estimate(&cfg, &ReuseFactors::new(1, 1, 1));
        let hi = ResourceModel::estimate(&cfg, &ReuseFactors::new(8, 8, 8));
        assert!(hi.dsps < lo.dsps);
        // Tail DSPs (4H per layer) are reuse-independent.
        assert!(hi.dsps >= (4 * 8 * 3) as f64);
    }

    #[test]
    fn paper_classifier_fits_zc706() {
        // The paper's classifier point (H=8, NL=3) with its reported reuse
        // factors must fit the chip under the 5% HLS slack.
        let cfg = ArchConfig::new(Task::Classify, 8, 3, "YNY");
        let est = ResourceModel::estimate(&cfg, &ReuseFactors::new(12, 1, 1));
        assert!(est.fits(&ZC706), "dsps = {}", est.dsps);
        assert!(est.dsps > 700.0, "should be near-full: {}", est.dsps);
    }

    #[test]
    fn bayesian_layers_cost_extra_fabric() {
        let b = ArchConfig::new(Task::Classify, 8, 3, "YYY");
        let p = ArchConfig::new(Task::Classify, 8, 3, "NNN");
        let r = ReuseFactors::new(4, 4, 1);
        let eb = ResourceModel::estimate(&b, &r);
        let ep = ResourceModel::estimate(&p, &r);
        assert!(eb.luts > ep.luts);
        assert!(eb.brams > ep.brams);
        assert_eq!(eb.dsps, ep.dsps, "samplers use no DSPs");
    }

    #[test]
    fn q16_estimate_identical_to_legacy_wrapper() {
        // `estimate` routes through `estimate_q(Precision::q16())`; the
        // numbers must be exactly the Table III-calibrated ones (scale
        // factors of 1.0 are exact in f64).
        let cfg = ArchConfig::new(Task::Classify, 8, 3, "YNY");
        let r = ReuseFactors::new(12, 1, 1);
        let a = ResourceModel::estimate(&cfg, &r);
        let b = ResourceModel::estimate_q(&cfg, &r, &Precision::q16());
        assert_eq!(a, b);
        // And the hand-checked classifier point still holds.
        assert!(a.fits(&ZC706));
    }

    #[test]
    fn narrower_precision_costs_less_everywhere() {
        let cfg = ArchConfig::new(Task::Classify, 16, 2, "YY");
        let r = ReuseFactors::new(4, 2, 1);
        let q16 = ResourceModel::estimate_q(&cfg, &r, &Precision::q16());
        let q12 = ResourceModel::estimate_q(&cfg, &r, &Precision::q12());
        let q8 = ResourceModel::estimate_q(&cfg, &r, &Precision::q8());
        // 12-bit: same DSP packing, narrower fabric/BRAM.
        assert_eq!(q12.dsps, q16.dsps, "12-bit MACs still use a full DSP");
        assert!(q12.luts < q16.luts);
        assert!(q12.brams < q16.brams);
        // 8-bit: packed MVMs — only the reuse-independent 4H tail and
        // the dense head keep their full cost.
        assert!(q8.dsps < q16.dsps);
        assert!(q8.luts < q12.luts);
        // Tail is precision-independent: DSPs never drop below 4H/layer.
        assert!(q8.dsps >= (4 * 16 * 2) as f64);
    }

    #[test]
    fn per_layer_override_changes_only_that_layer() {
        use crate::fixedpoint::QuantSpec;
        let cfg = ArchConfig::new(Task::Classify, 16, 2, "YY");
        let r = ReuseFactors::new(4, 2, 1);
        let uniform = ResourceModel::estimate_q(&cfg, &r, &Precision::q16());
        let mixed = ResourceModel::estimate_q(
            &cfg,
            &r,
            &Precision::q16().with_layer(1, QuantSpec::q8()),
        );
        assert!(mixed.dsps < uniform.dsps);
        // Exactly layer 1's packable MVM DSPs are halved.
        let saved = ResourceModel::lstm_dsps_packed(16, 16, &r, 1)
            - ResourceModel::lstm_dsps_packed(16, 16, &r, 2);
        assert!((uniform.dsps - mixed.dsps - saved).abs() < 1e-9);
    }

    #[test]
    fn utilization_percentages() {
        let est = ResourceEstimate {
            dsps: 450.0,
            luts: 109_500.0,
            ffs: 43_700.0,
            brams: 54.5,
        };
        let u = est.utilization(&ZC706);
        assert!((u[0] - 50.0).abs() < 1e-9);
        assert!((u[1] - 10.0).abs() < 1e-9);
        assert!((u[2] - 10.0).abs() < 1e-9);
        assert!((u[3] - 50.0).abs() < 1e-9);
    }
}
