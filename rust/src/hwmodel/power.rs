//! Power and energy models (Table IV).
//!
//! FPGA power is an activity model over the utilised resources, calibrated
//! against the paper's Vivado-reported numbers (3.44 W for the anomaly
//! design with 207k LUT / 758 DSP, 2.47 W for the classifier with 62k LUT
//! / 898 DSP). CPU/GPU envelopes reproduce the paper's power-meter /
//! nvidia-smi readings (15-16 W CPU under MKLDNN load, 65-69 W GPU — well
//! under TDP because the tiny RNN is launch-bound). Energy is J/sample =
//! P * latency / batch.

use super::resource::ResourceEstimate;
use crate::fixedpoint::Precision;

pub struct PowerModel;

impl PowerModel {
    /// Static + per-resource dynamic power [W], least-squares calibrated
    /// on the two Table III/IV design points (at the paper's 16-bit
    /// operands — the coefficients embed full-width toggle activity).
    pub const FPGA_STATIC_W: f64 = 0.30;
    pub const W_PER_LUT: f64 = 8.46e-6;
    pub const W_PER_DSP: f64 = 1.83e-3;
    pub const W_PER_BRAM: f64 = 8.0e-4;
    pub const W_PER_FF: f64 = 4.0e-7;

    /// FPGA board power for a synthesised design at the 16-bit
    /// reference operands (numerically identical to
    /// [`PowerModel::fpga_watts_q`] with `Precision::q16()`).
    pub fn fpga_watts(res: &ResourceEstimate) -> f64 {
        Self::FPGA_STATIC_W
            + Self::W_PER_LUT * res.luts
            + Self::W_PER_DSP * res.dsps
            + Self::W_PER_BRAM * res.brams
            + Self::W_PER_FF * res.ffs
    }

    /// Dynamic-activity scale for narrow operands: switching energy in
    /// the MVM datapaths tracks the number of toggling operand bits, so
    /// the *dynamic* term scales linearly between half activity (datapath
    /// width fixed, operands narrowed to nothing) and full activity at
    /// 16 bits — `0.5 + 0.5 * bits / 16`. Clock trees, control and the
    /// static term do not narrow, which is why the floor is 1/2 rather
    /// than `bits / 16`. Per-layer overrides are averaged over the
    /// design's LSTM layers.
    pub fn width_activity(precision: &Precision, lstm_layers: usize) -> f64 {
        let layers = lstm_layers.max(1);
        let mean_bits: f64 = (0..layers)
            .map(|l| precision.spec_for(l).act.total_bits as f64)
            .sum::<f64>()
            / layers as f64;
        0.5 + 0.5 * mean_bits / 16.0
    }

    /// FPGA board power at an explicit precision (ISSUE 5 satellite,
    /// PR 4 follow-up): the resource *counts* already shrink with the
    /// format (`ResourceModel::estimate_q`); this adds the second-order
    /// effect that the resources which remain also toggle fewer bits.
    /// Exactly [`PowerModel::fpga_watts`] at q16 — the Table IV
    /// calibration is untouched.
    pub fn fpga_watts_q(
        res: &ResourceEstimate,
        precision: &Precision,
        lstm_layers: usize,
    ) -> f64 {
        let a = Self::width_activity(precision, lstm_layers);
        Self::FPGA_STATIC_W
            + a * (Self::W_PER_LUT * res.luts
                + Self::W_PER_DSP * res.dsps
                + Self::W_PER_BRAM * res.brams
                + Self::W_PER_FF * res.ffs)
    }

    /// Xeon E5-2680 v2 under the MKLDNN RNN workload (paper power meter:
    /// 15-16 W above idle attributed to the job).
    pub fn cpu_watts() -> f64 {
        15.5
    }

    /// TITAN X Pascal during launch-bound small-RNN inference
    /// (nvidia-smi: 65-69 W).
    pub fn gpu_watts() -> f64 {
        67.0
    }

    /// Energy per sample [J]: power * latency / batch.
    pub fn joules_per_sample(watts: f64, latency_ms: f64, batch: usize) -> f64 {
        watts * (latency_ms / 1e3) / batch as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_matches_paper_anomaly_point() {
        // Anomaly design: 207k LUT, 218k FF, 149 BRAM, 758 DSP -> 3.44 W.
        let res = ResourceEstimate {
            dsps: 758.0,
            luts: 207_000.0,
            ffs: 218_000.0,
            brams: 149.0,
        };
        let w = PowerModel::fpga_watts(&res);
        assert!((w - 3.44).abs() < 0.35, "got {w} W, paper 3.44 W");
    }

    #[test]
    fn calibration_matches_paper_classifier_point() {
        // Classifier design: 62k LUT, 52k FF, 64 BRAM, 898 DSP -> 2.47 W.
        let res = ResourceEstimate {
            dsps: 898.0,
            luts: 62_000.0,
            ffs: 52_000.0,
            brams: 64.0,
        };
        let w = PowerModel::fpga_watts(&res);
        assert!((w - 2.47).abs() < 0.35, "got {w} W, paper 2.47 W");
    }

    #[test]
    fn fpga_far_below_cpu_gpu() {
        let res = ResourceEstimate {
            dsps: 900.0,
            luts: 219_000.0,
            ffs: 437_000.0,
            brams: 545.0,
        };
        let w = PowerModel::fpga_watts(&res);
        assert!(w < PowerModel::cpu_watts() / 2.0);
        assert!(w < PowerModel::gpu_watts() / 10.0);
    }

    /// Bitwidth sensitivity (ISSUE 5 satellite): q16 reproduces the
    /// calibrated model exactly; narrower operands cut the dynamic
    /// term monotonically but never below static + half dynamic.
    #[test]
    fn width_scaled_power_is_calibrated_at_q16_and_monotone() {
        use crate::fixedpoint::QuantSpec;
        let res = ResourceEstimate {
            dsps: 758.0,
            luts: 207_000.0,
            ffs: 218_000.0,
            brams: 149.0,
        };
        let nl = 4;
        let w16 = PowerModel::fpga_watts_q(&res, &Precision::q16(), nl);
        assert_eq!(w16, PowerModel::fpga_watts(&res), "q16 == legacy");
        let w12 = PowerModel::fpga_watts_q(&res, &Precision::q12(), nl);
        let w8 = PowerModel::fpga_watts_q(&res, &Precision::q8(), nl);
        assert!(w8 < w12 && w12 < w16, "{w8} < {w12} < {w16}");
        let dynamic = PowerModel::fpga_watts(&res) - PowerModel::FPGA_STATIC_W;
        assert!(w8 > PowerModel::FPGA_STATIC_W + 0.5 * dynamic);
        // Mixed per-layer precision lands between the uniform bounds.
        let mixed = Precision::q16().with_layer(0, QuantSpec::q8());
        let wm = PowerModel::fpga_watts_q(&res, &mixed, nl);
        assert!(w8 < wm && wm < w16);
        // Activity scale itself: q8 over 16 bits = 0.5 + 0.25.
        let a8 = PowerModel::width_activity(&Precision::q8(), 1);
        assert!((a8 - 0.75).abs() < 1e-12);
    }

    #[test]
    fn energy_accounting() {
        // Paper Table IV anomaly FPGA: 41.31 ms, 3.44 W, batch 50
        // -> 0.00284 J/sample (the paper rounds to 0.005 with overheads).
        let j = PowerModel::joules_per_sample(3.44, 41.31, 50);
        assert!(j > 0.002 && j < 0.006, "{j}");
    }
}
