//! Analytic GPU baseline (DESIGN.md §Substitutions).
//!
//! The paper measures a TITAN X Pascal running PyTorch + cuDNN/TensorRT.
//! No GPU exists in this environment, so we model it. The key physics the
//! model must capture — and the reason the FPGA wins in the paper — is
//! that a tiny Bayesian RNN on a GPU is *kernel-launch-bound*: every one
//! of the T=140 timesteps of every one of the S=30 MC passes issues a
//! couple of kernels per LSTM layer, and at H<=64 each kernel does far too
//! little work to cover its ~10 us launch+sync cost. Batch size barely
//! moves the total (the paper's 379.81 ms at batch 50 vs 402.76 ms at
//! batch 200), because extra rows ride along inside the same launches.
//!
//! Model: latency = launches * t_launch + compute_flops / roofline.
//! Calibrated: t_launch = 10 us, effective roofline 4 TFLOP/s (fp32 TITAN
//! X Pascal ~11 TFLOP/s peak; small GEMMs reach a fraction).

use crate::config::{ArchConfig, Task};

pub struct GpuModel;

impl GpuModel {
    pub const T_LAUNCH_S: f64 = 10.0e-6;
    pub const ROOFLINE_FLOPS: f64 = 4.0e12;
    /// Fixed framework overhead per inference call (dispatcher, Python
    /// binding, mask sampling on device).
    pub const CALL_OVERHEAD_S: f64 = 2.0e-3;

    /// Kernels per timestep per LSTM layer under cuDNN for a masked
    /// (MCD) cell: one fused gate GEMM + one elementwise tail.
    const KERNELS_PER_LSTM_STEP: f64 = 2.0;

    /// FLOPs of one full forward pass of one beat (one MC sample).
    pub fn flops_per_pass(cfg: &ArchConfig) -> f64 {
        let mut fl = 0.0;
        for (i, h) in cfg.lstm_dims() {
            // 4 gates, x and h MVMs, MAC = 2 flops, T steps.
            fl += (cfg.seq_len * 4 * 2 * (i * h + h * h)) as f64;
        }
        let (f, o) = cfg.dense_dims();
        let dense_rows = match cfg.task {
            Task::Anomaly => cfg.seq_len,
            Task::Classify => 1,
        };
        fl += (dense_rows * 2 * f * o) as f64;
        fl
    }

    /// Kernel launches for a batched inference with S MC samples.
    /// MC samples need distinct masks, so cuDNN's fused-sequence path is
    /// unavailable; each timestep launches per layer, samples share
    /// launches only within a batch.
    pub fn launches(cfg: &ArchConfig, s: usize) -> f64 {
        let lstm_launches = cfg.num_lstm_layers() as f64
            * cfg.seq_len as f64
            * Self::KERNELS_PER_LSTM_STEP;
        let dense_launches = match cfg.task {
            Task::Anomaly => cfg.seq_len as f64,
            Task::Classify => 1.0,
        };
        s as f64 * (lstm_launches + dense_launches)
    }

    /// Modelled latency [ms] for `batch` beats with `s` MC samples.
    pub fn latency_ms(cfg: &ArchConfig, batch: usize, s: usize) -> f64 {
        let launch_time = Self::launches(cfg, s) * Self::T_LAUNCH_S;
        let compute = Self::flops_per_pass(cfg)
            * (batch * s) as f64
            / Self::ROOFLINE_FLOPS;
        (Self::CALL_OVERHEAD_S + launch_time + compute) * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classifier_matches_paper_scale() {
        // Paper: classifier (8,3,YNY), batch 50, S=30 -> 245.14 ms GPU.
        let cfg = ArchConfig::new(Task::Classify, 8, 3, "YNY");
        let ms = GpuModel::latency_ms(&cfg, 50, 30);
        assert!(
            ms > 150.0 && ms < 350.0,
            "modelled {ms} ms, paper 245.14 ms"
        );
    }

    #[test]
    fn anomaly_matches_paper_scale() {
        // Paper: anomaly (16,2,YNYN), batch 50, S=30 -> 379.81 ms GPU.
        let cfg = ArchConfig::new(Task::Anomaly, 16, 2, "YNYN");
        let ms = GpuModel::latency_ms(&cfg, 50, 30);
        assert!(
            ms > 250.0 && ms < 550.0,
            "modelled {ms} ms, paper 379.81 ms"
        );
    }

    #[test]
    fn launch_bound_batch_insensitivity() {
        // The paper's signature shape: 4x the batch costs < 1.2x latency.
        let cfg = ArchConfig::new(Task::Anomaly, 16, 2, "YNYN");
        let b50 = GpuModel::latency_ms(&cfg, 50, 30);
        let b200 = GpuModel::latency_ms(&cfg, 200, 30);
        assert!(b200 / b50 < 1.2, "{b50} -> {b200}");
        assert!(b200 > b50);
    }

    #[test]
    fn s1_pointwise_is_fast() {
        // Opt-Latency configs run S=1; paper: 6.49 ms for (8,1,N) b50.
        let cfg = ArchConfig::new(Task::Classify, 8, 1, "N");
        let ms = GpuModel::latency_ms(&cfg, 50, 1);
        assert!(ms > 2.0 && ms < 15.0, "modelled {ms} ms, paper 6.49 ms");
    }

    #[test]
    fn flops_count() {
        let cfg = ArchConfig::new(Task::Classify, 8, 1, "N");
        // T*(4*2*(1*8+8*8)) + 2*8*4 = 140*576 + 64 = 80704.
        assert_eq!(GpuModel::flops_per_pass(&cfg), 80_704.0);
    }
}
