//! Analytic hardware models (paper Sec. IV-B/IV-C) plus the platform
//! descriptions used by the evaluation: the Xilinx ZC706 target, the
//! CPU/GPU baseline envelopes, and the power/energy accounting of
//! Table IV.

pub mod gpu;
pub mod latency;
pub mod power;
pub mod resource;

pub use gpu::GpuModel;
pub use latency::{LatencyModel, LayerTiming};
pub use power::PowerModel;
pub use resource::{ResourceEstimate, ResourceModel, ReuseFactors};

/// Xilinx ZC706 (XC7Z045) resources and clock — the paper's target board.
#[derive(Debug, Clone, Copy)]
pub struct Platform {
    pub name: &'static str,
    pub luts: u64,
    pub ffs: u64,
    pub brams: u64,
    pub dsps: u64,
    pub clock_hz: f64,
}

/// The evaluation board (Table III "Available" row; 100 MHz design clock).
pub const ZC706: Platform = Platform {
    name: "ZC706 (XC7Z045)",
    luts: 219_000,
    ffs: 437_000,
    brams: 545,
    dsps: 900,
    clock_hz: 100.0e6,
};

impl Platform {
    /// Convert a cycle count to milliseconds at the design clock.
    pub fn cycles_to_ms(&self, cycles: u64) -> f64 {
        cycles as f64 / self.clock_hz * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zc706_table3_available_row() {
        assert_eq!(ZC706.dsps, 900);
        assert_eq!(ZC706.brams, 545);
        assert_eq!(ZC706.luts, 219_000);
        assert_eq!(ZC706.ffs, 437_000);
    }

    #[test]
    fn cycles_to_ms_at_100mhz() {
        assert!((ZC706.cycles_to_ms(100_000) - 1.0).abs() < 1e-12);
    }
}
