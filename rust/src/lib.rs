//! Reproduction of *Optimizing Bayesian Recurrent Neural Networks on an
//! FPGA-based Accelerator* (Ferianc, Que, Fan, Luk, Rodrigues — 2021).
//!
//! The crate is the L3 layer of a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1/L2** (build time, `python/compile/`): the Bayesian LSTM model and
//!   its fused Pallas cell kernel, AOT-lowered to HLO text artifacts.
//! * **L3** (this crate): the paper's systems contribution — a cycle-level
//!   simulator of the proposed streaming FPGA accelerator ([`fpga`]), the
//!   analytic resource/latency/power models ([`hwmodel`]), the
//!   algorithmic–hardware design-space-exploration framework ([`dse`]),
//!   a PJRT runtime executing the AOT artifacts ([`runtime`]), a
//!   Rust-driven training loop ([`train`]), a native float reference
//!   engine ([`nn`]), a parametric-precision fixed-point substrate
//!   ([`fixedpoint`] — 8/12/16-bit activation paths with a widened
//!   cell path, quantisation as a DSE axis; `docs/quantization.md`),
//!   a shared blocked-MVM kernel layer ([`kernels`] —
//!   one weight fetch amortised over MC samples and batched beats,
//!   bit-exactness contract in `docs/kernels.md`), an async serving
//!   coordinator ([`coordinator`])
//!   with a sharded multi-engine fleet ([`coordinator::fleet`] —
//!   architecture and MC-shard semantics in `docs/serving.md`) and an
//!   adaptive uncertainty-quantification layer ([`uq`] — sequential MC
//!   early-exit, risk tiers and calibration; `docs/uncertainty.md`),
//!   plus a fleet-wide observability layer ([`obs`] — staged request
//!   tracing, mergeable log-bucketed histograms, engine health
//!   counters and Prometheus/JSON metrics export;
//!   `docs/observability.md`).
//!
//! Python never runs on the request path: after `make artifacts` the Rust
//! binary is self-contained.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod dse;
pub mod fixedpoint;
pub mod fpga;
pub mod hwmodel;
pub mod jsonio;
pub mod kernels;
pub mod lfsr;
pub mod metrics;
pub mod nn;
pub mod obs;
pub mod rng;
pub mod runtime;
pub mod tensor;
pub mod train;
pub mod uq;
