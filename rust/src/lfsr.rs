//! Hardware Bernoulli sampler (paper Sec. III-B, Fig. 3).
//!
//! The FPGA design generates MC-dropout masks with N_lfsr = 3 four-tap
//! linear feedback shift registers, each emitting an unbiased bit stream,
//! combined by a 3-input NAND: the output is 0 iff all three bits are 1,
//! i.e. a dropout (zero) probability of exactly p = 1/8 = 0.125 — the rate
//! the paper fixes for both x and h masks. A serial-in-parallel-out (SIPO)
//! register widens the bit stream and a FIFO decouples sampling from the
//! LSTM engines so sampling overlaps compute (Fig. 4); both are modelled
//! behaviourally here with exact cycle accounting used by the pipeline
//! simulator.

/// 16-bit Fibonacci LFSR with the maximal-length 4-tap polynomial
/// x^16 + x^15 + x^13 + x^4 + 1 (taps 16, 15, 13, 4). Period 2^16 - 1.
#[derive(Debug, Clone)]
pub struct Lfsr4 {
    state: u16,
}

impl Lfsr4 {
    /// Seed must be non-zero (the all-zero state is the LFSR fixed point).
    pub fn new(seed: u16) -> Self {
        Self { state: if seed == 0 { 0xACE1 } else { seed } }
    }

    /// Shift one cycle, returning the output bit.
    #[inline]
    pub fn step(&mut self) -> u8 {
        let s = self.state;
        let bit =
            ((s >> 15) ^ (s >> 14) ^ (s >> 12) ^ (s >> 3)) & 1;
        self.state = (s << 1) | bit;
        (s >> 15) as u8 & 1
    }

    /// Advance 16 cycles at once, returning the 16 output bits
    /// **MSB-first** (bit 15 = the first bit `step` would have emitted).
    ///
    /// Why the whole word is just the pre-shift state: the output tap is
    /// bit 15 and feedback enters at bit 0, so a feedback bit needs 15
    /// further shifts before it can reach the output — the next 16
    /// outputs are exactly the current state's bits, high to low. Only
    /// the replacement state (the 16 feedback bits) needs the serial
    /// recurrence.
    #[inline]
    pub fn next16(&mut self) -> u16 {
        let out = self.state;
        let mut s = self.state;
        for _ in 0..16 {
            let bit =
                ((s >> 15) ^ (s >> 14) ^ (s >> 12) ^ (s >> 3)) & 1;
            s = (s << 1) | bit;
        }
        self.state = s;
        out
    }
}

/// The paper's Bernoulli mask generator: 3 LFSRs + NAND => P(zero) = 1/8.
#[derive(Debug, Clone)]
pub struct BernoulliSampler {
    lfsrs: [Lfsr4; 3],
    /// Cycles spent generating bits so far (for the overlap model).
    cycles: u64,
    /// Pending keep bits, LSB-first (bit 0 = next draw), refilled 16 at
    /// a time by the word path. `cycles` counts *delivered* bits, so the
    /// overlap model and the bit-serial oracle see identical accounting
    /// whether bits leave through [`Self::sample`] or
    /// [`Self::keep_word`].
    buf: u128,
    buf_n: u32,
}

pub const N_LFSR: usize = 3;
/// Dropout probability realised by the 3-LFSR + NAND circuit.
pub const HW_DROPOUT_P: f32 = 0.125;

impl BernoulliSampler {
    pub fn new(seed: u64) -> Self {
        // Derive three distinct non-zero 16-bit seeds.
        let s = |k: u64| -> u16 {
            let mut x = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(k);
            x ^= x >> 29;
            let v = (x & 0xFFFF) as u16;
            if v == 0 {
                0xACE1
            } else {
                v
            }
        };
        Self {
            lfsrs: [Lfsr4::new(s(1)), Lfsr4::new(s(2)), Lfsr4::new(s(3))],
            cycles: 0,
            buf: 0,
            buf_n: 0,
        }
    }

    /// One mask bit: NAND of the three LFSR outputs.
    /// Returns 1.0 (keep) with probability 7/8, 0.0 (drop) with 1/8.
    #[inline]
    pub fn sample(&mut self) -> f32 {
        self.cycles += 1;
        // Drain any word-path lookahead first so bit-serial and
        // word-level consumers can interleave on one stream without
        // perturbing draw order.
        if self.buf_n > 0 {
            let keep = self.buf & 1 == 1;
            self.buf >>= 1;
            self.buf_n -= 1;
            return if keep { 1.0 } else { 0.0 };
        }
        let b0 = self.lfsrs[0].step();
        let b1 = self.lfsrs[1].step();
        let b2 = self.lfsrs[2].step();
        // NAND: zero only when all three are one.
        if b0 & b1 & b2 == 1 {
            0.0
        } else {
            1.0
        }
    }

    /// Pull 16 draws from the three LFSRs in one word operation and
    /// append them to the lookahead buffer. `Lfsr4::next16` emits
    /// MSB-first, so the NAND word is bit-reversed into the buffer's
    /// LSB-first draw order.
    #[inline]
    fn refill16(&mut self) {
        let a = self.lfsrs[0].next16();
        let b = self.lfsrs[1].next16();
        let c = self.lfsrs[2].next16();
        let keep = !(a & b & c);
        self.buf |= (keep.reverse_bits() as u128) << self.buf_n;
        self.buf_n += 16;
    }

    /// `n` mask bits (1..=64) as one word, LSB-first: bit `j` is draw
    /// `j`, set = keep. Consumes exactly `n` draws of the same stream
    /// [`Self::sample`] walks — the word-level fast path behind
    /// [`crate::kernels::BitPlanes::fill_row_words`], oracle-tested
    /// bit-for-bit against the serial path.
    #[inline]
    pub fn keep_word(&mut self, n: u32) -> u64 {
        debug_assert!((1..=64).contains(&n), "keep_word wants 1..=64 bits");
        while self.buf_n < n {
            self.refill16();
        }
        let out = (self.buf & ((1u128 << n) - 1)) as u64;
        self.buf >>= n;
        self.buf_n -= n;
        self.cycles += n as u64;
        out
    }

    /// Fill a pre-allocated mask buffer (SIPO widening: one bit per cycle
    /// into the parallel register, then pushed through the FIFO).
    pub fn fill(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.sample();
        }
    }

    /// Cycles consumed so far — the pipeline model uses this to verify the
    /// pre-sampling window hides inside the LSTM compute (Fig. 4).
    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Cycles needed to produce `n` mask bits through the SIPO: serial, one
    /// bit per cycle (all three LFSRs step in parallel).
    pub fn cycles_for(n: usize) -> u64 {
        n as u64
    }
}

// ---------------------------------------------------------------------------
// Variable-rate sampler — the paper's future work ("supporting a wide
// variety of dropout rates in hardware"). Instead of a fixed NAND over
// N_lfsr bit-streams (which only realises p = 2^-N), a comparator checks
// an N-bit word assembled from N parallel LFSRs against a programmable
// threshold: p = threshold / 2^N in steps of 2^-N. Costs N LFSRs plus an
// N-bit comparator — still DSP-free.
// ---------------------------------------------------------------------------

/// Programmable-probability Bernoulli sampler: p = threshold / 2^N.
///
/// Implementation note: assembling the word from N *parallel* LFSRs with
/// the same polynomial is subtly wrong — the N bit-streams are N phases
/// of one m-sequence and can be linearly dependent over GF(2), collapsing
/// the word distribution (we hit exactly this: p quantised to 2^-rank).
/// The standard hardware pattern compares the top N bits of a single
/// LFSR's *state register* against the threshold: the state is uniform
/// over the 2^16-1 nonzero values, so the comparison realises p to within
/// 2^-16 bias at the cost of one LFSR + one N-bit comparator.
#[derive(Debug, Clone)]
pub struct VariableSampler {
    lfsr: Lfsr4,
    bits: usize,
    threshold: u32,
    cycles: u64,
}

impl VariableSampler {
    /// `bits` comparator bits give p resolution 2^-bits; `p` is rounded
    /// to the nearest representable probability.
    pub fn new(seed: u64, bits: usize, p: f64) -> Self {
        assert!((1..=16).contains(&bits), "1..=16 comparator bits");
        assert!((0.0..1.0).contains(&p), "p in [0,1)");
        let mut x = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
        x ^= x >> 29;
        let s = (x & 0xFFFF) as u16;
        Self {
            lfsr: Lfsr4::new(if s == 0 { 0xACE1 } else { s }),
            bits,
            threshold: (p * (1u64 << bits) as f64).round() as u32,
            cycles: 0,
        }
    }

    /// The probability actually realised after threshold quantisation.
    pub fn effective_p(&self) -> f64 {
        self.threshold as f64 / (1u64 << self.bits) as f64
    }

    /// One mask bit: top `bits` of the LFSR state < threshold => drop.
    #[inline]
    pub fn sample(&mut self) -> f32 {
        self.cycles += 1;
        self.lfsr.step();
        let word = (self.lfsr.state >> (16 - self.bits)) as u32;
        if word < self.threshold {
            0.0
        } else {
            1.0
        }
    }

    pub fn fill(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.sample();
        }
    }

    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Extra LUTs over the fixed 3-LFSR design (resource-model hook):
    /// each additional LFSR ~16 LUT/FF, comparator ~bits LUTs.
    pub fn extra_luts(bits: usize) -> f64 {
        ((bits.saturating_sub(N_LFSR)) * 16 + bits) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lfsr_is_maximal_period() {
        let mut l = Lfsr4::new(1);
        let start = l.state;
        let mut period = 0u32;
        loop {
            l.step();
            period += 1;
            if l.state == start || period > 70_000 {
                break;
            }
        }
        assert_eq!(period, 65_535, "4-tap polynomial must be maximal length");
    }

    #[test]
    fn lfsr_never_hits_zero() {
        let mut l = Lfsr4::new(0xBEEF);
        for _ in 0..70_000 {
            l.step();
            assert_ne!(l.state, 0);
        }
    }

    #[test]
    fn lfsr_bit_balance() {
        let mut l = Lfsr4::new(0x1234);
        let ones: u32 = (0..65_535).map(|_| l.step() as u32).sum();
        // Maximal LFSR emits 32768 ones / 32767 zeros per period.
        assert_eq!(ones, 32_768);
    }

    #[test]
    fn zero_seed_coerced() {
        let mut l = Lfsr4::new(0);
        l.step(); // must not be stuck
        assert_ne!(l.state, 0);
    }

    #[test]
    fn next16_matches_sixteen_serial_steps() {
        for seed in [1u16, 0xACE1, 0xBEEF, 0x8000, 0x0001, 0x5A5A] {
            let mut serial = Lfsr4::new(seed);
            let mut word = Lfsr4::new(seed);
            for _ in 0..64 {
                let mut expect = 0u16;
                for _ in 0..16 {
                    expect = (expect << 1) | serial.step() as u16;
                }
                assert_eq!(word.next16(), expect, "MSB-first draw order");
                assert_eq!(word.state, serial.state, "states stay locked");
            }
        }
    }

    /// The tentpole oracle: the word-level generator must reproduce the
    /// bit-serial NAND stream draw for draw, for any chunking, with the
    /// same cycle accounting.
    #[test]
    fn keep_word_matches_sample_stream_bit_for_bit() {
        let mut serial = BernoulliSampler::new(42);
        let mut word = BernoulliSampler::new(42);
        // Awkward chunk sizes: sub-word, word-straddling, full width.
        for &n in &[1u32, 7, 16, 3, 64, 33, 15, 64, 2, 17, 48, 5] {
            let w = word.keep_word(n);
            for j in 0..n {
                let expect = serial.sample() != 0.0;
                assert_eq!(
                    (w >> j) & 1 == 1,
                    expect,
                    "chunk n={n} draw {j}"
                );
            }
            assert_eq!(word.cycles(), serial.cycles(), "cycle accounting");
        }
    }

    #[test]
    fn sample_and_keep_word_interleave_on_one_stream() {
        let mut serial = BernoulliSampler::new(9);
        let mut mixed = BernoulliSampler::new(9);
        let mut draws = Vec::new();
        // sample() must drain keep_word's lookahead, not fork the stream.
        for round in 0..20 {
            let n = 1 + (round * 11) % 40;
            let w = mixed.keep_word(n);
            for j in 0..n {
                draws.push((w >> j) & 1 == 1);
            }
            for _ in 0..(round % 5) {
                draws.push(mixed.sample() != 0.0);
            }
        }
        for (i, &keep) in draws.iter().enumerate() {
            assert_eq!(serial.sample() != 0.0, keep, "draw {i}");
        }
        assert_eq!(mixed.cycles(), serial.cycles());
    }

    #[test]
    fn keep_word_dropout_rate_is_one_eighth() {
        let mut s = BernoulliSampler::new(1234);
        let n = 200_000u32;
        let mut kept = 0u32;
        for _ in 0..(n / 64) {
            kept += s.keep_word(64).count_ones();
        }
        let rate = 1.0 - kept as f64 / (n - n % 64) as f64;
        assert!((rate - 0.125).abs() < 0.01, "dropout rate {rate}");
    }

    #[test]
    fn nand_gives_one_eighth_dropout() {
        let mut s = BernoulliSampler::new(42);
        let n = 200_000;
        let zeros = (0..n).filter(|_| s.sample() == 0.0).count();
        let rate = zeros as f64 / n as f64;
        assert!(
            (rate - 0.125).abs() < 0.01,
            "dropout rate {rate} should be ~1/8"
        );
    }

    #[test]
    fn different_seeds_different_streams() {
        let mut a = BernoulliSampler::new(1);
        let mut b = BernoulliSampler::new(2);
        let va: Vec<f32> = (0..64).map(|_| a.sample()).collect();
        let vb: Vec<f32> = (0..64).map(|_| b.sample()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = BernoulliSampler::new(5);
        let mut b = BernoulliSampler::new(5);
        for _ in 0..1000 {
            assert_eq!(a.sample(), b.sample());
        }
    }

    #[test]
    fn cycle_accounting() {
        let mut s = BernoulliSampler::new(9);
        let mut buf = vec![0.0; 37];
        s.fill(&mut buf);
        assert_eq!(s.cycles(), 37);
        assert_eq!(BernoulliSampler::cycles_for(37), 37);
    }

    #[test]
    fn masks_are_binary() {
        let mut s = BernoulliSampler::new(11);
        let mut buf = vec![0.5; 256];
        s.fill(&mut buf);
        assert!(buf.iter().all(|&v| v == 0.0 || v == 1.0));
    }

    #[test]
    fn variable_sampler_hits_requested_rates() {
        for &p in &[0.0625f64, 0.125, 0.25, 0.4375, 0.5] {
            let mut s = VariableSampler::new(33, 8, p);
            assert!((s.effective_p() - p).abs() < 1e-9, "p={p} representable");
            let n = 120_000;
            let zeros = (0..n).filter(|_| s.sample() == 0.0).count();
            let rate = zeros as f64 / n as f64;
            assert!(
                (rate - p).abs() < 0.012,
                "requested {p}, measured {rate}"
            );
        }
    }

    #[test]
    fn variable_sampler_quantises_p() {
        let s = VariableSampler::new(1, 3, 0.2);
        // Nearest multiple of 1/8 to 0.2 is 0.25.
        assert!((s.effective_p() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn variable_matches_fixed_at_one_eighth() {
        // At p = 1/8 the programmable design realises the same rate the
        // 3-LFSR NAND does.
        let mut a = VariableSampler::new(2, 3, 0.125);
        let mut b = BernoulliSampler::new(2);
        let n = 120_000;
        let ra = (0..n).filter(|_| a.sample() == 0.0).count() as f64 / n as f64;
        let rb = (0..n).filter(|_| b.sample() == 0.0).count() as f64 / n as f64;
        assert!((ra - rb).abs() < 0.01, "{ra} vs {rb}");
    }

    #[test]
    fn variable_zero_p_never_drops() {
        let mut s = VariableSampler::new(5, 6, 0.0);
        assert!((0..1000).all(|_| s.sample() == 1.0));
        assert_eq!(s.cycles(), 1000);
    }

    #[test]
    fn extra_luts_scale_with_bits() {
        assert_eq!(VariableSampler::extra_luts(3), 3.0); // comparator only
        assert!(VariableSampler::extra_luts(8) > VariableSampler::extra_luts(4));
    }
}
