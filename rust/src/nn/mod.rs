//! Native float32 reference engine: the paper's LSTM architectures with
//! full forward + BPTT backward + AdamW, mirroring `python/compile/model.py`
//! operation-for-operation.
//!
//! Why it exists (DESIGN.md §Inventory-8): the DSE framework benchmarks
//! *dozens* of architecture points (Figs. 8/9); training each through a
//! per-config AOT artifact would bloat `make artifacts`, so the sweep
//! trains natively here. The engine is cross-validated against the PJRT
//! train-step artifact in `rust/tests/` (same math, same ABI) and against
//! finite differences in unit tests.

pub mod adam;
pub mod gru;
pub mod lstm;
pub mod model;

pub use adam::{AdamState, AdamHp};
pub use lstm::{LstmLayer, LstmCache, LstmGrads};
pub use model::{Model, ModelGrads, Masks};

use crate::config::{ArchConfig, GATES};
use crate::rng::Rng;
use crate::tensor::Tensor;

/// Trainable parameters in ABI order (see `ArchConfig::param_shapes`).
#[derive(Debug, Clone)]
pub struct Params {
    pub tensors: Vec<Tensor>,
}

impl Params {
    /// Glorot-uniform init with forget-gate bias 1.0 — mirrors
    /// `model.py::init_params`.
    pub fn init(cfg: &ArchConfig, rng: &mut Rng) -> Self {
        let mut tensors = Vec::new();
        for (idim, hdim) in cfg.lstm_dims() {
            let sx = (6.0 / (idim + hdim) as f64).sqrt();
            let sh = (6.0 / (2 * hdim) as f64).sqrt();
            tensors.push(Tensor::from_fn(&[GATES, idim, hdim], |_| {
                rng.uniform_in(-sx, sx) as f32
            }));
            tensors.push(Tensor::from_fn(&[GATES, hdim, hdim], |_| {
                rng.uniform_in(-sh, sh) as f32
            }));
            let mut b = Tensor::zeros(&[GATES, hdim]);
            for j in 0..hdim {
                b.data[hdim + j] = 1.0; // forget gate (index 1)
            }
            tensors.push(b);
        }
        let (f, o) = cfg.dense_dims();
        let sd = (6.0 / (f + o) as f64).sqrt();
        tensors.push(Tensor::from_fn(&[f, o], |_| {
            rng.uniform_in(-sd, sd) as f32
        }));
        tensors.push(Tensor::zeros(&[o]));
        Self { tensors }
    }

    pub fn zeros_like(&self) -> Self {
        Self {
            tensors: self.tensors.iter().map(|t| Tensor::zeros(&t.shape)).collect(),
        }
    }

    /// Parameter tensors of LSTM layer `l`: (wx, wh, b).
    pub fn lstm(&self, l: usize) -> (&Tensor, &Tensor, &Tensor) {
        (&self.tensors[3 * l], &self.tensors[3 * l + 1], &self.tensors[3 * l + 2])
    }

    pub fn dense(&self) -> (&Tensor, &Tensor) {
        let n = self.tensors.len();
        (&self.tensors[n - 2], &self.tensors[n - 1])
    }

    /// Global L2 norm across all tensors (for grad clipping).
    pub fn global_norm(&self) -> f32 {
        self.tensors
            .iter()
            .map(|t| t.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>())
            .sum::<f64>()
            .sqrt() as f32
    }

    pub fn num_scalars(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Task;

    #[test]
    fn init_matches_abi_shapes() {
        let cfg = ArchConfig::new(Task::Anomaly, 16, 2, "YNYN");
        let p = Params::init(&cfg, &mut Rng::new(0));
        let shapes: Vec<Vec<usize>> =
            p.tensors.iter().map(|t| t.shape.clone()).collect();
        assert_eq!(shapes, cfg.param_shapes());
        assert_eq!(p.num_scalars(), cfg.num_weights());
    }

    #[test]
    fn forget_bias_is_one() {
        let cfg = ArchConfig::new(Task::Classify, 8, 1, "Y");
        let p = Params::init(&cfg, &mut Rng::new(0));
        let b = &p.tensors[2];
        for j in 0..8 {
            assert_eq!(b.at2(1, j), 1.0); // forget
            assert_eq!(b.at2(0, j), 0.0); // input
        }
    }

    #[test]
    fn init_bounded_by_glorot() {
        let cfg = ArchConfig::new(Task::Classify, 8, 1, "N");
        let p = Params::init(&cfg, &mut Rng::new(3));
        let sx = (6.0f32 / (1.0 + 8.0)).sqrt();
        assert!(p.tensors[0].data.iter().all(|v| v.abs() <= sx + 1e-6));
    }
}
