//! GRU cell support — the paper notes (Sec. III-A) that "a similar design
//! logic ... can be used for other recurrent units such as the gated
//! recurrent unit", and lists custom recurrent cells as future work. This
//! module provides the float GRU layer (forward + BPTT) with the same
//! per-gate MC-dropout decoupling as the LSTM; `fpga::gru` provides the
//! fixed-point engine; the ablation bench compares the two cells.
//!
//! Gate order along the leading axis of wx/wh/b: (r, z, n) — reset,
//! update, candidate. Shapes: wx `[3, I, H]`, wh `[3, H, H]`, b `[3, H]`,
//! masks zx `[n, 3, I]`, zh `[n, 3, H]`.
//!
//! n_t = tanh( (x*zx_n) Wx_n + r_t * ((h*zh_n) Wh_n) + b_n )
//! h_t = (1 - z_t) * n_t + z_t * h_{t-1}

use crate::kernels;
use crate::tensor::Tensor;

pub const GRU_GATES: usize = 3;

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

pub struct GruLayer<'a> {
    pub wx: &'a Tensor,
    pub wh: &'a Tensor,
    pub b: &'a Tensor,
}

/// Forward cache for BPTT.
pub struct GruCache {
    pub n: usize,
    pub t: usize,
    pub idim: usize,
    pub hdim: usize,
    /// Post-activation r, z, n per step: `[t][n][3][h]`.
    pub gates: Vec<f32>,
    /// Pre-masked hidden-path candidate term `(h*zh_n) Wh_n + b_hn`
    /// per step `[t][n][h]` (needed for dr in backward).
    pub hn_term: Vec<f32>,
    pub hs: Vec<f32>,
    pub xs: Vec<f32>,
}

impl GruCache {
    pub fn h_at(&self, t: usize) -> &[f32] {
        &self.hs[t * self.n * self.hdim..(t + 1) * self.n * self.hdim]
    }

    pub fn last_h(&self) -> &[f32] {
        self.h_at(self.t - 1)
    }

    pub fn hs_ntk(&self) -> Vec<f32> {
        let (n, t, h) = (self.n, self.t, self.hdim);
        let mut out = vec![0f32; n * t * h];
        for ti in 0..t {
            for ni in 0..n {
                let src = &self.hs[(ti * n + ni) * h..(ti * n + ni + 1) * h];
                out[(ni * t + ti) * h..(ni * t + ti + 1) * h]
                    .copy_from_slice(src);
            }
        }
        out
    }
}

/// Forward over a sequence; xs `[n][t][i]`, masks zx `[n][3][i]`,
/// zh `[n][3][h]`, reused across timesteps.
pub fn forward(
    layer: &GruLayer,
    xs: &[f32],
    n: usize,
    t: usize,
    zx: &Tensor,
    zh: &Tensor,
) -> GruCache {
    let idim = layer.wx.shape[1];
    let hdim = layer.wx.shape[2];
    let mut gates = vec![0f32; t * n * GRU_GATES * hdim];
    let mut hn_term = vec![0f32; t * n * hdim];
    let mut hs = vec![0f32; t * n * hdim];
    let mut h_prev = vec![0f32; n * hdim];
    // Per-timestep scratch, all n rows: x-path pre terms and h-path
    // terms, `[n][GRU_GATES][hdim]` (no allocation in the loop).
    let mut pre = vec![0f32; n * GRU_GATES * hdim];
    let mut hterm = vec![0f32; n * GRU_GATES * hdim];
    let kernel = kernels::active();
    let gate_stride = GRU_GATES * hdim;

    for ti in 0..t {
        // pre[g] = (x*zx_g) Wx_g + b_g and separately the h-path terms,
        // all batch rows per weight-row fetch (blocked kernel, masks
        // fused via strided lanes — bit-identical to the per-row loop).
        hterm.fill(0.0);
        for g in 0..GRU_GATES {
            let bg = &layer.b.data[g * hdim..(g + 1) * hdim];
            for ni in 0..n {
                pre[ni * gate_stride + g * hdim
                    ..ni * gate_stride + (g + 1) * hdim]
                    .copy_from_slice(bg);
            }
            let wxg = &layer.wx.data[g * idim * hdim..(g + 1) * idim * hdim];
            kernel.mvm_f32(
                wxg,
                idim,
                hdim,
                n,
                &xs[ti * idim..],
                t * idim,
                Some((&zx.data[g * idim..], GRU_GATES * idim)),
                &mut pre[g * hdim..],
                gate_stride,
            );
            let whg = &layer.wh.data[g * hdim * hdim..(g + 1) * hdim * hdim];
            kernel.mvm_f32(
                whg,
                hdim,
                hdim,
                n,
                &h_prev,
                hdim,
                Some((&zh.data[g * hdim..], GRU_GATES * hdim)),
                &mut hterm[g * hdim..],
                gate_stride,
            );
        }
        for ni in 0..n {
            let hp = &h_prev[ni * hdim..(ni + 1) * hdim];
            let pr = &pre[ni * gate_stride..(ni + 1) * gate_stride];
            let ht = &hterm[ni * gate_stride..(ni + 1) * gate_stride];
            let gb = ((ti * n) + ni) * GRU_GATES * hdim;
            for k in 0..hdim {
                let r = sigmoid(pr[k] + ht[k]);
                let z = sigmoid(pr[hdim + k] + ht[hdim + k]);
                let hn = ht[2 * hdim + k];
                let nv = (pr[2 * hdim + k] + r * hn).tanh();
                gates[gb + k] = r;
                gates[gb + hdim + k] = z;
                gates[gb + 2 * hdim + k] = nv;
                hn_term[(ti * n + ni) * hdim + k] = hn;
                hs[(ti * n + ni) * hdim + k] =
                    (1.0 - z) * nv + z * hp[k];
            }
        }
        let base = ti * n * hdim;
        h_prev.copy_from_slice(&hs[base..base + n * hdim]);
    }
    GruCache { n, t, idim, hdim, gates, hn_term, hs, xs: xs.to_vec() }
}

pub struct GruGrads {
    pub dwx: Tensor,
    pub dwh: Tensor,
    pub db: Tensor,
    pub dx: Vec<f32>,
}

/// BPTT. `dhs` grad wrt the hidden sequence `[n][t][h]`; `dh_last` extra
/// grad at the final state.
pub fn backward(
    layer: &GruLayer,
    cache: &GruCache,
    zx: &Tensor,
    zh: &Tensor,
    dhs: Option<&[f32]>,
    dh_last: Option<&[f32]>,
) -> GruGrads {
    let (n, t, idim, hdim) = (cache.n, cache.t, cache.idim, cache.hdim);
    let mut dwx = Tensor::zeros(&[GRU_GATES, idim, hdim]);
    let mut dwh = Tensor::zeros(&[GRU_GATES, hdim, hdim]);
    let mut db = Tensor::zeros(&[GRU_GATES, hdim]);
    let mut dx = vec![0f32; n * t * idim];
    let mut dh = vec![0f32; n * hdim];
    if let Some(dl) = dh_last {
        dh.copy_from_slice(dl);
    }
    let mut dpre = vec![0f32; GRU_GATES * hdim]; // d wrt x-path pre terms
    let mut dhterm = vec![0f32; GRU_GATES * hdim]; // d wrt h-path terms

    for ti in (0..t).rev() {
        if let Some(ds) = dhs {
            for ni in 0..n {
                for k in 0..hdim {
                    dh[ni * hdim + k] += ds[(ni * t + ti) * hdim + k];
                }
            }
        }
        for ni in 0..n {
            let gb = ((ti * n) + ni) * GRU_GATES * hdim;
            let x_t =
                &cache.xs[(ni * t + ti) * idim..(ni * t + ti + 1) * idim];
            let mut dh_prev = vec![0f32; hdim];
            for k in 0..hdim {
                let r = cache.gates[gb + k];
                let z = cache.gates[gb + hdim + k];
                let nv = cache.gates[gb + 2 * hdim + k];
                let hn = cache.hn_term[(ti * n + ni) * hdim + k];
                let hp = if ti == 0 {
                    0.0
                } else {
                    cache.h_at(ti - 1)[ni * hdim + k]
                };
                let dh_k = dh[ni * hdim + k];
                // h = (1-z) n + z h_prev
                let dz = dh_k * (hp - nv);
                let dn = dh_k * (1.0 - z);
                dh_prev[k] += dh_k * z;
                let dn_pre = dn * (1.0 - nv * nv);
                // n = tanh(xn + r*hn): dr = dn_pre*hn; d(hn) = dn_pre*r
                let dr = dn_pre * hn;
                dpre[2 * hdim + k] = dn_pre;
                dhterm[2 * hdim + k] = dn_pre * r;
                let dr_pre = dr * r * (1.0 - r);
                let dz_pre = dz * z * (1.0 - z);
                dpre[k] = dr_pre;
                dpre[hdim + k] = dr_pre; // placeholder; fixed below
                // r and z gates: pre = xterm + hterm, same derivative for
                // both components.
                dpre[k] = dr_pre;
                dhterm[k] = dr_pre;
                dpre[hdim + k] = dz_pre;
                dhterm[hdim + k] = dz_pre;
            }
            // Accumulate weight grads + input/hidden grads.
            for g in 0..GRU_GATES {
                let zx_row = zx.slice3(ni, g);
                let zh_row = zh.slice3(ni, g);
                let dp = &dpre[g * hdim..(g + 1) * hdim];
                let dht = &dhterm[g * hdim..(g + 1) * hdim];
                let wxg =
                    &layer.wx.data[g * idim * hdim..(g + 1) * idim * hdim];
                let whg =
                    &layer.wh.data[g * hdim * hdim..(g + 1) * hdim * hdim];
                for k in 0..hdim {
                    db.data[g * hdim + k] += dp[k];
                }
                for i in 0..idim {
                    let xm = x_t[i] * zx_row[i];
                    let mut dxi = 0.0;
                    for k in 0..hdim {
                        dwx.data[(g * idim + i) * hdim + k] += xm * dp[k];
                        dxi += dp[k] * wxg[i * hdim + k];
                    }
                    dx[(ni * t + ti) * idim + i] += dxi * zx_row[i];
                }
                if ti > 0 {
                    let h_prev = cache.h_at(ti - 1);
                    for j in 0..hdim {
                        let hm = h_prev[ni * hdim + j] * zh_row[j];
                        let mut dhj = 0.0;
                        for k in 0..hdim {
                            dwh.data[(g * hdim + j) * hdim + k] +=
                                hm * dht[k];
                            dhj += dht[k] * whg[j * hdim + k];
                        }
                        dh_prev[j] += dhj * zh_row[j];
                    }
                }
            }
            dh[ni * hdim..(ni + 1) * hdim].copy_from_slice(&dh_prev);
        }
    }
    GruGrads { dwx, dwh, db, dx }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn setup(
        n: usize,
        t: usize,
        idim: usize,
        hdim: usize,
        seed: u64,
    ) -> (Tensor, Tensor, Tensor, Vec<f32>, Tensor, Tensor) {
        let mut rng = Rng::new(seed);
        let mut rt = |shape: &[usize], s: f64| {
            Tensor::from_fn(shape, |_| rng.normal_scaled(0.0, s) as f32)
        };
        let wx = rt(&[GRU_GATES, idim, hdim], 0.3);
        let wh = rt(&[GRU_GATES, hdim, hdim], 0.3);
        let b = rt(&[GRU_GATES, hdim], 0.1);
        let mut rng2 = Rng::new(seed + 1);
        let xs: Vec<f32> =
            (0..n * t * idim).map(|_| rng2.normal() as f32).collect();
        let zx = Tensor::from_fn(&[n, GRU_GATES, idim], |_| {
            if rng2.bernoulli(0.125) { 0.0 } else { 1.0 }
        });
        let zh = Tensor::from_fn(&[n, GRU_GATES, hdim], |_| {
            if rng2.bernoulli(0.125) { 0.0 } else { 1.0 }
        });
        (wx, wh, b, xs, zx, zh)
    }

    #[test]
    fn forward_bounds_and_shapes() {
        let (wx, wh, b, xs, zx, zh) = setup(2, 6, 3, 5, 1);
        let layer = GruLayer { wx: &wx, wh: &wh, b: &b };
        let cache = forward(&layer, &xs, 2, 6, &zx, &zh);
        assert_eq!(cache.hs.len(), 6 * 2 * 5);
        // GRU hidden state is a convex combination of tanh values: |h|<=1.
        assert!(cache.hs.iter().all(|v| v.abs() <= 1.0 + 1e-6));
    }

    #[test]
    fn gru_differs_from_initial_state() {
        let (wx, wh, b, xs, zx, zh) = setup(1, 4, 2, 4, 3);
        let layer = GruLayer { wx: &wx, wh: &wh, b: &b };
        let cache = forward(&layer, &xs, 1, 4, &zx, &zh);
        assert!(cache.last_h().iter().any(|&v| v.abs() > 1e-4));
    }

    #[test]
    fn bptt_matches_finite_differences() {
        let (n, t, idim, hdim) = (2, 4, 3, 4);
        let (wx, wh, b, xs, zx, zh) = setup(n, t, idim, hdim, 7);
        let objective =
            |wx: &Tensor, wh: &Tensor, b: &Tensor, xs: &[f32]| -> f64 {
                let layer = GruLayer { wx, wh, b };
                let c = forward(&layer, xs, n, t, &zx, &zh);
                c.hs.iter().map(|&v| v as f64).sum::<f64>()
                    + 2.0 * c.last_h().iter().map(|&v| v as f64).sum::<f64>()
            };
        let layer = GruLayer { wx: &wx, wh: &wh, b: &b };
        let cache = forward(&layer, &xs, n, t, &zx, &zh);
        let dhs = vec![1f32; n * t * hdim];
        let dlast = vec![2f32; n * hdim];
        let grads =
            backward(&layer, &cache, &zx, &zh, Some(&dhs), Some(&dlast));
        let eps = 1e-3f32;
        let check = |analytic: f64, numeric: f64, what: &str| {
            let denom = analytic.abs().max(numeric.abs()).max(2e-3);
            assert!(
                ((analytic - numeric) / denom).abs() < 0.06,
                "{what}: {analytic} vs {numeric}"
            );
        };
        for &fi in &[0usize, 10, wx.len() - 1] {
            let mut p = wx.clone();
            p.data[fi] += eps;
            let mut m = wx.clone();
            m.data[fi] -= eps;
            let num = (objective(&p, &wh, &b, &xs)
                - objective(&m, &wh, &b, &xs))
                / (2.0 * eps as f64);
            check(grads.dwx.data[fi] as f64, num, "dwx");
        }
        for &fi in &[0usize, 17, wh.len() - 1] {
            let mut p = wh.clone();
            p.data[fi] += eps;
            let mut m = wh.clone();
            m.data[fi] -= eps;
            let num = (objective(&wx, &p, &b, &xs)
                - objective(&wx, &m, &b, &xs))
                / (2.0 * eps as f64);
            check(grads.dwh.data[fi] as f64, num, "dwh");
        }
        for &fi in &[0usize, hdim, b.len() - 1] {
            let mut p = b.clone();
            p.data[fi] += eps;
            let mut m = b.clone();
            m.data[fi] -= eps;
            let num = (objective(&wx, &wh, &p, &xs)
                - objective(&wx, &wh, &m, &xs))
                / (2.0 * eps as f64);
            check(grads.db.data[fi] as f64, num, "db");
        }
        for &fi in &[0usize, 9, xs.len() - 1] {
            let mut p = xs.clone();
            p[fi] += eps;
            let mut m = xs.clone();
            m[fi] -= eps;
            let num = (objective(&wx, &wh, &b, &p)
                - objective(&wx, &wh, &b, &m))
                / (2.0 * eps as f64);
            check(grads.dx[fi] as f64, num, "dx");
        }
    }

    #[test]
    fn masks_gate_gradients() {
        let (n, t, idim, hdim) = (1, 3, 2, 3);
        let (wx, wh, b, xs, _, zh) = setup(n, t, idim, hdim, 5);
        let mut zx = Tensor::ones(&[n, GRU_GATES, idim]);
        for g in 0..GRU_GATES {
            zx.data[g * idim] = 0.0;
        }
        let layer = GruLayer { wx: &wx, wh: &wh, b: &b };
        let cache = forward(&layer, &xs, n, t, &zx, &zh);
        let dhs = vec![1f32; n * t * hdim];
        let g = backward(&layer, &cache, &zx, &zh, Some(&dhs), None);
        for ti in 0..t {
            assert_eq!(g.dx[ti * idim], 0.0);
            assert_ne!(g.dx[ti * idim + 1], 0.0);
        }
    }
}
