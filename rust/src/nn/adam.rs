//! AdamW with global-norm gradient clipping — the paper's training recipe
//! (1000 epochs, batch 64, clip 3.0, weight decay 1e-4; Sec. V) and an
//! exact mirror of `model.py::train_step`.

use super::Params;

/// Hyperparameters; defaults mirror `model.py`.
#[derive(Debug, Clone, Copy)]
pub struct AdamHp {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    pub grad_clip: f32,
}

impl Default for AdamHp {
    fn default() -> Self {
        Self {
            lr: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 1e-4,
            grad_clip: 3.0,
        }
    }
}

/// Optimizer state (first/second moments + step count).
#[derive(Debug, Clone)]
pub struct AdamState {
    pub m: Params,
    pub v: Params,
    pub step: u32,
}

impl AdamState {
    pub fn new(params: &Params) -> Self {
        Self { m: params.zeros_like(), v: params.zeros_like(), step: 0 }
    }

    /// One AdamW update in place. `grads` must match `params` shapes.
    pub fn update(&mut self, hp: &AdamHp, params: &mut Params, grads: &Params) {
        // Global-norm clip.
        let gnorm = grads.global_norm();
        let scale = if gnorm > hp.grad_clip {
            hp.grad_clip / (gnorm + 1e-12)
        } else {
            1.0
        };
        self.step += 1;
        let bc1 = 1.0 - hp.beta1.powi(self.step as i32);
        let bc2 = 1.0 - hp.beta2.powi(self.step as i32);
        for ((p, g), (m, v)) in params
            .tensors
            .iter_mut()
            .zip(&grads.tensors)
            .zip(self.m.tensors.iter_mut().zip(self.v.tensors.iter_mut()))
        {
            for i in 0..p.data.len() {
                let gi = g.data[i] * scale;
                m.data[i] = hp.beta1 * m.data[i] + (1.0 - hp.beta1) * gi;
                v.data[i] = hp.beta2 * v.data[i] + (1.0 - hp.beta2) * gi * gi;
                let upd =
                    (m.data[i] / bc1) / ((v.data[i] / bc2).sqrt() + hp.eps);
                p.data[i] -= hp.lr * (upd + hp.weight_decay * p.data[i]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArchConfig, Task};
    use crate::rng::Rng;
    use crate::tensor::Tensor;

    fn tiny_params() -> Params {
        let cfg = ArchConfig::new(Task::Classify, 4, 1, "N");
        Params::init(&cfg, &mut Rng::new(0))
    }

    #[test]
    fn zero_lr_keeps_params() {
        let mut p = tiny_params();
        let orig = p.clone();
        let grads = Params {
            tensors: p.tensors.iter().map(|t| Tensor::ones(&t.shape)).collect(),
        };
        let mut st = AdamState::new(&p);
        st.update(&AdamHp { lr: 0.0, ..Default::default() }, &mut p, &grads);
        for (a, b) in p.tensors.iter().zip(&orig.tensors) {
            assert_eq!(a.data, b.data);
        }
        assert_eq!(st.step, 1);
    }

    #[test]
    fn descends_a_quadratic() {
        // Minimise f(w) = 0.5 * w^2 elementwise: grad = w.
        let mut p = Params { tensors: vec![Tensor::filled(&[4], 2.0)] };
        let mut st = AdamState::new(&p);
        let hp = AdamHp { lr: 0.05, weight_decay: 0.0, ..Default::default() };
        for _ in 0..200 {
            let grads = Params { tensors: vec![p.tensors[0].clone()] };
            st.update(&hp, &mut p, &grads);
        }
        assert!(p.tensors[0].data.iter().all(|v| v.abs() < 0.1));
    }

    #[test]
    fn clip_engages_on_huge_grads() {
        let mut p = Params { tensors: vec![Tensor::zeros(&[2])] };
        let grads = Params {
            tensors: vec![Tensor::new(vec![2], vec![3000.0, 4000.0])],
        };
        let mut st = AdamState::new(&p);
        let hp = AdamHp { lr: 1.0, weight_decay: 0.0, ..Default::default() };
        st.update(&hp, &mut p, &grads);
        // After clipping to norm 3, first-step Adam update is bounded ~lr.
        assert!(p.tensors[0].data.iter().all(|v| v.abs() <= 1.001));
        // Direction preserved: both negative updates.
        assert!(p.tensors[0].data.iter().all(|&v| v < 0.0));
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut p = Params { tensors: vec![Tensor::filled(&[3], 1.0)] };
        let zeros = Params { tensors: vec![Tensor::zeros(&[3])] };
        let mut st = AdamState::new(&p);
        let hp = AdamHp { lr: 0.1, weight_decay: 0.5, ..Default::default() };
        st.update(&hp, &mut p, &zeros);
        assert!(p.tensors[0].data.iter().all(|&v| v < 1.0 && v > 0.9));
    }
}
