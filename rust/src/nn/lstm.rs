//! One Bayesian LSTM layer: forward with activation cache + full BPTT
//! backward. Semantics identical to `kernels/lstm.py` / `kernels/ref.py`:
//! per-gate decoupled copies of x and h, each masked by its own MC-dropout
//! mask (sampled once per sequence), gate order (i, f, g, o).

use crate::config::GATES;
use crate::kernels;
use crate::tensor::Tensor;

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Borrowed view of one layer's parameters.
pub struct LstmLayer<'a> {
    /// `[4, I, H]`
    pub wx: &'a Tensor,
    /// `[4, H, H]`
    pub wh: &'a Tensor,
    /// `[4, H]`
    pub b: &'a Tensor,
}

/// Activation cache produced by the forward pass and consumed by BPTT.
/// All buffers are row-major with `n` rows.
pub struct LstmCache {
    pub n: usize,
    pub t: usize,
    pub idim: usize,
    pub hdim: usize,
    /// Post-activation gates `[t][n][4][h]`: i, f, g, o.
    pub gates: Vec<f32>,
    /// Cell states `[t][n][h]` (c_t after the step).
    pub cs: Vec<f32>,
    /// Hidden states `[t][n][h]` (h_t after the step).
    pub hs: Vec<f32>,
    /// The layer input `[n][t][i]` (borrowed copy for weight grads).
    pub xs: Vec<f32>,
}

impl LstmCache {
    #[inline]
    pub fn h_at(&self, t: usize) -> &[f32] {
        &self.hs[t * self.n * self.hdim..(t + 1) * self.n * self.hdim]
    }

    #[inline]
    pub fn c_at(&self, t: usize) -> &[f32] {
        &self.cs[t * self.n * self.hdim..(t + 1) * self.n * self.hdim]
    }

    /// Copy hidden states into `[n][t][h]` layout (the next layer's input).
    pub fn hs_ntk(&self) -> Vec<f32> {
        let (n, t, h) = (self.n, self.t, self.hdim);
        let mut out = vec![0f32; n * t * h];
        for ti in 0..t {
            for ni in 0..n {
                let src = &self.hs[(ti * n + ni) * h..(ti * n + ni + 1) * h];
                out[(ni * t + ti) * h..(ni * t + ti + 1) * h]
                    .copy_from_slice(src);
            }
        }
        out
    }

    /// Final hidden state `[n][h]`.
    pub fn last_h(&self) -> &[f32] {
        self.h_at(self.t - 1)
    }
}

/// Gradient accumulators for one layer.
pub struct LstmGrads {
    pub dwx: Tensor,
    pub dwh: Tensor,
    pub db: Tensor,
    /// Gradient wrt the layer input, `[n][t][i]`.
    pub dx: Vec<f32>,
}

/// Forward over a sequence. `xs` is `[n][t][i]` row-major; masks `zx`
/// `[n][4][i]` and `zh` `[n][4][h]` are applied at every timestep.
pub fn forward(
    layer: &LstmLayer,
    xs: &[f32],
    n: usize,
    t: usize,
    zx: &Tensor,
    zh: &Tensor,
) -> LstmCache {
    let idim = layer.wx.shape[1];
    let hdim = layer.wx.shape[2];
    debug_assert_eq!(xs.len(), n * t * idim);
    debug_assert_eq!(zx.shape, vec![n, GATES, idim]);
    debug_assert_eq!(zh.shape, vec![n, GATES, hdim]);

    let mut gates = vec![0f32; t * n * GATES * hdim];
    let mut cs = vec![0f32; t * n * hdim];
    let mut hs = vec![0f32; t * n * hdim];
    let mut h_prev = vec![0f32; n * hdim];
    let mut c_prev = vec![0f32; n * hdim];
    let kernel = kernels::active();

    for ti in 0..t {
        // Gate pre-activations for all n rows through the blocked
        // kernel: each weight row is fetched once per gate and MAC'd
        // into every batch row. The DX masks (x*zx, h*zh) are fused in
        // via the kernel's strided mask lanes; per-element term order
        // (bias, x-path rows ascending, h-path rows ascending) is the
        // one the original per-row loop used, so outputs are
        // bit-identical.
        for g in 0..GATES {
            let wxg =
                &layer.wx.data[g * idim * hdim..(g + 1) * idim * hdim];
            let whg =
                &layer.wh.data[g * hdim * hdim..(g + 1) * hdim * hdim];
            let bg = &layer.b.data[g * hdim..(g + 1) * hdim];
            let gate_stride = GATES * hdim;
            let base = ti * n * GATES * hdim + g * hdim;
            for ni in 0..n {
                gates[base + ni * gate_stride..base + ni * gate_stride + hdim]
                    .copy_from_slice(bg);
            }
            let out = &mut gates[base..];
            // pre = (x*zx_g) @ wx[g] + b[g]: batch row ni reads the
            // frame at xs[(ni*t + ti)*idim], i.e. stride t*idim.
            kernel.mvm_f32(
                wxg,
                idim,
                hdim,
                n,
                &xs[ti * idim..],
                t * idim,
                Some((&zx.data[g * idim..], GATES * idim)),
                out,
                gate_stride,
            );
            // += (h*zh_g) @ wh[g]
            kernel.mvm_f32(
                whg,
                hdim,
                hdim,
                n,
                &h_prev,
                hdim,
                Some((&zh.data[g * hdim..], GATES * hdim)),
                out,
                gate_stride,
            );
        }
        // Activations + tail.
        for ni in 0..n {
            let cp = &c_prev[ni * hdim..(ni + 1) * hdim];
            let gb = ((ti * n) + ni) * GATES * hdim;
            for k in 0..hdim {
                let i_g = sigmoid(gates[gb + k]);
                let f_g = sigmoid(gates[gb + hdim + k]);
                let g_g = gates[gb + 2 * hdim + k].tanh();
                let o_g = sigmoid(gates[gb + 3 * hdim + k]);
                gates[gb + k] = i_g;
                gates[gb + hdim + k] = f_g;
                gates[gb + 2 * hdim + k] = g_g;
                gates[gb + 3 * hdim + k] = o_g;
                let c_new = f_g * cp[k] + i_g * g_g;
                cs[(ti * n + ni) * hdim + k] = c_new;
                hs[(ti * n + ni) * hdim + k] = o_g * c_new.tanh();
            }
        }
        let base = ti * n * hdim;
        h_prev.copy_from_slice(&hs[base..base + n * hdim]);
        c_prev.copy_from_slice(&cs[base..base + n * hdim]);
    }

    LstmCache { n, t, idim, hdim, gates, cs, hs, xs: xs.to_vec() }
}

/// BPTT backward. `dhs` is the gradient wrt the full hidden sequence in
/// `[n][t][h]` layout (zeros where unused); `dh_last` optionally adds a
/// gradient at the final hidden state only (classifier / encoder
/// bottleneck path, `[n][h]`).
pub fn backward(
    layer: &LstmLayer,
    cache: &LstmCache,
    zx: &Tensor,
    zh: &Tensor,
    dhs: Option<&[f32]>,
    dh_last: Option<&[f32]>,
) -> LstmGrads {
    let (n, t, idim, hdim) = (cache.n, cache.t, cache.idim, cache.hdim);
    let mut dwx = Tensor::zeros(&[GATES, idim, hdim]);
    let mut dwh = Tensor::zeros(&[GATES, hdim, hdim]);
    let mut db = Tensor::zeros(&[GATES, hdim]);
    let mut dx = vec![0f32; n * t * idim];

    // Running gradients wrt h_t and c_t.
    let mut dh = vec![0f32; n * hdim];
    let mut dc = vec![0f32; n * hdim];
    if let Some(dl) = dh_last {
        debug_assert_eq!(dl.len(), n * hdim);
        dh.copy_from_slice(dl);
    }

    let mut dpre = vec![0f32; GATES * hdim];

    for ti in (0..t).rev() {
        // Inject the sequence gradient at this step.
        if let Some(ds) = dhs {
            for ni in 0..n {
                for k in 0..hdim {
                    dh[ni * hdim + k] += ds[(ni * t + ti) * hdim + k];
                }
            }
        }
        let c_t = cache.c_at(ti);
        for ni in 0..n {
            let gb = ((ti * n) + ni) * GATES * hdim;
            let (ig, fg, gg, og) = (
                &cache.gates[gb..gb + hdim],
                &cache.gates[gb + hdim..gb + 2 * hdim],
                &cache.gates[gb + 2 * hdim..gb + 3 * hdim],
                &cache.gates[gb + 3 * hdim..gb + 4 * hdim],
            );
            let dh_r = &mut dh[ni * hdim..(ni + 1) * hdim];
            let dc_r = &mut dc[ni * hdim..(ni + 1) * hdim];
            for k in 0..hdim {
                let tanh_c = c_t[ni * hdim + k].tanh();
                let do_ = dh_r[k] * tanh_c;
                dc_r[k] += dh_r[k] * og[k] * (1.0 - tanh_c * tanh_c);
                let c_prev = if ti == 0 {
                    0.0
                } else {
                    cache.c_at(ti - 1)[ni * hdim + k]
                };
                let di = dc_r[k] * gg[k];
                let df = dc_r[k] * c_prev;
                let dg = dc_r[k] * ig[k];
                dpre[k] = di * ig[k] * (1.0 - ig[k]);
                dpre[hdim + k] = df * fg[k] * (1.0 - fg[k]);
                dpre[2 * hdim + k] = dg * (1.0 - gg[k] * gg[k]);
                dpre[3 * hdim + k] = do_ * og[k] * (1.0 - og[k]);
                // dc flows to the previous step through the forget gate.
                dc_r[k] *= fg[k];
                dh_r[k] = 0.0; // rebuilt below from the gate paths
            }
            // Weight/bias/input/hidden gradients per gate.
            let x_t = &cache.xs
                [(ni * t + ti) * idim..(ni * t + ti + 1) * idim];
            for g in 0..GATES {
                let zx_row = zx.slice3(ni, g);
                let zh_row = zh.slice3(ni, g);
                let dp = &dpre[g * hdim..(g + 1) * hdim];
                let wxg =
                    &layer.wx.data[g * idim * hdim..(g + 1) * idim * hdim];
                let whg =
                    &layer.wh.data[g * hdim * hdim..(g + 1) * hdim * hdim];
                // db
                for k in 0..hdim {
                    db.data[g * hdim + k] += dp[k];
                }
                // dwx += xm^T dpre; dx += (dpre @ wx^T) * zx
                for i in 0..idim {
                    let xm = x_t[i] * zx_row[i];
                    let dwrow =
                        &mut dwx.data[(g * idim + i) * hdim..(g * idim + i + 1) * hdim];
                    let wrow = &wxg[i * hdim..(i + 1) * hdim];
                    let mut dxi = 0.0;
                    for k in 0..hdim {
                        dwrow[k] += xm * dp[k];
                        dxi += dp[k] * wrow[k];
                    }
                    dx[(ni * t + ti) * idim + i] += dxi * zx_row[i];
                }
                // dwh += hm^T dpre; dh_{t-1} += (dpre @ wh^T) * zh
                if ti > 0 {
                    let h_prev = cache.h_at(ti - 1);
                    for j in 0..hdim {
                        let hm = h_prev[ni * hdim + j] * zh_row[j];
                        let dwrow = &mut dwh.data
                            [(g * hdim + j) * hdim..(g * hdim + j + 1) * hdim];
                        let wrow = &whg[j * hdim..(j + 1) * hdim];
                        let mut dhj = 0.0;
                        for k in 0..hdim {
                            dwrow[k] += hm * dp[k];
                            dhj += dp[k] * wrow[k];
                        }
                        dh[ni * hdim + j] += dhj * zh_row[j];
                    }
                }
                // ti == 0: h_{-1} = 0 so no dwh/dh contribution.
            }
        }
    }

    LstmGrads { dwx, dwh, db, dx }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_tensor(rng: &mut Rng, shape: &[usize], scale: f64) -> Tensor {
        Tensor::from_fn(shape, |_| rng.normal_scaled(0.0, scale) as f32)
    }

    fn setup(
        n: usize,
        t: usize,
        idim: usize,
        hdim: usize,
        seed: u64,
    ) -> (Tensor, Tensor, Tensor, Vec<f32>, Tensor, Tensor) {
        let mut rng = Rng::new(seed);
        let wx = rand_tensor(&mut rng, &[GATES, idim, hdim], 0.3);
        let wh = rand_tensor(&mut rng, &[GATES, hdim, hdim], 0.3);
        let b = rand_tensor(&mut rng, &[GATES, hdim], 0.1);
        let xs: Vec<f32> = (0..n * t * idim)
            .map(|_| rng.normal() as f32)
            .collect();
        let zx = Tensor::from_fn(&[n, GATES, idim], |_| {
            if rng.bernoulli(0.125) { 0.0 } else { 1.0 }
        });
        let zh = Tensor::from_fn(&[n, GATES, hdim], |_| {
            if rng.bernoulli(0.125) { 0.0 } else { 1.0 }
        });
        (wx, wh, b, xs, zx, zh)
    }

    #[test]
    fn forward_shapes_and_bounds() {
        let (wx, wh, b, xs, zx, zh) = setup(3, 5, 2, 4, 1);
        let layer = LstmLayer { wx: &wx, wh: &wh, b: &b };
        let cache = forward(&layer, &xs, 3, 5, &zx, &zh);
        assert_eq!(cache.hs.len(), 5 * 3 * 4);
        assert!(cache.hs.iter().all(|v| v.abs() <= 1.0));
        let ntk = cache.hs_ntk();
        assert_eq!(ntk.len(), 3 * 5 * 4);
        // Spot-check the transpose.
        assert_eq!(ntk[(0 * 5 + 4) * 4], cache.last_h()[0]);
    }

    /// Finite-difference check of every gradient buffer.
    #[test]
    fn bptt_matches_finite_differences() {
        let (n, t, idim, hdim) = (2, 4, 3, 4);
        let (wx, wh, b, xs, zx, zh) = setup(n, t, idim, hdim, 7);

        // Scalar objective: sum of all hidden states + 2 * sum(last h).
        let objective = |wx: &Tensor, wh: &Tensor, b: &Tensor, xs: &[f32]| -> f64 {
            let layer = LstmLayer { wx, wh, b };
            let cache = forward(&layer, xs, n, t, &zx, &zh);
            cache.hs.iter().map(|&v| v as f64).sum::<f64>()
                + 2.0 * cache.last_h().iter().map(|&v| v as f64).sum::<f64>()
        };

        let layer = LstmLayer { wx: &wx, wh: &wh, b: &b };
        let cache = forward(&layer, &xs, n, t, &zx, &zh);
        let dhs = vec![1f32; n * t * hdim];
        let dh_last = vec![2f32; n * hdim];
        let grads =
            backward(&layer, &cache, &zx, &zh, Some(&dhs), Some(&dh_last));

        let eps = 1e-3f32;
        let check = |analytic: f64, numeric: f64, what: &str| {
            let denom = analytic.abs().max(numeric.abs()).max(1e-4);
            assert!(
                ((analytic - numeric) / denom).abs() < 0.05,
                "{what}: analytic {analytic} vs numeric {numeric}"
            );
        };

        // dwx (sample a few entries)
        for &flat in &[0usize, 5, 17, wx.len() - 1] {
            let mut wp = wx.clone();
            wp.data[flat] += eps;
            let mut wm = wx.clone();
            wm.data[flat] -= eps;
            let numeric = (objective(&wp, &wh, &b, &xs)
                - objective(&wm, &wh, &b, &xs))
                / (2.0 * eps as f64);
            check(grads.dwx.data[flat] as f64, numeric, "dwx");
        }
        // dwh
        for &flat in &[0usize, 9, wh.len() - 1] {
            let mut wp = wh.clone();
            wp.data[flat] += eps;
            let mut wm = wh.clone();
            wm.data[flat] -= eps;
            let numeric = (objective(&wx, &wp, &b, &xs)
                - objective(&wx, &wm, &b, &xs))
                / (2.0 * eps as f64);
            check(grads.dwh.data[flat] as f64, numeric, "dwh");
        }
        // db
        for &flat in &[0usize, hdim + 1, b.len() - 1] {
            let mut bp = b.clone();
            bp.data[flat] += eps;
            let mut bm = b.clone();
            bm.data[flat] -= eps;
            let numeric = (objective(&wx, &wh, &bp, &xs)
                - objective(&wx, &wh, &bm, &xs))
                / (2.0 * eps as f64);
            check(grads.db.data[flat] as f64, numeric, "db");
        }
        // dx
        for &flat in &[0usize, 7, xs.len() - 1] {
            let mut xp = xs.clone();
            xp[flat] += eps;
            let mut xm = xs.clone();
            xm[flat] -= eps;
            let numeric = (objective(&wx, &wh, &b, &xp)
                - objective(&wx, &wh, &b, &xm))
                / (2.0 * eps as f64);
            check(grads.dx[flat] as f64, numeric, "dx");
        }
    }

    #[test]
    fn masked_input_has_zero_grad() {
        // If zx[ni,g,i] == 0 for all gates, dx for that feature is 0.
        let (n, t, idim, hdim) = (1, 3, 2, 3);
        let (wx, wh, b, xs, _, zh) = setup(n, t, idim, hdim, 3);
        let mut zx = Tensor::ones(&[n, GATES, idim]);
        for g in 0..GATES {
            zx.data[g * idim] = 0.0; // mask feature 0 in all gates
        }
        let layer = LstmLayer { wx: &wx, wh: &wh, b: &b };
        let cache = forward(&layer, &xs, n, t, &zx, &zh);
        let dhs = vec![1f32; n * t * hdim];
        let grads = backward(&layer, &cache, &zx, &zh, Some(&dhs), None);
        for ti in 0..t {
            assert_eq!(grads.dx[ti * idim], 0.0, "masked feature grad");
            assert_ne!(grads.dx[ti * idim + 1], 0.0, "kept feature grad");
        }
    }
}
