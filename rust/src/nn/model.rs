//! Full-model forward/backward for both paper topologies (Sec. III-C),
//! plus MC-dropout mask containers and the native train step. Mirrors
//! `python/compile/model.py` so that PJRT-executed artifacts and this
//! engine are interchangeable (cross-checked in `rust/tests/`).

use super::adam::{AdamHp, AdamState};
use super::lstm::{self, LstmCache, LstmLayer};
use super::Params;
use crate::config::{ArchConfig, Task, GATES};
use crate::kernels;
use crate::lfsr::BernoulliSampler;
use crate::rng::Rng;
use crate::tensor::Tensor;

/// MC-dropout masks in ABI order: (zx, zh) per LSTM layer, `n` rows.
#[derive(Debug, Clone)]
pub struct Masks {
    pub tensors: Vec<Tensor>,
}

impl Masks {
    /// All-ones masks (the pointwise network).
    pub fn ones(cfg: &ArchConfig, n: usize) -> Self {
        Self {
            tensors: cfg
                .mask_shapes(n)
                .iter()
                .map(|s| Tensor::ones(s))
                .collect(),
        }
    }

    /// Software Bernoulli(1-p) sampling (the CPU/GPU baselines' RNG).
    pub fn sample(cfg: &ArchConfig, n: usize, rng: &mut Rng) -> Self {
        let mut tensors = Vec::new();
        for (l, (idim, hdim)) in cfg.lstm_dims().iter().enumerate() {
            for dim in [idim, hdim] {
                let shape = [n, GATES, *dim];
                let t = if cfg.bayes[l] {
                    Tensor::from_fn(&shape, |_| {
                        if rng.bernoulli(cfg.dropout_p as f64) { 0.0 } else { 1.0 }
                    })
                } else {
                    Tensor::ones(&shape)
                };
                tensors.push(t);
            }
        }
        Self { tensors }
    }

    /// Hardware-exact sampling through the LFSR Bernoulli sampler
    /// (Sec. III-B). Note: the 3-LFSR + NAND circuit realises p = 1/8
    /// regardless of `cfg.dropout_p` — exactly the paper's restriction.
    pub fn sample_lfsr(
        cfg: &ArchConfig,
        n: usize,
        sampler: &mut BernoulliSampler,
    ) -> Self {
        let mut tensors = Vec::new();
        for (l, (idim, hdim)) in cfg.lstm_dims().iter().enumerate() {
            for dim in [idim, hdim] {
                let shape = [n, GATES, *dim];
                let t = if cfg.bayes[l] {
                    let mut t = Tensor::zeros(&shape);
                    sampler.fill(&mut t.data);
                    t
                } else {
                    Tensor::ones(&shape)
                };
                tensors.push(t);
            }
        }
        Self { tensors }
    }

    pub fn layer(&self, l: usize) -> (&Tensor, &Tensor) {
        (&self.tensors[2 * l], &self.tensors[2 * l + 1])
    }
}

/// Block-generated MC-dropout masks for a whole shard of samples,
/// packed one bit per element ([`crate::kernels::BitPlanes`]).
///
/// The software baselines used to draw masks per (sample, beat) as
/// full `f32` tensors (`Masks::sample` once per sample index, 32 bits
/// per mask bit). A `MaskBlock` draws the **identical** mix3-seeded
/// `Rng` stream per sample — same seeds, same draw order, same bits,
/// oracle-tested in `coordinator::engines` — but generates the whole
/// `[count]`-sample block in one pass into bitplanes, and only expands
/// to `f32` tensors at the consumer that genuinely needs them (the
/// float matmul ABI, PJRT artifact arguments). The FPGA-sim engines
/// never expand: their kernels probe bitplanes directly
/// (`docs/kernels.md` §Bitplane masks).
#[derive(Debug, Clone)]
pub struct MaskBlock {
    /// Per LSTM layer: (zx, zh) planes with `count` rows; `None` for a
    /// non-Bayesian layer (all-ones, nothing drawn — matching
    /// `Masks::sample`).
    pub planes: Vec<Option<(crate::kernels::BitPlanes, crate::kernels::BitPlanes)>>,
    /// Per-layer (idim, hdim) the planes were shaped for.
    dims: Vec<(usize, usize)>,
    count: usize,
}

impl MaskBlock {
    /// Masks for samples `start..start + count` of a request's
    /// schedule: sample `k`'s row is drawn from
    /// `Rng::new(mix3(base, req_seed, k))` in exactly `Masks::sample`'s
    /// element order (per layer: zx `[GATES][idim]` then zh
    /// `[GATES][hdim]`, ascending) — the fleet's MC-shard seeding
    /// contract (`docs/serving.md`).
    pub fn seeded(
        cfg: &ArchConfig,
        base: u64,
        req_seed: u64,
        start: usize,
        count: usize,
    ) -> Self {
        let dims = cfg.lstm_dims();
        let mut planes: Vec<Option<(crate::kernels::BitPlanes, crate::kernels::BitPlanes)>> =
            dims.iter()
                .enumerate()
                .map(|(l, (idim, hdim))| {
                    cfg.bayes[l].then(|| {
                        (
                            crate::kernels::BitPlanes::ones(
                                count,
                                GATES * idim,
                            ),
                            crate::kernels::BitPlanes::ones(
                                count,
                                GATES * hdim,
                            ),
                        )
                    })
                })
                .collect();
        let p = cfg.dropout_p as f64;
        for j in 0..count {
            let mut rng = crate::rng::Rng::new(crate::rng::mix3(
                base,
                req_seed,
                (start + j) as u64,
            ));
            for pair in planes.iter_mut() {
                if let Some((zx, zh)) = pair {
                    zx.fill_row(j, || !rng.bernoulli(p));
                    zh.fill_row(j, || !rng.bernoulli(p));
                }
            }
        }
        Self { planes, dims, count }
    }

    pub fn count(&self) -> usize {
        self.count
    }

    /// Packed mask bytes held for the block (vs `count * bits * 4` for
    /// the expanded f32 tensors).
    pub fn bytes(&self) -> usize {
        self.planes
            .iter()
            .flatten()
            .map(|(zx, zh)| zx.bytes() + zh.bytes())
            .sum()
    }

    /// Expand to the ABI `Masks` tensors — only for consumers whose
    /// call interface requires f32 planes (the float model forward,
    /// PJRT artifact arguments).
    pub fn to_masks(&self) -> Masks {
        let n = self.count;
        let tensors = self
            .dims
            .iter()
            .zip(&self.planes)
            .flat_map(|((idim, hdim), pair)| {
                let expand = |dim: usize, which: usize| -> Tensor {
                    let shape = [n, GATES, dim];
                    match pair {
                        None => Tensor::ones(&shape),
                        Some((zx, zh)) => {
                            let plane = if which == 0 { zx } else { zh };
                            let mut t = Tensor::zeros(&shape);
                            for r in 0..n {
                                for i in 0..GATES * dim {
                                    t.data[r * GATES * dim + i] =
                                        if plane.get(r, i) { 1.0 } else { 0.0 };
                                }
                            }
                            t
                        }
                    }
                };
                [expand(*idim, 0), expand(*hdim, 1)]
            })
            .collect();
        Masks { tensors }
    }
}

/// Forward-pass product: the output plus every cache needed for BPTT.
pub struct ForwardCache {
    pub lstm_caches: Vec<LstmCache>,
    /// Dense-layer input rows (flattened `[rows][F]`).
    pub dense_in: Vec<f32>,
    /// Model output: AE `[n][t][1]` reconstruction; classifier `[n][k]`
    /// probabilities (softmax).
    pub output: Vec<f32>,
    pub n: usize,
}

/// Gradients in ABI order (same layout as `Params`).
pub type ModelGrads = Params;

/// The native model: an `ArchConfig` bound to parameter storage.
pub struct Model {
    pub cfg: ArchConfig,
    pub params: Params,
}

impl Model {
    pub fn new(cfg: ArchConfig, params: Params) -> Self {
        Self { cfg, params }
    }

    pub fn init(cfg: ArchConfig, rng: &mut Rng) -> Self {
        let params = Params::init(&cfg, rng);
        Self { cfg, params }
    }

    /// Forward over `xs` `[n][T][I]` with the given masks. Returns the
    /// output only (serving path).
    pub fn forward(&self, xs: &[f32], n: usize, masks: &Masks) -> Vec<f32> {
        self.forward_cached(xs, n, masks).output
    }

    /// Forward keeping caches (training path).
    pub fn forward_cached(
        &self,
        xs: &[f32],
        n: usize,
        masks: &Masks,
    ) -> ForwardCache {
        let cfg = &self.cfg;
        let t = cfg.seq_len;
        let nl = cfg.nl;
        let mut caches: Vec<LstmCache> = Vec::new();
        let mut cur: Vec<f32> = xs.to_vec();

        let encoder_range = 0..nl;
        for l in encoder_range {
            let (wx, wh, b) = self.params.lstm(l);
            let (zx, zh) = masks.layer(l);
            let layer = LstmLayer { wx, wh, b };
            let cache = lstm::forward(&layer, &cur, n, t, zx, zh);
            cur = cache.hs_ntk();
            caches.push(cache);
        }

        match cfg.task {
            Task::Anomaly => {
                // Bottleneck h_T repeated T times (cached for T steps).
                let hb = cfg.bottleneck();
                let emb = caches[nl - 1].last_h().to_vec(); // [n][H/2]
                let mut rep = vec![0f32; n * t * hb];
                for ni in 0..n {
                    for ti in 0..t {
                        rep[(ni * t + ti) * hb..(ni * t + ti + 1) * hb]
                            .copy_from_slice(&emb[ni * hb..(ni + 1) * hb]);
                    }
                }
                cur = rep;
                for l in nl..2 * nl {
                    let (wx, wh, b) = self.params.lstm(l);
                    let (zx, zh) = masks.layer(l);
                    let layer = LstmLayer { wx, wh, b };
                    let cache = lstm::forward(&layer, &cur, n, t, zx, zh);
                    cur = cache.hs_ntk();
                    caches.push(cache);
                }
                // Temporal dense: every timestep through the same
                // weights — one blocked kernel call over all n*t rows
                // (each weight row fetched once for the whole batch).
                let (w, bd) = self.params.dense();
                let (f, o) = cfg.dense_dims();
                let rows = n * t;
                let mut out = vec![0f32; rows * o];
                for r in 0..rows {
                    out[r * o..(r + 1) * o].copy_from_slice(&bd.data);
                }
                kernels::active().mvm_f32(
                    &w.data, f, o, rows, &cur, f, None, &mut out, o,
                );
                ForwardCache { lstm_caches: caches, dense_in: cur, output: out, n }
            }
            Task::Classify => {
                let h_t = caches[nl - 1].last_h().to_vec(); // [n][H]
                let (w, bd) = self.params.dense();
                let (f, k) = cfg.dense_dims();
                let mut logits = vec![0f32; n * k];
                for ni in 0..n {
                    logits[ni * k..(ni + 1) * k].copy_from_slice(&bd.data);
                }
                kernels::active().mvm_f32(
                    &w.data, f, k, n, &h_t, f, None, &mut logits, k,
                );
                // Softmax rows.
                let mut probs = logits.clone();
                for ni in 0..n {
                    softmax_row(&mut probs[ni * k..(ni + 1) * k]);
                }
                ForwardCache {
                    lstm_caches: caches,
                    dense_in: h_t,
                    output: probs,
                    n,
                }
            }
        }
    }

    /// Loss of a batch (MSE for AE, CE for classifier) given a forward
    /// cache; mirrors `model.py::loss_fn`.
    pub fn loss(&self, cache: &ForwardCache, xs: &[f32], ys: &[u8]) -> f32 {
        match self.cfg.task {
            Task::Anomaly => {
                let n = cache.output.len();
                cache
                    .output
                    .iter()
                    .zip(xs)
                    .map(|(r, x)| (r - x) * (r - x))
                    .sum::<f32>()
                    / n as f32
            }
            Task::Classify => {
                let k = self.cfg.num_classes;
                let n = cache.n;
                let mut nll = 0.0;
                for ni in 0..n {
                    let p = cache.output[ni * k + ys[ni] as usize].max(1e-12);
                    nll -= p.ln();
                }
                nll / n as f32
            }
        }
    }

    /// Full backward pass; returns grads in ABI order.
    pub fn backward(
        &self,
        cache: &ForwardCache,
        xs: &[f32],
        ys: &[u8],
        masks: &Masks,
    ) -> ModelGrads {
        let cfg = &self.cfg;
        let (n, t, nl) = (cache.n, cfg.seq_len, cfg.nl);
        let mut grads = self.params.zeros_like();
        let nparams = grads.tensors.len();

        match cfg.task {
            Task::Anomaly => {
                // dLoss/dRecon for MSE mean over n*t*o elements.
                let (f, o) = cfg.dense_dims();
                let rows = n * t;
                let total = (rows * o) as f32;
                let mut dout = vec![0f32; rows * o];
                for i in 0..rows * o {
                    dout[i] = 2.0 * (cache.output[i] - xs[i]) / total;
                }
                // Temporal dense backward.
                let (w, _) = self.params.dense();
                let mut dhs = vec![0f32; rows * f]; // [n][t][f]
                {
                    let (head, tail) = grads.tensors.split_at_mut(nparams - 1);
                    let dw = &mut head[nparams - 2];
                    let db = &mut tail[0];
                    for r in 0..rows {
                        let xrow = &cache.dense_in[r * f..(r + 1) * f];
                        let drow = &dout[r * o..(r + 1) * o];
                        for k in 0..o {
                            db.data[k] += drow[k];
                        }
                        for i in 0..f {
                            let mut dx = 0.0;
                            for k in 0..o {
                                dw.data[i * o + k] += xrow[i] * drow[k];
                                dx += drow[k] * w.data[i * o + k];
                            }
                            dhs[r * f + i] = dx;
                        }
                    }
                }
                // Decoder BPTT (reverse layer order).
                let mut dseq = dhs;
                for l in (nl..2 * nl).rev() {
                    let (wx, wh, b) = self.params.lstm(l);
                    let (zx, zh) = masks.layer(l);
                    let layer = LstmLayer { wx, wh, b };
                    let g = lstm::backward(
                        &layer,
                        &cache.lstm_caches[l],
                        zx,
                        zh,
                        Some(&dseq),
                        None,
                    );
                    grads.tensors[3 * l] = g.dwx;
                    grads.tensors[3 * l + 1] = g.dwh;
                    grads.tensors[3 * l + 2] = g.db;
                    dseq = g.dx;
                }
                // dseq is now the gradient wrt the repeated embedding
                // [n][t][H/2]; the repeat's backward is a sum over time
                // landing on the encoder's final hidden state.
                let hb = cfg.bottleneck();
                let mut dh_last = vec![0f32; n * hb];
                for ni in 0..n {
                    for ti in 0..t {
                        for j in 0..hb {
                            dh_last[ni * hb + j] += dseq[(ni * t + ti) * hb + j];
                        }
                    }
                }
                // Encoder BPTT: gradient enters only at the last step of
                // the last encoder layer; deeper encoder layers get full
                // sequence grads through dx.
                let mut dseq_opt: Option<Vec<f32>> = None;
                let mut dlast_opt = Some(dh_last);
                for l in (0..nl).rev() {
                    let (wx, wh, b) = self.params.lstm(l);
                    let (zx, zh) = masks.layer(l);
                    let layer = LstmLayer { wx, wh, b };
                    let g = lstm::backward(
                        &layer,
                        &cache.lstm_caches[l],
                        zx,
                        zh,
                        dseq_opt.as_deref(),
                        dlast_opt.as_deref(),
                    );
                    grads.tensors[3 * l] = g.dwx;
                    grads.tensors[3 * l + 1] = g.dwh;
                    grads.tensors[3 * l + 2] = g.db;
                    dseq_opt = Some(g.dx);
                    dlast_opt = None;
                }
            }
            Task::Classify => {
                let k = cfg.num_classes;
                let f = cfg.hidden;
                // d(CE with softmax)/dlogits = (p - onehot) / n.
                let mut dlogits = vec![0f32; n * k];
                for ni in 0..n {
                    for j in 0..k {
                        let p = cache.output[ni * k + j];
                        let y = if ys[ni] as usize == j { 1.0 } else { 0.0 };
                        dlogits[ni * k + j] = (p - y) / n as f32;
                    }
                }
                let (w, _) = self.params.dense();
                let mut dh_last = vec![0f32; n * f];
                {
                    let (head, tail) = grads.tensors.split_at_mut(nparams - 1);
                    let dw = &mut head[nparams - 2];
                    let db = &mut tail[0];
                    for ni in 0..n {
                        let xrow = &cache.dense_in[ni * f..(ni + 1) * f];
                        let drow = &dlogits[ni * k..(ni + 1) * k];
                        for j in 0..k {
                            db.data[j] += drow[j];
                        }
                        for i in 0..f {
                            let mut dx = 0.0;
                            for j in 0..k {
                                dw.data[i * k + j] += xrow[i] * drow[j];
                                dx += drow[j] * w.data[i * k + j];
                            }
                            dh_last[ni * f + i] = dx;
                        }
                    }
                }
                let mut dseq_opt: Option<Vec<f32>> = None;
                let mut dlast_opt = Some(dh_last);
                for l in (0..nl).rev() {
                    let (wx, wh, b) = self.params.lstm(l);
                    let (zx, zh) = masks.layer(l);
                    let layer = LstmLayer { wx, wh, b };
                    let g = lstm::backward(
                        &layer,
                        &cache.lstm_caches[l],
                        zx,
                        zh,
                        dseq_opt.as_deref(),
                        dlast_opt.as_deref(),
                    );
                    grads.tensors[3 * l] = g.dwx;
                    grads.tensors[3 * l + 1] = g.dwh;
                    grads.tensors[3 * l + 2] = g.db;
                    dseq_opt = Some(g.dx);
                    dlast_opt = None;
                }
            }
        }
        grads
    }

    /// One native train step: forward + backward + AdamW. Returns the loss.
    pub fn train_step(
        &mut self,
        hp: &AdamHp,
        state: &mut AdamState,
        xs: &[f32],
        ys: &[u8],
        masks: &Masks,
    ) -> f32 {
        let n = xs.len() / (self.cfg.seq_len * self.cfg.input_dim);
        let cache = self.forward_cached(xs, n, masks);
        let loss = self.loss(&cache, xs, ys);
        let grads = self.backward(&cache, xs, ys, masks);
        state.update(hp, &mut self.params, &grads);
        loss
    }
}

pub fn softmax_row(row: &mut [f32]) {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    for v in row.iter_mut() {
        *v /= sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch(cfg: &ArchConfig, n: usize, seed: u64) -> (Vec<f32>, Vec<u8>) {
        let mut rng = Rng::new(seed);
        let xs: Vec<f32> = (0..n * cfg.seq_len * cfg.input_dim)
            .map(|_| rng.normal() as f32)
            .collect();
        let ys: Vec<u8> =
            (0..n).map(|_| rng.below(cfg.num_classes) as u8).collect();
        (xs, ys)
    }

    fn short_ae() -> ArchConfig {
        let mut cfg = ArchConfig::new(Task::Anomaly, 8, 1, "NN");
        cfg.seq_len = 12;
        cfg
    }

    fn short_cls() -> ArchConfig {
        let mut cfg = ArchConfig::new(Task::Classify, 8, 2, "YN");
        cfg.seq_len = 12;
        cfg
    }

    #[test]
    fn forward_shapes() {
        let cfg = short_ae();
        let model = Model::init(cfg.clone(), &mut Rng::new(0));
        let (xs, _) = batch(&cfg, 3, 1);
        let out = model.forward(&xs, 3, &Masks::ones(&cfg, 3));
        assert_eq!(out.len(), 3 * cfg.seq_len * 1);

        let ccfg = short_cls();
        let cmodel = Model::init(ccfg.clone(), &mut Rng::new(0));
        let (cxs, _) = batch(&ccfg, 5, 2);
        let probs = cmodel.forward(&cxs, 5, &Masks::ones(&ccfg, 5));
        assert_eq!(probs.len(), 5 * 4);
        for ni in 0..5 {
            let s: f32 = probs[ni * 4..(ni + 1) * 4].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn mc_masks_change_output_only_when_bayesian() {
        let cfg = short_cls(); // layer 0 is Bayesian
        let model = Model::init(cfg.clone(), &mut Rng::new(0));
        let (xs, _) = batch(&cfg, 1, 3);
        let mut rng = Rng::new(10);
        let m1 = Masks::sample(&cfg, 1, &mut rng);
        let m2 = Masks::sample(&cfg, 1, &mut rng);
        let o1 = model.forward(&xs, 1, &m1);
        let o2 = model.forward(&xs, 1, &m2);
        assert_ne!(o1, o2, "MCD must perturb the prediction");
        let det1 = model.forward(&xs, 1, &Masks::ones(&cfg, 1));
        let det2 = model.forward(&xs, 1, &Masks::ones(&cfg, 1));
        assert_eq!(det1, det2);
    }

    #[test]
    fn lfsr_masks_respect_bayes_pattern() {
        let cfg = short_cls(); // B = YN
        let mut sampler = BernoulliSampler::new(7);
        let m = Masks::sample_lfsr(&cfg, 16, &mut sampler);
        // Layer 0 Bayesian: must contain zeros; layer 1 not: all ones.
        assert!(m.tensors[1].data.iter().any(|&v| v == 0.0));
        assert!(m.tensors[2].data.iter().all(|&v| v == 1.0));
        assert!(m.tensors[3].data.iter().all(|&v| v == 1.0));
    }

    /// End-to-end gradient check through the full model loss. Per-coordinate
    /// f32 finite differences drown in rounding noise for tiny LSTM grads,
    /// so we check *directional derivatives* along random directions: the
    /// aggregate signal is orders of magnitude above f32 noise while still
    /// exercising every gradient buffer.
    #[test]
    fn model_grads_match_directional_derivatives() {
        for cfg in [short_ae(), short_cls()] {
            let model = Model::init(cfg.clone(), &mut Rng::new(5));
            let (xs, ys) = batch(&cfg, 2, 7);
            let masks = Masks::ones(&cfg, 2);
            let cache = model.forward_cached(&xs, 2, &masks);
            let grads = model.backward(&cache, &xs, &ys, &masks);

            let loss_at = |params: &Params| -> f64 {
                let m = Model::new(cfg.clone(), params.clone());
                let c = m.forward_cached(&xs, 2, &masks);
                m.loss(&c, &xs, &ys) as f64
            };

            let mut dir_rng = Rng::new(123);
            for trial in 0..4 {
                // Random unit-ish direction over all parameters.
                let dir: Vec<Vec<f32>> = model
                    .params
                    .tensors
                    .iter()
                    .map(|t| {
                        (0..t.len()).map(|_| dir_rng.normal() as f32).collect()
                    })
                    .collect();
                let analytic: f64 = grads
                    .tensors
                    .iter()
                    .zip(&dir)
                    .map(|(g, d)| {
                        g.data
                            .iter()
                            .zip(d)
                            .map(|(a, b)| (*a as f64) * (*b as f64))
                            .sum::<f64>()
                    })
                    .sum();
                let eps = 2e-3f32;
                let mut pp = model.params.clone();
                let mut pm = model.params.clone();
                for (ti, d) in dir.iter().enumerate() {
                    for (fi, dv) in d.iter().enumerate() {
                        pp.tensors[ti].data[fi] += eps * dv;
                        pm.tensors[ti].data[fi] -= eps * dv;
                    }
                }
                let numeric =
                    (loss_at(&pp) - loss_at(&pm)) / (2.0 * eps as f64);
                let denom = numeric.abs().max(analytic.abs()).max(1e-4);
                assert!(
                    ((numeric - analytic) / denom).abs() < 0.05,
                    "task={:?} trial {trial}: analytic {analytic} vs \
                     numeric {numeric}",
                    cfg.task
                );
            }
        }
    }

    #[test]
    fn training_reduces_loss_on_real_beats() {
        let mut cfg = ArchConfig::new(Task::Anomaly, 16, 1, "NN");
        cfg.seq_len = crate::data::T;
        let data = crate::data::generate(16, 3);
        let mut model = Model::init(cfg.clone(), &mut Rng::new(0));
        let mut st = AdamState::new(&model.params);
        let hp = AdamHp { lr: 1e-2, ..Default::default() };
        let masks = Masks::ones(&cfg, 16);
        let first = model.train_step(&hp, &mut st, &data.x, &data.y, &masks);
        let mut last = first;
        for _ in 0..250 {
            last = model.train_step(&hp, &mut st, &data.x, &data.y, &masks);
        }
        assert!(
            last < first * 0.75,
            "loss should drop: first {first} last {last}"
        );
    }

    #[test]
    fn classifier_training_learns_labels() {
        let mut cfg = ArchConfig::new(Task::Classify, 8, 1, "N");
        cfg.seq_len = crate::data::T;
        let data = crate::data::generate(32, 5);
        let mut model = Model::init(cfg.clone(), &mut Rng::new(1));
        let mut st = AdamState::new(&model.params);
        let hp = AdamHp { lr: 5e-3, ..Default::default() };
        let masks = Masks::ones(&cfg, 32);
        let first = model.train_step(&hp, &mut st, &data.x, &data.y, &masks);
        let mut last = first;
        for _ in 0..60 {
            last = model.train_step(&hp, &mut st, &data.x, &data.y, &masks);
        }
        assert!(last < first * 0.7, "CE should drop: {first} -> {last}");
    }
}
