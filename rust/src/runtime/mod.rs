//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! This is the only place the crate touches XLA. Interchange is HLO
//! *text* — `HloModuleProto::from_text_file` reassigns instruction ids,
//! side-stepping the 64-bit-id protos that xla_extension 0.5.1 rejects
//! (see aot.py and /opt/xla-example/README.md).

pub mod manifest;
pub mod pjrt;

pub use manifest::{ArgMeta, ArtifactMeta, Manifest};
pub use pjrt::{Executable, HostValue, Runtime};
