//! PJRT execution: compile HLO-text artifacts once, execute many times.
//!
//! `Runtime` owns the CPU PJRT client and an executable cache keyed by
//! artifact name; `Executable` wraps one compiled module plus its ABI
//! metadata and marshals host tensors <-> XLA literals.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::manifest::{ArtifactMeta, Manifest};
use crate::tensor::Tensor;

/// A host-side argument value: f32 tensor or i32 tensor (labels).
#[derive(Debug, Clone)]
pub enum HostValue {
    F32(Tensor),
    I32(Vec<i32>, Vec<usize>),
}

impl HostValue {
    pub fn scalar(v: f32) -> Self {
        HostValue::F32(Tensor::scalar(v))
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            HostValue::F32(t) => {
                let lit = xla::Literal::vec1(&t.data);
                let dims: Vec<i64> =
                    t.shape.iter().map(|&d| d as i64).collect();
                Ok(lit.reshape(&dims)?)
            }
            HostValue::I32(data, shape) => {
                let lit = xla::Literal::vec1(data);
                let dims: Vec<i64> =
                    shape.iter().map(|&d| d as i64).collect();
                Ok(lit.reshape(&dims)?)
            }
        }
    }
}

/// One compiled artifact.
pub struct Executable {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with positional arguments following `meta.args`. Returns
    /// the output tensors in `meta.outputs` order.
    pub fn run(&self, args: &[HostValue]) -> Result<Vec<Tensor>> {
        anyhow::ensure!(
            args.len() == self.meta.args.len(),
            "artifact {} expects {} args, got {}",
            self.meta.name,
            self.meta.args.len(),
            args.len()
        );
        // Shape-check against the ABI before handing to XLA.
        for (v, m) in args.iter().zip(&self.meta.args) {
            let shape = match v {
                HostValue::F32(t) => &t.shape,
                HostValue::I32(_, s) => s,
            };
            anyhow::ensure!(
                shape == &m.shape,
                "arg {:?}: shape {:?} != ABI {:?}",
                m.name,
                shape,
                m.shape
            );
        }
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(HostValue::to_literal)
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True.
        let parts = result.to_tuple()?;
        anyhow::ensure!(
            parts.len() == self.meta.outputs.len(),
            "artifact {} returned {} outputs, ABI says {}",
            self.meta.name,
            parts.len(),
            self.meta.outputs.len()
        );
        parts
            .into_iter()
            .zip(&self.meta.outputs)
            .map(|(lit, om)| {
                let data = lit.to_vec::<f32>().with_context(|| {
                    format!("output {:?} not f32", om.name)
                })?;
                Ok(Tensor::new(om.shape.clone(), data))
            })
            .collect()
    }
}

/// The PJRT client + executable cache.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: HashMap<String, Executable>,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { manifest, client, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let meta = self
                .manifest
                .find(name)
                .with_context(|| format!("artifact {name:?} not in manifest"))?
                .clone();
            let path = self.manifest.path_of(&meta);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| {
                    anyhow::anyhow!("parsing {}: {e}", path.display())
                })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(name.to_string(), Executable { meta, exe });
        }
        Ok(&self.cache[name])
    }

    /// Number of compiled executables held.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }
}

// No unit tests here: PJRT needs the artifacts on disk, so coverage lives
// in rust/tests/pjrt_integration.rs (and the examples).
