//! The artifact manifest: `artifacts/manifest.json`, written by aot.py.
//! Describes every lowered HLO artifact — its architecture point, kind
//! (forward / train step), row count, and the exact positional tensor ABI
//! (names, shapes, dtypes of arguments and outputs).

use std::path::{Path, PathBuf};

use crate::config::{ArchConfig, Task};
use crate::jsonio::{self, Json};

/// One tensor slot in the positional ABI.
#[derive(Debug, Clone, PartialEq)]
pub struct ArgMeta {
    pub name: String,
    pub shape: Vec<usize>,
    /// "f32" or "i32".
    pub dtype: String,
}

impl ArgMeta {
    fn from_json(j: &Json) -> anyhow::Result<Self> {
        let shape = j
            .req_arr("shape")?
            .iter()
            .map(|d| {
                d.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("bad shape element"))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Self {
            name: j.req_str("name")?.to_string(),
            shape,
            dtype: j.req_str("dtype")?.to_string(),
        })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT artifact.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    /// "forward" | "train".
    pub kind: String,
    pub task: Task,
    pub hidden: usize,
    pub nl: usize,
    pub bayes: String,
    /// Batch rows N (forward) or train batch B.
    pub rows: usize,
    pub seq_len: usize,
    pub input_dim: usize,
    pub num_classes: usize,
    pub args: Vec<ArgMeta>,
    pub outputs: Vec<ArgMeta>,
}

impl ArtifactMeta {
    pub fn arch(&self) -> ArchConfig {
        let mut cfg =
            ArchConfig::new(self.task, self.hidden, self.nl, &self.bayes);
        cfg.seq_len = self.seq_len;
        cfg.input_dim = self.input_dim;
        cfg.num_classes = self.num_classes;
        cfg
    }
}

/// The whole manifest plus its directory (for resolving artifact files).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| {
                anyhow::anyhow!(
                    "cannot read {}/manifest.json (run `make artifacts`): {e}",
                    dir.display()
                )
            })?;
        let j = jsonio::parse(&text)?;
        let mut artifacts = Vec::new();
        for a in j.req_arr("artifacts")? {
            let args = a
                .req_arr("args")?
                .iter()
                .map(ArgMeta::from_json)
                .collect::<anyhow::Result<Vec<_>>>()?;
            let outputs = a
                .req_arr("outputs")?
                .iter()
                .map(ArgMeta::from_json)
                .collect::<anyhow::Result<Vec<_>>>()?;
            artifacts.push(ArtifactMeta {
                name: a.req_str("name")?.to_string(),
                file: a.req_str("file")?.to_string(),
                kind: a.req_str("kind")?.to_string(),
                task: a
                    .req_str("task")?
                    .parse()
                    .map_err(|s| anyhow::anyhow!("bad task: {s}"))?,
                hidden: a.req_usize("hidden")?,
                nl: a.req_usize("nl")?,
                bayes: a.req_str("bayes")?.to_string(),
                rows: a.req_usize("rows")?,
                seq_len: a.req_usize("seq_len")?,
                input_dim: a.req_usize("input_dim")?,
                num_classes: a.req_usize("num_classes")?,
                args,
                outputs,
            });
        }
        Ok(Self { dir: dir.to_path_buf(), artifacts })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Forward artifact for an architecture at a given row count.
    pub fn forward_for(
        &self,
        arch_name: &str,
        rows: usize,
    ) -> Option<&ArtifactMeta> {
        self.find(&format!("{arch_name}.fwd_n{rows}"))
    }

    /// Train-step artifact for an architecture at a batch size.
    pub fn train_for(
        &self,
        arch_name: &str,
        batch: usize,
    ) -> Option<&ArtifactMeta> {
        self.find(&format!("{arch_name}.train_b{batch}"))
    }

    pub fn path_of(&self, a: &ArtifactMeta) -> PathBuf {
        self.dir.join(&a.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        let doc = r#"{
 "version": 1,
 "artifacts": [
  {"name": "classify_h8_nl1_N.fwd_n30", "file": "f.hlo.txt",
   "kind": "forward", "task": "classify", "hidden": 8, "nl": 1,
   "bayes": "N", "rows": 30, "seq_len": 140, "input_dim": 1,
   "num_classes": 4,
   "args": [{"name": "lstm0.wx", "shape": [4,1,8], "dtype": "f32"},
            {"name": "xs", "shape": [30,140,1], "dtype": "f32"}],
   "outputs": [{"name": "probs", "shape": [30,4], "dtype": "f32"}]}
 ]}"#;
        std::fs::write(dir.join("manifest.json"), doc).unwrap();
    }

    #[test]
    fn loads_and_indexes() {
        let dir = std::env::temp_dir().join("manifest_test");
        write_fixture(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.forward_for("classify_h8_nl1_N", 30).unwrap();
        assert_eq!(a.kind, "forward");
        assert_eq!(a.args[1].shape, vec![30, 140, 1]);
        assert_eq!(a.args[1].elements(), 30 * 140);
        assert_eq!(a.outputs[0].name, "probs");
        assert!(m.forward_for("classify_h8_nl1_N", 7).is_none());
        assert!(m.train_for("classify_h8_nl1_N", 64).is_none());
        assert_eq!(m.path_of(a), dir.join("f.hlo.txt"));
        let arch = a.arch();
        assert_eq!(arch.name(), "classify_h8_nl1_N");
    }

    #[test]
    fn missing_manifest_is_friendly() {
        let err = Manifest::load(Path::new("/nonexistent/dir")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn real_manifest_parses_if_present() {
        // When `make artifacts` has run, the real manifest must load and
        // contain the paper's named architectures.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this environment
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.forward_for("anomaly_h16_nl2_YNYN", 30).is_some());
        assert!(m.train_for("classify_h8_nl3_YNY", 64).is_some());
    }
}
