//! Algorithmic-hardware design-space-exploration framework (paper Sec. IV,
//! Fig. 7).
//!
//! Inputs: user priorities (an optimisation mode), hardware constraints
//! (the platform's DSP budget) and the algorithm lookup table produced by
//! the training sweep. Output: the chosen architecture `A = {H, NL, B}`,
//! reuse factors `R = {R_x, R_h, R_d}`, the modelled latency, and the
//! algorithmic metrics — Tables V and VI.

pub mod lookup;
pub mod optimizer;
pub mod space;

pub use lookup::{quant_key, AlgoEntry, LookupTable};
pub use optimizer::{ChosenConfig, OptMode, Optimizer};
pub use space::{
    arch_space, bayes_patterns, precision_space, reuse_search, reuse_search_q,
};
