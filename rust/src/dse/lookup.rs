//! The algorithm-benchmark lookup table (Fig. 7: "previously built lookup
//! table consisting of algorithm-benchmarked architectures").
//!
//! The training sweep (`train::sweep`) populates one entry per
//! architecture point with its algorithmic metrics; the optimizer then
//! queries it. Persisted as JSON through `jsonio` so sweeps are reusable
//! across runs (`artifacts/lookup_<task>.json`).

use std::collections::BTreeMap;
use std::path::Path;

use crate::config::{ArchConfig, Task};
use crate::jsonio::{self, Json};

/// Column-name convention for quantised-accuracy entries: the metric
/// measured by running the simulated fixed-point engine at `precision`
/// over the eval split is stored as `"{metric}@{precision}"` (e.g.
/// `accuracy@q8`) alongside the float metrics — the precision axis of
/// the DSE (`docs/quantization.md`).
pub fn quant_key(metric: &str, precision: &str) -> String {
    format!("{metric}@{precision}")
}

/// One benchmarked architecture point.
#[derive(Debug, Clone)]
pub struct AlgoEntry {
    pub name: String,
    pub task: Task,
    pub hidden: usize,
    pub nl: usize,
    pub bayes: String,
    /// Metric name -> value. Anomaly: accuracy/ap/auc/rmse.
    /// Classify: accuracy/ap/ar/entropy. Quantised columns use the
    /// [`quant_key`] convention (`accuracy@q8` ...).
    pub metrics: BTreeMap<String, f64>,
}

impl AlgoEntry {
    pub fn arch(&self) -> ArchConfig {
        ArchConfig::new(self.task, self.hidden, self.nl, &self.bayes)
    }

    pub fn metric(&self, key: &str) -> Option<f64> {
        self.metrics.get(key).copied()
    }

    /// The metric as measured at `precision`. Tables swept before the
    /// precision axis existed carry no quantised columns; for those the
    /// float metric stands in for the 16-bit path (Tables I/II: 16-bit
    /// quantisation preserves quality), and narrower precisions are
    /// reported as unmeasured (`None`) so the optimizer cannot pick a
    /// format nobody benchmarked.
    pub fn metric_at(&self, metric: &str, precision: &str) -> Option<f64> {
        self.metrics
            .get(&quant_key(metric, precision))
            .copied()
            .or_else(|| {
                (precision == "q16").then(|| self.metric(metric)).flatten()
            })
    }
}

/// The persisted table.
#[derive(Debug, Clone, Default)]
pub struct LookupTable {
    pub entries: Vec<AlgoEntry>,
}

impl LookupTable {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, e: AlgoEntry) {
        self.entries.retain(|x| x.name != e.name);
        self.entries.push(e);
    }

    pub fn get(&self, name: &str) -> Option<&AlgoEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    pub fn for_task(&self, task: Task) -> Vec<&AlgoEntry> {
        self.entries.iter().filter(|e| e.task == task).collect()
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.entries
                .iter()
                .map(|e| {
                    jsonio::obj(vec![
                        ("name", Json::Str(e.name.clone())),
                        ("task", Json::Str(e.task.as_str().into())),
                        ("hidden", Json::Num(e.hidden as f64)),
                        ("nl", Json::Num(e.nl as f64)),
                        ("bayes", Json::Str(e.bayes.clone())),
                        (
                            "metrics",
                            Json::Obj(
                                e.metrics
                                    .iter()
                                    .map(|(k, v)| (k.clone(), Json::Num(*v)))
                                    .collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        )
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let arr = j
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("lookup table must be an array"))?;
        let mut entries = Vec::with_capacity(arr.len());
        for e in arr {
            let metrics = match e.get("metrics") {
                Some(Json::Obj(m)) => m
                    .iter()
                    .filter_map(|(k, v)| v.as_f64().map(|x| (k.clone(), x)))
                    .collect(),
                _ => BTreeMap::new(),
            };
            entries.push(AlgoEntry {
                name: e.req_str("name")?.to_string(),
                task: e.req_str("task")?.parse().map_err(|s| {
                    anyhow::anyhow!("bad task: {s}")
                })?,
                hidden: e.req_usize("hidden")?,
                nl: e.req_usize("nl")?,
                bayes: e.req_str("bayes")?.to_string(),
                metrics,
            });
        }
        Ok(Self { entries })
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, jsonio::write(&self.to_json()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&jsonio::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(name: &str, auc: f64) -> AlgoEntry {
        AlgoEntry {
            name: name.into(),
            task: Task::Anomaly,
            hidden: 16,
            nl: 2,
            bayes: "YNYN".into(),
            metrics: [("auc".to_string(), auc)].into_iter().collect(),
        }
    }

    #[test]
    fn insert_replaces_by_name() {
        let mut t = LookupTable::new();
        t.insert(entry("a", 0.9));
        t.insert(entry("a", 0.95));
        assert_eq!(t.entries.len(), 1);
        assert_eq!(t.get("a").unwrap().metric("auc"), Some(0.95));
    }

    #[test]
    fn json_roundtrip() {
        let mut t = LookupTable::new();
        t.insert(entry("anomaly_h16_nl2_YNYN", 0.98));
        let mut e2 = entry("x", 0.5);
        e2.task = Task::Classify;
        e2.bayes = "YNY".into();
        e2.nl = 3;
        e2.metrics.insert("entropy".into(), 0.36);
        t.insert(e2);
        let j = t.to_json();
        let t2 = LookupTable::from_json(&j).unwrap();
        assert_eq!(t2.entries.len(), 2);
        assert_eq!(t2.get("x").unwrap().metric("entropy"), Some(0.36));
        assert_eq!(t2.get("x").unwrap().task, Task::Classify);
        assert_eq!(t2.for_task(Task::Anomaly).len(), 1);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("dse_lookup_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("lookup.json");
        let mut t = LookupTable::new();
        t.insert(entry("a", 0.91));
        t.save(&path).unwrap();
        let t2 = LookupTable::load(&path).unwrap();
        assert_eq!(t2.get("a").unwrap().metric("auc"), Some(0.91));
    }

    #[test]
    fn arch_reconstruction() {
        let e = entry("anomaly_h16_nl2_YNYN", 0.9);
        assert_eq!(e.arch().name(), "anomaly_h16_nl2_YNYN");
    }

    #[test]
    fn quant_columns_roundtrip_and_fall_back() {
        let mut e = entry("a", 0.9);
        e.metrics.insert("accuracy".into(), 0.95);
        e.metrics.insert(quant_key("accuracy", "q8"), 0.91);
        // Measured column wins.
        assert_eq!(e.metric_at("accuracy", "q8"), Some(0.91));
        // q16 falls back to the float column when unmeasured.
        assert_eq!(e.metric_at("accuracy", "q16"), Some(0.95));
        // Narrow precisions without a measured column are unmeasured.
        assert_eq!(e.metric_at("accuracy", "q12"), None);
        // And the @-columns survive the JSON round trip.
        let mut t = LookupTable::new();
        t.insert(e);
        let t2 = LookupTable::from_json(&t.to_json()).unwrap();
        assert_eq!(t2.get("a").unwrap().metric_at("accuracy", "q8"), Some(0.91));
    }
}
