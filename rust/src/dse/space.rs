//! The search space: architecture grid A, hardware parameters R, and
//! the quantisation axis Q.
//!
//! Paper grids (Sec. V-A): anomaly H in {8,16,24,32}, NL in {1,2};
//! classification H in {8,16,32,64}, NL in {1,2,3}; dropout benchmarked
//! "at every position and combination". The full B power-set is available
//! (`bayes_patterns`), while `arch_space` defaults to the curated subset
//! that the figures highlight (all-N pointwise, all-Y, and the paper's
//! named mixed patterns) to keep the default sweep minutes-scale —
//! `full = true` restores the complete combination grid.
//!
//! The precision axis ([`precision_space`], `docs/quantization.md`) adds
//! the 8/12/16-bit activation formats the companion accelerator work
//! trades against parallelism; `reuse_search_q` solves the DSP
//! constraint at each format.

use crate::config::{ArchConfig, Task};
use crate::fixedpoint::Precision;
use crate::hwmodel::resource::{ResourceModel, ReuseFactors};
use crate::hwmodel::Platform;

/// All 2^L Y/N patterns for L LSTM layers.
pub fn bayes_patterns(layers: usize) -> Vec<String> {
    (0..1usize << layers)
        .map(|bits| {
            (0..layers)
                .map(|l| if bits >> l & 1 == 1 { 'Y' } else { 'N' })
                .collect()
        })
        .collect()
}

/// Curated interesting patterns: pointwise, fully Bayesian, first-layer
/// only, alternating (the paper's named configs are all among these).
fn curated_patterns(layers: usize) -> Vec<String> {
    let mut pats = vec!["N".repeat(layers), "Y".repeat(layers)];
    if layers > 1 {
        // First only.
        let mut first = "N".repeat(layers);
        first.replace_range(0..1, "Y");
        pats.push(first);
        // Alternating YN...
        pats.push(
            (0..layers)
                .map(|l| if l % 2 == 0 { 'Y' } else { 'N' })
                .collect(),
        );
        // Middle-Bayesian NY(N): the paper's Opt-Accuracy point.
        let mut mid = "N".repeat(layers);
        mid.replace_range(1..2, "Y");
        pats.push(mid);
    }
    pats.sort();
    pats.dedup();
    pats
}

/// The architecture grid for a task.
pub fn arch_space(task: Task, full: bool) -> Vec<ArchConfig> {
    let (hs, nls): (&[usize], &[usize]) = match task {
        Task::Anomaly => (&[8, 16, 24, 32], &[1, 2]),
        Task::Classify => (&[8, 16, 32, 64], &[1, 2, 3]),
    };
    let mut out = Vec::new();
    for &h in hs {
        for &nl in nls {
            let layers = match task {
                Task::Anomaly => 2 * nl,
                Task::Classify => nl,
            };
            let pats = if full {
                bayes_patterns(layers)
            } else {
                curated_patterns(layers)
            };
            for p in pats {
                out.push(ArchConfig::new(task, h, nl, &p));
            }
        }
    }
    out
}

/// The quantisation grid the DSE searches: uniform 8/12/16-bit
/// activation paths (each with its widened cell format).
pub fn precision_space() -> Vec<Precision> {
    vec![Precision::q8(), Precision::q12(), Precision::q16()]
}

/// Hardware optimisation at the paper's 16-bit precision.
pub fn reuse_search(cfg: &ArchConfig, platform: &Platform) -> Option<ReuseFactors> {
    reuse_search_q(cfg, platform, &Precision::q16())
}

/// Hardware optimisation: the smallest achievable II (and its reuse
/// factors) such that the design fits the platform's DSP budget at the
/// given precision.
///
/// DSP usage is monotone non-increasing in every reuse factor and II =
/// max(R_x, R_h), so feasibility at a given II is decided at
/// R_x = R_h = II; we then shrink R_x (and R_d) back down while the design
/// still fits, spending leftover DSPs to shorten the pipeline fill.
/// Returns None if even maximal reuse cannot fit.
pub fn reuse_search_q(
    cfg: &ArchConfig,
    platform: &Platform,
    precision: &Precision,
) -> Option<ReuseFactors> {
    const MAX_REUSE: usize = 256;
    let budget = platform.dsps as f64 * 1.05; // the paper's HLS slack
    let fits = |r: &ReuseFactors| {
        ResourceModel::estimate_q(cfg, r, precision).dsps <= budget
    };

    let mut chosen = None;
    for ii in 1..=MAX_REUSE {
        // R_d: the dense engine is off the recurrent loop; give it the
        // same multiplexing as the x path (the paper sets R_d = R_x for
        // the AE and 1 for the classifier when it fits).
        let r = ReuseFactors::new(ii, ii, ii);
        if fits(&r) {
            chosen = Some(r);
            break;
        }
    }
    let mut r = chosen?;
    // Spend leftover DSPs: lower rd, then rx (II unchanged — it is
    // bounded by rh through the recurrence).
    while r.rd > 1 {
        let cand = ReuseFactors::new(r.rx, r.rh, r.rd - 1);
        if fits(&cand) {
            r = cand;
        } else {
            break;
        }
    }
    while r.rx > 1 {
        let cand = ReuseFactors::new(r.rx - 1, r.rh, r.rd);
        if fits(&cand) {
            r = cand;
        } else {
            break;
        }
    }
    Some(r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwmodel::ZC706;

    #[test]
    fn pattern_powerset() {
        let p = bayes_patterns(3);
        assert_eq!(p.len(), 8);
        assert!(p.contains(&"NNN".to_string()));
        assert!(p.contains(&"YNY".to_string()));
    }

    #[test]
    fn curated_contains_paper_points() {
        // Anomaly best B=YNYN (4 layers, alternating).
        assert!(curated_patterns(4).contains(&"YNYN".to_string()));
        // Classification Opt-Accuracy B=NYN (middle).
        assert!(curated_patterns(3).contains(&"NYN".to_string()));
        // Classification best B=YNY (alternating).
        assert!(curated_patterns(3).contains(&"YNY".to_string()));
    }

    #[test]
    fn space_sizes() {
        let full = arch_space(Task::Classify, true);
        // 4 H * (2^1 + 2^2 + 2^3) patterns = 4 * 14 = 56.
        assert_eq!(full.len(), 56);
        let small = arch_space(Task::Classify, false);
        assert!(small.len() < full.len());
        assert!(small.iter().any(|c| c.name() == "classify_h8_nl3_YNY"));
    }

    #[test]
    fn reuse_search_fits_platform() {
        for cfg in [
            ArchConfig::new(Task::Anomaly, 16, 2, "YNYN"),
            ArchConfig::new(Task::Classify, 8, 3, "YNY"),
            ArchConfig::new(Task::Classify, 32, 3, "YYY"),
        ] {
            let r = reuse_search(&cfg, &ZC706).expect("must fit with reuse");
            let est = ResourceModel::estimate(&cfg, &r);
            assert!(
                est.dsps <= ZC706.dsps as f64 * 1.05,
                "{}: {} DSPs at {:?}",
                cfg.name(),
                est.dsps,
                r
            );
        }
    }

    #[test]
    fn small_nets_get_low_ii() {
        let small = ArchConfig::new(Task::Classify, 8, 1, "N");
        let big = ArchConfig::new(Task::Classify, 32, 3, "NNN");
        let rs = reuse_search(&small, &ZC706).unwrap();
        let rb = reuse_search(&big, &ZC706).unwrap();
        assert!(rs.rh < rb.rh, "{rs:?} vs {rb:?}");
    }

    #[test]
    fn oversized_nets_are_filtered() {
        // H=64, NL=3: the reuse-independent LSTM tail alone (4*H per
        // layer) blows the DSP budget — the DSE constraint filter must
        // reject it no matter the reuse (the paper's Fig. 7 filter stage).
        let cfg = ArchConfig::new(Task::Classify, 64, 3, "NNN");
        assert!(reuse_search(&cfg, &ZC706).is_none());
    }

    #[test]
    fn precision_space_covers_three_bitwidths() {
        let precs = precision_space();
        assert_eq!(precs.len(), 3);
        let names: Vec<String> = precs.iter().map(Precision::name).collect();
        assert_eq!(names, vec!["q8", "q12", "q16"]);
    }

    #[test]
    fn narrower_precision_unlocks_lower_reuse() {
        // At q8 the packed MVMs leave DSP headroom, so the constraint
        // solver can run the same net at equal-or-lower reuse (faster).
        let cfg = ArchConfig::new(Task::Classify, 32, 3, "YYY");
        let r16 = reuse_search_q(&cfg, &ZC706, &Precision::q16()).unwrap();
        let r8 = reuse_search_q(&cfg, &ZC706, &Precision::q8()).unwrap();
        assert!(
            r8.rh <= r16.rh && r8.rx <= r16.rx,
            "q8 {r8:?} vs q16 {r16:?}"
        );
        assert!(r8.rh < r16.rh, "h32 nl3 must gain from packing");
    }

    #[test]
    fn q8_packing_unlocks_nets_infeasible_at_q16() {
        // H=64, NL=3 blows the DSP budget at 16 bit at any reuse (the
        // Fig. 7 filter rejects it) but squeezes in once the MVMs pack
        // two MACs per DSP — precision widens the feasible region, the
        // co-design effect the ISSUE 4 axis exists for.
        let cfg = ArchConfig::new(Task::Classify, 64, 3, "NNN");
        assert!(reuse_search_q(&cfg, &ZC706, &Precision::q16()).is_none());
        let r8 = reuse_search_q(&cfg, &ZC706, &Precision::q8())
            .expect("feasible at q8");
        let est = ResourceModel::estimate_q(&cfg, &r8, &Precision::q8());
        assert!(est.dsps <= ZC706.dsps as f64 * 1.05);
    }

    #[test]
    fn leftover_dsps_spent_on_rx() {
        // After the II search, rx <= rh (x path shrunk into spare DSPs).
        let cfg = ArchConfig::new(Task::Anomaly, 16, 2, "YNYN");
        let r = reuse_search(&cfg, &ZC706).unwrap();
        assert!(r.rx <= r.rh);
    }
}
