//! The greedy optimizer (Fig. 7): pick the architecture maximising the
//! user's objective from the lookup table, then pick the hardware
//! configuration minimising latency under the DSP constraint, estimate
//! the latency from the model, and filter infeasible points — producing
//! the rows of Tables V and VI.

use super::lookup::LookupTable;
use super::space::reuse_search;
use crate::config::{ArchConfig, Task};
use crate::hwmodel::latency::LatencyModel;
use crate::hwmodel::power::PowerModel;
use crate::hwmodel::resource::{ResourceModel, ReuseFactors};
use crate::hwmodel::{GpuModel, Platform};

/// User-selected optimisation mode (Sec. V-D).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptMode {
    /// Minimise modelled FPGA latency (picks pointwise S=1 nets).
    Latency,
    /// Maximise an algorithmic metric from the lookup table
    /// ("accuracy", "ap", "auc", "ar", "entropy").
    Metric(&'static str),
}

impl OptMode {
    pub fn name(&self) -> String {
        match self {
            OptMode::Latency => "Opt-Latency".into(),
            OptMode::Metric(m) => format!("Opt-{}", capitalise(m)),
        }
    }
}

fn capitalise(s: &str) -> String {
    let mut c = s.chars();
    match c.next() {
        Some(f) => f.to_uppercase().collect::<String>() + c.as_str(),
        None => String::new(),
    }
}

/// The optimizer's output for one mode: one row of Table V/VI.
#[derive(Debug, Clone)]
pub struct ChosenConfig {
    pub mode: String,
    pub arch: ArchConfig,
    pub reuse: ReuseFactors,
    /// MC samples the deployment will run (30 for Bayesian, 1 pointwise).
    pub s: usize,
    pub fpga_latency_ms: f64,
    pub gpu_latency_ms: f64,
    pub fpga_watts: f64,
    pub objective: f64,
    pub metrics: std::collections::BTreeMap<String, f64>,
}

pub struct Optimizer<'a> {
    pub platform: &'a Platform,
    pub lookup: &'a LookupTable,
    /// Deployment batch for the latency estimate (paper: 50/200).
    pub batch: usize,
    /// MC samples for Bayesian deployments (paper: S=30, Fig. 10).
    pub mc_samples: usize,
}

impl<'a> Optimizer<'a> {
    pub fn new(platform: &'a Platform, lookup: &'a LookupTable) -> Self {
        Self { platform, lookup, batch: 50, mc_samples: 30 }
    }

    /// Latency (ms) of one candidate on the FPGA under its best reuse.
    fn candidate(
        &self,
        arch: &ArchConfig,
    ) -> Option<(ReuseFactors, usize, f64)> {
        let reuse = reuse_search(arch, self.platform)?;
        let s = if arch.is_bayesian() { self.mc_samples } else { 1 };
        let ms = LatencyModel::batch_ms(
            arch,
            &reuse,
            self.batch,
            s,
            self.platform.clock_hz,
        );
        Some((reuse, s, ms))
    }

    /// Run one optimisation mode over the lookup table.
    pub fn optimize(&self, task: Task, mode: OptMode) -> Option<ChosenConfig> {
        let mut best: Option<(f64, f64, ChosenConfig)> = None;
        for entry in self.lookup.for_task(task) {
            let arch = entry.arch();
            let Some((reuse, s, fpga_ms)) = self.candidate(&arch) else {
                continue; // filtered: does not meet the DSP constraint
            };
            let objective = match mode {
                OptMode::Latency => -fpga_ms,
                OptMode::Metric(m) => match entry.metric(m) {
                    Some(v) => v,
                    None => continue,
                },
            };
            // Tie-break on latency (then fewer DSPs implicitly via reuse).
            let tiebreak = -fpga_ms;
            let better = match &best {
                None => true,
                Some((o, t, _)) => {
                    objective > *o + 1e-12
                        || ((objective - *o).abs() <= 1e-12 && tiebreak > *t)
                }
            };
            if better {
                let res = ResourceModel::estimate(&arch, &reuse);
                best = Some((
                    objective,
                    tiebreak,
                    ChosenConfig {
                        mode: mode.name(),
                        arch: arch.clone(),
                        reuse,
                        s,
                        fpga_latency_ms: fpga_ms,
                        gpu_latency_ms: GpuModel::latency_ms(
                            &arch, self.batch, s,
                        ),
                        fpga_watts: PowerModel::fpga_watts(&res),
                        objective,
                        metrics: entry.metrics.clone(),
                    },
                ));
            }
        }
        best.map(|(_, _, c)| c)
    }

    /// The latency-vs-metric Pareto front over the lookup table (the
    /// paper's Fig. 8 observation that the front is at least partially
    /// Bayesian). Returns non-dominated (arch, latency, metric) points
    /// sorted by latency.
    pub fn pareto_front(
        &self,
        task: Task,
        metric: &str,
    ) -> Vec<(ArchConfig, f64, f64)> {
        let mut pts: Vec<(ArchConfig, f64, f64)> = Vec::new();
        for entry in self.lookup.for_task(task) {
            let arch = entry.arch();
            let Some((_, _, ms)) = self.candidate(&arch) else {
                continue;
            };
            let Some(m) = entry.metric(metric) else { continue };
            pts.push((arch, ms, m));
        }
        pts.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let mut front: Vec<(ArchConfig, f64, f64)> = Vec::new();
        let mut best = f64::NEG_INFINITY;
        for p in pts {
            if p.2 > best + 1e-12 {
                best = p.2;
                front.push(p);
            }
        }
        front
    }

    /// All modes applicable to a task (Table V vs Table VI rows).
    pub fn modes_for(task: Task) -> Vec<OptMode> {
        match task {
            Task::Anomaly => vec![
                OptMode::Latency,
                OptMode::Metric("accuracy"),
                OptMode::Metric("ap"),
                OptMode::Metric("auc"),
            ],
            Task::Classify => vec![
                OptMode::Latency,
                OptMode::Metric("accuracy"),
                OptMode::Metric("ap"),
                OptMode::Metric("ar"),
                OptMode::Metric("entropy"),
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::lookup::AlgoEntry;
    use crate::hwmodel::ZC706;
    use std::collections::BTreeMap;

    fn entry(
        task: Task,
        h: usize,
        nl: usize,
        b: &str,
        metrics: &[(&str, f64)],
    ) -> AlgoEntry {
        AlgoEntry {
            name: ArchConfig::new(task, h, nl, b).name(),
            task,
            hidden: h,
            nl,
            bayes: b.into(),
            metrics: metrics
                .iter()
                .map(|(k, v)| (k.to_string(), *v))
                .collect::<BTreeMap<_, _>>(),
        }
    }

    fn toy_lookup() -> LookupTable {
        let mut t = LookupTable::new();
        // Small fast pointwise net, mediocre metrics.
        t.insert(entry(
            Task::Classify,
            8,
            1,
            "N",
            &[("accuracy", 0.90), ("ap", 0.62), ("ar", 0.66), ("entropy", 0.15)],
        ));
        // Bigger Bayesian net, best accuracy.
        t.insert(entry(
            Task::Classify,
            8,
            3,
            "NYN",
            &[("accuracy", 0.93), ("ap", 0.67), ("ar", 0.67), ("entropy", 0.14)],
        ));
        // Entropy specialist.
        t.insert(entry(
            Task::Classify,
            8,
            3,
            "YNN",
            &[("accuracy", 0.89), ("ap", 0.59), ("ar", 0.64), ("entropy", 0.60)],
        ));
        t
    }

    #[test]
    fn opt_latency_picks_pointwise_s1() {
        let lookup = toy_lookup();
        let opt = Optimizer::new(&ZC706, &lookup);
        let c = opt.optimize(Task::Classify, OptMode::Latency).unwrap();
        assert_eq!(c.arch.bayes_str(), "N");
        assert_eq!(c.s, 1, "pointwise deployments run a single pass");
    }

    #[test]
    fn opt_metric_picks_the_specialist() {
        let lookup = toy_lookup();
        let opt = Optimizer::new(&ZC706, &lookup);
        let acc = opt
            .optimize(Task::Classify, OptMode::Metric("accuracy"))
            .unwrap();
        assert_eq!(acc.arch.bayes_str(), "NYN");
        let ent = opt
            .optimize(Task::Classify, OptMode::Metric("entropy"))
            .unwrap();
        assert_eq!(ent.arch.bayes_str(), "YNN");
        assert!(ent.objective > 0.5);
    }

    #[test]
    fn bayesian_choice_is_slower_but_better() {
        let lookup = toy_lookup();
        let opt = Optimizer::new(&ZC706, &lookup);
        let lat = opt.optimize(Task::Classify, OptMode::Latency).unwrap();
        let acc = opt
            .optimize(Task::Classify, OptMode::Metric("accuracy"))
            .unwrap();
        assert!(acc.fpga_latency_ms > lat.fpga_latency_ms * 5.0);
        assert!(acc.metrics["accuracy"] > lat.metrics["accuracy"]);
    }

    #[test]
    fn fpga_beats_modelled_gpu() {
        // The Table V/VI headline: FPGA latency below the GPU baseline.
        let lookup = toy_lookup();
        let opt = Optimizer::new(&ZC706, &lookup);
        for mode in Optimizer::modes_for(Task::Classify) {
            if let Some(c) = opt.optimize(Task::Classify, mode) {
                assert!(
                    c.fpga_latency_ms < c.gpu_latency_ms,
                    "{}: fpga {} vs gpu {}",
                    c.mode,
                    c.fpga_latency_ms,
                    c.gpu_latency_ms
                );
            }
        }
    }

    #[test]
    fn missing_metric_entries_are_skipped() {
        let mut lookup = toy_lookup();
        lookup.insert(entry(Task::Classify, 16, 1, "Y", &[("accuracy", 0.99)]));
        let opt = Optimizer::new(&ZC706, &lookup);
        // Entropy mode must ignore the entry lacking an entropy metric.
        let c = opt
            .optimize(Task::Classify, OptMode::Metric("entropy"))
            .unwrap();
        assert_eq!(c.arch.bayes_str(), "YNN");
    }

    #[test]
    fn pareto_front_is_monotone_and_nondominated() {
        let lookup = toy_lookup();
        let opt = Optimizer::new(&ZC706, &lookup);
        let front = opt.pareto_front(Task::Classify, "accuracy");
        assert!(!front.is_empty());
        for w in front.windows(2) {
            assert!(w[1].1 > w[0].1, "latency strictly increasing");
            assert!(w[1].2 > w[0].2, "metric strictly improving");
        }
        // The fast pointwise point must anchor the front.
        assert_eq!(front[0].0.bayes_str(), "N");
    }

    #[test]
    fn pareto_front_drops_dominated_points() {
        let mut lookup = toy_lookup();
        // A slower-and-worse entry than {8,1,N}: dominated, must not show.
        lookup.insert(entry(
            Task::Classify,
            8,
            3,
            "NNN",
            &[("accuracy", 0.85)],
        ));
        let opt = Optimizer::new(&ZC706, &lookup);
        let front = opt.pareto_front(Task::Classify, "accuracy");
        assert!(front.iter().all(|(a, _, _)| a.bayes_str() != "NNN"));
    }

    #[test]
    fn mode_lists_match_tables() {
        assert_eq!(Optimizer::modes_for(Task::Anomaly).len(), 4);
        assert_eq!(Optimizer::modes_for(Task::Classify).len(), 5);
        assert_eq!(OptMode::Latency.name(), "Opt-Latency");
        assert_eq!(OptMode::Metric("auc").name(), "Opt-Auc");
    }
}
