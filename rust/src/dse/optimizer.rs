//! The greedy optimizer (Fig. 7): pick the architecture maximising the
//! user's objective from the lookup table, then pick the hardware
//! configuration minimising latency under the DSP constraint, estimate
//! the latency from the model, and filter infeasible points — producing
//! the rows of Tables V and VI.
//!
//! The search space is A x R x Q: every candidate is additionally tried
//! at each precision in [`Optimizer::precisions`] (default 8/12/16-bit,
//! `docs/quantization.md`). Metric objectives read the lookup table's
//! quantised-accuracy columns (`accuracy@q8` ...) so a narrow format
//! only wins on the quality it actually measured; the chosen config
//! reports its precision, its resource estimate at that precision, and
//! the delta against the 16-bit baseline.

use super::lookup::LookupTable;
use super::space::{precision_space, reuse_search_q};
use crate::config::{ArchConfig, Task};
use crate::fixedpoint::Precision;
use crate::hwmodel::latency::LatencyModel;
use crate::hwmodel::power::PowerModel;
use crate::hwmodel::resource::{ResourceEstimate, ResourceModel, ReuseFactors};
use crate::hwmodel::{GpuModel, Platform};

/// User-selected optimisation mode (Sec. V-D).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptMode {
    /// Minimise modelled FPGA latency (picks pointwise S=1 nets).
    Latency,
    /// Maximise an algorithmic metric from the lookup table
    /// ("accuracy", "ap", "auc", "ar", "entropy").
    Metric(&'static str),
}

impl OptMode {
    pub fn name(&self) -> String {
        match self {
            OptMode::Latency => "Opt-Latency".into(),
            OptMode::Metric(m) => format!("Opt-{}", capitalise(m)),
        }
    }
}

fn capitalise(s: &str) -> String {
    let mut c = s.chars();
    match c.next() {
        Some(f) => f.to_uppercase().collect::<String>() + c.as_str(),
        None => String::new(),
    }
}

/// The optimizer's output for one mode: one row of Table V/VI.
#[derive(Debug, Clone)]
pub struct ChosenConfig {
    pub mode: String,
    pub arch: ArchConfig,
    pub reuse: ReuseFactors,
    /// Chosen quantisation (the Q axis of the search).
    pub precision: Precision,
    /// MC samples the deployment will run (30 for Bayesian, 1 pointwise).
    pub s: usize,
    pub fpga_latency_ms: f64,
    pub gpu_latency_ms: f64,
    pub fpga_watts: f64,
    pub objective: f64,
    /// Resource estimate at the chosen precision.
    pub resources: ResourceEstimate,
    /// The same architecture's estimate at the 16-bit baseline, when it
    /// fits there at all — the "resource delta" column of the report.
    pub resources_q16: Option<ResourceEstimate>,
    pub metrics: std::collections::BTreeMap<String, f64>,
}

impl ChosenConfig {
    /// DSP saving vs the 16-bit baseline, in percent (None when the
    /// architecture does not fit the chip at 16 bit).
    pub fn dsp_delta_vs_q16_pct(&self) -> Option<f64> {
        self.resources_q16.map(|q16| {
            (1.0 - self.resources.dsps / q16.dsps) * 100.0
        })
    }

    /// The quantised metric column backing this choice, if measured.
    pub fn quant_metric(&self, metric: &str) -> Option<f64> {
        self.metrics
            .get(&super::lookup::quant_key(metric, &self.precision.name()))
            .copied()
    }
}

pub struct Optimizer<'a> {
    pub platform: &'a Platform,
    pub lookup: &'a LookupTable,
    /// Deployment batch for the latency estimate (paper: 50/200).
    pub batch: usize,
    /// MC samples for Bayesian deployments (paper: S=30, Fig. 10).
    pub mc_samples: usize,
    /// Quantisation grid to search (default 8/12/16-bit).
    pub precisions: Vec<Precision>,
}

impl<'a> Optimizer<'a> {
    pub fn new(platform: &'a Platform, lookup: &'a LookupTable) -> Self {
        Self {
            platform,
            lookup,
            batch: 50,
            mc_samples: 30,
            precisions: precision_space(),
        }
    }

    /// Latency (ms) of one candidate on the FPGA under its best reuse at
    /// the given precision (the precision enters through the reuse the
    /// constraint solver can afford; timing at fixed reuse is
    /// format-independent).
    fn candidate(
        &self,
        arch: &ArchConfig,
        precision: &Precision,
    ) -> Option<(ReuseFactors, usize, f64)> {
        let reuse = reuse_search_q(arch, self.platform, precision)?;
        let s = if arch.is_bayesian() { self.mc_samples } else { 1 };
        let ms = LatencyModel::batch_ms(
            arch,
            &reuse,
            self.batch,
            s,
            self.platform.clock_hz,
        );
        Some((reuse, s, ms))
    }

    /// Run one optimisation mode over the lookup table, searching the
    /// architecture grid at every precision.
    pub fn optimize(&self, task: Task, mode: OptMode) -> Option<ChosenConfig> {
        let mut best: Option<(f64, f64, ChosenConfig)> = None;
        for precision in &self.precisions {
            for entry in self.lookup.for_task(task) {
                let arch = entry.arch();
                let Some((reuse, s, fpga_ms)) =
                    self.candidate(&arch, precision)
                else {
                    continue; // filtered: does not meet the DSP constraint
                };
                let objective = match mode {
                    OptMode::Latency => -fpga_ms,
                    // Quality objectives only credit what was measured at
                    // this precision (q16 falls back to the float column).
                    OptMode::Metric(m) => {
                        match entry.metric_at(m, &precision.name()) {
                            Some(v) => v,
                            None => continue,
                        }
                    }
                };
                // Tie-break on latency (then fewer DSPs implicitly via
                // reuse/precision).
                let tiebreak = -fpga_ms;
                let better = match &best {
                    None => true,
                    Some((o, t, _)) => {
                        objective > *o + 1e-12
                            || ((objective - *o).abs() <= 1e-12
                                && tiebreak > *t)
                    }
                };
                if better {
                    let res =
                        ResourceModel::estimate_q(&arch, &reuse, precision);
                    best = Some((
                        objective,
                        tiebreak,
                        ChosenConfig {
                            mode: mode.name(),
                            arch: arch.clone(),
                            reuse,
                            precision: precision.clone(),
                            s,
                            fpga_latency_ms: fpga_ms,
                            gpu_latency_ms: GpuModel::latency_ms(
                                &arch, self.batch, s,
                            ),
                            // Width-sensitive power (docs/quantization.md):
                            // narrow operands shrink both the resource
                            // counts (inside `res`) and the per-resource
                            // toggle activity.
                            fpga_watts: PowerModel::fpga_watts_q(
                                &res,
                                precision,
                                arch.num_lstm_layers(),
                            ),
                            objective,
                            resources: res,
                            // Filled in once for the winner below — the
                            // baseline solve is report-only and need not
                            // run inside the search loop.
                            resources_q16: None,
                            metrics: entry.metrics.clone(),
                        },
                    ));
                }
            }
        }
        best.map(|(_, _, mut c)| {
            let q16 = Precision::q16();
            c.resources_q16 = if c.precision == q16 {
                Some(c.resources)
            } else {
                reuse_search_q(&c.arch, self.platform, &q16).map(|r16| {
                    ResourceModel::estimate_q(&c.arch, &r16, &q16)
                })
            };
            c
        })
    }

    /// The latency-vs-metric Pareto front over the lookup table at the
    /// 16-bit reference precision (the paper's Fig. 8 observation that
    /// the front is at least partially Bayesian). Returns non-dominated
    /// (arch, latency, metric) points sorted by latency.
    pub fn pareto_front(
        &self,
        task: Task,
        metric: &str,
    ) -> Vec<(ArchConfig, f64, f64)> {
        let mut pts: Vec<(ArchConfig, f64, f64)> = Vec::new();
        let q16 = Precision::q16();
        for entry in self.lookup.for_task(task) {
            let arch = entry.arch();
            let Some((_, _, ms)) = self.candidate(&arch, &q16) else {
                continue;
            };
            let Some(m) = entry.metric(metric) else { continue };
            pts.push((arch, ms, m));
        }
        pts.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let mut front: Vec<(ArchConfig, f64, f64)> = Vec::new();
        let mut best = f64::NEG_INFINITY;
        for p in pts {
            if p.2 > best + 1e-12 {
                best = p.2;
                front.push(p);
            }
        }
        front
    }

    /// All modes applicable to a task (Table V vs Table VI rows).
    pub fn modes_for(task: Task) -> Vec<OptMode> {
        match task {
            Task::Anomaly => vec![
                OptMode::Latency,
                OptMode::Metric("accuracy"),
                OptMode::Metric("ap"),
                OptMode::Metric("auc"),
            ],
            Task::Classify => vec![
                OptMode::Latency,
                OptMode::Metric("accuracy"),
                OptMode::Metric("ap"),
                OptMode::Metric("ar"),
                OptMode::Metric("entropy"),
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::lookup::AlgoEntry;
    use crate::hwmodel::ZC706;
    use std::collections::BTreeMap;

    fn entry(
        task: Task,
        h: usize,
        nl: usize,
        b: &str,
        metrics: &[(&str, f64)],
    ) -> AlgoEntry {
        AlgoEntry {
            name: ArchConfig::new(task, h, nl, b).name(),
            task,
            hidden: h,
            nl,
            bayes: b.into(),
            metrics: metrics
                .iter()
                .map(|(k, v)| (k.to_string(), *v))
                .collect::<BTreeMap<_, _>>(),
        }
    }

    fn toy_lookup() -> LookupTable {
        let mut t = LookupTable::new();
        // Small fast pointwise net, mediocre metrics.
        t.insert(entry(
            Task::Classify,
            8,
            1,
            "N",
            &[("accuracy", 0.90), ("ap", 0.62), ("ar", 0.66), ("entropy", 0.15)],
        ));
        // Bigger Bayesian net, best accuracy.
        t.insert(entry(
            Task::Classify,
            8,
            3,
            "NYN",
            &[("accuracy", 0.93), ("ap", 0.67), ("ar", 0.67), ("entropy", 0.14)],
        ));
        // Entropy specialist.
        t.insert(entry(
            Task::Classify,
            8,
            3,
            "YNN",
            &[("accuracy", 0.89), ("ap", 0.59), ("ar", 0.64), ("entropy", 0.60)],
        ));
        t
    }

    #[test]
    fn opt_latency_picks_pointwise_s1() {
        let lookup = toy_lookup();
        let opt = Optimizer::new(&ZC706, &lookup);
        let c = opt.optimize(Task::Classify, OptMode::Latency).unwrap();
        assert_eq!(c.arch.bayes_str(), "N");
        assert_eq!(c.s, 1, "pointwise deployments run a single pass");
    }

    #[test]
    fn opt_metric_picks_the_specialist() {
        let lookup = toy_lookup();
        let opt = Optimizer::new(&ZC706, &lookup);
        let acc = opt
            .optimize(Task::Classify, OptMode::Metric("accuracy"))
            .unwrap();
        assert_eq!(acc.arch.bayes_str(), "NYN");
        let ent = opt
            .optimize(Task::Classify, OptMode::Metric("entropy"))
            .unwrap();
        assert_eq!(ent.arch.bayes_str(), "YNN");
        assert!(ent.objective > 0.5);
    }

    #[test]
    fn bayesian_choice_is_slower_but_better() {
        let lookup = toy_lookup();
        let opt = Optimizer::new(&ZC706, &lookup);
        let lat = opt.optimize(Task::Classify, OptMode::Latency).unwrap();
        let acc = opt
            .optimize(Task::Classify, OptMode::Metric("accuracy"))
            .unwrap();
        assert!(acc.fpga_latency_ms > lat.fpga_latency_ms * 5.0);
        assert!(acc.metrics["accuracy"] > lat.metrics["accuracy"]);
    }

    #[test]
    fn fpga_beats_modelled_gpu() {
        // The Table V/VI headline: FPGA latency below the GPU baseline.
        let lookup = toy_lookup();
        let opt = Optimizer::new(&ZC706, &lookup);
        for mode in Optimizer::modes_for(Task::Classify) {
            if let Some(c) = opt.optimize(Task::Classify, mode) {
                assert!(
                    c.fpga_latency_ms < c.gpu_latency_ms,
                    "{}: fpga {} vs gpu {}",
                    c.mode,
                    c.fpga_latency_ms,
                    c.gpu_latency_ms
                );
            }
        }
    }

    #[test]
    fn missing_metric_entries_are_skipped() {
        let mut lookup = toy_lookup();
        lookup.insert(entry(Task::Classify, 16, 1, "Y", &[("accuracy", 0.99)]));
        let opt = Optimizer::new(&ZC706, &lookup);
        // Entropy mode must ignore the entry lacking an entropy metric.
        let c = opt
            .optimize(Task::Classify, OptMode::Metric("entropy"))
            .unwrap();
        assert_eq!(c.arch.bayes_str(), "YNN");
    }

    #[test]
    fn pareto_front_is_monotone_and_nondominated() {
        let lookup = toy_lookup();
        let opt = Optimizer::new(&ZC706, &lookup);
        let front = opt.pareto_front(Task::Classify, "accuracy");
        assert!(!front.is_empty());
        for w in front.windows(2) {
            assert!(w[1].1 > w[0].1, "latency strictly increasing");
            assert!(w[1].2 > w[0].2, "metric strictly improving");
        }
        // The fast pointwise point must anchor the front.
        assert_eq!(front[0].0.bayes_str(), "N");
    }

    #[test]
    fn pareto_front_drops_dominated_points() {
        let mut lookup = toy_lookup();
        // A slower-and-worse entry than {8,1,N}: dominated, must not show.
        lookup.insert(entry(
            Task::Classify,
            8,
            3,
            "NNN",
            &[("accuracy", 0.85)],
        ));
        let opt = Optimizer::new(&ZC706, &lookup);
        let front = opt.pareto_front(Task::Classify, "accuracy");
        assert!(front.iter().all(|(a, _, _)| a.bayes_str() != "NNN"));
    }

    #[test]
    fn latency_mode_exploits_the_precision_axis() {
        // With no quality constraint, Opt-Latency takes the packed
        // 8-bit path — and still reports the q16 baseline for the
        // resource-delta column. Note the toy winner (h8, nl1) already
        // reaches II = 1 at 16 bit, so its *latency* cannot improve
        // (ceil(1/2) = 1): q8 wins the exact tie by search order and
        // must never be slower.
        let lookup = toy_lookup();
        let opt = Optimizer::new(&ZC706, &lookup);
        assert_eq!(opt.precisions.len(), 3, "searches >= 3 bitwidths");
        let c = opt.optimize(Task::Classify, OptMode::Latency).unwrap();
        assert_eq!(c.precision.name(), "q8");
        let q16 = {
            let mut o16 = Optimizer::new(&ZC706, &lookup);
            o16.precisions = vec![crate::fixedpoint::Precision::q16()];
            o16.optimize(Task::Classify, OptMode::Latency).unwrap()
        };
        assert!(
            c.fpga_latency_ms <= q16.fpga_latency_ms,
            "q8 must never be slower"
        );
        let delta = c.dsp_delta_vs_q16_pct().expect("fits at q16 too");
        assert!(delta > 0.0, "packed MVMs must save DSPs: {delta}");
        // Width-sensitive power (ISSUE 5 satellite): the chosen q8
        // design reports lower watts than the q16 baseline — fewer
        // resources *and* fewer toggling operand bits.
        assert!(
            c.fpga_watts < q16.fpga_watts,
            "q8 watts {} !< q16 watts {}",
            c.fpga_watts,
            q16.fpga_watts
        );

        // Where the design IS DSP-constrained (II > 1), the packed
        // format's DSP headroom buys a lower feasible reuse and with it
        // real modelled speedup.
        use crate::dse::space::reuse_search_q;
        use crate::fixedpoint::Precision;
        use crate::hwmodel::latency::LatencyModel;
        let arch = ArchConfig::new(Task::Classify, 32, 3, "YYY");
        let r16 = reuse_search_q(&arch, &ZC706, &Precision::q16()).unwrap();
        let r8 = reuse_search_q(&arch, &ZC706, &Precision::q8()).unwrap();
        assert!(
            LatencyModel::design_timing(&arch, &r16).ii > 1,
            "test premise: the big net is DSP-constrained"
        );
        let ms16 =
            LatencyModel::batch_ms(&arch, &r16, 50, 30, ZC706.clock_hz);
        let ms8 = LatencyModel::batch_ms(&arch, &r8, 50, 30, ZC706.clock_hz);
        assert!(
            ms8 < 0.75 * ms16,
            "q8 must be materially faster when constrained: {ms8} vs {ms16}"
        );
    }

    #[test]
    fn metric_modes_only_credit_measured_precisions() {
        use crate::dse::lookup::quant_key;
        // Entry without quantised columns: Metric modes must stay at the
        // q16 fallback even though q8 would be faster.
        let lookup = toy_lookup();
        let opt = Optimizer::new(&ZC706, &lookup);
        let c = opt
            .optimize(Task::Classify, OptMode::Metric("accuracy"))
            .unwrap();
        assert_eq!(c.precision.name(), "q16");
        assert_eq!(c.arch.bayes_str(), "NYN");

        // Now measure a q8 column that beats every float column: the
        // optimizer should move to it and report the quantised value.
        let mut lookup = toy_lookup();
        let mut e = entry(
            Task::Classify,
            8,
            2,
            "YN",
            &[("accuracy", 0.91)],
        );
        e.metrics.insert(quant_key("accuracy", "q8"), 0.94);
        lookup.insert(e);
        let opt = Optimizer::new(&ZC706, &lookup);
        let c = opt
            .optimize(Task::Classify, OptMode::Metric("accuracy"))
            .unwrap();
        assert_eq!(c.precision.name(), "q8");
        assert_eq!(c.arch.bayes_str(), "YN");
        assert!((c.objective - 0.94).abs() < 1e-12);
        assert_eq!(c.quant_metric("accuracy"), Some(0.94));
    }

    #[test]
    fn mode_lists_match_tables() {
        assert_eq!(Optimizer::modes_for(Task::Anomaly).len(), 4);
        assert_eq!(Optimizer::modes_for(Task::Classify).len(), 5);
        assert_eq!(OptMode::Latency.name(), "Opt-Latency");
        assert_eq!(OptMode::Metric("auc").name(), "Opt-Auc");
    }
}
