//! Algorithmic metrics used across the paper's evaluation (Sec. V):
//! ROC/AUC, average precision, accuracy at the Youden-optimal cutoff,
//! macro AP / macro recall for the 4-class task, predictive entropy,
//! Gaussian NLL, RMSE/L1, and MC-sample aggregation with an
//! epistemic/aleatoric uncertainty split.

/// One ROC point (false-positive rate, true-positive rate, threshold).
#[derive(Debug, Clone, Copy)]
pub struct RocPoint {
    pub fpr: f64,
    pub tpr: f64,
    pub threshold: f64,
}

/// Full receiver-operating characteristic for binary scores
/// (higher score = more anomalous/positive).
pub fn roc_curve(scores: &[f64], labels: &[bool]) -> Vec<RocPoint> {
    assert_eq!(scores.len(), labels.len());
    let pos = labels.iter().filter(|&&l| l).count();
    let neg = labels.len() - pos;
    assert!(pos > 0 && neg > 0, "ROC needs both classes");
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| {
        scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut pts = vec![RocPoint { fpr: 0.0, tpr: 0.0, threshold: f64::INFINITY }];
    let (mut tp, mut fp) = (0usize, 0usize);
    let mut i = 0;
    while i < idx.len() {
        // Process ties together.
        let thr = scores[idx[i]];
        while i < idx.len() && scores[idx[i]] == thr {
            if labels[idx[i]] {
                tp += 1;
            } else {
                fp += 1;
            }
            i += 1;
        }
        pts.push(RocPoint {
            fpr: fp as f64 / neg as f64,
            tpr: tp as f64 / pos as f64,
            threshold: thr,
        });
    }
    pts
}

/// Area under the ROC curve (trapezoid rule).
pub fn auc(scores: &[f64], labels: &[bool]) -> f64 {
    let pts = roc_curve(scores, labels);
    let mut area = 0.0;
    for w in pts.windows(2) {
        area += (w[1].fpr - w[0].fpr) * (w[1].tpr + w[0].tpr) / 2.0;
    }
    area
}

/// Average precision (area under the precision-recall curve, step-wise —
/// the sklearn definition).
pub fn average_precision(scores: &[f64], labels: &[bool]) -> f64 {
    let pos = labels.iter().filter(|&&l| l).count();
    assert!(pos > 0, "AP needs positives");
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| {
        scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal)
    });
    let (mut tp, mut fp) = (0usize, 0usize);
    let mut ap = 0.0;
    let mut prev_recall = 0.0;
    let mut i = 0;
    while i < idx.len() {
        let thr = scores[idx[i]];
        while i < idx.len() && scores[idx[i]] == thr {
            if labels[idx[i]] {
                tp += 1;
            } else {
                fp += 1;
            }
            i += 1;
        }
        let recall = tp as f64 / pos as f64;
        let precision = tp as f64 / (tp + fp) as f64;
        ap += (recall - prev_recall) * precision;
        prev_recall = recall;
    }
    ap
}

/// Accuracy at the cutoff maximising TPR - FPR (Youden's J — the paper's
/// "cutoff point that maximizes true positive rate against false positive
/// rate", Sec. V-A1).
pub fn accuracy_at_optimal_cutoff(scores: &[f64], labels: &[bool]) -> f64 {
    let pts = roc_curve(scores, labels);
    let best = pts
        .iter()
        .skip(1)
        .max_by(|a, b| {
            (a.tpr - a.fpr)
                .partial_cmp(&(b.tpr - b.fpr))
                .unwrap_or(std::cmp::Ordering::Equal)
        })
        .expect("non-empty ROC");
    let thr = best.threshold;
    let correct = scores
        .iter()
        .zip(labels)
        .filter(|&(&s, &l)| (s >= thr) == l)
        .count();
    correct as f64 / scores.len() as f64
}

// ---------------------------------------------------------------------------
// Multiclass metrics (classification task, Sec. V-A2). `probs` is row-major
// [n][k]; `labels` in 0..k.
// ---------------------------------------------------------------------------

pub fn multiclass_accuracy(probs: &[f64], labels: &[u8], k: usize) -> f64 {
    let n = labels.len();
    let mut correct = 0;
    for i in 0..n {
        let row = &probs[i * k..(i + 1) * k];
        let pred = argmax(row);
        if pred == labels[i] as usize {
            correct += 1;
        }
    }
    correct as f64 / n as f64
}

/// Macro-averaged one-vs-rest average precision (the paper's "macro AP"
/// for the severely unbalanced 4-class task).
pub fn macro_average_precision(probs: &[f64], labels: &[u8], k: usize) -> f64 {
    let n = labels.len();
    let mut total = 0.0;
    let mut classes = 0;
    for c in 0..k {
        let lab: Vec<bool> = labels.iter().map(|&l| l as usize == c).collect();
        if !lab.iter().any(|&b| b) {
            continue;
        }
        let sc: Vec<f64> = (0..n).map(|i| probs[i * k + c]).collect();
        total += average_precision(&sc, &lab);
        classes += 1;
    }
    total / classes as f64
}

/// Macro-averaged recall (the paper's "average recall, AR").
pub fn macro_recall(probs: &[f64], labels: &[u8], k: usize) -> f64 {
    let n = labels.len();
    let mut total = 0.0;
    let mut classes = 0;
    for c in 0..k {
        let in_class: Vec<usize> =
            (0..n).filter(|&i| labels[i] as usize == c).collect();
        if in_class.is_empty() {
            continue;
        }
        let hit = in_class
            .iter()
            .filter(|&&i| argmax(&probs[i * k..(i + 1) * k]) == c)
            .count();
        total += hit as f64 / in_class.len() as f64;
        classes += 1;
    }
    total / classes as f64
}

/// Predictive entropy in nats of a categorical distribution.
pub fn entropy(p: &[f64]) -> f64 {
    p.iter().filter(|&&v| v > 0.0).map(|&v| -v * v.ln()).sum()
}

/// Mean predictive entropy over rows of `probs` [n][k].
pub fn mean_entropy(probs: &[f64], k: usize) -> f64 {
    let n = probs.len() / k;
    (0..n).map(|i| entropy(&probs[i * k..(i + 1) * k])).sum::<f64>()
        / n as f64
}

pub fn argmax(row: &[f64]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

// ---------------------------------------------------------------------------
// Regression / reconstruction metrics (Fig. 1).
// ---------------------------------------------------------------------------

pub fn rmse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let s: f64 = a
        .iter()
        .zip(b)
        .map(|(x, y)| ((x - y) as f64) * ((x - y) as f64))
        .sum();
    (s / a.len() as f64).sqrt()
}

pub fn l1(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs() as f64).sum::<f64>()
        / a.len() as f64
}

/// Gaussian negative log-likelihood of targets under per-point mean/std.
pub fn gaussian_nll(target: &[f32], mean: &[f32], std: &[f32]) -> f64 {
    let mut nll = 0.0;
    for i in 0..target.len() {
        let s = (std[i] as f64).max(1e-6);
        let d = (target[i] - mean[i]) as f64;
        nll += 0.5 * ((2.0 * std::f64::consts::PI * s * s).ln()
            + d * d / (s * s));
    }
    nll / target.len() as f64
}

// ---------------------------------------------------------------------------
// MC-sample aggregation (Sec. II-B): predictions are the average over S
// feedforward passes; uncertainty decomposes into epistemic (variance of
// the per-sample means) and aleatoric (mean of per-sample variances,
// estimated from residual spread for the regression task).
// ---------------------------------------------------------------------------

/// Per-point mean and std over S MC samples. `samples` is [s][n] row-major.
pub fn mc_mean_std(samples: &[f32], s: usize, n: usize) -> (Vec<f32>, Vec<f32>) {
    let mut mean = vec![0f32; n];
    for si in 0..s {
        for i in 0..n {
            mean[i] += samples[si * n + i];
        }
    }
    for m in mean.iter_mut() {
        *m /= s as f32;
    }
    let mut std = vec![0f32; n];
    if s > 1 {
        for si in 0..s {
            for i in 0..n {
                let d = samples[si * n + i] - mean[i];
                std[i] += d * d;
            }
        }
        for v in std.iter_mut() {
            *v = (*v / (s - 1) as f32).sqrt();
        }
    }
    (mean, std)
}

/// Pooled per-point mean/std from accumulated MC moment sums
/// (`sum[i] = Σ_s x_si`, `sumsq[i] = Σ_s x_si²` over all `s` samples).
/// This is the fleet's MC-shard reduction: each engine returns its
/// shard's partial sums, the coordinator adds them element-wise and
/// finalises here. Matches [`mc_mean_std`] (sample std, n−1 divisor) up
/// to f64-accumulation order.
pub fn pooled_mean_std(
    sum: &[f64],
    sumsq: &[f64],
    s: usize,
) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(sum.len(), sumsq.len());
    assert!(s > 0, "pooled moments need at least one sample");
    let n = sum.len();
    let mut mean = vec![0f32; n];
    let mut std = vec![0f32; n];
    for i in 0..n {
        let m = sum[i] / s as f64;
        mean[i] = m as f32;
        if s > 1 {
            let var =
                ((sumsq[i] - s as f64 * m * m) / (s as f64 - 1.0)).max(0.0);
            std[i] = var.sqrt() as f32;
        }
    }
    (mean, std)
}

/// Average categorical distribution over S samples: `probs` [s][k] -> [k].
pub fn mc_mean_probs(probs: &[f64], s: usize, k: usize) -> Vec<f64> {
    let mut mean = vec![0f64; k];
    for si in 0..s {
        for i in 0..k {
            mean[i] += probs[si * k + i];
        }
    }
    for m in mean.iter_mut() {
        *m /= s as f64;
    }
    mean
}

// ---------------------------------------------------------------------------
// Calibration (the "accuracy vs calibration trade-off" the dropout rate p
// controls, Sec. II-B): expected calibration error over confidence bins.
// ---------------------------------------------------------------------------

/// Expected calibration error (ECE) with equal-width confidence bins.
/// `probs` [n][k] MC-mean distributions; `labels` ground truth.
pub fn expected_calibration_error(
    probs: &[f64],
    labels: &[u8],
    k: usize,
    bins: usize,
) -> f64 {
    let n = labels.len();
    assert!(bins > 0 && n > 0);
    let mut bin_conf = vec![0.0f64; bins];
    let mut bin_acc = vec![0.0f64; bins];
    let mut bin_n = vec![0usize; bins];
    for i in 0..n {
        let row = &probs[i * k..(i + 1) * k];
        let pred = argmax(row);
        let conf = row[pred];
        let b = ((conf * bins as f64) as usize).min(bins - 1);
        bin_conf[b] += conf;
        bin_acc[b] += if pred == labels[i] as usize { 1.0 } else { 0.0 };
        bin_n[b] += 1;
    }
    let mut ece = 0.0;
    for b in 0..bins {
        if bin_n[b] == 0 {
            continue;
        }
        let conf = bin_conf[b] / bin_n[b] as f64;
        let acc = bin_acc[b] / bin_n[b] as f64;
        ece += bin_n[b] as f64 / n as f64 * (conf - acc).abs();
    }
    ece
}

/// Epistemic/aleatoric decomposition for categorical MC predictions:
/// total = H(mean p); aleatoric = mean H(p_s); epistemic = mutual
/// information (total - aleatoric). `probs` [s][k].
pub fn uncertainty_decomposition(probs: &[f64], s: usize, k: usize)
    -> (f64, f64, f64)
{
    let mean = mc_mean_probs(probs, s, k);
    let total = entropy(&mean);
    let aleatoric = (0..s)
        .map(|si| entropy(&probs[si * k..(si + 1) * k]))
        .sum::<f64>()
        / s as f64;
    (total, aleatoric, (total - aleatoric).max(0.0))
}

/// Mean ± std over repeated retrains (Tables I/II report 3 retrains).
pub fn mean_std(values: &[f64]) -> (f64, f64) {
    let n = values.len() as f64;
    let mean = values.iter().sum::<f64>() / n;
    if values.len() < 2 {
        return (mean, 0.0);
    }
    let var =
        values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / (n - 1.0);
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auc_perfect_separation() {
        let scores = [0.9, 0.8, 0.2, 0.1];
        let labels = [true, true, false, false];
        assert!((auc(&scores, &labels) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn auc_interleaved() {
        // Pairs: (4>3)ok (4>1)ok (2<3)bad (2>1)ok -> 3/4 concordant.
        let scores = [4.0, 3.0, 2.0, 1.0];
        let labels = [true, false, true, false];
        let a = auc(&scores, &labels);
        assert!((a - 0.75).abs() < 1e-9, "{a}");
    }

    #[test]
    fn auc_antiperfect_is_zero() {
        let scores = [0.1, 0.2, 0.8, 0.9];
        let labels = [true, true, false, false];
        assert!(auc(&scores, &labels) < 1e-9);
    }

    #[test]
    fn auc_handles_ties() {
        let scores = [0.5, 0.5, 0.5, 0.5];
        let labels = [true, false, true, false];
        assert!((auc(&scores, &labels) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn ap_perfect() {
        let scores = [0.9, 0.8, 0.2, 0.1];
        let labels = [true, true, false, false];
        assert!((average_precision(&scores, &labels) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn accuracy_at_cutoff_perfect() {
        let scores = [0.9, 0.8, 0.2, 0.1];
        let labels = [true, true, false, false];
        assert!(
            (accuracy_at_optimal_cutoff(&scores, &labels) - 1.0).abs() < 1e-9
        );
    }

    #[test]
    fn multiclass_metrics() {
        // 3 rows, k=2: predictions 1, 0, 1 vs labels 1, 0, 0.
        let probs = [0.2, 0.8, 0.7, 0.3, 0.1, 0.9];
        let labels = [1u8, 0, 0];
        let acc = multiclass_accuracy(&probs, &labels, 2);
        assert!((acc - 2.0 / 3.0).abs() < 1e-9);
        let ar = macro_recall(&probs, &labels, 2);
        assert!((ar - (0.5 + 1.0) / 2.0).abs() < 1e-9);
        let ap = macro_average_precision(&probs, &labels, 2);
        assert!(ap > 0.5 && ap <= 1.0);
    }

    #[test]
    fn entropy_bounds() {
        assert!(entropy(&[1.0, 0.0]).abs() < 1e-12);
        let max = entropy(&[0.25; 4]);
        assert!((max - (4.0f64).ln()).abs() < 1e-9);
        let probs = [0.25, 0.25, 0.25, 0.25, 1.0, 0.0, 0.0, 0.0];
        let me = mean_entropy(&probs, 4);
        assert!((me - max / 2.0).abs() < 1e-9);
    }

    #[test]
    fn rmse_l1_nll() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [1.0f32, 2.0, 5.0];
        assert!((rmse(&a, &b) - (4.0f64 / 3.0).sqrt()).abs() < 1e-9);
        assert!((l1(&a, &b) - 2.0 / 3.0).abs() < 1e-9);
        let nll_tight = gaussian_nll(&a, &a, &[0.1, 0.1, 0.1]);
        let nll_wrong = gaussian_nll(&a, &b, &[0.1, 0.1, 0.1]);
        assert!(nll_wrong > nll_tight);
    }

    #[test]
    fn mc_aggregation() {
        // 2 samples over 3 points.
        let samples = [1.0f32, 2.0, 3.0, 3.0, 2.0, 1.0];
        let (mean, std) = mc_mean_std(&samples, 2, 3);
        assert_eq!(mean, vec![2.0, 2.0, 2.0]);
        assert!((std[0] - std::f32::consts::SQRT_2).abs() < 1e-6);
        assert!(std[1].abs() < 1e-9);
        let probs = [0.6, 0.4, 0.2, 0.8];
        let m = mc_mean_probs(&probs, 2, 2);
        assert!((m[0] - 0.4).abs() < 1e-12 && (m[1] - 0.6).abs() < 1e-12);
    }

    #[test]
    fn pooled_moments_match_direct_mc_aggregation() {
        use crate::rng::Rng;
        let mut rng = Rng::new(11);
        let (s, n) = (10usize, 7usize);
        let samples: Vec<f32> =
            (0..s * n).map(|_| rng.normal() as f32).collect();
        let (dm, ds) = mc_mean_std(&samples, s, n);
        // Accumulate shard-style partial sums (3 + 4 + 3 samples).
        let mut sum = vec![0f64; n];
        let mut sumsq = vec![0f64; n];
        for si in 0..s {
            for i in 0..n {
                let v = samples[si * n + i] as f64;
                sum[i] += v;
                sumsq[i] += v * v;
            }
        }
        let (pm, ps) = pooled_mean_std(&sum, &sumsq, s);
        for i in 0..n {
            assert!((pm[i] - dm[i]).abs() < 1e-5, "mean[{i}]");
            assert!((ps[i] - ds[i]).abs() < 1e-4, "std[{i}]");
        }
    }

    #[test]
    fn pooled_single_sample_has_zero_std() {
        let (m, s) = pooled_mean_std(&[2.0, 4.0], &[4.0, 16.0], 1);
        assert_eq!(m, vec![2.0, 4.0]);
        assert_eq!(s, vec![0.0, 0.0]);
    }

    #[test]
    fn mean_std_over_retrains() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - 1.0).abs() < 1e-12);
        let (m1, s1) = mean_std(&[5.0]);
        assert_eq!((m1, s1), (5.0, 0.0));
    }

    #[test]
    #[should_panic]
    fn roc_requires_both_classes() {
        roc_curve(&[0.1, 0.2], &[true, true]);
    }

    /// AUC is invariant under strictly monotone score transforms and
    /// complements under label flip — property sweep with random scores.
    #[test]
    fn auc_properties_random() {
        use crate::rng::Rng;
        let mut rng = Rng::new(5);
        for trial in 0..50 {
            let n = 20 + rng.below(60);
            let scores: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let labels: Vec<bool> =
                (0..n).map(|_| rng.bernoulli(0.4)).collect();
            if !labels.iter().any(|&l| l) || labels.iter().all(|&l| l) {
                continue;
            }
            let a = auc(&scores, &labels);
            assert!((0.0..=1.0).contains(&a), "trial {trial}: {a}");
            // Monotone transform invariance: exp is strictly increasing.
            let transformed: Vec<f64> =
                scores.iter().map(|s| s.exp()).collect();
            assert!((auc(&transformed, &labels) - a).abs() < 1e-12);
            // Label flip complements.
            let flipped: Vec<bool> = labels.iter().map(|l| !l).collect();
            assert!((auc(&scores, &flipped) - (1.0 - a)).abs() < 1e-9);
        }
    }

    #[test]
    fn ece_perfectly_calibrated_is_zero() {
        // Always predicts class 0 with confidence 1.0 and is always right.
        let probs = [1.0, 0.0, 1.0, 0.0, 1.0, 0.0];
        let labels = [0u8, 0, 0];
        assert!(expected_calibration_error(&probs, &labels, 2, 10) < 1e-12);
    }

    #[test]
    fn ece_overconfident_wrong() {
        // Confident (0.9) but only 50% correct -> ECE ~ 0.4.
        let probs = [0.9, 0.1, 0.9, 0.1];
        let labels = [0u8, 1];
        let ece = expected_calibration_error(&probs, &labels, 2, 10);
        assert!((ece - 0.4).abs() < 1e-9, "{ece}");
    }

    #[test]
    #[should_panic]
    fn ece_rejects_empty_input() {
        expected_calibration_error(&[], &[], 2, 10);
    }

    #[test]
    #[should_panic]
    fn ece_rejects_zero_bins() {
        expected_calibration_error(&[1.0, 0.0], &[0u8], 2, 0);
    }

    #[test]
    fn ece_one_hot_probs_land_in_top_bin() {
        // One-hot rows have confidence exactly 1.0, which must clamp
        // into the last bin instead of indexing out of bounds.
        let probs = [1.0, 0.0, 0.0, 1.0, 1.0, 0.0];
        let labels = [0u8, 1, 1]; // 2/3 correct at confidence 1.0
        let ece = expected_calibration_error(&probs, &labels, 2, 10);
        assert!((ece - 1.0 / 3.0).abs() < 1e-9, "{ece}");
    }

    #[test]
    fn ece_single_bin_degenerates_to_confidence_minus_accuracy() {
        // bins = 1: every prediction shares one bin, so ECE is
        // |mean confidence − accuracy|.
        let probs = [0.9, 0.1, 0.7, 0.3, 0.8, 0.2];
        let labels = [0u8, 0, 1]; // accuracy 2/3, mean confidence 0.8
        let ece = expected_calibration_error(&probs, &labels, 2, 1);
        assert!((ece - (0.8 - 2.0 / 3.0)).abs() < 1e-9, "{ece}");
    }

    #[test]
    fn ece_single_example_single_sample() {
        // n = 1 (the S = 1 serving edge): one confident correct row.
        let ece = expected_calibration_error(&[1.0, 0.0], &[0u8], 2, 15);
        assert!(ece < 1e-12);
        // ...and one confident wrong row: ECE = |1.0 - 0.0| = 1.
        let ece = expected_calibration_error(&[1.0, 0.0], &[1u8], 2, 15);
        assert!((ece - 1.0).abs() < 1e-12);
    }

    #[test]
    fn uncertainty_decomposition_single_sample_has_no_epistemic() {
        // S = 1: the MC mean *is* the sample, so total = aleatoric and
        // mutual information is exactly zero.
        let probs = [0.4, 0.3, 0.2, 0.1];
        let (t, a, e) = uncertainty_decomposition(&probs, 1, 4);
        assert!((t - a).abs() < 1e-15);
        assert_eq!(e, 0.0);
        assert!((t - entropy(&probs)).abs() < 1e-15);
    }

    #[test]
    fn uncertainty_decomposition_one_hot_samples() {
        // Identical one-hot samples: all three terms are zero.
        let same = [1.0, 0.0, 1.0, 0.0];
        let (t, a, e) = uncertainty_decomposition(&same, 2, 2);
        assert_eq!((t, a, e), (0.0, 0.0, 0.0));
        // Disagreeing one-hots: purely epistemic, total = MI = ln 2.
        let split = [1.0, 0.0, 0.0, 1.0];
        let (t2, a2, e2) = uncertainty_decomposition(&split, 2, 2);
        assert!((t2 - (2f64).ln()).abs() < 1e-12);
        assert!(a2 < 1e-15);
        assert!((e2 - (2f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn uncertainty_decomposition_epistemic_never_negative() {
        // f64 rounding can push total slightly below aleatoric for
        // near-identical samples; the clamp must hold the invariant.
        use crate::rng::Rng;
        let mut rng = Rng::new(13);
        for _ in 0..50 {
            let k = 2 + rng.below(4);
            let s = 1 + rng.below(6);
            let mut probs = Vec::with_capacity(s * k);
            for _ in 0..s {
                let mut row: Vec<f64> =
                    (0..k).map(|_| rng.uniform() + 1e-6).collect();
                let sum: f64 = row.iter().sum();
                row.iter_mut().for_each(|v| *v /= sum);
                probs.extend(row);
            }
            let (t, a, e) = uncertainty_decomposition(&probs, s, k);
            assert!(e >= 0.0, "epistemic clamped at zero");
            assert!(t >= 0.0 && a >= 0.0);
            assert!(e <= t + 1e-12, "MI cannot exceed total entropy");
        }
    }

    #[test]
    fn uncertainty_decomposition_identities() {
        // Identical samples: epistemic = 0, total = aleatoric.
        let probs = [0.5, 0.5, 0.5, 0.5];
        let (t, a, e) = uncertainty_decomposition(&probs, 2, 2);
        assert!((t - a).abs() < 1e-12 && e < 1e-12);
        // Confident but disagreeing samples: epistemic > 0, aleatoric ~ 0.
        let probs2 = [1.0, 0.0, 0.0, 1.0];
        let (t2, a2, e2) = uncertainty_decomposition(&probs2, 2, 2);
        assert!(a2 < 1e-9);
        assert!((t2 - (2f64).ln()).abs() < 1e-9);
        assert!(e2 > 0.6);
    }
}
