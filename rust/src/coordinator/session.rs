//! Byte-budgeted table of streaming sessions: the coordinator-side
//! home of resident MC lane state (`docs/serving.md` §Streaming
//! sessions).
//!
//! A session is a long-lived signal (an ECG monitor) whose recurrent
//! state stays resident between chunks, so each decision costs
//! O(chunk x S) instead of O(history x S) — the deployment shape of
//! continuous Bayesian monitoring in the paper's healthcare setting.
//! The table owns, per session:
//!
//! * the consumed **history** (raw signal values) — small, always
//!   retained, the replay source;
//! * zero or more resident [`StreamState`] lane ranges (one per
//!   MC-shard engine, or a single range under affinity routing) —
//!   the byte-budgeted part.
//!
//! Eviction is CLOCK second-chance over sessions, exactly the
//! [`crate::kernels::maskbank`] discipline, but the victim only loses
//! its *lane-state bytes*: because masks and state are pure functions
//! of `(design, session, beat, lane)`, an evicted session is rebuilt
//! transparently by replaying its history (`Resume::Replay`), or — with
//! replay disabled — rejected with a typed [`SessionError::Evicted`].
//! Sessions with queued or in-flight chunks are never evicted.
//!
//! Concurrency: one mutex over the table (sessions are few and
//! coarse, unlike the mask bank's per-lane-layer entries) plus a
//! condvar so [`SessionTable::close`] can drain in-flight chunks —
//! the close-session-drains contract. Counters are lock-free atomics
//! snapshotted into the `obs` export ([`SessionStats`]).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

use crate::fpga::StreamState;

/// Bookkeeping bytes charged per ring-resident session on top of its
/// lane-state words (map node, ring slot, entry fields — high-side
/// estimate, same convention as the mask bank).
const ENTRY_OVERHEAD: usize = 64;

/// Typed failures of the session plane.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SessionError {
    /// No such session (never opened, or already closed and removed).
    Unknown(u64),
    /// The session is closing; no new chunks are admitted.
    Closed(u64),
    /// Lane state was evicted and replay rebuilds are disabled.
    Evicted(u64),
    /// Streaming sessions are classifier-only.
    UnsupportedTask,
    /// The fleet was started without a session byte budget.
    Disabled,
    /// No healthy engine is left to serve this session's chunks.
    Unavailable(u64),
}

impl std::fmt::Display for SessionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SessionError::Unknown(sid) => write!(f, "unknown session {sid}"),
            SessionError::Closed(sid) => write!(f, "session {sid} is closed"),
            SessionError::Evicted(sid) => write!(
                f,
                "session {sid} lane state evicted (replay disabled)"
            ),
            SessionError::UnsupportedTask => {
                write!(f, "streaming sessions require a classifier design")
            }
            SessionError::Disabled => {
                write!(
                    f,
                    "streaming sessions are disabled (no session budget)"
                )
            }
            SessionError::Unavailable(sid) => write!(
                f,
                "session {sid}: no healthy engine left to serve chunks"
            ),
        }
    }
}

/// What a worker gets back when it picks up a session chunk.
#[derive(Debug)]
pub enum Resume {
    /// The range's lane state is resident — continue incrementally.
    Resident(StreamState),
    /// The range was evicted: rebuild by replaying `history` (the
    /// signal values consumed before the current chunk) into a fresh
    /// stream, then continue. Bit-identical to having stayed resident,
    /// because lane state is a pure function of the consumed signal.
    Replay { history: Vec<f32> },
}

/// Point-in-time counter snapshot, exported through `obs`
/// (`docs/observability.md` §Serve metrics).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SessionStats {
    /// Sessions ever opened.
    pub opened: u64,
    /// Sessions closed (drained and removed).
    pub closed: u64,
    /// Sessions currently open (gauge).
    pub resident: u64,
    /// Lane-state bytes currently resident (gauge).
    pub resident_bytes: u64,
    /// Byte budget for resident lane state.
    pub capacity_bytes: u64,
    /// Sessions whose lane state was evicted by the byte budget.
    pub evictions: u64,
    /// Lane ranges rebuilt by history replay after an eviction.
    pub replay_rebuilds: u64,
    /// Chunks submitted across all sessions.
    pub chunks: u64,
    /// Chunks whose decision was recomputed at the boosted MC budget
    /// after an uncertainty spike.
    pub boosted_chunks: u64,
}

/// Static facts about one open session, stamped at `open` and read by
/// the fleet's routing and worker paths.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionMeta {
    /// Seed the session's per-beat mask schedule derives from.
    pub seed: u64,
    /// Engine the session's lanes are pinned to (affinity routing);
    /// ignored under MC-shard placement.
    pub engine: usize,
    /// Base MC samples per decision.
    pub samples: usize,
}

struct Entry {
    meta: SessionMeta,
    /// Raw signal values consumed so far (the replay source — always
    /// retained; the byte budget governs lane state only).
    history: Vec<f32>,
    /// Resident lane ranges keyed by their first MC lane.
    states: HashMap<usize, StreamState>,
    /// Lane-state bytes currently charged for this session.
    state_bytes: usize,
    /// Chunks submitted but not yet parked back (queued or computing).
    pending: usize,
    closed: bool,
    /// CLOCK reference bit: set on every chunk touch, cleared (second
    /// chance) when the eviction hand sweeps past. Fresh sessions
    /// start unreferenced, like fresh mask-bank inserts.
    referenced: bool,
    /// Whether this sid currently occupies a CLOCK ring slot (and is
    /// charged `ENTRY_OVERHEAD`).
    in_ring: bool,
}

#[derive(Default)]
struct Inner {
    entries: HashMap<u64, Entry>,
    /// CLOCK ring of sessions holding resident lane-state bytes.
    ring: Vec<u64>,
    hand: usize,
    bytes: usize,
}

impl Inner {
    /// Evict lane state (never history) until the budget holds, CLOCK
    /// order, skipping sessions with pending chunks. Returns the
    /// number of sessions evicted.
    fn make_room(&mut self, budget: usize) -> u64 {
        let mut evicted = 0u64;
        // Guard against a ring where every survivor is pinned by
        // pending work: a full no-progress double-lap ends the sweep.
        let mut since_progress = 0usize;
        while self.bytes > budget
            && !self.ring.is_empty()
            && since_progress <= 2 * self.ring.len()
        {
            if self.hand >= self.ring.len() {
                self.hand = 0;
            }
            let sid = self.ring[self.hand];
            let e = self.entries.get_mut(&sid).expect("ring/map desync");
            if e.pending > 0 {
                // Queued work needs this state imminently; skip.
                self.hand += 1;
                since_progress += 1;
            } else if e.referenced {
                e.referenced = false;
                self.hand += 1;
                since_progress += 1;
            } else {
                let cost = e.state_bytes + ENTRY_OVERHEAD;
                e.states.clear();
                e.state_bytes = 0;
                e.in_ring = false;
                self.ring.swap_remove(self.hand);
                // swap_remove moved the tail sid under the hand; keep
                // the hand in place so it is inspected next.
                self.bytes -= cost;
                evicted += 1;
                since_progress = 0;
            }
        }
        evicted
    }

    /// Charge `added` freshly parked lane-state bytes to `sid`,
    /// entering it into the CLOCK ring if it is not there already.
    fn charge(&mut self, sid: u64, added: usize) {
        let e = self.entries.get_mut(&sid).expect("charging unknown sid");
        if !e.in_ring {
            e.in_ring = true;
            self.ring.push(sid);
            self.bytes += ENTRY_OVERHEAD;
        }
        self.bytes += added;
    }
}

/// The table itself. Shared as `Arc<SessionTable>` between the fleet
/// (open/submit/close) and its engine workers (resume/park).
pub struct SessionTable {
    inner: Mutex<Inner>,
    drained: Condvar,
    capacity_bytes: usize,
    replay: bool,
    opened: AtomicU64,
    closed: AtomicU64,
    evictions: AtomicU64,
    replay_rebuilds: AtomicU64,
    chunks: AtomicU64,
    boosted_chunks: AtomicU64,
}

impl std::fmt::Debug for SessionTable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("SessionTable")
            .field("resident", &s.resident)
            .field("resident_bytes", &s.resident_bytes)
            .field("capacity_bytes", &s.capacity_bytes)
            .field("evictions", &s.evictions)
            .finish()
    }
}

impl SessionTable {
    /// A table budgeting at most `capacity_bytes` of resident lane
    /// state (the CLI's `--session-mb`, scaled). `replay = false`
    /// turns transparent rebuilds into [`SessionError::Evicted`].
    pub fn new(capacity_bytes: usize, replay: bool) -> Self {
        Self {
            inner: Mutex::new(Inner::default()),
            drained: Condvar::new(),
            capacity_bytes,
            replay,
            opened: AtomicU64::new(0),
            closed: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            replay_rebuilds: AtomicU64::new(0),
            chunks: AtomicU64::new(0),
            boosted_chunks: AtomicU64::new(0),
        }
    }

    /// Whether evicted sessions are rebuilt transparently.
    pub fn replay_enabled(&self) -> bool {
        self.replay
    }

    /// Register a session. No lane state is charged yet: the worker
    /// serving the first chunk opens fresh zero state (`history_end`
    /// 0 replays nothing) and parks it back, at which point the
    /// session enters the byte budget's CLOCK ring.
    pub fn open(&self, sid: u64, meta: SessionMeta) {
        let mut inner = self.inner.lock().expect("session table poisoned");
        let entry = Entry {
            meta,
            history: Vec::new(),
            states: HashMap::new(),
            state_bytes: 0,
            pending: 0,
            closed: false,
            referenced: false,
            in_ring: false,
        };
        let prev = inner.entries.insert(sid, entry);
        debug_assert!(prev.is_none(), "session id reused");
        drop(inner);
        self.opened.fetch_add(1, Ordering::Relaxed);
    }

    /// Session facts stamped at `open`.
    pub fn meta(&self, sid: u64) -> Result<SessionMeta, SessionError> {
        let inner = self.inner.lock().expect("session table poisoned");
        inner
            .entries
            .get(&sid)
            .map(|e| e.meta)
            .ok_or(SessionError::Unknown(sid))
    }

    /// Move an affinity session's pin to a new engine (fault
    /// tolerance: its home worker died). Purely a metadata update —
    /// any lane state still resident stays keyed by `start` and is
    /// engine-agnostic, and evicted ranges rebuild by replay on the
    /// new engine, so outputs are unchanged by construction.
    pub fn repin(
        &self,
        sid: u64,
        engine: usize,
    ) -> Result<(), SessionError> {
        let mut inner = self.inner.lock().expect("session table poisoned");
        let e = inner
            .entries
            .get_mut(&sid)
            .ok_or(SessionError::Unknown(sid))?;
        if e.closed {
            return Err(SessionError::Closed(sid));
        }
        e.meta.engine = engine;
        Ok(())
    }

    /// Admit a chunk: append it to the session's history and account
    /// `ranges` pending work items (one per engine shard the fleet
    /// will dispatch). Returns the history length (values) *before*
    /// this chunk — the `history_end` workers replay up to on rebuild.
    pub fn submit(
        &self,
        sid: u64,
        chunk: &[f32],
        ranges: usize,
    ) -> Result<usize, SessionError> {
        let mut inner = self.inner.lock().expect("session table poisoned");
        let e = inner
            .entries
            .get_mut(&sid)
            .ok_or(SessionError::Unknown(sid))?;
        if e.closed {
            return Err(SessionError::Closed(sid));
        }
        let history_end = e.history.len();
        e.history.extend_from_slice(chunk);
        e.pending += ranges;
        e.referenced = true;
        drop(inner);
        self.chunks.fetch_add(1, Ordering::Relaxed);
        Ok(history_end)
    }

    /// Worker side: take ownership of the lane range starting at
    /// `start` for the duration of a chunk. Resident state is handed
    /// out directly; evicted state comes back as [`Resume::Replay`]
    /// with the history up to `history_end` — or, with replay
    /// disabled, a typed error (whose pending slot is released here,
    /// since no `park` will follow).
    pub fn resume(
        &self,
        sid: u64,
        start: usize,
        history_end: usize,
    ) -> Result<Resume, SessionError> {
        let mut inner = self.inner.lock().expect("session table poisoned");
        let e = inner
            .entries
            .get_mut(&sid)
            .ok_or(SessionError::Unknown(sid))?;
        e.referenced = true;
        if let Some(state) = e.states.remove(&start) {
            let bytes = state.resident_bytes();
            e.state_bytes -= bytes;
            inner.bytes -= bytes;
            return Ok(Resume::Resident(state));
        }
        if history_end == 0 {
            // First chunk of a fresh session: nothing to replay, the
            // worker opens zero state. Not an eviction rebuild, and
            // fine even with replay disabled.
            return Ok(Resume::Replay { history: Vec::new() });
        }
        if !self.replay {
            e.pending = e.pending.saturating_sub(1);
            drop(inner);
            self.drained.notify_all();
            return Err(SessionError::Evicted(sid));
        }
        let history = e.history[..history_end].to_vec();
        drop(inner);
        self.replay_rebuilds.fetch_add(1, Ordering::Relaxed);
        Ok(Resume::Replay { history })
    }

    /// Worker side: a chunk failed between `resume` and `park` (e.g.
    /// the engine rejected the rebuild) — release its pending slot so
    /// [`SessionTable::close`] does not wait forever. Any checked-out
    /// lane state is lost; the next chunk rebuilds by replay.
    pub fn abandon(&self, sid: u64) {
        let mut inner = self.inner.lock().expect("session table poisoned");
        if let Some(e) = inner.entries.get_mut(&sid) {
            e.pending = e.pending.saturating_sub(1);
        }
        drop(inner);
        self.drained.notify_all();
    }

    /// The signal values consumed before `end` — the replay source for
    /// the boosted-lane escalation path, which rebuilds its extra
    /// lanes from scratch regardless of residency.
    pub fn history(
        &self,
        sid: u64,
        end: usize,
    ) -> Result<Vec<f32>, SessionError> {
        let inner = self.inner.lock().expect("session table poisoned");
        let e = inner.entries.get(&sid).ok_or(SessionError::Unknown(sid))?;
        Ok(e.history[..end.min(e.history.len())].to_vec())
    }

    /// Worker side: return a range's advanced lane state after a
    /// chunk, release its pending slot, and run the byte budget
    /// (which may immediately evict the parked state — or another
    /// session's).
    pub fn park(&self, sid: u64, state: StreamState) {
        let mut inner = self.inner.lock().expect("session table poisoned");
        let added = state.resident_bytes();
        {
            let Some(e) = inner.entries.get_mut(&sid) else {
                // Session force-removed while the chunk was in flight;
                // drop the state on the floor.
                return;
            };
            e.state_bytes += added;
            e.pending = e.pending.saturating_sub(1);
            e.states.insert(state.start, state);
            e.referenced = true;
        }
        inner.charge(sid, added);
        let evicted = inner.make_room(self.capacity_bytes);
        drop(inner);
        if evicted > 0 {
            self.evictions.fetch_add(evicted, Ordering::Relaxed);
        }
        self.drained.notify_all();
    }

    /// Close a session: stop admitting chunks, **drain** what is
    /// queued or in flight (blocking on the worker-side `park`s),
    /// then drop the session entirely — history, lane state, bytes.
    pub fn close(&self, sid: u64) -> Result<(), SessionError> {
        let mut inner = self.inner.lock().expect("session table poisoned");
        match inner.entries.get_mut(&sid) {
            None => return Err(SessionError::Unknown(sid)),
            Some(e) => e.closed = true,
        }
        while inner.entries.get(&sid).expect("closing session").pending > 0 {
            inner = self
                .drained
                .wait(inner)
                .expect("session table poisoned");
        }
        let e = inner.entries.remove(&sid).expect("closing session");
        if e.in_ring {
            inner.ring.retain(|&s| s != sid);
            inner.bytes -= e.state_bytes + ENTRY_OVERHEAD;
            // The hand may now point past the end; make_room re-wraps.
        }
        drop(inner);
        self.closed.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Record a chunk whose decision was recomputed at the boosted MC
    /// budget (the adaptive streaming tier).
    pub fn note_boost(&self) {
        self.boosted_chunks.fetch_add(1, Ordering::Relaxed);
    }

    pub fn stats(&self) -> SessionStats {
        let (resident, resident_bytes) = {
            let inner = self.inner.lock().expect("session table poisoned");
            (inner.entries.len() as u64, inner.bytes as u64)
        };
        SessionStats {
            opened: self.opened.load(Ordering::Relaxed),
            closed: self.closed.load(Ordering::Relaxed),
            resident,
            resident_bytes,
            capacity_bytes: self.capacity_bytes as u64,
            evictions: self.evictions.load(Ordering::Relaxed),
            replay_rebuilds: self.replay_rebuilds.load(Ordering::Relaxed),
            chunks: self.chunks.load(Ordering::Relaxed),
            boosted_chunks: self.boosted_chunks.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArchConfig, Task};
    use crate::fpga::Accelerator;
    use crate::hwmodel::resource::ReuseFactors;
    use crate::nn::Params;
    use crate::rng::Rng;
    use std::sync::Arc;

    fn accel() -> Accelerator {
        let mut cfg = ArchConfig::new(Task::Classify, 8, 2, "YY");
        cfg.seq_len = 24;
        let params = Params::init(&cfg, &mut Rng::new(2));
        Accelerator::new(&cfg, &params, ReuseFactors::new(1, 1, 1), 9)
    }

    fn meta(engine: usize) -> SessionMeta {
        SessionMeta { seed: 7, engine, samples: 4 }
    }

    #[test]
    fn open_submit_resume_park_round_trip() {
        let a = accel();
        let table = SessionTable::new(1 << 20, true);
        table.open(1, meta(0));
        assert_eq!(table.meta(1).unwrap().samples, 4);
        let end = table.submit(1, &[0.5; 24], 1).unwrap();
        assert_eq!(end, 0, "first chunk starts at history 0");
        match table.resume(1, 0, end).unwrap() {
            Resume::Replay { history } => {
                assert!(history.is_empty(), "fresh session: nothing to replay")
            }
            Resume::Resident(_) => panic!("no state before the first park"),
        }
        let s = table.stats();
        assert_eq!(s.replay_rebuilds, 0, "a fresh open is not a rebuild");
        // The worker opens zero state, advances it, parks it back.
        table.park(1, a.open_stream(7, 0, 4));
        let s = table.stats();
        assert_eq!((s.opened, s.resident, s.chunks), (1, 1, 1));
        assert!(s.resident_bytes > 0);
        // Second chunk finds the parked state resident and the
        // history appended.
        let end = table.submit(1, &[0.25; 10], 1).unwrap();
        assert_eq!(end, 24);
        let Resume::Resident(st) = table.resume(1, 0, end).unwrap() else {
            panic!("state parked by the first chunk must be resident");
        };
        assert_eq!(st.count, 4);
        assert_eq!(table.history(1, end).unwrap().len(), 24);
        table.park(1, st);
        table.close(1).unwrap();
        let s = table.stats();
        assert_eq!((s.closed, s.resident, s.resident_bytes), (1, 0, 0));
        assert!(matches!(
            table.submit(1, &[0.0; 2], 1),
            Err(SessionError::Unknown(1))
        ));
        assert_eq!(table.meta(1), Err(SessionError::Unknown(1)));
    }

    #[test]
    fn repin_moves_the_session_home() {
        let table = SessionTable::new(1 << 20, true);
        table.open(3, meta(0));
        assert_eq!(table.meta(3).unwrap().engine, 0);
        table.repin(3, 2).unwrap();
        assert_eq!(table.meta(3).unwrap().engine, 2);
        assert_eq!(table.repin(99, 1), Err(SessionError::Unknown(99)));
        table.close(3).unwrap();
        assert_eq!(table.repin(3, 1), Err(SessionError::Unknown(3)));
    }

    #[test]
    fn zero_budget_evicts_and_replay_hands_out_history() {
        let a = accel();
        let table = SessionTable::new(0, true);
        table.open(5, meta(0));
        let end = table.submit(5, &[1.0, 2.0, 3.0], 1).unwrap();
        match table.resume(5, 0, end).unwrap() {
            Resume::Replay { history } => {
                assert!(history.is_empty(), "no history before chunk 0")
            }
            Resume::Resident(_) => panic!("nothing parked yet"),
        }
        // Parking under a zero budget evicts the state immediately.
        table.park(5, a.open_stream(9, 0, 4));
        assert_eq!(table.stats().evictions, 1);
        assert_eq!(table.stats().resident_bytes, 0);
        let end = table.submit(5, &[4.0; 2], 1).unwrap();
        assert_eq!(end, 3);
        match table.resume(5, 0, end).unwrap() {
            Resume::Replay { history } => {
                assert_eq!(history, vec![1.0, 2.0, 3.0])
            }
            Resume::Resident(_) => panic!("budget 0 keeps nothing"),
        }
        let s = table.stats();
        assert_eq!(s.replay_rebuilds, 1, "the eviction rebuild is counted");
        table.park(5, a.open_stream(9, 0, 4));
        assert_eq!(table.stats().evictions, 2);
        table.close(5).unwrap();
    }

    #[test]
    fn replay_disabled_turns_eviction_into_typed_error() {
        let a = accel();
        let table = SessionTable::new(0, false);
        assert!(!table.replay_enabled());
        table.open(2, meta(1));
        // The first chunk is always admitted: fresh zero state needs
        // no replay.
        let end = table.submit(2, &[0.5; 4], 1).unwrap();
        assert!(matches!(
            table.resume(2, 0, end).unwrap(),
            Resume::Replay { .. }
        ));
        table.park(2, a.open_stream(3, 0, 4)); // budget 0 → evicted
        // The second chunk finds the state gone and replay disabled.
        let end = table.submit(2, &[0.5; 4], 1).unwrap();
        assert_eq!(
            table.resume(2, 0, end).unwrap_err(),
            SessionError::Evicted(2)
        );
        // The failed resume released its pending slot: close drains
        // immediately instead of hanging.
        table.close(2).unwrap();
        assert_eq!(table.stats().closed, 1);
    }

    #[test]
    fn pending_sessions_are_never_evicted() {
        let a = accel();
        // Budget fits exactly one session's lane state.
        let one = a.open_stream(1, 0, 8).resident_bytes() + ENTRY_OVERHEAD;
        let table = SessionTable::new(one, true);
        // Run a session's first chunk to completion: its zero state is
        // parked and resident afterwards.
        let prime = |sid: u64| {
            table.open(sid, meta(0));
            let end = table.submit(sid, &[0.0; 8], 1).unwrap();
            assert!(matches!(
                table.resume(sid, 0, end).unwrap(),
                Resume::Replay { .. }
            ));
            table.park(sid, a.open_stream(sid, 0, 8));
        };
        prime(1);
        assert_eq!(table.stats().evictions, 0);
        // Check session 1's range out: it now has a pending chunk.
        let end = table.submit(1, &[1.0; 8], 1).unwrap();
        let Resume::Resident(checked_out) = table.resume(1, 0, end).unwrap()
        else {
            panic!("primed state must be resident");
        };
        // Priming a second session overflows the budget; the sweep
        // must evict session 2 itself, never the pending session 1.
        prime(2);
        assert_eq!(table.stats().evictions, 1);
        let end2 = table.submit(2, &[0.0; 4], 1).unwrap();
        match table.resume(2, 0, end2).unwrap() {
            Resume::Replay { history } => assert_eq!(history.len(), 8),
            Resume::Resident(_) => panic!("session 2 must be the victim"),
        }
        table.park(2, a.open_stream(2, 0, 8));
        // Session 1's checked-out range parks back fine.
        table.park(1, checked_out);
        table.close(1).unwrap();
        table.close(2).unwrap();
        assert_eq!(table.stats().resident_bytes, 0);
    }

    #[test]
    fn close_blocks_until_inflight_chunks_park() {
        let a = accel();
        let table = Arc::new(SessionTable::new(1 << 20, true));
        table.open(9, meta(0));
        let end = table.submit(9, &[0.0; 6], 1).unwrap();
        assert!(matches!(
            table.resume(9, 0, end).unwrap(),
            Resume::Replay { .. }
        ));
        let st = a.open_stream(9, 0, 4);
        // A worker parks the state back after a delay; close must wait
        // for it (the close-session-drains regression).
        let worker_table = table.clone();
        let worker = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(50));
            worker_table.park(9, st);
        });
        let t0 = std::time::Instant::now();
        table.close(9).unwrap();
        assert!(
            t0.elapsed() >= std::time::Duration::from_millis(40),
            "close returned before the in-flight chunk parked"
        );
        worker.join().unwrap();
        assert_eq!(table.stats().resident, 0);
    }

    #[test]
    fn clock_second_chance_prefers_untouched_sessions() {
        let a = accel();
        let one = a.open_stream(1, 0, 4).resident_bytes() + ENTRY_OVERHEAD;
        // Room for exactly two sessions' lane state.
        let table = SessionTable::new(2 * one, true);
        let prime = |sid: u64| {
            table.open(sid, meta(0));
            let end = table.submit(sid, &[0.0], 1).unwrap();
            let _ = table.resume(sid, 0, end).unwrap();
            table.park(sid, a.open_stream(sid, 0, 4));
        };
        prime(1);
        prime(2);
        assert_eq!(table.stats().evictions, 0);
        // A third session overflows the budget. Every reference bit is
        // set (each park references its session), so the first sweep
        // clears them all and evicts the hand's next stop — session 1.
        prime(3);
        assert_eq!(table.stats().evictions, 1);
        // Touch session 2 (sets its bit); session 3 stays untouched.
        let end = table.submit(2, &[1.0], 1).unwrap();
        let Resume::Resident(st) = table.resume(2, 0, end).unwrap() else {
            panic!("session 2 must still be resident");
        };
        table.park(2, st);
        // A fourth session overflows again: the hand now finds the
        // untouched session 3 first and evicts it; the referenced
        // session 2 survives on its second chance.
        prime(4);
        assert_eq!(table.stats().evictions, 2);
        let end = table.submit(2, &[2.0], 1).unwrap();
        assert!(matches!(
            table.resume(2, 0, end).unwrap(),
            Resume::Resident(_)
        ));
        let end = table.submit(3, &[0.0], 1).unwrap();
        assert!(matches!(
            table.resume(3, 0, end).unwrap(),
            Resume::Replay { .. }
        ));
    }
}
