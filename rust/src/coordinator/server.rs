//! The serving loop: bounded request queue -> batcher -> engine worker ->
//! response channel, with end-to-end latency accounting.
//!
//! Single engine-worker thread (the FPGA is one device; PJRT CPU
//! executables are internally threaded), many producers. Backpressure:
//! `submit` uses a bounded sync_channel, so producers block when the
//! queue is full — the paper's DMA/AXI stream behaves the same way.

use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use super::batcher::{Batcher, BatchPolicy};
use super::engines::{Engine, Prediction};
use super::stats::LatencyStats;
use crate::obs::{StageStats, WorkerTimeline};

/// Server configuration.
pub struct ServerConfig {
    pub policy: BatchPolicy,
    /// Request-queue depth before producers block.
    pub queue_depth: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            policy: BatchPolicy::stream(),
            queue_depth: super::DEFAULT_QUEUE_DEPTH,
        }
    }
}

struct Request {
    id: u64,
    beat: Vec<f32>,
    enqueued: Instant,
    reply: mpsc::Sender<Response>,
}

/// A served response.
pub struct Response {
    pub id: u64,
    pub prediction: Prediction,
    /// Wall-clock queue+service latency observed by the coordinator.
    pub e2e_ms: f64,
}

/// Summary returned by `Server::join` (also the per-engine summary type
/// of the fleet, `coordinator::fleet`).
#[derive(Debug, Clone)]
pub struct ServeSummary {
    pub served: usize,
    pub wall: Duration,
    pub e2e: LatencyStats,
    /// Engine-model latency (FPGA cycles / GPU model / PJRT measured).
    pub engine: LatencyStats,
    pub batches: usize,
    pub mean_batch: f64,
    /// Requests shed by admission control (always 0 for the single-engine
    /// `Server`, which blocks instead; the fleet counts rejections here).
    pub rejected: usize,
    /// Per-stage (queue / batch-form / compute) latency histograms;
    /// `None` unless the fleet ran with observability enabled.
    pub stages: Option<StageStats>,
    /// MC sample rows this worker computed (items × shard sizes).
    pub mc_rows: usize,
    /// Engine backend label (`fpga:<kernel>` / `gpu` / `pjrt`).
    pub kernel: String,
    /// Largest batch the worker's batcher ever formed.
    pub peak_batch: usize,
    /// Deepest this engine's queue ever got (fleet-injected; the
    /// single-engine `Server` does not track it).
    pub queue_highwater: usize,
    /// Work items rejected at this engine's queue (fleet-injected).
    pub sheds: usize,
    /// Per-window stage/throughput slice of this worker's run; `None`
    /// unless the fleet ran with a windowed timeline
    /// (`ObsConfig::window`).
    pub timeline: Option<WorkerTimeline>,
}

/// Handle for submitting requests.
pub struct Server {
    tx: Option<mpsc::SyncSender<Request>>,
    worker: Option<thread::JoinHandle<ServeSummary>>,
    next_id: u64,
}

impl Server {
    /// Spawn the engine worker. Engines built on PJRT hold non-`Send` XLA
    /// handles, so the engine is constructed *inside* the worker thread
    /// from a `Send` factory.
    pub fn start(
        factory: impl FnOnce() -> Engine + Send + 'static,
        cfg: ServerConfig,
    ) -> Self {
        let (tx, rx) = mpsc::sync_channel::<Request>(cfg.queue_depth);
        let worker = thread::spawn(move || {
            let mut engine = factory();
            let mut batcher: Batcher<Request> = Batcher::new(cfg.policy);
            let mut e2e = LatencyStats::new();
            let mut eng = LatencyStats::new();
            let mut served = 0usize;
            let mut batches = 0usize;
            let t0 = Instant::now();
            let mut open = true;
            while open || !batcher.is_empty() {
                if open {
                    if batcher.is_empty() {
                        // Nothing pending: block briefly for new work.
                        match rx.recv_timeout(Duration::from_millis(1)) {
                            Ok(req) => batcher.push(req.id, req),
                            Err(mpsc::RecvTimeoutError::Timeout) => {}
                            Err(mpsc::RecvTimeoutError::Disconnected) => {
                                open = false;
                            }
                        }
                    }
                    // Work is pending: drain opportunistically, never
                    // sleep (sleeping here added ~1 ms per request —
                    // see EXPERIMENTS.md §Perf).
                    loop {
                        match rx.try_recv() {
                            Ok(r) => batcher.push(r.id, r),
                            Err(mpsc::TryRecvError::Empty) => break,
                            Err(mpsc::TryRecvError::Disconnected) => {
                                open = false;
                                break;
                            }
                        }
                    }
                }
                let queue_empty = true; // everything available was drained
                if batcher.ready(queue_empty) {
                    let batch = batcher.take();
                    batches += 1;
                    let beats: Vec<&[f32]> =
                        batch.items.iter().map(|r| r.beat.as_slice()).collect();
                    match engine.infer_batch(&beats) {
                        Ok(preds) => {
                            for (req, pred) in
                                batch.items.into_iter().zip(preds)
                            {
                                let ms = req.enqueued.elapsed().as_secs_f64()
                                    * 1e3;
                                e2e.record_ms(ms);
                                eng.record_ms(pred.model_latency_ms);
                                served += 1;
                                let _ = req.reply.send(Response {
                                    id: req.id,
                                    prediction: pred,
                                    e2e_ms: ms,
                                });
                            }
                        }
                        Err(e) => {
                            // Engine failure: drop the batch, report via
                            // closed reply channels.
                            eprintln!("engine error: {e:#}");
                        }
                    }
                }
            }
            let wall = t0.elapsed();
            let mean_batch = if batches > 0 {
                served as f64 / batches as f64
            } else {
                0.0
            };
            ServeSummary {
                served,
                wall,
                e2e,
                engine: eng,
                batches,
                mean_batch,
                rejected: 0,
                stages: None,
                mc_rows: served * engine.s,
                kernel: engine.backend_label(),
                peak_batch: batcher.peak_batch(),
                queue_highwater: 0,
                sheds: 0,
                timeline: None,
            }
        });
        Self { tx: Some(tx), worker: Some(worker), next_id: 0 }
    }

    /// Submit a beat; returns a receiver for the response. Blocks when
    /// the queue is full (backpressure).
    pub fn submit(&mut self, beat: Vec<f32>) -> mpsc::Receiver<Response> {
        let (reply_tx, reply_rx) = mpsc::channel();
        let id = self.next_id;
        self.next_id += 1;
        self.tx
            .as_ref()
            .expect("server already joined")
            .send(Request { id, beat, enqueued: Instant::now(), reply: reply_tx })
            .expect("worker gone");
        reply_rx
    }

    /// Close the queue and wait for the worker; returns serving stats.
    pub fn join(mut self) -> ServeSummary {
        drop(self.tx.take());
        self.worker.take().expect("already joined").join().expect("worker panicked")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArchConfig, Task};
    use crate::hwmodel::resource::ReuseFactors;
    use crate::nn::model::Model;
    use crate::rng::Rng;

    fn tiny_engine(s: usize) -> Engine {
        let mut cfg = ArchConfig::new(Task::Classify, 8, 1, "Y");
        cfg.seq_len = 20;
        let model = Model::init(cfg.clone(), &mut Rng::new(0));
        Engine::fpga(&cfg, &model, ReuseFactors::new(2, 1, 1), s, 5)
    }

    #[test]
    fn serves_all_requests_in_order_of_reply() {
        let mut server = Server::start(|| tiny_engine(2), ServerConfig::default());
        let beat: Vec<f32> = (0..20).map(|i| (i as f32 * 0.3).sin()).collect();
        let receivers: Vec<_> =
            (0..12).map(|_| server.submit(beat.clone())).collect();
        let mut got = 0;
        for rx in receivers {
            let resp = rx.recv().expect("response");
            assert_eq!(resp.prediction.mean.len(), 4);
            assert!(resp.e2e_ms >= 0.0);
            got += 1;
        }
        assert_eq!(got, 12);
        let summary = server.join();
        assert_eq!(summary.served, 12);
        assert!(summary.e2e.count() == 12);
        assert!(summary.engine.mean_ms() > 0.0);
        assert!(summary.batches >= 1);
    }

    #[test]
    fn batched_policy_groups_requests() {
        let cfg = ServerConfig {
            policy: BatchPolicy::batched(4, Duration::from_millis(50)),
            queue_depth: 64,
        };
        let mut server = Server::start(|| tiny_engine(1), cfg);
        let beat: Vec<f32> = vec![0.1; 20];
        let receivers: Vec<_> =
            (0..8).map(|_| server.submit(beat.clone())).collect();
        for rx in receivers {
            rx.recv().unwrap();
        }
        let summary = server.join();
        assert_eq!(summary.served, 8);
        // With 8 requests racing in, batches must form (fewer than 8).
        assert!(summary.batches <= 8);
        assert!(summary.mean_batch >= 1.0);
    }

    #[test]
    fn join_without_requests_is_clean() {
        let server = Server::start(|| tiny_engine(1), ServerConfig::default());
        let summary = server.join();
        assert_eq!(summary.served, 0);
        assert_eq!(summary.batches, 0);
    }
}
