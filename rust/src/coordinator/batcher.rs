//! Dynamic batcher: groups queued requests into engine batches under a
//! size/deadline policy. Streaming (batch 1) mirrors the paper's
//! request-at-a-time arrival; batched policies feed the engines'
//! blocked entry points (`docs/kernels.md`), which compute one blocked
//! kernel call per batch. An optional *row budget* additionally caps
//! the total MC-sample rows per batch, since a blocked call's cost
//! scales with sample rows, not request count.

use std::time::{Duration, Instant};

/// Batch-forming policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Max requests per batch (1 = stream-through).
    pub max_batch: usize,
    /// Max time the first queued request may wait for company.
    pub max_wait: Duration,
    /// Max total weight (MC-sample rows) per batch; 0 = unlimited.
    /// Items pushed via [`Batcher::push_weighted`] count their weight,
    /// plain pushes count 1. A single over-budget item still forms its
    /// own batch (never starve).
    pub max_rows: usize,
}

impl BatchPolicy {
    pub fn stream() -> Self {
        Self { max_batch: 1, max_wait: Duration::ZERO, max_rows: 0 }
    }

    pub fn batched(max_batch: usize, max_wait: Duration) -> Self {
        assert!(max_batch >= 1);
        Self { max_batch, max_wait, max_rows: 0 }
    }

    /// Batched with a row budget: flush once the pending MC-sample rows
    /// reach `max_rows` (whichever of size / rows / deadline first).
    pub fn batched_rows(
        max_batch: usize,
        max_wait: Duration,
        max_rows: usize,
    ) -> Self {
        assert!(max_batch >= 1);
        Self { max_batch, max_wait, max_rows }
    }
}

/// A formed batch of request ids + payloads.
#[derive(Debug)]
pub struct Batch<T> {
    pub ids: Vec<u64>,
    pub items: Vec<T>,
}

/// Accumulates requests and decides when a batch is ready.
pub struct Batcher<T> {
    policy: BatchPolicy,
    pending_ids: Vec<u64>,
    pending: Vec<T>,
    /// Per-item weight (MC-sample rows), parallel to `pending`.
    weights: Vec<usize>,
    pending_rows: usize,
    oldest: Option<Instant>,
    // Occupancy counters (observability; never consulted by policy).
    formed: usize,
    peak_batch: usize,
    peak_pending_rows: usize,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        Self {
            policy,
            pending_ids: Vec::new(),
            pending: Vec::new(),
            weights: Vec::new(),
            pending_rows: 0,
            oldest: None,
            formed: 0,
            peak_batch: 0,
            peak_pending_rows: 0,
        }
    }

    pub fn push(&mut self, id: u64, item: T) {
        self.push_weighted(id, item, 1);
    }

    /// Queue an item carrying `rows` MC-sample rows of engine work
    /// (what a blocked call's cost actually scales with).
    pub fn push_weighted(&mut self, id: u64, item: T, rows: usize) {
        if self.pending.is_empty() {
            self.oldest = Some(Instant::now());
        }
        self.pending_ids.push(id);
        self.pending.push(item);
        self.weights.push(rows.max(1));
        self.pending_rows += rows.max(1);
        self.peak_pending_rows = self.peak_pending_rows.max(self.pending_rows);
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Pending MC-sample rows across all queued items.
    pub fn pending_rows(&self) -> usize {
        self.pending_rows
    }

    /// Batches formed so far (`take` calls).
    pub fn formed(&self) -> usize {
        self.formed
    }

    /// Largest batch ever taken (occupancy high-water, in items).
    pub fn peak_batch(&self) -> usize {
        self.peak_batch
    }

    /// Deepest the pending row backlog ever got.
    pub fn peak_pending_rows(&self) -> usize {
        self.peak_pending_rows
    }

    /// Is a batch ready under the policy? `queue_empty` signals that no
    /// more requests are immediately available (flush early rather than
    /// idle-wait — request latency beats batch efficiency on an
    /// interactive medical stream).
    pub fn ready(&self, queue_empty: bool) -> bool {
        if self.pending.is_empty() {
            return false;
        }
        if self.pending.len() >= self.policy.max_batch {
            return true;
        }
        if self.policy.max_rows > 0 && self.pending_rows >= self.policy.max_rows
        {
            return true;
        }
        if queue_empty {
            return true;
        }
        match self.oldest {
            Some(t0) => t0.elapsed() >= self.policy.max_wait,
            None => false,
        }
    }

    /// Take a batch: up to `max_batch` items and (if a row budget is
    /// set) at most `max_rows` total rows — but always at least one
    /// item, so an over-budget request still runs.
    pub fn take(&mut self) -> Batch<T> {
        let mut n = 0;
        let mut rows = 0;
        while n < self.pending.len() && n < self.policy.max_batch {
            let w = self.weights[n];
            if n > 0
                && self.policy.max_rows > 0
                && rows + w > self.policy.max_rows
            {
                break;
            }
            rows += w;
            n += 1;
        }
        let items: Vec<T> = self.pending.drain(..n).collect();
        let ids: Vec<u64> = self.pending_ids.drain(..n).collect();
        self.weights.drain(..n);
        self.pending_rows -= rows;
        self.formed += 1;
        self.peak_batch = self.peak_batch.max(n);
        if self.pending.is_empty() {
            self.oldest = None;
        } else {
            self.oldest = Some(Instant::now());
        }
        Batch { ids, items }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_policy_fires_immediately() {
        let mut b: Batcher<u32> = Batcher::new(BatchPolicy::stream());
        assert!(!b.ready(true));
        b.push(1, 10);
        assert!(b.ready(false));
        let batch = b.take();
        assert_eq!(batch.ids, vec![1]);
        assert!(b.is_empty());
    }

    #[test]
    fn size_trigger() {
        let mut b: Batcher<u32> =
            Batcher::new(BatchPolicy::batched(3, Duration::from_secs(10)));
        b.push(1, 0);
        b.push(2, 0);
        assert!(!b.ready(false), "below size, queue non-empty, no timeout");
        b.push(3, 0);
        assert!(b.ready(false));
        assert_eq!(b.take().ids, vec![1, 2, 3]);
    }

    #[test]
    fn empty_queue_flushes_partial_batch() {
        let mut b: Batcher<u32> =
            Batcher::new(BatchPolicy::batched(8, Duration::from_secs(10)));
        b.push(7, 0);
        assert!(b.ready(true), "flush rather than wait on an idle queue");
    }

    #[test]
    fn deadline_trigger() {
        let mut b: Batcher<u32> =
            Batcher::new(BatchPolicy::batched(8, Duration::from_millis(1)));
        b.push(1, 0);
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.ready(false));
    }

    /// Stream vs. size/timeout batching on the same backlog: stream
    /// drains one-by-one, batched drains in max_batch groups.
    #[test]
    fn stream_vs_batched_grouping() {
        let mut s: Batcher<u32> = Batcher::new(BatchPolicy::stream());
        for i in 0..4 {
            s.push(i, i as u32);
        }
        let mut sizes = Vec::new();
        while !s.is_empty() {
            assert!(s.ready(false), "stream is always ready with work");
            sizes.push(s.take().ids.len());
        }
        assert_eq!(sizes, vec![1, 1, 1, 1]);

        let mut b: Batcher<u32> =
            Batcher::new(BatchPolicy::batched(4, Duration::from_secs(1)));
        for i in 0..4 {
            b.push(i, i as u32);
        }
        assert!(b.ready(false));
        assert_eq!(b.take().ids.len(), 4);
        assert!(b.is_empty());
    }

    /// A partial batch holds for company while the queue is busy, then
    /// flushes at the deadline.
    #[test]
    fn batched_waits_for_company_until_deadline() {
        let mut b: Batcher<u32> =
            Batcher::new(BatchPolicy::batched(4, Duration::from_millis(20)));
        b.push(1, 0);
        assert!(!b.ready(false), "below size, before deadline, queue busy");
        std::thread::sleep(Duration::from_millis(30));
        assert!(b.ready(false), "deadline flush");
        assert_eq!(b.take().ids, vec![1]);
    }

    /// The row budget fires on total MC-sample rows and `take` splits
    /// at the budget boundary (never starving an over-budget item).
    #[test]
    fn row_budget_flushes_and_splits() {
        let mut b: Batcher<u32> = Batcher::new(BatchPolicy::batched_rows(
            8,
            Duration::from_secs(10),
            10,
        ));
        b.push_weighted(1, 0, 4);
        b.push_weighted(2, 0, 4);
        assert!(!b.ready(false), "8 rows under the 10-row budget");
        b.push_weighted(3, 0, 4);
        assert_eq!(b.pending_rows(), 12);
        assert!(b.ready(false), "12 rows over the 10-row budget");
        let batch = b.take();
        assert_eq!(batch.ids, vec![1, 2], "third item exceeds the budget");
        assert_eq!(b.pending_rows(), 4);
        assert_eq!(b.take().ids, vec![3]);

        // A single over-budget item still forms its own batch.
        let mut b: Batcher<u32> =
            Batcher::new(BatchPolicy::batched_rows(8, Duration::ZERO, 10));
        b.push_weighted(9, 0, 64);
        assert!(b.ready(false));
        assert_eq!(b.take().ids, vec![9]);
        assert_eq!(b.pending_rows(), 0);
    }

    /// Occupancy counters track formed batches, the size high-water and
    /// the deepest pending-row backlog, without influencing policy.
    #[test]
    fn occupancy_counters_track_peaks() {
        let mut b: Batcher<u32> =
            Batcher::new(BatchPolicy::batched(3, Duration::from_secs(10)));
        assert_eq!((b.formed(), b.peak_batch(), b.peak_pending_rows()), (0, 0, 0));
        b.push_weighted(1, 0, 4);
        b.push_weighted(2, 0, 8);
        assert_eq!(b.peak_pending_rows(), 12);
        assert_eq!(b.take().ids.len(), 2);
        assert_eq!((b.formed(), b.peak_batch()), (1, 2));
        b.push(3, 0);
        assert_eq!(b.take().ids.len(), 1);
        assert_eq!(b.formed(), 2);
        assert_eq!(b.peak_batch(), 2, "peak survives a smaller batch");
        assert_eq!(b.peak_pending_rows(), 12, "peak survives the drain");
    }

    #[test]
    fn take_respects_max_batch() {
        let mut b: Batcher<u32> =
            Batcher::new(BatchPolicy::batched(2, Duration::ZERO));
        for i in 0..5 {
            b.push(i, i as u32);
        }
        assert_eq!(b.take().ids, vec![0, 1]);
        assert_eq!(b.len(), 3);
        assert_eq!(b.take().ids, vec![2, 3]);
        assert_eq!(b.take().ids, vec![4]);
    }
}
