//! Dynamic batcher: groups queued requests into engine batches under a
//! size/deadline policy. The FPGA path uses batch 1 (the paper streams
//! each request as it arrives); the CPU/GPU baseline paths batch up to
//! the configured size the way PyTorch serving does.

use std::time::{Duration, Instant};

/// Batch-forming policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Max requests per batch (1 = stream-through).
    pub max_batch: usize,
    /// Max time the first queued request may wait for company.
    pub max_wait: Duration,
}

impl BatchPolicy {
    pub fn stream() -> Self {
        Self { max_batch: 1, max_wait: Duration::ZERO }
    }

    pub fn batched(max_batch: usize, max_wait: Duration) -> Self {
        assert!(max_batch >= 1);
        Self { max_batch, max_wait }
    }
}

/// A formed batch of request ids + payloads.
#[derive(Debug)]
pub struct Batch<T> {
    pub ids: Vec<u64>,
    pub items: Vec<T>,
}

/// Accumulates requests and decides when a batch is ready.
pub struct Batcher<T> {
    policy: BatchPolicy,
    pending_ids: Vec<u64>,
    pending: Vec<T>,
    oldest: Option<Instant>,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        Self {
            policy,
            pending_ids: Vec::new(),
            pending: Vec::new(),
            oldest: None,
        }
    }

    pub fn push(&mut self, id: u64, item: T) {
        if self.pending.is_empty() {
            self.oldest = Some(Instant::now());
        }
        self.pending_ids.push(id);
        self.pending.push(item);
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Is a batch ready under the policy? `queue_empty` signals that no
    /// more requests are immediately available (flush early rather than
    /// idle-wait — request latency beats batch efficiency on an
    /// interactive medical stream).
    pub fn ready(&self, queue_empty: bool) -> bool {
        if self.pending.is_empty() {
            return false;
        }
        if self.pending.len() >= self.policy.max_batch {
            return true;
        }
        if queue_empty {
            return true;
        }
        match self.oldest {
            Some(t0) => t0.elapsed() >= self.policy.max_wait,
            None => false,
        }
    }

    /// Take up to max_batch items as a batch.
    pub fn take(&mut self) -> Batch<T> {
        let n = self.pending.len().min(self.policy.max_batch);
        let items: Vec<T> = self.pending.drain(..n).collect();
        let ids: Vec<u64> = self.pending_ids.drain(..n).collect();
        if self.pending.is_empty() {
            self.oldest = None;
        } else {
            self.oldest = Some(Instant::now());
        }
        Batch { ids, items }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_policy_fires_immediately() {
        let mut b: Batcher<u32> = Batcher::new(BatchPolicy::stream());
        assert!(!b.ready(true));
        b.push(1, 10);
        assert!(b.ready(false));
        let batch = b.take();
        assert_eq!(batch.ids, vec![1]);
        assert!(b.is_empty());
    }

    #[test]
    fn size_trigger() {
        let mut b: Batcher<u32> =
            Batcher::new(BatchPolicy::batched(3, Duration::from_secs(10)));
        b.push(1, 0);
        b.push(2, 0);
        assert!(!b.ready(false), "below size, queue non-empty, no timeout");
        b.push(3, 0);
        assert!(b.ready(false));
        assert_eq!(b.take().ids, vec![1, 2, 3]);
    }

    #[test]
    fn empty_queue_flushes_partial_batch() {
        let mut b: Batcher<u32> =
            Batcher::new(BatchPolicy::batched(8, Duration::from_secs(10)));
        b.push(7, 0);
        assert!(b.ready(true), "flush rather than wait on an idle queue");
    }

    #[test]
    fn deadline_trigger() {
        let mut b: Batcher<u32> =
            Batcher::new(BatchPolicy::batched(8, Duration::from_millis(1)));
        b.push(1, 0);
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.ready(false));
    }

    /// Stream vs. size/timeout batching on the same backlog: stream
    /// drains one-by-one, batched drains in max_batch groups.
    #[test]
    fn stream_vs_batched_grouping() {
        let mut s: Batcher<u32> = Batcher::new(BatchPolicy::stream());
        for i in 0..4 {
            s.push(i, i as u32);
        }
        let mut sizes = Vec::new();
        while !s.is_empty() {
            assert!(s.ready(false), "stream is always ready with work");
            sizes.push(s.take().ids.len());
        }
        assert_eq!(sizes, vec![1, 1, 1, 1]);

        let mut b: Batcher<u32> =
            Batcher::new(BatchPolicy::batched(4, Duration::from_secs(1)));
        for i in 0..4 {
            b.push(i, i as u32);
        }
        assert!(b.ready(false));
        assert_eq!(b.take().ids.len(), 4);
        assert!(b.is_empty());
    }

    /// A partial batch holds for company while the queue is busy, then
    /// flushes at the deadline.
    #[test]
    fn batched_waits_for_company_until_deadline() {
        let mut b: Batcher<u32> =
            Batcher::new(BatchPolicy::batched(4, Duration::from_millis(20)));
        b.push(1, 0);
        assert!(!b.ready(false), "below size, before deadline, queue busy");
        std::thread::sleep(Duration::from_millis(30));
        assert!(b.ready(false), "deadline flush");
        assert_eq!(b.take().ids, vec![1]);
    }

    #[test]
    fn take_respects_max_batch() {
        let mut b: Batcher<u32> =
            Batcher::new(BatchPolicy::batched(2, Duration::ZERO));
        for i in 0..5 {
            b.push(i, i as u32);
        }
        assert_eq!(b.take().ids, vec![0, 1]);
        assert_eq!(b.len(), 3);
        assert_eq!(b.take().ids, vec![2, 3]);
        assert_eq!(b.take().ids, vec![4]);
    }
}
