//! Request placement across the engine fleet.
//!
//! Three policies (see `docs/serving.md`):
//!
//! * **round-robin** — stateless rotation; best when engines are
//!   homogeneous and requests are uniform.
//! * **least-loaded** — picks the engine with the fewest outstanding work
//!   items (queue-depth snapshot); absorbs heterogeneous engines (`mix`
//!   backends) and bursty arrivals.
//! * **mc-shard** — splits one request's S Monte-Carlo samples across all
//!   engines (the dimension Fan et al. and VIBNN parallelise across
//!   compute units); the coordinator merges the partial predictive
//!   distributions. Cuts per-request latency ~N× instead of raising
//!   request-level throughput.
//! * **affinity** — streaming sessions are pinned to the least-loaded
//!   engine at open time ([`Router::pin`]) and every chunk follows the
//!   pin, so the session's resident lane state never migrates and the
//!   per-engine FIFO serialises its chunks. Non-session requests fall
//!   back to round-robin.

/// Placement policy for the fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    RoundRobin,
    LeastLoaded,
    McShard,
    /// Session-affinity: chunks of one streaming session always land on
    /// the engine the session was pinned to at `open_session`.
    Affinity,
}

impl RouterPolicy {
    pub fn as_str(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "rr",
            RouterPolicy::LeastLoaded => "least-loaded",
            RouterPolicy::McShard => "mc-shard",
            RouterPolicy::Affinity => "affinity",
        }
    }
}

impl std::str::FromStr for RouterPolicy {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "rr" | "round-robin" => Ok(RouterPolicy::RoundRobin),
            "ll" | "least-loaded" => Ok(RouterPolicy::LeastLoaded),
            "mc-shard" | "mcshard" => Ok(RouterPolicy::McShard),
            "affinity" | "session-affinity" => Ok(RouterPolicy::Affinity),
            other => Err(format!(
                "unknown router {other:?} \
                 (rr | least-loaded | mc-shard | affinity)"
            )),
        }
    }
}

/// Stateful placement: owns the round-robin cursor and a per-engine
/// placement tally (an observability counter — how skewed did routing
/// actually come out, e.g. under least-loaded with mixed backends).
pub struct Router {
    policy: RouterPolicy,
    next: usize,
    placed: Vec<usize>,
}

impl Router {
    pub fn new(policy: RouterPolicy) -> Self {
        Self { policy, next: 0, placed: Vec::new() }
    }

    pub fn policy(&self) -> RouterPolicy {
        self.policy
    }

    /// Placement decisions per engine so far (index = engine). Grows
    /// lazily with the fleet width seen in `route` calls.
    pub fn placements(&self) -> &[usize] {
        &self.placed
    }

    /// Pick one engine for a whole request. `loads` is a snapshot of
    /// outstanding work items per engine (only consulted by
    /// least-loaded; ties break to the lowest index).
    pub fn route(&mut self, loads: &[usize]) -> usize {
        assert!(!loads.is_empty());
        let j = match self.policy {
            RouterPolicy::LeastLoaded => loads
                .iter()
                .enumerate()
                .min_by_key(|&(i, &l)| (l, i))
                .map(|(i, _)| i)
                .unwrap_or(0),
            _ => {
                let j = self.next % loads.len();
                self.next = self.next.wrapping_add(1);
                j
            }
        };
        if self.placed.len() < loads.len() {
            self.placed.resize(loads.len(), 0);
        }
        self.placed[j] += 1;
        j
    }

    /// Pin a new streaming session to an engine: the least-loaded one
    /// at open time (ties to the lowest index), regardless of policy.
    /// Chunks then follow the pin instead of re-routing, so resident
    /// lane state never migrates. Tallied like any placement.
    pub fn pin(&mut self, loads: &[usize]) -> usize {
        assert!(!loads.is_empty());
        let j = loads
            .iter()
            .enumerate()
            .min_by_key(|&(i, &l)| (l, i))
            .map(|(i, _)| i)
            .unwrap_or(0);
        if self.placed.len() < loads.len() {
            self.placed.resize(loads.len(), 0);
        }
        self.placed[j] += 1;
        j
    }

    /// Health-masked [`Router::route`]: skip engines whose worker died.
    /// With every engine healthy this makes exactly the decisions
    /// `route` would (same cursor advance, same tie-breaks), so the
    /// no-fault path is unchanged; returns `None` when no engine is
    /// healthy.
    pub fn route_healthy(
        &mut self,
        loads: &[usize],
        healthy: &[bool],
    ) -> Option<usize> {
        assert_eq!(loads.len(), healthy.len());
        let j = match self.policy {
            RouterPolicy::LeastLoaded => loads
                .iter()
                .enumerate()
                .filter(|&(i, _)| healthy[i])
                .min_by_key(|&(i, &l)| (l, i))
                .map(|(i, _)| i)?,
            _ => {
                let mut pick = None;
                // One full cursor revolution; a dead engine costs its
                // slot (the cursor still advances past it) so the
                // survivors keep their relative rotation.
                for _ in 0..loads.len() {
                    let j = self.next % loads.len();
                    self.next = self.next.wrapping_add(1);
                    if healthy[j] {
                        pick = Some(j);
                        break;
                    }
                }
                pick?
            }
        };
        if self.placed.len() < loads.len() {
            self.placed.resize(loads.len(), 0);
        }
        self.placed[j] += 1;
        Some(j)
    }

    /// Pick a surviving engine for re-dispatched or hedged work:
    /// least-loaded healthy engine, optionally excluding the shard's
    /// current home (a hedge on the engine it is stuck on is useless).
    /// Tallied like any placement; `None` when nobody qualifies.
    pub fn rescue(
        &mut self,
        loads: &[usize],
        healthy: &[bool],
        exclude: Option<usize>,
    ) -> Option<usize> {
        assert_eq!(loads.len(), healthy.len());
        let j = loads
            .iter()
            .enumerate()
            .filter(|&(i, _)| healthy[i] && Some(i) != exclude)
            .min_by_key(|&(i, &l)| (l, i))
            .map(|(i, _)| i)?;
        if self.placed.len() < loads.len() {
            self.placed.resize(loads.len(), 0);
        }
        self.placed[j] += 1;
        Some(j)
    }

    /// Split `s` MC samples over `n` engines: `(start, count)` per
    /// engine, contiguous, disjoint, covering `0..s`. The first `s % n`
    /// engines take one extra sample; with `s < n` the tail engines get
    /// zero-size shards (callers skip those).
    pub fn shards(&self, s: usize, n: usize) -> Vec<(usize, usize)> {
        let n = n.max(1);
        let base = s / n;
        let rem = s % n;
        let mut out = Vec::with_capacity(n);
        let mut start = 0;
        for j in 0..n {
            let count = base + usize::from(j < rem);
            out.push((start, count));
            start += count;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_policy_names() {
        assert_eq!("rr".parse::<RouterPolicy>(), Ok(RouterPolicy::RoundRobin));
        assert_eq!(
            "round-robin".parse::<RouterPolicy>(),
            Ok(RouterPolicy::RoundRobin)
        );
        assert_eq!(
            "least-loaded".parse::<RouterPolicy>(),
            Ok(RouterPolicy::LeastLoaded)
        );
        assert_eq!(
            "mc-shard".parse::<RouterPolicy>(),
            Ok(RouterPolicy::McShard)
        );
        assert_eq!(
            "affinity".parse::<RouterPolicy>(),
            Ok(RouterPolicy::Affinity)
        );
        assert_eq!(
            "session-affinity".parse::<RouterPolicy>(),
            Ok(RouterPolicy::Affinity)
        );
        assert!("banana".parse::<RouterPolicy>().is_err());
        assert_eq!(RouterPolicy::McShard.as_str(), "mc-shard");
        assert_eq!(RouterPolicy::Affinity.as_str(), "affinity");
    }

    #[test]
    fn affinity_pins_least_loaded_and_routes_rest_round_robin() {
        let mut r = Router::new(RouterPolicy::Affinity);
        assert_eq!(r.pin(&[3, 1, 2]), 1, "pin to least-loaded");
        assert_eq!(r.pin(&[2, 0, 0]), 1, "ties break to lowest index");
        // Non-session traffic under affinity cycles like round-robin.
        let loads = [0usize; 3];
        let picks: Vec<usize> = (0..4).map(|_| r.route(&loads)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0]);
        assert_eq!(r.placements().iter().sum::<usize>(), 6);
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RouterPolicy::RoundRobin);
        let loads = [0usize; 3];
        let picks: Vec<usize> = (0..7).map(|_| r.route(&loads)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn least_loaded_picks_min_with_low_index_ties() {
        let mut r = Router::new(RouterPolicy::LeastLoaded);
        assert_eq!(r.route(&[3, 1, 2]), 1);
        assert_eq!(r.route(&[2, 0, 0]), 1, "ties break to lowest index");
        assert_eq!(r.route(&[0, 0, 0]), 0);
    }

    #[test]
    fn shards_are_balanced_disjoint_and_cover() {
        let r = Router::new(RouterPolicy::McShard);
        for (s, n) in [(30usize, 4usize), (8, 3), (5, 5), (1, 4), (16, 1)] {
            let shards = r.shards(s, n);
            assert_eq!(shards.len(), n);
            let mut expect_start = 0;
            let mut total = 0;
            for &(start, count) in &shards {
                assert_eq!(start, expect_start, "s={s} n={n}");
                expect_start += count;
                total += count;
            }
            assert_eq!(total, s, "shards must cover all samples");
            let max = shards.iter().map(|&(_, c)| c).max().unwrap();
            let min = shards.iter().map(|&(_, c)| c).min().unwrap();
            assert!(max - min <= 1, "balanced to within one sample");
        }
    }

    #[test]
    fn placements_tally_every_route_call() {
        let mut r = Router::new(RouterPolicy::RoundRobin);
        assert!(r.placements().is_empty(), "no routing yet");
        let loads = [0usize; 3];
        for _ in 0..7 {
            r.route(&loads);
        }
        assert_eq!(r.placements(), &[3, 2, 2]);
        assert_eq!(r.placements().iter().sum::<usize>(), 7);

        let mut ll = Router::new(RouterPolicy::LeastLoaded);
        ll.route(&[5, 0]);
        ll.route(&[5, 1]);
        ll.route(&[0, 2]);
        assert_eq!(ll.placements(), &[1, 2]);
    }

    #[test]
    fn route_healthy_matches_route_when_all_alive() {
        let loads = [0usize; 3];
        let all = [true; 3];
        let mut plain = Router::new(RouterPolicy::RoundRobin);
        let mut masked = Router::new(RouterPolicy::RoundRobin);
        for _ in 0..7 {
            assert_eq!(
                Some(plain.route(&loads)),
                masked.route_healthy(&loads, &all)
            );
        }
        assert_eq!(plain.placements(), masked.placements());

        let mut ll = Router::new(RouterPolicy::LeastLoaded);
        assert_eq!(ll.route_healthy(&[3, 1, 2], &all), Some(1));
    }

    #[test]
    fn route_healthy_skips_dead_engines() {
        let loads = [0usize; 3];
        let mut r = Router::new(RouterPolicy::RoundRobin);
        let healthy = [true, false, true];
        let picks: Vec<_> = (0..4)
            .map(|_| r.route_healthy(&loads, &healthy).unwrap())
            .collect();
        assert_eq!(picks, vec![0, 2, 0, 2], "dead slot is skipped");

        let mut ll = Router::new(RouterPolicy::LeastLoaded);
        assert_eq!(
            ll.route_healthy(&[0, 5, 9], &[false, true, true]),
            Some(1),
            "least-loaded among the living"
        );
        assert_eq!(
            ll.route_healthy(&loads, &[false; 3]),
            None,
            "no healthy engine"
        );
    }

    #[test]
    fn rescue_prefers_least_loaded_survivor_and_honours_exclude() {
        let mut r = Router::new(RouterPolicy::McShard);
        assert_eq!(
            r.rescue(&[4, 1, 2], &[true, true, true], None),
            Some(1)
        );
        assert_eq!(
            r.rescue(&[4, 1, 2], &[true, true, true], Some(1)),
            Some(2),
            "home engine excluded for hedging"
        );
        assert_eq!(
            r.rescue(&[4, 1, 2], &[false, true, false], Some(1)),
            None,
            "only the excluded engine survives"
        );
        assert_eq!(r.placements().iter().sum::<usize>(), 2);
    }

    #[test]
    fn small_s_leaves_empty_tail_shards() {
        let r = Router::new(RouterPolicy::McShard);
        let shards = r.shards(2, 4);
        assert_eq!(shards, vec![(0, 1), (1, 1), (2, 0), (2, 0)]);
    }
}
