//! Sharded multi-engine serving: N engine workers (any mix of FPGA-sim /
//! GPU-model / PJRT backends), each with its own bounded queue and
//! batcher, behind a [`Router`] — the fleet-scale layer the single-engine
//! [`super::server::Server`] cannot reach.
//!
//! Placement (see `docs/serving.md`):
//! * `rr` / `least-loaded` — whole requests go to one engine.
//! * `mc-shard` — a request's S Monte-Carlo samples are split across all
//!   engines; each returns partial moment sums
//!   ([`PartialPrediction`]) and the coordinator reduces them with
//!   [`crate::metrics::pooled_mean_std`]. Because every sample's dropout
//!   masks are seeded by `mix3(engine_seed, request_seed, sample_index)`,
//!   the merged prediction is invariant to the engine count (same seed ⇒
//!   same samples, any N).
//!
//! Admission control: with `shed = true`, a full worker queue rejects the
//! request immediately (counted in [`FleetSummary::rejected`]) instead of
//! exerting backpressure on the producer — the "fail fast under overload"
//! posture of a production serving tier.
//!
//! Threading mirrors `server.rs`: std::thread + mpsc, engines built
//! inside their worker threads from `Send` factories (PJRT handles are
//! not `Send`). Usage: `submit` all → `wait` each ticket → `join`.
//!
//! Adaptive requests are driven by a dedicated **adaptive coordinator
//! thread**: workers stream raw sample blocks to it, it runs each
//! request's stopping-rule controller and dispatches follow-up sampling
//! rounds the moment a round completes. `wait_adaptive` only collects
//! the finished response — so multi-round requests make progress
//! concurrently, whatever order the caller waits in (previously rounds
//! were driven from the waiter thread, serialising them head-of-line in
//! submit-all-then-wait loops and inflating later requests' e2e;
//! ROADMAP PR 3 review finding a). Request e2e is stamped by the
//! coordinator at completion time, not at `wait` time.
//!
//! **Streaming sessions** ([`Fleet::open_session`] / `submit_chunk` /
//! `close_session`, `docs/serving.md` §Streaming sessions): long-lived
//! signals keep their MC lane state resident in a byte-budgeted
//! [`SessionTable`] between chunks, so each decision costs O(chunk)
//! instead of O(history). Chunks follow the session's pinned engine
//! (affinity — the engine FIFO serialises them) or split into disjoint
//! lane ranges under mc-shard; either way the merged per-beat samples
//! are bit-identical to one continuous single-engine pass, with or
//! without evictions, because lane state is a pure function of
//! `(design, session, consumed signal, lane)`.
//!
//! **Fault tolerance** (`docs/serving.md` §Fault tolerance): every
//! fixed/stream shard is tracked in an outstanding-shard table until
//! its reply lands. Worker death (a panic — injected by the
//! [`super::chaos`] harness or genuine) is detected through an
//! obituary channel plus send-failure, the engine is marked unhealthy
//! so routing skips it, and its queued + in-flight shards are
//! re-dispatched to survivors. Because per-`(request, sample)` mask
//! seeding makes a shard a pure function of `(request seed, start,
//! count)`, re-execution on any engine is bit-identical — merged
//! outputs are unchanged by faults. Shards overdue against the
//! windowed latency profile are hedged (speculatively re-executed,
//! first reply wins, duplicates deduped by shard start), and when no
//! engine can serve, `wait`/`wait_chunk` return a typed
//! [`FleetError::Degraded`] instead of hanging.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{Batcher, BatchPolicy};
use super::chaos::{ChaosKill, FaultPlan, WorkerChaos};
use super::engines::{
    Engine, PartialPrediction, Prediction, SampleBlock, ShardRequest,
};
use super::router::{Router, RouterPolicy};
use super::server::ServeSummary;
use super::session::{
    Resume, SessionError, SessionMeta, SessionStats, SessionTable,
};
use super::stats::LatencyStats;
use crate::fpga::McOutput;
use crate::kernels::MaskBankStats;
use crate::metrics::pooled_mean_std;
use crate::obs::{
    window_index, EngineLoad, FaultCounters, FaultStats, LogHistogram,
    McCounters, ObsConfig, Sampler, StageStats, Timeline, WindowedCount,
    WindowedHist, WorkerTimeline,
};
use crate::uq::controller::{
    stream_should_boost, AdaptiveController, AdaptiveMcConfig, McDecision,
};

/// Fleet configuration.
pub struct FleetConfig {
    /// Engine workers to spawn (one thread + bounded queue each).
    pub engines: usize,
    /// Placement policy.
    pub router: RouterPolicy,
    /// Batch policy applied by every worker's batcher.
    pub policy: BatchPolicy,
    /// Per-engine queue depth before a submit blocks (or sheds).
    pub queue_depth: usize,
    /// Queue-full behaviour: `true` rejects instead of blocking.
    pub shed: bool,
    /// MC samples per request.
    pub samples: usize,
    /// Observability switches (stage timing, histograms, optional
    /// JSONL tracing). Off by default; when off, serve outputs are
    /// bit-identical to a fleet without the observability layer.
    pub obs: ObsConfig,
    /// Resident streaming-session lane-state budget in bytes (the
    /// CLI's `--session-mb`, scaled). `None` disables the session
    /// plane entirely: no table is created and serve outputs stay
    /// byte-identical to a session-less fleet.
    pub session_bytes: Option<usize>,
    /// Rebuild evicted session lane state transparently by history
    /// replay (`true`) or reject the chunk with a typed error.
    pub session_replay: bool,
    /// Optional adaptive streaming tier: a chunk whose base-budget CI
    /// half-width exceeds `target_ci` is recomputed at `s_max` lanes
    /// by replay (affinity placement only — a lane shard cannot judge
    /// the pooled CI).
    pub session_uq: Option<AdaptiveMcConfig>,
    /// Deterministic fault-injection plan (`--chaos`). `None` (and the
    /// empty plan) injects nothing; straggler hedging only arms when a
    /// non-empty plan is configured, so an un-chaosed fleet's behaviour
    /// is untouched.
    pub chaos: Option<FaultPlan>,
    /// Upper bound on `wait`/`wait_chunk`/`wait_adaptive`
    /// (`--wait-timeout-ms`). `None` keeps the long 120 s backstop;
    /// setting it surfaces lost replies as [`FleetError::Degraded`]
    /// promptly instead of blocking.
    pub wait_timeout: Option<Duration>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            engines: 1,
            router: RouterPolicy::RoundRobin,
            policy: BatchPolicy::stream(),
            queue_depth: super::DEFAULT_QUEUE_DEPTH,
            shed: false,
            samples: 1,
            obs: ObsConfig::default(),
            session_bytes: None,
            session_replay: true,
            session_uq: None,
            chaos: None,
            wait_timeout: None,
        }
    }
}

/// Default backstop for `wait`/`wait_chunk` when no `--wait-timeout-ms`
/// is configured (the pre-fault-tolerance hang bound).
const DEFAULT_WAIT: Duration = Duration::from_secs(120);

/// Poll interval of the wait loops: between replies the waiter wakes
/// this often to process worker obituaries (re-dispatching orphans) and
/// check hedging deadlines.
const PROBE: Duration = Duration::from_millis(20);

/// Straggler deadline = windowed e2e p99 × this factor…
const HEDGE_FACTOR: f64 = 4.0;

/// …floored here (ms), so an empty latency profile (first requests)
/// doesn't hedge everything instantly.
const HEDGE_MIN_MS: f64 = 25.0;

/// A typed fleet-level wait failure. `Degraded` is the load-bearing
/// variant: the fleet kept serving what it could but this response is
/// incomplete (worker death with no survivor to re-dispatch to, or
/// chaos-dropped replies) — the caller gets an honest typed outcome
/// instead of an indefinite block, per the paper's degraded-but-honest
/// serving posture.
#[derive(Debug, Clone, PartialEq)]
pub enum FleetError {
    /// Not every shard reply arrived before the wait deadline.
    Degraded {
        /// Request id (or session id for `wait_chunk`).
        id: u64,
        /// Shard replies that did arrive.
        got: usize,
        /// Shard replies expected.
        expected: usize,
        /// How long the waiter watched before giving up.
        waited_ms: f64,
    },
    /// An engine reported a shard failure (bad artifact, engine error).
    Engine { id: u64, msg: String },
}

impl std::fmt::Display for FleetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FleetError::Degraded { id, got, expected, waited_ms } => {
                write!(
                    f,
                    "request {id} degraded: {got}/{expected} shard \
                     replies after {waited_ms:.0} ms"
                )
            }
            FleetError::Engine { id, msg } => {
                write!(f, "request {id}: engine failed: {msg}")
            }
        }
    }
}

impl std::error::Error for FleetError {}

/// Where a worker sends one shard's outcome: the fixed path pre-reduces
/// the shard to moment sums and replies on the request's own channel;
/// the adaptive path forwards the raw sample block to the fleet's
/// adaptive coordinator thread (which needs individual samples for
/// order-stable reduction and the epistemic decomposition).
#[derive(Clone)]
enum ReplySink {
    Fixed(mpsc::Sender<Result<PartialPrediction, String>>),
    Adaptive(mpsc::Sender<AdaptiveEvent>, u64),
    Stream(mpsc::Sender<Result<StreamBlock, String>>),
}

/// One unit of engine work: a whole request (`start = 0, count = S`) or
/// one MC shard of it. `Clone` exists for the fault-tolerance paths
/// only (outstanding-shard tracking, re-dispatch, hedging) — the
/// payload is behind an `Arc` and the sinks are channel senders, so a
/// clone re-executes the *same* shard against the *same* reply channel.
#[derive(Clone)]
struct WorkItem {
    beat: Arc<Vec<f32>>,
    req_seed: u64,
    start: usize,
    count: usize,
    enqueued: Instant,
    /// When this round was dispatched onto the engine queue. Distinct
    /// from `enqueued` (request arrival): adaptive continuation rounds
    /// reuse the request-level `enqueued`, so queue-stage timing must
    /// not conflate a later round's channel wait with the whole
    /// request's age.
    sent: Instant,
    /// When the worker pulled the item off its queue (stamped only with
    /// observability enabled; queue stage = `sent → pulled`, batch
    /// stage = `pulled → dispatch`).
    pulled: Option<Instant>,
    /// Shard outcome destination (errors are stringified so the worker
    /// keeps running and the waiter can surface them).
    sink: ReplySink,
    /// Present on streaming-session chunks: identifies the session and
    /// how much history precedes this chunk, so the worker can resume
    /// (or replay-rebuild) the right lane state. Stream items bypass
    /// the batcher — the session's pinned-engine FIFO already
    /// serialises its chunks.
    stream: Option<StreamJob>,
}

/// Session routing metadata riding on a streaming chunk's `WorkItem`.
#[derive(Clone)]
struct StreamJob {
    sid: u64,
    /// History length (in f32 values) *before* this chunk was appended
    /// — the replay prefix needed to rebuild evicted lane state.
    history_end: usize,
}

/// One engine's (or lane shard's) outcome for one streaming chunk.
struct StreamBlock {
    start: usize,
    beats: Vec<McOutput>,
    model_latency_ms: f64,
    boosted: bool,
}

/// Handle for one in-flight streaming chunk: pass it back to
/// [`Fleet::wait_chunk`] to collect the decisions (merging lane shards
/// under mc-shard routing).
pub struct ChunkTicket {
    pub sid: u64,
    /// Session seed (= shard-table request key for this chunk's items).
    seed: u64,
    /// History length before this chunk — disambiguates this chunk's
    /// shard-table entries from other in-flight chunks of the session.
    history_end: usize,
    enqueued: Instant,
    expected: usize,
    rx: mpsc::Receiver<Result<StreamBlock, String>>,
}

/// The decisions one streaming chunk produced: one [`McOutput`] per
/// completed beat (possibly none, if the chunk didn't cross a beat
/// boundary — state still advanced).
pub struct ChunkResponse {
    pub sid: u64,
    pub beats: Vec<McOutput>,
    /// `true` if the adaptive tier re-ran this chunk at `s_max` lanes.
    pub boosted: bool,
    pub e2e_ms: f64,
    pub model_latency_ms: f64,
}

/// Handle for one in-flight request: hold it, then pass it back to
/// [`Fleet::wait`] to collect (and, for MC-shard, reduce) the response.
pub struct Ticket {
    pub id: u64,
    enqueued: Instant,
    expected: usize,
    total_s: usize,
    rx: mpsc::Receiver<Result<PartialPrediction, String>>,
}

/// Handle for one in-flight *adaptive* request
/// ([`Fleet::submit_adaptive`]): the coordinator thread drives the
/// sampling rounds; the ticket only receives the finished response.
pub struct AdaptiveTicket {
    pub id: u64,
    /// Wait bound scaled by the envelope's worst-case round count, so a
    /// long-but-healthy multi-round request is at least as patient as
    /// the old per-shard-per-round timeout was.
    timeout: Duration,
    rx: mpsc::Receiver<Result<AdaptiveResponse, String>>,
}

/// Events feeding the adaptive coordinator thread. `Submit` always
/// precedes any of its request's `Shard`s (sent before the first round
/// is dispatched); `Started` / `Cancelled` resolve the first round's
/// shard count after dispatch (admission control may shed mid-round,
/// leaving `stray` already-enqueued shards to swallow).
enum AdaptiveEvent {
    Submit {
        id: u64,
        beat: Arc<Vec<f32>>,
        req_seed: u64,
        mc: AdaptiveMcConfig,
        enqueued: Instant,
        done: mpsc::Sender<Result<AdaptiveResponse, String>>,
    },
    Started {
        id: u64,
        outstanding: usize,
    },
    Cancelled {
        id: u64,
        stray: usize,
    },
    Shard {
        id: u64,
        block: Result<SampleBlock, String>,
    },
    Shutdown,
}

/// A completed adaptive request.
pub struct AdaptiveResponse {
    pub id: u64,
    pub prediction: Prediction,
    /// Raw MC samples in ascending-`k` order, `[s_used][out_len]`.
    pub samples: Vec<f32>,
    pub out_len: usize,
    /// Samples actually drawn (`<= s_max`).
    pub s_used: usize,
    /// `true` if the CI stopping rule fired before `s_max`.
    pub converged: bool,
    /// Sequential sampling rounds the request took.
    pub rounds: usize,
    pub e2e_ms: f64,
    /// Wall time of the final MC-merge (ordered reduction +
    /// finalisation) on the coordinator thread, in microseconds.
    pub merge_us: f64,
    /// When the coordinator finalised the request — the timeline
    /// window the completion belongs to (a late `wait_adaptive` must
    /// not attribute it to the window the waiter ran in).
    pub completed_at: Instant,
}

/// A completed fleet request.
pub struct FleetResponse {
    pub id: u64,
    pub prediction: Prediction,
    /// Queue + service + reduction latency observed by the coordinator.
    pub e2e_ms: f64,
    /// Engine shards that contributed (1 unless MC-shard).
    pub shards: usize,
}

/// Fleet-level observability aggregates carried in [`FleetSummary`]
/// (populated only with [`ObsConfig::enabled`]; the health counters —
/// MC accounting, placements — are always-on, they are too cheap to
/// gate).
#[derive(Debug, Clone, Default)]
pub struct FleetObs {
    /// Whether stage timing / histograms were collected.
    pub enabled: bool,
    /// Request end-to-end latency (log-bucketed, mergeable).
    pub e2e: LogHistogram,
    /// MC-merge (reduction) stage latency.
    pub merge: LogHistogram,
    /// MC samples drawn across all served requests.
    pub mc_spent: usize,
    /// MC samples the adaptive controller's early exit avoided.
    pub mc_saved: usize,
    /// Submit-path placement decisions per engine (the adaptive
    /// coordinator's continuation rounds route on its own thread-owned
    /// cursor and are not tallied here).
    pub placements: Vec<usize>,
    /// Trace events lost to write failures (0 without `--trace`; a
    /// non-zero value means the trace file is incomplete).
    pub trace_dropped: u64,
    /// Mask-bank counters at join time (`--mask-bank-mb`). The bank is
    /// owned by the CLI and shared into the engines via
    /// [`Engine::set_mask_bank`]; the fleet never sees it, so this is
    /// `None` unless the caller stamps the stats after `join`.
    pub mask_bank: Option<MaskBankStats>,
    /// Streaming-session counters at join time (`None` when the
    /// session plane is disabled). Stamped by `join` itself — the
    /// fleet owns the table, unlike the mask bank.
    pub sessions: Option<SessionStats>,
    /// Fault-tolerance accounting (always stamped; all-zero on a clean
    /// run — [`FaultStats::any`] gates the conditional JSON block).
    pub faults: FaultStats,
}

/// Aggregate + per-engine serving stats, returned by [`Fleet::join`].
#[derive(Debug, Clone)]
pub struct FleetSummary {
    /// Requests fully served (all shards reduced).
    pub served: usize,
    /// Requests rejected by admission control.
    pub rejected: usize,
    pub wall: Duration,
    /// Request-level end-to-end latency (recorded at reduction time).
    pub e2e: LatencyStats,
    /// Per-engine summaries (`served` there counts work *items*, i.e.
    /// shards — an MC-shard request contributes to several engines).
    pub per_engine: Vec<ServeSummary>,
    /// Fleet-level observability aggregates.
    pub obs: FleetObs,
    /// Windowed time-series of the run (`ObsConfig::window`): per-window
    /// e2e/stage histograms, request counters and gauge samples, merged
    /// across workers at join. `None` without windowed observability.
    pub timeline: Option<Timeline>,
}

impl FleetSummary {
    /// Served requests per second over the fleet wall-clock.
    pub fn throughput(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.served as f64 / self.wall.as_secs_f64()
    }

    /// Engine-model latency merged across all engines.
    pub fn engine_stats(&self) -> LatencyStats {
        let mut all = LatencyStats::new();
        for e in &self.per_engine {
            all.merge(&e.engine);
        }
        all
    }

    /// Total work items (shards) completed across engines.
    pub fn items(&self) -> usize {
        self.per_engine.iter().map(|e| e.served).sum()
    }

    /// Total batches formed across engines.
    pub fn batches(&self) -> usize {
        self.per_engine.iter().map(|e| e.batches).sum()
    }

    /// Per-stage latency merged across all engines (exact associative
    /// histogram merge — fleet tails, not averaged per-engine tails).
    /// Empty unless the fleet ran with observability enabled.
    pub fn stage_stats(&self) -> StageStats {
        let mut all = StageStats::default();
        for e in &self.per_engine {
            if let Some(st) = &e.stages {
                all.merge(st);
            }
        }
        all
    }
}

/// Fleet-side windowed timeline state: the shared epoch, the window
/// streams only the submit/wait paths can record (request-level
/// counters and e2e) and the background gauge sampler. Worker-side
/// streams live in each worker's [`WorkerTimeline`] and merge in at
/// join.
struct FleetWindows {
    epoch: Instant,
    width: Duration,
    e2e: WindowedHist,
    submitted: WindowedCount,
    served: WindowedCount,
    rejected: WindowedCount,
    sampler: Option<Sampler>,
}

/// Identity of one tracked shard: `(request key, chunk disambiguator,
/// shard start)`. The request key is the request id on the fixed path
/// and the session seed (= sid) on the stream path; the disambiguator
/// is `history_end + 1` for stream chunks and 0 for fixed requests, so
/// the two key spaces cannot collide and pipelined chunks of one
/// session stay distinct.
type ShardKey = (u64, u64, usize);

/// One dispatched-but-unreplied shard. The cloned `WorkItem` (payload
/// behind an `Arc`, sink a channel sender) is everything needed to
/// re-execute the shard bit-identically on any engine.
struct PendingShard {
    engine: usize,
    item: WorkItem,
    dispatched: Instant,
    hedged: bool,
}

/// The outstanding-shard table: inserted before dispatch, re-targeted
/// on re-dispatch, removed by the executing worker just before it
/// replies. Uncontended in steady state (one lock per shard hop).
type ShardTable = Mutex<HashMap<ShardKey, PendingShard>>;

/// Flip an engine dead exactly once, whichever path noticed first (the
/// obituary channel, a failed send, or join's panic catch).
fn mark_dead(health: &[AtomicBool], faults: &FaultCounters, i: usize) {
    if health[i].swap(false, Ordering::AcqRel) {
        faults.worker_lost();
    }
}

/// Worker death notice: armed at spawn, disarmed on clean exit, so an
/// unwinding panic — chaos-injected or genuine — reports the engine
/// index on the fleet's obituary channel as the thread dies.
struct Obituary {
    idx: usize,
    tx: mpsc::Sender<usize>,
    armed: bool,
}

impl Drop for Obituary {
    fn drop(&mut self) {
        if self.armed {
            let _ = self.tx.send(self.idx);
        }
    }
}

/// Fault-tolerance state threaded into each worker.
struct WorkerCtx {
    chaos: WorkerChaos,
    epoch: Instant,
    faults: Arc<FaultCounters>,
    outstanding: Arc<ShardTable>,
}

/// The sharded serving fleet.
pub struct Fleet {
    txs: Vec<mpsc::SyncSender<WorkItem>>,
    loads: Vec<Arc<EngineLoad>>,
    workers: Vec<thread::JoinHandle<ServeSummary>>,
    adaptive_tx: mpsc::Sender<AdaptiveEvent>,
    adaptive_coord: Option<thread::JoinHandle<()>>,
    router: Router,
    samples: usize,
    shed: bool,
    next_id: u64,
    rejected: usize,
    served: usize,
    e2e: LatencyStats,
    t0: Instant,
    obs: ObsConfig,
    e2e_hist: LogHistogram,
    merge_hist: LogHistogram,
    mc: Arc<McCounters>,
    win: Option<FleetWindows>,
    /// Streaming-session plane (`None` unless `session_bytes` was set).
    sessions: Option<Arc<SessionTable>>,
    next_sid: u64,
    /// Per-engine liveness (flipped false on worker death, never back).
    health: Arc<Vec<AtomicBool>>,
    /// Fault-tolerance accounting, shared with workers + coordinator.
    faults: Arc<FaultCounters>,
    /// Dispatched-but-unreplied fixed/stream shards (re-dispatch and
    /// hedging source of truth).
    outstanding: Arc<ShardTable>,
    /// Worker obituaries (engine index per death), drained by
    /// [`Fleet::supervise`].
    deaths_rx: mpsc::Receiver<usize>,
    /// `true` when a non-empty chaos plan is configured: arms straggler
    /// hedging (never armed on a clean fleet — zero behaviour change).
    chaos_armed: bool,
    /// Caller-configured wait bound (`--wait-timeout-ms`).
    wait_timeout: Option<Duration>,
}

impl Fleet {
    /// Spawn one worker thread per factory. All engines must share the
    /// same design seed for MC-shard determinism (the CLI and tests do).
    pub fn start(
        cfg: FleetConfig,
        factories: Vec<Box<dyn FnOnce() -> Engine + Send + 'static>>,
    ) -> Self {
        assert!(cfg.engines >= 1, "fleet needs at least one engine");
        assert_eq!(
            factories.len(),
            cfg.engines,
            "one factory per engine"
        );
        assert!(cfg.samples >= 1, "S must be positive");
        // The timeline epoch: window 0 of every stream (worker stages,
        // submit/wait counters, gauge sampler, loadgen offered load)
        // starts here, so per-window merges align across threads.
        let epoch = Instant::now();
        let worker_win = if cfg.obs.enabled {
            cfg.obs.window.map(|width| (epoch, width))
        } else {
            None
        };
        let mc = Arc::new(McCounters::new());
        let sessions = cfg
            .session_bytes
            .map(|b| Arc::new(SessionTable::new(b, cfg.session_replay)));
        let plan = cfg.chaos.clone().unwrap_or_default();
        let chaos_armed = !plan.is_empty();
        let health: Arc<Vec<AtomicBool>> = Arc::new(
            (0..cfg.engines).map(|_| AtomicBool::new(true)).collect(),
        );
        let faults = Arc::new(FaultCounters::new());
        let outstanding: Arc<ShardTable> =
            Arc::new(Mutex::new(HashMap::new()));
        let (deaths_tx, deaths_rx) = mpsc::channel::<usize>();
        let mut txs = Vec::with_capacity(cfg.engines);
        let mut loads = Vec::with_capacity(cfg.engines);
        let mut workers = Vec::with_capacity(cfg.engines);
        for (idx, factory) in factories.into_iter().enumerate() {
            let (tx, rx) = mpsc::sync_channel::<WorkItem>(cfg.queue_depth);
            let load = Arc::new(EngineLoad::new());
            let worker_load = Arc::clone(&load);
            let policy = cfg.policy;
            let worker_obs = cfg.obs.clone();
            let worker_sessions = sessions.clone();
            let worker_uq = cfg.session_uq;
            let ctx = WorkerCtx {
                chaos: plan.for_engine(idx),
                epoch,
                faults: Arc::clone(&faults),
                outstanding: Arc::clone(&outstanding),
            };
            let obit_tx = deaths_tx.clone();
            workers.push(thread::spawn(move || {
                let mut obituary =
                    Obituary { idx, tx: obit_tx, armed: true };
                let summary = worker_loop(
                    factory, rx, policy, worker_load, idx, worker_obs,
                    worker_win, worker_sessions, worker_uq, ctx,
                );
                obituary.armed = false;
                summary
            }));
            txs.push(tx);
            loads.push(load);
        }
        let win = worker_win.map(|(epoch, width)| FleetWindows {
            epoch,
            width,
            e2e: WindowedHist::new(),
            submitted: WindowedCount::new(),
            served: WindowedCount::new(),
            rejected: WindowedCount::new(),
            sampler: Some(Sampler::spawn(epoch, width, loads.clone())),
        });
        // The adaptive coordinator: owns its own router cursor and
        // worker-queue senders so it can place continuation rounds
        // without the submitting thread.
        let (adaptive_tx, adaptive_rx) = mpsc::channel::<AdaptiveEvent>();
        let coord_txs = txs.clone();
        let coord_loads = loads.clone();
        let coord_self_tx = adaptive_tx.clone();
        let coord_router = Router::new(cfg.router);
        let coord_mc = Arc::clone(&mc);
        let coord_health = Arc::clone(&health);
        let coord_faults = Arc::clone(&faults);
        let coord_outstanding = Arc::clone(&outstanding);
        let adaptive_coord = thread::spawn(move || {
            adaptive_coordinator(
                adaptive_rx,
                coord_self_tx,
                coord_txs,
                coord_loads,
                coord_router,
                coord_mc,
                coord_health,
                coord_faults,
                coord_outstanding,
            )
        });
        Self {
            txs,
            loads,
            workers,
            adaptive_tx,
            adaptive_coord: Some(adaptive_coord),
            router: Router::new(cfg.router),
            samples: cfg.samples,
            shed: cfg.shed,
            next_id: 0,
            rejected: 0,
            served: 0,
            e2e: LatencyStats::new(),
            t0: epoch,
            obs: cfg.obs,
            e2e_hist: LogHistogram::new(),
            merge_hist: LogHistogram::new(),
            mc,
            win,
            sessions,
            next_sid: 0,
            health,
            faults,
            outstanding,
            deaths_rx,
            chaos_armed,
            wait_timeout: cfg.wait_timeout,
        }
    }

    pub fn engines(&self) -> usize {
        self.txs.len()
    }

    /// Timeline window parameters when windowed observability is on.
    /// The open-loop load generator aligns its offered-load windows to
    /// the fleet's epoch through this.
    pub fn obs_window(&self) -> Option<(Instant, Duration)> {
        self.win.as_ref().map(|w| (w.epoch, w.width))
    }

    /// Fault-tolerance counters so far (also stamped into
    /// [`FleetObs::faults`] at join).
    pub fn fault_stats(&self) -> FaultStats {
        self.faults.snapshot()
    }

    /// Engines whose workers are still alive.
    pub fn healthy_engines(&self) -> usize {
        self.health
            .iter()
            .filter(|h| h.load(Ordering::Acquire))
            .count()
    }

    fn health_snapshot(&self) -> Vec<bool> {
        self.health.iter().map(|h| h.load(Ordering::Acquire)).collect()
    }

    fn load_snapshot(&self) -> Vec<usize> {
        self.loads.iter().map(|l| l.outstanding()).collect()
    }

    /// Process worker deaths: drain the obituary channel, flip health,
    /// and re-dispatch every outstanding shard stranded on a dead
    /// engine to a survivor. Called from the submit paths and from the
    /// wait loops' probe ticks, so orphans recover while the caller is
    /// still waiting. Deterministic per-`(request, sample)` mask
    /// seeding makes the re-executed shard bit-identical wherever it
    /// lands.
    fn supervise(&mut self) {
        let mut observed_death = false;
        while let Ok(idx) = self.deaths_rx.try_recv() {
            mark_dead(&self.health, &self.faults, idx);
            observed_death = true;
        }
        if observed_death {
            self.redispatch_orphans();
        }
    }

    fn redispatch_orphans(&mut self) {
        let healthy = self.health_snapshot();
        // Clone victims under the lock, send outside it (workers take
        // the same lock on every reply).
        let victims: Vec<(ShardKey, WorkItem)> = {
            let tab = self.outstanding.lock().expect("shard table");
            tab.iter()
                .filter(|(_, p)| !healthy[p.engine])
                .map(|(k, p)| (*k, p.item.clone()))
                .collect()
        };
        for (key, mut item) in victims {
            let load_snapshot = self.load_snapshot();
            let Some(j) =
                self.router.rescue(&load_snapshot, &healthy, None)
            else {
                // No survivor: the wait deadline surfaces this as
                // `FleetError::Degraded`.
                return;
            };
            // Queue timing restarts at the re-dispatch; the request's
            // e2e clock (`enqueued`) keeps running — the fault's cost
            // stays visible in e2e.
            item.sent = Instant::now();
            self.loads[j].inc();
            if self.txs[j].send(item).is_err() {
                self.loads[j].dec();
                mark_dead(&self.health, &self.faults, j);
                continue;
            }
            let mut tab = self.outstanding.lock().expect("shard table");
            if let Some(p) = tab.get_mut(&key) {
                p.engine = j;
                p.dispatched = Instant::now();
            }
            self.faults.shard_redispatched();
        }
    }

    /// Hedge fixed-path shards of request `id` that are overdue against
    /// the observed latency profile (e2e p99 × [`HEDGE_FACTOR`],
    /// floored): speculatively re-execute on the least-loaded survivor
    /// *other than* the shard's home. First reply wins at the waiter
    /// (dedup by shard start); `hedged` records each hedged shard's
    /// home engine so a hedge win can be attributed.
    fn hedge_overdue(
        &mut self,
        id: u64,
        hedged: &mut HashMap<usize, usize>,
    ) {
        let deadline_ms = (self.e2e.percentile_ms(99.0) * HEDGE_FACTOR)
            .max(HEDGE_MIN_MS);
        let healthy = self.health_snapshot();
        let mut victims: Vec<(usize, WorkItem, usize)> = Vec::new();
        {
            let mut tab = self.outstanding.lock().expect("shard table");
            for (&(req, aux, start), p) in tab.iter_mut() {
                if req == id
                    && aux == 0
                    && !p.hedged
                    && p.dispatched.elapsed().as_secs_f64() * 1e3
                        > deadline_ms
                {
                    p.hedged = true;
                    victims.push((start, p.item.clone(), p.engine));
                }
            }
        }
        for (start, mut item, home) in victims {
            let load_snapshot = self.load_snapshot();
            let Some(j) =
                self.router.rescue(&load_snapshot, &healthy, Some(home))
            else {
                continue; // nowhere to hedge to
            };
            item.sent = Instant::now();
            self.loads[j].inc();
            if self.txs[j].send(item).is_err() {
                self.loads[j].dec();
                mark_dead(&self.health, &self.faults, j);
                continue;
            }
            self.faults.hedge_fired();
            hedged.insert(start, home);
        }
    }

    /// Drop the tracked shards of a request/chunk the waiter gave up
    /// on, so a degraded request doesn't pin its work items (and their
    /// reply-channel clones) until join.
    fn forget_shards(&self, req: u64, aux: u64) {
        let mut tab = self.outstanding.lock().expect("shard table");
        tab.retain(|&(r, a, _), _| !(r == req && a == aux));
    }

    /// Submit a beat at the fleet's configured S. Returns `None` if
    /// admission control shed it (any target queue full with
    /// `shed = true`); shards already enqueued for a shed request still
    /// execute but their replies are discarded.
    pub fn submit(&mut self, beat: Vec<f32>) -> Option<Ticket> {
        let s = self.samples;
        self.submit_with_samples(beat, s)
    }

    /// Submit a beat with a per-request sample count — the fixed-S
    /// entry point for callers that already know how much evidence a
    /// request needs (the adaptive path instead discovers it, see
    /// [`Fleet::submit_adaptive`]).
    pub fn submit_with_samples(
        &mut self,
        beat: Vec<f32>,
        s: usize,
    ) -> Option<Ticket> {
        self.submit_with_samples_at(beat, s, Instant::now())
    }

    /// Coordinated-omission-correct submit: the request's e2e clock
    /// starts at `scheduled` (its intended arrival time), not at the
    /// moment this call ran. An open-loop load generator that fell
    /// behind its schedule therefore charges the slip to the measured
    /// latency instead of silently forgiving it — the closed-loop
    /// submit-then-wait pattern under-reports tail latency exactly when
    /// the system is overloaded (see docs/observability.md).
    pub fn submit_with_samples_at(
        &mut self,
        beat: Vec<f32>,
        s: usize,
        scheduled: Instant,
    ) -> Option<Ticket> {
        assert!(s >= 1, "S must be positive");
        self.supervise();
        let id = self.next_id;
        self.next_id += 1;
        // The request seed IS the request id: every engine derives the
        // same per-sample mask seeds from it, in any placement mode.
        let req_seed = id;
        let enqueued = scheduled;
        self.obs.trace_event(req_seed, "submit", None, 0.0);
        let beat = Arc::new(beat);
        let (reply_tx, reply_rx) = mpsc::channel();
        let expected = match place_round(
            &mut self.router,
            &self.txs,
            &self.loads,
            &self.health,
            &self.faults,
            &self.outstanding,
            &beat,
            req_seed,
            0,
            s,
            enqueued,
            &mut || ReplySink::Fixed(reply_tx.clone()),
            self.shed,
        ) {
            Ok(n) => n,
            Err(_stray) => {
                // Reject the whole request; dropping `reply_rx` voids
                // any shards already enqueued.
                self.rejected += 1;
                if let Some(win) = self.win.as_mut() {
                    win.rejected.inc(window_index(
                        win.epoch,
                        win.width,
                        Instant::now(),
                    ));
                }
                return None;
            }
        };
        if let Some(win) = self.win.as_mut() {
            win.submitted
                .inc(window_index(win.epoch, win.width, Instant::now()));
        }
        Some(Ticket { id, enqueued, expected, total_s: s, rx: reply_rx })
    }

    /// `true` if the streaming-session plane is configured
    /// (`session_bytes` was set).
    pub fn streaming_enabled(&self) -> bool {
        self.sessions.is_some()
    }

    /// Open a streaming session: registers it in the session table and
    /// pins it to the least-loaded engine (mc-shard routing instead
    /// splits every chunk across all engines, so no pin is taken).
    /// The session seed is the session id — every engine derives the
    /// same per-(beat, lane) mask seeds from it, so chunk boundaries,
    /// engine counts and eviction/replay cannot change the bits.
    pub fn open_session(&mut self) -> Result<u64, SessionError> {
        let table =
            self.sessions.clone().ok_or(SessionError::Disabled)?;
        let sid = self.next_sid;
        self.next_sid += 1;
        let loads: Vec<usize> =
            self.loads.iter().map(|l| l.outstanding()).collect();
        let engine = if self.router.policy() == RouterPolicy::McShard {
            0 // unused: chunks shard across all engines
        } else {
            self.router.pin(&loads)
        };
        table.open(
            sid,
            SessionMeta { seed: sid, engine, samples: self.samples },
        );
        Ok(sid)
    }

    /// Submit the next chunk of a session's signal. Chunks may be any
    /// length (a multiple of `input_dim`); decisions are emitted only
    /// for beats completed within the chunk. Session chunks bypass
    /// admission shedding — the caller opened the session precisely to
    /// get every decision, and the pinned engine's FIFO bounds them.
    pub fn submit_chunk(
        &mut self,
        sid: u64,
        chunk: Vec<f32>,
    ) -> Result<ChunkTicket, SessionError> {
        self.submit_chunk_at(sid, chunk, Instant::now())
    }

    /// Coordinated-omission-correct chunk submit: the chunk's e2e
    /// clock starts at `scheduled` (its intended arrival), so an
    /// open-loop streaming generator that slipped charges the slip to
    /// the measured latency (same contract as
    /// [`Fleet::submit_with_samples_at`]).
    pub fn submit_chunk_at(
        &mut self,
        sid: u64,
        chunk: Vec<f32>,
        scheduled: Instant,
    ) -> Result<ChunkTicket, SessionError> {
        let table =
            self.sessions.clone().ok_or(SessionError::Disabled)?;
        self.supervise();
        let mut meta = table.meta(sid)?;
        let healthy = self.health_snapshot();
        let assignments: Vec<(usize, usize, usize)> =
            if self.router.policy() == RouterPolicy::McShard {
                self.router
                    .shards(meta.samples, self.txs.len())
                    .into_iter()
                    .enumerate()
                    .filter(|&(_, (_, c))| c > 0)
                    .map(|(j, (s0, c))| (j, s0, c))
                    .collect()
            } else {
                if !healthy[meta.engine] {
                    // The pinned worker died: re-pin to the
                    // least-loaded survivor. Lane state is keyed by
                    // range start and engine-agnostic — anything still
                    // resident carries over, anything lost with the
                    // dead worker rebuilds transparently by replay.
                    let load_snapshot = self.load_snapshot();
                    let j = self
                        .router
                        .rescue(&load_snapshot, &healthy, None)
                        .ok_or(SessionError::Unavailable(sid))?;
                    table.repin(sid, j)?;
                    self.faults.session_repinned();
                    meta.engine = j;
                }
                vec![(meta.engine, 0, meta.samples)]
            };
        let history_end = table.submit(sid, &chunk, assignments.len())?;
        let enqueued = scheduled;
        // Dispatch stamp is *now*, not the scheduled arrival: queue
        // timing must not absorb generator slip (that belongs to e2e).
        let sent = Instant::now();
        let beat = Arc::new(chunk);
        let (tx, rx) = mpsc::channel();
        let expected = assignments.len();
        for (done, &(j, s0, c)) in assignments.iter().enumerate() {
            let item = WorkItem {
                beat: Arc::clone(&beat),
                req_seed: meta.seed,
                start: s0,
                count: c,
                enqueued,
                sent,
                pulled: None,
                sink: ReplySink::Stream(tx.clone()),
                stream: Some(StreamJob { sid, history_end }),
            };
            let key = (meta.seed, history_end as u64 + 1, s0);
            match dispatch_item(
                &mut self.router,
                &self.txs,
                &self.loads,
                &self.health,
                &self.faults,
                &self.outstanding,
                j,
                key,
                true,
                item,
                false,
            ) {
                Dispatch::Sent(_) => {}
                Dispatch::Full | Dispatch::NoEngines => {
                    // Release the pending slots this chunk reserved
                    // for its undispatched ranges so `close` drains.
                    for _ in done..expected {
                        table.abandon(sid);
                    }
                    return Err(SessionError::Unavailable(sid));
                }
            }
        }
        Ok(ChunkTicket {
            sid,
            seed: meta.seed,
            history_end,
            enqueued,
            expected,
            rx,
        })
    }

    /// Collect one chunk's decisions, merging lane shards in ascending
    /// lane order (bit-identical to a single-engine pass). Worker
    /// deaths during the wait are handled on the probe ticks: orphaned
    /// lane ranges re-dispatch to survivors (replay rebuild keeps them
    /// bit-identical); if no engine can serve before the deadline the
    /// chunk degrades to a typed [`FleetError::Degraded`].
    pub fn wait_chunk(
        &mut self,
        t: ChunkTicket,
    ) -> std::result::Result<ChunkResponse, FleetError> {
        let deadline = self.wait_timeout.unwrap_or(DEFAULT_WAIT);
        let t_wait = Instant::now();
        let mut blocks: Vec<StreamBlock> = Vec::with_capacity(t.expected);
        let mut seen: HashSet<usize> = HashSet::new();
        while blocks.len() < t.expected {
            match t.rx.recv_timeout(PROBE) {
                Ok(Ok(block)) => {
                    // First reply per lane range wins; a duplicate can
                    // only arrive from re-dispatch racing the original.
                    if seen.insert(block.start) {
                        blocks.push(block);
                    }
                }
                Ok(Err(msg)) => {
                    return Err(FleetError::Engine { id: t.sid, msg });
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    self.supervise();
                    if t_wait.elapsed() >= deadline {
                        self.forget_shards(t.seed, t.history_end as u64 + 1);
                        return Err(FleetError::Degraded {
                            id: t.sid,
                            got: blocks.len(),
                            expected: t.expected,
                            waited_ms: t_wait.elapsed().as_secs_f64()
                                * 1e3,
                        });
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    // Every sender (workers + tracked shard clones) is
                    // gone: nothing can ever arrive.
                    self.supervise();
                    return Err(FleetError::Degraded {
                        id: t.sid,
                        got: blocks.len(),
                        expected: t.expected,
                        waited_ms: t_wait.elapsed().as_secs_f64() * 1e3,
                    });
                }
            }
        }
        blocks.sort_by_key(|b| b.start);
        let n_beats = blocks.first().map_or(0, |b| b.beats.len());
        let mut beats = Vec::with_capacity(n_beats);
        for i in 0..n_beats {
            let out_len = blocks[0].beats[i].out_len;
            let mut samples = Vec::new();
            let mut s = 0;
            for b in &blocks {
                samples.extend_from_slice(&b.beats[i].samples);
                s += b.beats[i].s;
            }
            beats.push(McOutput { samples, s, out_len });
        }
        let boosted = blocks.iter().any(|b| b.boosted);
        let model_latency_ms =
            blocks.iter().fold(0.0f64, |m, b| m.max(b.model_latency_ms));
        let e2e_ms = t.enqueued.elapsed().as_secs_f64() * 1e3;
        self.e2e.record_ms(e2e_ms);
        self.served += 1;
        if self.obs.enabled {
            self.e2e_hist.record_ms(e2e_ms);
        }
        if let Some(win) = self.win.as_mut() {
            let w = window_index(win.epoch, win.width, Instant::now());
            win.e2e.record_ms(w, e2e_ms);
            win.served.inc(w);
        }
        Ok(ChunkResponse {
            sid: t.sid,
            beats,
            boosted,
            e2e_ms,
            model_latency_ms,
        })
    }

    /// Close a session: blocks until in-flight chunks have parked,
    /// then drops its state and history.
    pub fn close_session(&self, sid: u64) -> Result<(), SessionError> {
        self.sessions
            .as_ref()
            .ok_or(SessionError::Disabled)?
            .close(sid)
    }

    /// Session-plane counters (`None` when streaming is disabled).
    pub fn session_stats(&self) -> Option<SessionStats> {
        self.sessions.as_ref().map(|t| t.stats())
    }

    /// Submit a beat under an adaptive sampling envelope: the first
    /// round draws `mc.s_min` samples; the fleet's adaptive coordinator
    /// thread dispatches follow-up rounds until the CI stopping rule
    /// fires or `mc.s_max` is exhausted — requests progress without
    /// anyone calling [`Fleet::wait_adaptive`]. Admission control
    /// (shedding) applies to the first round only — a request the fleet
    /// has started sampling is never dropped half-served.
    pub fn submit_adaptive(
        &mut self,
        beat: Vec<f32>,
        mc: &AdaptiveMcConfig,
    ) -> Option<AdaptiveTicket> {
        mc.validate().expect("invalid AdaptiveMcConfig");
        self.supervise();
        let id = self.next_id;
        self.next_id += 1;
        let req_seed = id;
        let enqueued = Instant::now();
        self.obs.trace_event(req_seed, "submit", None, 0.0);
        let beat = Arc::new(beat);
        let (done_tx, done_rx) = mpsc::channel();
        // Register with the coordinator BEFORE dispatching, so the
        // Submit event orders ahead of any worker's Shard event in the
        // coordinator's queue.
        self.adaptive_tx
            .send(AdaptiveEvent::Submit {
                id,
                beat: Arc::clone(&beat),
                req_seed,
                mc: *mc,
                enqueued,
                done: done_tx,
            })
            .expect("adaptive coordinator alive");
        let sink_tx = self.adaptive_tx.clone();
        match place_round(
            &mut self.router,
            &self.txs,
            &self.loads,
            &self.health,
            &self.faults,
            &self.outstanding,
            &beat,
            req_seed,
            0,
            mc.s_min,
            enqueued,
            &mut || ReplySink::Adaptive(sink_tx.clone(), id),
            self.shed,
        ) {
            Ok(n) => {
                self.adaptive_tx
                    .send(AdaptiveEvent::Started { id, outstanding: n })
                    .expect("adaptive coordinator alive");
                if let Some(win) = self.win.as_mut() {
                    win.submitted.inc(window_index(
                        win.epoch,
                        win.width,
                        Instant::now(),
                    ));
                }
                // Worst-case sequential rounds under this envelope:
                // s_min first, then chunk-sized draws to s_max.
                let max_rounds = 1 + mc
                    .s_max
                    .saturating_sub(mc.s_min)
                    .div_ceil(mc.chunk.max(1));
                Some(AdaptiveTicket {
                    id,
                    timeout: Duration::from_secs(120)
                        * max_rounds.min(512) as u32,
                    rx: done_rx,
                })
            }
            Err(stray) => {
                // Shed: tell the coordinator how many already-enqueued
                // shards to swallow, then forget the request.
                self.adaptive_tx
                    .send(AdaptiveEvent::Cancelled { id, stray })
                    .expect("adaptive coordinator alive");
                self.rejected += 1;
                if let Some(win) = self.win.as_mut() {
                    win.rejected.inc(window_index(
                        win.epoch,
                        win.width,
                        Instant::now(),
                    ));
                }
                None
            }
        }
    }

    /// Block until all of a ticket's shards arrive, reduce them, and
    /// record request-level latency. Call before `join`.
    ///
    /// Fault handling happens on the probe ticks between replies:
    /// worker obituaries are processed (orphaned shards re-dispatch to
    /// survivors) and — with a chaos plan armed — overdue shards are
    /// hedged, first reply winning. Shards are merged in ascending
    /// shard-start order whatever order they arrived, so the f64
    /// moment reduction is deterministic and a re-dispatched or hedged
    /// run merges bit-identically to a fault-free one. Returns a typed
    /// [`FleetError`]: `Engine` if a shard's engine failed,
    /// `Degraded` if replies stopped arriving before the deadline.
    pub fn wait(
        &mut self,
        ticket: Ticket,
    ) -> std::result::Result<FleetResponse, FleetError> {
        let deadline = self.wait_timeout.unwrap_or(DEFAULT_WAIT);
        let t_wait = Instant::now();
        let mut parts: Vec<PartialPrediction> =
            Vec::with_capacity(ticket.expected);
        let mut seen: HashSet<usize> = HashSet::new();
        let mut hedged: HashMap<usize, usize> = HashMap::new();
        while parts.len() < ticket.expected {
            match ticket.rx.recv_timeout(PROBE) {
                Ok(Ok(partial)) => {
                    // First reply per shard wins; duplicates (hedge vs
                    // original, re-dispatch races) are discarded.
                    if !seen.insert(partial.start) {
                        continue;
                    }
                    if let Some(&home) = hedged.get(&partial.start) {
                        if partial.engine != home {
                            self.faults.hedge_won();
                        }
                    }
                    parts.push(partial);
                }
                Ok(Err(msg)) => {
                    return Err(FleetError::Engine {
                        id: ticket.id,
                        msg,
                    });
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    self.supervise();
                    if self.chaos_armed {
                        self.hedge_overdue(ticket.id, &mut hedged);
                    }
                    if t_wait.elapsed() >= deadline {
                        self.forget_shards(ticket.id, 0);
                        return Err(FleetError::Degraded {
                            id: ticket.id,
                            got: parts.len(),
                            expected: ticket.expected,
                            waited_ms: t_wait.elapsed().as_secs_f64()
                                * 1e3,
                        });
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    self.supervise();
                    return Err(FleetError::Degraded {
                        id: ticket.id,
                        got: parts.len(),
                        expected: ticket.expected,
                        waited_ms: t_wait.elapsed().as_secs_f64() * 1e3,
                    });
                }
            }
        }
        // Deterministic merge: ascending shard start, independent of
        // arrival order (and therefore of faults, hedging and engine
        // count — the chaos determinism tests assert exact equality).
        parts.sort_by_key(|p| p.start);
        let mut sum: Vec<f64> = Vec::new();
        let mut sumsq: Vec<f64> = Vec::new();
        let mut got_s = 0usize;
        let mut latency = 0f64;
        for partial in &parts {
            if sum.is_empty() {
                sum = vec![0.0; partial.sum.len()];
                sumsq = vec![0.0; partial.sum.len()];
            }
            for i in 0..partial.sum.len() {
                sum[i] += partial.sum[i];
                sumsq[i] += partial.sumsq[i];
            }
            got_s += partial.count;
            // Shards run in parallel: request latency is the slowest one.
            latency = latency.max(partial.model_latency_ms);
        }
        debug_assert_eq!(got_s, ticket.total_s, "shards must cover S");
        let t_merge = Instant::now();
        let (mean, std) = pooled_mean_std(&sum, &sumsq, got_s);
        let merge_us = t_merge.elapsed().as_secs_f64() * 1e6;
        let e2e_ms = ticket.enqueued.elapsed().as_secs_f64() * 1e3;
        self.e2e.record_ms(e2e_ms);
        self.served += 1;
        self.mc.add_spent(got_s);
        if self.obs.enabled {
            self.e2e_hist.record_ms(e2e_ms);
            self.merge_hist.record_us(merge_us);
            self.obs.trace_event(ticket.id, "merge", None, merge_us);
            self.obs.trace_event(ticket.id, "reply", None, e2e_ms * 1e3);
        }
        if let Some(win) = self.win.as_mut() {
            let w = window_index(win.epoch, win.width, Instant::now());
            win.e2e.record_ms(w, e2e_ms);
            win.served.inc(w);
        }
        Ok(FleetResponse {
            id: ticket.id,
            prediction: Prediction { mean, std, model_latency_ms: latency },
            e2e_ms,
            shards: ticket.expected,
        })
    }

    /// Collect one adaptive request's finished response. The adaptive
    /// coordinator thread has been driving its rounds since submit;
    /// sample blocks are merged in ascending sample order, so for a
    /// fixed seed the result is bit-identical to the single-engine
    /// eager path — for any engine count, router policy, chunking or
    /// wait order (the determinism invariant; tested below and in
    /// `fpga::accel`).
    pub fn wait_adaptive(
        &mut self,
        ticket: AdaptiveTicket,
    ) -> Result<AdaptiveResponse> {
        self.supervise();
        let t_wait = Instant::now();
        // Adaptive shards are not tracked for re-dispatch (a replayed
        // sample block would double-feed the controller), so a worker
        // death can strand a round: the configured wait bound converts
        // that into a typed degraded outcome.
        let timeout = self.wait_timeout.unwrap_or(ticket.timeout);
        let resp = ticket
            .rx
            .recv_timeout(timeout)
            .map_err(|_| {
                self.supervise();
                anyhow::Error::from(FleetError::Degraded {
                    id: ticket.id,
                    got: 0,
                    expected: 1,
                    waited_ms: t_wait.elapsed().as_secs_f64() * 1e3,
                })
            })?
            .map_err(|msg| {
                anyhow::Error::from(FleetError::Engine {
                    id: ticket.id,
                    msg,
                })
            })?;
        // e2e was stamped by the coordinator at completion time — the
        // request stopped costing latency when its last round landed,
        // not when the caller got around to waiting.
        self.e2e.record_ms(resp.e2e_ms);
        self.served += 1;
        if self.obs.enabled {
            self.e2e_hist.record_ms(resp.e2e_ms);
            self.merge_hist.record_us(resp.merge_us);
            self.obs.trace_event(resp.id, "merge", None, resp.merge_us);
            self.obs
                .trace_event(resp.id, "reply", None, resp.e2e_ms * 1e3);
        }
        if let Some(win) = self.win.as_mut() {
            // Attribute to the window the request *completed* in (the
            // coordinator stamped it), not the window the caller waited.
            let w =
                window_index(win.epoch, win.width, resp.completed_at);
            win.e2e.record_ms(w, resp.e2e_ms);
            win.served.inc(w);
        }
        Ok(resp)
    }

    /// Close all queues, wait for the workers, and return fleet stats.
    pub fn join(mut self) -> FleetSummary {
        // Shut the adaptive coordinator down first: it drains any
        // still-in-flight adaptive requests (workers stay alive while
        // the coordinator holds queue senders), then drops its senders
        // so the workers can exit.
        let _ = self.adaptive_tx.send(AdaptiveEvent::Shutdown);
        if let Some(coord) = self.adaptive_coord.take() {
            coord.join().expect("adaptive coordinator panicked");
        }
        // Dropping the queue senders lets the workers drain and exit.
        self.txs.clear();
        let workers = std::mem::take(&mut self.workers);
        // A worker panic (chaos kill or genuine) must not abort the
        // fleet or lose the survivors' stats: fold the death into the
        // fault summary and keep a placeholder per-engine slot so the
        // summary stays one-entry-per-engine.
        let mut per_engine: Vec<ServeSummary> = workers
            .into_iter()
            .enumerate()
            .map(|(i, w)| match w.join() {
                Ok(summary) => summary,
                Err(payload) => {
                    mark_dead(&self.health, &self.faults, i);
                    if payload.downcast_ref::<ChaosKill>().is_none() {
                        eprintln!("fleet worker {i} panicked");
                    }
                    lost_worker_summary()
                }
            })
            .collect();
        // Deaths already noticed by a waiter were counted there; the
        // swap inside mark_dead keeps each engine counted once.
        while let Ok(i) = self.deaths_rx.try_recv() {
            mark_dead(&self.health, &self.faults, i);
        }
        // Queue pressure lives in the fleet-side EngineLoad gauges
        // (workers only decrement them) — inject into the summaries.
        for (e, load) in per_engine.iter_mut().zip(&self.loads) {
            e.queue_highwater = load.highwater();
            e.sheds = load.sheds();
        }
        let mut placements = self.router.placements().to_vec();
        if placements.len() < self.loads.len() {
            // route() is lazy (mc-shard never calls it): pad so the
            // exported array always has one slot per engine.
            placements.resize(self.loads.len(), 0);
        }
        if let Some(t) = &self.obs.trace {
            t.flush();
        }
        // Assemble the fleet timeline: coordinator-side windows
        // (e2e / admission counters) plus the exact merge of every
        // worker's per-window stage histograms — the same associativity
        // contract the whole-run histograms rely on.
        let timeline = self.win.take().map(|mut win| {
            let samples = win
                .sampler
                .take()
                .map(|s| s.finish())
                .unwrap_or_default();
            let mut tl = Timeline::new(win.width);
            tl.e2e = win.e2e;
            tl.submitted = win.submitted;
            tl.served = win.served;
            tl.rejected = win.rejected;
            tl.samples = samples;
            for e in &per_engine {
                if let Some(wt) = &e.timeline {
                    tl.queue.merge(&wt.queue);
                    tl.batch.merge(&wt.batch);
                    tl.compute.merge(&wt.compute);
                    tl.items.merge(&wt.items);
                    tl.batches.merge(&wt.batches);
                }
            }
            tl
        });
        FleetSummary {
            served: self.served,
            rejected: self.rejected,
            wall: self.t0.elapsed(),
            e2e: self.e2e.clone(),
            per_engine,
            obs: FleetObs {
                enabled: self.obs.enabled,
                e2e: self.e2e_hist.clone(),
                merge: self.merge_hist.clone(),
                mc_spent: self.mc.spent(),
                mc_saved: self.mc.saved(),
                placements,
                trace_dropped: self
                    .obs
                    .trace
                    .as_ref()
                    .map(|t| t.dropped())
                    .unwrap_or(0),
                mask_bank: None,
                sessions: self.sessions.as_ref().map(|t| t.stats()),
                faults: self.faults.snapshot(),
            },
            timeline,
        }
    }
}

/// Placeholder per-engine summary for a worker that died before
/// reporting: keeps `FleetSummary::per_engine` one-entry-per-engine
/// with an unmistakable `kernel` label.
fn lost_worker_summary() -> ServeSummary {
    ServeSummary {
        served: 0,
        wall: Duration::default(),
        e2e: LatencyStats::new(),
        engine: LatencyStats::new(),
        batches: 0,
        mean_batch: 0.0,
        rejected: 0,
        stages: None,
        mc_rows: 0,
        kernel: "lost".to_string(),
        peak_batch: 0,
        queue_highwater: 0,
        sheds: 0,
        timeline: None,
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        // A fleet dropped without `join` must not leak its threads: the
        // coordinator blocks on its event channel (and holds worker
        // queue senders), so nudge it to shut down. After it drains and
        // exits, the workers observe queue disconnection and exit too
        // (their join handles are detached here). After a normal
        // `join` the send simply fails and is ignored.
        let _ = self.adaptive_tx.send(AdaptiveEvent::Shutdown);
    }
}

/// How one work item's dispatch resolved.
enum Dispatch {
    /// Accepted by this engine's queue (its planned home, or a
    /// survivor after diversion).
    Sent(usize),
    /// Shed mode and the target queue was full.
    Full,
    /// Every engine is dead — nothing can accept work.
    NoEngines,
}

/// Send one work item to engine `home`, diverting to the least-loaded
/// survivor when `home` is dead (or dies mid-send — a failed send
/// marks it dead and retries elsewhere). With `track`, the item is
/// registered in the outstanding-shard table under `key` *before* the
/// send, so a worker death between dispatch and reply always finds a
/// re-dispatchable entry; the executing worker removes it when it
/// replies. With every engine healthy this is exactly the old
/// inc-then-send (unshed) / try_send-then-inc (shed) dispatch.
#[allow(clippy::too_many_arguments)]
fn dispatch_item(
    router: &mut Router,
    txs: &[mpsc::SyncSender<WorkItem>],
    loads: &[Arc<EngineLoad>],
    health: &[AtomicBool],
    faults: &FaultCounters,
    outstanding: &ShardTable,
    home: usize,
    key: ShardKey,
    track: bool,
    mut item: WorkItem,
    shed: bool,
) -> Dispatch {
    let mut j = home;
    loop {
        let healthy: Vec<bool> =
            health.iter().map(|h| h.load(Ordering::Acquire)).collect();
        if !healthy[j] {
            let load_snapshot: Vec<usize> =
                loads.iter().map(|l| l.outstanding()).collect();
            match router.rescue(&load_snapshot, &healthy, None) {
                Some(r) => j = r,
                None => {
                    if track {
                        let mut tab =
                            outstanding.lock().expect("shard table");
                        tab.remove(&key);
                    }
                    return Dispatch::NoEngines;
                }
            }
        }
        if track {
            let mut tab = outstanding.lock().expect("shard table");
            tab.insert(
                key,
                PendingShard {
                    engine: j,
                    item: item.clone(),
                    dispatched: Instant::now(),
                    hedged: false,
                },
            );
        }
        if shed {
            match txs[j].try_send(item) {
                Ok(()) => {
                    loads[j].inc();
                    break Dispatch::Sent(j);
                }
                Err(mpsc::TrySendError::Full(_)) => {
                    loads[j].shed();
                    if track {
                        let mut tab =
                            outstanding.lock().expect("shard table");
                        tab.remove(&key);
                    }
                    break Dispatch::Full;
                }
                Err(mpsc::TrySendError::Disconnected(it)) => {
                    mark_dead(health, faults, j);
                    item = it;
                }
            }
        } else {
            loads[j].inc();
            match txs[j].send(item) {
                Ok(()) => break Dispatch::Sent(j),
                Err(mpsc::SendError(it)) => {
                    loads[j].dec();
                    mark_dead(health, faults, j);
                    item = it;
                }
            }
        }
    }
}

/// Place one sampling round `start..start + count` on the fleet
/// according to the router policy (MC-shard splits it across all
/// engines; rr/least-loaded give the whole round to one engine, dead
/// engines skipped). Returns `Ok(shards dispatched)`, or `Err(shards
/// already enqueued before the rejection)` — when `shed` and a target
/// queue was full, or when no healthy engine remains.
#[allow(clippy::too_many_arguments)]
fn place_round(
    router: &mut Router,
    txs: &[mpsc::SyncSender<WorkItem>],
    loads: &[Arc<EngineLoad>],
    health: &[AtomicBool],
    faults: &FaultCounters,
    outstanding: &ShardTable,
    beat: &Arc<Vec<f32>>,
    req_seed: u64,
    start: usize,
    count: usize,
    enqueued: Instant,
    sink: &mut dyn FnMut() -> ReplySink,
    shed: bool,
) -> std::result::Result<usize, usize> {
    let healthy: Vec<bool> =
        health.iter().map(|h| h.load(Ordering::Acquire)).collect();
    // (engine, start, count) assignments. MC-shard plans over the FULL
    // engine count — shard ranges must stay engine-count-invariant for
    // merge determinism — and a dead planned home is diverted at
    // dispatch below (counted as a re-dispatch).
    let assignments: Vec<(usize, usize, usize)> =
        if router.policy() == RouterPolicy::McShard {
            router
                .shards(count, txs.len())
                .into_iter()
                .enumerate()
                .filter(|&(_, (_, c))| c > 0)
                .map(|(j, (s0, c))| (j, start + s0, c))
                .collect()
        } else {
            let load_snapshot: Vec<usize> =
                loads.iter().map(|l| l.outstanding()).collect();
            match router.route_healthy(&load_snapshot, &healthy) {
                Some(j) => vec![(j, start, count)],
                None => return Err(0),
            }
        };

    // One dispatch stamp per round: queue stage = sent → worker pull.
    let sent = Instant::now();
    for (done, &(j, s0, c)) in assignments.iter().enumerate() {
        let item = WorkItem {
            beat: Arc::clone(beat),
            req_seed,
            start: s0,
            count: c,
            enqueued,
            sent,
            pulled: None,
            sink: sink(),
            stream: None,
        };
        let track = matches!(item.sink, ReplySink::Fixed(_));
        let key: ShardKey = (req_seed, 0, s0);
        match dispatch_item(
            router, txs, loads, health, faults, outstanding, j, key,
            track, item, shed,
        ) {
            Dispatch::Sent(took) => {
                if took != j {
                    // The planned home was dead: this shard moved to a
                    // survivor at dispatch time.
                    faults.shard_redispatched();
                }
            }
            Dispatch::Full => return Err(done),
            Dispatch::NoEngines => return Err(done),
        }
    }
    Ok(assignments.len())
}

/// Per-request state inside the adaptive coordinator.
struct AdaptiveState {
    beat: Arc<Vec<f32>>,
    req_seed: u64,
    mc: AdaptiveMcConfig,
    enqueued: Instant,
    done: mpsc::Sender<Result<AdaptiveResponse, String>>,
    ctl: Option<AdaptiveController>,
    /// Shards outstanding this round (`None` until `Started` resolves
    /// the first round's dispatch count).
    outstanding: Option<usize>,
    received: usize,
    round_ms: f64,
    latency_ms: f64,
    rounds: usize,
    failed: Option<String>,
    /// Set by `Cancelled`: swallow this many stray shard replies, then
    /// drop the request without responding.
    cancelled_stray: Option<usize>,
}

/// The adaptive coordinator loop: one thread per fleet owning every
/// in-flight adaptive request's controller. Rounds complete and
/// follow-up rounds dispatch here — independent of the waiter — which
/// removes the head-of-line serialisation of multi-round requests in
/// submit-all-then-wait loops (ROADMAP PR 3 review finding a).
#[allow(clippy::too_many_arguments)]
fn adaptive_coordinator(
    rx: mpsc::Receiver<AdaptiveEvent>,
    self_tx: mpsc::Sender<AdaptiveEvent>,
    txs: Vec<mpsc::SyncSender<WorkItem>>,
    loads: Vec<Arc<EngineLoad>>,
    mut router: Router,
    counters: Arc<McCounters>,
    health: Arc<Vec<AtomicBool>>,
    faults: Arc<FaultCounters>,
    outstanding: Arc<ShardTable>,
) {
    let mut states: HashMap<u64, AdaptiveState> = HashMap::new();
    let mut shutdown = false;
    while !(shutdown && states.is_empty()) {
        let ev = match rx.recv() {
            Ok(ev) => ev,
            // All senders gone (fleet dropped mid-flight): nothing more
            // can arrive — bail out.
            Err(_) => break,
        };
        match ev {
            AdaptiveEvent::Submit {
                id,
                beat,
                req_seed,
                mc,
                enqueued,
                done,
            } => {
                states.insert(
                    id,
                    AdaptiveState {
                        beat,
                        req_seed,
                        mc,
                        enqueued,
                        done,
                        ctl: None,
                        outstanding: None,
                        received: 0,
                        round_ms: 0.0,
                        latency_ms: 0.0,
                        rounds: 0,
                        failed: None,
                        cancelled_stray: None,
                    },
                );
            }
            AdaptiveEvent::Started { id, outstanding } => {
                if let Some(st) = states.get_mut(&id) {
                    st.outstanding = Some(outstanding);
                }
                finish_round_if_complete(
                    id, &mut states, &self_tx, &txs, &loads, &mut router,
                    &counters, &health, &faults, &outstanding,
                );
            }
            AdaptiveEvent::Cancelled { id, stray } => {
                if let Some(st) = states.get_mut(&id) {
                    if st.received >= stray {
                        states.remove(&id);
                    } else {
                        st.cancelled_stray = Some(stray);
                    }
                }
            }
            AdaptiveEvent::Shard { id, block } => {
                let Some(st) = states.get_mut(&id) else {
                    continue; // stray shard of an already-dropped request
                };
                st.received += 1;
                if let Some(stray) = st.cancelled_stray {
                    if st.received >= stray {
                        states.remove(&id);
                    }
                    continue;
                }
                match block {
                    Ok(b) => {
                        st.round_ms = st.round_ms.max(b.model_latency_ms);
                        st.ctl
                            .get_or_insert_with(|| {
                                AdaptiveController::new(st.mc, b.out_len)
                            })
                            .push_block(b.start, b.samples);
                    }
                    Err(msg) => st.failed = Some(msg),
                }
                finish_round_if_complete(
                    id, &mut states, &self_tx, &txs, &loads, &mut router,
                    &counters, &health, &faults, &outstanding,
                );
            }
            AdaptiveEvent::Shutdown => shutdown = true,
        }
    }
    // Dropping `txs` here releases the coordinator's queue senders so
    // the workers can observe disconnection and exit.
}

/// If request `id`'s current round is fully collected, advance it:
/// record the round, consult the stopping rule, dispatch the next round
/// or finalise the response.
#[allow(clippy::too_many_arguments)]
fn finish_round_if_complete(
    id: u64,
    states: &mut HashMap<u64, AdaptiveState>,
    self_tx: &mpsc::Sender<AdaptiveEvent>,
    txs: &[mpsc::SyncSender<WorkItem>],
    loads: &[Arc<EngineLoad>],
    router: &mut Router,
    counters: &McCounters,
    health: &[AtomicBool],
    faults: &FaultCounters,
    outstanding: &ShardTable,
) {
    let Some(st) = states.get_mut(&id) else { return };
    let Some(outstanding) = st.outstanding else { return };
    if st.received < outstanding {
        return;
    }
    // Round complete. Shards ran in parallel: the round costs its
    // slowest shard; rounds are sequential: the request sums rounds.
    st.latency_ms += st.round_ms;
    st.round_ms = 0.0;
    st.received = 0;
    st.rounds += 1;
    if let Some(msg) = st.failed.take() {
        let st = states.remove(&id).expect("state present");
        let _ = st.done.send(Err(msg));
        return;
    }
    let decision = st
        .ctl
        .as_ref()
        .expect("completed round pushed at least one block")
        .decision();
    match decision {
        McDecision::Draw { start, count } => {
            // Later rounds bypass admission control: the fleet has
            // already invested in this request. An unshed dispatch can
            // still fail when every engine is dead — fail the request
            // with a typed message rather than hanging the waiter.
            match place_round(
                router,
                txs,
                loads,
                health,
                faults,
                outstanding,
                &Arc::clone(&st.beat),
                st.req_seed,
                start,
                count,
                st.enqueued,
                &mut || ReplySink::Adaptive(self_tx.clone(), id),
                false,
            ) {
                Ok(n) => st.outstanding = Some(n),
                Err(_) => {
                    let st = states.remove(&id).expect("state present");
                    let _ = st.done.send(Err(String::from(
                        "no healthy engine left for continuation round",
                    )));
                }
            }
        }
        McDecision::Converged | McDecision::Exhausted => {
            let converged = matches!(decision, McDecision::Converged);
            let st = states.remove(&id).expect("state present");
            let ctl = st.ctl.expect("at least one round collected");
            let t_merge = Instant::now();
            let (mean, std) = ctl.acc.finalize();
            let samples = ctl.acc.samples_ordered();
            let merge_us = t_merge.elapsed().as_secs_f64() * 1e6;
            let s_used = ctl.acc.count();
            // MC accounting happens here (not at wait) so unwaited
            // requests the coordinator drains still count.
            counters.add_spent(s_used);
            counters.add_saved(st.mc.s_max.saturating_sub(s_used));
            let e2e_ms = st.enqueued.elapsed().as_secs_f64() * 1e3;
            let _ = st.done.send(Ok(AdaptiveResponse {
                id,
                prediction: Prediction {
                    mean,
                    std,
                    model_latency_ms: st.latency_ms,
                },
                samples,
                out_len: ctl.acc.out_len(),
                s_used,
                converged,
                rounds: st.rounds,
                e2e_ms,
                merge_us,
                completed_at: Instant::now(),
            }));
        }
    }
}

/// Per-engine event loop: bounded queue -> batcher -> engine ->
/// per-shard replies. Same drain discipline as `server.rs` (block 1 ms
/// when idle, never sleep while work is pending). Each formed batch is
/// issued to the engine as **one** blocked call
/// ([`Engine::infer_samples_batch`]) instead of a per-request loop —
/// on the FPGA simulator every weight row is then fetched once per
/// timestep for the whole batch. Items are queued with their MC-row
/// weight so a `max_rows` batch policy can bound blocked-call size.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    factory: Box<dyn FnOnce() -> Engine + Send>,
    rx: mpsc::Receiver<WorkItem>,
    policy: BatchPolicy,
    load: Arc<EngineLoad>,
    idx: usize,
    obs: ObsConfig,
    win: Option<(Instant, Duration)>,
    sessions: Option<Arc<SessionTable>>,
    session_uq: Option<AdaptiveMcConfig>,
    mut ctx: WorkerCtx,
) -> ServeSummary {
    let mut engine = factory();
    let mut batcher: Batcher<WorkItem> = Batcher::new(policy);
    let mut e2e = LatencyStats::new();
    let mut eng = LatencyStats::new();
    let mut stages = if obs.enabled {
        Some(StageStats::default())
    } else {
        None
    };
    // Windowed slice of this worker's stage stats; merged exactly into
    // the fleet timeline at `join` (shared epoch → aligned windows).
    let mut timeline: Option<(Instant, Duration, WorkerTimeline)> =
        win.map(|(epoch, width)| (epoch, width, WorkerTimeline::default()));
    let mut served = 0usize;
    let mut batches = 0usize;
    let mut mc_rows = 0usize;
    let mut seq = 0u64;
    let t0 = Instant::now();
    let mut open = true;
    while open || !batcher.is_empty() {
        // Injected kills fire only here, between items — an item the
        // worker started is always finished (or its engine genuinely
        // panicked), so checked-out session state is never stranded
        // mid-chunk and the outstanding-shard table stays consistent.
        if ctx.chaos.should_kill(ctx.epoch.elapsed()) {
            std::panic::panic_any(ChaosKill(idx));
        }
        if open {
            if batcher.is_empty() {
                match rx.recv_timeout(Duration::from_millis(1)) {
                    Ok(mut item) => {
                        if obs.enabled {
                            item.pulled = Some(Instant::now());
                        }
                        if item.stream.is_some() {
                            serve_stream_item(
                                &mut engine,
                                sessions.as_deref(),
                                session_uq.as_ref(),
                                &load,
                                item,
                                &mut e2e,
                                &mut eng,
                                &mut served,
                                &mut mc_rows,
                                &mut ctx,
                            );
                        } else {
                            let rows = item.count;
                            batcher.push_weighted(seq, item, rows);
                            seq += 1;
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        open = false;
                    }
                }
            }
            loop {
                match rx.try_recv() {
                    Ok(mut item) => {
                        if obs.enabled {
                            item.pulled = Some(Instant::now());
                        }
                        if item.stream.is_some() {
                            serve_stream_item(
                                &mut engine,
                                sessions.as_deref(),
                                session_uq.as_ref(),
                                &load,
                                item,
                                &mut e2e,
                                &mut eng,
                                &mut served,
                                &mut mc_rows,
                                &mut ctx,
                            );
                        } else {
                            let rows = item.count;
                            batcher.push_weighted(seq, item, rows);
                            seq += 1;
                        }
                    }
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
        }
        if batcher.ready(true) {
            let batch = batcher.take();
            batches += 1;
            let group = batch.items.len();
            let reqs: Vec<ShardRequest> = batch
                .items
                .iter()
                .map(|item| ShardRequest {
                    beat: item.beat.as_slice(),
                    req_seed: item.req_seed,
                    start: item.start,
                    count: item.count,
                })
                .collect();
            if let Some(d) = ctx.chaos.stall_for(ctx.epoch.elapsed()) {
                thread::sleep(d);
            }
            let t_dispatch = Instant::now();
            let results = engine.infer_samples_batch(&reqs, group);
            // Every item in the batch rode the same blocked engine
            // call, so they share its wall time as the compute stage.
            let compute_us = t_dispatch.elapsed().as_secs_f64() * 1e6;
            let t_done = Instant::now();
            if let Some((epoch, width, tl)) = timeline.as_mut() {
                tl.batches.inc(window_index(*epoch, *width, t_done));
            }
            for (item, result) in batch.items.iter().zip(results) {
                load.dec();
                let outcome: std::result::Result<SampleBlock, String> =
                    match result {
                        Ok(block) => {
                            e2e.record_ms(
                                item.enqueued.elapsed().as_secs_f64() * 1e3,
                            );
                            eng.record_ms(block.model_latency_ms);
                            served += 1;
                            mc_rows += item.count;
                            if let Some(st) = stages.as_mut() {
                                let pulled =
                                    item.pulled.unwrap_or(t_dispatch);
                                let queue_us = pulled
                                    .duration_since(item.sent)
                                    .as_secs_f64()
                                    * 1e6;
                                let batch_us = t_dispatch
                                    .duration_since(pulled)
                                    .as_secs_f64()
                                    * 1e6;
                                st.queue.record_us(queue_us);
                                st.batch.record_us(batch_us);
                                st.compute.record_us(compute_us);
                                if let Some((epoch, width, tl)) =
                                    timeline.as_mut()
                                {
                                    let w = window_index(
                                        *epoch, *width, t_done,
                                    );
                                    tl.queue.record_us(w, queue_us);
                                    tl.batch.record_us(w, batch_us);
                                    tl.compute.record_us(w, compute_us);
                                    tl.items.inc(w);
                                }
                                let req = item.req_seed;
                                obs.trace_event(
                                    req, "queue", Some(idx), queue_us,
                                );
                                obs.trace_event(
                                    req, "batch", Some(idx), batch_us,
                                );
                                obs.trace_event(
                                    req, "compute", Some(idx), compute_us,
                                );
                            }
                            Ok(block)
                        }
                        Err(e) => {
                            eprintln!("fleet engine error: {e:#}");
                            Err(format!("{e:#}"))
                        }
                    };
                // Fixed-path sinks get the shard pre-reduced to moment
                // sums; adaptive sinks get the raw samples forwarded to
                // the coordinator. Receivers may be gone (shed
                // request / dropped fleet): ignore send failures.
                match &item.sink {
                    ReplySink::Fixed(tx) => {
                        // This shard is answered: retire its
                        // outstanding-table entry so supervision stops
                        // tracking it, THEN (chaos only) maybe drop the
                        // reply. The drop hash is engine-independent,
                        // so a re-dispatched copy drops identically and
                        // the loss deterministically surfaces as a
                        // waiter timeout instead of flaking.
                        {
                            let mut tab = ctx
                                .outstanding
                                .lock()
                                .expect("shard table");
                            tab.remove(&(item.req_seed, 0, item.start));
                        }
                        if ctx
                            .chaos
                            .should_drop(item.req_seed, item.start)
                        {
                            ctx.faults.reply_dropped();
                        } else {
                            let _ = tx.send(outcome.map(|b| {
                                PartialPrediction::from_samples(
                                    &b.samples,
                                    b.count,
                                    b.out_len,
                                    b.model_latency_ms,
                                )
                                .with_origin(item.start, idx)
                            }));
                        }
                    }
                    ReplySink::Adaptive(tx, id) => {
                        let _ = tx.send(AdaptiveEvent::Shard {
                            id: *id,
                            block: outcome,
                        });
                    }
                    // Stream items never enter the batcher (diverted at
                    // the pull sites above).
                    ReplySink::Stream(_) => {}
                }
            }
        }
    }
    let mean_batch =
        if batches > 0 { served as f64 / batches as f64 } else { 0.0 };
    ServeSummary {
        served,
        wall: t0.elapsed(),
        e2e,
        engine: eng,
        batches,
        mean_batch,
        rejected: 0,
        stages,
        mc_rows,
        kernel: engine.backend_label(),
        peak_batch: batcher.peak_batch(),
        // Fleet-side gauges; Fleet::join injects them from EngineLoad.
        queue_highwater: 0,
        sheds: 0,
        timeline: timeline.map(|(_, _, tl)| tl),
    }
}

/// Serve one streaming chunk immediately (no batching — the engine
/// FIFO already serialises a session's chunks) and reply on its
/// stream sink.
#[allow(clippy::too_many_arguments)]
fn serve_stream_item(
    engine: &mut Engine,
    table: Option<&SessionTable>,
    uq: Option<&AdaptiveMcConfig>,
    load: &EngineLoad,
    item: WorkItem,
    e2e: &mut LatencyStats,
    eng: &mut LatencyStats,
    served: &mut usize,
    mc_rows: &mut usize,
    ctx: &mut WorkerCtx,
) {
    if let Some(d) = ctx.chaos.stall_for(ctx.epoch.elapsed()) {
        thread::sleep(d);
    }
    let outcome = match table {
        Some(table) => stream_chunk_outcome(engine, table, uq, &item),
        None => Err("streaming sessions are disabled".to_string()),
    };
    load.dec();
    if let Ok(block) = &outcome {
        e2e.record_ms(item.enqueued.elapsed().as_secs_f64() * 1e3);
        eng.record_ms(block.model_latency_ms);
        *served += 1;
        *mc_rows += item.count;
    }
    // Chunk is parked/abandoned: retire the outstanding entry, then
    // (chaos only) maybe drop the reply — same engine-independent hash
    // as the fixed path.
    if let Some(job) = &item.stream {
        let mut tab = ctx.outstanding.lock().expect("shard table");
        tab.remove(&(
            item.req_seed,
            job.history_end as u64 + 1,
            item.start,
        ));
    }
    if ctx.chaos.should_drop(item.req_seed, item.start) {
        ctx.faults.reply_dropped();
        return;
    }
    if let ReplySink::Stream(tx) = &item.sink {
        let _ = tx.send(outcome);
    }
}

/// Resume (or replay-rebuild) the session's lane state, advance it
/// through the chunk, optionally escalate uncertain beats, and park the
/// state back. Every exit path either parks or abandons, so `close`
/// never waits on a slot that will not drain.
fn stream_chunk_outcome(
    engine: &mut Engine,
    table: &SessionTable,
    uq: Option<&AdaptiveMcConfig>,
    item: &WorkItem,
) -> std::result::Result<StreamBlock, String> {
    let job = item.stream.as_ref().expect("stream item");
    let meta = match table.meta(job.sid) {
        Ok(m) => m,
        Err(e) => {
            table.abandon(job.sid);
            return Err(e.to_string());
        }
    };
    let mut ms = 0.0f64;
    let mut st = match table.resume(job.sid, item.start, job.history_end)
    {
        Ok(Resume::Resident(st)) => st,
        Ok(Resume::Replay { history }) => {
            let mut st = match engine.open_stream(
                meta.seed,
                item.start,
                item.count,
            ) {
                Ok(st) => st,
                Err(e) => {
                    table.abandon(job.sid);
                    return Err(format!("{e:#}"));
                }
            };
            if !history.is_empty() {
                // Evicted under the byte budget: rebuild by replaying
                // the retained history. The cost is charged to this
                // chunk's model latency so thrash shows honestly.
                match engine.infer_stream_chunk(&mut st, &history) {
                    Ok((_, rebuild_ms)) => ms += rebuild_ms,
                    Err(e) => {
                        table.abandon(job.sid);
                        return Err(format!("{e:#}"));
                    }
                }
            }
            st
        }
        Err(e) => return Err(e.to_string()),
    };
    match engine.infer_stream_chunk(&mut st, &item.beat) {
        Ok((mut beats, chunk_ms)) => {
            ms += chunk_ms;
            let mut boosted = false;
            if let Some(mc) = uq {
                match boost_uncertain_beats(
                    engine, table, &meta, job, item, mc, &mut beats,
                ) {
                    Ok(Some(boost_ms)) => {
                        ms += boost_ms;
                        boosted = true;
                        table.note_boost();
                    }
                    Ok(None) => {}
                    Err(msg) => {
                        table.park(job.sid, st);
                        return Err(msg);
                    }
                }
            }
            table.park(job.sid, st);
            Ok(StreamBlock {
                start: item.start,
                beats,
                model_latency_ms: ms,
                boosted,
            })
        }
        Err(e) => {
            // predict_stream validates before mutating, so the state is
            // untouched — park it back to keep the session coherent.
            table.park(job.sid, st);
            Err(format!("{e:#}"))
        }
    }
}

/// The adaptive streaming tier: if any beat's CI half-width at the base
/// budget exceeds the target, recompute lanes `samples..s_max` by
/// replaying history + chunk through a fresh stateless stream and merge
/// the tail beats in. Lane state being a pure function of
/// `(design, session, consumed signal, lane)` makes the merged output
/// bit-identical to an always-`s_max` session. Affinity placement only:
/// a lane shard cannot judge the pooled CI (gated on the item owning
/// every lane).
fn boost_uncertain_beats(
    engine: &mut Engine,
    table: &SessionTable,
    meta: &SessionMeta,
    job: &StreamJob,
    item: &WorkItem,
    mc: &AdaptiveMcConfig,
    beats: &mut Vec<McOutput>,
) -> std::result::Result<Option<f64>, String> {
    if item.start != 0
        || item.count != meta.samples
        || mc.s_max <= meta.samples
        || beats.is_empty()
    {
        return Ok(None);
    }
    let spike = beats
        .iter()
        .any(|b| stream_should_boost(&b.mean_std().1, b.s, mc));
    if !spike {
        return Ok(None);
    }
    let mut full = table
        .history(job.sid, job.history_end)
        .map_err(|e| e.to_string())?;
    full.extend_from_slice(&item.beat);
    let extra = mc.s_max - meta.samples;
    let mut bst = engine
        .open_stream(meta.seed, meta.samples, extra)
        .map_err(|e| format!("{e:#}"))?;
    let (boost_all, boost_ms) = engine
        .infer_stream_chunk(&mut bst, &full)
        .map_err(|e| format!("{e:#}"))?;
    // The replay spans the whole history, so its trailing beats align
    // with this chunk's beats.
    let tail = boost_all.len() - beats.len();
    for (b, extra_out) in beats.iter_mut().zip(&boost_all[tail..]) {
        b.samples.extend_from_slice(&extra_out.samples);
        b.s += extra_out.s;
    }
    Ok(Some(boost_ms))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArchConfig, Task};
    use crate::hwmodel::resource::ReuseFactors;
    use crate::nn::model::Model;
    use crate::nn::Params;
    use crate::rng::Rng;

    fn tiny_cfg() -> ArchConfig {
        let mut cfg = ArchConfig::new(Task::Classify, 8, 1, "Y");
        cfg.seq_len = 20;
        cfg
    }

    fn fpga_factories(
        n: usize,
        s: usize,
        seed: u64,
    ) -> Vec<Box<dyn FnOnce() -> Engine + Send + 'static>> {
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, &mut Rng::new(0));
        (0..n)
            .map(|_| {
                let c = cfg.clone();
                let p = params.clone();
                let f: Box<dyn FnOnce() -> Engine + Send + 'static> =
                    Box::new(move || {
                        let model = Model::new(c.clone(), p);
                        Engine::fpga(
                            &c,
                            &model,
                            ReuseFactors::new(2, 1, 1),
                            s,
                            seed,
                        )
                    });
                f
            })
            .collect()
    }

    fn beat() -> Vec<f32> {
        (0..20).map(|i| (i as f32 * 0.3).sin()).collect()
    }

    /// Like [`fpga_factories`], but every engine shares one mask bank
    /// (the `repro serve --mask-bank-mb` wiring).
    fn banked_factories(
        n: usize,
        s: usize,
        seed: u64,
        bank: &Arc<crate::kernels::MaskBank>,
    ) -> Vec<Box<dyn FnOnce() -> Engine + Send + 'static>> {
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, &mut Rng::new(0));
        (0..n)
            .map(|_| {
                let c = cfg.clone();
                let p = params.clone();
                let b = Arc::clone(bank);
                let f: Box<dyn FnOnce() -> Engine + Send + 'static> =
                    Box::new(move || {
                        let model = Model::new(c.clone(), p);
                        let mut e = Engine::fpga(
                            &c,
                            &model,
                            ReuseFactors::new(2, 1, 1),
                            s,
                            seed,
                        );
                        e.set_mask_bank(Some(b));
                        e
                    });
                f
            })
            .collect()
    }

    #[test]
    fn round_robin_fleet_serves_all_and_spreads_load() {
        let s = 2;
        let mut fleet = Fleet::start(
            FleetConfig {
                engines: 2,
                samples: s,
                ..FleetConfig::default()
            },
            fpga_factories(2, s, 5),
        );
        let tickets: Vec<Ticket> =
            (0..12).filter_map(|_| fleet.submit(beat())).collect();
        assert_eq!(tickets.len(), 12, "no shedding by default");
        for t in tickets {
            let resp = fleet.wait(t).expect("response");
            assert_eq!(resp.prediction.mean.len(), 4);
            assert_eq!(resp.shards, 1);
            assert!(resp.e2e_ms >= 0.0);
        }
        let summary = fleet.join();
        assert_eq!(summary.served, 12);
        assert_eq!(summary.rejected, 0);
        assert_eq!(summary.per_engine.len(), 2);
        assert_eq!(summary.items(), 12);
        // Round-robin must touch both engines.
        assert!(summary.per_engine.iter().all(|e| e.served == 6));
        assert!(summary.throughput() > 0.0);
        // Always-on health counters: 12 requests × S=2 samples, and
        // one placement decision per request.
        assert_eq!(summary.obs.mc_spent, 24);
        assert_eq!(summary.obs.mc_saved, 0, "fixed path saves nothing");
        assert_eq!(summary.obs.placements, vec![6, 6]);
        assert!(
            summary.per_engine.iter().all(|e| e.kernel.starts_with("fpga:")),
            "FPGA-sim engines report an fpga:<kernel> label"
        );
        assert!(!summary.obs.enabled, "obs is opt-in");
        assert!(summary.obs.e2e.is_empty(), "no histograms when disabled");
        assert!(
            summary.per_engine.iter().all(|e| e.stages.is_none()),
            "no stage stats when disabled"
        );
    }

    /// The headline invariant: MC-shard over 3 engines reproduces the
    /// single-engine prediction (same design seed, same request id).
    #[test]
    fn mc_shard_matches_single_engine_prediction() {
        let s = 8;
        let mut single = Fleet::start(
            FleetConfig { engines: 1, samples: s, ..FleetConfig::default() },
            fpga_factories(1, s, 9),
        );
        let t = single.submit(beat()).unwrap();
        let base = single.wait(t).expect("response");
        single.join();

        let mut sharded = Fleet::start(
            FleetConfig {
                engines: 3,
                router: RouterPolicy::McShard,
                samples: s,
                ..FleetConfig::default()
            },
            fpga_factories(3, s, 9),
        );
        let t = sharded.submit(beat()).unwrap();
        let resp = sharded.wait(t).expect("response");
        assert_eq!(resp.shards, 3);
        let summary = sharded.join();
        assert_eq!(summary.served, 1);
        assert_eq!(summary.items(), 3, "one shard per engine");

        assert_eq!(base.prediction.mean.len(), resp.prediction.mean.len());
        for i in 0..base.prediction.mean.len() {
            assert!(
                (base.prediction.mean[i] - resp.prediction.mean[i]).abs()
                    < 1e-5,
                "mean[{i}]: {} vs {}",
                base.prediction.mean[i],
                resp.prediction.mean[i]
            );
            assert!(
                (base.prediction.std[i] - resp.prediction.std[i]).abs()
                    < 1e-4,
                "std[{i}]"
            );
        }
        // Sharding must cut the modelled per-request hardware latency.
        assert!(
            resp.prediction.model_latency_ms
                < base.prediction.model_latency_ms,
            "{} !< {}",
            resp.prediction.model_latency_ms,
            base.prediction.model_latency_ms
        );
    }

    #[test]
    fn mc_shard_with_more_engines_than_samples_skips_empty_shards() {
        let s = 2;
        let mut fleet = Fleet::start(
            FleetConfig {
                engines: 4,
                router: RouterPolicy::McShard,
                samples: s,
                ..FleetConfig::default()
            },
            fpga_factories(4, s, 1),
        );
        let t = fleet.submit(beat()).unwrap();
        let resp = fleet.wait(t).expect("response");
        assert_eq!(resp.shards, 2, "only non-empty shards dispatched");
        let summary = fleet.join();
        assert_eq!(summary.items(), 2);
    }

    #[test]
    fn shedding_rejects_when_queues_fill() {
        let s = 6; // slow enough that a depth-1 queue backs up
        let mut fleet = Fleet::start(
            FleetConfig {
                engines: 1,
                queue_depth: 1,
                shed: true,
                samples: s,
                ..FleetConfig::default()
            },
            fpga_factories(1, s, 3),
        );
        let mut tickets = Vec::new();
        for _ in 0..64 {
            if let Some(t) = fleet.submit(beat()) {
                tickets.push(t);
            }
        }
        let accepted = tickets.len();
        for t in tickets {
            fleet.wait(t).expect("response");
        }
        let summary = fleet.join();
        assert_eq!(summary.served, accepted);
        assert_eq!(summary.served + summary.rejected, 64);
        assert!(
            summary.rejected > 0,
            "64 instant submits into a depth-1 queue must shed"
        );
        // Engine health counters agree with admission control: each
        // rejected request shed exactly one work item at the single
        // engine, and the depth-1 queue must have filled.
        assert_eq!(summary.per_engine[0].sheds, summary.rejected);
        assert!(summary.per_engine[0].queue_highwater >= 1);
    }

    /// ISSUE 2 acceptance: with `s_max` samples forced (early exit
    /// disabled), the adaptive path is *bit-identical* to the fixed-S
    /// eager path for the same seed — for 1 engine and for N engines
    /// under MC-shard.
    #[test]
    fn adaptive_forced_matches_fixed_path_bitwise_across_engine_counts() {
        use crate::fpga::accel::Accelerator;
        use crate::uq::McAccumulator;
        let s_max = 8;
        let design_seed = 9;
        let mc = AdaptiveMcConfig {
            s_min: 3,
            s_max,
            target_ci: 0.0, // force the full budget
            z: 1.96,
            chunk: 3,
        };

        // Fixed-S reference: eager seeded range on a bare accelerator,
        // reduced the canonical way. Request seed 0 = first fleet id.
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, &mut Rng::new(0));
        let mut accel = Accelerator::new(
            &cfg,
            &params,
            ReuseFactors::new(2, 1, 1),
            design_seed,
        );
        let whole = accel.predict_seeded(&beat(), 0, 0, s_max);
        let mut acc = McAccumulator::new(whole.out_len);
        acc.push_block(0, whole.samples);
        let (fixed_mean, fixed_std) = acc.finalize();

        for (engines, router) in
            [(1usize, RouterPolicy::RoundRobin), (3, RouterPolicy::McShard)]
        {
            let mut fleet = Fleet::start(
                FleetConfig {
                    engines,
                    router,
                    samples: s_max,
                    ..FleetConfig::default()
                },
                fpga_factories(engines, s_max, design_seed),
            );
            let t = fleet.submit_adaptive(beat(), &mc).unwrap();
            let resp = fleet.wait_adaptive(t).expect("adaptive response");
            fleet.join();
            assert_eq!(resp.s_used, s_max, "{engines} engines: no exit");
            assert!(!resp.converged);
            assert_eq!(
                resp.prediction.mean, fixed_mean,
                "{engines} engines: mean must be bit-identical"
            );
            assert_eq!(
                resp.prediction.std, fixed_std,
                "{engines} engines: std must be bit-identical"
            );
            assert_eq!(resp.samples.len(), s_max * resp.out_len);
        }
    }

    #[test]
    fn adaptive_early_exit_saves_samples_in_the_fleet() {
        let s_max = 24;
        let mut fleet = Fleet::start(
            FleetConfig {
                engines: 2,
                router: RouterPolicy::McShard,
                samples: s_max,
                ..FleetConfig::default()
            },
            fpga_factories(2, s_max, 5),
        );
        // Probabilities are bounded in [0, 1]: CI half-width at s = 4
        // is far below 1.0, so this target always converges at s_min.
        let mc = AdaptiveMcConfig {
            s_min: 4,
            s_max,
            target_ci: 1.0,
            z: 1.96,
            chunk: 4,
        };
        let t = fleet.submit_adaptive(beat(), &mc).unwrap();
        let resp = fleet.wait_adaptive(t).expect("adaptive response");
        assert!(resp.converged);
        assert_eq!(resp.s_used, 4, "converges at s_min");
        assert_eq!(resp.rounds, 1);
        assert!(resp.prediction.model_latency_ms > 0.0);
        let summary = fleet.join();
        assert_eq!(summary.served, 1);
        assert_eq!(
            summary.items(),
            2,
            "one 2-sample shard per engine, single round"
        );
        // Adaptive MC accounting: 4 drawn, s_max − s_used = 20 saved.
        assert_eq!(summary.obs.mc_spent, 4);
        assert_eq!(summary.obs.mc_saved, 20);
    }

    /// Head-of-line regression (ROADMAP PR 3 finding a): continuation
    /// rounds are driven by the coordinator thread, so multi-round
    /// adaptive requests submitted together progress concurrently and
    /// can be waited in ANY order — here strictly reverse submit order,
    /// which under waiter-driven rounds would have serialised every
    /// request behind the last-submitted one. Results must still be
    /// bit-identical to the eager fixed-S reference per request.
    #[test]
    fn adaptive_requests_progress_without_waiters_in_any_order() {
        use crate::fpga::accel::Accelerator;
        use crate::uq::McAccumulator;
        let s_max = 9;
        let design_seed = 9;
        let n_req = 6;
        // target_ci 0 forces ceil((s_max - s_min)/chunk) + 1 = 4 rounds.
        let mc = AdaptiveMcConfig {
            s_min: 3,
            s_max,
            target_ci: 0.0,
            z: 1.96,
            chunk: 2,
        };
        let mut fleet = Fleet::start(
            FleetConfig {
                engines: 2,
                router: RouterPolicy::McShard,
                samples: s_max,
                ..FleetConfig::default()
            },
            fpga_factories(2, s_max, design_seed),
        );
        let tickets: Vec<AdaptiveTicket> = (0..n_req)
            .map(|_| fleet.submit_adaptive(beat(), &mc).unwrap())
            .collect();

        // Eager per-request references on a bare accelerator (request
        // seed == submit index).
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, &mut Rng::new(0));
        let mut accel = Accelerator::new(
            &cfg,
            &params,
            ReuseFactors::new(2, 1, 1),
            design_seed,
        );
        let mut want = Vec::new();
        for req in 0..n_req as u64 {
            let whole = accel.predict_seeded(&beat(), req, 0, s_max);
            let mut acc = McAccumulator::new(whole.out_len);
            acc.push_block(0, whole.samples);
            want.push(acc.finalize());
        }

        // Wait in reverse submit order.
        for (i, t) in tickets.into_iter().enumerate().rev().collect::<Vec<_>>()
        {
            let resp = fleet.wait_adaptive(t).expect("adaptive response");
            assert_eq!(resp.s_used, s_max);
            assert_eq!(resp.rounds, 4, "request {i}: forced round count");
            let (ref m, ref s) = want[i];
            assert_eq!(&resp.prediction.mean, m, "request {i}: mean");
            assert_eq!(&resp.prediction.std, s, "request {i}: std");
        }
        let summary = fleet.join();
        assert_eq!(summary.served, n_req);
    }

    /// Requests complete inside the fleet even if nobody waits before
    /// join (the coordinator drains in-flight adaptive work).
    #[test]
    fn join_drains_unwaited_adaptive_requests() {
        let s_max = 6;
        let mc = AdaptiveMcConfig {
            s_min: 2,
            s_max,
            target_ci: 0.0,
            z: 1.96,
            chunk: 2,
        };
        let mut fleet = Fleet::start(
            FleetConfig {
                engines: 1,
                samples: s_max,
                ..FleetConfig::default()
            },
            fpga_factories(1, s_max, 3),
        );
        let _unwaited = fleet.submit_adaptive(beat(), &mc).unwrap();
        // join must not deadlock; the unwaited request is simply not
        // counted as served.
        let summary = fleet.join();
        assert_eq!(summary.served, 0);
        // Its work items were still executed by the engine.
        assert!(summary.items() >= 1);
    }

    #[test]
    fn per_request_sample_counts_are_honoured() {
        let mut fleet = Fleet::start(
            FleetConfig {
                engines: 1,
                samples: 2,
                ..FleetConfig::default()
            },
            fpga_factories(1, 2, 3),
        );
        let small = fleet.submit_with_samples(beat(), 1).unwrap();
        let big = fleet.submit_with_samples(beat(), 6).unwrap();
        let r_small = fleet.wait(small).expect("response");
        let r_big = fleet.wait(big).expect("response");
        // S = 1 has no spread; S = 6 on a Bayesian layer does.
        assert!(r_small.prediction.std.iter().all(|&v| v == 0.0));
        assert!(r_big.prediction.std.iter().any(|&v| v > 0.0));
        // More samples cost more simulated hardware time.
        assert!(
            r_big.prediction.model_latency_ms
                > r_small.prediction.model_latency_ms
        );
        fleet.join();
    }

    /// A worker forming multi-request batches (one blocked engine call
    /// per batch, bounded by a row budget) must produce bit-identical
    /// predictions to the streamed per-request path.
    #[test]
    fn batched_worker_blocked_calls_match_streamed_results() {
        let s = 6;
        let n_req = 8;
        let mut stream = Fleet::start(
            FleetConfig { engines: 1, samples: s, ..FleetConfig::default() },
            fpga_factories(1, s, 9),
        );
        let tickets: Vec<Ticket> =
            (0..n_req).filter_map(|_| stream.submit(beat())).collect();
        let base: Vec<Prediction> = tickets
            .into_iter()
            .map(|t| stream.wait(t).expect("response").prediction)
            .collect();
        stream.join();

        let mut batched = Fleet::start(
            FleetConfig {
                engines: 1,
                samples: s,
                policy: BatchPolicy::batched_rows(
                    4,
                    Duration::from_millis(5),
                    4 * s,
                ),
                ..FleetConfig::default()
            },
            fpga_factories(1, s, 9),
        );
        let tickets: Vec<Ticket> =
            (0..n_req).filter_map(|_| batched.submit(beat())).collect();
        let got: Vec<Prediction> = tickets
            .into_iter()
            .map(|t| batched.wait(t).expect("response").prediction)
            .collect();
        let summary = batched.join();
        assert_eq!(summary.served, n_req);
        for (i, (b, g)) in base.iter().zip(&got).enumerate() {
            assert_eq!(b.mean, g.mean, "request {i}: mean must be bitwise");
            assert_eq!(b.std, g.std, "request {i}: std must be bitwise");
        }
    }

    /// The observability acceptance contract: enabling obs must not
    /// perturb predictions (bitwise), and the collected stage stats
    /// must be internally consistent — one sample per work item per
    /// stage, and no queue-stage duration can exceed the longest
    /// request end-to-end time that contains it.
    #[test]
    fn obs_enabled_is_bit_identical_and_stages_are_consistent() {
        let s = 6;
        let n_req = 8;
        let run = |obs: ObsConfig| -> (Vec<Prediction>, FleetSummary) {
            let mut fleet = Fleet::start(
                FleetConfig {
                    engines: 2,
                    router: RouterPolicy::McShard,
                    samples: s,
                    obs,
                    ..FleetConfig::default()
                },
                fpga_factories(2, s, 9),
            );
            let tickets: Vec<Ticket> =
                (0..n_req).filter_map(|_| fleet.submit(beat())).collect();
            let preds = tickets
                .into_iter()
                .map(|t| fleet.wait(t).expect("response").prediction)
                .collect();
            (preds, fleet.join())
        };
        let (base, plain) = run(ObsConfig::default());
        let (observed, summary) = run(ObsConfig::on());
        for (i, (b, o)) in base.iter().zip(&observed).enumerate() {
            assert_eq!(b.mean, o.mean, "request {i}: obs changed the mean");
            assert_eq!(b.std, o.std, "request {i}: obs changed the std");
        }
        assert_eq!(plain.served, summary.served);

        assert!(summary.obs.enabled);
        assert_eq!(summary.obs.e2e.count() as usize, n_req);
        assert_eq!(summary.obs.merge.count() as usize, n_req);
        // Per engine: one stage sample per completed work item.
        for (j, e) in summary.per_engine.iter().enumerate() {
            let st = e.stages.as_ref().expect("stages collected");
            assert_eq!(st.queue.count() as usize, e.served, "engine {j}");
            assert_eq!(st.batch.count() as usize, e.served, "engine {j}");
            assert_eq!(st.compute.count() as usize, e.served, "engine {j}");
            assert_eq!(e.mc_rows, e.served * s / 2, "engine {j}: s/2 shards");
            assert!(e.peak_batch >= 1, "engine {j}");
        }
        // Fleet merge covers every item, and stage durations nest
        // inside request e2e: every queue interval is contained in its
        // request's [submit, reply] window.
        let stages = summary.stage_stats();
        assert_eq!(stages.queue.count() as usize, summary.items());
        assert!(
            stages.queue.max_ms() <= summary.obs.e2e.max_ms(),
            "queue stage {} ms cannot exceed the slowest request {} ms",
            stages.queue.max_ms(),
            summary.obs.e2e.max_ms()
        );
    }

    /// JSONL trace integration: a traced fleet writes parseable events
    /// covering every stage of a request's life, with non-decreasing
    /// log-relative timestamps per request.
    #[test]
    fn trace_log_captures_full_request_lifecycle() {
        use crate::obs::TraceLog;
        let path = std::env::temp_dir().join(format!(
            "repro_fleet_trace_{}.jsonl",
            std::process::id()
        ));
        let trace = Arc::new(TraceLog::create(&path).expect("trace file"));
        let s = 4;
        let mut fleet = Fleet::start(
            FleetConfig {
                engines: 2,
                router: RouterPolicy::McShard,
                samples: s,
                obs: ObsConfig {
                    enabled: true,
                    trace: Some(Arc::clone(&trace)),
                    window: None,
                },
                ..FleetConfig::default()
            },
            fpga_factories(2, s, 9),
        );
        let tickets: Vec<Ticket> =
            (0..3).filter_map(|_| fleet.submit(beat())).collect();
        for t in tickets {
            fleet.wait(t).expect("response");
        }
        fleet.join();
        trace.flush();

        let text = std::fs::read_to_string(&path).expect("read trace");
        let mut by_req: HashMap<u64, Vec<(String, u64)>> = HashMap::new();
        for line in text.lines() {
            let j = crate::jsonio::parse(line).expect("valid JSONL event");
            let req =
                j.req_usize("req").expect("req id") as u64;
            let stage = j.req_str("stage").expect("stage").to_string();
            let at = j.req_usize("at_us").expect("at_us") as u64;
            by_req.entry(req).or_default().push((stage, at));
        }
        assert_eq!(by_req.len(), 3, "one event stream per request");
        for (req, events) in &by_req {
            for want in
                ["submit", "queue", "batch", "compute", "merge", "reply"]
            {
                assert!(
                    events.iter().any(|(s, _)| s == want),
                    "request {req}: missing {want} event"
                );
            }
            assert_eq!(events[0].0, "submit", "request {req}");
            assert_eq!(
                events.last().unwrap().0,
                "reply",
                "request {req}: reply is stamped last"
            );
            assert!(
                events.windows(2).all(|w| w[0].1 <= w[1].1),
                "request {req}: at_us must be non-decreasing"
            );
        }
        let _ = std::fs::remove_file(&path);
    }

    /// Coordinated-omission regression: under overload, an open-loop
    /// measurement (every request stamped with its scheduled arrival —
    /// here, all due at t0) must report a far worse p99 than the
    /// closed-loop submit-then-wait pattern over the same work, because
    /// the closed loop silently forgives queueing delay by only
    /// submitting after the previous response returned.
    #[test]
    fn open_loop_overload_p99_exceeds_closed_loop_p99() {
        let s = 6;
        let n_req = 24;
        let mut closed = Fleet::start(
            FleetConfig { samples: s, ..FleetConfig::default() },
            fpga_factories(1, s, 11),
        );
        let mut closed_e2e = LatencyStats::new();
        for _ in 0..n_req {
            let t = closed.submit(beat()).unwrap();
            closed_e2e.record_ms(closed.wait(t).expect("response").e2e_ms);
        }
        closed.join();

        let mut open = Fleet::start(
            FleetConfig { samples: s, ..FleetConfig::default() },
            fpga_factories(1, s, 11),
        );
        let t0 = Instant::now();
        let tickets: Vec<Ticket> = (0..n_req)
            .map(|_| {
                open.submit_with_samples_at(beat(), s, t0).unwrap()
            })
            .collect();
        let mut open_e2e = LatencyStats::new();
        for t in tickets {
            open_e2e.record_ms(open.wait(t).expect("response").e2e_ms);
        }
        open.join();

        let closed_p99 = closed_e2e.percentile_ms(99.0);
        let open_p99 = open_e2e.percentile_ms(99.0);
        // The last open-loop request queued behind ~23 others, so its
        // e2e is many service times; the closed-loop p99 is about one.
        // 2x is a deliberately loose bound for CI-machine noise.
        assert!(
            open_p99 > closed_p99 * 2.0,
            "open-loop p99 {open_p99} ms must exceed closed-loop \
             p99 {closed_p99} ms under overload"
        );
    }

    /// Windowed timeline accounting: every request and work item lands
    /// in exactly one window, and summing the windows reproduces the
    /// whole-run aggregates bit-for-bit (the same merge contract the
    /// whole-run histograms obey).
    #[test]
    fn windowed_timeline_accounts_for_every_request() {
        let s = 6;
        let n_req = 8;
        let mut fleet = Fleet::start(
            FleetConfig {
                engines: 2,
                router: RouterPolicy::McShard,
                samples: s,
                obs: ObsConfig::on_windowed(Duration::from_millis(50)),
                ..FleetConfig::default()
            },
            fpga_factories(2, s, 9),
        );
        let tickets: Vec<Ticket> =
            (0..n_req).filter_map(|_| fleet.submit(beat())).collect();
        for t in tickets {
            fleet.wait(t).expect("response");
        }
        let summary = fleet.join();
        let tl = summary.timeline.as_ref().expect("windowed timeline");
        assert_eq!(
            tl.e2e.total(),
            summary.obs.e2e,
            "window slices must sum to the whole-run e2e histogram"
        );
        assert_eq!(tl.served.total() as usize, n_req);
        assert_eq!(tl.submitted.total() as usize, n_req);
        assert_eq!(tl.rejected.total(), 0);
        assert_eq!(tl.items.total() as usize, summary.items());
        assert_eq!(
            tl.queue.total().count() as usize,
            summary.items(),
            "one queue-stage sample per work item"
        );
        assert_eq!(summary.obs.trace_dropped, 0, "no trace, no drops");
    }

    /// ISSUE 8 fleet-level acceptance: the same request (same request
    /// seed — fresh fleets restart ids at 0) served with the bank cold,
    /// then warm, then split across a 3-engine MC-shard fleet, returns
    /// bit-identical samples and predictions to a bank-off fleet, with
    /// all-miss on the cold pass and all-hit on the warm passes. The
    /// adaptive path's ordered reduction gives the bitwise comparison
    /// across engine counts (the fixed path merges shard moments in
    /// arrival order).
    #[test]
    fn mask_bank_cold_warm_and_shard_splits_are_bit_identical() {
        use crate::kernels::MaskBank;
        let s_max = 8;
        let design_seed = 9;
        // target_ci 0 forces the full budget: every k in 0..8 is drawn
        // exactly once, whatever the round/shard split.
        let mc = AdaptiveMcConfig {
            s_min: 3,
            s_max,
            target_ci: 0.0,
            z: 1.96,
            chunk: 3,
        };
        let run = |factories: Vec<
            Box<dyn FnOnce() -> Engine + Send + 'static>,
        >,
                   router: RouterPolicy| {
            let engines = factories.len();
            let mut fleet = Fleet::start(
                FleetConfig {
                    engines,
                    router,
                    samples: s_max,
                    ..FleetConfig::default()
                },
                factories,
            );
            let t = fleet.submit_adaptive(beat(), &mc).unwrap();
            let resp = fleet.wait_adaptive(t).expect("adaptive response");
            fleet.join();
            assert_eq!(resp.s_used, s_max);
            (resp.samples, resp.prediction)
        };

        let (base_samples, base_pred) = run(
            fpga_factories(1, s_max, design_seed),
            RouterPolicy::RoundRobin,
        );

        // tiny_cfg has one Bayesian layer: one bank key per sample lane.
        let bank = Arc::new(MaskBank::new(1 << 20));
        let (cold_samples, cold_pred) = run(
            banked_factories(1, s_max, design_seed, &bank),
            RouterPolicy::RoundRobin,
        );
        assert_eq!(cold_samples, base_samples, "cold bank changed bits");
        assert_eq!(cold_pred.mean, base_pred.mean);
        assert_eq!(cold_pred.std, base_pred.std);
        let cold = bank.stats();
        assert_eq!(cold.hits, 0, "fresh bank cannot hit");
        assert_eq!(cold.misses, s_max as u64, "one miss per sample lane");
        assert!(cold.resident_bytes > 0);

        // Same request seed again (fresh fleet, id restarts at 0):
        // every lane's masks come out of the bank.
        let (warm_samples, _) = run(
            banked_factories(1, s_max, design_seed, &bank),
            RouterPolicy::RoundRobin,
        );
        assert_eq!(warm_samples, base_samples, "warm bank changed bits");
        let warm = bank.stats();
        assert_eq!(warm.hits, s_max as u64, "warm pass must be all-hit");
        assert_eq!(warm.misses, cold.misses, "warm pass adds no misses");

        // 1-vs-3-engine MC-shard split over the warm bank: the shards
        // cover the same 8 sample lanes, so same bits and 8 more hits.
        let (shard_samples, shard_pred) = run(
            banked_factories(3, s_max, design_seed, &bank),
            RouterPolicy::McShard,
        );
        assert_eq!(
            shard_samples, base_samples,
            "3-engine MC-shard split over the bank changed bits"
        );
        assert_eq!(shard_pred.mean, base_pred.mean);
        assert_eq!(shard_pred.std, base_pred.std);
        let sharded = bank.stats();
        assert_eq!(sharded.hits, 2 * s_max as u64);
        assert_eq!(sharded.misses, cold.misses);
    }

    #[test]
    fn least_loaded_fleet_completes() {
        let s = 2;
        let mut fleet = Fleet::start(
            FleetConfig {
                engines: 3,
                router: RouterPolicy::LeastLoaded,
                samples: s,
                ..FleetConfig::default()
            },
            fpga_factories(3, s, 7),
        );
        let tickets: Vec<Ticket> =
            (0..9).filter_map(|_| fleet.submit(beat())).collect();
        for t in tickets {
            fleet.wait(t).expect("response");
        }
        let summary = fleet.join();
        assert_eq!(summary.served, 9);
        assert_eq!(summary.items(), 9);
    }

    /// A longer signal for streaming tests: `n` values of a slow sine
    /// (tiny_cfg's seq_len is 20, so 60 values = 3 beats).
    fn stream_signal(n: usize) -> Vec<f32> {
        (0..n).map(|i| (i as f32 * 0.21).sin()).collect()
    }

    /// Open one session, push `chunks` through it, and return the
    /// per-beat sample vectors plus the join summary.
    fn collect_stream(
        policy: RouterPolicy,
        engines: usize,
        s: usize,
        chunks: &[&[f32]],
        session_bytes: usize,
    ) -> (Vec<Vec<f32>>, FleetSummary) {
        let mut fleet = Fleet::start(
            FleetConfig {
                engines,
                router: policy,
                samples: s,
                session_bytes: Some(session_bytes),
                ..FleetConfig::default()
            },
            fpga_factories(engines, s, 5),
        );
        let sid = fleet.open_session().expect("session plane on");
        let mut beats = Vec::new();
        for chunk in chunks {
            let t = fleet
                .submit_chunk(sid, chunk.to_vec())
                .expect("chunk admitted");
            let resp = fleet.wait_chunk(t).expect("chunk served");
            for b in resp.beats {
                assert_eq!(b.s, s, "every beat carries all S lanes");
                beats.push(b.samples);
            }
        }
        fleet.close_session(sid).expect("close drains");
        (beats, fleet.join())
    }

    /// The streaming headline invariant: any chunking on any engine
    /// count (affinity-pinned or MC-shard split) reproduces the
    /// continuous single-engine pass bit for bit.
    #[test]
    fn streamed_chunks_equal_one_shot_for_any_engine_count() {
        let s = 6;
        let signal = stream_signal(60); // 3 beats
        let (whole, _) = collect_stream(
            RouterPolicy::Affinity,
            1,
            s,
            &[&signal],
            1 << 20,
        );
        assert_eq!(whole.len(), 3, "60 timesteps = 3 decisions");

        // Ragged chunk boundaries that straddle beats.
        let parts: [&[f32]; 3] =
            [&signal[..7], &signal[7..33], &signal[33..]];
        let (chunked, summary) = collect_stream(
            RouterPolicy::Affinity,
            1,
            s,
            &parts,
            1 << 20,
        );
        assert_eq!(chunked, whole, "chunking changed bits");
        let stats = summary.obs.sessions.expect("session stats");
        assert_eq!(stats.chunks, 3);
        assert_eq!((stats.opened, stats.closed), (1, 1));

        // Same chunks, 3-engine MC-shard split (2 lanes per engine);
        // the session seed is the sid (0) in every fleet and the
        // factories share the design seed, so the merged lane ranges
        // must reproduce the same bits.
        let (sharded, _) = collect_stream(
            RouterPolicy::McShard,
            3,
            s,
            &parts,
            1 << 20,
        );
        assert_eq!(sharded, whole, "mc-shard streaming changed bits");
    }

    /// A zero-byte budget forces an eviction after every chunk; replay
    /// rebuilds must reproduce the resident bits and the counters must
    /// record the thrash.
    #[test]
    fn zero_budget_thrash_replays_and_matches_resident() {
        let s = 4;
        let signal = stream_signal(60);
        let parts: [&[f32]; 3] =
            [&signal[..7], &signal[7..33], &signal[33..]];
        let (resident, _) = collect_stream(
            RouterPolicy::Affinity,
            1,
            s,
            &parts,
            1 << 20,
        );
        let (thrash, summary) =
            collect_stream(RouterPolicy::Affinity, 1, s, &parts, 0);
        assert_eq!(thrash, resident, "replay rebuild changed bits");
        let stats = summary.obs.sessions.expect("session stats");
        assert!(
            stats.evictions >= 2,
            "zero budget must evict after parks: {stats:?}"
        );
        assert!(
            stats.replay_rebuilds >= 2,
            "chunks 2 and 3 must rebuild by replay: {stats:?}"
        );
    }

    /// Without `session_bytes` the plane is off: typed error on open,
    /// oneshot serving untouched, no session stats in the summary.
    #[test]
    fn session_plane_disabled_by_default() {
        let s = 2;
        let mut fleet = Fleet::start(
            FleetConfig { engines: 1, samples: s, ..FleetConfig::default() },
            fpga_factories(1, s, 5),
        );
        assert!(!fleet.streaming_enabled());
        assert_eq!(fleet.open_session(), Err(SessionError::Disabled));
        let t = fleet.submit(beat()).unwrap();
        fleet.wait(t).expect("oneshot path unaffected");
        let summary = fleet.join();
        assert_eq!(summary.served, 1);
        assert!(summary.obs.sessions.is_none());
    }

    /// The adaptive tier escalates an uncertain chunk to `s_max` lanes
    /// and the merged samples match an always-`s_max` session bitwise.
    #[test]
    fn adaptive_stream_boosts_uncertain_chunks() {
        let s = 2;
        let mc = AdaptiveMcConfig {
            s_min: 2,
            s_max: 8,
            target_ci: 1e-6, // effectively: always too uncertain
            z: 1.96,
            chunk: 2,
        };
        let mut fleet = Fleet::start(
            FleetConfig {
                engines: 1,
                router: RouterPolicy::Affinity,
                samples: s,
                session_bytes: Some(1 << 20),
                session_uq: Some(mc),
                ..FleetConfig::default()
            },
            fpga_factories(1, s, 5),
        );
        let sid = fleet.open_session().unwrap();
        let t = fleet.submit_chunk(sid, beat()).unwrap();
        let resp = fleet.wait_chunk(t).expect("chunk served");
        assert!(resp.boosted, "tiny CI target must trigger the boost");
        assert_eq!(resp.beats.len(), 1);
        assert_eq!(resp.beats[0].s, 8, "boost tops the beat up to s_max");
        fleet.close_session(sid).unwrap();
        let summary = fleet.join();
        let stats = summary.obs.sessions.expect("session stats");
        assert_eq!(stats.boosted_chunks, 1);
        assert_eq!((stats.opened, stats.closed), (1, 1));

        // Bitwise: the boosted beat equals the same beat streamed at
        // S = 8 outright (lane state is per-lane pure, so lanes 2..8
        // computed by replay match lanes 2..8 computed inline).
        let sig = beat();
        let (full, _) = collect_stream(
            RouterPolicy::Affinity,
            1,
            8,
            &[&sig],
            1 << 20,
        );
        assert_eq!(resp.beats[0].samples, full[0]);
    }

    /// `close_session` blocks until in-flight chunks park; afterwards
    /// the session is gone and further chunks get a typed error.
    #[test]
    fn close_session_drains_inflight_chunks() {
        let s = 2;
        let mut fleet = Fleet::start(
            FleetConfig {
                engines: 1,
                router: RouterPolicy::Affinity,
                samples: s,
                session_bytes: Some(1 << 20),
                ..FleetConfig::default()
            },
            fpga_factories(1, s, 5),
        );
        let sid = fleet.open_session().unwrap();
        let tickets: Vec<ChunkTicket> = (0..4)
            .map(|_| fleet.submit_chunk(sid, beat()).unwrap())
            .collect();
        // Close before waiting: must block until all four chunks have
        // parked, not hang and not race ahead of them.
        fleet.close_session(sid).expect("close drains in-flight work");
        for t in tickets {
            let resp = fleet.wait_chunk(t).expect("chunk served");
            assert_eq!(resp.beats.len(), 1);
        }
        assert_eq!(
            fleet.submit_chunk(sid, beat()).err(),
            Some(SessionError::Unknown(sid))
        );
        let summary = fleet.join();
        let stats = summary.obs.sessions.expect("session stats");
        assert_eq!(stats.chunks, 4);
        assert_eq!(stats.resident, 0);
    }

    /// Chaos acceptance: killing one of three MC-shard engines loses no
    /// request, and every merged prediction is *bit-identical* to the
    /// fault-free run — deterministic per-(request, sample) mask
    /// seeding means a re-executed shard lands the same bits wherever
    /// it runs, and the sorted-by-start merge is arrival-order-free.
    #[test]
    fn chaos_kill_redispatches_and_matches_fault_free_bitwise() {
        let s = 6;
        let k = 6;
        let run = |chaos: Option<FaultPlan>| {
            let mut fleet = Fleet::start(
                FleetConfig {
                    engines: 3,
                    router: RouterPolicy::McShard,
                    samples: s,
                    chaos,
                    ..FleetConfig::default()
                },
                fpga_factories(3, s, 9),
            );
            let mut preds = Vec::new();
            for _ in 0..k {
                let t = fleet.submit(beat()).expect("no shedding");
                let resp = fleet.wait(t).expect("request survives kill");
                preds.push((resp.prediction.mean, resp.prediction.std));
            }
            (preds, fleet.join())
        };
        let (clean, _) = run(None);
        let plan = FaultPlan::parse("kill=e1@0ms").expect("plan");
        let (chaotic, summary) = run(Some(plan));
        assert_eq!(chaotic, clean, "fault recovery changed bits");
        assert_eq!(summary.served, k, "every request completed");
        let faults = summary.obs.faults;
        assert_eq!(faults.workers_lost, 1, "{faults:?}");
        assert!(faults.shards_redispatched >= 1, "{faults:?}");
        assert_eq!(summary.per_engine.len(), 3, "dead slot kept");
    }

    /// A stalled engine's shard is hedged onto a survivor once it blows
    /// past the latency deadline; first reply wins and the merged
    /// output still matches the fault-free run bitwise.
    #[test]
    fn chaos_stall_hedges_straggler_shards() {
        let s = 6;
        let run = |chaos: Option<FaultPlan>| {
            let mut fleet = Fleet::start(
                FleetConfig {
                    engines: 3,
                    router: RouterPolicy::McShard,
                    samples: s,
                    chaos,
                    ..FleetConfig::default()
                },
                fpga_factories(3, s, 9),
            );
            let t = fleet.submit(beat()).expect("no shedding");
            let resp = fleet.wait(t).expect("request survives stall");
            (resp.prediction.mean, resp.prediction.std, fleet.join())
        };
        let (mean, std, _) = run(None);
        // 300 ms stall vs a 25 ms hedge floor: the hedge must fire and
        // its reply must land long before the straggler wakes.
        let plan = FaultPlan::parse("stall=e1@0ms+300ms").expect("plan");
        let (m2, s2, summary) = run(Some(plan));
        assert_eq!((m2, s2), (mean, std), "hedged merge changed bits");
        let faults = summary.obs.faults;
        assert!(faults.hedges_fired >= 1, "{faults:?}");
        assert!(faults.hedges_won >= 1, "{faults:?}");
        assert_eq!(faults.workers_lost, 0, "stall is not a death");
    }

    /// Killing the engine a streaming session is pinned to must repin
    /// the session to a survivor and replay-rebuild its lane state —
    /// chunked output stays bit-identical to the fault-free one-shot.
    #[test]
    fn chaos_kill_pinned_engine_repins_session_and_replays() {
        let s = 4;
        let signal = stream_signal(60);
        let parts: [&[f32]; 3] =
            [&signal[..7], &signal[7..33], &signal[33..]];
        let (whole, _) = collect_stream(
            RouterPolicy::Affinity,
            1,
            s,
            &[&signal],
            1 << 20,
        );

        // Two engines; the fresh session pins to least-loaded e0,
        // which the plan kills immediately.
        let plan = FaultPlan::parse("kill=e0@0ms").expect("plan");
        let mut fleet = Fleet::start(
            FleetConfig {
                engines: 2,
                router: RouterPolicy::Affinity,
                samples: s,
                session_bytes: Some(1 << 20),
                chaos: Some(plan),
                ..FleetConfig::default()
            },
            fpga_factories(2, s, 5),
        );
        let sid = fleet.open_session().expect("session plane on");
        let mut beats = Vec::new();
        for (i, chunk) in parts.iter().enumerate() {
            if i == 1 {
                // Give the obituary time to land so the repin happens
                // on the submit path (not only dispatch diversion).
                thread::sleep(Duration::from_millis(30));
            }
            let t = fleet
                .submit_chunk(sid, chunk.to_vec())
                .expect("chunk admitted");
            let resp = fleet.wait_chunk(t).expect("chunk survives kill");
            for b in resp.beats {
                beats.push(b.samples);
            }
        }
        fleet.close_session(sid).expect("close drains");
        let summary = fleet.join();
        assert_eq!(beats, whole, "re-pinned replay changed bits");
        let faults = summary.obs.faults;
        assert_eq!(faults.workers_lost, 1, "{faults:?}");
        assert!(faults.sessions_repinned >= 1, "{faults:?}");
    }

    /// With every reply dropped, the waiter must give up at the
    /// configured timeout with a typed degraded error instead of
    /// hanging forever — lost replies are observable, not silent.
    #[test]
    fn dropped_replies_surface_as_typed_degraded_error() {
        let s = 2;
        let plan = FaultPlan::parse("drop=1.0").expect("plan");
        let mut fleet = Fleet::start(
            FleetConfig {
                engines: 1,
                samples: s,
                chaos: Some(plan),
                wait_timeout: Some(Duration::from_millis(150)),
                ..FleetConfig::default()
            },
            fpga_factories(1, s, 5),
        );
        let t = fleet.submit(beat()).expect("admitted");
        match fleet.wait(t) {
            Err(FleetError::Degraded { got, expected, .. }) => {
                assert_eq!((got, expected), (0, 1));
            }
            other => panic!("expected Degraded, got {other:?}"),
        }
        let summary = fleet.join();
        assert_eq!(summary.served, 0);
        let faults = summary.obs.faults;
        assert_eq!(faults.replies_dropped, 1, "{faults:?}");
    }

    /// Satellite (a): `Fleet::join` survives a worker panic — the dead
    /// engine keeps a placeholder per-engine slot and the survivors'
    /// stats are intact.
    #[test]
    fn join_survives_worker_panic() {
        let s = 2;
        let plan = FaultPlan::parse("kill=e1@0ms").expect("plan");
        let mut fleet = Fleet::start(
            FleetConfig {
                engines: 2,
                samples: s,
                chaos: Some(plan),
                ..FleetConfig::default()
            },
            fpga_factories(2, s, 5),
        );
        for _ in 0..4 {
            let t = fleet.submit(beat()).expect("admitted");
            fleet.wait(t).expect("survivor serves everything");
        }
        let summary = fleet.join();
        assert_eq!(summary.served, 4);
        assert_eq!(summary.per_engine.len(), 2, "dead slot kept");
        assert_eq!(summary.per_engine[1].kernel, "lost");
        assert_eq!(summary.per_engine[1].served, 0);
        assert!(summary.per_engine[0].served >= 1);
        assert_eq!(summary.obs.faults.workers_lost, 1);
    }
}
