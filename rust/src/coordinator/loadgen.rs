//! Open-loop load generator: Poisson arrivals of ECG beats, as a hospital
//! telemetry stream would produce them (the paper's "requests need to be
//! processed as soon as they arrive"). Closed-loop benchmarks (submit-all,
//! wait-all) hide queueing behaviour; an open-loop arrival process
//! exposes the latency knee as offered load approaches engine capacity.

use std::time::{Duration, Instant};

use super::fleet::{ChunkTicket, Fleet, Ticket};
use super::router::RouterPolicy;
use super::session::SessionError;
use super::stats::LatencyStats;
use crate::data::Dataset;
use crate::obs::{window_index, WindowedCount};
use crate::rng::Rng;

/// A generated arrival: offset from stream start + the beat payload index.
#[derive(Debug, Clone, Copy)]
pub struct Arrival {
    pub at: Duration,
    pub beat_idx: usize,
}

/// Poisson-process arrival trace over a dataset.
pub struct PoissonTrace {
    pub arrivals: Vec<Arrival>,
    pub rate_per_s: f64,
}

impl PoissonTrace {
    /// `rate_per_s` mean arrivals/second for `n` requests, beats drawn
    /// round-robin from the dataset.
    pub fn generate(rate_per_s: f64, n: usize, data: &Dataset, seed: u64) -> Self {
        assert!(rate_per_s > 0.0);
        let mut rng = Rng::new(seed ^ 0x10AD);
        let mut t = 0.0f64;
        let mut arrivals = Vec::with_capacity(n);
        for i in 0..n {
            // Exponential inter-arrival: -ln(U)/rate.
            let u = loop {
                let u = rng.uniform();
                if u > 1e-12 {
                    break u;
                }
            };
            t += -u.ln() / rate_per_s;
            arrivals.push(Arrival {
                at: Duration::from_secs_f64(t),
                beat_idx: i % data.n,
            });
        }
        Self { arrivals, rate_per_s }
    }

    pub fn duration(&self) -> Duration {
        self.arrivals.last().map(|a| a.at).unwrap_or(Duration::ZERO)
    }

    /// Empirical rate of the generated trace.
    pub fn empirical_rate(&self) -> f64 {
        if self.arrivals.is_empty() {
            return 0.0;
        }
        self.arrivals.len() as f64 / self.duration().as_secs_f64().max(1e-9)
    }
}

/// Replay a trace against a server, sleeping between arrivals (open
/// loop), and return the observed end-to-end latencies.
pub fn replay(
    trace: &PoissonTrace,
    server: &mut super::server::Server,
    data: &Dataset,
) -> Vec<std::sync::mpsc::Receiver<super::server::Response>> {
    let start = std::time::Instant::now();
    let mut receivers = Vec::with_capacity(trace.arrivals.len());
    for a in &trace.arrivals {
        if let Some(wait) = a.at.checked_sub(start.elapsed()) {
            if !wait.is_zero() {
                std::thread::sleep(wait);
            }
        }
        receivers.push(server.submit(data.beat(a.beat_idx).to_vec()));
    }
    receivers
}

/// One weighted payload class in a scenario's request mix (e.g. the
/// `poisson_mix` scenario's light/standard/heavy MC budgets).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PayloadClass {
    pub name: &'static str,
    /// MC samples a request of this class asks for.
    pub samples: usize,
    /// Relative draw weight (normalised by `Rng::categorical`).
    pub weight: f64,
}

/// One scheduled request of an open-loop trace: *when* it is due,
/// which beat it carries and how much MC evidence it wants. `at` is
/// the request's intended arrival — the coordinated-omission-correct
/// e2e clock starts there, whether or not the generator kept up.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScheduledRequest {
    pub at: Duration,
    pub beat_idx: usize,
    pub samples: usize,
    /// Index into the scenario's mix (0 when the mix is empty).
    pub class: usize,
}

/// The named open-loop scenarios `repro loadgen --scenario` accepts.
pub const SCENARIOS: &[&str] = &[
    "baseline",
    "fan_out",
    "fan_in",
    "scaling",
    "poisson_mix",
    "stream_monitor",
];

/// A reusable open-loop load scenario: fleet shape + arrival process +
/// payload mix. Presets cover the serving matrix (`docs/serving.md`);
/// every field stays overridable by the CLI after `preset`.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    pub name: String,
    pub engines: usize,
    pub router: RouterPolicy,
    pub rate_per_s: f64,
    pub requests: usize,
    /// Default MC samples per request (classes override per draw).
    pub samples: usize,
    /// Weighted payload classes; empty = every request at `samples`.
    pub mix: Vec<PayloadClass>,
    pub queue_depth: usize,
    pub shed: bool,
    pub seed: u64,
}

impl ScenarioSpec {
    /// Build a named preset. `engines`/`rate`/`requests`/`samples` are
    /// the caller's baseline; presets adjust topology and policy:
    ///
    /// * `baseline` — one engine, round-robin (the degenerate case).
    /// * `fan_out` — MC-shard across all engines.
    /// * `fan_in` — one engine behind a shallow shedding queue
    ///   (admission-control behaviour under overload).
    /// * `scaling` — least-loaded placement over all engines.
    /// * `poisson_mix` — round-robin with a light/standard/heavy
    ///   payload-class mix.
    /// * `stream_monitor` — long-lived streaming sessions under
    ///   session-affinity routing: `requests` is the total chunk
    ///   count across the monitored sessions, `rate_per_s` the chunk
    ///   arrival rate (the CLI's streaming runner drives the session
    ///   lifecycle — `docs/serving.md` §Streaming sessions).
    pub fn preset(
        name: &str,
        engines: usize,
        rate_per_s: f64,
        requests: usize,
        samples: usize,
        seed: u64,
    ) -> Result<Self, String> {
        let mut spec = Self {
            name: name.to_string(),
            engines,
            router: RouterPolicy::RoundRobin,
            rate_per_s,
            requests,
            samples,
            mix: Vec::new(),
            queue_depth: super::DEFAULT_QUEUE_DEPTH,
            shed: false,
            seed,
        };
        match name {
            "baseline" => spec.engines = 1,
            "fan_out" => spec.router = RouterPolicy::McShard,
            "fan_in" => {
                spec.engines = 1;
                spec.shed = true;
                spec.queue_depth = 8;
            }
            "scaling" => spec.router = RouterPolicy::LeastLoaded,
            "stream_monitor" => spec.router = RouterPolicy::Affinity,
            "poisson_mix" => {
                spec.mix = vec![
                    PayloadClass {
                        name: "light",
                        samples: (samples / 4).max(1),
                        weight: 0.6,
                    },
                    PayloadClass {
                        name: "standard",
                        samples,
                        weight: 0.3,
                    },
                    PayloadClass {
                        name: "heavy",
                        samples: samples * 2,
                        weight: 0.1,
                    },
                ];
            }
            other => {
                return Err(format!(
                    "unknown scenario '{other}' (expected one of {})",
                    SCENARIOS.join(", ")
                ))
            }
        }
        Ok(spec)
    }

    /// Generate the deterministic arrival schedule: seeded Poisson
    /// inter-arrivals, beats round-robin over the dataset, payload
    /// class drawn per request from the mix. Same spec + seed ⇒ same
    /// schedule, byte for byte.
    pub fn trace(&self, data_n: usize) -> Vec<ScheduledRequest> {
        assert!(self.rate_per_s > 0.0, "rate must be positive");
        assert!(data_n > 0, "dataset must be non-empty");
        let mut rng = Rng::new(self.seed ^ 0x5CE7_A210);
        let weights: Vec<f64> =
            self.mix.iter().map(|c| c.weight).collect();
        let mut t = 0.0f64;
        let mut out = Vec::with_capacity(self.requests);
        for i in 0..self.requests {
            let u = loop {
                let u = rng.uniform();
                if u > 1e-12 {
                    break u;
                }
            };
            t += -u.ln() / self.rate_per_s;
            let (class, samples) = if self.mix.is_empty() {
                (0, self.samples)
            } else {
                let c = rng.categorical(&weights);
                (c, self.mix[c].samples)
            };
            out.push(ScheduledRequest {
                at: Duration::from_secs_f64(t),
                beat_idx: i % data_n,
                samples,
                class,
            });
        }
        out
    }
}

/// What an open-loop run produced, before waiting on the replies.
#[derive(Default)]
pub struct OpenLoopOutcome {
    /// Accepted tickets with the request's payload-class index.
    pub tickets: Vec<(Ticket, usize)>,
    /// Requests the schedule offered (= trace length).
    pub offered: usize,
    /// Requests the fleet admitted.
    pub submitted: usize,
    /// Requests shed at submit by admission control.
    pub rejected_at_submit: usize,
    /// Generator lag: how late each submit ran past its scheduled
    /// arrival. A p99 here near zero certifies the generator kept up —
    /// large values mean offered load outran the *generator*, not the
    /// fleet, and the run should be rerun at a lower rate.
    pub lag: LatencyStats,
    /// Offered arrivals per timeline window (scheduled times, aligned
    /// to the fleet epoch) — the "offered vs achieved" numerator.
    pub offered_per_window: WindowedCount,
}

/// Replay a scheduled trace against a fleet, open loop: sleep until
/// each request's due time, then submit stamped with the *scheduled*
/// arrival (coordinated-omission-correct — queueing delay the fleet
/// causes shows up in e2e even if the generator fell behind). Callers
/// wait on the returned tickets and then `join` the fleet.
pub fn run_open_loop(
    fleet: &mut Fleet,
    trace: &[ScheduledRequest],
    data: &Dataset,
) -> OpenLoopOutcome {
    let win = fleet.obs_window();
    let mut out = OpenLoopOutcome {
        offered: trace.len(),
        ..OpenLoopOutcome::default()
    };
    let start = Instant::now();
    for r in trace {
        let target = start + r.at;
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        let submit_t = Instant::now();
        out.lag.record(submit_t.saturating_duration_since(target));
        if let Some((epoch, width)) = win {
            // Offered load is attributed to the *scheduled* window:
            // the demand curve must not smear when the generator slips.
            out.offered_per_window
                .inc(window_index(epoch, width, target));
        }
        match fleet.submit_with_samples_at(
            data.beat(r.beat_idx).to_vec(),
            r.samples,
            target,
        ) {
            Some(ticket) => {
                out.submitted += 1;
                out.tickets.push((ticket, r.class));
            }
            None => out.rejected_at_submit += 1,
        }
    }
    out
}

/// What a streaming open-loop run produced, before waiting on chunks.
pub struct StreamLoopOutcome {
    /// Chunk tickets in submit order; callers `wait_chunk` these.
    pub tickets: Vec<ChunkTicket>,
    /// Sessions the runner opened, to `close_session` once the chunks
    /// are drained.
    pub sids: Vec<u64>,
    /// Chunks the schedule offered (= trace length). Sessions bypass
    /// admission shedding, so offered == submitted here.
    pub offered: usize,
    /// Generator lag, as in [`OpenLoopOutcome::lag`].
    pub lag: LatencyStats,
    /// Offered chunk arrivals per timeline window (scheduled times).
    pub offered_per_window: WindowedCount,
}

/// Replay a scheduled trace as *streaming session chunks*, open loop:
/// `n_sessions` long-lived sessions are opened up front and the trace's
/// arrivals become their chunks round-robin — session `k` receives
/// every `n_sessions`-th beat as the next chunk of its monitored
/// signal, so per-session chunk order (the bitwise-contract
/// precondition) is preserved while chunks from different sessions
/// interleave on the wire. Chunks are stamped with the *scheduled*
/// arrival (coordinated-omission-correct, like [`run_open_loop`]).
/// Callers `wait_chunk` the tickets, `close_session` the sids, then
/// `join` the fleet.
pub fn run_stream_open_loop(
    fleet: &mut Fleet,
    trace: &[ScheduledRequest],
    data: &Dataset,
    n_sessions: usize,
) -> Result<StreamLoopOutcome, SessionError> {
    let n_sessions = n_sessions.max(1);
    let win = fleet.obs_window();
    let mut sids = Vec::with_capacity(n_sessions);
    for _ in 0..n_sessions {
        sids.push(fleet.open_session()?);
    }
    let mut out = StreamLoopOutcome {
        tickets: Vec::with_capacity(trace.len()),
        sids,
        offered: trace.len(),
        lag: LatencyStats::new(),
        offered_per_window: WindowedCount::default(),
    };
    let start = Instant::now();
    for (i, r) in trace.iter().enumerate() {
        let target = start + r.at;
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        out.lag.record(Instant::now().saturating_duration_since(target));
        if let Some((epoch, width)) = win {
            out.offered_per_window
                .inc(window_index(epoch, width, target));
        }
        let sid = out.sids[i % n_sessions];
        out.tickets.push(fleet.submit_chunk_at(
            sid,
            data.beat(r.beat_idx).to_vec(),
            target,
        )?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;

    #[test]
    fn poisson_rate_matches() {
        let d = data::generate(16, 0);
        let trace = PoissonTrace::generate(1000.0, 5000, &d, 1);
        let rate = trace.empirical_rate();
        assert!(
            (rate - 1000.0).abs() / 1000.0 < 0.08,
            "empirical rate {rate}"
        );
        // Arrivals strictly ordered.
        for w in trace.arrivals.windows(2) {
            assert!(w[1].at >= w[0].at);
        }
    }

    #[test]
    fn interarrival_distribution_is_exponential() {
        // CV (std/mean) of exponential inter-arrivals is 1.
        let d = data::generate(4, 0);
        let trace = PoissonTrace::generate(500.0, 8000, &d, 3);
        let gaps: Vec<f64> = trace
            .arrivals
            .windows(2)
            .map(|w| (w[1].at - w[0].at).as_secs_f64())
            .collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>()
            / gaps.len() as f64;
        let cv = var.sqrt() / mean;
        assert!((cv - 1.0).abs() < 0.08, "cv {cv}");
    }

    #[test]
    fn beats_round_robin() {
        let d = data::generate(3, 0);
        let trace = PoissonTrace::generate(10.0, 7, &d, 0);
        let idx: Vec<usize> =
            trace.arrivals.iter().map(|a| a.beat_idx).collect();
        assert_eq!(idx, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn open_loop_replay_serves_all() {
        use crate::config::{ArchConfig, Task};
        use crate::coordinator::{
            BatchPolicy, Engine, Server, ServerConfig,
        };
        use crate::hwmodel::resource::ReuseFactors;
        use crate::nn::model::Model;
        use crate::rng::Rng;
        let mut cfg = ArchConfig::new(Task::Classify, 8, 1, "N");
        cfg.seq_len = data::T;
        let model = Model::init(cfg.clone(), &mut Rng::new(0));
        let c2 = cfg.clone();
        let p = model.params.tensors.clone();
        let mut server = Server::start(
            move || {
                let m = Model::new(
                    c2.clone(),
                    bayes_rnn_fpga_params(p.clone()),
                );
                Engine::fpga(&c2, &m, ReuseFactors::new(4, 4, 4), 1, 0)
            },
            ServerConfig {
                policy: BatchPolicy::stream(),
                queue_depth: 64,
            },
        );
        let d = data::generate(8, 1);
        let trace = PoissonTrace::generate(2000.0, 30, &d, 2);
        let receivers = replay(&trace, &mut server, &d);
        for rx in receivers {
            rx.recv().unwrap();
        }
        assert_eq!(server.join().served, 30);
    }

    fn bayes_rnn_fpga_params(
        tensors: Vec<crate::tensor::Tensor>,
    ) -> crate::nn::Params {
        crate::nn::Params { tensors }
    }

    #[test]
    fn preset_matrix_covers_every_scenario() {
        for name in SCENARIOS {
            let spec = ScenarioSpec::preset(name, 4, 100.0, 32, 8, 1)
                .expect("known scenario");
            assert_eq!(spec.name, *name);
            assert!(spec.engines >= 1);
        }
        let base =
            ScenarioSpec::preset("baseline", 4, 100.0, 32, 8, 1).unwrap();
        assert_eq!(base.engines, 1, "baseline collapses to one engine");
        let fan_out =
            ScenarioSpec::preset("fan_out", 4, 100.0, 32, 8, 1).unwrap();
        assert_eq!(fan_out.router, RouterPolicy::McShard);
        assert_eq!(fan_out.engines, 4);
        let fan_in =
            ScenarioSpec::preset("fan_in", 4, 100.0, 32, 8, 1).unwrap();
        assert!(fan_in.shed, "fan_in sheds under overload");
        assert_eq!(fan_in.queue_depth, 8);
        let scaling =
            ScenarioSpec::preset("scaling", 4, 100.0, 32, 8, 1).unwrap();
        assert_eq!(scaling.router, RouterPolicy::LeastLoaded);
        let stream =
            ScenarioSpec::preset("stream_monitor", 4, 100.0, 32, 8, 1)
                .unwrap();
        assert_eq!(stream.router, RouterPolicy::Affinity);
        assert_eq!(stream.engines, 4);
        let mix =
            ScenarioSpec::preset("poisson_mix", 4, 100.0, 32, 8, 1)
                .unwrap();
        assert_eq!(mix.mix.len(), 3);
        assert_eq!(mix.mix[0].samples, 2, "light = S/4");
        assert_eq!(mix.mix[2].samples, 16, "heavy = 2S");
        let err = ScenarioSpec::preset("nope", 4, 100.0, 32, 8, 1)
            .expect_err("unknown scenario must error");
        assert!(err.contains("unknown scenario"), "{err}");
    }

    #[test]
    fn scheduled_trace_is_deterministic_and_draws_every_class() {
        let spec =
            ScenarioSpec::preset("poisson_mix", 2, 1000.0, 2000, 8, 7)
                .unwrap();
        let a = spec.trace(16);
        let b = spec.trace(16);
        assert_eq!(a, b, "same spec + seed => identical schedule");
        assert_eq!(a.len(), 2000);
        for w in a.windows(2) {
            assert!(w[1].at >= w[0].at, "arrivals ordered");
        }
        for class in 0..3 {
            assert!(
                a.iter().any(|r| r.class == class),
                "class {class} never drawn in 2000 requests"
            );
        }
        // Class => samples mapping holds for every request.
        for r in &a {
            assert_eq!(r.samples, spec.mix[r.class].samples);
        }
        let wall = a.last().unwrap().at.as_secs_f64();
        let rate = a.len() as f64 / wall;
        assert!(
            (rate - 1000.0).abs() / 1000.0 < 0.1,
            "empirical rate {rate}"
        );
        // A different seed moves the schedule.
        let mut other = spec.clone();
        other.seed = 8;
        assert_ne!(other.trace(16), a);
    }

    #[test]
    fn open_loop_runner_accounts_for_every_offered_request() {
        use crate::config::{ArchConfig, Task};
        use crate::coordinator::{Engine, Fleet, FleetConfig};
        use crate::hwmodel::resource::ReuseFactors;
        use crate::nn::model::Model;
        use crate::rng::Rng;

        let spec =
            ScenarioSpec::preset("baseline", 1, 2000.0, 16, 2, 3)
                .unwrap();
        let mut cfg = ArchConfig::new(Task::Classify, 8, 1, "Y");
        cfg.seq_len = data::T;
        let model = Model::init(cfg.clone(), &mut Rng::new(0));
        let c2 = cfg.clone();
        let p = model.params.tensors.clone();
        let factory: Box<dyn FnOnce() -> Engine + Send + 'static> =
            Box::new(move || {
                let m =
                    Model::new(c2.clone(), bayes_rnn_fpga_params(p));
                Engine::fpga(&c2, &m, ReuseFactors::new(4, 4, 4), 2, 0)
            });
        let mut fleet = Fleet::start(
            FleetConfig {
                engines: spec.engines,
                router: spec.router,
                queue_depth: spec.queue_depth,
                shed: spec.shed,
                samples: spec.samples,
                ..FleetConfig::default()
            },
            vec![factory],
        );
        let d = data::generate(8, 1);
        let trace = spec.trace(d.n);
        let outcome = run_open_loop(&mut fleet, &trace, &d);
        assert_eq!(outcome.offered, 16);
        assert_eq!(
            outcome.offered,
            outcome.submitted + outcome.rejected_at_submit
        );
        assert_eq!(outcome.rejected_at_submit, 0, "no shedding here");
        assert_eq!(outcome.lag.count(), 16, "one lag sample per offer");
        for (t, class) in outcome.tickets {
            assert_eq!(class, 0, "baseline has no mix");
            fleet.wait(t).expect("response");
        }
        assert_eq!(fleet.join().served, 16);
    }

    /// Conservation under fleet faults: replay an open-loop trace
    /// against a 3-engine mc-shard fleet while chaos kills one engine
    /// at t=0. Every offered request must still be accounted for —
    /// submitted requests all complete (orphaned shards re-dispatch to
    /// survivors), nothing hangs, and the fault counters record
    /// exactly one lost worker.
    #[test]
    fn open_loop_conserves_requests_when_an_engine_dies() {
        use crate::config::{ArchConfig, Task};
        use crate::coordinator::chaos::FaultPlan;
        use crate::coordinator::{Engine, Fleet, FleetConfig};
        use crate::hwmodel::resource::ReuseFactors;
        use crate::nn::model::Model;
        use crate::rng::Rng;

        let spec =
            ScenarioSpec::preset("fan_out", 3, 2000.0, 12, 4, 5)
                .unwrap();
        assert_eq!(spec.engines, 3);
        let mut cfg = ArchConfig::new(Task::Classify, 8, 1, "Y");
        cfg.seq_len = data::T;
        let model = Model::init(cfg.clone(), &mut Rng::new(0));
        let factories: Vec<
            Box<dyn FnOnce() -> Engine + Send + 'static>,
        > = (0..3)
            .map(|_| {
                let c2 = cfg.clone();
                let p = model.params.tensors.clone();
                let f: Box<dyn FnOnce() -> Engine + Send + 'static> =
                    Box::new(move || {
                        let m = Model::new(
                            c2.clone(),
                            bayes_rnn_fpga_params(p),
                        );
                        Engine::fpga(
                            &c2,
                            &m,
                            ReuseFactors::new(4, 4, 4),
                            4,
                            0,
                        )
                    });
                f
            })
            .collect();
        let mut fleet = Fleet::start(
            FleetConfig {
                engines: spec.engines,
                router: spec.router,
                queue_depth: spec.queue_depth,
                shed: spec.shed,
                samples: spec.samples,
                chaos: Some(
                    FaultPlan::parse("kill=e1@0ms")
                        .expect("plan")
                        .with_seed(5),
                ),
                ..FleetConfig::default()
            },
            factories,
        );
        let d = data::generate(8, 1);
        let trace = spec.trace(d.n);
        let outcome = run_open_loop(&mut fleet, &trace, &d);
        assert_eq!(outcome.offered, 12);
        assert_eq!(
            outcome.offered,
            outcome.submitted + outcome.rejected_at_submit
        );
        let mut served = 0;
        for (t, _) in outcome.tickets {
            fleet.wait(t).expect("request survives the kill");
            served += 1;
        }
        assert_eq!(served, outcome.submitted, "nothing lost or hung");
        let summary = fleet.join();
        assert_eq!(summary.served, outcome.submitted);
        let faults = summary.obs.faults;
        assert_eq!(faults.workers_lost, 1, "{faults:?}");
        assert_eq!(summary.per_engine.len(), 3, "dead slot kept");
    }
}
