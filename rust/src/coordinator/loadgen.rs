//! Open-loop load generator: Poisson arrivals of ECG beats, as a hospital
//! telemetry stream would produce them (the paper's "requests need to be
//! processed as soon as they arrive"). Closed-loop benchmarks (submit-all,
//! wait-all) hide queueing behaviour; an open-loop arrival process
//! exposes the latency knee as offered load approaches engine capacity.

use std::time::Duration;

use crate::data::Dataset;
use crate::rng::Rng;

/// A generated arrival: offset from stream start + the beat payload index.
#[derive(Debug, Clone, Copy)]
pub struct Arrival {
    pub at: Duration,
    pub beat_idx: usize,
}

/// Poisson-process arrival trace over a dataset.
pub struct PoissonTrace {
    pub arrivals: Vec<Arrival>,
    pub rate_per_s: f64,
}

impl PoissonTrace {
    /// `rate_per_s` mean arrivals/second for `n` requests, beats drawn
    /// round-robin from the dataset.
    pub fn generate(rate_per_s: f64, n: usize, data: &Dataset, seed: u64) -> Self {
        assert!(rate_per_s > 0.0);
        let mut rng = Rng::new(seed ^ 0x10AD);
        let mut t = 0.0f64;
        let mut arrivals = Vec::with_capacity(n);
        for i in 0..n {
            // Exponential inter-arrival: -ln(U)/rate.
            let u = loop {
                let u = rng.uniform();
                if u > 1e-12 {
                    break u;
                }
            };
            t += -u.ln() / rate_per_s;
            arrivals.push(Arrival {
                at: Duration::from_secs_f64(t),
                beat_idx: i % data.n,
            });
        }
        Self { arrivals, rate_per_s }
    }

    pub fn duration(&self) -> Duration {
        self.arrivals.last().map(|a| a.at).unwrap_or(Duration::ZERO)
    }

    /// Empirical rate of the generated trace.
    pub fn empirical_rate(&self) -> f64 {
        if self.arrivals.is_empty() {
            return 0.0;
        }
        self.arrivals.len() as f64 / self.duration().as_secs_f64().max(1e-9)
    }
}

/// Replay a trace against a server, sleeping between arrivals (open
/// loop), and return the observed end-to-end latencies.
pub fn replay(
    trace: &PoissonTrace,
    server: &mut super::server::Server,
    data: &Dataset,
) -> Vec<std::sync::mpsc::Receiver<super::server::Response>> {
    let start = std::time::Instant::now();
    let mut receivers = Vec::with_capacity(trace.arrivals.len());
    for a in &trace.arrivals {
        if let Some(wait) = a.at.checked_sub(start.elapsed()) {
            if !wait.is_zero() {
                std::thread::sleep(wait);
            }
        }
        receivers.push(server.submit(data.beat(a.beat_idx).to_vec()));
    }
    receivers
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;

    #[test]
    fn poisson_rate_matches() {
        let d = data::generate(16, 0);
        let trace = PoissonTrace::generate(1000.0, 5000, &d, 1);
        let rate = trace.empirical_rate();
        assert!(
            (rate - 1000.0).abs() / 1000.0 < 0.08,
            "empirical rate {rate}"
        );
        // Arrivals strictly ordered.
        for w in trace.arrivals.windows(2) {
            assert!(w[1].at >= w[0].at);
        }
    }

    #[test]
    fn interarrival_distribution_is_exponential() {
        // CV (std/mean) of exponential inter-arrivals is 1.
        let d = data::generate(4, 0);
        let trace = PoissonTrace::generate(500.0, 8000, &d, 3);
        let gaps: Vec<f64> = trace
            .arrivals
            .windows(2)
            .map(|w| (w[1].at - w[0].at).as_secs_f64())
            .collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>()
            / gaps.len() as f64;
        let cv = var.sqrt() / mean;
        assert!((cv - 1.0).abs() < 0.08, "cv {cv}");
    }

    #[test]
    fn beats_round_robin() {
        let d = data::generate(3, 0);
        let trace = PoissonTrace::generate(10.0, 7, &d, 0);
        let idx: Vec<usize> =
            trace.arrivals.iter().map(|a| a.beat_idx).collect();
        assert_eq!(idx, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn open_loop_replay_serves_all() {
        use crate::config::{ArchConfig, Task};
        use crate::coordinator::{
            BatchPolicy, Engine, Server, ServerConfig,
        };
        use crate::hwmodel::resource::ReuseFactors;
        use crate::nn::model::Model;
        use crate::rng::Rng;
        let mut cfg = ArchConfig::new(Task::Classify, 8, 1, "N");
        cfg.seq_len = data::T;
        let model = Model::init(cfg.clone(), &mut Rng::new(0));
        let c2 = cfg.clone();
        let p = model.params.tensors.clone();
        let mut server = Server::start(
            move || {
                let m = Model::new(
                    c2.clone(),
                    bayes_rnn_fpga_params(p.clone()),
                );
                Engine::fpga(&c2, &m, ReuseFactors::new(4, 4, 4), 1, 0)
            },
            ServerConfig {
                policy: BatchPolicy::stream(),
                queue_depth: 64,
            },
        );
        let d = data::generate(8, 1);
        let trace = PoissonTrace::generate(2000.0, 30, &d, 2);
        let receivers = replay(&trace, &mut server, &d);
        for rx in receivers {
            rx.recv().unwrap();
        }
        assert_eq!(server.join().served, 30);
    }

    fn bayes_rnn_fpga_params(
        tensors: Vec<crate::tensor::Tensor>,
    ) -> crate::nn::Params {
        crate::nn::Params { tensors }
    }
}
