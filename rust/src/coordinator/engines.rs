//! Inference engines behind the coordinator: the FPGA simulator (batch-1
//! streaming, functional fixed-point output + simulated hardware
//! latency), the PJRT CPU baseline (real measured wallclock over the AOT
//! artifact), and the analytic GPU baseline (float output + modelled
//! latency).

use anyhow::Result;
use std::time::Instant;

use crate::config::{ArchConfig, Task};
use crate::fpga::accel::{Accelerator, McOutput};
use crate::fpga::pipeline::PipelineSim;
use crate::hwmodel::resource::ReuseFactors;
use crate::hwmodel::{GpuModel, ZC706};
use crate::nn::model::{Masks, Model};
use crate::rng::Rng;
use crate::runtime::{HostValue, Runtime};
use crate::tensor::Tensor;

/// One served prediction.
#[derive(Debug, Clone)]
pub struct Prediction {
    /// MC-mean output (reconstruction or class probabilities).
    pub mean: Vec<f32>,
    /// Per-point MC std (uncertainty).
    pub std: Vec<f32>,
    /// Engine-reported model latency in ms (FPGA: simulated cycles; GPU:
    /// analytic; PJRT: measured).
    pub model_latency_ms: f64,
}

/// Engine selector.
pub enum EngineKind {
    /// Fixed-point accelerator simulator + cycle-level timing.
    FpgaSim { accel: Accelerator, sim: PipelineSim },
    /// Real PJRT CPU execution of the fwd artifact (rows = S).
    PjrtCpu {
        runtime: Runtime,
        artifact: String,
        cfg: ArchConfig,
        params: Vec<Tensor>,
        rng: Rng,
    },
    /// Float model + analytic TITAN-X latency (no GPU in this testbed).
    GpuModel { model: Model, rng: Rng },
}

/// A batched inference engine.
pub struct Engine {
    pub kind: EngineKind,
    /// MC samples per request.
    pub s: usize,
}

impl Engine {
    pub fn fpga(
        cfg: &ArchConfig,
        model: &Model,
        reuse: ReuseFactors,
        s: usize,
        seed: u64,
    ) -> Self {
        let accel = Accelerator::new(cfg, &model.params, reuse, seed);
        let sim = PipelineSim::new(cfg, reuse);
        Self { kind: EngineKind::FpgaSim { accel, sim }, s }
    }

    pub fn gpu(model: Model, s: usize, seed: u64) -> Self {
        Self { kind: EngineKind::GpuModel { model, rng: Rng::new(seed) }, s }
    }

    /// PJRT engine bound to `<arch>.fwd_n<rows>` where rows = s.
    pub fn pjrt(
        mut runtime: Runtime,
        arch_name: &str,
        params: &[Tensor],
        s: usize,
        seed: u64,
    ) -> Result<Self> {
        let meta = runtime
            .manifest
            .forward_for(arch_name, s)
            .ok_or_else(|| {
                anyhow::anyhow!("no fwd_n{s} artifact for {arch_name}")
            })?
            .clone();
        runtime.load(&meta.name)?;
        Ok(Self {
            kind: EngineKind::PjrtCpu {
                runtime,
                artifact: meta.name.clone(),
                cfg: meta.arch(),
                params: params.to_vec(),
                rng: Rng::new(seed),
            },
            s,
        })
    }

    pub fn task(&self) -> Task {
        match &self.kind {
            EngineKind::FpgaSim { accel, .. } => accel.cfg.task,
            EngineKind::PjrtCpu { cfg, .. } => cfg.task,
            EngineKind::GpuModel { model, .. } => model.cfg.task,
        }
    }

    /// Serve a batch of beats; returns one prediction per beat.
    pub fn infer_batch(&mut self, beats: &[&[f32]]) -> Result<Vec<Prediction>> {
        let s = self.s;
        match &mut self.kind {
            EngineKind::FpgaSim { accel, sim } => {
                // The FPGA streams requests back-to-back (batch size 1
                // each); hardware latency comes from the cycle simulator.
                let per_req_ms = sim.simulate_ms(1, s, ZC706.clock_hz);
                beats
                    .iter()
                    .map(|b| {
                        let out = accel.predict(b, s);
                        Ok(Prediction {
                            mean: out.mean(),
                            std: out.std(),
                            model_latency_ms: per_req_ms,
                        })
                    })
                    .collect()
            }
            EngineKind::GpuModel { model, rng } => {
                let cfg = model.cfg.clone();
                let ms = GpuModel::latency_ms(&cfg, beats.len(), s);
                beats
                    .iter()
                    .map(|b| {
                        let out = predict_float(model, b, s, rng);
                        Ok(Prediction {
                            mean: out.mean(),
                            std: out.std(),
                            model_latency_ms: ms,
                        })
                    })
                    .collect()
            }
            EngineKind::PjrtCpu { runtime, artifact, cfg, params, rng } => {
                // rows = S: one request per execution, measured wallclock.
                let mut preds = Vec::with_capacity(beats.len());
                for beat in beats {
                    let mut xs = Vec::with_capacity(s * beat.len());
                    for _ in 0..s {
                        xs.extend_from_slice(beat);
                    }
                    let masks = if cfg.is_bayesian() {
                        Masks::sample(cfg, s, rng)
                    } else {
                        Masks::ones(cfg, s)
                    };
                    let mut args: Vec<HostValue> = params
                        .iter()
                        .map(|p| HostValue::F32(p.clone()))
                        .collect();
                    args.push(HostValue::F32(Tensor::new(
                        vec![s, cfg.seq_len, cfg.input_dim],
                        xs,
                    )));
                    for m in &masks.tensors {
                        args.push(HostValue::F32(m.clone()));
                    }
                    let t0 = Instant::now();
                    let exe = runtime.load(artifact)?;
                    let out = exe.run(&args)?;
                    let ms = t0.elapsed().as_secs_f64() * 1e3;
                    let y = &out[0];
                    let out_len = y.data.len() / s;
                    let mc = McOutput {
                        samples: y.data.clone(),
                        s,
                        out_len,
                    };
                    preds.push(Prediction {
                        mean: mc.mean(),
                        std: mc.std(),
                        model_latency_ms: ms,
                    });
                }
                Ok(preds)
            }
        }
    }
}

/// Float-model MC prediction (shared by the GPU engine and tests).
pub fn predict_float(
    model: &Model,
    beat: &[f32],
    s: usize,
    rng: &mut Rng,
) -> McOutput {
    let cfg = &model.cfg;
    let mut xs = Vec::with_capacity(s * beat.len());
    for _ in 0..s {
        xs.extend_from_slice(beat);
    }
    let masks = if cfg.is_bayesian() {
        Masks::sample(cfg, s, rng)
    } else {
        Masks::ones(cfg, s)
    };
    let out = model.forward(&xs, s, &masks);
    let out_len = out.len() / s;
    McOutput { samples: out, s, out_len }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_model(bayes: &str) -> (ArchConfig, Model) {
        let mut cfg = ArchConfig::new(Task::Classify, 8, bayes.len(), bayes);
        cfg.seq_len = 20;
        let model = Model::init(cfg.clone(), &mut Rng::new(0));
        (cfg, model)
    }

    #[test]
    fn fpga_engine_serves_batch() {
        let (cfg, model) = tiny_model("YN");
        let mut e = Engine::fpga(&cfg, &model, ReuseFactors::new(2, 1, 1), 4, 9);
        let beat: Vec<f32> = (0..20).map(|i| (i as f32 * 0.3).sin()).collect();
        let beats = [beat.as_slice(), beat.as_slice()];
        let preds = e.infer_batch(&beats).unwrap();
        assert_eq!(preds.len(), 2);
        for p in &preds {
            assert_eq!(p.mean.len(), 4);
            assert!((p.mean.iter().sum::<f32>() - 1.0).abs() < 1e-3);
            assert!(p.model_latency_ms > 0.0);
        }
    }

    #[test]
    fn gpu_engine_reports_analytic_latency() {
        let (_, model) = tiny_model("NN");
        let cfg = model.cfg.clone();
        let mut e = Engine::gpu(model, 1, 0);
        let beat: Vec<f32> = vec![0.0; 20];
        let preds = e.infer_batch(&[&beat]).unwrap();
        let expect = GpuModel::latency_ms(&cfg, 1, 1);
        assert!((preds[0].model_latency_ms - expect).abs() < 1e-9);
    }

    #[test]
    fn bayesian_engine_has_nonzero_uncertainty() {
        let (cfg, model) = tiny_model("YY");
        let mut e =
            Engine::fpga(&cfg, &model, ReuseFactors::new(1, 1, 1), 8, 3);
        let beat: Vec<f32> = (0..20).map(|i| (i as f32 * 0.5).cos()).collect();
        let preds = e.infer_batch(&[&beat]).unwrap();
        assert!(
            preds[0].std.iter().any(|&v| v > 0.0),
            "MCD must yield spread"
        );
    }
}
