//! Inference engines behind the coordinator: the FPGA simulator (batch-1
//! streaming, functional fixed-point output + simulated hardware
//! latency), the PJRT CPU baseline (real measured wallclock over the AOT
//! artifact), and the analytic GPU baseline (float output + modelled
//! latency).

use anyhow::Result;
use std::sync::Arc;
use std::time::Instant;

use crate::config::{ArchConfig, Task};
use crate::fixedpoint::Precision;
use crate::fpga::accel::{Accelerator, McOutput, StreamState};
use crate::fpga::pipeline::PipelineSim;
use crate::hwmodel::resource::ReuseFactors;
use crate::hwmodel::{GpuModel, ZC706};
use crate::kernels::{KernelBackend, MaskBank};
use crate::nn::model::{MaskBlock, Masks, Model};
use crate::rng::Rng;
use crate::runtime::{HostValue, Runtime};
use crate::tensor::Tensor;

/// One served prediction.
#[derive(Debug, Clone)]
pub struct Prediction {
    /// MC-mean output (reconstruction or class probabilities).
    pub mean: Vec<f32>,
    /// Per-point MC std (uncertainty).
    pub std: Vec<f32>,
    /// Engine-reported model latency in ms (FPGA: simulated cycles; GPU:
    /// analytic; PJRT: measured).
    pub model_latency_ms: f64,
}

/// A shard of one request's MC-sample schedule, computed by one engine:
/// partial moment sums over samples `start..start+count`, ready for the
/// coordinator's pooled mean/variance reduction
/// ([`crate::metrics::pooled_mean_std`]).
#[derive(Debug, Clone)]
pub struct PartialPrediction {
    /// Per-point Σ x over the shard's samples.
    pub sum: Vec<f64>,
    /// Per-point Σ x² over the shard's samples.
    pub sumsq: Vec<f64>,
    /// Samples in this shard.
    pub count: usize,
    /// Engine-model latency for computing the shard, in ms.
    pub model_latency_ms: f64,
    /// First sample index of the shard within the request's schedule.
    /// The waiter dedups duplicate replies (hedging / re-dispatch) by
    /// this key and sorts shards on it before merging, so the f64
    /// moment reduction is arrival-order-independent.
    pub start: usize,
    /// Engine that actually computed the shard (may differ from the
    /// engine it was first dispatched to, under re-dispatch/hedging).
    pub engine: usize,
}

impl PartialPrediction {
    /// Reduce raw `[count][out_len]` samples to moment sums.
    pub fn from_samples(
        samples: &[f32],
        count: usize,
        out_len: usize,
        model_latency_ms: f64,
    ) -> Self {
        debug_assert_eq!(samples.len(), count * out_len);
        let mut sum = vec![0f64; out_len];
        let mut sumsq = vec![0f64; out_len];
        for k in 0..count {
            for i in 0..out_len {
                let v = samples[k * out_len + i] as f64;
                sum[i] += v;
                sumsq[i] += v * v;
            }
        }
        Self { sum, sumsq, count, model_latency_ms, start: 0, engine: 0 }
    }

    /// Stamp which shard this is and who computed it (fleet workers
    /// call this; the single-engine paths keep the zero defaults).
    pub fn with_origin(mut self, start: usize, engine: usize) -> Self {
        self.start = start;
        self.engine = engine;
        self
    }
}

/// A shard of one request's MC schedule as *raw samples* — the adaptive
/// serving path's reply unit. Unlike [`PartialPrediction`] the samples
/// are not pre-reduced: the coordinator's
/// [`crate::uq::McAccumulator`] needs them individually (a) to reduce
/// in ascending-`k` order regardless of shard arrival order (the
/// bit-identity invariant) and (b) to run the epistemic/aleatoric
/// decomposition behind the risk tiers.
#[derive(Debug, Clone)]
pub struct SampleBlock {
    /// First sample index of the shard within the request's schedule.
    pub start: usize,
    /// Samples in this shard.
    pub count: usize,
    pub out_len: usize,
    /// Raw outputs, `[count][out_len]` row-major.
    pub samples: Vec<f32>,
    /// Engine-model latency for computing the shard, in ms.
    pub model_latency_ms: f64,
}

/// Engine selector.
pub enum EngineKind {
    /// Fixed-point accelerator simulator + cycle-level timing.
    FpgaSim { accel: Accelerator, sim: PipelineSim },
    /// Real PJRT CPU execution of the fwd artifact (rows = S).
    PjrtCpu {
        runtime: Runtime,
        artifact: String,
        cfg: ArchConfig,
        params: Vec<Tensor>,
        rng: Rng,
        seed: u64,
    },
    /// Float model + analytic TITAN-X latency (no GPU in this testbed).
    GpuModel { model: Model, rng: Rng, seed: u64 },
}

/// A batched inference engine.
pub struct Engine {
    pub kind: EngineKind,
    /// MC samples per request.
    pub s: usize,
}

impl Engine {
    pub fn fpga(
        cfg: &ArchConfig,
        model: &Model,
        reuse: ReuseFactors,
        s: usize,
        seed: u64,
    ) -> Self {
        Self::fpga_q(cfg, model, reuse, s, seed, &Precision::q16())
    }

    /// FPGA-sim engine at an explicit precision: the functional
    /// simulator quantises at the given formats; `reuse` should come
    /// from `reuse_search_q` at the same precision, which is how narrow
    /// formats reach the cycle model (`docs/quantization.md`). A fleet
    /// must run all engines at ONE precision — mc-shard merges shard
    /// numerics across engines.
    pub fn fpga_q(
        cfg: &ArchConfig,
        model: &Model,
        reuse: ReuseFactors,
        s: usize,
        seed: u64,
        precision: &Precision,
    ) -> Self {
        let accel = Accelerator::with_precision(
            cfg,
            &model.params,
            reuse,
            seed,
            precision.clone(),
        );
        let sim = PipelineSim::new(cfg, reuse);
        Self { kind: EngineKind::FpgaSim { accel, sim }, s }
    }

    pub fn gpu(model: Model, s: usize, seed: u64) -> Self {
        Self {
            kind: EngineKind::GpuModel { model, rng: Rng::new(seed), seed },
            s,
        }
    }

    /// PJRT engine bound to `<arch>.fwd_n<rows>` where rows = s.
    pub fn pjrt(
        mut runtime: Runtime,
        arch_name: &str,
        params: &[Tensor],
        s: usize,
        seed: u64,
    ) -> Result<Self> {
        let meta = runtime
            .manifest
            .forward_for(arch_name, s)
            .ok_or_else(|| {
                anyhow::anyhow!("no fwd_n{s} artifact for {arch_name}")
            })?
            .clone();
        runtime.load(&meta.name)?;
        Ok(Self {
            kind: EngineKind::PjrtCpu {
                runtime,
                artifact: meta.name.clone(),
                cfg: meta.arch(),
                params: params.to_vec(),
                rng: Rng::new(seed),
                seed,
            },
            s,
        })
    }

    /// Select the kernel backend for an FPGA-sim engine (`repro serve
    /// --kernel`, `docs/kernels.md` §Backends). `scalar` additionally
    /// forces the structural per-sample loop — the full legacy cost
    /// model, as the bench baseline expects — and the two flags are
    /// only ever set together here, so the serve JSON's `"kernel"`
    /// field can't desynchronize from the loop actually running.
    /// Output bits never change. No-op for float backends (they
    /// dispatch through the process-wide
    /// [`crate::kernels::default_backend`]).
    pub fn set_kernel_backend(&mut self, backend: KernelBackend) {
        if let EngineKind::FpgaSim { accel, .. } = &mut self.kind {
            accel.set_kernel_backend(backend);
            accel.scalar_reference = backend == KernelBackend::Scalar;
        }
    }

    /// Attach a shared seed-indexed mask bank to an FPGA-sim engine
    /// (`repro serve --mask-bank-mb`, `docs/kernels.md` §Mask bank).
    /// Output bits never change; repeat mask seeds become row copies
    /// instead of LFSR streams. No-op for float backends (their mask
    /// path is `MaskBlock`, not the engine bitplanes).
    pub fn set_mask_bank(&mut self, bank: Option<Arc<MaskBank>>) {
        if let EngineKind::FpgaSim { accel, .. } = &mut self.kind {
            accel.set_mask_bank(bank);
        }
    }

    pub fn task(&self) -> Task {
        match &self.kind {
            EngineKind::FpgaSim { accel, .. } => accel.cfg.task,
            EngineKind::PjrtCpu { cfg, .. } => cfg.task,
            EngineKind::GpuModel { model, .. } => model.cfg.task,
        }
    }

    /// Stable backend label for health reporting
    /// (`repro_engine_kernel_info`): `fpga:<kernel>` for the simulator
    /// (kernel = the active [`KernelBackend`]), `gpu` / `pjrt` for the
    /// float baselines.
    pub fn backend_label(&self) -> String {
        match &self.kind {
            EngineKind::FpgaSim { accel, .. } => {
                format!("fpga:{}", accel.kernel_backend.name())
            }
            EngineKind::GpuModel { .. } => "gpu".to_string(),
            EngineKind::PjrtCpu { .. } => "pjrt".to_string(),
        }
    }

    /// Serve a batch of beats; returns one prediction per beat.
    pub fn infer_batch(&mut self, beats: &[&[f32]]) -> Result<Vec<Prediction>> {
        let s = self.s;
        match &mut self.kind {
            EngineKind::FpgaSim { accel, sim } => {
                // The FPGA streams requests back-to-back (batch size 1
                // each); hardware latency comes from the cycle simulator.
                let per_req_ms = sim.simulate_ms(1, s, ZC706.clock_hz);
                beats
                    .iter()
                    .map(|b| {
                        let out = accel.predict(b, s);
                        let (mean, std) = out.mean_std();
                        Ok(Prediction {
                            mean,
                            std,
                            model_latency_ms: per_req_ms,
                        })
                    })
                    .collect()
            }
            EngineKind::GpuModel { model, rng, .. } => {
                let cfg = model.cfg.clone();
                let ms = GpuModel::latency_ms(&cfg, beats.len(), s);
                beats
                    .iter()
                    .map(|b| {
                        let out = predict_float(model, b, s, rng);
                        let (mean, std) = out.mean_std();
                        Ok(Prediction { mean, std, model_latency_ms: ms })
                    })
                    .collect()
            }
            EngineKind::PjrtCpu { runtime, artifact, cfg, params, rng, .. } => {
                // rows = S: one request per execution, measured wallclock.
                let mut preds = Vec::with_capacity(beats.len());
                for beat in beats {
                    let mut xs = Vec::with_capacity(s * beat.len());
                    for _ in 0..s {
                        xs.extend_from_slice(beat);
                    }
                    let masks = if cfg.is_bayesian() {
                        Masks::sample(cfg, s, rng)
                    } else {
                        Masks::ones(cfg, s)
                    };
                    let mut args: Vec<HostValue> = params
                        .iter()
                        .map(|p| HostValue::F32(p.clone()))
                        .collect();
                    args.push(HostValue::F32(Tensor::new(
                        vec![s, cfg.seq_len, cfg.input_dim],
                        xs,
                    )));
                    for m in &masks.tensors {
                        args.push(HostValue::F32(m.clone()));
                    }
                    let t0 = Instant::now();
                    let exe = runtime.load(artifact)?;
                    let out = exe.run(&args)?;
                    let ms = t0.elapsed().as_secs_f64() * 1e3;
                    let y = &out[0];
                    let out_len = y.data.len() / s;
                    let mc = McOutput {
                        samples: y.data.clone(),
                        s,
                        out_len,
                    };
                    let (mean, std) = mc.mean_std();
                    preds.push(Prediction { mean, std, model_latency_ms: ms });
                }
                Ok(preds)
            }
        }
    }

    /// Compute MC samples `start..start+count` of one request's S-sample
    /// schedule and return the shard's partial moment sums. Sample `k`'s
    /// dropout masks derive from `mix3(engine_seed, req_seed, k)`, so the
    /// union over shards is independent of how many engines the schedule
    /// is split across (the fleet's MC-shard invariant). `group` is the
    /// number of requests the worker batched together (feeds the GPU
    /// latency model's batch amortisation).
    pub fn infer_partial(
        &mut self,
        beat: &[f32],
        req_seed: u64,
        start: usize,
        count: usize,
        group: usize,
    ) -> Result<PartialPrediction> {
        let block = self.infer_samples(beat, req_seed, start, count, group)?;
        Ok(PartialPrediction::from_samples(
            &block.samples,
            block.count,
            block.out_len,
            block.model_latency_ms,
        ))
    }

    /// Like [`Engine::infer_partial`] but returning the shard's raw
    /// samples instead of moment sums — the adaptive-MC reply unit.
    /// Same seeding contract: sample `k` is a pure function of
    /// `(engine_seed, req_seed, k)`.
    pub fn infer_samples(
        &mut self,
        beat: &[f32],
        req_seed: u64,
        start: usize,
        count: usize,
        group: usize,
    ) -> Result<SampleBlock> {
        anyhow::ensure!(count > 0, "empty MC shard");
        match &mut self.kind {
            EngineKind::FpgaSim { accel, sim } => {
                // The FPGA streams the shard's passes back-to-back; fewer
                // samples per engine = proportionally lower latency (the
                // MC-parallel win).
                let ms = sim.simulate_ms(1, count, ZC706.clock_hz);
                let out = accel.predict_seeded(beat, req_seed, start, count);
                Ok(SampleBlock {
                    start,
                    count,
                    out_len: out.out_len,
                    samples: out.samples,
                    model_latency_ms: ms,
                })
            }
            EngineKind::GpuModel { model, seed, .. } => {
                let cfg = model.cfg.clone();
                let ms = GpuModel::latency_ms(&cfg, group.max(1), count);
                let out_len = cfg.out_len();
                // All `count` samples as rows of one blocked forward
                // (the float kernel amortises each weight-row fetch over
                // the sample block); per-row masks are the same
                // mix3-seeded draws the per-sample loop made, so the
                // sample set is bit-identical.
                let mut xs = Vec::with_capacity(count * beat.len());
                for _ in 0..count {
                    xs.extend_from_slice(beat);
                }
                let masks = seeded_masks(&cfg, *seed, req_seed, start, count);
                let samples = model.forward(&xs, count, &masks);
                debug_assert_eq!(samples.len(), count * out_len);
                Ok(SampleBlock {
                    start,
                    count,
                    out_len,
                    samples,
                    model_latency_ms: ms,
                })
            }
            EngineKind::PjrtCpu { runtime, cfg, params, seed, .. } => {
                // Needs a fwd artifact with rows = the shard size.
                let meta = runtime
                    .manifest
                    .forward_for(&cfg.name(), count)
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "no fwd_n{count} artifact for {} — MC-shard \
                             over PJRT needs one artifact per shard size",
                            cfg.name()
                        )
                    })?
                    .clone();
                let mut xs = Vec::with_capacity(count * beat.len());
                for _ in 0..count {
                    xs.extend_from_slice(beat);
                }
                let masks =
                    seeded_masks(cfg, *seed, req_seed, start, count);
                let mut args: Vec<HostValue> = params
                    .iter()
                    .map(|p| HostValue::F32(p.clone()))
                    .collect();
                args.push(HostValue::F32(Tensor::new(
                    vec![count, cfg.seq_len, cfg.input_dim],
                    xs,
                )));
                for m in &masks.tensors {
                    args.push(HostValue::F32(m.clone()));
                }
                let t0 = Instant::now();
                let exe = runtime.load(&meta.name)?;
                let out = exe.run(&args)?;
                let ms = t0.elapsed().as_secs_f64() * 1e3;
                let y = out.into_iter().next().expect("fwd output");
                let out_len = y.data.len() / count;
                Ok(SampleBlock {
                    start,
                    count,
                    out_len,
                    samples: y.data,
                    model_latency_ms: ms,
                })
            }
        }
    }
}

/// One request's shard in a batched engine call
/// ([`Engine::infer_samples_batch`]).
#[derive(Debug, Clone, Copy)]
pub struct ShardRequest<'a> {
    pub beat: &'a [f32],
    pub req_seed: u64,
    pub start: usize,
    pub count: usize,
}

impl Engine {
    /// Batched shard inference — the fleet worker's blocked entry
    /// point. On the FPGA simulator the whole batch runs as **one**
    /// blocked accelerator call ([`Accelerator::predict_batch_shards`]):
    /// every weight row is fetched once per timestep for all
    /// (request, sample) lanes, instead of once per request shard. Other
    /// backends fall back to per-shard calls (PJRT already batches rows
    /// internally; the GPU model batches its sample block). Outputs are
    /// bit-identical to per-shard [`Engine::infer_samples`] calls.
    /// Returns one result per request, in order.
    pub fn infer_samples_batch(
        &mut self,
        reqs: &[ShardRequest],
        group: usize,
    ) -> Vec<Result<SampleBlock>> {
        if let EngineKind::FpgaSim { accel, sim } = &mut self.kind {
            if reqs.iter().all(|q| q.count > 0) {
                let batch: Vec<crate::fpga::accel::BatchRequest> = reqs
                    .iter()
                    .map(|q| crate::fpga::accel::BatchRequest {
                        beat: q.beat,
                        req_seed: q.req_seed,
                        start: q.start,
                        count: q.count,
                    })
                    .collect();
                let outs = accel.predict_batch_shards(&batch);
                return reqs
                    .iter()
                    .zip(outs)
                    .map(|(q, out)| {
                        // Per-shard hardware latency is unchanged by the
                        // batched simulation: the modelled FPGA still
                        // streams `count` passes for this request.
                        let ms =
                            sim.simulate_ms(1, q.count, ZC706.clock_hz);
                        Ok(SampleBlock {
                            start: q.start,
                            count: q.count,
                            out_len: out.out_len,
                            samples: out.samples,
                            model_latency_ms: ms,
                        })
                    })
                    .collect();
            }
        }
        reqs.iter()
            .map(|q| {
                self.infer_samples(q.beat, q.req_seed, q.start, q.count, group)
            })
            .collect()
    }

    /// Open streaming lane state for MC lanes `start..start+count` of
    /// a session. FPGA-sim only: streaming sessions are built on the
    /// accelerator's resident fixed-point recurrent state
    /// ([`Accelerator::open_stream`]); the float baselines have no
    /// persistent-state path.
    pub fn open_stream(
        &self,
        session_seed: u64,
        start: usize,
        count: usize,
    ) -> Result<StreamState> {
        match &self.kind {
            EngineKind::FpgaSim { accel, .. } => {
                Ok(accel.open_stream(session_seed, start, count))
            }
            _ => anyhow::bail!("streaming sessions require the fpga backend"),
        }
    }

    /// Feed one session chunk through resident lane state: advances
    /// `st` in place and returns the per-beat MC sample blocks plus
    /// the simulated model latency. The latency is the cycle
    /// simulator's per-beat cost at this lane count, pro-rated by the
    /// timesteps actually consumed — O(chunk), never O(history), which
    /// is the entire point of keeping the state resident.
    pub fn infer_stream_chunk(
        &mut self,
        st: &mut StreamState,
        signal: &[f32],
    ) -> Result<(Vec<McOutput>, f64)> {
        match &mut self.kind {
            EngineKind::FpgaSim { accel, sim } => {
                let idim = accel.cfg.input_dim.max(1);
                let seq = accel.cfg.seq_len.max(1);
                let steps = signal.len() / idim;
                let outs = accel
                    .predict_stream(st, signal)
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
                let per_beat_ms =
                    sim.simulate_ms(1, st.count.max(1), ZC706.clock_hz);
                let ms = per_beat_ms * steps as f64 / seq as f64;
                Ok((outs, ms))
            }
            _ => anyhow::bail!("streaming sessions require the fpga backend"),
        }
    }
}

/// Per-sample-seeded dropout masks for samples `start..start+count`:
/// sample `k` is drawn from `Rng::new(mix3(base, req_seed, k))`,
/// mirroring the accelerator's per-sample LFSR reseeding so software
/// baselines shard the same schedule shape. The whole shard is
/// block-generated as bitplanes ([`MaskBlock::seeded`] — identical
/// `Rng` streams, 1 bit/element) and expanded to the f32 tensor ABI
/// only here, at the float-consumer boundary. The bit-for-bit oracle
/// against the old per-(sample, beat) tensor draws is
/// `mask_block_matches_per_sample_masks_sample_oracle` below.
fn seeded_masks(
    cfg: &ArchConfig,
    base: u64,
    req_seed: u64,
    start: usize,
    count: usize,
) -> Masks {
    if !cfg.is_bayesian() || count == 0 {
        return Masks::ones(cfg, count);
    }
    MaskBlock::seeded(cfg, base, req_seed, start, count).to_masks()
}

/// Float-model MC prediction (shared by the GPU engine and tests).
pub fn predict_float(
    model: &Model,
    beat: &[f32],
    s: usize,
    rng: &mut Rng,
) -> McOutput {
    let cfg = &model.cfg;
    let mut xs = Vec::with_capacity(s * beat.len());
    for _ in 0..s {
        xs.extend_from_slice(beat);
    }
    let masks = if cfg.is_bayesian() {
        Masks::sample(cfg, s, rng)
    } else {
        Masks::ones(cfg, s)
    };
    let out = model.forward(&xs, s, &masks);
    let out_len = out.len() / s;
    McOutput { samples: out, s, out_len }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_model(bayes: &str) -> (ArchConfig, Model) {
        let mut cfg = ArchConfig::new(Task::Classify, 8, bayes.len(), bayes);
        cfg.seq_len = 20;
        let model = Model::init(cfg.clone(), &mut Rng::new(0));
        (cfg, model)
    }

    #[test]
    fn fpga_engine_serves_batch() {
        let (cfg, model) = tiny_model("YN");
        let mut e = Engine::fpga(&cfg, &model, ReuseFactors::new(2, 1, 1), 4, 9);
        let beat: Vec<f32> = (0..20).map(|i| (i as f32 * 0.3).sin()).collect();
        let beats = [beat.as_slice(), beat.as_slice()];
        let preds = e.infer_batch(&beats).unwrap();
        assert_eq!(preds.len(), 2);
        for p in &preds {
            assert_eq!(p.mean.len(), 4);
            assert!((p.mean.iter().sum::<f32>() - 1.0).abs() < 1e-3);
            assert!(p.model_latency_ms > 0.0);
        }
    }

    #[test]
    fn gpu_engine_reports_analytic_latency() {
        let (_, model) = tiny_model("NN");
        let cfg = model.cfg.clone();
        let mut e = Engine::gpu(model, 1, 0);
        let beat: Vec<f32> = vec![0.0; 20];
        let preds = e.infer_batch(&[&beat]).unwrap();
        let expect = GpuModel::latency_ms(&cfg, 1, 1);
        assert!((preds[0].model_latency_ms - expect).abs() < 1e-9);
    }

    /// MC-shard invariant at the engine level: merging shard partials
    /// must reproduce the whole-range seeded prediction.
    #[test]
    fn sharded_partials_merge_to_whole_prediction() {
        let (cfg, model) = tiny_model("YY");
        let reuse = ReuseFactors::new(1, 1, 1);
        let s = 8;
        let req_seed = 42u64;

        let mut whole = Engine::fpga(&cfg, &model, reuse, s, 9);
        let w = whole.infer_partial(
            &beat20(), req_seed, 0, s, 1,
        ).unwrap();
        let (wm, ws) = crate::metrics::pooled_mean_std(&w.sum, &w.sumsq, s);

        // Three engines, same design seed, disjoint shards.
        let mut sum = vec![0f64; w.sum.len()];
        let mut sumsq = vec![0f64; w.sum.len()];
        for (start, count) in [(0usize, 3usize), (3, 3), (6, 2)] {
            let mut e = Engine::fpga(&cfg, &model, reuse, s, 9);
            let p = e
                .infer_partial(&beat20(), req_seed, start, count, 1)
                .unwrap();
            assert_eq!(p.count, count);
            assert!(p.model_latency_ms > 0.0);
            for i in 0..sum.len() {
                sum[i] += p.sum[i];
                sumsq[i] += p.sumsq[i];
            }
        }
        let (mm, ms) = crate::metrics::pooled_mean_std(&sum, &sumsq, s);
        for i in 0..wm.len() {
            assert!((mm[i] - wm[i]).abs() < 1e-5, "mean[{i}]");
            assert!((ms[i] - ws[i]).abs() < 1e-4, "std[{i}]");
        }
    }

    /// `infer_partial` is exactly `infer_samples` + moment reduction,
    /// for every backend shape we can build offline.
    #[test]
    fn raw_samples_reduce_to_the_partial_prediction() {
        let (cfg, model) = tiny_model("YY");
        let mut fpga =
            Engine::fpga(&cfg, &model, ReuseFactors::new(1, 1, 1), 8, 9);
        let (c2, m2) = tiny_model("YY");
        let _ = c2;
        let mut gpu = Engine::gpu(m2, 8, 9);
        for e in [&mut fpga, &mut gpu] {
            let block = e.infer_samples(&beat20(), 7, 2, 5, 1).unwrap();
            assert_eq!(block.start, 2);
            assert_eq!(block.count, 5);
            assert_eq!(block.samples.len(), 5 * block.out_len);
            let p = e.infer_partial(&beat20(), 7, 2, 5, 1).unwrap();
            let from_raw = PartialPrediction::from_samples(
                &block.samples,
                block.count,
                block.out_len,
                block.model_latency_ms,
            );
            assert_eq!(p.sum, from_raw.sum);
            assert_eq!(p.sumsq, from_raw.sumsq);
            assert_eq!(p.count, from_raw.count);
        }
    }

    #[test]
    fn gpu_partial_is_deterministic_per_request_seed() {
        let (_, model) = tiny_model("YY");
        let mut a = Engine::gpu(model, 4, 5);
        let p1 = a.infer_partial(&beat20(), 7, 0, 4, 1).unwrap();
        let p2 = a.infer_partial(&beat20(), 7, 0, 4, 1).unwrap();
        assert_eq!(p1.sum, p2.sum, "same (req, k) seeds => same samples");
        let p3 = a.infer_partial(&beat20(), 8, 0, 4, 1).unwrap();
        assert_ne!(p1.sum, p3.sum, "request seed must perturb samples");
    }

    fn beat20() -> Vec<f32> {
        (0..20).map(|i| (i as f32 * 0.3).sin()).collect()
    }

    /// Bitplane-mask oracle (ISSUE 5): the block-generated
    /// [`MaskBlock`] must reproduce, bit for bit, the legacy
    /// per-(sample) tensor draws — one `Masks::sample` per mix3-seeded
    /// `Rng`, rows concatenated — that `seeded_masks` used to make.
    #[test]
    fn mask_block_matches_per_sample_masks_sample_oracle() {
        use crate::rng::mix3;
        for bayes in ["YY", "YN", "NY"] {
            let (cfg, _) = tiny_model(bayes);
            let (base, req_seed, start, count) = (9u64, 42u64, 3usize, 5usize);
            // Legacy oracle, reconstructed verbatim: per-sample tensors
            // from the same seed schedule, concatenated along rows.
            let per: Vec<Masks> = (0..count)
                .map(|j| {
                    let mut rng = Rng::new(mix3(
                        base,
                        req_seed,
                        (start + j) as u64,
                    ));
                    Masks::sample(&cfg, 1, &mut rng)
                })
                .collect();
            let want: Vec<Tensor> = (0..per[0].tensors.len())
                .map(|ti| {
                    let mut shape = per[0].tensors[ti].shape.clone();
                    shape[0] = count;
                    let mut data = Vec::new();
                    for m in &per {
                        data.extend_from_slice(&m.tensors[ti].data);
                    }
                    Tensor::new(shape, data)
                })
                .collect();

            let block =
                MaskBlock::seeded(&cfg, base, req_seed, start, count);
            let got = block.to_masks();
            assert_eq!(got.tensors.len(), want.len());
            for (ti, (g, w)) in
                got.tensors.iter().zip(&want).enumerate()
            {
                assert_eq!(g.shape, w.shape, "{bayes} tensor {ti} shape");
                assert_eq!(
                    g.data, w.data,
                    "{bayes} tensor {ti}: block-generated bitplane \
                     masks drifted from the per-sample draws"
                );
            }
            // The packed block is a small fraction of the expanded f32
            // tensors it replaces.
            let expanded: usize =
                want.iter().map(|t| t.data.len() * 4).sum();
            if cfg.is_bayesian() {
                assert!(
                    block.bytes() < expanded / 4,
                    "packed {}B vs expanded {}B",
                    block.bytes(),
                    expanded
                );
            }
        }
    }

    /// Fleet-level leg of the backend-equivalence contract: a batched
    /// engine call computes bit-identical sample blocks under every
    /// kernel backend (including scalar, which also flips the
    /// structural per-sample loop).
    #[test]
    fn all_kernel_backends_bit_identical_at_fleet_level() {
        use crate::kernels::KernelBackend;
        let (cfg, model) = tiny_model("YY");
        let reuse = ReuseFactors::new(1, 1, 1);
        let beat_a = beat20();
        let beat_b: Vec<f32> =
            (0..20).map(|i| (i as f32 * 0.41).cos()).collect();
        let reqs = [
            ShardRequest { beat: &beat_a, req_seed: 7, start: 0, count: 4 },
            ShardRequest { beat: &beat_b, req_seed: 8, start: 2, count: 3 },
        ];
        let run = |backend: KernelBackend| -> Vec<Vec<f32>> {
            let mut e = Engine::fpga(&cfg, &model, reuse, 8, 9);
            e.set_kernel_backend(backend);
            e.infer_samples_batch(&reqs, 1)
                .into_iter()
                .map(|r| r.unwrap().samples)
                .collect()
        };
        let want = run(KernelBackend::Blocked);
        for backend in [
            KernelBackend::Scalar,
            KernelBackend::Simd,
            KernelBackend::Parallel,
        ] {
            assert_eq!(
                run(backend),
                want,
                "{}: fleet-level batch drifted",
                backend.name()
            );
        }
    }

    /// A shared mask bank attached at the engine level changes no bits
    /// and converts the second identical batch into hits.
    #[test]
    fn engine_mask_bank_is_transparent_and_hits_when_warm() {
        let (cfg, model) = tiny_model("YY");
        let reuse = ReuseFactors::new(1, 1, 1);
        let beat = beat20();
        let reqs = [ShardRequest {
            beat: &beat,
            req_seed: 7,
            start: 0,
            count: 4,
        }];
        let mut plain = Engine::fpga(&cfg, &model, reuse, 8, 9);
        let want: Vec<Vec<f32>> = plain
            .infer_samples_batch(&reqs, 1)
            .into_iter()
            .map(|r| r.unwrap().samples)
            .collect();
        let bank = Arc::new(MaskBank::new(1 << 20));
        let mut banked = Engine::fpga(&cfg, &model, reuse, 8, 9);
        banked.set_mask_bank(Some(bank.clone()));
        for round in 0..2 {
            let got: Vec<Vec<f32>> = banked
                .infer_samples_batch(&reqs, 1)
                .into_iter()
                .map(|r| r.unwrap().samples)
                .collect();
            assert_eq!(got, want, "round {round}: banked engine drifted");
        }
        let s = bank.stats();
        assert!(s.hits > 0, "warm round must hit");
        assert!(s.misses > 0 && s.resident_bytes > 0);
    }

    /// Engine-level leg of the streaming bitwise contract: resuming a
    /// session chunk by chunk equals one continuous pass, the per-chunk
    /// step meter never touches history, and the O(chunk) latencies sum
    /// to the one-shot cost.
    #[test]
    fn stream_chunks_match_one_shot_bitwise_at_engine_level() {
        let (cfg, model) = tiny_model("YY");
        let reuse = ReuseFactors::new(2, 1, 1);
        let signal: Vec<f32> =
            (0..60).map(|i| (i as f32 * 0.17).sin()).collect();

        let mut whole = Engine::fpga(&cfg, &model, reuse, 4, 9);
        let mut ws = whole.open_stream(11, 0, 4).unwrap();
        let (wout, wms) =
            whole.infer_stream_chunk(&mut ws, &signal).unwrap();
        assert_eq!(wout.len(), 3, "three beat boundaries in 60 steps");
        assert!(wms > 0.0);

        let lane_steps = |e: &Engine| match &e.kind {
            EngineKind::FpgaSim { accel, .. } => accel.lane_steps(),
            _ => unreachable!(),
        };
        let mut chunked = Engine::fpga(&cfg, &model, reuse, 4, 9);
        let mut cs = chunked.open_stream(11, 0, 4).unwrap();
        let mut outs = Vec::new();
        let mut ms = 0.0;
        for range in [0..13usize, 13..46, 46..60] {
            let before = lane_steps(&chunked);
            let (o, m) = chunked
                .infer_stream_chunk(&mut cs, &signal[range.clone()])
                .unwrap();
            outs.extend(o);
            ms += m;
            // O(chunk): a resumed chunk steps exactly its own
            // timesteps (× layers × lanes), never the history.
            assert_eq!(
                lane_steps(&chunked) - before,
                range.len() as u64 * 2 * 4
            );
        }
        assert_eq!(outs.len(), wout.len());
        for (c, w) in outs.iter().zip(&wout) {
            assert_eq!(c.samples, w.samples, "bitwise across chunk splits");
            assert_eq!((c.s, c.out_len), (w.s, w.out_len));
        }
        assert!((ms - wms).abs() < 1e-9, "chunk costs sum to one-shot");

        // Float baselines have no resident-state path.
        let (_, m2) = tiny_model("YY");
        let gpu = Engine::gpu(m2, 4, 9);
        assert!(gpu.open_stream(1, 0, 4).is_err());
    }

    #[test]
    fn bayesian_engine_has_nonzero_uncertainty() {
        let (cfg, model) = tiny_model("YY");
        let mut e =
            Engine::fpga(&cfg, &model, ReuseFactors::new(1, 1, 1), 8, 3);
        let beat: Vec<f32> = (0..20).map(|i| (i as f32 * 0.5).cos()).collect();
        let preds = e.infer_batch(&[&beat]).unwrap();
        assert!(
            preds[0].std.iter().any(|&v| v > 0.0),
            "MCD must yield spread"
        );
    }
}
