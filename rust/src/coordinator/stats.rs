//! Serving metrics: latency percentiles + throughput accounting.

use std::time::Duration;

/// Online latency collector (stores all samples; serving runs here are
/// bounded, so exact percentiles beat sketches).
///
/// Percentile queries sort a cached copy once and reuse it until the
/// next record/merge invalidates it — a sequence of `percentile_ms`
/// calls (the JSON report asks for several) costs one sort, not one
/// sort per call. For mergeable, report-time-bounded tails across a
/// fleet prefer [`crate::obs::LogHistogram`]; this collector stays the
/// exact reference.
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    samples_us: Vec<f64>,
    sorted_us: Vec<f64>,
    dirty: bool,
}

impl LatencyStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_secs_f64() * 1e6);
        self.dirty = true;
    }

    pub fn record_ms(&mut self, ms: f64) {
        self.samples_us.push(ms * 1e3);
        self.dirty = true;
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    pub fn mean_ms(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<f64>() / self.samples_us.len() as f64
            / 1e3
    }

    /// Exact percentile (nearest-rank), in milliseconds.
    pub fn percentile_ms(&mut self, p: f64) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        if self.dirty || self.sorted_us.len() != self.samples_us.len() {
            self.sorted_us.clear();
            self.sorted_us.extend_from_slice(&self.samples_us);
            self.sorted_us
                .sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.dirty = false;
        }
        let v = &self.sorted_us;
        let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
        v[rank.clamp(1, v.len()) - 1] / 1e3
    }

    /// Exact count of samples strictly above `ms` — the overall SLO
    /// attainment numerator (windowed counts use the bucketed
    /// `LogHistogram::count_over_us`; this stays the exact reference).
    pub fn count_over_ms(&self, ms: f64) -> usize {
        let us = ms * 1e3;
        self.samples_us.iter().filter(|&&v| v > us).count()
    }

    pub fn max_ms(&self) -> f64 {
        self.samples_us.iter().cloned().fold(0.0, f64::max) / 1e3
    }

    pub fn min_ms(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().cloned().fold(f64::INFINITY, f64::min) / 1e3
    }

    /// Requests per second given a wall-clock window.
    pub fn throughput(&self, wall: Duration) -> f64 {
        if wall.is_zero() {
            return 0.0;
        }
        self.samples_us.len() as f64 / wall.as_secs_f64()
    }

    pub fn merge(&mut self, other: &LatencyStats) {
        self.samples_us.extend_from_slice(&other.samples_us);
        self.dirty = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_exact() {
        let mut s = LatencyStats::new();
        for ms in [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0] {
            s.record_ms(ms);
        }
        assert_eq!(s.count(), 10);
        assert!((s.percentile_ms(50.0) - 5.0).abs() < 1e-9);
        assert!((s.percentile_ms(90.0) - 9.0).abs() < 1e-9);
        assert!((s.percentile_ms(100.0) - 10.0).abs() < 1e-9);
        assert!((s.mean_ms() - 5.5).abs() < 1e-9);
        assert!((s.max_ms() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn empty_stats_are_zero() {
        let mut s = LatencyStats::new();
        assert_eq!(s.percentile_ms(99.0), 0.0);
        assert_eq!(s.mean_ms(), 0.0);
    }

    /// Percentile edge cases: 0, 1 and 2 samples must never index out of
    /// bounds and must follow nearest-rank semantics.
    #[test]
    fn percentile_zero_one_two_samples() {
        // 0 samples: everything is 0.
        let mut s0 = LatencyStats::new();
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(s0.percentile_ms(p), 0.0);
        }
        assert_eq!(s0.min_ms(), 0.0);
        assert_eq!(s0.max_ms(), 0.0);

        // 1 sample: every percentile is that sample.
        let mut s1 = LatencyStats::new();
        s1.record_ms(7.0);
        for p in [0.0, 1.0, 50.0, 99.0, 100.0] {
            assert!((s1.percentile_ms(p) - 7.0).abs() < 1e-9, "p={p}");
        }
        assert!((s1.min_ms() - 7.0).abs() < 1e-9);

        // 2 samples: nearest-rank splits at p = 50.
        let mut s2 = LatencyStats::new();
        s2.record_ms(1.0);
        s2.record_ms(9.0);
        assert!((s2.percentile_ms(0.0) - 1.0).abs() < 1e-9);
        assert!((s2.percentile_ms(50.0) - 1.0).abs() < 1e-9);
        assert!((s2.percentile_ms(51.0) - 9.0).abs() < 1e-9);
        assert!((s2.percentile_ms(99.0) - 9.0).abs() < 1e-9);
        assert!((s2.percentile_ms(100.0) - 9.0).abs() < 1e-9);
        assert!((s2.min_ms() - 1.0).abs() < 1e-9);
        assert!((s2.max_ms() - 9.0).abs() < 1e-9);
    }

    #[test]
    fn count_over_is_exact_and_strict() {
        let mut s = LatencyStats::new();
        for ms in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.record_ms(ms);
        }
        assert_eq!(s.count_over_ms(3.0), 2, "strictly above, not >=");
        assert_eq!(s.count_over_ms(0.0), 5);
        assert_eq!(s.count_over_ms(5.0), 0);
        assert_eq!(LatencyStats::new().count_over_ms(1.0), 0);
    }

    #[test]
    fn throughput_and_merge() {
        let mut a = LatencyStats::new();
        a.record(Duration::from_millis(2));
        let mut b = LatencyStats::new();
        b.record(Duration::from_millis(4));
        a.merge(&b);
        assert_eq!(a.count(), 2);
        let thr = a.throughput(Duration::from_secs(2));
        assert!((thr - 1.0).abs() < 1e-9);
        assert_eq!(a.throughput(Duration::ZERO), 0.0, "zero wall guard");
    }

    /// The sorted cache must invalidate on every mutation path:
    /// record, record_ms and merge after a percentile query.
    #[test]
    fn sorted_cache_invalidates_on_mutation() {
        let mut s = LatencyStats::new();
        s.record_ms(5.0);
        assert!((s.percentile_ms(100.0) - 5.0).abs() < 1e-9);
        s.record_ms(9.0);
        assert!((s.percentile_ms(100.0) - 9.0).abs() < 1e-9);
        s.record(Duration::from_millis(20));
        assert!((s.percentile_ms(100.0) - 20.0).abs() < 1e-9);
        let mut other = LatencyStats::new();
        other.record_ms(40.0);
        s.merge(&other);
        assert!((s.percentile_ms(100.0) - 40.0).abs() < 1e-9);
        assert!((s.percentile_ms(0.0) - 5.0).abs() < 1e-9);
    }
}
