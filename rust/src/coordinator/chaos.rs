//! Deterministic fault injection for the serving fleet (the chaos
//! harness — ROADMAP "chaos scenarios on top of the open-loop
//! harness", second half).
//!
//! A [`FaultPlan`] is parsed from a compact directive string:
//!
//! ```text
//! kill=e1@250ms,stall=e2@100ms+50ms,drop=0.01
//! ```
//!
//! * `kill=e<N>@<T>ms` — worker `N` (zero-based) panics the first time
//!   it looks at its queue at or after `T` ms from fleet start. The
//!   panic unwinds the worker thread: its queue receiver drops, queued
//!   items are lost, and the fleet's supervision path (obituary →
//!   health mask → shard re-dispatch) takes over.
//! * `stall=e<N>@<T>ms+<D>ms` — worker `N` sleeps `D` ms before the
//!   first engine call it issues at or after `T` ms (a one-shot
//!   straggler; repeat the directive for repeated stalls).
//! * `drop=<p>` — every fixed/stream reply is independently discarded
//!   with probability `p`, decided by a hash of
//!   `(plan seed, request seed, shard start)` — deliberately
//!   *engine-independent*, so a re-dispatched or hedged re-execution
//!   of the same shard is dropped too and a lost reply reliably
//!   surfaces as a typed degraded wait instead of being papered over.
//!
//! Determinism contract: the same plan string and seed produce the
//! same per-worker schedule and the same drop decisions. Kill/stall
//! *trigger times* are wall-clock offsets from the fleet epoch, so
//! which in-flight request they land on depends on machine speed — but
//! the set of faults injected, and (because per-`(request, sample)`
//! mask seeding makes re-executed shards bit-identical) the merged
//! outputs, do not.

use std::time::Duration;

/// One scheduled one-shot stall window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StallSpec {
    /// Offset from the fleet epoch at which the stall arms.
    pub at: Duration,
    /// How long the worker sleeps when it fires.
    pub dur: Duration,
}

/// A parsed, seeded fault-injection plan (see module docs for the
/// grammar). `Default` is the empty plan: no faults, nothing armed.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// `(engine, offset)` kill schedule.
    pub kills: Vec<(usize, Duration)>,
    /// `(engine, stall)` straggler schedule.
    pub stalls: Vec<(usize, StallSpec)>,
    /// Per-reply drop probability in `[0, 1]`.
    pub drop_p: f64,
    /// Seeds the drop-decision hash (set from the CLI `--seed`).
    pub seed: u64,
}

impl FaultPlan {
    /// Parse the `kill=…,stall=…,drop=…` directive grammar.
    pub fn parse(s: &str) -> Result<Self, String> {
        let mut plan = FaultPlan::default();
        for directive in s.split(',') {
            let directive = directive.trim();
            if directive.is_empty() {
                continue;
            }
            let (key, val) = directive.split_once('=').ok_or_else(|| {
                format!(
                    "chaos directive {directive:?} wants key=value \
                     (kill=e1@250ms | stall=e2@100ms+50ms | drop=0.01)"
                )
            })?;
            match key {
                "kill" => {
                    let (e, at) = val.split_once('@').ok_or_else(|| {
                        format!("kill={val:?} wants e<N>@<T>ms")
                    })?;
                    plan.kills.push((
                        parse_engine(e)?,
                        parse_ms(at)?,
                    ));
                }
                "stall" => {
                    let (e, when) =
                        val.split_once('@').ok_or_else(|| {
                            format!(
                                "stall={val:?} wants e<N>@<T>ms+<D>ms"
                            )
                        })?;
                    let (at, dur) =
                        when.split_once('+').ok_or_else(|| {
                            format!(
                                "stall={val:?} wants e<N>@<T>ms+<D>ms"
                            )
                        })?;
                    plan.stalls.push((
                        parse_engine(e)?,
                        StallSpec {
                            at: parse_ms(at)?,
                            dur: parse_ms(dur)?,
                        },
                    ));
                }
                "drop" => {
                    let p: f64 = val.parse().map_err(|_| {
                        format!("drop={val:?} wants a probability")
                    })?;
                    if !(0.0..=1.0).contains(&p) {
                        return Err(format!(
                            "drop={p} out of range [0, 1]"
                        ));
                    }
                    plan.drop_p = p;
                }
                other => {
                    return Err(format!(
                        "unknown chaos directive {other:?} \
                         (kill | stall | drop)"
                    ));
                }
            }
        }
        Ok(plan)
    }

    /// Bind the drop-decision seed (the CLI threads `--seed` through).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// `true` if the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.kills.is_empty()
            && self.stalls.is_empty()
            && self.drop_p == 0.0
    }

    /// The slice of the plan one worker executes. Cheap and pure: the
    /// same plan and index always produce the same schedule.
    pub fn for_engine(&self, idx: usize) -> WorkerChaos {
        WorkerChaos {
            kill_at: self
                .kills
                .iter()
                .filter(|&&(e, _)| e == idx)
                .map(|&(_, at)| at)
                .min(),
            stalls: self
                .stalls
                .iter()
                .filter(|&&(e, _)| e == idx)
                .map(|&(_, sp)| (sp, false))
                .collect(),
            drop_p: self.drop_p,
            seed: self.seed,
        }
    }
}

fn parse_engine(s: &str) -> Result<usize, String> {
    s.strip_prefix('e')
        .and_then(|n| n.parse().ok())
        .ok_or_else(|| {
            format!("engine {s:?} wants e<N> (zero-based index)")
        })
}

fn parse_ms(s: &str) -> Result<Duration, String> {
    let num = s.strip_suffix("ms").unwrap_or(s);
    let ms: f64 = num
        .parse()
        .map_err(|_| format!("duration {s:?} wants <N>ms"))?;
    if ms < 0.0 || !ms.is_finite() {
        return Err(format!("duration {s:?} must be >= 0"));
    }
    Ok(Duration::from_secs_f64(ms / 1e3))
}

/// One worker's runtime view of the plan. The drop decision is a pure
/// hash so the schedule replays identically; the kill/stall triggers
/// compare elapsed-since-epoch against the scheduled offsets.
#[derive(Debug, Clone, Default)]
pub struct WorkerChaos {
    kill_at: Option<Duration>,
    stalls: Vec<(StallSpec, bool)>,
    drop_p: f64,
    seed: u64,
}

/// Panic payload for a chaos-injected worker kill: lets `Fleet::join`
/// distinguish an injected death from a genuine engine panic (both are
/// folded into the `faults` summary either way).
#[derive(Debug)]
pub struct ChaosKill(pub usize);

impl WorkerChaos {
    /// `true` if this worker has any fault scheduled.
    pub fn armed(&self) -> bool {
        self.kill_at.is_some()
            || !self.stalls.is_empty()
            || self.drop_p > 0.0
    }

    /// Should the worker die now? Checked at queue-pull boundaries
    /// only, so a kill never fires mid-item (re-dispatched work is
    /// always either unprocessed or fully parked).
    pub fn should_kill(&self, elapsed: Duration) -> bool {
        self.kill_at.is_some_and(|at| elapsed >= at)
    }

    /// One-shot straggler: the first call at or after a stall's offset
    /// returns its duration (and disarms it).
    pub fn stall_for(&mut self, elapsed: Duration) -> Option<Duration> {
        for (spec, fired) in self.stalls.iter_mut() {
            if !*fired && elapsed >= spec.at {
                *fired = true;
                return Some(spec.dur);
            }
        }
        None
    }

    /// Deterministic reply-drop decision for one shard. Keyed on
    /// `(plan seed, request seed, shard start)` — engine-independent
    /// by design (see module docs).
    pub fn should_drop(&self, req_seed: u64, start: usize) -> bool {
        if self.drop_p <= 0.0 {
            return false;
        }
        if self.drop_p >= 1.0 {
            return true;
        }
        let h = mix64(
            mix64(mix64(self.seed ^ 0x9E37_79B9_7F4A_7C15)
                .wrapping_add(req_seed))
            .wrapping_add(start as u64),
        );
        let unit = (h >> 11) as f64 / (1u64 << 53) as f64;
        unit < self.drop_p
    }
}

/// SplitMix64 finaliser — the same avalanche the mask RNG family uses,
/// kept local so the chaos layer has no RNG dependencies.
fn mix64(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_full_grammar() {
        let plan = FaultPlan::parse(
            "kill=e1@250ms,stall=e2@100ms+50ms,drop=0.01",
        )
        .expect("valid plan");
        assert_eq!(
            plan.kills,
            vec![(1, Duration::from_millis(250))]
        );
        assert_eq!(
            plan.stalls,
            vec![(
                2,
                StallSpec {
                    at: Duration::from_millis(100),
                    dur: Duration::from_millis(50),
                }
            )]
        );
        assert_eq!(plan.drop_p, 0.01);
        assert!(!plan.is_empty());
        // Bare numbers are milliseconds too.
        let bare = FaultPlan::parse("kill=e0@5").expect("bare ms");
        assert_eq!(bare.kills, vec![(0, Duration::from_millis(5))]);
        assert!(FaultPlan::parse("").expect("empty ok").is_empty());
    }

    #[test]
    fn rejects_malformed_directives() {
        for bad in [
            "kill=1@5ms",    // missing e prefix
            "kill=e1",       // missing @time
            "stall=e1@5ms",  // missing +duration
            "drop=1.5",      // out of range
            "drop=x",        // not a number
            "pause=e1@5ms",  // unknown directive
            "kill",          // no key=value
        ] {
            assert!(
                FaultPlan::parse(bad).is_err(),
                "{bad:?} must not parse"
            );
        }
    }

    #[test]
    fn same_plan_and_seed_replays_the_same_schedule() {
        let text = "kill=e1@250ms,stall=e0@10ms+20ms,drop=0.5";
        let a = FaultPlan::parse(text).unwrap().with_seed(7);
        let b = FaultPlan::parse(text).unwrap().with_seed(7);
        assert_eq!(a, b, "parse is deterministic");
        let ca = a.for_engine(0);
        let cb = b.for_engine(0);
        let decisions = |c: &WorkerChaos| -> Vec<bool> {
            (0..256u64)
                .flat_map(|req| {
                    (0..4).map(move |s| (req, s))
                })
                .map(|(req, s)| c.should_drop(req, s))
                .collect()
        };
        assert_eq!(
            decisions(&ca),
            decisions(&cb),
            "same seed, same drop schedule"
        );
        // A different seed decides differently somewhere, and the
        // empirical rate tracks p.
        let cc = FaultPlan::parse(text).unwrap().with_seed(8).for_engine(0);
        assert_ne!(decisions(&ca), decisions(&cc));
        let dropped =
            decisions(&ca).iter().filter(|&&d| d).count() as f64;
        let rate = dropped / 1024.0;
        assert!(
            (rate - 0.5).abs() < 0.1,
            "drop rate {rate} should track p=0.5"
        );
    }

    #[test]
    fn worker_slices_trigger_at_their_offsets() {
        let plan = FaultPlan::parse(
            "kill=e1@250ms,stall=e1@100ms+50ms,drop=1.0",
        )
        .unwrap();
        let mut w1 = plan.for_engine(1);
        let w0 = plan.for_engine(0);
        assert!(w1.armed());
        assert!(w0.armed(), "drop applies to every worker");
        assert!(!w0.should_kill(Duration::from_secs(10)));
        assert!(!w1.should_kill(Duration::from_millis(249)));
        assert!(w1.should_kill(Duration::from_millis(250)));
        assert_eq!(w1.stall_for(Duration::from_millis(99)), None);
        assert_eq!(
            w1.stall_for(Duration::from_millis(100)),
            Some(Duration::from_millis(50))
        );
        assert_eq!(
            w1.stall_for(Duration::from_millis(200)),
            None,
            "stalls are one-shot"
        );
        assert!(w1.should_drop(3, 0), "p=1 drops everything");
        assert!(
            !FaultPlan::default().for_engine(0).armed(),
            "empty plan arms nothing"
        );
    }
}
