//! Serving coordinator — the L3 request path.
//!
//! A deployment serves ECG beats arriving as requests (the paper's
//! "requests need to be processed as soon as they arrive", batch size 1
//! on the FPGA; the CPU/GPU baselines batch). The coordinator owns:
//!
//! * a bounded request queue with backpressure,
//! * a batcher (size/deadline policy) for engines that benefit from
//!   batching,
//! * worker threads driving an inference engine,
//! * MC aggregation (mean prediction + uncertainty per request),
//! * latency/throughput metrics,
//! * a sharded multi-engine fleet ([`fleet`]) with round-robin /
//!   least-loaded / MC-shard placement ([`router`]) and queue-depth
//!   admission control — see `docs/serving.md` for the architecture,
//! * adaptive per-request MC sampling ([`Fleet::submit_adaptive`] /
//!   [`Fleet::wait_adaptive`]) driven by the [`crate::uq`] controller —
//!   see `docs/uncertainty.md`,
//! * staged tracing, per-stage latency histograms and engine health
//!   counters via [`crate::obs`] (opt-in, bit-identical outputs when
//!   off — see `docs/observability.md`),
//! * deterministic fault injection ([`chaos`], `--chaos`) and the fleet
//!   fault-tolerance plane it exercises: worker-death supervision,
//!   shard re-dispatch, straggler hedging and typed degraded outcomes
//!   — see `docs/serving.md` §Fault tolerance.
//!
//! No tokio in this offline environment (DESIGN.md §Substitutions):
//! std::thread + mpsc channels implement the same event loop.
//!
//! Streaming sessions ([`session`], [`Fleet::open_session`]) keep MC
//! lane state resident between chunks so long-lived signals pay
//! O(chunk) per decision — see `docs/serving.md` §Streaming sessions.

pub mod batcher;
pub mod chaos;
pub mod fleet;
pub mod loadgen;
pub mod engines;
pub mod router;
pub mod server;
pub mod session;
pub mod stats;

/// Default bounded queue depth per engine, shared by the server, the
/// fleet, the loadgen presets and the CLI (one knob, one value).
pub const DEFAULT_QUEUE_DEPTH: usize = 256;

pub use batcher::{Batch, Batcher, BatchPolicy};
pub use chaos::FaultPlan;
pub use engines::{
    Engine, EngineKind, PartialPrediction, Prediction, SampleBlock,
    ShardRequest,
};
pub use fleet::{
    AdaptiveResponse, AdaptiveTicket, ChunkResponse, ChunkTicket, Fleet,
    FleetConfig, FleetError, FleetObs, FleetResponse, FleetSummary,
    Ticket,
};
pub use loadgen::{
    run_open_loop, run_stream_open_loop, OpenLoopOutcome, PayloadClass,
    PoissonTrace, ScenarioSpec, ScheduledRequest, StreamLoopOutcome,
    SCENARIOS,
};
pub use router::{Router, RouterPolicy};
pub use server::{Server, ServerConfig, ServeSummary};
pub use session::{
    Resume, SessionError, SessionMeta, SessionStats, SessionTable,
};
pub use stats::LatencyStats;
