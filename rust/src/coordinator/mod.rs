//! Serving coordinator — the L3 request path.
//!
//! A deployment serves ECG beats arriving as requests (the paper's
//! "requests need to be processed as soon as they arrive", batch size 1
//! on the FPGA; the CPU/GPU baselines batch). The coordinator owns:
//!
//! * a bounded request queue with backpressure,
//! * a batcher (size/deadline policy) for engines that benefit from
//!   batching,
//! * worker threads driving an inference engine,
//! * MC aggregation (mean prediction + uncertainty per request),
//! * latency/throughput metrics.
//!
//! No tokio in this offline environment (DESIGN.md §Substitutions):
//! std::thread + mpsc channels implement the same event loop.

pub mod batcher;
pub mod loadgen;
pub mod engines;
pub mod server;
pub mod stats;

pub use batcher::{Batch, Batcher, BatchPolicy};
pub use engines::{Engine, EngineKind, Prediction};
pub use server::{Server, ServerConfig, ServeSummary};
pub use stats::LatencyStats;
