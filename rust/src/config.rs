//! Architecture configuration `A = {H, NL, B}` (paper Sec. IV-A), mirrored
//! exactly from `python/compile/model.py::ArchConfig`. The parameter and
//! mask orderings defined here are the positional ABI shared with the AOT
//! HLO artifacts.

/// Number of LSTM gates (input, forget, modulation, output).
pub const GATES: usize = 4;

/// The two evaluation tasks of the paper (Sec. V).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    /// Recurrent autoencoder reconstructing the beat (anomaly detection).
    Anomaly,
    /// Recurrent classifier over the 4 ECG5000 classes.
    Classify,
}

impl Task {
    pub fn as_str(&self) -> &'static str {
        match self {
            Task::Anomaly => "anomaly",
            Task::Classify => "classify",
        }
    }
}

impl std::str::FromStr for Task {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "anomaly" => Ok(Task::Anomaly),
            "classify" => Ok(Task::Classify),
            other => Err(format!("unknown task {other:?}")),
        }
    }
}

/// Architecture point: hidden size `H`, layer count `NL`, Bayesian pattern
/// `B` (one flag per LSTM layer: `2*NL` for the autoencoder, `NL` for the
/// classifier) plus the task constants.
#[derive(Debug, Clone, PartialEq)]
pub struct ArchConfig {
    pub task: Task,
    pub hidden: usize,
    pub nl: usize,
    /// `true` = MC-dropout enabled for that LSTM layer (a `Y` in the paper).
    pub bayes: Vec<bool>,
    pub input_dim: usize,
    pub seq_len: usize,
    pub num_classes: usize,
    /// Dropout probability; the paper fixes p = 1/8 (3 LFSRs + NAND).
    pub dropout_p: f32,
}

impl ArchConfig {
    pub fn new(task: Task, hidden: usize, nl: usize, bayes: &str) -> Self {
        let cfg = Self {
            task,
            hidden,
            nl,
            bayes: bayes.chars().map(|c| c == 'Y').collect(),
            input_dim: 1,
            seq_len: 140,
            num_classes: 4,
            dropout_p: 0.125,
        };
        cfg.validate().expect("invalid ArchConfig");
        cfg
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.bayes.len() != self.num_lstm_layers() {
            return Err(format!(
                "B pattern has {} flags, need {}",
                self.bayes.len(),
                self.num_lstm_layers()
            ));
        }
        if self.task == Task::Anomaly && self.hidden % 2 != 0 {
            return Err("autoencoder bottleneck is H/2; H must be even".into());
        }
        if self.hidden == 0 || self.nl == 0 || self.seq_len == 0 {
            return Err("H, NL, T must be positive".into());
        }
        Ok(())
    }

    /// Total LSTM layers: encoder+decoder for the AE, encoder only for the
    /// classifier.
    pub fn num_lstm_layers(&self) -> usize {
        match self.task {
            Task::Anomaly => 2 * self.nl,
            Task::Classify => self.nl,
        }
    }

    /// Bottleneck width of the autoencoder (`H/2`, Sec. III-C).
    pub fn bottleneck(&self) -> usize {
        self.hidden / 2
    }

    /// `(input_dim, hidden_dim)` per LSTM layer, in order. Mirrors
    /// `ArchConfig.lstm_dims` in `model.py`.
    pub fn lstm_dims(&self) -> Vec<(usize, usize)> {
        let mut dims = Vec::with_capacity(self.num_lstm_layers());
        match self.task {
            Task::Anomaly => {
                let mut prev = self.input_dim;
                for l in 0..self.nl {
                    let h = if l == self.nl - 1 {
                        self.bottleneck()
                    } else {
                        self.hidden
                    };
                    dims.push((prev, h));
                    prev = h;
                }
                for _ in 0..self.nl {
                    dims.push((prev, self.hidden));
                    prev = self.hidden;
                }
            }
            Task::Classify => {
                let mut prev = self.input_dim;
                for _ in 0..self.nl {
                    dims.push((prev, self.hidden));
                    prev = self.hidden;
                }
            }
        }
        dims
    }

    /// `(in, out)` of the final dense layer.
    pub fn dense_dims(&self) -> (usize, usize) {
        match self.task {
            Task::Anomaly => (self.hidden, self.input_dim),
            Task::Classify => (self.hidden, self.num_classes),
        }
    }

    /// Parameter tensor shapes in ABI order: per layer `wx [4,I,H]`,
    /// `wh [4,H,H]`, `b [4,H]`; then `dense.w [F,O]`, `dense.b [O]`.
    pub fn param_shapes(&self) -> Vec<Vec<usize>> {
        let mut shapes = Vec::new();
        for (i, h) in self.lstm_dims() {
            shapes.push(vec![GATES, i, h]);
            shapes.push(vec![GATES, h, h]);
            shapes.push(vec![GATES, h]);
        }
        let (f, o) = self.dense_dims();
        shapes.push(vec![f, o]);
        shapes.push(vec![o]);
        shapes
    }

    pub fn param_names(&self) -> Vec<String> {
        let mut names = Vec::new();
        for l in 0..self.num_lstm_layers() {
            names.push(format!("lstm{l}.wx"));
            names.push(format!("lstm{l}.wh"));
            names.push(format!("lstm{l}.b"));
        }
        names.push("dense.w".into());
        names.push("dense.b".into());
        names
    }

    /// Mask tensor shapes (zx then zh per LSTM layer) for `n` rows.
    pub fn mask_shapes(&self, n: usize) -> Vec<Vec<usize>> {
        let mut shapes = Vec::new();
        for (i, h) in self.lstm_dims() {
            shapes.push(vec![n, GATES, i]);
            shapes.push(vec![n, GATES, h]);
        }
        shapes
    }

    /// Per-pass output length: T reconstruction points for the
    /// autoencoder, K class probabilities for the classifier.
    pub fn out_len(&self) -> usize {
        match self.task {
            Task::Anomaly => self.seq_len,
            Task::Classify => self.num_classes,
        }
    }

    /// The Y/N string form of `B`.
    pub fn bayes_str(&self) -> String {
        self.bayes.iter().map(|&b| if b { 'Y' } else { 'N' }).collect()
    }

    /// Whether any layer is Bayesian (pointwise nets run S=1).
    pub fn is_bayesian(&self) -> bool {
        self.bayes.iter().any(|&b| b)
    }

    /// Total trainable parameter count.
    pub fn num_weights(&self) -> usize {
        self.param_shapes().iter().map(|s| s.iter().product::<usize>()).sum()
    }

    /// Artifact-name stem shared with `model.py::ArchConfig.name`.
    pub fn name(&self) -> String {
        format!(
            "{}_h{}_nl{}_{}",
            self.task.as_str(),
            self.hidden,
            self.nl,
            self.bayes_str()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ae_dims_match_python() {
        let cfg = ArchConfig::new(Task::Anomaly, 16, 2, "YNYN");
        assert_eq!(cfg.lstm_dims(), vec![(1, 16), (16, 8), (8, 16), (16, 16)]);
        assert_eq!(cfg.dense_dims(), (16, 1));
        assert_eq!(cfg.num_lstm_layers(), 4);
        assert_eq!(cfg.name(), "anomaly_h16_nl2_YNYN");
    }

    #[test]
    fn ae_nl1_bottleneck() {
        let cfg = ArchConfig::new(Task::Anomaly, 8, 1, "NN");
        assert_eq!(cfg.lstm_dims(), vec![(1, 4), (4, 8)]);
        assert!(!cfg.is_bayesian());
    }

    #[test]
    fn classifier_dims() {
        let cfg = ArchConfig::new(Task::Classify, 8, 3, "YNY");
        assert_eq!(cfg.lstm_dims(), vec![(1, 8), (8, 8), (8, 8)]);
        assert_eq!(cfg.dense_dims(), (8, 4));
        assert!(cfg.is_bayesian());
    }

    #[test]
    fn param_shapes_abi() {
        let cfg = ArchConfig::new(Task::Classify, 8, 1, "Y");
        assert_eq!(
            cfg.param_shapes(),
            vec![
                vec![4, 1, 8],
                vec![4, 8, 8],
                vec![4, 8],
                vec![8, 4],
                vec![4],
            ]
        );
        assert_eq!(cfg.num_weights(), 4 * 8 + 4 * 64 + 32 + 32 + 4);
    }

    #[test]
    fn mask_shapes_abi() {
        let cfg = ArchConfig::new(Task::Anomaly, 8, 1, "YN");
        assert_eq!(
            cfg.mask_shapes(3),
            vec![vec![3, 4, 1], vec![3, 4, 4], vec![3, 4, 4], vec![3, 4, 8]]
        );
    }

    #[test]
    #[should_panic]
    fn wrong_bayes_len_panics() {
        ArchConfig::new(Task::Classify, 8, 2, "Y");
    }

    #[test]
    #[should_panic]
    fn odd_hidden_ae_panics() {
        ArchConfig::new(Task::Anomaly, 7, 1, "NN");
    }

    #[test]
    fn out_len_per_task() {
        assert_eq!(ArchConfig::new(Task::Anomaly, 8, 1, "NN").out_len(), 140);
        assert_eq!(ArchConfig::new(Task::Classify, 8, 1, "N").out_len(), 4);
    }

    #[test]
    fn task_roundtrip() {
        assert_eq!("anomaly".parse::<Task>().unwrap(), Task::Anomaly);
        assert_eq!("classify".parse::<Task>().unwrap(), Task::Classify);
        assert!("foo".parse::<Task>().is_err());
    }
}
