//! Staged request tracing: per-stage duration histograms plus an
//! optional JSONL trace-event log (`docs/observability.md` §Trace
//! event schema).
//!
//! The serving path stamps each request at
//! `submit → queue → batch-form → dispatch/compute → MC-merge → reply`.
//! Stage *durations* aggregate into [`StageStats`] (mergeable
//! [`LogHistogram`]s, so per-engine stages combine into fleet-wide
//! tails); stage *events* optionally stream to a [`TraceLog`] keyed by
//! the deterministic fleet request id (= the request seed, so a trace
//! can be replayed against the exact same MC sample set).

use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use super::hist::LogHistogram;

/// Per-stage duration histograms for one engine worker (merged across
/// the fleet by [`StageStats::merge`]).
///
/// * `queue` — dispatch to worker pull (channel wait),
/// * `batch` — worker pull to batch formation (batcher residence),
/// * `compute` — wall time of the blocked engine call the item rode in
///   (the modelled hardware latency is tracked separately in
///   `ServeSummary::engine`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StageStats {
    pub queue: LogHistogram,
    pub batch: LogHistogram,
    pub compute: LogHistogram,
}

impl StageStats {
    pub fn merge(&mut self, other: &StageStats) {
        self.queue.merge(&other.queue);
        self.batch.merge(&other.batch);
        self.compute.merge(&other.compute);
    }
}

/// Append-only JSONL trace-event sink, shared by every fleet thread
/// behind a mutex (tracing is opt-in; the serving path never touches
/// the lock when no `TraceLog` is configured).
///
/// One event per line:
/// `{"req":N,"stage":"queue","engine":0,"at_us":T,"us":D}` — `req` the
/// deterministic request id, `engine` omitted for fleet-level stages
/// (`submit` / `merge` / `reply`), `at_us` the log-relative time the
/// event was recorded, `us` the stage duration (0 for point events).
pub struct TraceLog {
    t0: Instant,
    w: Mutex<BufWriter<File>>,
    dropped: AtomicU64,
}

impl TraceLog {
    pub fn create(path: &Path) -> io::Result<Self> {
        Ok(Self {
            t0: Instant::now(),
            w: Mutex::new(BufWriter::new(File::create(path)?)),
            dropped: AtomicU64::new(0),
        })
    }

    /// Record one stage event. Stage names are fixed tokens (no JSON
    /// escaping needed); write failures never take the serving path
    /// down, but they are no longer silent: each failed write bumps
    /// [`TraceLog::dropped`], surfaced in the serve obs JSON and as
    /// `repro_trace_dropped_total` in the metrics export.
    pub fn event(
        &self,
        req: u64,
        stage: &str,
        engine: Option<usize>,
        dur_us: f64,
    ) {
        let engine_field = match engine {
            Some(j) => format!(",\"engine\":{j}"),
            None => String::new(),
        };
        let mut w = self.w.lock().expect("trace writer poisoned");
        // Stamped under the writer lock: file order == `at_us` order,
        // so the log is globally sorted without a post-pass.
        let at_us = self.t0.elapsed().as_micros() as u64;
        if writeln!(
            w,
            "{{\"req\":{req},\"stage\":\"{stage}\"{engine_field},\
             \"at_us\":{at_us},\"us\":{dur_us:.1}}}"
        )
        .is_err()
        {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Events lost to write failures so far (a non-zero value means
    /// the trace file is incomplete — e.g. disk full mid-run).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    pub fn flush(&self) {
        let _ = self.w.lock().expect("trace writer poisoned").flush();
    }
}

impl Drop for TraceLog {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jsonio::{self, Json};

    #[test]
    fn trace_log_writes_parseable_jsonl() {
        let path = std::env::temp_dir().join(format!(
            "repro_trace_test_{}.jsonl",
            std::process::id()
        ));
        {
            let log = TraceLog::create(&path).expect("create trace log");
            log.event(0, "submit", None, 0.0);
            log.event(0, "queue", Some(1), 42.5);
            log.event(0, "reply", None, 1234.0);
            assert_eq!(log.dropped(), 0, "healthy sink drops nothing");
        } // drop flushes
        let text = std::fs::read_to_string(&path).expect("read trace");
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        let mut last_at = 0u64;
        for (i, line) in lines.iter().enumerate() {
            let j = jsonio::parse(line).expect("valid JSON event");
            assert_eq!(j.get("req").and_then(Json::as_usize), Some(0));
            assert!(j.get("stage").and_then(Json::as_str).is_some());
            let at = j.get("at_us").and_then(Json::as_usize).unwrap() as u64;
            assert!(at >= last_at, "event {i}: at_us must be monotonic");
            last_at = at;
        }
        let q = jsonio::parse(lines[1]).unwrap();
        assert_eq!(q.get("engine").and_then(Json::as_usize), Some(1));
        assert_eq!(q.get("us").and_then(Json::as_f64), Some(42.5));
        assert!(jsonio::parse(lines[0]).unwrap().get("engine").is_none());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn stage_stats_merge_accumulates_all_stages() {
        let mut a = StageStats::default();
        a.queue.record_us(10.0);
        a.batch.record_us(20.0);
        a.compute.record_us(30.0);
        let mut b = StageStats::default();
        b.queue.record_us(40.0);
        b.compute.record_us(50.0);
        a.merge(&b);
        assert_eq!(a.queue.count(), 2);
        assert_eq!(a.batch.count(), 1);
        assert_eq!(a.compute.count(), 2);
    }
}
