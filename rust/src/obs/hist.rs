//! Mergeable log2-bucketed latency histograms — the fleet-wide tail
//! primitive (`docs/observability.md` §Histogram bucket scheme).
//!
//! [`crate::coordinator::stats::LatencyStats`] stores every sample and
//! computes exact percentiles; that is right for one bounded serving
//! run but cannot combine across workers without concatenating sample
//! vectors. [`LogHistogram`] trades a bounded relative error for an
//! **exact, associative, commutative merge**: per-engine and per-worker
//! histograms element-wise-sum into one honest fleet-wide distribution,
//! which is what multi-worker tail reporting (and the planned chaos
//! harness) needs.
//!
//! Bucket scheme over microseconds:
//!
//! * bucket 0 — underflow, `[0, 1)` µs (plus non-finite junk),
//! * `OCTAVES × SUB_BUCKETS` buckets — octave `k` covers
//!   `[2^k, 2^(k+1))` µs, split into [`SUB_BUCKETS`] equal linear
//!   sub-buckets, so the bucket containing a value is never wider than
//!   `value / SUB_BUCKETS` (12.5 % relative),
//! * last bucket — overflow, `[2^OCTAVES µs, ∞)` (≈ 17.9 min).
//!
//! Percentiles are nearest-rank over the cumulative bucket counts: the
//! k-th smallest recorded value lies in the bucket where the cumulative
//! count reaches k, so the reported value (the bucket's upper edge,
//! clamped into the observed `[min, max]`) is within one bucket width
//! of the exact nearest-rank sample — property-tested below.

use std::time::Duration;

use crate::jsonio::{self, Json};

/// Linear sub-buckets per octave (8 ⇒ ≤ 12.5 % relative bucket width).
pub const SUB_BUCKETS: usize = 8;
const SUB_BITS: u32 = 3;
/// Octaves covered before overflow: `[1 µs, 2^30 µs ≈ 17.9 min)`.
pub const OCTAVES: usize = 30;
/// Total buckets: underflow + octaves × sub-buckets + overflow.
pub const N_BUCKETS: usize = 2 + OCTAVES * SUB_BUCKETS;

/// Bucket index for a value in microseconds. Exact (no float log):
/// the octave is the IEEE-754 exponent, the sub-bucket the top
/// [`SUB_BITS`] mantissa bits.
fn bucket_index(us: f64) -> usize {
    if !(us >= 1.0) {
        // Underflow, negatives and NaN all land in bucket 0.
        return 0;
    }
    let oct = ((us.to_bits() >> 52) & 0x7ff) as usize - 1023;
    if oct >= OCTAVES {
        return N_BUCKETS - 1;
    }
    let frac = us / (1u64 << oct) as f64; // in [1, 2)
    let sub =
        (((frac - 1.0) * SUB_BUCKETS as f64) as usize).min(SUB_BUCKETS - 1);
    1 + oct * SUB_BUCKETS + sub
}

/// `[lo, hi)` bounds of bucket `i`, in microseconds. The overflow
/// bucket's upper bound is `f64::INFINITY`.
pub fn bucket_bounds_us(i: usize) -> (f64, f64) {
    assert!(i < N_BUCKETS, "bucket {i} out of range");
    if i == 0 {
        return (0.0, 1.0);
    }
    if i == N_BUCKETS - 1 {
        return ((1u64 << OCTAVES) as f64, f64::INFINITY);
    }
    let oct = (i - 1) / SUB_BUCKETS;
    let sub = (i - 1) % SUB_BUCKETS;
    let base = (1u64 << oct) as f64;
    let step = base / SUB_BUCKETS as f64;
    (base + sub as f64 * step, base + (sub + 1) as f64 * step)
}

/// A mergeable latency histogram. Equality is structural and exact —
/// counts are integers and the running sum is kept in integer
/// nanoseconds precisely so that `merge` is associative and
/// commutative bit-for-bit (f64 addition is not associative).
#[derive(Debug, Clone, PartialEq)]
pub struct LogHistogram {
    counts: Vec<u64>,
    count: u64,
    sum_ns: u128,
    min_us: f64,
    max_us: f64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self {
            counts: vec![0; N_BUCKETS],
            count: 0,
            sum_ns: 0,
            min_us: f64::INFINITY,
            max_us: 0.0,
        }
    }

    pub fn record_us(&mut self, us: f64) {
        if !us.is_finite() || us < 0.0 {
            return; // keep count integrity under junk input
        }
        self.counts[bucket_index(us)] += 1;
        self.count += 1;
        self.sum_ns += (us * 1e3).round() as u128;
        self.min_us = self.min_us.min(us);
        self.max_us = self.max_us.max(us);
    }

    pub fn record_ms(&mut self, ms: f64) {
        self.record_us(ms * 1e3);
    }

    pub fn record(&mut self, d: Duration) {
        self.record_us(d.as_secs_f64() * 1e6);
    }

    pub fn count(&self) -> usize {
        self.count as usize
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn mean_ms(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_ns as f64 / self.count as f64 / 1e6
    }

    pub fn min_ms(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.min_us / 1e3
    }

    pub fn max_ms(&self) -> f64 {
        self.max_us / 1e3
    }

    /// Nearest-rank percentile in milliseconds. Returns the upper edge
    /// of the bucket holding the rank-th smallest sample, clamped into
    /// the observed `[min, max]` — so the result is within one bucket
    /// width of the exact nearest-rank value (and exact for singleton
    /// histograms).
    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = (((p / 100.0) * self.count as f64).ceil() as u64)
            .clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                let (_, hi) = bucket_bounds_us(i);
                return hi.min(self.max_us).max(self.min_us) / 1e3;
            }
        }
        self.max_us / 1e3 // unreachable: cum == count >= rank
    }

    /// Recorded samples in buckets entirely at or above `us` — the SLO
    /// evaluator's per-window "over threshold" count. Exact when `us`
    /// is a bucket boundary; otherwise the bucket straddling `us` is
    /// excluded, so the count is conservative (undercounts the bad
    /// side) by at most that bucket's population — a threshold error
    /// bounded by one bucket width (≤ 12.5 % relative).
    pub fn count_over_us(&self, us: f64) -> u64 {
        let mut n = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            if c > 0 && bucket_bounds_us(i).0 >= us {
                n += c;
            }
        }
        n
    }

    pub fn count_over_ms(&self, ms: f64) -> u64 {
        self.count_over_us(ms * 1e3)
    }

    /// The `{count, mean, p50, p99, max}` millisecond summary every
    /// nested JSON export uses for a stage histogram.
    pub fn summary_json(&self) -> Json {
        jsonio::obj(vec![
            ("count", Json::Num(self.count() as f64)),
            ("mean", Json::Num(self.mean_ms())),
            ("p50", Json::Num(self.percentile_ms(50.0))),
            ("p99", Json::Num(self.percentile_ms(99.0))),
            ("max", Json::Num(self.max_ms())),
        ])
    }

    /// Width (ms) of the bucket containing `value_ms` — the percentile
    /// error bound at that value. Infinite in the overflow bucket.
    pub fn bucket_width_ms(value_ms: f64) -> f64 {
        let (lo, hi) = bucket_bounds_us(bucket_index(value_ms * 1e3));
        (hi - lo) / 1e3
    }

    /// Exact element-wise merge: associative and commutative (counts
    /// and the nanosecond sum are integers; min/max are order-free).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.min_us = self.min_us.min(other.min_us);
        self.max_us = self.max_us.max(other.max_us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic xorshift ⇒ no rand dependency; spans several
    /// orders of magnitude so many octaves are exercised.
    fn samples(seed: u64, n: usize) -> Vec<f64> {
        let mut state = seed.max(1);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            let u = (state >> 11) as f64 / (1u64 << 53) as f64; // [0, 1)
            // log-uniform over [0.5 µs, ~1.2e6 µs]
            out.push(0.5 * (2.0f64).powf(u * 21.0));
        }
        out
    }

    fn hist_of(vals: &[f64]) -> LogHistogram {
        let mut h = LogHistogram::new();
        for &v in vals {
            h.record_us(v);
        }
        h
    }

    fn exact_nearest_rank_us(sorted: &[f64], p: f64) -> f64 {
        let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
        sorted[rank.clamp(1, sorted.len()) - 1]
    }

    #[test]
    fn bucket_bounds_partition_the_axis() {
        // Buckets tile [0, 2^OCTAVES) without gaps or overlap, and
        // every value indexes into the bucket whose bounds contain it.
        let mut expect_lo = 0.0;
        for i in 0..N_BUCKETS - 1 {
            let (lo, hi) = bucket_bounds_us(i);
            assert_eq!(lo, expect_lo, "bucket {i} starts at a gap/overlap");
            assert!(hi > lo);
            expect_lo = hi;
        }
        assert_eq!(expect_lo, (1u64 << OCTAVES) as f64);
        for &us in &samples(7, 4000) {
            let i = bucket_index(us);
            let (lo, hi) = bucket_bounds_us(i);
            assert!(lo <= us && us < hi, "{us} outside bucket {i} [{lo},{hi})");
        }
        // Edges land in the bucket they open.
        for us in [1.0, 2.0, 1024.0, 1.5, 3.25] {
            let (lo, _) = bucket_bounds_us(bucket_index(us));
            assert!(lo <= us);
        }
        assert_eq!(bucket_index(0.0), 0);
        assert_eq!(bucket_index(f64::NAN), 0);
        assert_eq!(bucket_index(1e12), N_BUCKETS - 1);
    }

    #[test]
    fn merge_is_commutative_and_associative_exactly() {
        let (a0, b0, c0) =
            (samples(1, 500), samples(2, 700), samples(3, 300));
        let (a, b, c) = (hist_of(&a0), hist_of(&b0), hist_of(&c0));

        // a ⊕ b == b ⊕ a (structural equality: counts, sum, min, max).
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba, "merge must commute");

        // (a ⊕ b) ⊕ c == a ⊕ (b ⊕ c).
        let mut ab_c = ab.clone();
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab_c, a_bc, "merge must associate");

        // And both equal the histogram of the pooled samples.
        let mut pooled = a0.clone();
        pooled.extend(&b0);
        pooled.extend(&c0);
        assert_eq!(ab_c, hist_of(&pooled));
    }

    /// The acceptance bound: percentiles of per-engine histograms
    /// merged into one must match the exact nearest-rank value of the
    /// pooled samples within one bucket width.
    #[test]
    fn merged_percentiles_match_exact_within_one_bucket() {
        let shards =
            [samples(11, 400), samples(12, 650), samples(13, 123)];
        let mut merged = LogHistogram::new();
        let mut pooled = Vec::new();
        for sh in &shards {
            merged.merge(&hist_of(sh));
            pooled.extend(sh);
        }
        pooled.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [1.0, 10.0, 25.0, 50.0, 75.0, 90.0, 99.0, 99.9, 100.0] {
            let exact_ms = exact_nearest_rank_us(&pooled, p) / 1e3;
            let est_ms = merged.percentile_ms(p);
            let bound = LogHistogram::bucket_width_ms(exact_ms);
            assert!(
                (est_ms - exact_ms).abs() <= bound + 1e-12,
                "p{p}: |{est_ms} - {exact_ms}| > bucket width {bound}"
            );
        }
    }

    #[test]
    fn empty_and_singleton_edge_cases() {
        let empty = LogHistogram::new();
        assert!(empty.is_empty());
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(empty.percentile_ms(p), 0.0);
        }
        assert_eq!(empty.mean_ms(), 0.0);
        assert_eq!(empty.min_ms(), 0.0);
        assert_eq!(empty.max_ms(), 0.0);

        // Merging empty is the identity.
        let a = hist_of(&samples(5, 100));
        let mut a2 = a.clone();
        a2.merge(&empty);
        assert_eq!(a2, a);
        let mut e2 = LogHistogram::new();
        e2.merge(&a);
        assert_eq!(e2, a);

        // One sample: every percentile is that sample, exactly (the
        // min/max clamp collapses the bucket).
        let mut one = LogHistogram::new();
        one.record_ms(7.25);
        assert_eq!(one.count(), 1);
        for p in [0.0, 1.0, 50.0, 99.0, 100.0] {
            assert!((one.percentile_ms(p) - 7.25).abs() < 1e-12, "p={p}");
        }
        assert!((one.mean_ms() - 7.25).abs() < 1e-9);
        assert!((one.min_ms() - 7.25).abs() < 1e-12);
        assert!((one.max_ms() - 7.25).abs() < 1e-12);
    }

    #[test]
    fn junk_input_is_dropped_not_counted() {
        let mut h = LogHistogram::new();
        h.record_us(f64::NAN);
        h.record_us(f64::INFINITY);
        h.record_us(-3.0);
        assert!(h.is_empty());
        h.record_us(5.0);
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn count_over_is_exact_at_bucket_boundaries() {
        let mut h = LogHistogram::new();
        // Octave boundaries are bucket boundaries: 1024 µs opens a
        // bucket, so a threshold there splits the population exactly.
        for us in [10.0, 100.0, 1000.0, 1024.0, 2048.0, 1e6] {
            h.record_us(us);
        }
        assert_eq!(h.count_over_us(1024.0), 3);
        assert_eq!(h.count_over_us(0.0), 6);
        assert_eq!(h.count_over_us(1e9), 0);
        assert_eq!(h.count_over_ms(1.024), 3);
        // Conservative in between: never counts a bucket the
        // threshold cuts through.
        let exact = 4; // samples > 500 µs
        assert!(h.count_over_us(500.0) <= exact);
        // Merge preserves the count.
        let mut m = LogHistogram::new();
        m.merge(&h);
        m.merge(&h);
        assert_eq!(m.count_over_us(1024.0), 6);
    }

    #[test]
    fn summary_json_reports_the_stage_shape() {
        let mut h = LogHistogram::new();
        h.record_ms(2.0);
        h.record_ms(8.0);
        let j = h.summary_json();
        assert_eq!(j.get("count").and_then(|v| v.as_usize()), Some(2));
        assert!((j.get("mean").unwrap().as_f64().unwrap() - 5.0).abs() < 0.1);
        assert!((j.get("max").unwrap().as_f64().unwrap() - 8.0).abs() < 1e-9);
        assert!(j.get("p50").is_some() && j.get("p99").is_some());
    }

    #[test]
    fn duration_and_ms_entry_points_agree() {
        let mut a = LogHistogram::new();
        a.record(Duration::from_micros(1500));
        let mut b = LogHistogram::new();
        b.record_ms(1.5);
        assert_eq!(a.counts, b.counts);
        assert!((a.percentile_ms(50.0) - b.percentile_ms(50.0)).abs() < 1e-12);
    }
}
