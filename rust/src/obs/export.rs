//! Metrics export: a stable metrics JSON plus a Prometheus-style text
//! exposition, built from a [`FleetSummary`] after `Fleet::join`
//! (`repro serve --metrics <path>`; metric names and schema in
//! `docs/observability.md`).

use crate::coordinator::fleet::FleetSummary;
use crate::jsonio::{self, Json};

use super::hist::LogHistogram;
use super::procstat::{self, ProcStat};
use super::slo::SloReport;
use super::timeseries::Timeline;

/// Every metric name `serve_metric_set` emits — the single source of
/// truth shared by the unit test below, the docs table and the CI
/// metrics-smoke validation.
pub const SERVE_METRIC_NAMES: &[&str] = &[
    "repro_requests_served_total",
    "repro_requests_rejected_total",
    "repro_wall_seconds",
    "repro_throughput_rps",
    "repro_e2e_latency_ms",
    "repro_stage_latency_ms",
    "repro_engine_items_total",
    "repro_engine_batches_total",
    "repro_engine_peak_batch",
    "repro_engine_queue_highwater",
    "repro_engine_sheds_total",
    "repro_engine_mc_rows_total",
    "repro_engine_kernel_info",
    "repro_mc_samples_spent_total",
    "repro_mc_samples_saved_total",
    "repro_router_placements_total",
    "repro_trace_dropped_total",
    "repro_mask_bank_hits_total",
    "repro_mask_bank_misses_total",
    "repro_mask_bank_evictions_total",
    "repro_mask_bank_resident_bytes",
    "repro_sessions_opened_total",
    "repro_sessions_resident",
    "repro_session_state_resident_bytes",
    "repro_session_evictions_total",
    "repro_session_replay_rebuilds_total",
    "repro_session_chunks_total",
    "repro_session_boosted_chunks_total",
    "repro_fault_workers_lost_total",
    "repro_fault_shards_redispatched_total",
    "repro_fault_hedges_fired_total",
    "repro_fault_hedges_won_total",
    "repro_fault_sessions_repinned_total",
    "repro_fault_replies_dropped_total",
];

/// Metric names `push_timeline_metrics` emits (windowed runs only).
/// Per-window samples carry a `window` label.
pub const TIMELINE_METRIC_NAMES: &[&str] = &[
    "repro_timeline_window_seconds",
    "repro_timeline_windows",
    "repro_timeline_offered_total",
    "repro_timeline_served_total",
    "repro_timeline_rejected_total",
    "repro_timeline_e2e_p99_ms",
    "repro_timeline_throughput_rps",
    "repro_timeline_rss_bytes",
    "repro_timeline_cpu_util",
    "repro_timeline_inflight",
];

/// Metric names `push_slo_metrics` emits (runs evaluated against an
/// SLO only). `repro_slo_burn_rate` carries a `window` label.
pub const SLO_METRIC_NAMES: &[&str] = &[
    "repro_slo_pass",
    "repro_slo_attainment",
    "repro_slo_target",
    "repro_slo_latency_threshold_ms",
    "repro_slo_shed_rate",
    "repro_slo_worst_burn_rate",
    "repro_slo_violating_windows",
    "repro_slo_burn_rate",
];

/// One exported metric sample.
pub struct Metric {
    pub name: &'static str,
    /// `"counter"` or `"gauge"` (Prometheus TYPE line).
    pub kind: &'static str,
    pub help: &'static str,
    pub labels: Vec<(&'static str, String)>,
    pub value: f64,
}

/// An ordered metric collection with the two stable renderings.
#[derive(Default)]
pub struct MetricSet {
    metrics: Vec<Metric>,
}

impl MetricSet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(
        &mut self,
        name: &'static str,
        help: &'static str,
        labels: Vec<(&'static str, String)>,
        value: f64,
    ) {
        self.metrics.push(Metric { name, kind: "counter", help, labels, value });
    }

    pub fn gauge(
        &mut self,
        name: &'static str,
        help: &'static str,
        labels: Vec<(&'static str, String)>,
        value: f64,
    ) {
        self.metrics.push(Metric { name, kind: "gauge", help, labels, value });
    }

    pub fn metrics(&self) -> &[Metric] {
        &self.metrics
    }

    /// Stable JSON: one key per metric name, each an array of
    /// `{"labels": {...}, "value": v}` samples in emission order.
    pub fn to_json(&self) -> Json {
        let mut names: Vec<&'static str> = Vec::new();
        for m in &self.metrics {
            if !names.contains(&m.name) {
                names.push(m.name);
            }
        }
        let mut top = Vec::new();
        for name in names {
            let samples: Vec<Json> = self
                .metrics
                .iter()
                .filter(|m| m.name == name)
                .map(|m| {
                    let labels = m
                        .labels
                        .iter()
                        .map(|(k, v)| (*k, Json::Str(v.clone())))
                        .collect();
                    jsonio::obj(vec![
                        ("labels", jsonio::obj(labels)),
                        ("value", Json::Num(m.value)),
                    ])
                })
                .collect();
            top.push((name, Json::Arr(samples)));
        }
        jsonio::obj(top)
    }

    /// Prometheus text exposition: `# HELP` / `# TYPE` once per name,
    /// then one `name{labels} value` line per sample.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let mut seen: Vec<&'static str> = Vec::new();
        for m in &self.metrics {
            if !seen.contains(&m.name) {
                seen.push(m.name);
                out.push_str(&format!("# HELP {} {}\n", m.name, m.help));
                out.push_str(&format!("# TYPE {} {}\n", m.name, m.kind));
                for s in self.metrics.iter().filter(|s| s.name == m.name) {
                    if s.labels.is_empty() {
                        out.push_str(&format!("{} {}\n", s.name, s.value));
                    } else {
                        let labels: Vec<String> = s
                            .labels
                            .iter()
                            .map(|(k, v)| format!("{k}=\"{v}\""))
                            .collect();
                        out.push_str(&format!(
                            "{}{{{}}} {}\n",
                            s.name,
                            labels.join(","),
                            s.value
                        ));
                    }
                }
            }
        }
        out
    }
}

/// Quantile gauges for one histogram under a shared label prefix.
fn quantile_gauges(
    set: &mut MetricSet,
    name: &'static str,
    help: &'static str,
    base: &[(&'static str, String)],
    h: &LogHistogram,
) {
    let points: [(&str, f64); 4] = [
        ("p50", h.percentile_ms(50.0)),
        ("p99", h.percentile_ms(99.0)),
        ("max", h.max_ms()),
        ("mean", h.mean_ms()),
    ];
    for (q, v) in points {
        let mut labels = base.to_vec();
        labels.push(("quantile", q.to_string()));
        set.gauge(name, help, labels, v);
    }
}

/// Build the full serve metric set from a joined fleet summary.
pub fn serve_metric_set(
    summary: &FleetSummary,
    wall_s: f64,
    throughput: f64,
) -> MetricSet {
    let mut set = MetricSet::new();
    set.counter(
        "repro_requests_served_total",
        "Requests fully served (all shards reduced)",
        vec![],
        summary.served as f64,
    );
    set.counter(
        "repro_requests_rejected_total",
        "Requests rejected by admission control",
        vec![],
        summary.rejected as f64,
    );
    set.gauge(
        "repro_wall_seconds",
        "Serving wall-clock window",
        vec![],
        wall_s,
    );
    set.gauge(
        "repro_throughput_rps",
        "Served requests per second",
        vec![],
        throughput,
    );
    quantile_gauges(
        &mut set,
        "repro_e2e_latency_ms",
        "Request end-to-end latency (log-bucketed histogram)",
        &[],
        &summary.obs.e2e,
    );
    let stages = summary.stage_stats();
    let stage_hists: [(&str, &LogHistogram); 4] = [
        ("queue", &stages.queue),
        ("batch", &stages.batch),
        ("compute", &stages.compute),
        ("merge", &summary.obs.merge),
    ];
    for (stage, h) in stage_hists {
        quantile_gauges(
            &mut set,
            "repro_stage_latency_ms",
            "Per-stage latency, merged across engines",
            &[("stage", stage.to_string())],
            h,
        );
    }
    for (j, e) in summary.per_engine.iter().enumerate() {
        let eng = vec![("engine", j.to_string())];
        set.counter(
            "repro_engine_items_total",
            "Work items (shards) completed",
            eng.clone(),
            e.served as f64,
        );
        set.counter(
            "repro_engine_batches_total",
            "Batches formed",
            eng.clone(),
            e.batches as f64,
        );
        set.gauge(
            "repro_engine_peak_batch",
            "Largest batch formed (occupancy high-water)",
            eng.clone(),
            e.peak_batch as f64,
        );
        set.gauge(
            "repro_engine_queue_highwater",
            "Deepest the engine queue ever got",
            eng.clone(),
            e.queue_highwater as f64,
        );
        set.counter(
            "repro_engine_sheds_total",
            "Work items rejected at this engine's queue",
            eng.clone(),
            e.sheds as f64,
        );
        set.counter(
            "repro_engine_mc_rows_total",
            "MC sample rows computed",
            eng.clone(),
            e.mc_rows as f64,
        );
        let mut info = eng.clone();
        info.push(("kernel", e.kernel.clone()));
        set.gauge(
            "repro_engine_kernel_info",
            "Engine backend/kernel label (value is always 1)",
            info,
            1.0,
        );
    }
    set.counter(
        "repro_mc_samples_spent_total",
        "MC samples drawn across all served requests",
        vec![],
        summary.obs.mc_spent as f64,
    );
    set.counter(
        "repro_mc_samples_saved_total",
        "MC samples avoided by adaptive early exit (vs s_max)",
        vec![],
        summary.obs.mc_saved as f64,
    );
    for (j, &n) in summary.obs.placements.iter().enumerate() {
        set.counter(
            "repro_router_placements_total",
            "Submit-path placement decisions per engine",
            vec![("engine", j.to_string())],
            n as f64,
        );
    }
    set.counter(
        "repro_trace_dropped_total",
        "Trace events lost to write failures (trace file incomplete if > 0)",
        vec![],
        summary.obs.trace_dropped as f64,
    );
    // Always emitted for a stable scrape surface; all-zero when the
    // bank is disabled (`--mask-bank-mb 0`, the default).
    let bank = summary.obs.mask_bank.unwrap_or_default();
    set.counter(
        "repro_mask_bank_hits_total",
        "Mask rows served from the seed-indexed bank",
        vec![],
        bank.hits as f64,
    );
    set.counter(
        "repro_mask_bank_misses_total",
        "Mask rows generated by the LFSR samplers (bank miss)",
        vec![],
        bank.misses as f64,
    );
    set.counter(
        "repro_mask_bank_evictions_total",
        "Bank entries evicted by the CLOCK sweep",
        vec![],
        bank.evictions as f64,
    );
    set.gauge(
        "repro_mask_bank_resident_bytes",
        "Bytes of bitplane rows resident in the bank",
        vec![],
        bank.resident_bytes as f64,
    );
    // Streaming-session plane; all-zero when disabled (no
    // `--session-mb`), same stable-surface convention as the bank.
    let sess = summary.obs.sessions.unwrap_or_default();
    set.counter(
        "repro_sessions_opened_total",
        "Streaming sessions opened",
        vec![],
        sess.opened as f64,
    );
    set.gauge(
        "repro_sessions_resident",
        "Streaming sessions currently in the session table",
        vec![],
        sess.resident as f64,
    );
    set.gauge(
        "repro_session_state_resident_bytes",
        "Bytes of MC lane state resident across all sessions",
        vec![],
        sess.resident_bytes as f64,
    );
    set.counter(
        "repro_session_evictions_total",
        "Session lane states evicted by the byte-budget CLOCK sweep",
        vec![],
        sess.evictions as f64,
    );
    set.counter(
        "repro_session_replay_rebuilds_total",
        "Evicted lane states rebuilt by history replay",
        vec![],
        sess.replay_rebuilds as f64,
    );
    set.counter(
        "repro_session_chunks_total",
        "Streaming chunks admitted across all sessions",
        vec![],
        sess.chunks as f64,
    );
    set.counter(
        "repro_session_boosted_chunks_total",
        "Chunks escalated to the boosted MC budget by the adaptive tier",
        vec![],
        sess.boosted_chunks as f64,
    );
    // Fault-tolerance plane; always emitted, all-zero on a clean run
    // (the dashboard alert surface must exist before the first fault).
    let faults = summary.obs.faults;
    set.counter(
        "repro_fault_workers_lost_total",
        "Engine workers lost to panics (chaos-injected or genuine)",
        vec![],
        faults.workers_lost as f64,
    );
    set.counter(
        "repro_fault_shards_redispatched_total",
        "Shards re-dispatched from a dead engine to a survivor",
        vec![],
        faults.shards_redispatched as f64,
    );
    set.counter(
        "repro_fault_hedges_fired_total",
        "Speculative re-executions of overdue shards",
        vec![],
        faults.hedges_fired as f64,
    );
    set.counter(
        "repro_fault_hedges_won_total",
        "Hedged shards whose hedge replied before the original",
        vec![],
        faults.hedges_won as f64,
    );
    set.counter(
        "repro_fault_sessions_repinned_total",
        "Streaming sessions moved off a dead pinned engine",
        vec![],
        faults.sessions_repinned as f64,
    );
    set.counter(
        "repro_fault_replies_dropped_total",
        "Shard replies dropped by the chaos harness",
        vec![],
        faults.replies_dropped as f64,
    );
    if let Some(p) = procstat::sample() {
        set.gauge(
            "repro_proc_rss_bytes",
            "Resident set size",
            vec![],
            p.rss_bytes as f64,
        );
        set.counter(
            "repro_proc_cpu_seconds_total",
            "Cumulative user+system CPU time",
            vec![],
            p.cpu_seconds,
        );
    }
    set
}

/// Per-window timeline metrics. Whole-window counters/gauges are
/// labelled with the window index so a scrape carries the full series.
pub fn push_timeline_metrics(set: &mut MetricSet, tl: &Timeline) {
    set.gauge(
        "repro_timeline_window_seconds",
        "Timeline window width",
        vec![],
        tl.width.as_secs_f64(),
    );
    let n = tl.windows();
    set.gauge(
        "repro_timeline_windows",
        "Windows spanned by the run",
        vec![],
        n as f64,
    );
    let width_s = tl.width.as_secs_f64().max(1e-9);
    for w in 0..n {
        let lbl = vec![("window", w.to_string())];
        set.counter(
            "repro_timeline_offered_total",
            "Requests the open-loop schedule offered in the window",
            lbl.clone(),
            tl.offered.get(w) as f64,
        );
        let served = tl.served.get(w);
        set.counter(
            "repro_timeline_served_total",
            "Requests completed in the window",
            lbl.clone(),
            served as f64,
        );
        set.counter(
            "repro_timeline_rejected_total",
            "Requests shed by admission control in the window",
            lbl.clone(),
            tl.rejected.get(w) as f64,
        );
        set.gauge(
            "repro_timeline_e2e_p99_ms",
            "Window p99 end-to-end latency",
            lbl.clone(),
            tl.e2e.window(w).map(|h| h.percentile_ms(99.0)).unwrap_or(0.0),
        );
        set.gauge(
            "repro_timeline_throughput_rps",
            "Achieved throughput in the window",
            lbl.clone(),
            served as f64 / width_s,
        );
        if let Some(s) = tl.sample_at(w) {
            set.gauge(
                "repro_timeline_rss_bytes",
                "Resident set size sampled in the window",
                lbl.clone(),
                s.rss_bytes as f64,
            );
            set.gauge(
                "repro_timeline_cpu_util",
                "CPU cores busy during the window (delta-based)",
                lbl.clone(),
                s.cpu_delta_s / width_s,
            );
            set.gauge(
                "repro_timeline_inflight",
                "Peak in-flight work items sampled in the window",
                lbl,
                s.max_in_flight as f64,
            );
        }
    }
}

/// SLO verdict metrics: overall pass/attainment plus the per-window
/// burn-rate series the verdict was computed from.
pub fn push_slo_metrics(set: &mut MetricSet, r: &SloReport) {
    set.gauge(
        "repro_slo_pass",
        "1 if the run met the SLO, else 0",
        vec![],
        if r.pass { 1.0 } else { 0.0 },
    );
    set.gauge(
        "repro_slo_attainment",
        "Fraction of served requests within the latency threshold",
        vec![],
        r.attained,
    );
    set.gauge(
        "repro_slo_target",
        "Attainment fraction the SLO demands",
        vec![],
        r.spec.target,
    );
    set.gauge(
        "repro_slo_latency_threshold_ms",
        "SLO latency threshold",
        vec![],
        r.spec.latency_ms,
    );
    set.gauge(
        "repro_slo_shed_rate",
        "Fraction of offered requests shed by admission control",
        vec![],
        r.shed_rate,
    );
    set.gauge(
        "repro_slo_worst_burn_rate",
        "Worst windowed burn rate (>1 burns error budget)",
        vec![],
        r.worst_burn,
    );
    set.gauge(
        "repro_slo_violating_windows",
        "Windows whose burn rate exceeded 1",
        vec![],
        r.violating_windows as f64,
    );
    for w in &r.windows {
        set.gauge(
            "repro_slo_burn_rate",
            "Windowed error-budget burn rate",
            vec![("window", w.window.to_string())],
            w.burn,
        );
    }
}

/// Histogram summary object for the nested serve JSON.
fn hist_json(h: &LogHistogram) -> Json {
    h.summary_json()
}

/// The nested `"obs"` object added to the `repro serve --json` line
/// when observability is enabled: fleet-wide stage percentiles, a
/// per-engine breakdown (stages + health counters), MC sample
/// accounting, router placements and a process snapshot. `proc0` is an
/// optional snapshot from run start — with it, the proc block also
/// reports the CPU actually burned *during* the run
/// (`cpu_delta_seconds`) rather than only the process-lifetime total.
pub fn serve_obs_json(
    summary: &FleetSummary,
    proc0: Option<ProcStat>,
) -> Json {
    let stages = summary.stage_stats();
    let engines: Vec<Json> = summary
        .per_engine
        .iter()
        .enumerate()
        .map(|(j, e)| {
            let mut fields = vec![
                ("engine", Json::Num(j as f64)),
                ("kernel", Json::Str(e.kernel.clone())),
                ("items", Json::Num(e.served as f64)),
                ("batches", Json::Num(e.batches as f64)),
                ("mean_batch", Json::Num(e.mean_batch)),
                ("peak_batch", Json::Num(e.peak_batch as f64)),
                ("queue_highwater", Json::Num(e.queue_highwater as f64)),
                ("sheds", Json::Num(e.sheds as f64)),
                ("mc_rows", Json::Num(e.mc_rows as f64)),
            ];
            if let Some(st) = &e.stages {
                fields.push(("queue_ms", hist_json(&st.queue)));
                fields.push(("batch_ms", hist_json(&st.batch)));
                fields.push(("compute_ms", hist_json(&st.compute)));
            }
            jsonio::obj(fields)
        })
        .collect();
    let proc = match procstat::sample() {
        Some(p) => {
            let mut fields = vec![
                ("rss_bytes", Json::Num(p.rss_bytes as f64)),
                ("cpu_seconds", Json::Num(p.cpu_seconds)),
            ];
            if let Some(p0) = proc0 {
                fields.push((
                    "cpu_delta_seconds",
                    Json::Num(p.cpu_delta_since(&p0)),
                ));
            }
            jsonio::obj(fields)
        }
        None => Json::Null,
    };
    let mut top = vec![
        (
            "stages",
            jsonio::obj(vec![
                ("queue", hist_json(&stages.queue)),
                ("batch", hist_json(&stages.batch)),
                ("compute", hist_json(&stages.compute)),
                ("merge", hist_json(&summary.obs.merge)),
                ("e2e", hist_json(&summary.obs.e2e)),
            ]),
        ),
        ("engines", Json::Arr(engines)),
        (
            "mc_samples",
            jsonio::obj(vec![
                ("spent", Json::Num(summary.obs.mc_spent as f64)),
                ("saved", Json::Num(summary.obs.mc_saved as f64)),
            ]),
        ),
        (
            "placements",
            Json::Arr(
                summary
                    .obs
                    .placements
                    .iter()
                    .map(|&n| Json::Num(n as f64))
                    .collect(),
            ),
        ),
        (
            "trace_dropped",
            Json::Num(summary.obs.trace_dropped as f64),
        ),
    ];
    // Only present when a bank was attached — the disabled serve line
    // stays byte-identical to builds without the feature.
    if let Some(b) = summary.obs.mask_bank {
        top.push((
            "mask_bank",
            jsonio::obj(vec![
                ("hits", Json::Num(b.hits as f64)),
                ("misses", Json::Num(b.misses as f64)),
                ("evictions", Json::Num(b.evictions as f64)),
                ("resident_bytes", Json::Num(b.resident_bytes as f64)),
                ("capacity_bytes", Json::Num(b.capacity_bytes as f64)),
            ]),
        ));
    }
    // Faults block only when something actually went wrong: a clean
    // run's obs JSON stays byte-identical to pre-fault-tolerance
    // builds.
    if summary.obs.faults.any() {
        let ft = summary.obs.faults;
        top.push((
            "faults",
            jsonio::obj(vec![
                ("workers_lost", Json::Num(ft.workers_lost as f64)),
                (
                    "shards_redispatched",
                    Json::Num(ft.shards_redispatched as f64),
                ),
                ("hedges_fired", Json::Num(ft.hedges_fired as f64)),
                ("hedges_won", Json::Num(ft.hedges_won as f64)),
                (
                    "sessions_repinned",
                    Json::Num(ft.sessions_repinned as f64),
                ),
                (
                    "replies_dropped",
                    Json::Num(ft.replies_dropped as f64),
                ),
            ]),
        ));
    }
    // Same convention for the streaming-session plane (`--session-mb`).
    if let Some(s) = summary.obs.sessions {
        top.push((
            "sessions",
            jsonio::obj(vec![
                ("opened", Json::Num(s.opened as f64)),
                ("closed", Json::Num(s.closed as f64)),
                ("resident", Json::Num(s.resident as f64)),
                ("resident_bytes", Json::Num(s.resident_bytes as f64)),
                ("capacity_bytes", Json::Num(s.capacity_bytes as f64)),
                ("evictions", Json::Num(s.evictions as f64)),
                (
                    "replay_rebuilds",
                    Json::Num(s.replay_rebuilds as f64),
                ),
                ("chunks", Json::Num(s.chunks as f64)),
                ("boosted_chunks", Json::Num(s.boosted_chunks as f64)),
            ]),
        ));
    }
    top.push(("proc", proc));
    jsonio::obj(top)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::fleet::FleetObs;
    use crate::coordinator::server::ServeSummary;
    use crate::coordinator::stats::LatencyStats;
    use crate::obs::trace::StageStats;
    use std::time::Duration;

    fn fake_summary() -> FleetSummary {
        let mut stages = StageStats::default();
        stages.queue.record_ms(0.5);
        stages.batch.record_ms(0.1);
        stages.compute.record_ms(2.0);
        let engine = ServeSummary {
            served: 4,
            wall: Duration::from_millis(10),
            e2e: LatencyStats::new(),
            engine: LatencyStats::new(),
            batches: 2,
            mean_batch: 2.0,
            rejected: 0,
            stages: Some(stages),
            mc_rows: 24,
            kernel: "fpga:blocked".to_string(),
            queue_highwater: 3,
            sheds: 1,
            peak_batch: 2,
            timeline: None,
        };
        let mut obs = FleetObs { enabled: true, ..FleetObs::default() };
        obs.e2e.record_ms(3.0);
        obs.merge.record_ms(0.05);
        obs.mc_spent = 24;
        obs.mc_saved = 8;
        obs.placements = vec![4];
        obs.trace_dropped = 2;
        obs.mask_bank = Some(crate::kernels::MaskBankStats {
            hits: 40,
            misses: 8,
            evictions: 1,
            resident_bytes: 4096,
            capacity_bytes: 1 << 20,
        });
        obs.sessions = Some(crate::coordinator::SessionStats {
            opened: 3,
            closed: 2,
            resident: 1,
            resident_bytes: 2048,
            capacity_bytes: 1 << 21,
            evictions: 5,
            replay_rebuilds: 4,
            chunks: 12,
            boosted_chunks: 2,
        });
        FleetSummary {
            served: 4,
            rejected: 1,
            wall: Duration::from_millis(10),
            e2e: LatencyStats::new(),
            per_engine: vec![engine],
            obs,
            timeline: None,
        }
    }

    #[test]
    fn serve_metric_set_covers_every_documented_name() {
        let set = serve_metric_set(&fake_summary(), 0.01, 400.0);
        for name in SERVE_METRIC_NAMES {
            assert!(
                set.metrics().iter().any(|m| m.name == *name),
                "metric {name} missing from serve_metric_set"
            );
        }
        // proc metrics are Linux-only extras, not in the required list.
        let json = jsonio::write(&set.to_json());
        let parsed = jsonio::parse(&json).expect("metrics JSON parses");
        for name in SERVE_METRIC_NAMES {
            assert!(parsed.get(name).is_some(), "JSON missing {name}");
        }
    }

    #[test]
    fn prometheus_text_has_help_type_and_labelled_samples() {
        let set = serve_metric_set(&fake_summary(), 0.01, 400.0);
        let text = set.to_prometheus();
        for name in SERVE_METRIC_NAMES {
            assert_eq!(
                text.matches(&format!("# HELP {name} ")).count(),
                1,
                "{name}: exactly one HELP line"
            );
            assert_eq!(
                text.matches(&format!("# TYPE {name} ")).count(),
                1,
                "{name}: exactly one TYPE line"
            );
        }
        assert!(text.contains("repro_requests_served_total 4\n"));
        assert!(text
            .contains("repro_stage_latency_ms{stage=\"queue\",quantile=\"p50\"}"));
        assert!(text.contains(
            "repro_engine_kernel_info{engine=\"0\",kernel=\"fpga:blocked\"} 1\n"
        ));
        assert!(
            text.contains("repro_trace_dropped_total 2\n"),
            "dropped-event counter must surface in the exposition"
        );
        assert!(text.contains("repro_mask_bank_hits_total 40\n"));
        assert!(text.contains("repro_mask_bank_resident_bytes 4096\n"));
        assert!(text.contains("repro_sessions_opened_total 3\n"));
        assert!(text.contains("repro_session_replay_rebuilds_total 4\n"));
        assert!(text.contains("repro_session_boosted_chunks_total 2\n"));
    }

    /// With no bank attached the four metrics still exist (stable
    /// scrape surface) but read zero.
    #[test]
    fn mask_bank_metrics_are_zero_without_a_bank() {
        let mut summary = fake_summary();
        summary.obs.mask_bank = None;
        let set = serve_metric_set(&summary, 0.01, 400.0);
        for name in [
            "repro_mask_bank_hits_total",
            "repro_mask_bank_misses_total",
            "repro_mask_bank_evictions_total",
            "repro_mask_bank_resident_bytes",
        ] {
            let m = set
                .metrics()
                .iter()
                .find(|m| m.name == name)
                .unwrap_or_else(|| panic!("{name} missing"));
            assert_eq!(m.value, 0.0, "{name} must read 0 when disabled");
        }
        // And the obs JSON omits the block entirely.
        let line = jsonio::write(&serve_obs_json(&summary, None));
        assert!(!line.contains("mask_bank"));
    }

    /// Same stable-surface contract for the session plane: without
    /// `--session-mb` the seven metrics exist but read zero, and the
    /// obs JSON has no `sessions` block.
    #[test]
    fn session_metrics_are_zero_without_the_plane() {
        let mut summary = fake_summary();
        summary.obs.sessions = None;
        let set = serve_metric_set(&summary, 0.01, 400.0);
        for name in [
            "repro_sessions_opened_total",
            "repro_sessions_resident",
            "repro_session_state_resident_bytes",
            "repro_session_evictions_total",
            "repro_session_replay_rebuilds_total",
            "repro_session_chunks_total",
            "repro_session_boosted_chunks_total",
        ] {
            let m = set
                .metrics()
                .iter()
                .find(|m| m.name == name)
                .unwrap_or_else(|| panic!("{name} missing"));
            assert_eq!(m.value, 0.0, "{name} must read 0 when disabled");
        }
        let line = jsonio::write(&serve_obs_json(&summary, None));
        assert!(!line.contains("\"sessions\""));
    }

    #[test]
    fn serve_obs_json_nests_stages_engines_and_accounting() {
        let j = serve_obs_json(&fake_summary(), procstat::sample());
        let line = jsonio::write(&j);
        let parsed = jsonio::parse(&line).expect("obs JSON parses");
        for stage in ["queue", "batch", "compute", "merge", "e2e"] {
            assert!(
                parsed
                    .get("stages")
                    .and_then(|s| s.get(stage))
                    .and_then(|s| s.get("p99"))
                    .is_some(),
                "stages.{stage}.p99 missing"
            );
        }
        let engines = parsed.get("engines").and_then(Json::as_arr).unwrap();
        assert_eq!(engines.len(), 1);
        assert_eq!(
            engines[0].get("mc_rows").and_then(Json::as_usize),
            Some(24)
        );
        assert_eq!(
            parsed
                .get("mc_samples")
                .and_then(|m| m.get("saved"))
                .and_then(Json::as_usize),
            Some(8)
        );
        assert_eq!(
            parsed.get("trace_dropped").and_then(Json::as_usize),
            Some(2)
        );
        assert_eq!(
            parsed
                .get("mask_bank")
                .and_then(|b| b.get("hits"))
                .and_then(Json::as_usize),
            Some(40)
        );
        assert_eq!(
            parsed
                .get("sessions")
                .and_then(|s| s.get("replay_rebuilds"))
                .and_then(Json::as_usize),
            Some(4)
        );
        // With a start snapshot, the proc block reports run-delta CPU
        // (on Linux, where /proc parses).
        if procstat::sample().is_some() {
            assert!(
                parsed
                    .get("proc")
                    .and_then(|p| p.get("cpu_delta_seconds"))
                    .is_some(),
                "cpu_delta_seconds missing from proc block"
            );
        }
    }

    /// Fault counters follow the stable-surface convention: metrics
    /// always exist (zero on a clean run), the obs JSON block appears
    /// only when a fault was actually recorded.
    #[test]
    fn fault_metrics_always_exist_but_json_block_is_conditional() {
        let clean = fake_summary();
        let set = serve_metric_set(&clean, 0.01, 400.0);
        for name in [
            "repro_fault_workers_lost_total",
            "repro_fault_shards_redispatched_total",
            "repro_fault_hedges_fired_total",
            "repro_fault_hedges_won_total",
            "repro_fault_sessions_repinned_total",
            "repro_fault_replies_dropped_total",
        ] {
            let m = set
                .metrics()
                .iter()
                .find(|m| m.name == name)
                .unwrap_or_else(|| panic!("{name} missing"));
            assert_eq!(m.value, 0.0, "{name} must read 0 on a clean run");
        }
        let line = jsonio::write(&serve_obs_json(&clean, None));
        assert!(!line.contains("\"faults\""), "clean run: no faults block");

        let mut faulty = fake_summary();
        faulty.obs.faults = crate::obs::FaultStats {
            workers_lost: 1,
            shards_redispatched: 3,
            hedges_fired: 2,
            hedges_won: 1,
            sessions_repinned: 1,
            replies_dropped: 4,
        };
        let set = serve_metric_set(&faulty, 0.01, 400.0);
        let text = set.to_prometheus();
        assert!(text.contains("repro_fault_workers_lost_total 1\n"));
        assert!(text.contains("repro_fault_replies_dropped_total 4\n"));
        let line = jsonio::write(&serve_obs_json(&faulty, None));
        let parsed = jsonio::parse(&line).expect("obs JSON parses");
        assert_eq!(
            parsed
                .get("faults")
                .and_then(|f| f.get("shards_redispatched"))
                .and_then(Json::as_usize),
            Some(3)
        );
        assert_eq!(
            parsed
                .get("faults")
                .and_then(|f| f.get("hedges_won"))
                .and_then(Json::as_usize),
            Some(1)
        );
    }

    fn fake_timeline() -> Timeline {
        use super::super::timeseries::WindowSample;
        let mut tl = Timeline::new(Duration::from_millis(100));
        for (w, ms) in [(0usize, 2.0), (0, 3.0), (1, 500.0)] {
            tl.e2e.record_ms(w, ms);
            tl.served.inc(w);
            tl.submitted.inc(w);
        }
        tl.offered.add(0, 2);
        tl.offered.add(1, 2);
        tl.rejected.inc(1);
        tl.samples.push(WindowSample {
            window: 1,
            rss_bytes: 1 << 20,
            cpu_delta_s: 0.05,
            max_in_flight: 3,
        });
        tl
    }

    #[test]
    fn timeline_metrics_cover_every_documented_name() {
        let mut set = MetricSet::new();
        push_timeline_metrics(&mut set, &fake_timeline());
        for name in TIMELINE_METRIC_NAMES {
            assert!(
                set.metrics().iter().any(|m| m.name == *name),
                "metric {name} missing from push_timeline_metrics"
            );
        }
        let text = set.to_prometheus();
        assert!(
            text.contains("repro_timeline_served_total{window=\"0\"} 2\n"),
            "per-window label missing:\n{text}"
        );
        assert!(text.contains("repro_timeline_inflight{window=\"1\"} 3\n"));
    }

    #[test]
    fn slo_metrics_cover_every_documented_name() {
        use super::super::slo::{evaluate, SloSpec};
        let tl = fake_timeline();
        let spec =
            SloSpec { latency_ms: 100.0, target: 0.5, max_shed_rate: 1.0 };
        let report = evaluate(&spec, 3, 1, 1, Some(&tl));
        let mut set = MetricSet::new();
        push_slo_metrics(&mut set, &report);
        for name in SLO_METRIC_NAMES {
            assert!(
                set.metrics().iter().any(|m| m.name == *name),
                "metric {name} missing from push_slo_metrics"
            );
        }
        let text = set.to_prometheus();
        assert!(text.contains("# TYPE repro_slo_pass gauge"));
        assert!(
            text.contains("repro_slo_burn_rate{window="),
            "per-window burn series missing:\n{text}"
        );
    }
}
