//! Declarative SLOs evaluated as windowed burn rates
//! (`docs/observability.md` §SLO).
//!
//! An [`SloSpec`] states the objective — "`target` fraction of
//! requests complete under `latency_ms`, and at most `max_shed_rate`
//! of traffic is shed". [`evaluate`] turns one run into a verdict:
//!
//! * **overall attainment** is exact — counted over the run's
//!   sample-keeping [`crate::coordinator::stats::LatencyStats`], not
//!   the bucketed histograms, and
//! * **per-window burn rates** come from the timeline's windowed
//!   histograms ([`super::timeseries::Timeline`]). The burn rate of a
//!   window is `(bad/total) / (1 − target)` — the rate at which that
//!   window consumed the error budget; a window burning > 1 is
//!   violating even if the whole-run average still passes. Window
//!   counts use [`super::hist::LogHistogram::count_over_us`], so they
//!   are exact at bucket boundaries and conservative (undercounting
//!   the bad side by at most one bucket, ≤ 12.5 % of the threshold)
//!   otherwise.
//!
//! The verdict nests into serve/loadgen JSON, renders in human mode
//! and exports through `--metrics`; `--slo-gate` turns a failing
//! verdict into a non-zero exit for CI.

use crate::jsonio::{self, Json};

use super::timeseries::Timeline;

/// A declarative serving objective.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloSpec {
    /// Latency threshold in milliseconds.
    pub latency_ms: f64,
    /// Target fraction of served requests under the threshold
    /// (e.g. 0.99 = "p99 under `latency_ms`").
    pub target: f64,
    /// Maximum tolerated fraction of submissions shed at admission.
    pub max_shed_rate: f64,
}

impl Default for SloSpec {
    /// The `--obs` default: p99 ≤ 250 ms, ≤ 1 % shed. Generous for the
    /// smoke scale this repo serves at, so an un-tuned run passes.
    fn default() -> Self {
        Self { latency_ms: 250.0, target: 0.99, max_shed_rate: 0.01 }
    }
}

impl SloSpec {
    /// Parse a `--slo` argument: comma-separated `key=value` with keys
    /// `latency_ms`, `target`, `max_shed`. Omitted keys keep the
    /// default. Example: `latency_ms=50,target=0.99,max_shed=0.01`.
    pub fn parse(s: &str) -> Result<Self, String> {
        let mut spec = Self::default();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| format!("--slo: expected key=value, got {part:?}"))?;
            let num: f64 = val
                .trim()
                .parse()
                .map_err(|_| format!("--slo: {key} wants a number, got {val:?}"))?;
            match key.trim() {
                "latency_ms" => {
                    if num <= 0.0 {
                        return Err("--slo: latency_ms must be > 0".into());
                    }
                    spec.latency_ms = num;
                }
                "target" => {
                    if !(0.0..=1.0).contains(&num) {
                        return Err("--slo: target must be in [0, 1]".into());
                    }
                    spec.target = num;
                }
                "max_shed" => {
                    if !(0.0..=1.0).contains(&num) {
                        return Err("--slo: max_shed must be in [0, 1]".into());
                    }
                    spec.max_shed_rate = num;
                }
                other => {
                    return Err(format!(
                        "--slo: unknown key {other:?} (latency_ms, target, max_shed)"
                    ));
                }
            }
        }
        Ok(spec)
    }

    /// Error-budget burn rate for `over` bad requests out of `total`:
    /// `(over/total) / (1 − target)`. 1.0 means the budget is consumed
    /// exactly at the sustainable rate; > 1 is a violating window.
    pub fn burn_rate(&self, over: u64, total: u64) -> f64 {
        if total == 0 {
            return 0.0;
        }
        let bad = over as f64 / total as f64;
        bad / (1.0 - self.target).max(1e-9)
    }
}

/// One window's share of the verdict (only windows that served
/// traffic appear).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloWindow {
    pub window: usize,
    pub total: u64,
    pub over: u64,
    pub burn: f64,
}

/// The evaluated verdict for one run.
#[derive(Debug, Clone)]
pub struct SloReport {
    pub spec: SloSpec,
    pub served: usize,
    pub rejected: usize,
    /// Served requests over the latency threshold (exact count).
    pub over: usize,
    /// Fraction of served requests under the threshold.
    pub attained: f64,
    pub shed_rate: f64,
    pub windows: Vec<SloWindow>,
    pub worst_burn: f64,
    pub violating_windows: usize,
    pub pass: bool,
}

/// Evaluate a spec against one run. `over_exact` is the exact count of
/// served requests above `spec.latency_ms` (from the run's
/// `LatencyStats`); `timeline` supplies the per-window e2e histograms
/// when windowing was on.
pub fn evaluate(
    spec: &SloSpec,
    served: usize,
    rejected: usize,
    over_exact: usize,
    timeline: Option<&Timeline>,
) -> SloReport {
    let attained = if served == 0 {
        1.0
    } else {
        (served - over_exact.min(served)) as f64 / served as f64
    };
    let submitted = served + rejected;
    let shed_rate = if submitted == 0 {
        0.0
    } else {
        rejected as f64 / submitted as f64
    };
    let mut windows = Vec::new();
    if let Some(tl) = timeline {
        for w in 0..tl.e2e.len() {
            let h = match tl.e2e.window(w) {
                Some(h) if !h.is_empty() => h,
                _ => continue,
            };
            let total = h.count() as u64;
            let over = h.count_over_us(spec.latency_ms * 1e3).min(total);
            windows.push(SloWindow {
                window: w,
                total,
                over,
                burn: spec.burn_rate(over, total),
            });
        }
    }
    let worst_burn =
        windows.iter().map(|w| w.burn).fold(0.0f64, f64::max);
    let violating_windows = windows.iter().filter(|w| w.burn > 1.0).count();
    let pass = attained >= spec.target && shed_rate <= spec.max_shed_rate;
    SloReport {
        spec: *spec,
        served,
        rejected,
        over: over_exact,
        attained,
        shed_rate,
        windows,
        worst_burn,
        violating_windows,
        pass,
    }
}

impl SloReport {
    pub fn to_json(&self) -> Json {
        let windows = self
            .windows
            .iter()
            .map(|w| {
                jsonio::obj(vec![
                    ("w", Json::Num(w.window as f64)),
                    ("total", Json::Num(w.total as f64)),
                    ("over", Json::Num(w.over as f64)),
                    ("burn", Json::Num(w.burn)),
                ])
            })
            .collect();
        jsonio::obj(vec![
            (
                "spec",
                jsonio::obj(vec![
                    ("latency_ms", Json::Num(self.spec.latency_ms)),
                    ("target", Json::Num(self.spec.target)),
                    ("max_shed_rate", Json::Num(self.spec.max_shed_rate)),
                ]),
            ),
            ("served", Json::Num(self.served as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("over_threshold", Json::Num(self.over as f64)),
            ("attained", Json::Num(self.attained)),
            ("shed_rate", Json::Num(self.shed_rate)),
            ("worst_burn_rate", Json::Num(self.worst_burn)),
            (
                "violating_windows",
                Json::Num(self.violating_windows as f64),
            ),
            ("pass", Json::Bool(self.pass)),
            ("windows", Json::Arr(windows)),
        ])
    }

    /// Multi-line human rendering for `repro serve`/`repro loadgen`
    /// without `--json`.
    pub fn render(&self) -> String {
        let mut out = format!(
            "SLO: {:.4} of {} served under {} ms (target {:.4}) — {}\n",
            self.attained,
            self.served,
            self.spec.latency_ms,
            self.spec.target,
            if self.pass { "PASS" } else { "FAIL" },
        );
        out.push_str(&format!(
            "     shed rate {:.4} (max {:.4}); worst window burn {:.2}x, \
             {} violating window(s) of {}\n",
            self.shed_rate,
            self.spec.max_shed_rate,
            self.worst_burn,
            self.violating_windows,
            self.windows.len(),
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn parse_accepts_partial_specs_and_rejects_junk() {
        let d = SloSpec::default();
        assert_eq!(SloSpec::parse("").unwrap(), d);
        let s = SloSpec::parse("latency_ms=50").unwrap();
        assert_eq!(s.latency_ms, 50.0);
        assert_eq!(s.target, d.target);
        let s =
            SloSpec::parse("latency_ms=50, target=0.95, max_shed=0.1").unwrap();
        assert_eq!(
            s,
            SloSpec { latency_ms: 50.0, target: 0.95, max_shed_rate: 0.1 }
        );
        assert!(SloSpec::parse("latency=50").is_err());
        assert!(SloSpec::parse("latency_ms=fast").is_err());
        assert!(SloSpec::parse("latency_ms=-1").is_err());
        assert!(SloSpec::parse("target=1.5").is_err());
        assert!(SloSpec::parse("nonsense").is_err());
    }

    #[test]
    fn burn_rate_scales_with_error_budget() {
        let spec =
            SloSpec { latency_ms: 10.0, target: 0.99, max_shed_rate: 1.0 };
        // Exactly at budget: 1% bad with a 1% budget burns at 1.0x.
        assert!((spec.burn_rate(1, 100) - 1.0).abs() < 1e-9);
        assert!((spec.burn_rate(10, 100) - 10.0).abs() < 1e-9);
        assert_eq!(spec.burn_rate(0, 100), 0.0);
        assert_eq!(spec.burn_rate(0, 0), 0.0);
    }

    fn timeline_with_e2e(windows: &[&[f64]]) -> Timeline {
        let mut tl = Timeline::new(Duration::from_millis(100));
        for (w, vals) in windows.iter().enumerate() {
            for &ms in *vals {
                tl.e2e.record_ms(w, ms);
                tl.served.inc(w);
            }
        }
        tl
    }

    #[test]
    fn evaluate_flags_the_violating_window() {
        // Window 0 healthy, window 1 pathological: a 50% target (2x
        // budget) makes window 1 burn at 2x while window 0 burns 0.
        let tl = timeline_with_e2e(&[
            &[1.0, 1.0, 1.0, 1.0],
            &[1.0, 1.0, 4000.0, 4000.0, 4000.0, 4000.0],
        ]);
        let spec =
            SloSpec { latency_ms: 100.0, target: 0.5, max_shed_rate: 1.0 };
        let report = evaluate(&spec, 10, 0, 4, Some(&tl));
        assert_eq!(report.windows.len(), 2);
        assert_eq!(report.windows[0].over, 0);
        assert_eq!(report.windows[1].over, 4);
        assert_eq!(report.violating_windows, 1);
        assert!(report.worst_burn > 1.0);
        // Overall: 6/10 under 100ms >= 0.5 target → pass.
        assert!(report.attained >= 0.5 && report.pass);
    }

    #[test]
    fn evaluate_pass_fail_thresholds() {
        let spec =
            SloSpec { latency_ms: 10.0, target: 0.9, max_shed_rate: 0.05 };
        // 95% attained, no sheds → pass.
        let r = evaluate(&spec, 100, 0, 5, None);
        assert!(r.pass && (r.attained - 0.95).abs() < 1e-9);
        // 85% attained → fail on latency.
        assert!(!evaluate(&spec, 100, 0, 15, None).pass);
        // Attained but shedding 10% → fail on shed rate.
        let r = evaluate(&spec, 90, 10, 0, None);
        assert!(!r.pass && (r.shed_rate - 0.1).abs() < 1e-9);
        // Empty run trivially passes.
        let r = evaluate(&spec, 0, 0, 0, None);
        assert!(r.pass && r.attained == 1.0 && r.shed_rate == 0.0);
    }

    #[test]
    fn report_json_shape_round_trips() {
        let tl = timeline_with_e2e(&[&[1.0, 200.0]]);
        let spec = SloSpec::default();
        let report = evaluate(&spec, 2, 1, 0, Some(&tl));
        let j = report.to_json();
        let text = crate::jsonio::write(&j);
        let back = crate::jsonio::parse(&text).expect("slo JSON parses");
        assert_eq!(back.get("served").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(
            back.get("pass").and_then(|v| v.as_bool()),
            Some(report.pass)
        );
        let windows = back.get("windows").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(windows.len(), 1);
        assert_eq!(
            windows[0].get("total").and_then(|v| v.as_usize()),
            Some(2)
        );
    }
}
