//! Lightweight `/proc/self` process sampler: resident-set size and
//! cumulative CPU time, read once per export (not per request). Returns
//! `None` off Linux or when `/proc` is unreadable — callers degrade to
//! omitting the `proc` block rather than failing the run.

/// One process snapshot.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProcStat {
    /// Resident set size in bytes.
    pub rss_bytes: u64,
    /// Cumulative user + system CPU seconds.
    pub cpu_seconds: f64,
}

impl ProcStat {
    /// CPU seconds burned between `earlier` and this sample — the
    /// per-window utilization primitive. `cpu_seconds` alone is a
    /// cumulative tick counter, meaningless inside a timeline window;
    /// deltas between consecutive samples are the signal. Clamped at 0
    /// so samples taken out of order (or a tick-counter hiccup) can't
    /// report negative CPU.
    pub fn cpu_delta_since(&self, earlier: &ProcStat) -> f64 {
        (self.cpu_seconds - earlier.cpu_seconds).max(0.0)
    }
}

/// Common Linux defaults; without libc there is no portable sysconf,
/// and these match every mainstream distro kernel config. A wrong
/// constant skews absolute RSS/CPU numbers but not the trends the
/// bench trajectory tracks.
const PAGE_SIZE: u64 = 4096;
const USER_HZ: f64 = 100.0;

/// Sample `/proc/self/{statm,stat}`.
pub fn sample() -> Option<ProcStat> {
    let statm = std::fs::read_to_string("/proc/self/statm").ok()?;
    // statm: size resident shared text lib data dt (pages).
    let resident_pages: u64 =
        statm.split_whitespace().nth(1)?.parse().ok()?;
    let stat = std::fs::read_to_string("/proc/self/stat").ok()?;
    // stat field 2 (comm) may contain spaces — split after the closing
    // paren, then utime/stime are fields 14/15 overall = 11/12 of the
    // remainder.
    let rest = stat.rsplit_once(')')?.1;
    let fields: Vec<&str> = rest.split_whitespace().collect();
    let utime: u64 = fields.get(11)?.parse().ok()?;
    let stime: u64 = fields.get(12)?.parse().ok()?;
    Some(ProcStat {
        rss_bytes: resident_pages * PAGE_SIZE,
        cpu_seconds: (utime + stime) as f64 / USER_HZ,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_is_sane_on_linux_and_none_elsewhere() {
        match sample() {
            Some(p) => {
                // Any running test binary has resident pages and has
                // burned some (possibly sub-tick) CPU.
                assert!(p.rss_bytes > 0);
                assert!(p.cpu_seconds >= 0.0);
            }
            None => {
                assert!(
                    !cfg!(target_os = "linux"),
                    "/proc/self must parse on Linux"
                );
            }
        }
    }

    #[test]
    fn cpu_delta_is_nonnegative_and_ordered() {
        let a = ProcStat { rss_bytes: 1, cpu_seconds: 1.5 };
        let b = ProcStat { rss_bytes: 1, cpu_seconds: 2.25 };
        assert!((b.cpu_delta_since(&a) - 0.75).abs() < 1e-12);
        // Reversed order clamps to zero instead of going negative.
        assert_eq!(a.cpu_delta_since(&b), 0.0);
        assert_eq!(a.cpu_delta_since(&a), 0.0);
    }
}
