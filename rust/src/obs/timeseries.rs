//! Windowed time-series plane: fixed-interval per-window histograms,
//! counters and process gauges (`docs/observability.md` §Time-series).
//!
//! PR 6's observability layer could only summarize a whole run; this
//! module slices the same measurements into fixed-width wall-clock
//! windows so a report can show *when* the fleet degraded, feed SLO
//! burn rates ([`super::slo`]) and compare offered vs achieved load
//! per window. Two invariants carry over from the histogram layer:
//!
//! * **Windows merge exactly.** A [`WindowedHist`] is a vector of
//!   [`LogHistogram`]s merged element-wise, so per-worker timelines
//!   combine across the fleet with the same associative/commutative
//!   contract as the whole-run histograms, and
//! * **the whole run is the sum of its windows**: merging every window
//!   of a [`WindowedHist`] reproduces, bit for bit, the histogram that
//!   would have been recorded without windowing (property-tested
//!   below). Nothing is lost by slicing.
//!
//! Window index = `(t − epoch) / width`, where `epoch` is captured once
//! at fleet start and shared by every recorder (workers, submit path,
//! the background [`Sampler`] and the open-loop load generator), so all
//! window streams align.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use super::counters::EngineLoad;
use super::hist::LogHistogram;
use super::procstat::{self, ProcStat};
use crate::jsonio::{self, Json};

/// Hard cap on window count: a run long enough to exceed it collapses
/// the tail into the last window instead of growing without bound
/// (4096 windows at the default 100 ms width is ~7 min of serving).
pub const MAX_WINDOWS: usize = 4096;

/// Window index of instant `at` relative to `epoch`, clamped to
/// [`MAX_WINDOWS`]. Instants before the epoch land in window 0
/// (saturating), so a scheduled arrival slightly ahead of fleet start
/// cannot panic or wrap.
pub fn window_index(epoch: Instant, width: Duration, at: Instant) -> usize {
    let ns = at.saturating_duration_since(epoch).as_nanos();
    let w = (ns / width.as_nanos().max(1)) as usize;
    w.min(MAX_WINDOWS - 1)
}

/// A vector of per-window [`LogHistogram`]s with element-wise exact
/// merge. Lazily grown: windows that saw no samples before the last
/// recorded one are present but empty.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WindowedHist {
    windows: Vec<LogHistogram>,
}

impl WindowedHist {
    pub fn new() -> Self {
        Self::default()
    }

    fn grow_to(&mut self, w: usize) {
        let w = w.min(MAX_WINDOWS - 1);
        if self.windows.len() <= w {
            self.windows.resize_with(w + 1, LogHistogram::new);
        }
    }

    pub fn record_us(&mut self, w: usize, us: f64) {
        self.grow_to(w);
        let i = w.min(self.windows.len() - 1);
        self.windows[i].record_us(us);
    }

    pub fn record_ms(&mut self, w: usize, ms: f64) {
        self.record_us(w, ms * 1e3);
    }

    pub fn len(&self) -> usize {
        self.windows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }

    pub fn window(&self, i: usize) -> Option<&LogHistogram> {
        self.windows.get(i)
    }

    /// Exact element-wise merge — same associativity/commutativity
    /// contract as [`LogHistogram::merge`].
    pub fn merge(&mut self, other: &WindowedHist) {
        if other.windows.is_empty() {
            return;
        }
        self.grow_to(other.windows.len() - 1);
        for (a, b) in self.windows.iter_mut().zip(&other.windows) {
            a.merge(b);
        }
    }

    /// Merge of every window — bit-identical to the histogram that
    /// would have been recorded without windowing.
    pub fn total(&self) -> LogHistogram {
        let mut out = LogHistogram::new();
        for w in &self.windows {
            out.merge(w);
        }
        out
    }
}

/// Per-window integer counters (served, rejected, offered, items,
/// batches) with exact merge.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WindowedCount {
    windows: Vec<u64>,
}

impl WindowedCount {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, w: usize, n: u64) {
        let w = w.min(MAX_WINDOWS - 1);
        if self.windows.len() <= w {
            self.windows.resize(w + 1, 0);
        }
        self.windows[w] += n;
    }

    pub fn inc(&mut self, w: usize) {
        self.add(w, 1);
    }

    pub fn len(&self) -> usize {
        self.windows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }

    pub fn get(&self, i: usize) -> u64 {
        self.windows.get(i).copied().unwrap_or(0)
    }

    pub fn merge(&mut self, other: &WindowedCount) {
        if other.windows.len() > self.windows.len() {
            self.windows.resize(other.windows.len(), 0);
        }
        for (a, &b) in self.windows.iter_mut().zip(&other.windows) {
            *a += b;
        }
    }

    pub fn total(&self) -> u64 {
        self.windows.iter().sum()
    }
}

/// The slice of the timeline one engine worker owns: stage histograms
/// plus item/batch counts, recorded at the window in which the batch's
/// compute finished. Merged across workers at fleet join.
#[derive(Debug, Clone, Default)]
pub struct WorkerTimeline {
    pub queue: WindowedHist,
    pub batch: WindowedHist,
    pub compute: WindowedHist,
    pub items: WindowedCount,
    pub batches: WindowedCount,
}

impl WorkerTimeline {
    pub fn merge(&mut self, other: &WorkerTimeline) {
        self.queue.merge(&other.queue);
        self.batch.merge(&other.batch);
        self.compute.merge(&other.compute);
        self.items.merge(&other.items);
        self.batches.merge(&other.batches);
    }
}

/// One process-level gauge sample collapsed to its window: last RSS,
/// CPU seconds burned *within* the window (delta between consecutive
/// window-closing samples, never cumulative ticks) and peak in-flight
/// request count.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WindowSample {
    pub window: usize,
    pub rss_bytes: u64,
    pub cpu_delta_s: f64,
    pub max_in_flight: usize,
}

struct RawSample {
    at: Instant,
    proc: Option<ProcStat>,
    in_flight: usize,
}

/// Background gauge sampler: a thread polling `/proc/self` and the
/// engine load counters every ~width/4 (clamped to [1, 50] ms). It
/// only *reads* relaxed atomics and procfs — it cannot perturb the
/// serving path. Dropping a `Sampler` without calling
/// [`Sampler::finish`] still stops and joins the thread.
pub struct Sampler {
    epoch: Instant,
    width: Duration,
    stop: Arc<AtomicBool>,
    handle: Option<thread::JoinHandle<Vec<RawSample>>>,
}

impl Sampler {
    pub fn spawn(
        epoch: Instant,
        width: Duration,
        loads: Vec<Arc<EngineLoad>>,
    ) -> Self {
        let tick = (width / 4)
            .clamp(Duration::from_millis(1), Duration::from_millis(50));
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = thread::spawn(move || {
            let mut raw = Vec::new();
            loop {
                raw.push(RawSample {
                    at: Instant::now(),
                    proc: procstat::sample(),
                    in_flight: loads.iter().map(|l| l.outstanding()).sum(),
                });
                if stop2.load(Ordering::Acquire) {
                    break;
                }
                thread::sleep(tick);
            }
            raw
        });
        Self { epoch, width, stop, handle: Some(handle) }
    }

    /// Stop the sampler thread and collapse its raw samples into one
    /// [`WindowSample`] per window that saw at least one poll.
    pub fn finish(mut self) -> Vec<WindowSample> {
        self.stop.store(true, Ordering::Release);
        let raw = match self.handle.take() {
            Some(h) => h.join().expect("obs sampler thread panicked"),
            None => Vec::new(),
        };
        collapse(self.epoch, self.width, &raw)
    }
}

impl Drop for Sampler {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn collapse(
    epoch: Instant,
    width: Duration,
    raw: &[RawSample],
) -> Vec<WindowSample> {
    let mut out: Vec<WindowSample> = Vec::new();
    // CPU reference: the first successful proc sample. Each window's
    // cpu_delta_s is measured from the previous window's closing
    // sample, so summing deltas over windows gives the run's total.
    let mut prev_cpu = raw.iter().find_map(|r| r.proc.map(|p| p.cpu_seconds));
    let mut cur: Option<WindowSample> = None;
    let mut last_cpu: Option<f64> = None;
    for r in raw {
        let w = window_index(epoch, width, r.at);
        if cur.map(|c| c.window) != Some(w) {
            if let Some(mut c) = cur.take() {
                if let (Some(cpu), Some(prev)) = (last_cpu, prev_cpu) {
                    c.cpu_delta_s = (cpu - prev).max(0.0);
                    prev_cpu = Some(cpu);
                }
                out.push(c);
            }
            cur = Some(WindowSample {
                window: w,
                rss_bytes: 0,
                cpu_delta_s: 0.0,
                max_in_flight: 0,
            });
            last_cpu = None;
        }
        let c = cur.as_mut().expect("window sample just initialised");
        if let Some(p) = r.proc {
            c.rss_bytes = p.rss_bytes;
            last_cpu = Some(p.cpu_seconds);
        }
        c.max_in_flight = c.max_in_flight.max(r.in_flight);
    }
    if let Some(mut c) = cur.take() {
        if let (Some(cpu), Some(prev)) = (last_cpu, prev_cpu) {
            c.cpu_delta_s = (cpu - prev).max(0.0);
        }
        out.push(c);
    }
    out
}

/// The assembled fleet timeline: windowed latency histograms, request
/// counters and gauge samples over one run, all indexed from the same
/// epoch. Built at fleet `join()` by merging worker timelines into the
/// fleet-level window state; `offered` is filled in afterwards by the
/// open-loop load generator (empty for closed-loop runs).
#[derive(Debug, Clone)]
pub struct Timeline {
    pub width: Duration,
    pub e2e: WindowedHist,
    pub queue: WindowedHist,
    pub batch: WindowedHist,
    pub compute: WindowedHist,
    pub offered: WindowedCount,
    pub submitted: WindowedCount,
    pub served: WindowedCount,
    pub rejected: WindowedCount,
    pub items: WindowedCount,
    pub batches: WindowedCount,
    pub samples: Vec<WindowSample>,
}

impl Timeline {
    pub fn new(width: Duration) -> Self {
        Self {
            width,
            e2e: WindowedHist::new(),
            queue: WindowedHist::new(),
            batch: WindowedHist::new(),
            compute: WindowedHist::new(),
            offered: WindowedCount::new(),
            submitted: WindowedCount::new(),
            served: WindowedCount::new(),
            rejected: WindowedCount::new(),
            items: WindowedCount::new(),
            batches: WindowedCount::new(),
            samples: Vec::new(),
        }
    }

    /// Number of windows spanned by any stream in this timeline.
    pub fn windows(&self) -> usize {
        let counts = [
            self.offered.len(),
            self.submitted.len(),
            self.served.len(),
            self.rejected.len(),
            self.items.len(),
            self.batches.len(),
        ];
        let hists = [
            self.e2e.len(),
            self.queue.len(),
            self.batch.len(),
            self.compute.len(),
        ];
        let gauges =
            self.samples.iter().map(|s| s.window + 1).max().unwrap_or(0);
        counts
            .into_iter()
            .chain(hists)
            .chain([gauges])
            .max()
            .unwrap_or(0)
    }

    /// Gauge sample for window `w`, if the background sampler ticked
    /// during it.
    pub fn sample_at(&self, w: usize) -> Option<&WindowSample> {
        self.samples.iter().find(|s| s.window == w)
    }

    /// `{"window_s", "windows", "per_window": [...]}` — one object per
    /// window with counters, e2e summary, stage p99s and gauges.
    pub fn to_json(&self) -> Json {
        let width_s = self.width.as_secs_f64();
        let n = self.windows();
        let mut per_window = Vec::with_capacity(n);
        for w in 0..n {
            let empty = LogHistogram::new();
            let e2e = self.e2e.window(w).unwrap_or(&empty);
            let served = self.served.get(w);
            let mut fields = vec![
                ("w", Json::Num(w as f64)),
                ("offered", Json::Num(self.offered.get(w) as f64)),
                ("submitted", Json::Num(self.submitted.get(w) as f64)),
                ("served", Json::Num(served as f64)),
                ("rejected", Json::Num(self.rejected.get(w) as f64)),
                ("items", Json::Num(self.items.get(w) as f64)),
                ("batches", Json::Num(self.batches.get(w) as f64)),
                (
                    "throughput_rps",
                    Json::Num(served as f64 / width_s.max(1e-9)),
                ),
                ("e2e", e2e.summary_json()),
                (
                    "queue_p99",
                    Json::Num(
                        self.queue
                            .window(w)
                            .map(|h| h.percentile_ms(99.0))
                            .unwrap_or(0.0),
                    ),
                ),
                (
                    "compute_p99",
                    Json::Num(
                        self.compute
                            .window(w)
                            .map(|h| h.percentile_ms(99.0))
                            .unwrap_or(0.0),
                    ),
                ),
            ];
            if let Some(s) = self.sample_at(w) {
                fields.push(("rss_bytes", Json::Num(s.rss_bytes as f64)));
                fields.push(("cpu_s", Json::Num(s.cpu_delta_s)));
                fields.push((
                    "cpu_util",
                    Json::Num(s.cpu_delta_s / width_s.max(1e-9)),
                ));
                fields.push((
                    "in_flight",
                    Json::Num(s.max_in_flight as f64),
                ));
            }
            per_window.push(jsonio::obj(fields));
        }
        jsonio::obj(vec![
            ("window_s", Json::Num(width_s)),
            ("windows", Json::Num(n as f64)),
            ("per_window", Json::Arr(per_window)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn seeded_values(seed: u64, n: usize) -> Vec<(usize, f64)> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let w = rng.below(13);
                // log-uniform over [~1 µs, ~1 s]
                let us = (2.0f64).powf(rng.uniform() * 20.0);
                (w, us)
            })
            .collect()
    }

    /// The tentpole property: slicing a run into windows loses nothing
    /// — merging every window reproduces the unwindowed histogram with
    /// exact structural equality.
    #[test]
    fn whole_run_equals_sum_of_windows() {
        let vals = seeded_values(42, 5000);
        let mut windowed = WindowedHist::new();
        let mut plain = LogHistogram::new();
        for &(w, us) in &vals {
            windowed.record_us(w, us);
            plain.record_us(us);
        }
        assert_eq!(windowed.total(), plain);
        assert_eq!(windowed.total().count(), 5000);
    }

    /// Per-window merge across workers carries the LogHistogram
    /// contract: order-free, and window-by-window exact.
    #[test]
    fn windowed_merge_is_order_free_and_exact() {
        let vals = seeded_values(7, 3000);
        // Shard the same value stream across three "workers".
        let mut shards = [
            WindowedHist::new(),
            WindowedHist::new(),
            WindowedHist::new(),
        ];
        let mut pooled = WindowedHist::new();
        for (i, &(w, us)) in vals.iter().enumerate() {
            shards[i % 3].record_us(w, us);
            pooled.record_us(w, us);
        }
        let mut abc = shards[0].clone();
        abc.merge(&shards[1]);
        abc.merge(&shards[2]);
        let mut cba = shards[2].clone();
        cba.merge(&shards[1]);
        cba.merge(&shards[0]);
        assert_eq!(abc, cba, "windowed merge must commute");
        assert_eq!(abc, pooled, "windowed merge must equal pooled recording");
        assert_eq!(abc.total(), pooled.total());
    }

    #[test]
    fn windowed_counts_merge_and_total() {
        let mut a = WindowedCount::new();
        let mut b = WindowedCount::new();
        a.inc(0);
        a.add(2, 5);
        b.inc(1);
        b.add(2, 3);
        b.inc(4);
        a.merge(&b);
        assert_eq!(a.len(), 5);
        assert_eq!(
            (0..5).map(|i| a.get(i)).collect::<Vec<_>>(),
            vec![1, 1, 8, 0, 1]
        );
        assert_eq!(a.total(), 11);
        assert_eq!(a.get(99), 0);
    }

    #[test]
    fn window_index_clamps_and_aligns() {
        let epoch = Instant::now();
        let w = Duration::from_millis(100);
        assert_eq!(window_index(epoch, w, epoch), 0);
        assert_eq!(
            window_index(epoch, w, epoch + Duration::from_millis(250)),
            2
        );
        // Before the epoch saturates to window 0.
        assert_eq!(
            window_index(epoch + Duration::from_secs(1), w, epoch),
            0
        );
        // Far future clamps instead of allocating unboundedly.
        assert_eq!(
            window_index(epoch, w, epoch + Duration::from_secs(100_000)),
            MAX_WINDOWS - 1
        );
    }

    #[test]
    fn sampler_collapses_to_per_window_gauges() {
        let epoch = Instant::now();
        let sampler = Sampler::spawn(
            epoch,
            Duration::from_millis(8),
            vec![Arc::new(EngineLoad::default())],
        );
        thread::sleep(Duration::from_millis(25));
        let samples = sampler.finish();
        assert!(!samples.is_empty(), "sampler produced no samples");
        for pair in samples.windows(2) {
            assert!(
                pair[0].window < pair[1].window,
                "window samples must be strictly ordered"
            );
        }
        for s in &samples {
            assert!(s.cpu_delta_s >= 0.0);
        }
    }

    #[test]
    fn timeline_json_shape() {
        let mut tl = Timeline::new(Duration::from_millis(100));
        tl.e2e.record_ms(0, 5.0);
        tl.e2e.record_ms(1, 25.0);
        tl.served.inc(0);
        tl.served.inc(1);
        tl.offered.add(0, 2);
        tl.samples.push(WindowSample {
            window: 1,
            rss_bytes: 1024,
            cpu_delta_s: 0.05,
            max_in_flight: 3,
        });
        let j = tl.to_json();
        assert_eq!(j.get("windows").and_then(|v| v.as_usize()), Some(2));
        let per = j.get("per_window").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(per.len(), 2);
        assert_eq!(per[0].get("offered").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(per[1].get("served").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(
            per[1].get("in_flight").and_then(|v| v.as_usize()),
            Some(3)
        );
        assert!(per[0].get("in_flight").is_none(), "no gauge in window 0");
        let e2e = per[1].get("e2e").unwrap();
        assert_eq!(e2e.get("count").and_then(|v| v.as_usize()), Some(1));
        // Round-trips through the writer/parser.
        let text = jsonio::write(&j);
        let back = jsonio::parse(&text).expect("timeline JSON parses");
        assert_eq!(back.get("windows").and_then(|v| v.as_usize()), Some(2));
    }
}
