//! Engine health counters: lock-free gauges shared between the fleet's
//! submit path, adaptive coordinator and workers
//! (`docs/observability.md` §Engine health).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Per-engine queue-pressure tracker. The outstanding count is the
/// gauge the least-loaded router already consulted; this extends it
/// with a high-water mark and a shed counter without adding any
/// synchronisation beyond the pre-existing atomics (the high-water
/// `fetch_max` rides the same cache line the `fetch_add` just touched).
#[derive(Debug, Default)]
pub struct EngineLoad {
    outstanding: AtomicUsize,
    highwater: AtomicUsize,
    sheds: AtomicUsize,
}

impl EngineLoad {
    pub fn new() -> Self {
        Self::default()
    }

    /// One work item enqueued.
    pub fn inc(&self) {
        let now = self.outstanding.fetch_add(1, Ordering::AcqRel) + 1;
        self.highwater.fetch_max(now, Ordering::AcqRel);
    }

    /// One work item completed by the worker.
    pub fn dec(&self) {
        self.outstanding.fetch_sub(1, Ordering::AcqRel);
    }

    /// Admission control rejected a work item aimed at this engine.
    pub fn shed(&self) {
        self.sheds.fetch_add(1, Ordering::AcqRel);
    }

    /// Outstanding work items (the router's load snapshot).
    pub fn outstanding(&self) -> usize {
        self.outstanding.load(Ordering::Acquire)
    }

    /// Deepest the queue ever got.
    pub fn highwater(&self) -> usize {
        self.highwater.load(Ordering::Acquire)
    }

    /// Work items rejected at this engine's queue.
    pub fn sheds(&self) -> usize {
        self.sheds.load(Ordering::Acquire)
    }
}

/// Fleet-wide MC sample accounting: samples actually drawn vs samples
/// the adaptive controller's early exit avoided (vs its `s_max`
/// budget). Updated by the waiter thread (fixed path) and the adaptive
/// coordinator thread (adaptive path), hence atomic.
#[derive(Debug, Default)]
pub struct McCounters {
    spent: AtomicUsize,
    saved: AtomicUsize,
}

impl McCounters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_spent(&self, n: usize) {
        self.spent.fetch_add(n, Ordering::AcqRel);
    }

    pub fn add_saved(&self, n: usize) {
        self.saved.fetch_add(n, Ordering::AcqRel);
    }

    pub fn spent(&self) -> usize {
        self.spent.load(Ordering::Acquire)
    }

    pub fn saved(&self) -> usize {
        self.saved.load(Ordering::Acquire)
    }
}

/// Fleet fault-tolerance accounting (`docs/observability.md` §Fault
/// metrics). Bumped from the submit path, the waiter threads and the
/// workers' reply paths, hence atomic; snapshotted into [`FaultStats`]
/// for the summary/JSON/metrics layers.
#[derive(Debug, Default)]
pub struct FaultCounters {
    workers_lost: AtomicU64,
    shards_redispatched: AtomicU64,
    hedges_fired: AtomicU64,
    hedges_won: AtomicU64,
    sessions_repinned: AtomicU64,
    replies_dropped: AtomicU64,
}

impl FaultCounters {
    pub fn new() -> Self {
        Self::default()
    }

    /// A worker thread died (panic or injected kill).
    pub fn worker_lost(&self) {
        self.workers_lost.fetch_add(1, Ordering::AcqRel);
    }

    /// A queued or in-flight shard was re-sent to a surviving engine.
    pub fn shard_redispatched(&self) {
        self.shards_redispatched.fetch_add(1, Ordering::AcqRel);
    }

    /// An overdue shard was speculatively re-executed elsewhere.
    pub fn hedge_fired(&self) {
        self.hedges_fired.fetch_add(1, Ordering::AcqRel);
    }

    /// A hedge's reply arrived before the original's.
    pub fn hedge_won(&self) {
        self.hedges_won.fetch_add(1, Ordering::AcqRel);
    }

    /// An affinity session was moved off an unhealthy engine.
    pub fn session_repinned(&self) {
        self.sessions_repinned.fetch_add(1, Ordering::AcqRel);
    }

    /// Chaos discarded a reply before it reached the waiter.
    pub fn reply_dropped(&self) {
        self.replies_dropped.fetch_add(1, Ordering::AcqRel);
    }

    pub fn snapshot(&self) -> FaultStats {
        FaultStats {
            workers_lost: self.workers_lost.load(Ordering::Acquire),
            shards_redispatched: self
                .shards_redispatched
                .load(Ordering::Acquire),
            hedges_fired: self.hedges_fired.load(Ordering::Acquire),
            hedges_won: self.hedges_won.load(Ordering::Acquire),
            sessions_repinned: self
                .sessions_repinned
                .load(Ordering::Acquire),
            replies_dropped: self
                .replies_dropped
                .load(Ordering::Acquire),
        }
    }
}

/// Point-in-time view of [`FaultCounters`]; all zeros on a clean run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    pub workers_lost: u64,
    pub shards_redispatched: u64,
    pub hedges_fired: u64,
    pub hedges_won: u64,
    pub sessions_repinned: u64,
    pub replies_dropped: u64,
}

impl FaultStats {
    /// `true` if any fault-tolerance machinery engaged this run.
    pub fn any(&self) -> bool {
        *self != FaultStats::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_load_tracks_highwater_and_sheds() {
        let l = EngineLoad::new();
        l.inc();
        l.inc();
        l.inc();
        assert_eq!(l.outstanding(), 3);
        assert_eq!(l.highwater(), 3);
        l.dec();
        l.dec();
        assert_eq!(l.outstanding(), 1);
        assert_eq!(l.highwater(), 3, "high-water survives drain");
        l.inc();
        assert_eq!(l.highwater(), 3, "2 outstanding does not beat 3");
        l.shed();
        l.shed();
        assert_eq!(l.sheds(), 2);
    }

    #[test]
    fn mc_counters_accumulate() {
        let c = McCounters::new();
        c.add_spent(8);
        c.add_spent(4);
        c.add_saved(16);
        assert_eq!(c.spent(), 12);
        assert_eq!(c.saved(), 16);
    }

    #[test]
    fn fault_counters_snapshot_and_any() {
        let f = FaultCounters::new();
        assert!(!f.snapshot().any(), "clean fleet reports no faults");
        f.worker_lost();
        f.shard_redispatched();
        f.shard_redispatched();
        f.hedge_fired();
        f.hedge_won();
        f.session_repinned();
        f.reply_dropped();
        let s = f.snapshot();
        assert_eq!(s.workers_lost, 1);
        assert_eq!(s.shards_redispatched, 2);
        assert_eq!(s.hedges_fired, 1);
        assert_eq!(s.hedges_won, 1);
        assert_eq!(s.sessions_repinned, 1);
        assert_eq!(s.replies_dropped, 1);
        assert!(s.any());
    }
}
