//! Engine health counters: lock-free gauges shared between the fleet's
//! submit path, adaptive coordinator and workers
//! (`docs/observability.md` §Engine health).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Per-engine queue-pressure tracker. The outstanding count is the
/// gauge the least-loaded router already consulted; this extends it
/// with a high-water mark and a shed counter without adding any
/// synchronisation beyond the pre-existing atomics (the high-water
/// `fetch_max` rides the same cache line the `fetch_add` just touched).
#[derive(Debug, Default)]
pub struct EngineLoad {
    outstanding: AtomicUsize,
    highwater: AtomicUsize,
    sheds: AtomicUsize,
}

impl EngineLoad {
    pub fn new() -> Self {
        Self::default()
    }

    /// One work item enqueued.
    pub fn inc(&self) {
        let now = self.outstanding.fetch_add(1, Ordering::AcqRel) + 1;
        self.highwater.fetch_max(now, Ordering::AcqRel);
    }

    /// One work item completed by the worker.
    pub fn dec(&self) {
        self.outstanding.fetch_sub(1, Ordering::AcqRel);
    }

    /// Admission control rejected a work item aimed at this engine.
    pub fn shed(&self) {
        self.sheds.fetch_add(1, Ordering::AcqRel);
    }

    /// Outstanding work items (the router's load snapshot).
    pub fn outstanding(&self) -> usize {
        self.outstanding.load(Ordering::Acquire)
    }

    /// Deepest the queue ever got.
    pub fn highwater(&self) -> usize {
        self.highwater.load(Ordering::Acquire)
    }

    /// Work items rejected at this engine's queue.
    pub fn sheds(&self) -> usize {
        self.sheds.load(Ordering::Acquire)
    }
}

/// Fleet-wide MC sample accounting: samples actually drawn vs samples
/// the adaptive controller's early exit avoided (vs its `s_max`
/// budget). Updated by the waiter thread (fixed path) and the adaptive
/// coordinator thread (adaptive path), hence atomic.
#[derive(Debug, Default)]
pub struct McCounters {
    spent: AtomicUsize,
    saved: AtomicUsize,
}

impl McCounters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_spent(&self, n: usize) {
        self.spent.fetch_add(n, Ordering::AcqRel);
    }

    pub fn add_saved(&self, n: usize) {
        self.saved.fetch_add(n, Ordering::AcqRel);
    }

    pub fn spent(&self) -> usize {
        self.spent.load(Ordering::Acquire)
    }

    pub fn saved(&self) -> usize {
        self.saved.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_load_tracks_highwater_and_sheds() {
        let l = EngineLoad::new();
        l.inc();
        l.inc();
        l.inc();
        assert_eq!(l.outstanding(), 3);
        assert_eq!(l.highwater(), 3);
        l.dec();
        l.dec();
        assert_eq!(l.outstanding(), 1);
        assert_eq!(l.highwater(), 3, "high-water survives drain");
        l.inc();
        assert_eq!(l.highwater(), 3, "2 outstanding does not beat 3");
        l.shed();
        l.shed();
        assert_eq!(l.sheds(), 2);
    }

    #[test]
    fn mc_counters_accumulate() {
        let c = McCounters::new();
        c.add_spent(8);
        c.add_spent(4);
        c.add_saved(16);
        assert_eq!(c.spent(), 12);
        assert_eq!(c.saved(), 16);
    }
}
