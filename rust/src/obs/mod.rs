//! Fleet-wide observability: staged request tracing, mergeable
//! log-bucketed latency histograms, engine health counters and a
//! metrics export layer (`docs/observability.md`).
//!
//! Design constraints, in priority order:
//!
//! 1. **Never perturb the data path.** Observability reads timestamps
//!    and counters around the serving path; it does not reorder work,
//!    change batch formation or touch RNG state. With
//!    [`ObsConfig::enabled`] false, `repro serve` output is
//!    bit-identical to a build without this module.
//! 2. **Mergeable by construction.** Per-engine and per-worker
//!    [`LogHistogram`]s combine with an exact associative merge
//!    (integer bucket counts + integer nanosecond sums), so fleet-wide
//!    tail percentiles are computed from *all* samples, not from
//!    averaged per-engine percentiles.
//! 3. **Zero dependencies.** Like the rest of the crate: hand-rolled
//!    JSON via [`crate::jsonio`], `/proc` parsing via `std::fs`, no
//!    metrics crates.

pub mod counters;
pub mod export;
pub mod hist;
pub mod procstat;
pub mod slo;
pub mod timeseries;
pub mod trace;

pub use counters::{EngineLoad, FaultCounters, FaultStats, McCounters};
pub use export::{
    push_slo_metrics, push_timeline_metrics, serve_metric_set,
    serve_obs_json, Metric, MetricSet, SERVE_METRIC_NAMES,
    SLO_METRIC_NAMES, TIMELINE_METRIC_NAMES,
};
pub use hist::LogHistogram;
pub use procstat::{sample as proc_sample, ProcStat};
pub use slo::{SloReport, SloSpec};
pub use timeseries::{
    window_index, Sampler, Timeline, WindowSample, WindowedCount,
    WindowedHist, WorkerTimeline,
};
pub use trace::{StageStats, TraceLog};

use std::sync::Arc;
use std::time::Duration;

/// Observability switches threaded through [`crate::coordinator::fleet::FleetConfig`].
///
/// `enabled` turns on stage timing, histograms and the nested serve
/// JSON/metrics export; `trace` additionally streams per-request stage
/// events to a JSONL file; `window` slices the run into a fixed-width
/// timeline ([`timeseries::Timeline`]) and starts the background gauge
/// sampler. All default off, and the fleet guarantees bit-identical
/// serve output when disabled.
#[derive(Clone, Default)]
pub struct ObsConfig {
    pub enabled: bool,
    pub trace: Option<Arc<TraceLog>>,
    /// Timeline window width; `None` keeps PR 6's whole-run-summary
    /// behaviour. Only honoured when `enabled`.
    pub window: Option<Duration>,
}

impl ObsConfig {
    /// Enabled, no trace file — the common `--obs` configuration and
    /// the one integration tests use.
    pub fn on() -> Self {
        Self { enabled: true, trace: None, window: None }
    }

    /// Enabled with a windowed timeline of the given width.
    pub fn on_windowed(width: Duration) -> Self {
        Self { enabled: true, trace: None, window: Some(width) }
    }

    /// Record a trace event if a trace sink is configured.
    pub fn trace_event(
        &self,
        req: u64,
        stage: &str,
        engine: Option<usize>,
        dur_us: f64,
    ) {
        if let Some(t) = &self.trace {
            t.event(req, stage, engine, dur_us);
        }
    }
}
