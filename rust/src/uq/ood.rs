//! Out-of-distribution scoring from the epistemic half of the MC
//! uncertainty decomposition.
//!
//! For categorical MC predictions the mutual information
//! `MI = H(mean p) − mean H(p_s)` isolates *model* disagreement from
//! inherent class overlap: dropout samples that each commit confidently
//! but to different classes drive MI up, which is the signature of an
//! input the posterior has never seen (the paper's Gaussian-noise
//! entropy experiment, Sec. V-A2, reports the entropy analogue). The
//! scorer is fitted offline as a quantile of in-distribution MI scores;
//! serving marks anything above the threshold as OOD and the risk
//! policy abstains.

use crate::metrics::uncertainty_decomposition;

/// Epistemic-score OOD detector with a quantile-fitted threshold.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OodScorer {
    /// Scores above this are out-of-distribution.
    pub threshold: f64,
}

impl OodScorer {
    /// Fixed-threshold scorer (the CLI's `--max-epistemic`).
    pub fn with_threshold(threshold: f64) -> Self {
        Self { threshold }
    }

    /// Fit the threshold as the `quantile` (in [0, 1]) of in-distribution
    /// epistemic scores, e.g. 0.99 to flag the most model-uncertain 1%.
    pub fn fit(in_dist_scores: &[f64], quantile: f64) -> Self {
        assert!(
            !in_dist_scores.is_empty(),
            "OOD fit needs in-distribution scores"
        );
        assert!((0.0..=1.0).contains(&quantile), "quantile in [0,1]");
        let mut sorted = in_dist_scores.to_vec();
        sorted.sort_by(|a, b| {
            a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal)
        });
        let rank = ((quantile * sorted.len() as f64).ceil() as usize)
            .clamp(1, sorted.len());
        Self { threshold: sorted[rank - 1] }
    }

    /// Epistemic score of one request: mutual information of its MC
    /// sample distributions `probs` `[s][k]`.
    pub fn score(probs: &[f64], s: usize, k: usize) -> f64 {
        let (_, _, epistemic) = uncertainty_decomposition(probs, s, k);
        epistemic
    }

    pub fn is_ood(&self, score: f64) -> bool {
        score > self.threshold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disagreeing_samples_score_higher_than_agreeing() {
        // Two confident-but-contradictory samples vs two identical ones.
        let disagree = [1.0, 0.0, 0.0, 1.0];
        let agree = [0.7, 0.3, 0.7, 0.3];
        let hi = OodScorer::score(&disagree, 2, 2);
        let lo = OodScorer::score(&agree, 2, 2);
        assert!(hi > 0.6, "max MI for k=2 is ln2 ≈ 0.69, got {hi}");
        assert!(lo < 1e-9, "identical samples have zero MI, got {lo}");
    }

    #[test]
    fn quantile_fit_flags_the_tail() {
        // 99 small in-distribution scores + 1 large.
        let mut scores: Vec<f64> =
            (0..99).map(|i| 0.001 * i as f64).collect();
        scores.push(0.5);
        let scorer = OodScorer::fit(&scores, 0.95);
        assert!(scorer.threshold < 0.5);
        assert!(scorer.is_ood(0.5));
        assert!(!scorer.is_ood(0.01));

        // quantile 1.0 keeps everything in-distribution.
        let all = OodScorer::fit(&scores, 1.0);
        assert!(!all.is_ood(0.5));
        assert!(all.is_ood(0.6));
    }

    #[test]
    fn fixed_threshold_scorer() {
        let s = OodScorer::with_threshold(0.15);
        assert!(!s.is_ood(0.15));
        assert!(s.is_ood(0.150001));
    }
}
