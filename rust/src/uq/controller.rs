//! Adaptive Monte-Carlo controller: draw samples sequentially and stop
//! as soon as the predictive distribution has converged.
//!
//! S — the MC sample count — is the paper's dominant algorithmic knob:
//! latency and energy scale linearly in it (Sec. IV-C), yet a fixed S
//! spends the same budget on an unambiguous beat as on a borderline
//! one. The controller replaces fixed S with a *stopping rule*: after
//! `s` samples, the standard error of the running MC mean at output
//! point `i` is `σ̂_i / √s` (σ̂ the sample std), so the half-width of
//! the `z`-level confidence interval on the mean is
//!
//! ```text
//!     hw_i(s) = z · σ̂_i / √s
//! ```
//!
//! Sampling stops at the first `s ∈ [s_min, s_max]` with
//! `max_i hw_i(s) ≤ target_ci`; hitting `s_max` without convergence
//! marks the request unconverged (the risk policy defers or abstains).
//! `target_ci ≤ 0` disables early exit entirely — every request draws
//! exactly `s_max` samples, which is the determinism escape hatch the
//! fixed-S comparison tests rely on.
//!
//! Because every sample `k` is a pure function of
//! `(design_seed, request_seed, k)` ([`crate::fpga::accel::Accelerator::
//! predict_seeded`]), the sample *set* is identical whether drawn
//! eagerly, in chunks, or sharded across fleet engines; the
//! [`McAccumulator`] additionally fixes the *reduction order* (ascending
//! `k`) so the finalised mean/std is bit-identical across all of those
//! schedules.

use crate::metrics::pooled_mean_std;

/// Configuration of the sequential sampling envelope.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaptiveMcConfig {
    /// Samples always drawn before the stopping rule is consulted
    /// (a variance estimate needs at least 2; default 4).
    pub s_min: usize,
    /// Hard budget — the fixed-S equivalent and latency upper bound.
    pub s_max: usize,
    /// Target half-width of the confidence interval on the MC mean,
    /// in output units (probability for the classifier, reconstruction
    /// amplitude for the autoencoder). `<= 0` forces exactly `s_max`
    /// samples (no early exit).
    pub target_ci: f64,
    /// Confidence multiplier (1.96 ≈ 95% under the CLT normal approx).
    pub z: f64,
    /// Samples drawn per incremental round after `s_min`.
    pub chunk: usize,
}

impl Default for AdaptiveMcConfig {
    fn default() -> Self {
        Self { s_min: 4, s_max: 30, target_ci: 0.02, z: 1.96, chunk: 4 }
    }
}

impl AdaptiveMcConfig {
    /// Envelope with early exit disabled: always draws exactly `s`.
    pub fn fixed(s: usize) -> Self {
        Self {
            s_min: s,
            s_max: s,
            target_ci: 0.0,
            ..Self::default()
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.s_min == 0 || self.chunk == 0 {
            return Err("s_min and chunk must be positive".into());
        }
        if self.s_max < self.s_min {
            return Err(format!(
                "s_max {} < s_min {}",
                self.s_max, self.s_min
            ));
        }
        if self.z <= 0.0 {
            return Err("z must be positive".into());
        }
        Ok(())
    }
}

/// Per-chunk streaming escalation rule: should this chunk's decisions
/// be recomputed at the boosted budget `s_max`?
///
/// A streaming session keeps `s` resident lanes (its base budget); the
/// worker consults this rule on each chunk's pooled std. It is the
/// controller's stopping rule read in reverse: a CI half-width
/// `z·σ̂_max/√s` above `target_ci` means the base evidence did not
/// converge, so the worker replays lanes `s..s_max` and merges them in.
/// `target_ci <= 0` never boosts (the fixed-budget escape hatch), and a
/// budget already at `s_max` has nothing to escalate to.
pub fn stream_should_boost(
    std: &[f32],
    s: usize,
    cfg: &AdaptiveMcConfig,
) -> bool {
    if cfg.target_ci <= 0.0 || s == 0 || cfg.s_max <= s {
        return false;
    }
    if s < 2 {
        return true; // no variance estimate yet — escalate
    }
    let sem = (s as f64).sqrt();
    std.iter()
        .map(|&v| cfg.z * v as f64 / sem)
        .fold(0.0, f64::max)
        > cfg.target_ci
}

/// Order-stable accumulator of MC sample blocks.
///
/// Blocks may arrive out of order (fleet shards complete whenever their
/// engine does); `finalize` always reduces in ascending sample index, so
/// the result is independent of arrival order and of how the schedule
/// was chunked — the property behind the adaptive-vs-fixed bit-identity
/// test.
#[derive(Debug, Clone)]
pub struct McAccumulator {
    out_len: usize,
    /// `(start, samples)` with `samples.len() = count * out_len`.
    blocks: Vec<(usize, Vec<f32>)>,
    count: usize,
}

impl McAccumulator {
    pub fn new(out_len: usize) -> Self {
        assert!(out_len > 0, "output length must be positive");
        Self { out_len, blocks: Vec::new(), count: 0 }
    }

    pub fn out_len(&self) -> usize {
        self.out_len
    }

    /// Samples accumulated so far.
    pub fn count(&self) -> usize {
        self.count
    }

    /// Add the block of samples `start..start + len/out_len`.
    pub fn push_block(&mut self, start: usize, samples: Vec<f32>) {
        assert!(
            !samples.is_empty() && samples.len() % self.out_len == 0,
            "block must hold whole samples"
        );
        self.count += samples.len() / self.out_len;
        // Keep blocks sorted by start index (insertion point search —
        // block counts are tiny).
        let pos = self
            .blocks
            .iter()
            .position(|&(s, _)| s > start)
            .unwrap_or(self.blocks.len());
        self.blocks.insert(pos, (start, samples));
    }

    /// Per-point moment sums (Σx, Σx²) reduced in ascending sample
    /// order — the exact accumulation a single eager pass would do.
    pub fn moments(&self) -> (Vec<f64>, Vec<f64>) {
        let mut sum = vec![0f64; self.out_len];
        let mut sumsq = vec![0f64; self.out_len];
        for (_, samples) in &self.blocks {
            for row in samples.chunks_exact(self.out_len) {
                for (i, &x) in row.iter().enumerate() {
                    let v = x as f64;
                    sum[i] += v;
                    sumsq[i] += v * v;
                }
            }
        }
        (sum, sumsq)
    }

    /// All samples in ascending-`k` order, `[count][out_len]` row-major.
    pub fn samples_ordered(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.count * self.out_len);
        for (_, samples) in &self.blocks {
            out.extend_from_slice(samples);
        }
        out
    }

    /// Pooled per-point MC mean/std over everything accumulated.
    pub fn finalize(&self) -> (Vec<f32>, Vec<f32>) {
        assert!(self.count > 0, "finalize needs at least one sample");
        let (sum, sumsq) = self.moments();
        pooled_mean_std(&sum, &sumsq, self.count)
    }

    /// Worst-case (max over output points) CI half-width `z·σ̂/√s`.
    /// Infinite below 2 samples (no variance estimate).
    pub fn max_ci_halfwidth(&self, z: f64) -> f64 {
        if self.count < 2 {
            return f64::INFINITY;
        }
        let (_, std) = self.finalize();
        let sem = (self.count as f64).sqrt();
        std.iter()
            .map(|&s| z * s as f64 / sem)
            .fold(0.0, f64::max)
    }
}

/// What the controller wants next.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum McDecision {
    /// Draw `count` more samples starting at index `start`.
    Draw { start: usize, count: usize },
    /// Stop: the distribution converged under the stopping rule.
    Converged,
    /// Stop: `s_max` exhausted without convergence.
    Exhausted,
}

/// The sequential controller: owns the envelope and the accumulator.
#[derive(Debug, Clone)]
pub struct AdaptiveController {
    pub cfg: AdaptiveMcConfig,
    pub acc: McAccumulator,
}

impl AdaptiveController {
    pub fn new(cfg: AdaptiveMcConfig, out_len: usize) -> Self {
        cfg.validate().expect("invalid AdaptiveMcConfig");
        Self { cfg, acc: McAccumulator::new(out_len) }
    }

    /// Consult the stopping rule against the accumulated evidence.
    pub fn decision(&self) -> McDecision {
        let s = self.acc.count();
        if s < self.cfg.s_min {
            return McDecision::Draw {
                start: s,
                count: self.cfg.s_min - s,
            };
        }
        if self.cfg.target_ci > 0.0
            && self.acc.max_ci_halfwidth(self.cfg.z) <= self.cfg.target_ci
        {
            return McDecision::Converged;
        }
        if s >= self.cfg.s_max {
            return McDecision::Exhausted;
        }
        McDecision::Draw {
            start: s,
            count: self.cfg.chunk.min(self.cfg.s_max - s),
        }
    }

    /// Feed a drawn block back in.
    pub fn push_block(&mut self, start: usize, samples: Vec<f32>) {
        self.acc.push_block(start, samples);
    }

    /// True once `decision()` is a stop verdict.
    pub fn done(&self) -> bool {
        !matches!(self.decision(), McDecision::Draw { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::mc_mean_std;
    use crate::rng::Rng;

    #[test]
    fn accumulator_is_order_and_chunk_invariant() {
        let (s, n) = (12usize, 5usize);
        let mut rng = Rng::new(3);
        let samples: Vec<f32> =
            (0..s * n).map(|_| rng.normal() as f32).collect();

        // One eager block.
        let mut whole = McAccumulator::new(n);
        whole.push_block(0, samples.clone());
        let (wm, ws) = whole.finalize();

        // Same samples as out-of-order chunks.
        let mut chunked = McAccumulator::new(n);
        for (start, count) in [(8usize, 4usize), (0, 3), (3, 5)] {
            chunked.push_block(
                start,
                samples[start * n..(start + count) * n].to_vec(),
            );
        }
        assert_eq!(chunked.count(), s);
        assert_eq!(chunked.samples_ordered(), samples);
        let (cm, cs) = chunked.finalize();
        // Bit-identical, not approximately equal: same reduction order.
        assert_eq!(wm, cm);
        assert_eq!(ws, cs);

        // And both agree with the reference reducer numerically.
        let (rm, rs) = mc_mean_std(&samples, s, n);
        for i in 0..n {
            assert!((wm[i] - rm[i]).abs() < 1e-5);
            assert!((ws[i] - rs[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn halfwidth_shrinks_with_samples() {
        let mut rng = Rng::new(7);
        let n = 3;
        let mut acc = McAccumulator::new(n);
        assert_eq!(acc.max_ci_halfwidth(1.96), f64::INFINITY);
        let mut prev = f64::INFINITY;
        for round in 0..4 {
            let block: Vec<f32> =
                (0..16 * n).map(|_| rng.normal() as f32).collect();
            acc.push_block(round * 16, block);
            if round == 0 {
                prev = acc.max_ci_halfwidth(1.96);
                continue;
            }
            let hw = acc.max_ci_halfwidth(1.96);
            assert!(hw < prev, "round {round}: {hw} !< {prev}");
            prev = hw;
        }
    }

    #[test]
    fn controller_converges_within_envelope() {
        let cfg = AdaptiveMcConfig {
            s_min: 4,
            s_max: 64,
            target_ci: 0.5,
            z: 1.96,
            chunk: 4,
        };
        let mut ctl = AdaptiveController::new(cfg, 2);
        let mut rng = Rng::new(1);
        let mut drawn = 0usize;
        loop {
            match ctl.decision() {
                McDecision::Draw { start, count } => {
                    assert_eq!(start, drawn, "contiguous schedule");
                    let block: Vec<f32> = (0..count * 2)
                        .map(|_| 0.1 * rng.normal() as f32)
                        .collect();
                    ctl.push_block(start, block);
                    drawn += count;
                }
                McDecision::Converged => break,
                McDecision::Exhausted => {
                    panic!("σ=0.1 must converge before 64 at ci=0.5")
                }
            }
        }
        assert!(drawn >= cfg.s_min && drawn <= cfg.s_max);
        // σ=0.1: hw(4) = 1.96*0.1/2 ≈ 0.098 « 0.5 — converges at s_min.
        assert_eq!(drawn, cfg.s_min);
        assert!(ctl.done());
    }

    #[test]
    fn zero_target_ci_forces_s_max() {
        let cfg = AdaptiveMcConfig {
            s_min: 2,
            s_max: 9,
            target_ci: 0.0,
            z: 1.96,
            chunk: 4,
        };
        let mut ctl = AdaptiveController::new(cfg, 1);
        let mut drawn = 0usize;
        while let McDecision::Draw { start, count } = ctl.decision() {
            // Identical constant samples: would converge instantly if
            // early exit were allowed.
            ctl.push_block(start, vec![1.0f32; count]);
            drawn += count;
        }
        assert_eq!(drawn, 9, "no early exit at target_ci = 0");
        assert_eq!(ctl.decision(), McDecision::Exhausted);
    }

    #[test]
    fn high_variance_exhausts_budget() {
        let cfg = AdaptiveMcConfig {
            s_min: 2,
            s_max: 6,
            target_ci: 1e-9,
            z: 1.96,
            chunk: 2,
        };
        let mut ctl = AdaptiveController::new(cfg, 1);
        let mut rng = Rng::new(9);
        while let McDecision::Draw { start, count } = ctl.decision() {
            let block: Vec<f32> =
                (0..count).map(|_| rng.normal() as f32).collect();
            ctl.push_block(start, block);
        }
        assert_eq!(ctl.decision(), McDecision::Exhausted);
        assert_eq!(ctl.acc.count(), 6);
    }

    #[test]
    fn chunk_never_overshoots_s_max() {
        let cfg = AdaptiveMcConfig {
            s_min: 3,
            s_max: 10,
            target_ci: 1e-12,
            z: 1.96,
            chunk: 4,
        };
        let mut ctl = AdaptiveController::new(cfg, 1);
        let mut rng = Rng::new(2);
        let mut schedule = Vec::new();
        while let McDecision::Draw { start, count } = ctl.decision() {
            schedule.push((start, count));
            let block: Vec<f32> =
                (0..count).map(|_| rng.normal() as f32).collect();
            ctl.push_block(start, block);
        }
        assert_eq!(schedule, vec![(0, 3), (3, 4), (7, 3)]);
    }

    #[test]
    fn fixed_envelope_draws_exactly_s() {
        let cfg = AdaptiveMcConfig::fixed(5);
        assert!(cfg.validate().is_ok());
        let mut ctl = AdaptiveController::new(cfg, 1);
        match ctl.decision() {
            McDecision::Draw { start: 0, count: 5 } => {}
            d => panic!("expected one whole draw, got {d:?}"),
        }
        ctl.push_block(0, vec![0.0; 5]);
        assert_eq!(ctl.decision(), McDecision::Exhausted);
    }

    #[test]
    fn stream_boost_triggers_on_wide_intervals_only() {
        let cfg = AdaptiveMcConfig {
            s_min: 2,
            s_max: 16,
            target_ci: 0.1,
            z: 2.0,
            chunk: 4,
        };
        // hw = 2·0.3/√4 = 0.3 > 0.1 → escalate.
        assert!(stream_should_boost(&[0.01, 0.3], 4, &cfg));
        // hw = 2·0.01/√4 = 0.01 ≤ 0.1 → stay at base budget.
        assert!(!stream_should_boost(&[0.01, 0.01], 4, &cfg));
        // Already at the boosted budget — nothing to escalate to.
        assert!(!stream_should_boost(&[9.0], 16, &cfg));
        // target_ci = 0 is the fixed-budget escape hatch.
        let fixed = AdaptiveMcConfig { target_ci: 0.0, ..cfg };
        assert!(!stream_should_boost(&[9.0], 4, &fixed));
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(AdaptiveMcConfig {
            s_min: 0,
            ..AdaptiveMcConfig::default()
        }
        .validate()
        .is_err());
        assert!(AdaptiveMcConfig {
            s_min: 8,
            s_max: 4,
            ..AdaptiveMcConfig::default()
        }
        .validate()
        .is_err());
        assert!(AdaptiveMcConfig {
            chunk: 0,
            ..AdaptiveMcConfig::default()
        }
        .validate()
        .is_err());
    }
}
