//! Risk-tiered serving policy: map a request's uncertainty evidence to
//! an operational decision.
//!
//! The clinical deployment the paper motivates (and van der Westhuizen
//! & Lasenby's "Bayesian LSTMs in medicine" argues for explicitly) never
//! consumes a bare class label — it consumes a label *plus permission to
//! act on it*. The policy grades each served prediction into:
//!
//! * **Accept** — calibrated confidence is high, the MC distribution
//!   converged, epistemic uncertainty is in-distribution: safe to act.
//! * **Defer** — the prediction is usable but under-determined (didn't
//!   converge within `s_max`, or entropy above the defer line): queue
//!   for more samples / second-stage model / batch review.
//! * **Abstain** — the model should not be trusted at all: epistemic
//!   score above the OOD threshold or calibrated entropy above the
//!   abstain line. Route to a human.
//!
//! Thresholds are in nats of the *calibrated* predictive distribution
//! (temperature scaling first — an overconfident model would otherwise
//! sail through the entropy gates).

use super::calibrate::TemperatureScaler;
use super::ood::OodScorer;
use crate::metrics::{entropy, mc_mean_probs, uncertainty_decomposition};

/// The three serving tiers, ordered by escalation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RiskTier {
    Accept,
    Defer,
    Abstain,
}

impl RiskTier {
    pub fn as_str(&self) -> &'static str {
        match self {
            RiskTier::Accept => "accept",
            RiskTier::Defer => "defer",
            RiskTier::Abstain => "abstain",
        }
    }
}

/// Tiering thresholds + the fitted calibration/OOD maps.
#[derive(Debug, Clone, Copy)]
pub struct RiskPolicy {
    /// Calibrated predictive entropy (nats) above which the model
    /// abstains outright.
    pub abstain_entropy: f64,
    /// Calibrated predictive entropy above which an otherwise-healthy
    /// prediction is deferred.
    pub defer_entropy: f64,
    /// Epistemic (mutual-information) OOD gate.
    pub ood: OodScorer,
    /// Offline-fitted temperature map applied before the entropy gates.
    pub scaler: TemperatureScaler,
}

impl Default for RiskPolicy {
    fn default() -> Self {
        Self {
            abstain_entropy: 0.9,
            defer_entropy: 0.5,
            ood: OodScorer::with_threshold(0.15),
            scaler: TemperatureScaler::identity(),
        }
    }
}

/// The graded outcome for one request.
#[derive(Debug, Clone)]
pub struct TierDecision {
    pub tier: RiskTier,
    /// Calibrated MC-mean distribution the gates were evaluated on.
    pub calibrated: Vec<f64>,
    /// Entropy of the calibrated mean distribution (nats).
    pub entropy: f64,
    /// Mutual-information epistemic score.
    pub epistemic: f64,
    /// Mean per-sample entropy (aleatoric component, nats).
    pub aleatoric: f64,
    /// Whether the epistemic gate fired.
    pub ood: bool,
}

impl RiskPolicy {
    /// Grade one classification request from its raw MC sample
    /// distributions `probs` `[s][k]`. `converged` is the adaptive
    /// controller's verdict (fixed-S callers pass `true`).
    pub fn classify(
        &self,
        probs: &[f64],
        s: usize,
        k: usize,
        converged: bool,
    ) -> TierDecision {
        assert!(s > 0 && k > 0);
        assert_eq!(probs.len(), s * k);
        // Epistemic/aleatoric split on the *raw* samples: calibration
        // rescales confidence, but model disagreement is a property of
        // the uncalibrated posterior draws. (The epistemic term is the
        // same mutual information `OodScorer::score` computes.)
        let (_, aleatoric, epistemic) =
            uncertainty_decomposition(probs, s, k);
        let mut calibrated = mc_mean_probs(probs, s, k);
        self.scaler.apply_row(&mut calibrated);
        let h = entropy(&calibrated);
        let ood = self.ood.is_ood(epistemic);
        let tier = if ood || h > self.abstain_entropy {
            RiskTier::Abstain
        } else if !converged || h > self.defer_entropy {
            RiskTier::Defer
        } else {
            RiskTier::Accept
        };
        TierDecision {
            tier,
            calibrated,
            entropy: h,
            epistemic,
            aleatoric,
            ood,
        }
    }

    /// Grade a regression (autoencoder) request from its MC mean/std:
    /// the entropy gates read the mean per-point epistemic std instead
    /// of entropy (same units as the reconstruction), the OOD gate reads
    /// the max per-point std.
    pub fn grade_regression(
        &self,
        std: &[f32],
        converged: bool,
    ) -> RiskTier {
        assert!(!std.is_empty());
        let mean_std = std.iter().map(|&v| v as f64).sum::<f64>()
            / std.len() as f64;
        let max_std =
            std.iter().map(|&v| v as f64).fold(0.0, f64::max);
        if self.ood.is_ood(max_std) || mean_std > self.abstain_entropy {
            RiskTier::Abstain
        } else if !converged || mean_std > self.defer_entropy {
            RiskTier::Defer
        } else {
            RiskTier::Accept
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> RiskPolicy {
        RiskPolicy {
            abstain_entropy: 0.9,
            defer_entropy: 0.5,
            ood: OodScorer::with_threshold(0.3),
            scaler: TemperatureScaler::identity(),
        }
    }

    #[test]
    fn confident_converged_prediction_accepts() {
        // 3 near-identical confident samples.
        let probs = [
            0.97, 0.01, 0.01, 0.01, //
            0.96, 0.02, 0.01, 0.01, //
            0.97, 0.01, 0.01, 0.01,
        ];
        let d = policy().classify(&probs, 3, 4, true);
        assert_eq!(d.tier, RiskTier::Accept);
        assert!(!d.ood);
        assert!(d.entropy < 0.5);
        assert!((d.calibrated.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn unconverged_prediction_defers() {
        let probs = [0.97, 0.01, 0.01, 0.01, 0.97, 0.01, 0.01, 0.01];
        let d = policy().classify(&probs, 2, 4, false);
        assert_eq!(d.tier, RiskTier::Defer);
    }

    #[test]
    fn ambiguous_prediction_defers_then_abstains() {
        // Entropy between defer and abstain lines: ~0.69 nats for a
        // clean two-way split over k=4.
        let two_way = [0.5, 0.5, 0.0, 0.0, 0.5, 0.5, 0.0, 0.0];
        let d = policy().classify(&two_way, 2, 4, true);
        assert!((d.entropy - (2f64).ln()).abs() < 1e-9);
        assert_eq!(d.tier, RiskTier::Defer);
        assert!(d.epistemic < 1e-9, "identical samples: no epistemic");

        // Near-uniform: entropy ≈ ln 4 ≈ 1.39 > abstain line.
        let uniform = [0.25; 8];
        let d = policy().classify(&uniform, 2, 4, true);
        assert_eq!(d.tier, RiskTier::Abstain);
        assert!(!d.ood, "aleatoric abstain, not epistemic");
    }

    #[test]
    fn epistemic_disagreement_abstains_via_ood_gate() {
        // Confident but contradictory: MI ≈ ln 2 ≈ 0.69 > 0.3 threshold.
        let probs = [1.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0];
        let d = policy().classify(&probs, 2, 4, true);
        assert_eq!(d.tier, RiskTier::Abstain);
        assert!(d.ood);
        assert!(d.aleatoric < 1e-9);
        assert!(d.epistemic > 0.6);
    }

    #[test]
    fn calibration_moves_the_entropy_gate() {
        // Overconfident prediction that a hot temperature flattens past
        // the defer line.
        let probs = [0.8, 0.2 / 3.0, 0.2 / 3.0, 0.2 / 3.0];
        let cool = policy().classify(&probs, 1, 4, true);
        assert_eq!(cool.tier, RiskTier::Defer, "H ≈ 0.72 nats raw");

        let mut hot = policy();
        hot.scaler = TemperatureScaler { temperature: 4.0 };
        let d = hot.classify(&probs, 1, 4, true);
        assert!(d.entropy > cool.entropy);
        assert_eq!(d.tier, RiskTier::Abstain);
    }

    #[test]
    fn regression_grading_uses_std() {
        let p = policy();
        assert_eq!(
            p.grade_regression(&[0.01, 0.02, 0.01], true),
            RiskTier::Accept
        );
        assert_eq!(
            p.grade_regression(&[0.01, 0.02, 0.01], false),
            RiskTier::Defer
        );
        assert_eq!(
            p.grade_regression(&[0.6, 0.7, 0.6], true),
            RiskTier::Abstain
        );
    }
}
