//! Aggregation of per-request adaptive-UQ outcomes into the one-line
//! JSON report shared by `repro uq --json`, `repro serve --adaptive-mc
//! --json` and the `adaptive_mc` bench scenario.

use super::policy::RiskTier;
use crate::jsonio::{self, Json};

/// Per-tier request counts.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierCounts {
    pub accept: usize,
    pub defer: usize,
    pub abstain: usize,
}

impl TierCounts {
    pub fn record(&mut self, tier: RiskTier) {
        match tier {
            RiskTier::Accept => self.accept += 1,
            RiskTier::Defer => self.defer += 1,
            RiskTier::Abstain => self.abstain += 1,
        }
    }

    pub fn total(&self) -> usize {
        self.accept + self.defer + self.abstain
    }

    pub fn to_json(&self) -> Json {
        jsonio::obj(vec![
            ("accept", Json::Num(self.accept as f64)),
            ("defer", Json::Num(self.defer as f64)),
            ("abstain", Json::Num(self.abstain as f64)),
        ])
    }
}

/// Streaming collector: feed one record per served request.
#[derive(Debug, Clone, Default)]
pub struct UqCollector {
    samples_used: Vec<usize>,
    /// Sequential sampling rounds per request, when the serving path
    /// reports them (the fleet's adaptive coordinator does; the bare
    /// accelerator path does not).
    rounds: Vec<usize>,
    converged: usize,
    pub tiers: TierCounts,
}

impl UqCollector {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(
        &mut self,
        samples_used: usize,
        converged: bool,
        tier: RiskTier,
    ) {
        self.samples_used.push(samples_used);
        if converged {
            self.converged += 1;
        }
        self.tiers.record(tier);
    }

    /// Record one request's sequential round count (optional —
    /// call alongside `record` when the serving path exposes it).
    pub fn record_rounds(&mut self, rounds: usize) {
        self.rounds.push(rounds);
    }

    pub fn requests(&self) -> usize {
        self.samples_used.len()
    }

    pub fn mean_samples(&self) -> f64 {
        if self.samples_used.is_empty() {
            return 0.0;
        }
        self.samples_used.iter().sum::<usize>() as f64
            / self.samples_used.len() as f64
    }

    pub fn mean_rounds(&self) -> f64 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        self.rounds.iter().sum::<usize>() as f64 / self.rounds.len() as f64
    }

    /// Total MC sample rows actually drawn across all recorded requests
    /// (the absolute counterpart of `samples_saved_pct`; matches the
    /// fleet's `obs` spent counter when every request is recorded).
    pub fn samples_spent(&self) -> usize {
        self.samples_used.iter().sum()
    }

    /// Finalise against the fixed-S budget the adaptive run replaced.
    pub fn finish(&self, s_max: usize) -> UqReport {
        let n = self.requests();
        let mean = self.mean_samples();
        let saved = if s_max > 0 && n > 0 {
            (1.0 - mean / s_max as f64) * 100.0
        } else {
            0.0
        };
        let spent = self.samples_spent();
        UqReport {
            requests: n,
            s_max,
            mean_samples: mean,
            samples_saved_pct: saved,
            samples_spent: spent,
            samples_saved: (s_max * n).saturating_sub(spent),
            mean_rounds: self.mean_rounds(),
            converged: self.converged,
            tiers: self.tiers,
        }
    }
}

/// The finalised adaptive-UQ summary.
#[derive(Debug, Clone, PartialEq)]
pub struct UqReport {
    pub requests: usize,
    /// The fixed-S budget the controller was capped at.
    pub s_max: usize,
    pub mean_samples: f64,
    /// `(1 − mean_samples / s_max) · 100` — the headline win.
    pub samples_saved_pct: f64,
    /// Absolute MC sample rows drawn (sum over requests).
    pub samples_spent: usize,
    /// Absolute rows avoided vs the fixed-S budget:
    /// `s_max · requests − samples_spent`.
    pub samples_saved: usize,
    /// Mean sequential sampling rounds per request (0 when the serving
    /// path did not report rounds).
    pub mean_rounds: f64,
    /// Requests whose CI converged before `s_max`.
    pub converged: usize,
    pub tiers: TierCounts,
}

impl UqReport {
    /// One-line JSON (bench-harness consumable).
    pub fn to_json(&self) -> Json {
        jsonio::obj(vec![
            ("requests", Json::Num(self.requests as f64)),
            ("s_max", Json::Num(self.s_max as f64)),
            ("mean_samples", Json::Num(self.mean_samples)),
            ("samples_saved_pct", Json::Num(self.samples_saved_pct)),
            ("samples_spent", Json::Num(self.samples_spent as f64)),
            ("samples_saved", Json::Num(self.samples_saved as f64)),
            ("mean_rounds", Json::Num(self.mean_rounds)),
            ("converged", Json::Num(self.converged as f64)),
            ("tiers", self.tiers.to_json()),
        ])
    }

    pub fn to_json_line(&self) -> String {
        jsonio::write(&self.to_json())
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let num = |key: &str| -> anyhow::Result<f64> {
            j.get(key).and_then(Json::as_f64).ok_or_else(|| {
                anyhow::anyhow!("report missing numeric field {key:?}")
            })
        };
        let tiers = j
            .get("tiers")
            .ok_or_else(|| anyhow::anyhow!("report missing \"tiers\""))?;
        let tier = |key: &str| -> anyhow::Result<usize> {
            tiers.get(key).and_then(Json::as_usize).ok_or_else(|| {
                anyhow::anyhow!("tiers missing field {key:?}")
            })
        };
        let requests = num("requests")? as usize;
        let s_max = num("s_max")? as usize;
        let mean_samples = num("mean_samples")?;
        // Optional: reports written before absolute totals were tracked
        // derive them from the mean (exact when the mean was exact).
        let samples_spent = j
            .get("samples_spent")
            .and_then(Json::as_usize)
            .unwrap_or_else(|| {
                (mean_samples * requests as f64).round() as usize
            });
        let samples_saved = j
            .get("samples_saved")
            .and_then(Json::as_usize)
            .unwrap_or_else(|| {
                (s_max * requests).saturating_sub(samples_spent)
            });
        Ok(Self {
            requests,
            s_max,
            mean_samples,
            samples_saved_pct: num("samples_saved_pct")?,
            samples_spent,
            samples_saved,
            // Optional: reports written before rounds were tracked.
            mean_rounds: j
                .get("mean_rounds")
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
            converged: num("converged")? as usize,
            tiers: TierCounts {
                accept: tier("accept")?,
                defer: tier("defer")?,
                abstain: tier("abstain")?,
            },
        })
    }

    /// Multi-line human rendering for the CLI's non-JSON mode.
    pub fn render(&self) -> String {
        let rounds = if self.mean_rounds > 0.0 {
            format!(
                "\n\x20 mean rounds/request   {:.2}",
                self.mean_rounds
            )
        } else {
            String::new()
        };
        format!(
            "adaptive MC over {} requests (S_max = {}):\n\
             \x20 mean samples/request  {:.2}  ({:.1}% saved vs fixed S)\
             {}\n\
             \x20 samples spent/saved   {} / {}\n\
             \x20 converged             {} / {}\n\
             \x20 tiers                 accept {}  defer {}  abstain {}",
            self.requests,
            self.s_max,
            self.mean_samples,
            self.samples_saved_pct,
            rounds,
            self.samples_spent,
            self.samples_saved,
            self.converged,
            self.requests,
            self.tiers.accept,
            self.tiers.defer,
            self.tiers.abstain,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collector_aggregates_and_reports() {
        let mut c = UqCollector::new();
        c.record(4, true, RiskTier::Accept);
        c.record(8, true, RiskTier::Accept);
        c.record(24, false, RiskTier::Defer);
        c.record(12, true, RiskTier::Abstain);
        let r = c.finish(24);
        assert_eq!(r.requests, 4);
        assert_eq!(r.converged, 3);
        assert!((r.mean_samples - 12.0).abs() < 1e-9);
        assert!((r.samples_saved_pct - 50.0).abs() < 1e-9);
        assert_eq!(r.samples_spent, 48);
        assert_eq!(r.samples_saved, 48);
        assert_eq!(
            r.tiers,
            TierCounts { accept: 2, defer: 1, abstain: 1 }
        );
        assert_eq!(r.tiers.total(), 4);
    }

    #[test]
    fn report_json_roundtrip() {
        let mut c = UqCollector::new();
        c.record(6, true, RiskTier::Accept);
        c.record(24, false, RiskTier::Defer);
        let r = c.finish(24);
        let line = r.to_json_line();
        let parsed = jsonio::parse(&line).expect("valid JSON");
        let back = UqReport::from_json(&parsed).expect("roundtrip");
        assert_eq!(back, r);
        // Required bench fields present by name.
        for key in [
            "mean_samples",
            "samples_saved_pct",
            "samples_spent",
            "samples_saved",
            "tiers",
        ] {
            assert!(parsed.get(key).is_some(), "missing {key}");
        }
    }

    #[test]
    fn from_json_derives_totals_for_old_reports() {
        // Reports written before absolute totals existed.
        let line = "{\"requests\":2,\"s_max\":24,\"mean_samples\":15,\
                    \"samples_saved_pct\":37.5,\"converged\":1,\
                    \"tiers\":{\"accept\":1,\"defer\":1,\"abstain\":0}}";
        let r = UqReport::from_json(&jsonio::parse(line).unwrap())
            .expect("old report parses");
        assert_eq!(r.samples_spent, 30);
        assert_eq!(r.samples_saved, 18);
    }

    #[test]
    fn empty_collector_is_well_defined() {
        let r = UqCollector::new().finish(30);
        assert_eq!(r.requests, 0);
        assert_eq!(r.mean_samples, 0.0);
        assert_eq!(r.samples_saved_pct, 0.0);
    }
}
