//! Adaptive uncertainty quantification — the runtime layer that turns
//! the paper's S Monte-Carlo passes from a fixed cost into a controlled
//! budget, and its uncertainty estimates from reported numbers into
//! serving decisions.
//!
//! Four cooperating pieces (math and semantics in
//! `docs/uncertainty.md`):
//!
//! * [`controller`] — sequential MC sampling with a confidence-interval
//!   stopping rule inside a hard `[s_min, s_max]` envelope, plus the
//!   order-stable sample accumulator that keeps adaptive, eager and
//!   fleet-sharded schedules bit-identical.
//! * [`calibrate`] — offline temperature scaling fitted by NLL descent,
//!   applied before any entropy threshold is consulted.
//! * [`ood`] — max-epistemic (mutual-information) out-of-distribution
//!   scoring with a quantile-fitted threshold.
//! * [`policy`] — the accept / defer / abstain risk tiers.
//! * [`report`] — per-run aggregation into the one-line JSON consumed
//!   by the `adaptive_mc` bench scenario.
//!
//! Entry points: [`crate::fpga::accel::Accelerator::predict_adaptive`]
//! (single engine), [`crate::coordinator::Fleet::submit_adaptive`] /
//! [`crate::coordinator::Fleet::wait_adaptive`] (fleet), `repro uq`
//! and `repro serve --adaptive-mc` (CLI).

pub mod calibrate;
pub mod controller;
pub mod ood;
pub mod policy;
pub mod report;

pub use calibrate::TemperatureScaler;
pub use controller::{
    stream_should_boost, AdaptiveController, AdaptiveMcConfig,
    McAccumulator, McDecision,
};
pub use ood::OodScorer;
pub use policy::{RiskPolicy, RiskTier, TierDecision};
pub use report::{TierCounts, UqCollector, UqReport};
