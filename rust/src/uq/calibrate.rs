//! Temperature scaling (Guo et al. 2017) fitted offline on held-out
//! MC-mean predictions — the single-parameter calibration map the risk
//! policy consumes.
//!
//! The serving path only sees *probabilities* (the classifier head
//! softmaxes on-device), so scaling happens in log space:
//!
//! ```text
//!     q_i ∝ p_i^(1/T)        (softmax(log p / T))
//! ```
//!
//! which is exactly logit temperature scaling for any distribution that
//! came out of a softmax. `T > 1` flattens an overconfident model,
//! `T < 1` sharpens an underconfident one, `T = 1` is the identity. The
//! fit minimises NLL of the scaled distributions with a golden-section
//! search over `log T` — NLL is convex in `log T` for this family, so
//! the 1-D search is exact to tolerance.

use crate::jsonio::{self, Json};
use crate::metrics::expected_calibration_error;

/// A fitted temperature-scaling map.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TemperatureScaler {
    pub temperature: f64,
}

impl Default for TemperatureScaler {
    fn default() -> Self {
        Self::identity()
    }
}

impl TemperatureScaler {
    /// The no-op calibration (`T = 1`).
    pub fn identity() -> Self {
        Self { temperature: 1.0 }
    }

    /// Fit `T` on MC-mean distributions `probs` `[n][k]` against labels
    /// by NLL minimisation over `log T ∈ [ln 0.05, ln 20]`.
    pub fn fit(probs: &[f64], labels: &[u8], k: usize) -> Self {
        assert!(k > 0 && !labels.is_empty(), "calibration needs data");
        assert_eq!(probs.len(), labels.len() * k);
        let nll_at = |log_t: f64| -> f64 {
            Self { temperature: log_t.exp() }.nll(probs, labels, k)
        };
        // Golden-section search on the convex 1-D objective.
        let phi = (5f64.sqrt() - 1.0) / 2.0;
        let (mut lo, mut hi) = (0.05f64.ln(), 20f64.ln());
        let mut x1 = hi - phi * (hi - lo);
        let mut x2 = lo + phi * (hi - lo);
        let (mut f1, mut f2) = (nll_at(x1), nll_at(x2));
        while hi - lo > 1e-4 {
            if f1 <= f2 {
                hi = x2;
                x2 = x1;
                f2 = f1;
                x1 = hi - phi * (hi - lo);
                f1 = nll_at(x1);
            } else {
                lo = x1;
                x1 = x2;
                f1 = f2;
                x2 = lo + phi * (hi - lo);
                f2 = nll_at(x2);
            }
        }
        Self { temperature: ((lo + hi) / 2.0).exp() }
    }

    /// Scale one distribution row in place (`p_i^(1/T)`, renormalised).
    pub fn apply_row(&self, row: &mut [f64]) {
        if (self.temperature - 1.0).abs() < 1e-12 {
            return;
        }
        let inv_t = 1.0 / self.temperature;
        let mut sum = 0.0;
        for p in row.iter_mut() {
            *p = p.max(1e-300).powf(inv_t);
            sum += *p;
        }
        for p in row.iter_mut() {
            *p /= sum;
        }
    }

    /// Scale `[n][k]` distributions, returning the calibrated copy.
    pub fn apply(&self, probs: &[f64], k: usize) -> Vec<f64> {
        let mut out = probs.to_vec();
        for row in out.chunks_exact_mut(k) {
            self.apply_row(row);
        }
        out
    }

    /// Mean NLL of the labels under the scaled distributions.
    pub fn nll(&self, probs: &[f64], labels: &[u8], k: usize) -> f64 {
        let n = labels.len();
        let mut total = 0.0;
        for (i, &y) in labels.iter().enumerate() {
            let mut row = probs[i * k..(i + 1) * k].to_vec();
            self.apply_row(&mut row);
            total -= row[y as usize].max(1e-300).ln();
        }
        total / n as f64
    }

    /// ECE of the scaled distributions (15 bins, the common default).
    pub fn ece(&self, probs: &[f64], labels: &[u8], k: usize) -> f64 {
        expected_calibration_error(
            &self.apply(probs, k),
            labels,
            k,
            15,
        )
    }

    /// Serialise as a single-line JSON object.
    pub fn to_json(&self) -> String {
        jsonio::write(&jsonio::obj(vec![(
            "temperature",
            Json::Num(self.temperature),
        )]))
    }

    /// Parse the object written by [`TemperatureScaler::to_json`].
    pub fn from_json(text: &str) -> anyhow::Result<Self> {
        let j = jsonio::parse(text)?;
        let t = j
            .get("temperature")
            .and_then(Json::as_f64)
            .ok_or_else(|| {
                anyhow::anyhow!("calibration JSON missing \"temperature\"")
            })?;
        anyhow::ensure!(
            t.is_finite() && t > 0.0,
            "temperature must be positive, got {t}"
        );
        Ok(Self { temperature: t })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Overconfident synthetic model: says 0.9 but is right 60% of the
    /// time. The fitted temperature must flatten (T > 1) and both NLL
    /// and ECE must improve.
    #[test]
    fn fit_flattens_overconfident_model() {
        let k = 2;
        let n = 200;
        let mut probs = Vec::with_capacity(n * k);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            probs.extend_from_slice(&[0.9, 0.1]);
            labels.push(if i % 5 < 3 { 0u8 } else { 1 }); // 60% class 0
        }
        let scaler = TemperatureScaler::fit(&probs, &labels, k);
        assert!(
            scaler.temperature > 1.5,
            "overconfident ⇒ T > 1, got {}",
            scaler.temperature
        );
        let id = TemperatureScaler::identity();
        assert!(scaler.nll(&probs, &labels, k) < id.nll(&probs, &labels, k));
        assert!(scaler.ece(&probs, &labels, k) < id.ece(&probs, &labels, k));
    }

    #[test]
    fn fit_sharpens_underconfident_model() {
        // Always right but only 60% confident: T < 1 sharpens.
        let k = 2;
        let n = 100;
        let mut probs = Vec::with_capacity(n * k);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            probs.extend_from_slice(&[0.6, 0.4]);
            labels.push(0u8);
        }
        let scaler = TemperatureScaler::fit(&probs, &labels, k);
        assert!(
            scaler.temperature < 0.5,
            "underconfident ⇒ T < 1, got {}",
            scaler.temperature
        );
    }

    #[test]
    fn identity_preserves_rows_and_argmax_invariant() {
        let id = TemperatureScaler::identity();
        let probs = [0.7, 0.2, 0.1];
        let mut row = probs.to_vec();
        id.apply_row(&mut row);
        assert_eq!(row, probs.to_vec());

        // Any temperature preserves the argmax (monotone map).
        let hot = TemperatureScaler { temperature: 3.0 };
        let mut r2 = probs.to_vec();
        hot.apply_row(&mut r2);
        assert!((r2.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(r2[0] > r2[1] && r2[1] > r2[2]);
        // Flattened towards uniform.
        assert!(r2[0] < probs[0]);
    }

    #[test]
    fn json_roundtrip() {
        let s = TemperatureScaler { temperature: 1.75 };
        let back = TemperatureScaler::from_json(&s.to_json()).unwrap();
        assert!((back.temperature - 1.75).abs() < 1e-9);
        assert!(TemperatureScaler::from_json("{}").is_err());
        assert!(
            TemperatureScaler::from_json("{\"temperature\":-1}").is_err()
        );
    }
}
