//! Training + evaluation drivers.
//!
//! * [`native`]: the paper's training recipe (Adam, batch 64, grad-clip
//!   3.0, wd 1e-4, MCD masks resampled per batch) on the native engine —
//!   used by the DSE sweep, which benchmarks dozens of architecture
//!   points.
//! * [`pjrt`]: the same train step executed through the AOT HLO artifact
//!   on PJRT — the L2-fwd/bwd path, cross-checked against `native` in
//!   `rust/tests/`.
//! * [`eval`]: MC-dropout prediction + the paper's metric battery for
//!   both tasks, generic over any predictor (float model, fixed-point
//!   accelerator, PJRT executable).
//! * [`sweep`]: populates the DSE lookup table (Figs. 8/9).

pub mod eval;
pub mod native;
pub mod pjrt;
pub mod sweep;

pub use eval::{AnomalyReport, ClassifyReport, Predictor};
pub use native::{NativeTrainer, TrainOpts};
pub use pjrt::PjrtTrainer;
