//! Native training loop — the paper's recipe (Sec. V: batch 64, gradient
//! clipping 3.0, weight decay 1e-4; epochs configurable, the paper uses
//! 1000 and we default lower for minutes-scale sweeps, see DESIGN.md
//! §Substitutions). MCD masks are resampled once per batch, matching
//! Gal & Ghahramani's variational interpretation.

use crate::config::ArchConfig;
#[cfg(test)]
use crate::config::Task;
use crate::data::Dataset;
use crate::nn::model::{Masks, Model};
use crate::nn::{AdamHp, AdamState};
use crate::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub struct TrainOpts {
    pub epochs: usize,
    pub batch: usize,
    pub lr: f32,
    pub seed: u64,
}

impl Default for TrainOpts {
    fn default() -> Self {
        Self { epochs: 60, batch: 64, lr: 5e-3, seed: 0 }
    }
}

pub struct NativeTrainer {
    pub model: Model,
    pub opts: TrainOpts,
    pub loss_history: Vec<f32>,
    state: AdamState,
    hp: AdamHp,
    rng: Rng,
}

impl NativeTrainer {
    pub fn new(cfg: ArchConfig, opts: TrainOpts) -> Self {
        let mut rng = Rng::new(opts.seed);
        let model = Model::init(cfg, &mut rng);
        let state = AdamState::new(&model.params);
        let hp = AdamHp { lr: opts.lr, ..Default::default() };
        Self { model, opts, loss_history: Vec::new(), state, hp, rng }
    }

    /// Train on a dataset. For the anomaly task the caller passes the
    /// normal-only training split (Sec. V-A1).
    pub fn fit(&mut self, data: &Dataset) -> &mut Self {
        let cfg = self.model.cfg.clone();
        let b = self.opts.batch.min(data.n);
        let steps_per_epoch = data.n.div_ceil(b);
        let mut order: Vec<usize> = (0..data.n).collect();
        for _epoch in 0..self.opts.epochs {
            // Fisher-Yates shuffle.
            for i in (1..order.len()).rev() {
                let j = self.rng.below(i + 1);
                order.swap(i, j);
            }
            let mut epoch_loss = 0.0;
            for s in 0..steps_per_epoch {
                let idx: Vec<usize> = (0..b)
                    .map(|k| order[(s * b + k) % data.n])
                    .collect();
                let batch = data.subset(&idx);
                let masks = Masks::sample(&cfg, b, &mut self.rng);
                let loss = self.model.train_step(
                    &self.hp,
                    &mut self.state,
                    &batch.x,
                    &batch.y,
                    &masks,
                );
                epoch_loss += loss;
            }
            self.loss_history.push(epoch_loss / steps_per_epoch as f32);
        }
        self
    }

    pub fn final_loss(&self) -> f32 {
        *self.loss_history.last().unwrap_or(&f32::NAN)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;

    #[test]
    fn classifier_learns_ecg() {
        let cfg = ArchConfig::new(Task::Classify, 8, 1, "N");
        let train = data::generate(96, 1);
        let mut t = NativeTrainer::new(
            cfg,
            TrainOpts { epochs: 12, batch: 32, lr: 5e-3, seed: 0 },
        );
        t.fit(&train);
        let first = t.loss_history[0];
        let last = t.final_loss();
        assert!(last < first * 0.8, "CE {first} -> {last}");
    }

    #[test]
    fn autoencoder_loss_decreases_on_normal_beats() {
        // The repeated-embedding LSTM autoencoder converges slowly (the
        // paper trains 1000 epochs); at unit-test scale we assert steady
        // progress, while eval::tests asserts the thing that matters —
        // that even a briefly-trained AE separates anomalies (AUC > 0.8).
        let cfg = ArchConfig::new(Task::Anomaly, 16, 1, "NN");
        let (train, _) = data::anomaly_splits(0);
        let small = train.subset(&(0..96.min(train.n)).collect::<Vec<_>>());
        let mut t = NativeTrainer::new(
            cfg,
            TrainOpts { epochs: 60, batch: 32, lr: 1e-2, seed: 0 },
        );
        t.fit(&small);
        let first = t.loss_history[0];
        let last = t.final_loss();
        assert!(last < first * 0.97, "no progress: {first} -> {last}");
        // Later epochs should on average beat early epochs.
        let early: f32 = t.loss_history[..10].iter().sum::<f32>() / 10.0;
        let late: f32 =
            t.loss_history[50..].iter().sum::<f32>() / 10.0;
        assert!(late < early, "early {early} late {late}");
    }

    #[test]
    fn bayesian_training_converges_too() {
        let cfg = ArchConfig::new(Task::Classify, 8, 2, "YN");
        let train = data::generate(64, 2);
        let mut t = NativeTrainer::new(
            cfg,
            TrainOpts { epochs: 10, batch: 32, lr: 5e-3, seed: 3 },
        );
        t.fit(&train);
        assert!(t.final_loss() < t.loss_history[0]);
        assert_eq!(t.loss_history.len(), 10);
    }
}
