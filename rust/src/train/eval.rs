//! MC-dropout evaluation: the paper's metric battery for both tasks,
//! generic over any predictor so the *same* evaluation code scores the
//! float model, the fixed-point accelerator and the PJRT executable
//! (Tables I/II compare exactly these).

use crate::config::Task;
#[cfg(test)]
use crate::config::ArchConfig;
use crate::data::Dataset;
use crate::fpga::accel::{Accelerator, McOutput};
use crate::metrics;
use crate::nn::model::{Masks, Model};
use crate::rng::Rng;

/// Anything that can produce S MC samples for one beat.
pub trait Predictor {
    fn predict(&mut self, beat: &[f32], s: usize) -> McOutput;
    fn task(&self) -> Task;
}

/// Float-engine predictor with software mask sampling.
pub struct ModelPredictor<'a> {
    pub model: &'a Model,
    pub rng: Rng,
}

impl<'a> ModelPredictor<'a> {
    pub fn new(model: &'a Model, seed: u64) -> Self {
        Self { model, rng: Rng::new(seed) }
    }
}

impl<'a> Predictor for ModelPredictor<'a> {
    fn predict(&mut self, beat: &[f32], s: usize) -> McOutput {
        let cfg = &self.model.cfg;
        // Fold the S samples into the row dimension: replicate the beat,
        // sample per-row masks (exactly what the AOT fwd artifact does).
        let mut xs = Vec::with_capacity(s * beat.len());
        for _ in 0..s {
            xs.extend_from_slice(beat);
        }
        let masks = if cfg.is_bayesian() {
            Masks::sample(cfg, s, &mut self.rng)
        } else {
            Masks::ones(cfg, s)
        };
        let out = self.model.forward(&xs, s, &masks);
        let out_len = out.len() / s;
        McOutput { samples: out, s, out_len }
    }

    fn task(&self) -> Task {
        self.model.cfg.task
    }
}

impl Predictor for Accelerator {
    fn predict(&mut self, beat: &[f32], s: usize) -> McOutput {
        Accelerator::predict(self, beat, s)
    }

    fn task(&self) -> Task {
        self.cfg.task
    }
}

/// Anomaly-detection evaluation (Sec. V-A1): score = RMSE of the MC-mean
/// reconstruction; labels = beat is anomalous.
#[derive(Debug, Clone)]
pub struct AnomalyReport {
    pub auc: f64,
    pub ap: f64,
    pub accuracy: f64,
    pub mean_rmse_normal: f64,
    pub mean_rmse_anomalous: f64,
    /// (score, is_anomalous) pairs for ROC plotting (Fig. 8).
    pub scores: Vec<(f64, bool)>,
}

pub fn eval_anomaly(
    pred: &mut dyn Predictor,
    test: &Dataset,
    s: usize,
) -> AnomalyReport {
    assert_eq!(pred.task(), Task::Anomaly);
    let mut scores = Vec::with_capacity(test.n);
    let mut labels = Vec::with_capacity(test.n);
    let (mut rn, mut cn, mut ra, mut ca) = (0.0, 0usize, 0.0, 0usize);
    for i in 0..test.n {
        let beat = test.beat(i);
        let out = pred.predict(beat, s);
        let mean = out.mean();
        let rmse = metrics::rmse(&mean, beat);
        let anom = test.label(i) != 0;
        scores.push(rmse);
        labels.push(anom);
        if anom {
            ra += rmse;
            ca += 1;
        } else {
            rn += rmse;
            cn += 1;
        }
    }
    AnomalyReport {
        auc: metrics::auc(&scores, &labels),
        ap: metrics::average_precision(&scores, &labels),
        accuracy: metrics::accuracy_at_optimal_cutoff(&scores, &labels),
        mean_rmse_normal: rn / cn.max(1) as f64,
        mean_rmse_anomalous: ra / ca.max(1) as f64,
        scores: scores.into_iter().zip(labels).collect(),
    }
}

/// Classification evaluation (Sec. V-A2): accuracy, macro AP, macro
/// recall on the test set; predictive entropy on Gaussian noise.
#[derive(Debug, Clone)]
pub struct ClassifyReport {
    pub accuracy: f64,
    pub ap: f64,
    pub ar: f64,
    pub noise_entropy: f64,
}

pub fn eval_classify(
    pred: &mut dyn Predictor,
    test: &Dataset,
    noise: &Dataset,
    s: usize,
) -> ClassifyReport {
    assert_eq!(pred.task(), Task::Classify);
    let k = 4;
    let mut probs = Vec::with_capacity(test.n * k);
    for i in 0..test.n {
        let out = pred.predict(test.beat(i), s);
        let mean: Vec<f64> = out.mean().iter().map(|&v| v as f64).collect();
        probs.extend(mean);
    }
    let labels = &test.y;
    let mut ent = 0.0;
    for i in 0..noise.n {
        let out = pred.predict(noise.beat(i), s);
        let mean: Vec<f64> = out.mean().iter().map(|&v| v as f64).collect();
        ent += metrics::entropy(&mean);
    }
    ClassifyReport {
        accuracy: metrics::multiclass_accuracy(&probs, labels, k),
        ap: metrics::macro_average_precision(&probs, labels, k),
        ar: metrics::macro_recall(&probs, labels, k),
        noise_entropy: ent / noise.n.max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::train::native::{NativeTrainer, TrainOpts};

    fn quick_opts() -> TrainOpts {
        TrainOpts { epochs: 10, batch: 32, lr: 1e-2, seed: 0 }
    }

    #[test]
    fn trained_autoencoder_separates_anomalies() {
        let cfg = ArchConfig::new(Task::Anomaly, 16, 1, "NN");
        let (train, test) = data::anomaly_splits(1);
        let train_small =
            train.subset(&(0..128.min(train.n)).collect::<Vec<_>>());
        let mut t = NativeTrainer::new(cfg, quick_opts());
        t.fit(&train_small);
        let test_small = test.subset(&(0..160).collect::<Vec<_>>());
        let mut p = ModelPredictor::new(&t.model, 9);
        let rep = eval_anomaly(&mut p, &test_small, 1);
        assert!(
            rep.auc > 0.8,
            "even a quick AE should separate: auc {}",
            rep.auc
        );
        assert!(rep.mean_rmse_anomalous > rep.mean_rmse_normal);
        assert_eq!(rep.scores.len(), 160);
    }

    #[test]
    fn trained_classifier_beats_chance() {
        let cfg = ArchConfig::new(Task::Classify, 8, 1, "N");
        let (train, test) = data::splits(2);
        let mut t = NativeTrainer::new(
            cfg,
            TrainOpts { epochs: 20, batch: 32, lr: 1e-2, seed: 1 },
        );
        t.fit(&train);
        let test_small = test.subset(&(0..200).collect::<Vec<_>>());
        let noise = data::gaussian_noise(16, 0);
        let mut p = ModelPredictor::new(&t.model, 5);
        let rep = eval_classify(&mut p, &test_small, &noise, 1);
        assert!(rep.accuracy > 0.6, "accuracy {}", rep.accuracy);
        assert!(rep.ar > 0.3, "macro recall {}", rep.ar);
        assert!(rep.noise_entropy >= 0.0);
    }

    #[test]
    fn bayesian_uncertainty_higher_on_noise_than_beats() {
        // The MCD signature the paper sells (Fig. 1): predictive entropy
        // on garbage inputs exceeds entropy on in-distribution beats.
        let cfg = ArchConfig::new(Task::Classify, 8, 2, "YY");
        let (train, test) = data::splits(3);
        let mut t = NativeTrainer::new(
            cfg,
            TrainOpts { epochs: 20, batch: 32, lr: 1e-2, seed: 2 },
        );
        t.fit(&train);
        let mut p = ModelPredictor::new(&t.model, 11);
        let beats = test.subset(&(0..60).collect::<Vec<_>>());
        let noise = data::gaussian_noise(60, 1);
        let rep_beats = eval_classify(&mut p, &beats, &beats, 10);
        let rep_noise = eval_classify(&mut p, &beats, &noise, 10);
        assert!(
            rep_noise.noise_entropy > rep_beats.noise_entropy,
            "noise {} vs beats {}",
            rep_noise.noise_entropy,
            rep_beats.noise_entropy
        );
    }
}
