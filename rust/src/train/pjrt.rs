//! PJRT-backed training: the AOT train-step artifact (L2 fwd/bwd lowered
//! by aot.py) driven from Rust. Parameters and Adam state live as host
//! tensors between steps; masks are sampled host-side (the coordinator's
//! RNG), exactly mirroring `NativeTrainer` so the two are
//! cross-checkable step-for-step.

use anyhow::{Context, Result};

use crate::config::{ArchConfig, Task};
use crate::data::Dataset;
use crate::nn::model::Masks;
use crate::nn::Params;
use crate::rng::Rng;
use crate::runtime::{HostValue, Runtime};
use crate::tensor::Tensor;

pub struct PjrtTrainer<'rt> {
    pub cfg: ArchConfig,
    pub params: Params,
    pub m: Params,
    pub v: Params,
    pub step: f32,
    pub lr: f32,
    pub loss_history: Vec<f32>,
    runtime: &'rt mut Runtime,
    artifact: String,
    batch: usize,
    rng: Rng,
}

impl<'rt> PjrtTrainer<'rt> {
    /// Bind to the `<arch>.train_b<batch>` artifact.
    pub fn new(
        runtime: &'rt mut Runtime,
        arch_name: &str,
        batch: usize,
        lr: f32,
        seed: u64,
    ) -> Result<Self> {
        let meta = runtime
            .manifest
            .train_for(arch_name, batch)
            .with_context(|| {
                format!("no train artifact for {arch_name} at batch {batch}")
            })?
            .clone();
        let cfg = meta.arch();
        // Compile up front.
        runtime.load(&meta.name)?;
        let mut rng = Rng::new(seed);
        let params = Params::init(&cfg, &mut rng);
        let m = params.zeros_like();
        let v = params.zeros_like();
        Ok(Self {
            cfg,
            params,
            m,
            v,
            step: 0.0,
            lr,
            loss_history: Vec::new(),
            runtime,
            artifact: meta.name,
            batch,
            rng,
        })
    }

    /// One train step on a batch (xs `[B][T][I]` flattened; ys labels).
    pub fn step_batch(&mut self, xs: &[f32], ys: &[u8]) -> Result<f32> {
        let cfg = self.cfg.clone();
        let b = self.batch;
        anyhow::ensure!(xs.len() == b * cfg.seq_len * cfg.input_dim);
        let masks = Masks::sample(&cfg, b, &mut self.rng);

        // Positional ABI (aot.py build_train): params, m, v, step, lr,
        // xs, [ys], masks.
        let mut args: Vec<HostValue> = Vec::new();
        for p in &self.params.tensors {
            args.push(HostValue::F32(p.clone()));
        }
        for p in &self.m.tensors {
            args.push(HostValue::F32(p.clone()));
        }
        for p in &self.v.tensors {
            args.push(HostValue::F32(p.clone()));
        }
        args.push(HostValue::scalar(self.step));
        args.push(HostValue::scalar(self.lr));
        args.push(HostValue::F32(Tensor::new(
            vec![b, cfg.seq_len, cfg.input_dim],
            xs.to_vec(),
        )));
        if cfg.task == Task::Classify {
            args.push(HostValue::I32(
                ys.iter().map(|&y| y as i32).collect(),
                vec![b],
            ));
        }
        for t in &masks.tensors {
            args.push(HostValue::F32(t.clone()));
        }

        let exe = self.runtime.load(&self.artifact)?;
        let mut out = exe.run(&args)?;
        // Outputs: params', m', v', step', loss.
        let loss = out.pop().context("missing loss")?.data[0];
        let step = out.pop().context("missing step")?.data[0];
        let np = self.params.tensors.len();
        anyhow::ensure!(out.len() == 3 * np, "bad output count");
        let vs: Vec<Tensor> = out.split_off(2 * np);
        let ms: Vec<Tensor> = out.split_off(np);
        self.params = Params { tensors: out };
        self.m = Params { tensors: ms };
        self.v = Params { tensors: vs };
        self.step = step;
        self.loss_history.push(loss);
        Ok(loss)
    }

    /// Epoch loop mirroring `NativeTrainer::fit`.
    pub fn fit(&mut self, data: &Dataset, epochs: usize) -> Result<()> {
        let b = self.batch;
        let steps = data.n.div_ceil(b);
        let mut order: Vec<usize> = (0..data.n).collect();
        for _ in 0..epochs {
            for i in (1..order.len()).rev() {
                let j = self.rng.below(i + 1);
                order.swap(i, j);
            }
            for s in 0..steps {
                let idx: Vec<usize> =
                    (0..b).map(|k| order[(s * b + k) % data.n]).collect();
                let batch = data.subset(&idx);
                self.step_batch(&batch.x, &batch.y)?;
            }
        }
        Ok(())
    }
}

// PJRT-dependent coverage lives in rust/tests/pjrt_integration.rs.
