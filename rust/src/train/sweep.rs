//! The algorithmic DSE sweep (Figs. 8/9): train every architecture point
//! in the grid, evaluate the paper's metrics, and populate the lookup
//! table consumed by the optimisation framework.
//!
//! Besides the float metrics, each point is re-evaluated on the
//! simulated fixed-point engine at every precision in
//! [`crate::dse::space::precision_space`] that fits the chip
//! ([`crate::dse::lookup::quant_key`] columns, e.g. `accuracy@q8`) —
//! the measurements the optimizer's precision axis selects on
//! (`docs/quantization.md`).

use crate::config::Task;
use crate::data;
use crate::dse::lookup::{quant_key, AlgoEntry, LookupTable};
use crate::dse::space::{arch_space, precision_space, reuse_search_q};
use crate::fpga::accel::Accelerator;
use crate::hwmodel::ZC706;
use crate::train::eval::{self, ModelPredictor};
use crate::train::native::{NativeTrainer, TrainOpts};

/// Sweep configuration. Defaults keep the whole sweep minutes-scale
/// (DESIGN.md §Substitutions documents the scale-down from the paper's
/// 1000 epochs / 4500-beat test set).
#[derive(Debug, Clone, Copy)]
pub struct SweepOpts {
    pub full_grid: bool,
    pub epochs: usize,
    pub train_subset: usize,
    pub test_subset: usize,
    pub noise_subset: usize,
    pub mc_samples: usize,
    /// Beats of the test split used for the per-precision fixed-point
    /// evals (0 skips the quantised columns entirely). Kept smaller
    /// than `test_subset`: the fixed-point sim runs once per format.
    pub quant_subset: usize,
    pub seed: u64,
}

impl Default for SweepOpts {
    fn default() -> Self {
        Self {
            full_grid: false,
            epochs: 25,
            train_subset: 500,
            test_subset: 400,
            noise_subset: 40,
            mc_samples: 10,
            quant_subset: 64,
            seed: 0,
        }
    }
}

/// Run the sweep for one task, appending entries to `table`.
/// `progress` is called with (done, total, name) after each point.
pub fn run(
    task: Task,
    opts: &SweepOpts,
    table: &mut LookupTable,
    mut progress: impl FnMut(usize, usize, &str),
) {
    let archs = arch_space(task, opts.full_grid);
    let total = archs.len();
    for (i, cfg) in archs.into_iter().enumerate() {
        let name = cfg.name();
        let train_opts = TrainOpts {
            epochs: opts.epochs,
            batch: 64,
            lr: if task == Task::Anomaly { 1e-2 } else { 5e-3 },
            seed: opts.seed,
        };
        let mut metrics = std::collections::BTreeMap::new();
        match task {
            Task::Anomaly => {
                let (train, test) = data::anomaly_splits(opts.seed);
                let tr = train.subset(
                    &(0..opts.train_subset.min(train.n)).collect::<Vec<_>>(),
                );
                let te = test.subset(
                    &(0..opts.test_subset.min(test.n)).collect::<Vec<_>>(),
                );
                let mut trainer = NativeTrainer::new(cfg.clone(), train_opts);
                trainer.fit(&tr);
                let s = if cfg.is_bayesian() { opts.mc_samples } else { 1 };
                let mut p = ModelPredictor::new(&trainer.model, opts.seed + 7);
                let rep = eval::eval_anomaly(&mut p, &te, s);
                metrics.insert("accuracy".into(), rep.accuracy);
                metrics.insert("ap".into(), rep.ap);
                metrics.insert("auc".into(), rep.auc);
                metrics.insert(
                    "rmse".into(),
                    rep.mean_rmse_normal,
                );
                // Per-precision fixed-point columns on a smaller window.
                if opts.quant_subset > 0 {
                    let te_q = test.subset(
                        &(0..opts.quant_subset.min(test.n))
                            .collect::<Vec<_>>(),
                    );
                    for prec in precision_space() {
                        let Some(reuse) =
                            reuse_search_q(&cfg, &ZC706, &prec)
                        else {
                            continue; // infeasible at this format
                        };
                        let mut acc = Accelerator::with_precision(
                            &cfg,
                            &trainer.model.params,
                            reuse,
                            opts.seed + 11,
                            prec.clone(),
                        );
                        let q = eval::eval_anomaly(&mut acc, &te_q, s);
                        let pn = prec.name();
                        metrics
                            .insert(quant_key("accuracy", &pn), q.accuracy);
                        metrics.insert(quant_key("auc", &pn), q.auc);
                    }
                }
            }
            Task::Classify => {
                let (train, test) = data::splits(opts.seed);
                let tr = train.subset(
                    &(0..opts.train_subset.min(train.n)).collect::<Vec<_>>(),
                );
                let te = test.subset(
                    &(0..opts.test_subset.min(test.n)).collect::<Vec<_>>(),
                );
                let noise = data::gaussian_noise(opts.noise_subset, opts.seed);
                let mut trainer = NativeTrainer::new(cfg.clone(), train_opts);
                trainer.fit(&tr);
                let s = if cfg.is_bayesian() { opts.mc_samples } else { 1 };
                let mut p = ModelPredictor::new(&trainer.model, opts.seed + 7);
                let rep = eval::eval_classify(&mut p, &te, &noise, s);
                metrics.insert("accuracy".into(), rep.accuracy);
                metrics.insert("ap".into(), rep.ap);
                metrics.insert("ar".into(), rep.ar);
                metrics.insert("entropy".into(), rep.noise_entropy);
                if opts.quant_subset > 0 {
                    let te_q = test.subset(
                        &(0..opts.quant_subset.min(test.n))
                            .collect::<Vec<_>>(),
                    );
                    let noise_q = data::gaussian_noise(
                        opts.noise_subset.min(8),
                        opts.seed,
                    );
                    for prec in precision_space() {
                        let Some(reuse) =
                            reuse_search_q(&cfg, &ZC706, &prec)
                        else {
                            continue;
                        };
                        let mut acc = Accelerator::with_precision(
                            &cfg,
                            &trainer.model.params,
                            reuse,
                            opts.seed + 11,
                            prec.clone(),
                        );
                        let q =
                            eval::eval_classify(&mut acc, &te_q, &noise_q, s);
                        let pn = prec.name();
                        metrics
                            .insert(quant_key("accuracy", &pn), q.accuracy);
                        metrics.insert(quant_key("ap", &pn), q.ap);
                    }
                }
            }
        }
        table.insert(AlgoEntry {
            name: name.clone(),
            task,
            hidden: cfg.hidden,
            nl: cfg.nl,
            bayes: cfg.bayes_str(),
            metrics,
        });
        progress(i + 1, total, &name);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_sweep_populates_table() {
        // One-point-ish sweep: tiny budgets, curated grid, just verify the
        // plumbing end to end (full sweeps run via the CLI / benches).
        let opts = SweepOpts {
            epochs: 2,
            train_subset: 48,
            test_subset: 60,
            noise_subset: 8,
            mc_samples: 2,
            quant_subset: 12,
            ..Default::default()
        };
        let mut table = LookupTable::new();
        let mut seen = 0;
        run(Task::Classify, &opts, &mut table, |done, total, _| {
            seen = done;
            assert!(done <= total);
        });
        assert!(seen > 0);
        assert_eq!(table.entries.len(), seen);
        for e in &table.entries {
            assert!(e.metrics.contains_key("accuracy"));
            assert!(e.metrics.contains_key("entropy"));
            let acc = e.metrics["accuracy"];
            assert!((0.0..=1.0).contains(&acc));
            // Quantised columns exist for every precision the arch fits
            // at (q8 always fits whenever anything does on this grid).
            for prec in precision_space() {
                if reuse_search_q(&e.arch(), &crate::hwmodel::ZC706, &prec)
                    .is_some()
                {
                    let key = quant_key("accuracy", &prec.name());
                    let q = *e
                        .metrics
                        .get(&key)
                        .unwrap_or_else(|| panic!("{} missing {key}", e.name));
                    assert!((0.0..=1.0).contains(&q));
                }
            }
        }
        assert!(
            table.entries.iter().any(|e| {
                e.metrics.contains_key(&quant_key("accuracy", "q8"))
            }),
            "at least one point must carry a q8 column"
        );
    }
}
