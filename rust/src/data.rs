//! Synthetic ECG5000-equivalent dataset (DESIGN.md §Substitutions).
//!
//! Mirrors `python/compile/ecg.py`: Gaussian-bump P-QRS-T heartbeat
//! morphologies, T = 140 samples per beat, per-beat z-normalisation, four
//! classes (0 = normal, 1–3 = anomalous variants) with ECG5000's heavy
//! class imbalance, and the paper's 500-train / 4500-test split. The Rust
//! and Python generators share morphology constants and class mixture; the
//! pytest/cargo suites cross-check their statistics.

use crate::rng::Rng;

/// Beat length (timesteps).
pub const T: usize = 140;
/// Number of classes (1 normal + 3 anomalous).
pub const CLASSES: usize = 4;
/// Class mixture mirroring ECG5000's imbalance (normal ~58%).
pub const CLASS_PROBS: [f64; 4] = [0.584, 0.310, 0.070, 0.036];
pub const TRAIN_N: usize = 500;
pub const TEST_N: usize = 4500;

/// A labelled pool of beats: `x` is `[n][T]` row-major, labels in `y`.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub x: Vec<f32>,
    pub y: Vec<u8>,
    pub n: usize,
}

impl Dataset {
    pub fn beat(&self, i: usize) -> &[f32] {
        &self.x[i * T..(i + 1) * T]
    }

    pub fn label(&self, i: usize) -> u8 {
        self.y[i]
    }

    /// Indices of beats with the given label.
    pub fn indices_of(&self, label: u8) -> Vec<usize> {
        (0..self.n).filter(|&i| self.y[i] == label).collect()
    }

    /// Subset by indices (copies).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let mut x = Vec::with_capacity(idx.len() * T);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(self.beat(i));
            y.push(self.y[i]);
        }
        Dataset { x, y, n: idx.len() }
    }

    /// Fraction of beats labelled 0 (normal).
    pub fn normal_fraction(&self) -> f64 {
        self.y.iter().filter(|&&l| l == 0).count() as f64 / self.n as f64
    }
}

#[inline]
fn bump(t: f64, center: f64, width: f64, amp: f64) -> f64 {
    let d = (t - center) / width;
    amp * (-0.5 * d * d).exp()
}

/// One beat of length T for class `label` (0 = normal). Mirrors
/// `ecg.py::_beat` (same landmarks, amplitudes and jitter scales).
fn gen_beat(rng: &mut Rng, label: u8, out: &mut [f32]) {
    debug_assert_eq!(out.len(), T);
    let j = |rng: &mut Rng, s: f64| rng.normal_scaled(0.0, s);
    let p_c = 25.0 + j(rng, 2.0);
    let q_c = 55.0 + j(rng, 1.5);
    let r_c = 62.0 + j(rng, 1.5);
    let s_c = 69.0 + j(rng, 1.5);
    let t_c = 105.0 + j(rng, 3.0);
    let p_a = 0.18 + j(rng, 0.02);
    let q_a = -0.28 + j(rng, 0.03);
    let r_a = 1.60 + j(rng, 0.08);
    let s_a = -0.45 + j(rng, 0.04);
    let t_a = 0.45 + j(rng, 0.04);

    let mut sig = [0f64; T];
    for (i, v) in sig.iter_mut().enumerate() {
        let t = i as f64;
        *v = bump(t, p_c, 4.0, p_a)
            + bump(t, q_c, 1.8, q_a)
            + bump(t, r_c, 2.2, r_a)
            + bump(t, s_c, 2.0, s_a)
            + bump(t, t_c, 9.0, t_a);
    }
    match label {
        1 => {
            // R-on-T / PVC-like: inverted widened T + depressed ST.
            let amp = 0.55 + j(rng, 0.05);
            let st_c = (s_c + t_c) / 2.0;
            for (i, v) in sig.iter_mut().enumerate() {
                let t = i as f64;
                *v -= 2.1 * bump(t, t_c, 11.0, amp);
                *v -= 0.25 * bump(t, st_c, 12.0, 1.0);
            }
        }
        2 => {
            // Supraventricular-like: flattened R, early weak T.
            let ra = 0.95 + j(rng, 0.06);
            let ta = 0.22 + j(rng, 0.03);
            for (i, v) in sig.iter_mut().enumerate() {
                let t = i as f64;
                *v -= bump(t, r_c, 2.2, ra);
                *v -= 0.5 * bump(t, t_c, 9.0, 0.45);
                *v += bump(t, t_c - 18.0, 7.0, ta);
            }
        }
        3 => {
            // Premature/ectopic-like: time-warp earlier + sinusoidal drift.
            let shift = (12.0 + j(rng, 3.0).abs()) as usize;
            let phase = j(rng, 0.5);
            let mut rolled = [0f64; T];
            for i in 0..T {
                rolled[i] = sig[(i + shift) % T];
            }
            for (i, v) in rolled.iter_mut().enumerate() {
                let t = i as f64;
                *v += 0.15
                    * (2.0 * std::f64::consts::PI * t / T as f64 + phase)
                        .sin();
            }
            sig = rolled;
        }
        _ => {}
    }
    // Sensor noise + per-beat z-normalisation (dataset preprocessing).
    for v in sig.iter_mut() {
        *v += rng.normal_scaled(0.0, 0.05);
    }
    let mean = sig.iter().sum::<f64>() / T as f64;
    let var = sig.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
        / T as f64;
    let std = var.sqrt() + 1e-8;
    for (o, v) in out.iter_mut().zip(sig.iter()) {
        *o = ((v - mean) / std) as f32;
    }
}

/// Generate `n` labelled beats with the ECG5000 class mixture.
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut x = vec![0f32; n * T];
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let label = rng.categorical(&CLASS_PROBS) as u8;
        gen_beat(&mut rng, label, &mut x[i * T..(i + 1) * T]);
        y.push(label);
    }
    Dataset { x, y, n }
}

/// The paper's split: 500 train / 4500 test from one 5000-beat pool.
pub fn splits(seed: u64) -> (Dataset, Dataset) {
    let pool = generate(TRAIN_N + TEST_N, seed);
    let train = pool.subset(&(0..TRAIN_N).collect::<Vec<_>>());
    let test = pool.subset(&(TRAIN_N..TRAIN_N + TEST_N).collect::<Vec<_>>());
    (train, test)
}

/// Anomaly-detection arrangement (Sec. V-A1): train on *normal* training
/// beats only; the anomalous training beats are appended to the test set.
pub fn anomaly_splits(seed: u64) -> (Dataset, Dataset) {
    let (train, test) = splits(seed);
    let normal_idx = train.indices_of(0);
    let anomalous_idx: Vec<usize> =
        (0..train.n).filter(|&i| train.y[i] != 0).collect();
    let train_normal = train.subset(&normal_idx);
    // test + anomalous train beats
    let mut x = test.x.clone();
    let mut y = test.y.clone();
    let extra = train.subset(&anomalous_idx);
    x.extend_from_slice(&extra.x);
    y.extend_from_slice(&extra.y);
    let n = y.len();
    (train_normal, Dataset { x, y, n })
}

/// Pure Gaussian-noise sequences for the entropy/uncertainty probe
/// (Sec. V-A2: "sequences of random Gaussian noise").
pub fn gaussian_noise(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0xD1CE);
    let mut x = vec![0f32; n * T];
    for v in x.iter_mut() {
        *v = rng.normal() as f32;
    }
    Dataset { x, y: vec![0; n], n }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let a = generate(32, 9);
        let b = generate(32, 9);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        assert_eq!(a.x.len(), 32 * T);
    }

    #[test]
    fn z_normalised_per_beat() {
        let d = generate(16, 2);
        for i in 0..d.n {
            let beat = d.beat(i);
            let mean: f32 = beat.iter().sum::<f32>() / T as f32;
            let var: f32 =
                beat.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>()
                    / T as f32;
            assert!(mean.abs() < 1e-4, "mean {mean}");
            assert!((var.sqrt() - 1.0).abs() < 1e-3, "std {}", var.sqrt());
        }
    }

    #[test]
    fn class_imbalance_matches_ecg5000() {
        let d = generate(5000, 0);
        let f = d.normal_fraction();
        assert!(f > 0.52 && f < 0.65, "normal fraction {f}");
    }

    #[test]
    fn splits_sizes() {
        let (tr, te) = splits(0);
        assert_eq!(tr.n, 500);
        assert_eq!(te.n, 4500);
    }

    #[test]
    fn anomaly_splits_only_normal_train() {
        let (tr, te) = anomaly_splits(0);
        assert!(tr.y.iter().all(|&l| l == 0));
        assert!(te.n > 4500, "anomalous train beats must be appended");
        assert!(te.y.iter().any(|&l| l != 0));
    }

    #[test]
    fn anomalies_differ_from_normal() {
        let d = generate(2000, 3);
        let mean_of = |label: u8| -> Vec<f32> {
            let idx = d.indices_of(label);
            let mut m = vec![0f32; T];
            for &i in &idx {
                for (mm, v) in m.iter_mut().zip(d.beat(i)) {
                    *mm += v;
                }
            }
            for mm in m.iter_mut() {
                *mm /= idx.len() as f32;
            }
            m
        };
        let normal = mean_of(0);
        for c in 1..=3u8 {
            let mc = mean_of(c);
            let rmse = (normal
                .iter()
                .zip(&mc)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
                / T as f32)
                .sqrt();
            assert!(rmse > 0.3, "class {c} rmse {rmse}");
        }
    }

    #[test]
    fn gaussian_noise_is_unstructured() {
        let d = gaussian_noise(64, 0);
        let mean: f32 = d.x.iter().sum::<f32>() / d.x.len() as f32;
        assert!(mean.abs() < 0.05);
    }

    #[test]
    fn subset_copies_right_rows() {
        let d = generate(10, 4);
        let s = d.subset(&[3, 7]);
        assert_eq!(s.n, 2);
        assert_eq!(s.beat(0), d.beat(3));
        assert_eq!(s.label(1), d.label(7));
    }
}
