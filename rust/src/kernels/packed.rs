//! Packed narrow weight planes: q8/q12 weights stored as `i8`/`i16`
//! rows, widened in-register at MAC time.
//!
//! The paper's DSP packing (two ≤ 8-bit MACs per DSP48 slice; also Fan
//! et al., arXiv:2105.09163) is an *operand-width* win: narrow weights
//! cost less to move as well as to multiply. The simulator used to
//! store every format's weights in 16-bit `Fx16` containers, so a q8
//! design moved exactly as many weight bytes per MAC as a q16 one.
//! [`PackedWeights`] stores the raw lattice points at their container
//! width — `i8` rows for ≤ 8-bit formats, `i16` otherwise — halving
//! weight bandwidth at q8 (and quartering it against the float model's
//! `f32` weights). Values are widened to `i16` in-register inside the
//! kernel's MAC (`MacAcc::mac_raw`), which is exact: the raw lattice
//! point is unchanged, so packed MVMs are **bit-identical** to unpacked
//! ones (property-tested in `super::tests`).

use crate::fixedpoint::{Fx16, QFormat};

/// Row-major `[in_dim][out_dim]` weights at their format's container
/// width.
#[derive(Debug, Clone)]
pub struct PackedWeights {
    pub fmt: QFormat,
    pub in_dim: usize,
    pub out_dim: usize,
    pub(crate) plane: Plane,
}

/// The storage plane: one narrow integer per weight.
#[derive(Debug, Clone)]
pub(crate) enum Plane {
    I8(Vec<i8>),
    I16(Vec<i16>),
}

impl PackedWeights {
    /// Pack quantised weights. A ≤ 8-bit format's raw values fit `i8`
    /// by construction (the rails are `±2^(total-1)`); values quantised
    /// at a wider format are rejected here — this is a cold
    /// construction path, and a silent `as i8` wrap would corrupt every
    /// subsequent MVM.
    pub fn pack(w: &[Fx16], in_dim: usize, out_dim: usize, fmt: QFormat) -> Self {
        assert_eq!(w.len(), in_dim * out_dim, "weight shape mismatch");
        let plane = if fmt.total_bits <= 8 {
            Plane::I8(
                w.iter()
                    .map(|v| {
                        assert!(
                            v.0 >= i8::MIN as i16 && v.0 <= i8::MAX as i16,
                            "raw {} exceeds the {}-bit container",
                            v.0,
                            fmt.total_bits
                        );
                        v.0 as i8
                    })
                    .collect(),
            )
        } else {
            Plane::I16(w.iter().map(|v| v.0).collect())
        };
        Self { fmt, in_dim, out_dim, plane }
    }

    /// Elements actually stored in the plane (the kernels' shape guard
    /// compares this against `in_dim * out_dim`, so it must come from
    /// the storage, not the dims).
    pub fn len(&self) -> usize {
        match &self.plane {
            Plane::I8(p) => p.len(),
            Plane::I16(p) => p.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes the weight plane occupies — the bandwidth the MVM streams.
    pub fn bytes(&self) -> usize {
        match &self.plane {
            Plane::I8(p) => p.len(),
            Plane::I16(p) => p.len() * 2,
        }
    }

    /// Bytes moved per MAC (1 at q8, 2 at q12/q16; the `Fx16` baseline
    /// is always 2 and the float model's 4).
    pub fn bytes_per_weight(&self) -> f64 {
        if self.len() == 0 {
            0.0
        } else {
            self.bytes() as f64 / self.len() as f64
        }
    }

    /// Read one weight back as its `Fx16` lattice point (tests/debug;
    /// the kernels stream whole rows instead).
    pub fn get(&self, i: usize, k: usize) -> Fx16 {
        let j = i * self.out_dim + k;
        match &self.plane {
            Plane::I8(p) => Fx16(p[j] as i16),
            Plane::I16(p) => Fx16(p[j]),
        }
    }
}

/// Dispatch a packed plane to a generic body: `with_plane!(w, p =>
/// expr)` binds `p` to the typed row slice in each arm — the single
/// place the [`Plane`] variants are enumerated by the kernel backends
/// (one monomorphized body per width, no per-element matching).
macro_rules! with_plane {
    ($w:expr, $p:ident => $body:expr) => {
        match &$w.plane {
            $crate::kernels::packed::Plane::I8($p) => $body,
            $crate::kernels::packed::Plane::I16($p) => $body,
        }
    };
}
pub(crate) use with_plane;

/// A weight lattice point the kernels widen in-register at MAC time.
/// The widening is exact (raw value unchanged), which is what keeps
/// packed and unpacked MVMs bit-identical.
pub trait WeightElem: Copy {
    fn raw(self) -> i16;
}

impl WeightElem for Fx16 {
    #[inline(always)]
    fn raw(self) -> i16 {
        self.0
    }
}

impl WeightElem for i8 {
    #[inline(always)]
    fn raw(self) -> i16 {
        self as i16
    }
}

impl WeightElem for i16 {
    #[inline(always)]
    fn raw(self) -> i16 {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q8_packs_one_byte_per_weight() {
        let fmt = QFormat::Q8_ACT;
        let w: Vec<Fx16> = (0..6).map(|i| fmt.quantize(i as f32 * 0.5 - 1.0)).collect();
        let p = PackedWeights::pack(&w, 2, 3, fmt);
        assert_eq!(p.bytes(), 6);
        assert!((p.bytes_per_weight() - 1.0).abs() < 1e-12);
        for i in 0..2 {
            for k in 0..3 {
                assert_eq!(p.get(i, k), w[i * 3 + k], "widening must be exact");
            }
        }
    }

    #[test]
    fn wide_formats_keep_i16_rows() {
        for fmt in [QFormat::Q12_ACT, QFormat::Q16_ACT] {
            let w: Vec<Fx16> = (0..4).map(|i| fmt.quantize(i as f32 - 1.5)).collect();
            let p = PackedWeights::pack(&w, 2, 2, fmt);
            assert_eq!(p.bytes(), 8, "{}", fmt.name());
            assert!((p.bytes_per_weight() - 2.0).abs() < 1e-12);
            assert_eq!(p.get(1, 1), w[3]);
        }
    }

    #[test]
    fn q8_rails_survive_the_i8_container() {
        let fmt = QFormat::Q8_ACT;
        let w = [Fx16(fmt.min_raw() as i16), Fx16(fmt.max_raw() as i16)];
        let p = PackedWeights::pack(&w, 1, 2, fmt);
        assert_eq!(p.get(0, 0).0 as i32, fmt.min_raw());
        assert_eq!(p.get(0, 1).0 as i32, fmt.max_raw());
    }
}
