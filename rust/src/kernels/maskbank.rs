//! Seed-indexed mask bank: a sharded, byte-budgeted cache of
//! precomputed bitplane mask rows (`docs/kernels.md` §Mask bank).
//!
//! VIBNN (arXiv:1802.00822) measures RNG as a first-order cost in
//! Bayesian accelerators, and this crate's per-(request, sample)
//! dropout masks are pure functions of a `mix3`-derived seed and the
//! layer shape — regenerating them is pure waste whenever a seed
//! recurs. Seeds *do* recur in production shapes of this workload:
//! adaptive-MC continuation rounds re-touch early sample indices,
//! loadgen scenario replays re-issue whole request streams, and
//! calibration sweeps pin seeds on purpose. The bank memoises the
//! packed row words ([`super::BitPlanes::row_words`]) keyed by
//! `(layer seed, zx width, zh width)`, so a repeat seed costs one hash
//! lookup and a row copy instead of a full LFSR stream.
//!
//! Design points:
//!
//! * **Sharded**: the map is split across [`SHARDS`] independently
//!   locked shards (key-hash selected), so engine workers hitting the
//!   bank concurrently contend only 1/[`SHARDS`] of the time.
//! * **Byte-budgeted with CLOCK eviction**: each shard owns an equal
//!   slice of the byte budget. Inserting past the budget sweeps a
//!   CLOCK hand over the shard's ring — entries touched since the
//!   last sweep get a second chance (their reference bit is cleared),
//!   untouched ones are evicted. An entry larger than a whole shard's
//!   budget is simply not cached.
//! * **Correctness by construction**: the bank stores the *exact*
//!   words the generator produced (tail padding included), and a hit
//!   restores them verbatim ([`super::BitPlanes::copy_row_from_words`])
//!   — so bank on vs off is bit-identical by definition, which
//!   `fpga::accel` and `coordinator::fleet` assert end to end.
//! * **Observable**: hit/miss/eviction/resident-bytes counters are
//!   lock-free atomics, snapshotted by [`MaskBank::stats`] into the
//!   `obs` export (`docs/observability.md`).

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Shard count: enough to keep a handful of engine workers off each
/// other's locks, small enough that a few-MB budget still gives each
/// shard a useful slice.
const SHARDS: usize = 8;

/// Bookkeeping bytes charged per entry on top of the row words (map
/// node, key, ring slot — an estimate, deliberately on the high side).
const ENTRY_OVERHEAD: usize = 64;

/// Cache key: the per-(request, sample, layer) mask seed plus the
/// layer's two mask-plane widths. Widths are part of the key so a
/// seed collision across differently-shaped layers (or architectures
/// sharing a bank) can never alias.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MaskKey {
    /// The layer-salted sampler seed the mask stream is derived from.
    pub layer_seed: u64,
    /// Width in bits of the input-side (`zx`) mask row.
    pub zx_width: usize,
    /// Width in bits of the recurrent-side (`zh`) mask row.
    pub zh_width: usize,
}

struct Entry {
    words: Arc<[u64]>,
    /// CLOCK reference bit: set on every hit, cleared (second chance)
    /// when the hand sweeps past.
    referenced: bool,
}

#[derive(Default)]
struct Shard {
    entries: HashMap<MaskKey, Entry>,
    /// The CLOCK ring: insertion order, swept circularly by `hand`.
    ring: Vec<MaskKey>,
    hand: usize,
    bytes: usize,
}

impl Shard {
    fn entry_cost(words: &[u64]) -> usize {
        words.len() * 8 + ENTRY_OVERHEAD
    }

    /// Evict until `need` bytes fit in `budget`, CLOCK order. Returns
    /// (evictions, bytes freed).
    fn make_room(&mut self, need: usize, budget: usize) -> (u64, usize) {
        let mut evicted = 0u64;
        let mut freed = 0usize;
        // Each lap clears every reference bit, so the sweep terminates:
        // after one full lap every survivor is unreferenced and the
        // next pass removes entries until the budget fits.
        while self.bytes + need > budget && !self.ring.is_empty() {
            if self.hand >= self.ring.len() {
                self.hand = 0;
            }
            let key = self.ring[self.hand];
            let e = self.entries.get_mut(&key).expect("ring/map desync");
            if e.referenced {
                e.referenced = false;
                self.hand += 1;
            } else {
                let cost = Self::entry_cost(&e.words);
                self.entries.remove(&key);
                self.ring.swap_remove(self.hand);
                self.bytes -= cost;
                freed += cost;
                evicted += 1;
                // swap_remove moved the tail key under the hand; keep
                // the hand in place so it is inspected next.
            }
        }
        (evicted, freed)
    }
}

/// Point-in-time counter snapshot, exported through `obs`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MaskBankStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub resident_bytes: u64,
    pub capacity_bytes: u64,
}

/// The bank itself. Cheap to share: callers hold it as
/// `Arc<MaskBank>` and clone the `Arc` into each engine worker.
pub struct MaskBank {
    shards: Vec<Mutex<Shard>>,
    shard_budget: usize,
    capacity_bytes: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    resident_bytes: AtomicU64,
}

impl std::fmt::Debug for MaskBank {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("MaskBank")
            .field("capacity_bytes", &s.capacity_bytes)
            .field("resident_bytes", &s.resident_bytes)
            .field("hits", &s.hits)
            .field("misses", &s.misses)
            .field("evictions", &s.evictions)
            .finish()
    }
}

impl MaskBank {
    /// A bank holding at most `capacity_bytes` of cached rows
    /// (`--mask-bank-mb` scaled to bytes by the CLI).
    pub fn new(capacity_bytes: usize) -> Self {
        Self::with_shards(capacity_bytes, SHARDS)
    }

    /// Shard-count override — single-shard banks make eviction-order
    /// tests deterministic.
    pub fn with_shards(capacity_bytes: usize, shards: usize) -> Self {
        let shards = shards.max(1);
        Self {
            shards: (0..shards).map(|_| Mutex::new(Shard::default())).collect(),
            shard_budget: capacity_bytes / shards,
            capacity_bytes,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            resident_bytes: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: &MaskKey) -> &Mutex<Shard> {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % self.shards.len()]
    }

    /// Look up the cached row words for `key`. A hit marks the entry
    /// referenced (CLOCK second chance) and counts toward the hit
    /// counter; a miss only counts.
    pub fn get(&self, key: &MaskKey) -> Option<Arc<[u64]>> {
        let mut shard = self.shard(key).lock().expect("mask bank poisoned");
        match shard.entries.get_mut(key) {
            Some(e) => {
                e.referenced = true;
                let words = e.words.clone();
                drop(shard);
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(words)
            }
            None => {
                drop(shard);
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Cache freshly generated row words under `key`, evicting CLOCK
    /// victims if the shard is over budget. Oversized entries (bigger
    /// than a whole shard's budget) are dropped silently — the caller
    /// already has the words it needs. Re-inserting an existing key is
    /// a no-op (first generation wins; the words are deterministic in
    /// the key anyway).
    pub fn insert(&self, key: MaskKey, words: &[u64]) {
        let cost = Shard::entry_cost(words);
        if cost > self.shard_budget {
            return;
        }
        let mut shard = self.shard(&key).lock().expect("mask bank poisoned");
        if shard.entries.contains_key(&key) {
            return;
        }
        let (evicted, freed) = shard.make_room(cost, self.shard_budget);
        shard.entries.insert(
            key,
            Entry { words: Arc::from(words), referenced: false },
        );
        shard.ring.push(key);
        shard.bytes += cost;
        drop(shard);
        if evicted > 0 {
            self.evictions.fetch_add(evicted, Ordering::Relaxed);
            self.resident_bytes
                .fetch_sub(freed as u64, Ordering::Relaxed);
        }
        self.resident_bytes.fetch_add(cost as u64, Ordering::Relaxed);
    }

    pub fn stats(&self) -> MaskBankStats {
        MaskBankStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            resident_bytes: self.resident_bytes.load(Ordering::Relaxed),
            capacity_bytes: self.capacity_bytes as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(seed: u64) -> MaskKey {
        MaskKey { layer_seed: seed, zx_width: 64, zh_width: 32 }
    }

    #[test]
    fn miss_then_hit_roundtrips_the_words() {
        let bank = MaskBank::new(1 << 20);
        let k = key(42);
        assert!(bank.get(&k).is_none());
        let words = [0xDEAD_BEEF_u64, u64::MAX, 0];
        bank.insert(k, &words);
        let got = bank.get(&k).expect("hit after insert");
        assert_eq!(&got[..], &words[..]);
        let s = bank.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 1, 0));
        assert!(s.resident_bytes > 0 && s.resident_bytes <= s.capacity_bytes);
    }

    #[test]
    fn shape_is_part_of_the_key() {
        let bank = MaskBank::new(1 << 20);
        let a = MaskKey { layer_seed: 7, zx_width: 64, zh_width: 32 };
        let b = MaskKey { layer_seed: 7, zx_width: 128, zh_width: 32 };
        bank.insert(a, &[1, 2]);
        assert!(bank.get(&b).is_none(), "different shape, same seed");
        assert_eq!(&bank.get(&a).unwrap()[..], &[1, 2]);
    }

    #[test]
    fn eviction_keeps_resident_bytes_under_budget() {
        // Single shard so the budget math is exact.
        let budget = 4 * (8 * 8 + ENTRY_OVERHEAD); // room for ~4 entries
        let bank = MaskBank::with_shards(budget, 1);
        for s in 0..32u64 {
            bank.insert(key(s), &[s; 8]);
        }
        let st = bank.stats();
        assert!(st.evictions > 0, "budget overflow must evict");
        assert!(
            st.resident_bytes <= budget as u64,
            "resident {} > budget {budget}",
            st.resident_bytes
        );
        // The bank still serves hits for whatever survived.
        let survivors = (0..32u64).filter(|&s| bank.get(&key(s)).is_some());
        assert_eq!(survivors.count(), 4);
    }

    #[test]
    fn clock_gives_referenced_entries_a_second_chance() {
        let budget = 2 * (8 * 4 + ENTRY_OVERHEAD); // exactly 2 entries
        let bank = MaskBank::with_shards(budget, 1);
        bank.insert(key(1), &[1; 4]);
        bank.insert(key(2), &[2; 4]);
        // Touch key 1: its reference bit protects it from the next
        // sweep; key 2 (untouched) is the victim.
        assert!(bank.get(&key(1)).is_some());
        bank.insert(key(3), &[3; 4]);
        assert!(bank.get(&key(1)).is_some(), "referenced entry survives");
        assert!(bank.get(&key(2)).is_none(), "unreferenced entry evicted");
        assert!(bank.get(&key(3)).is_some(), "new entry resident");
        assert_eq!(bank.stats().evictions, 1);
    }

    #[test]
    fn oversized_entries_are_not_cached() {
        let bank = MaskBank::with_shards(64, 1);
        bank.insert(key(9), &[0u64; 1024]); // way over budget
        assert!(bank.get(&key(9)).is_none());
        assert_eq!(bank.stats().resident_bytes, 0);
        assert_eq!(bank.stats().evictions, 0);
    }

    #[test]
    fn reinsert_is_a_noop() {
        let bank = MaskBank::new(1 << 16);
        bank.insert(key(5), &[10, 11]);
        let before = bank.stats().resident_bytes;
        bank.insert(key(5), &[99, 99]); // same key: first write wins
        assert_eq!(bank.stats().resident_bytes, before);
        assert_eq!(&bank.get(&key(5)).unwrap()[..], &[10, 11]);
    }

    #[test]
    fn concurrent_access_is_consistent() {
        let bank = Arc::new(MaskBank::new(1 << 20));
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let bank = bank.clone();
                std::thread::spawn(move || {
                    for s in 0..64u64 {
                        let k = key(s);
                        match bank.get(&k) {
                            Some(w) => assert_eq!(&w[..], &[s; 6]),
                            None => bank.insert(k, &[s; 6]),
                        }
                        let _ = t;
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        for s in 0..64u64 {
            assert_eq!(&bank.get(&key(s)).unwrap()[..], &[s; 6]);
        }
    }
}
