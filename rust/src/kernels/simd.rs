//! Vectorized kernel backend: the blocked schedule with the inner
//! `out_dim` loop tiled into fixed-width lanes the compiler
//! autovectorizes.
//!
//! Stable Rust only — no nightly `std::simd`, no platform intrinsics,
//! no new dependencies. The vector shape is expressed structurally:
//! accumulator and weight rows are walked in [`LANES`]-wide
//! `chunks_exact` tiles whose trip count is a compile-time constant, so
//! the per-tile micro-loop is fully unrolled and vectorized by LLVM
//! (i16 x i16 -> i64 widening MACs on the fixed-point path, f32 FMA
//! lanes on the float path). The tail (`out_dim % LANES` elements)
//! falls back to the scalar epilogue.
//!
//! The schedule lives in the shared cores ([`super::run_fx_blocked`] /
//! [`super::run_f32_blocked`]) — this backend only swaps in the
//! lane-tiled row MAC. Bit-exactness: lane tiling partitions the
//! *output elements* `k`, it never reorders the terms *within* an
//! element — for every `(r, k)` the contributions still arrive in
//! ascending weight-row order `i`, so this backend is bit-identical to
//! [`super::ScalarKernel`] and [`super::BlockedKernel`] for `Fx16`
//! (exact `i64` adds) and `f32` (identical rounding order) alike.
//! Property-tested in `super::tests`; the engine/accelerator/fleet
//! levels pin the same contract one layer up.

use super::packed::{with_plane, WeightElem};
use super::{
    check_bounds_f32, check_bounds_fx, run_f32_blocked, run_fx_blocked,
    Kernel, MaskRef, PackedWeights,
};
use crate::fixedpoint::{Fx16, MacAcc};

/// Lane width of the inner tile. Eight i64 accumulators span two AVX2
/// registers (or four NEON ones) while keeping the live tile small
/// enough that `s_block` sample rows still fit in L1 alongside it.
pub const LANES: usize = 8;

pub struct SimdKernel {
    /// Live accumulator rows per chunk (the MC-sample block size),
    /// identical semantics to [`super::BlockedKernel::s_block`].
    pub s_block: usize,
}

impl Default for SimdKernel {
    fn default() -> Self {
        Self { s_block: super::DEFAULT_S_BLOCK }
    }
}

/// One lane-tiled row MAC: `acc_r[k] += xi * wrow[k]` over the whole
/// row, widened in-register. The fixed-trip-count inner loops are the
/// autovectorization seeds.
#[inline(always)]
fn mac_row_lanes<W: WeightElem>(xi: i16, wrow: &[W], acc_r: &mut [MacAcc]) {
    let mut at = acc_r.chunks_exact_mut(LANES);
    let mut wt = wrow.chunks_exact(LANES);
    for (a8, w8) in at.by_ref().zip(wt.by_ref()) {
        for l in 0..LANES {
            a8[l].mac_raw(xi, w8[l].raw());
        }
    }
    for (a, &wv) in at.into_remainder().iter_mut().zip(wt.remainder()) {
        a.mac_raw(xi, wv.raw());
    }
}

/// Float twin of [`mac_row_lanes`].
#[inline(always)]
fn mac_row_lanes_f32(xv: f32, wrow: &[f32], out_r: &mut [f32]) {
    let mut ot = out_r.chunks_exact_mut(LANES);
    let mut wt = wrow.chunks_exact(LANES);
    for (o8, w8) in ot.by_ref().zip(wt.by_ref()) {
        for l in 0..LANES {
            o8[l] += xv * w8[l];
        }
    }
    for (o, &wv) in ot.into_remainder().iter_mut().zip(wt.remainder()) {
        *o += xv * wv;
    }
}

impl Kernel for SimdKernel {
    fn name(&self) -> &'static str {
        "simd"
    }

    fn mvm_fx(
        &self,
        w: &[Fx16],
        in_dim: usize,
        out_dim: usize,
        rows: usize,
        x: &[Fx16],
        x_stride: usize,
        mask: Option<MaskRef>,
        acc: &mut [MacAcc],
        acc_stride: usize,
    ) {
        check_bounds_fx(
            w.len(),
            in_dim,
            out_dim,
            rows,
            x.len(),
            x_stride,
            mask.as_ref(),
            acc.len(),
            acc_stride,
        );
        run_fx_blocked(
            self.s_block,
            w,
            in_dim,
            out_dim,
            rows,
            x,
            x_stride,
            mask,
            acc,
            acc_stride,
            mac_row_lanes,
        );
    }

    fn mvm_fx_packed(
        &self,
        w: &PackedWeights,
        rows: usize,
        x: &[Fx16],
        x_stride: usize,
        mask: Option<MaskRef>,
        acc: &mut [MacAcc],
        acc_stride: usize,
    ) {
        check_bounds_fx(
            w.len(),
            w.in_dim,
            w.out_dim,
            rows,
            x.len(),
            x_stride,
            mask.as_ref(),
            acc.len(),
            acc_stride,
        );
        with_plane!(w, p => run_fx_blocked(
            self.s_block,
            p,
            w.in_dim,
            w.out_dim,
            rows,
            x,
            x_stride,
            mask,
            acc,
            acc_stride,
            mac_row_lanes,
        ));
    }

    fn mvm_f32(
        &self,
        w: &[f32],
        in_dim: usize,
        out_dim: usize,
        rows: usize,
        x: &[f32],
        x_stride: usize,
        mask: Option<(&[f32], usize)>,
        out: &mut [f32],
        out_stride: usize,
    ) {
        check_bounds_f32(
            w.len(),
            in_dim,
            out_dim,
            rows,
            x.len(),
            x_stride,
            mask.map(|(m, s)| (m.len(), s)),
            out.len(),
            out_stride,
        );
        run_f32_blocked(
            self.s_block,
            w,
            in_dim,
            out_dim,
            rows,
            x,
            x_stride,
            mask,
            out,
            out_stride,
            mac_row_lanes_f32,
        );
    }
}
