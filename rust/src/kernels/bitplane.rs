//! Bitplane dropout masks: the DX keep/drop gates packed one bit per
//! element.
//!
//! The hardware never materialises masks as words — the Bernoulli
//! sampler's bit stream is widened through a SIPO register and consumed
//! as *bits* by the gate engines (paper Sec. III-B, Fig. 3; VIBNN,
//! arXiv:1802.00822, makes the same point that mask generation and
//! storage are first-order costs in a Bayesian accelerator). The
//! simulator used to expand every mask bit into a 16-bit `Fx16` word
//! (`[rows][GATES][dim]` buffers), moving 16x the hardware's mask
//! traffic through memory on every beat. [`BitPlanes`] restores the
//! hardware's layout: a `[rows][width]` bitset the kernels probe
//! directly through a [`BitLanes`] view — same bits, 1/16th the bytes.
//!
//! Generation order is the contract: [`BitPlanes::fill_row`] consumes a
//! bit source in ascending element order, exactly the order the old
//! f32 buffer fills (`BernoulliSampler::fill`, `Rng`-driven
//! `Masks::sample`) drew, so the packed masks are bit-for-bit the masks
//! the scalar path produced (oracle-tested in `fpga::engine` and
//! `coordinator::engines`).

/// A `[rows][width]` bitset of keep/drop mask bits. Bit set = keep.
/// Rows are word-aligned so a lane view's stride is a whole number of
/// bits and the kernel's per-element probe is one shift+mask.
#[derive(Debug, Clone)]
pub struct BitPlanes {
    words: Vec<u64>,
    rows: usize,
    width: usize,
    words_per_row: usize,
}

impl BitPlanes {
    /// All-ones planes (every element kept — the non-Bayesian default).
    pub fn ones(rows: usize, width: usize) -> Self {
        let words_per_row = width.div_ceil(64).max(1);
        Self {
            words: vec![u64::MAX; rows * words_per_row],
            rows,
            width,
            words_per_row,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn width(&self) -> usize {
        self.width
    }

    /// Reset every bit to keep.
    pub fn fill_ones(&mut self) {
        self.words.fill(u64::MAX);
    }

    #[inline]
    fn pos(&self, r: usize, i: usize) -> (usize, u32) {
        debug_assert!(r < self.rows && i < self.width);
        (r * self.words_per_row + i / 64, (i % 64) as u32)
    }

    /// Set element `(r, i)` to keep (`true`) or drop (`false`).
    #[inline]
    pub fn set(&mut self, r: usize, i: usize, keep: bool) {
        let (w, b) = self.pos(r, i);
        if keep {
            self.words[w] |= 1u64 << b;
        } else {
            self.words[w] &= !(1u64 << b);
        }
    }

    #[inline]
    pub fn get(&self, r: usize, i: usize) -> bool {
        let (w, b) = self.pos(r, i);
        (self.words[w] >> b) & 1 == 1
    }

    /// Fill row `r` from a bit source in **ascending element order** —
    /// the SIPO widening. The source is called exactly `width` times,
    /// so a sampler driving it consumes the same stream positions the
    /// legacy f32-buffer fill did.
    pub fn fill_row(&mut self, r: usize, mut keep: impl FnMut() -> bool) {
        for i in 0..self.width {
            let k = keep();
            self.set(r, i, k);
        }
    }

    /// Fill row `r` from a word source in **ascending element order**,
    /// 64 bits per call instead of one: `next(n)` must return the next
    /// `n` bits of the stream, LSB-first (bit 0 = the earliest draw).
    /// The last call receives the row's tail width, so a sampler
    /// driving it consumes exactly `width` stream positions — the same
    /// contract as [`BitPlanes::fill_row`], and like it, bits at and
    /// beyond `width` in the tail word are left untouched.
    pub fn fill_row_words(
        &mut self,
        r: usize,
        mut next: impl FnMut(u32) -> u64,
    ) {
        debug_assert!(r < self.rows);
        let mut w = r * self.words_per_row;
        let mut remaining = self.width;
        while remaining > 0 {
            let n = remaining.min(64) as u32;
            let bits = next(n);
            if n == 64 {
                self.words[w] = bits;
            } else {
                let mask = (1u64 << n) - 1;
                self.words[w] = (self.words[w] & !mask) | (bits & mask);
            }
            remaining -= n as usize;
            w += 1;
        }
    }

    /// The raw words backing row `r`, tail bits beyond `width`
    /// included — what the seed-indexed mask bank caches verbatim
    /// (the tail bits are the all-ones padding [`BitPlanes::ones`]
    /// laid down, so a cached row restores byte-identically).
    pub fn row_words(&self, r: usize) -> &[u64] {
        debug_assert!(r < self.rows);
        let base = r * self.words_per_row;
        &self.words[base..base + self.words_per_row]
    }

    /// Overwrite row `r` with words captured by [`BitPlanes::row_words`]
    /// from an identically-shaped plane — the mask-bank hit path.
    pub fn copy_row_from_words(&mut self, r: usize, words: &[u64]) {
        debug_assert!(r < self.rows);
        assert_eq!(
            words.len(),
            self.words_per_row,
            "cached row shape mismatch"
        );
        let base = r * self.words_per_row;
        self.words[base..base + self.words_per_row]
            .copy_from_slice(words);
    }

    /// Words per row (the cached-row granularity of the mask bank).
    pub fn words_per_row(&self) -> usize {
        self.words_per_row
    }

    /// Mask bytes actually stored (the 16x-vs-`Fx16` claim is
    /// `bytes() * 16 ~ rows * width * 2`).
    pub fn bytes(&self) -> usize {
        self.words.len() * 8
    }

    /// Lane view starting `base` bits into every row — the per-gate
    /// mask lanes of a `[rows][GATES * dim]` plane are
    /// `lanes(g * dim)`.
    #[inline]
    pub fn lanes(&self, base: usize) -> BitLanes<'_> {
        BitLanes { words: &self.words, base, stride: self.words_per_row * 64 }
    }
}

/// A borrowed strided view into a bitset: element `(r, i)` is bit
/// `base + r * stride + i`. This is the form the kernels consume
/// ([`super::MaskRef::Bits`]).
#[derive(Debug, Clone, Copy)]
pub struct BitLanes<'a> {
    pub words: &'a [u64],
    /// Bit offset of element (0, 0).
    pub base: usize,
    /// Row stride in bits.
    pub stride: usize,
}

impl BitLanes<'_> {
    #[inline]
    pub fn keep(&self, r: usize, i: usize) -> bool {
        let bit = self.base + r * self.stride + i;
        (self.words[bit / 64] >> (bit % 64)) & 1 == 1
    }

    /// Every probed bit must lie inside the word array.
    pub(crate) fn check(&self, rows: usize, in_dim: usize) {
        if rows == 0 || in_dim == 0 {
            return;
        }
        let last = self.base + (rows - 1) * self.stride + in_dim - 1;
        assert!(last / 64 < self.words.len(), "bitplane mask out of bounds");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip_across_word_boundaries() {
        let mut p = BitPlanes::ones(3, 130);
        assert!(p.get(2, 129));
        p.set(1, 63, false);
        p.set(1, 64, false);
        p.set(2, 129, false);
        assert!(!p.get(1, 63));
        assert!(!p.get(1, 64));
        assert!(!p.get(2, 129));
        assert!(p.get(0, 63), "other rows untouched");
        assert!(p.get(1, 65));
        p.fill_ones();
        assert!(p.get(1, 63) && p.get(2, 129));
    }

    #[test]
    fn fill_row_consumes_bits_in_ascending_order() {
        let mut p = BitPlanes::ones(2, 9);
        let mut seq = Vec::new();
        let mut n = 0u32;
        p.fill_row(1, || {
            n += 1;
            let keep = n % 3 != 0;
            seq.push(keep);
            keep
        });
        assert_eq!(seq.len(), 9, "exactly width draws");
        for (i, &k) in seq.iter().enumerate() {
            assert_eq!(p.get(1, i), k, "bit {i}");
        }
        // Row 0 untouched.
        assert!((0..9).all(|i| p.get(0, i)));
    }

    /// Word fill == bit fill for the same stream, at widths that
    /// exercise sub-word rows, exact multiples and straddling tails.
    #[test]
    fn fill_row_words_matches_fill_row_bit_for_bit() {
        for width in [1usize, 9, 63, 64, 65, 128, 130, 200] {
            // A deterministic pseudo-stream shared by both fills.
            let stream = |i: usize| (i * 7 + i / 5) % 3 != 0;
            let mut by_bit = BitPlanes::ones(3, width);
            let mut n = 0usize;
            by_bit.fill_row(1, || {
                let k = stream(n);
                n += 1;
                k
            });
            let mut by_word = BitPlanes::ones(3, width);
            let mut pos = 0usize;
            let mut asked = Vec::new();
            by_word.fill_row_words(1, |n| {
                asked.push(n);
                let mut w = 0u64;
                for j in 0..n {
                    w |= (stream(pos + j as usize) as u64) << j;
                }
                pos += n as usize;
                w
            });
            assert_eq!(pos, width, "exactly width stream positions");
            assert_eq!(
                asked.iter().map(|&n| n as usize).sum::<usize>(),
                width
            );
            for i in 0..width {
                assert_eq!(
                    by_word.get(1, i),
                    by_bit.get(1, i),
                    "width {width} bit {i}"
                );
            }
            // Other rows and the tail padding stay all-ones.
            assert_eq!(by_word.words, by_bit.words, "words incl. padding");
            assert!((0..width).all(|i| by_word.get(0, i)));
        }
    }

    #[test]
    fn row_words_roundtrip_through_copy() {
        let mut src = BitPlanes::ones(2, 130);
        src.fill_row(1, {
            let mut n = 0u32;
            move || {
                n += 1;
                n % 5 != 0
            }
        });
        assert_eq!(src.words_per_row(), 3);
        let cached: Vec<u64> = src.row_words(1).to_vec();
        let mut dst = BitPlanes::ones(4, 130);
        dst.copy_row_from_words(2, &cached);
        for i in 0..130 {
            assert_eq!(dst.get(2, i), src.get(1, i), "bit {i}");
        }
        assert!((0..130).all(|i| dst.get(0, i)), "other rows untouched");
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn copy_row_rejects_wrong_shape() {
        let mut p = BitPlanes::ones(2, 130);
        p.copy_row_from_words(0, &[0u64; 2]);
    }

    #[test]
    fn lane_views_select_strided_elements() {
        // [rows = 2][GATES = 3 x dim = 5] layout, lane g = base g*5.
        let mut p = BitPlanes::ones(2, 15);
        p.set(0, 5 + 2, false); // row 0, gate 1, elem 2
        p.set(1, 10 + 4, false); // row 1, gate 2, elem 4
        let g1 = p.lanes(5);
        assert!(!g1.keep(0, 2));
        assert!(g1.keep(1, 2));
        let g2 = p.lanes(10);
        assert!(!g2.keep(1, 4));
        assert!(g2.keep(0, 4));
        g2.check(2, 5); // in bounds
    }

    #[test]
    fn packed_storage_is_16x_smaller_than_fx16_words() {
        // 8 lanes x 4 gates x 64 elements: Fx16 masks are 2 bytes/elem.
        let p = BitPlanes::ones(8, 4 * 64);
        let fx16_bytes = 8 * 4 * 64 * 2;
        assert_eq!(p.bytes() * 16, fx16_bytes);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn lane_bounds_are_checked() {
        let p = BitPlanes::ones(2, 8);
        p.lanes(60).check(2, 8); // row 1 would read past the words
    }
}
