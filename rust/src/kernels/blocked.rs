//! Blocked production kernel: weight row outer, sample block inner.
//!
//! Rows (MC samples x batched beats) are processed in chunks of
//! `s_block`. Within a chunk the loop nest is inverted relative to the
//! scalar reference: each weight row `w[i]` is fetched **once** and
//! MAC'd into every live accumulator row before the next row is
//! touched — the paper's weight-fetch amortisation (Sec. IV), with
//! `[s_block x out_dim]` accumulators playing the role of the engine's
//! parallel sample lanes.
//!
//! The schedule itself lives in the shared cores
//! ([`super::run_fx_blocked`] / [`super::run_f32_blocked`]); this
//! backend contributes the plain per-element row MAC. Bit-exactness:
//! for a fixed output element `(r, k)` the terms still arrive in
//! ascending `i`, so results are bit-identical to
//! [`super::ScalarKernel`] (asserted by the property tests in
//! `super::tests` for both `Fx16` and `f32`, packed planes and bitplane
//! masks included).

use super::packed::{with_plane, WeightElem};
use super::{
    check_bounds_f32, check_bounds_fx, run_f32_blocked, run_fx_blocked,
    Kernel, MaskRef, PackedWeights,
};
use crate::fixedpoint::{Fx16, MacAcc};

pub struct BlockedKernel {
    /// Live accumulator rows per chunk (the MC-sample block size).
    pub s_block: usize,
}

impl Default for BlockedKernel {
    fn default() -> Self {
        Self { s_block: super::DEFAULT_S_BLOCK }
    }
}

/// Plain row MAC: one widening multiply-accumulate per output element.
#[inline(always)]
fn mac_row<W: WeightElem>(xi: i16, wrow: &[W], acc_r: &mut [MacAcc]) {
    for (a, &wv) in acc_r.iter_mut().zip(wrow) {
        a.mac_raw(xi, wv.raw());
    }
}

impl Kernel for BlockedKernel {
    fn name(&self) -> &'static str {
        "blocked"
    }

    fn mvm_fx(
        &self,
        w: &[Fx16],
        in_dim: usize,
        out_dim: usize,
        rows: usize,
        x: &[Fx16],
        x_stride: usize,
        mask: Option<MaskRef>,
        acc: &mut [MacAcc],
        acc_stride: usize,
    ) {
        check_bounds_fx(
            w.len(),
            in_dim,
            out_dim,
            rows,
            x.len(),
            x_stride,
            mask.as_ref(),
            acc.len(),
            acc_stride,
        );
        run_fx_blocked(
            self.s_block,
            w,
            in_dim,
            out_dim,
            rows,
            x,
            x_stride,
            mask,
            acc,
            acc_stride,
            mac_row,
        );
    }

    fn mvm_fx_packed(
        &self,
        w: &PackedWeights,
        rows: usize,
        x: &[Fx16],
        x_stride: usize,
        mask: Option<MaskRef>,
        acc: &mut [MacAcc],
        acc_stride: usize,
    ) {
        check_bounds_fx(
            w.len(),
            w.in_dim,
            w.out_dim,
            rows,
            x.len(),
            x_stride,
            mask.as_ref(),
            acc.len(),
            acc_stride,
        );
        with_plane!(w, p => run_fx_blocked(
            self.s_block,
            p,
            w.in_dim,
            w.out_dim,
            rows,
            x,
            x_stride,
            mask,
            acc,
            acc_stride,
            mac_row,
        ));
    }

    fn mvm_f32(
        &self,
        w: &[f32],
        in_dim: usize,
        out_dim: usize,
        rows: usize,
        x: &[f32],
        x_stride: usize,
        mask: Option<(&[f32], usize)>,
        out: &mut [f32],
        out_stride: usize,
    ) {
        check_bounds_f32(
            w.len(),
            in_dim,
            out_dim,
            rows,
            x.len(),
            x_stride,
            mask.map(|(m, s)| (m.len(), s)),
            out.len(),
            out_stride,
        );
        run_f32_blocked(
            self.s_block,
            w,
            in_dim,
            out_dim,
            rows,
            x,
            x_stride,
            mask,
            out,
            out_stride,
            |xv, wrow, out_r| {
                for (o, &wv) in out_r.iter_mut().zip(wrow) {
                    *o += xv * wv;
                }
            },
        );
    }
}
