//! Blocked production kernel: weight row outer, sample block inner.
//!
//! Rows (MC samples x batched beats) are processed in chunks of
//! `s_block`. Within a chunk the loop nest is inverted relative to the
//! scalar reference: each weight row `w[i]` is fetched **once** and
//! MAC'd into every live accumulator row before the next row is
//! touched — the paper's weight-fetch amortisation (Sec. IV), with
//! `[s_block x out_dim]` accumulators playing the role of the engine's
//! parallel sample lanes.
//!
//! Bit-exactness: for a fixed output element `(r, k)` the terms still
//! arrive in ascending `i`, so results are bit-identical to
//! [`super::ScalarKernel`] (asserted by the property tests in
//! `super::tests` for both `Fx16` and `f32`).

use super::{check_bounds, Kernel};
use crate::fixedpoint::{Fx16, MacAcc};

pub struct BlockedKernel {
    /// Live accumulator rows per chunk (the MC-sample block size).
    pub s_block: usize,
}

impl Default for BlockedKernel {
    fn default() -> Self {
        Self { s_block: super::DEFAULT_S_BLOCK }
    }
}

impl Kernel for BlockedKernel {
    fn name(&self) -> &'static str {
        "blocked"
    }

    fn mvm_fx(
        &self,
        w: &[Fx16],
        in_dim: usize,
        out_dim: usize,
        rows: usize,
        x: &[Fx16],
        x_stride: usize,
        mask: Option<(&[Fx16], usize)>,
        acc: &mut [MacAcc],
        acc_stride: usize,
    ) {
        check_bounds(
            w.len(),
            in_dim,
            out_dim,
            rows,
            x.len(),
            x_stride,
            mask.map(|(m, s)| (m.len(), s)),
            acc.len(),
            acc_stride,
        );
        let s_block = self.s_block.max(1);
        let mut r0 = 0;
        while r0 < rows {
            let r1 = (r0 + s_block).min(rows);
            for i in 0..in_dim {
                let wrow = &w[i * out_dim..(i + 1) * out_dim];
                for r in r0..r1 {
                    let xi = x[r * x_stride + i];
                    if xi.0 == 0 {
                        continue; // DX gating, as in the scalar kernel
                    }
                    if let Some((m, ms)) = mask {
                        if m[r * ms + i].0 == 0 {
                            continue;
                        }
                    }
                    let acc_r =
                        &mut acc[r * acc_stride..r * acc_stride + out_dim];
                    for (a, &wv) in acc_r.iter_mut().zip(wrow) {
                        a.mac(xi, wv);
                    }
                }
            }
            r0 = r1;
        }
    }

    fn mvm_f32(
        &self,
        w: &[f32],
        in_dim: usize,
        out_dim: usize,
        rows: usize,
        x: &[f32],
        x_stride: usize,
        mask: Option<(&[f32], usize)>,
        out: &mut [f32],
        out_stride: usize,
    ) {
        check_bounds(
            w.len(),
            in_dim,
            out_dim,
            rows,
            x.len(),
            x_stride,
            mask.map(|(m, s)| (m.len(), s)),
            out.len(),
            out_stride,
        );
        let s_block = self.s_block.max(1);
        let mut r0 = 0;
        while r0 < rows {
            let r1 = (r0 + s_block).min(rows);
            for i in 0..in_dim {
                let wrow = &w[i * out_dim..(i + 1) * out_dim];
                for r in r0..r1 {
                    let xi = x[r * x_stride + i];
                    let xv = match mask {
                        Some((m, ms)) => xi * m[r * ms + i],
                        None => xi,
                    };
                    if xv == 0.0 {
                        continue;
                    }
                    let out_r =
                        &mut out[r * out_stride..r * out_stride + out_dim];
                    for (o, &wv) in out_r.iter_mut().zip(wrow) {
                        *o += xv * wv;
                    }
                }
            }
            r0 = r1;
        }
    }
}
