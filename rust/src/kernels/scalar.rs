//! Scalar reference kernel: row-at-a-time, the exact loop nest the
//! engines shipped with (sample outer, weight row inner). Every weight
//! row is re-fetched once per sample — the per-sample cost model the
//! blocked kernel amortises away. Kept as the bit-exactness oracle and
//! the bench baseline.

use super::{check_bounds, Kernel};
use crate::fixedpoint::{Fx16, MacAcc};

pub struct ScalarKernel;

impl Kernel for ScalarKernel {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn mvm_fx(
        &self,
        w: &[Fx16],
        in_dim: usize,
        out_dim: usize,
        rows: usize,
        x: &[Fx16],
        x_stride: usize,
        mask: Option<(&[Fx16], usize)>,
        acc: &mut [MacAcc],
        acc_stride: usize,
    ) {
        check_bounds(
            w.len(),
            in_dim,
            out_dim,
            rows,
            x.len(),
            x_stride,
            mask.map(|(m, s)| (m.len(), s)),
            acc.len(),
            acc_stride,
        );
        for r in 0..rows {
            let xr = &x[r * x_stride..r * x_stride + in_dim];
            let acc_r = &mut acc[r * acc_stride..r * acc_stride + out_dim];
            for (i, &xi) in xr.iter().enumerate() {
                if xi.0 == 0 {
                    continue; // gated by DX: zero rows do no switching
                }
                if let Some((m, ms)) = mask {
                    if m[r * ms + i].0 == 0 {
                        continue;
                    }
                }
                let wrow = &w[i * out_dim..(i + 1) * out_dim];
                for (a, &wv) in acc_r.iter_mut().zip(wrow) {
                    a.mac(xi, wv);
                }
            }
        }
    }

    fn mvm_f32(
        &self,
        w: &[f32],
        in_dim: usize,
        out_dim: usize,
        rows: usize,
        x: &[f32],
        x_stride: usize,
        mask: Option<(&[f32], usize)>,
        out: &mut [f32],
        out_stride: usize,
    ) {
        check_bounds(
            w.len(),
            in_dim,
            out_dim,
            rows,
            x.len(),
            x_stride,
            mask.map(|(m, s)| (m.len(), s)),
            out.len(),
            out_stride,
        );
        for r in 0..rows {
            let xr = &x[r * x_stride..r * x_stride + in_dim];
            let out_r = &mut out[r * out_stride..r * out_stride + out_dim];
            for (i, &xi) in xr.iter().enumerate() {
                let xv = match mask {
                    Some((m, ms)) => xi * m[r * ms + i],
                    None => xi,
                };
                if xv == 0.0 {
                    continue;
                }
                let wrow = &w[i * out_dim..(i + 1) * out_dim];
                for (o, &wv) in out_r.iter_mut().zip(wrow) {
                    *o += xv * wv;
                }
            }
        }
    }
}
