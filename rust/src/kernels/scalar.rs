//! Scalar reference kernel: row-at-a-time, the exact loop nest the
//! engines shipped with (sample outer, weight row inner). Every weight
//! row is re-fetched once per sample — the per-sample cost model the
//! blocked kernels amortise away. Kept as the bit-exactness oracle and
//! the bench baseline.

use super::packed::{with_plane, WeightElem};
use super::{check_bounds_f32, check_bounds_fx, Kernel, MaskRef, PackedWeights};
use crate::fixedpoint::{Fx16, MacAcc};

pub struct ScalarKernel;

/// The shared fixed-point core, generic over the weight plane element
/// (`Fx16`, packed `i8`, packed `i16`): widened in-register at MAC
/// time, so every instantiation computes identical bits.
fn run_fx<W: WeightElem>(
    w: &[W],
    in_dim: usize,
    out_dim: usize,
    rows: usize,
    x: &[Fx16],
    x_stride: usize,
    mask: Option<MaskRef>,
    acc: &mut [MacAcc],
    acc_stride: usize,
) {
    for r in 0..rows {
        let xr = &x[r * x_stride..r * x_stride + in_dim];
        let acc_r = &mut acc[r * acc_stride..r * acc_stride + out_dim];
        for (i, &xi) in xr.iter().enumerate() {
            if xi.0 == 0 {
                continue; // gated by DX: zero rows do no switching
            }
            if let Some(m) = mask {
                if !m.keep(r, i) {
                    continue;
                }
            }
            let wrow = &w[i * out_dim..(i + 1) * out_dim];
            for (a, &wv) in acc_r.iter_mut().zip(wrow) {
                a.mac_raw(xi.0, wv.raw());
            }
        }
    }
}

impl Kernel for ScalarKernel {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn mvm_fx(
        &self,
        w: &[Fx16],
        in_dim: usize,
        out_dim: usize,
        rows: usize,
        x: &[Fx16],
        x_stride: usize,
        mask: Option<MaskRef>,
        acc: &mut [MacAcc],
        acc_stride: usize,
    ) {
        check_bounds_fx(
            w.len(),
            in_dim,
            out_dim,
            rows,
            x.len(),
            x_stride,
            mask.as_ref(),
            acc.len(),
            acc_stride,
        );
        run_fx(w, in_dim, out_dim, rows, x, x_stride, mask, acc, acc_stride);
    }

    fn mvm_fx_packed(
        &self,
        w: &PackedWeights,
        rows: usize,
        x: &[Fx16],
        x_stride: usize,
        mask: Option<MaskRef>,
        acc: &mut [MacAcc],
        acc_stride: usize,
    ) {
        check_bounds_fx(
            w.len(),
            w.in_dim,
            w.out_dim,
            rows,
            x.len(),
            x_stride,
            mask.as_ref(),
            acc.len(),
            acc_stride,
        );
        with_plane!(w, p => run_fx(
            p, w.in_dim, w.out_dim, rows, x, x_stride, mask, acc,
            acc_stride,
        ));
    }

    fn mvm_f32(
        &self,
        w: &[f32],
        in_dim: usize,
        out_dim: usize,
        rows: usize,
        x: &[f32],
        x_stride: usize,
        mask: Option<(&[f32], usize)>,
        out: &mut [f32],
        out_stride: usize,
    ) {
        check_bounds_f32(
            w.len(),
            in_dim,
            out_dim,
            rows,
            x.len(),
            x_stride,
            mask.map(|(m, s)| (m.len(), s)),
            out.len(),
            out_stride,
        );
        for r in 0..rows {
            let xr = &x[r * x_stride..r * x_stride + in_dim];
            let out_r = &mut out[r * out_stride..r * out_stride + out_dim];
            for (i, &xi) in xr.iter().enumerate() {
                let xv = match mask {
                    Some((m, ms)) => xi * m[r * ms + i],
                    None => xi,
                };
                if xv == 0.0 {
                    continue;
                }
                let wrow = &w[i * out_dim..(i + 1) * out_dim];
                for (o, &wv) in out_r.iter_mut().zip(wrow) {
                    *o += xv * wv;
                }
            }
        }
    }
}
