//! Runtime-dispatched multi-backend kernel layer (paper Sec. IV
//! co-design).
//!
//! The paper's central hardware win is amortising each weight fetch
//! across Monte-Carlo samples and batched inputs: the LSTM engines keep
//! one copy of the weights on chip and stream S MC samples (and B
//! batched beats) through them, so a weight row is read once per
//! timestep instead of once per sample. This module is the shared
//! kernel layer that gives every matrix-vector hot loop in the crate —
//! the float model ([`crate::nn`]), the fixed-point engines
//! ([`crate::fpga::engine`]) and the serving fleet's batched entry
//! points — that same amortisation, behind one [`Kernel`] contract
//! with three selectable backends (`docs/kernels.md` §Backends):
//!
//! * [`ScalarKernel`] — the reference. Row-at-a-time, literally the
//!   loop nest the engines shipped with (sample outer, weight row
//!   inner). Kept for equivalence tests and as the bench baseline.
//! * [`BlockedKernel`] — weight row outer, sample block inner: each
//!   fetched row is MAC'd into up to `s_block` accumulator rows before
//!   the next row is touched (`[S_block x out_dim]` live accumulators,
//!   the Fig. 2 gate-engine shape).
//! * [`SimdKernel`] — the blocked schedule with the inner `out_dim`
//!   loop tiled into fixed-width lanes ([`simd::LANES`]) the compiler
//!   autovectorizes (stable Rust, no intrinsics, no new deps).
//! * [`ParallelKernel`] — the blocked schedule with the sample rows
//!   partitioned across a small persistent thread pool ([`parallel`]);
//!   each chunk delegates to [`BlockedKernel`], so the per-element
//!   term order — and therefore the bits — cannot diverge.
//!
//! The backend is selected at runtime through the [`KernelBackend`]
//! registry: process-wide via `REPRO_KERNEL` / [`set_default_backend`]
//! (the `repro serve --kernel` flag), per engine via the `set_backend`
//! hooks in [`crate::fpga::engine`] / [`crate::fpga::accel`] /
//! [`crate::coordinator`].
//!
//! Two further operand-packing layers mirror the accelerator's
//! bandwidth story on the software side:
//!
//! * [`PackedWeights`] — q8 weight planes stored as `i8` rows (i16 at
//!   q12/q16), widened in-register at MAC time ([`packed`]).
//! * [`BitPlanes`] / [`MaskRef::Bits`] — dropout masks packed one bit
//!   per element, probed directly by the kernels ([`bitplane`]).
//!
//! ## Bit-exactness contract
//!
//! All backends produce **bit-identical** results (`docs/kernels.md`):
//! for every output element `(r, k)` the contributing terms are
//! accumulated in ascending weight-row order `i`, whatever the blocking
//! or lane tiling. For the fixed-point path that is trivially exact
//! (the [`MacAcc`] accumulator is a plain `i64` add); for `f32` the
//! identical term order makes float rounding identical too. Packed
//! weights and bitplane masks preserve the contract because widening a
//! raw lattice point and probing a mask bit are both exact. The
//! property tests below assert bitwise equality across random shapes,
//! strides, block sizes, mask representations and weight planes;
//! `fpga::engine`, `fpga::accel` and `coordinator::engines` assert the
//! same contract at the engine, accelerator and fleet levels.
//!
//! ## Masking semantics
//!
//! Masks are the MC-dropout DX gates (binary keep/drop):
//!
//! * fixed point: a dropped row is *skipped* (the engine's DX gating —
//!   zero rows do no switching); kept rows use `x[i]` unchanged. The
//!   mask is either strided `Fx16` lanes ([`MaskRef::Lanes`], zero raw
//!   = drop) or a packed bitplane ([`MaskRef::Bits`], clear bit =
//!   drop) — identical skip set either way.
//! * float: the masked input is `x[i] * mask[i]` (the software models
//!   multiply by the {0.0, 1.0} mask before the matmul); rows whose
//!   masked value is exactly `0.0` are skipped, matching the zero-skip
//!   in the original `nn::lstm` loops.

pub mod bitplane;
pub mod blocked;
pub mod maskbank;
pub mod packed;
pub mod parallel;
pub mod scalar;
pub mod simd;

pub use bitplane::{BitLanes, BitPlanes};
pub use blocked::BlockedKernel;
pub use maskbank::{MaskBank, MaskBankStats};
pub use packed::{PackedWeights, WeightElem};
pub use parallel::ParallelKernel;
pub use scalar::ScalarKernel;
pub use simd::SimdKernel;

use std::sync::atomic::{AtomicU8, Ordering};

use crate::fixedpoint::{Fx16, MacAcc};

/// Default MC-sample block: 16 live accumulator rows keeps the working
/// set (`s_block * out_dim` accumulators) inside L1 for the paper's
/// hidden sizes while amortising each weight-row fetch 16x.
pub const DEFAULT_S_BLOCK: usize = 16;

/// The selectable kernel backends (`docs/kernels.md` §Backends).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum KernelBackend {
    /// Legacy per-sample loop nest — the bit-exactness oracle and bench
    /// baseline.
    Scalar = 0,
    /// Weight-row-outer sample blocking (the PR 3 production kernel).
    Blocked = 1,
    /// Blocked schedule + fixed-width autovectorized lanes.
    Simd = 2,
    /// Blocked schedule with sample rows partitioned across a small
    /// persistent thread pool (stable Rust, zero deps).
    Parallel = 3,
}

impl KernelBackend {
    pub const ALL: [KernelBackend; 4] = [
        KernelBackend::Scalar,
        KernelBackend::Blocked,
        KernelBackend::Simd,
        KernelBackend::Parallel,
    ];

    pub fn name(self) -> &'static str {
        match self {
            KernelBackend::Scalar => "scalar",
            KernelBackend::Blocked => "blocked",
            KernelBackend::Simd => "simd",
            KernelBackend::Parallel => "parallel",
        }
    }

    /// Parse a CLI / `REPRO_KERNEL` selector.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.trim() {
            "scalar" => Ok(KernelBackend::Scalar),
            "blocked" => Ok(KernelBackend::Blocked),
            "simd" => Ok(KernelBackend::Simd),
            "parallel" => Ok(KernelBackend::Parallel),
            other => Err(format!(
                "unknown kernel backend {other:?} \
                 (scalar | blocked | simd | parallel)"
            )),
        }
    }

    /// The registry: one static instance per backend.
    pub fn kernel(self) -> &'static dyn Kernel {
        static SCALAR: ScalarKernel = ScalarKernel;
        static BLOCKED: BlockedKernel =
            BlockedKernel { s_block: DEFAULT_S_BLOCK };
        static SIMD: SimdKernel = SimdKernel { s_block: DEFAULT_S_BLOCK };
        static PARALLEL: ParallelKernel =
            ParallelKernel { s_block: DEFAULT_S_BLOCK };
        match self {
            KernelBackend::Scalar => &SCALAR,
            KernelBackend::Blocked => &BLOCKED,
            KernelBackend::Simd => &SIMD,
            KernelBackend::Parallel => &PARALLEL,
        }
    }

    fn from_u8(v: u8) -> Self {
        match v {
            0 => KernelBackend::Scalar,
            2 => KernelBackend::Simd,
            3 => KernelBackend::Parallel,
            _ => KernelBackend::Blocked,
        }
    }
}

/// Sentinel: the process default has not been resolved yet.
const BACKEND_UNSET: u8 = u8::MAX;
static DEFAULT_BACKEND: AtomicU8 = AtomicU8::new(BACKEND_UNSET);

/// The process-wide default backend: `REPRO_KERNEL` if set and valid
/// (resolved once), otherwise [`KernelBackend::Blocked`]. Engines
/// capture it at construction; [`set_default_backend`] (the `--kernel`
/// flag) overrides it for everything constructed afterwards.
pub fn default_backend() -> KernelBackend {
    match DEFAULT_BACKEND.load(Ordering::Relaxed) {
        BACKEND_UNSET => {
            let b = std::env::var("REPRO_KERNEL")
                .ok()
                .and_then(|s| match KernelBackend::parse(&s) {
                    Ok(b) => Some(b),
                    Err(e) => {
                        eprintln!("note: REPRO_KERNEL ignored — {e}");
                        None
                    }
                })
                .unwrap_or(KernelBackend::Blocked);
            DEFAULT_BACKEND.store(b as u8, Ordering::Relaxed);
            b
        }
        v => KernelBackend::from_u8(v),
    }
}

/// Override the process-wide default backend (CLI `--kernel`). Every
/// backend computes bit-identical results, so flipping this mid-run
/// changes cost shape, never numerics.
pub fn set_default_backend(b: KernelBackend) {
    DEFAULT_BACKEND.store(b as u8, Ordering::Relaxed);
}

/// The kernel used by paths without an engine-level backend override
/// (the float model's forward loops).
#[inline]
pub fn active() -> &'static dyn Kernel {
    default_backend().kernel()
}

/// A dropout-mask view the fixed-point kernels probe per element.
#[derive(Debug, Clone, Copy)]
pub enum MaskRef<'a> {
    /// Strided `{0, 1}` `Fx16` lanes: element `(r, i)` at
    /// `m[r * stride + i]`; zero raw value = drop.
    Lanes(&'a [Fx16], usize),
    /// Packed bitplane lanes (1 bit/element): clear bit = drop.
    Bits(BitLanes<'a>),
}

impl<'a> MaskRef<'a> {
    #[inline(always)]
    pub fn keep(&self, r: usize, i: usize) -> bool {
        match self {
            MaskRef::Lanes(m, stride) => m[r * stride + i].0 != 0,
            MaskRef::Bits(b) => b.keep(r, i),
        }
    }

    /// The same mask shifted down `r0` rows: element `(r, i)` of the
    /// result is element `(r0 + r, i)` of the original. This is how the
    /// parallel backend hands each row chunk a correctly-offset view.
    #[inline]
    pub(crate) fn offset_rows(&self, r0: usize) -> MaskRef<'a> {
        match *self {
            MaskRef::Lanes(m, stride) => {
                MaskRef::Lanes(&m[r0 * stride..], stride)
            }
            MaskRef::Bits(b) => MaskRef::Bits(BitLanes {
                words: b.words,
                base: b.base + r0 * b.stride,
                stride: b.stride,
            }),
        }
    }

    fn check(&self, rows: usize, in_dim: usize) {
        if rows == 0 {
            return;
        }
        match self {
            MaskRef::Lanes(m, stride) => assert!(
                (rows - 1) * stride + in_dim <= m.len(),
                "mask rows out of bounds"
            ),
            MaskRef::Bits(b) => b.check(rows, in_dim),
        }
    }
}

/// A blocked masked matrix-vector-multiply kernel over row-major
/// `[in_dim][out_dim]` weights.
///
/// For each row `r` in `0..rows`, reading input row
/// `x[r * x_stride ..][..in_dim]` and (if present) mask element
/// `(r, i)`, the kernel accumulates
///
/// ```text
///   out[r * out_stride + k] += masked(x_r[i]) * w[i * out_dim + k]
/// ```
///
/// over the kept rows `i` in **ascending order** — the bit-exactness
/// contract every backend shares. Strides let callers point the kernel
/// directly at interleaved tensors (e.g. per-gate mask lanes in a
/// `[rows][GATES][dim]` buffer) without gather copies.
pub trait Kernel: Sync {
    fn name(&self) -> &'static str;

    /// Fixed-point MVM into wide [`MacAcc`] accumulators (the DSP48
    /// cascade). Kept rows use `x[i]` unchanged; a dropped mask element
    /// or `x[i].0 == 0` skips the row (DX gating).
    fn mvm_fx(
        &self,
        w: &[Fx16],
        in_dim: usize,
        out_dim: usize,
        rows: usize,
        x: &[Fx16],
        x_stride: usize,
        mask: Option<MaskRef>,
        acc: &mut [MacAcc],
        acc_stride: usize,
    );

    /// Fixed-point MVM over a packed narrow weight plane — identical
    /// contract and bits as [`Kernel::mvm_fx`] on the unpacked plane;
    /// the narrow rows are widened in-register at MAC time.
    fn mvm_fx_packed(
        &self,
        w: &PackedWeights,
        rows: usize,
        x: &[Fx16],
        x_stride: usize,
        mask: Option<MaskRef>,
        acc: &mut [MacAcc],
        acc_stride: usize,
    );

    /// Float MVM accumulating into `out` (add, not overwrite — callers
    /// preload bias rows). The masked input is `x[i] * mask[i]`; exact
    /// zeros are skipped.
    fn mvm_f32(
        &self,
        w: &[f32],
        in_dim: usize,
        out_dim: usize,
        rows: usize,
        x: &[f32],
        x_stride: usize,
        mask: Option<(&[f32], usize)>,
        out: &mut [f32],
        out_stride: usize,
    );
}

/// Shared bounds checks: every row's input, mask and output slice must
/// lie inside its buffer.
#[inline]
pub(crate) fn check_bounds_fx(
    w_len: usize,
    in_dim: usize,
    out_dim: usize,
    rows: usize,
    x_len: usize,
    x_stride: usize,
    mask: Option<&MaskRef>,
    out_len: usize,
    out_stride: usize,
) {
    assert_eq!(w_len, in_dim * out_dim, "weight shape mismatch");
    if rows == 0 {
        return;
    }
    assert!(
        (rows - 1) * x_stride + in_dim <= x_len,
        "input rows out of bounds"
    );
    if let Some(m) = mask {
        m.check(rows, in_dim);
    }
    assert!(
        (rows - 1) * out_stride + out_dim <= out_len,
        "output rows out of bounds"
    );
}

/// Shared blocked-schedule fixed-point core (weight row outer, sample
/// block inner), generic over the weight element and the per-row MAC:
/// [`BlockedKernel`] passes the plain element loop, [`SimdKernel`] the
/// lane-tiled one. Keeping the schedule — skip set, chunking, ascending
/// `i` — in exactly one place is what keeps the backends' bit-exactness
/// contract from silently diverging.
#[inline(always)]
pub(crate) fn run_fx_blocked<W: WeightElem>(
    s_block: usize,
    w: &[W],
    in_dim: usize,
    out_dim: usize,
    rows: usize,
    x: &[Fx16],
    x_stride: usize,
    mask: Option<MaskRef>,
    acc: &mut [MacAcc],
    acc_stride: usize,
    mac_row: impl Fn(i16, &[W], &mut [MacAcc]),
) {
    let s_block = s_block.max(1);
    let mut r0 = 0;
    while r0 < rows {
        let r1 = (r0 + s_block).min(rows);
        for i in 0..in_dim {
            let wrow = &w[i * out_dim..(i + 1) * out_dim];
            for r in r0..r1 {
                let xi = x[r * x_stride + i];
                if xi.0 == 0 {
                    continue; // DX gating, as in the scalar kernel
                }
                if let Some(m) = mask {
                    if !m.keep(r, i) {
                        continue;
                    }
                }
                mac_row(
                    xi.0,
                    wrow,
                    &mut acc[r * acc_stride..r * acc_stride + out_dim],
                );
            }
        }
        r0 = r1;
    }
}

/// Float twin of [`run_fx_blocked`]: same schedule, `x * mask`
/// semantics with exact-zero skip.
#[inline(always)]
pub(crate) fn run_f32_blocked(
    s_block: usize,
    w: &[f32],
    in_dim: usize,
    out_dim: usize,
    rows: usize,
    x: &[f32],
    x_stride: usize,
    mask: Option<(&[f32], usize)>,
    out: &mut [f32],
    out_stride: usize,
    mac_row: impl Fn(f32, &[f32], &mut [f32]),
) {
    let s_block = s_block.max(1);
    let mut r0 = 0;
    while r0 < rows {
        let r1 = (r0 + s_block).min(rows);
        for i in 0..in_dim {
            let wrow = &w[i * out_dim..(i + 1) * out_dim];
            for r in r0..r1 {
                let xi = x[r * x_stride + i];
                let xv = match mask {
                    Some((m, ms)) => xi * m[r * ms + i],
                    None => xi,
                };
                if xv == 0.0 {
                    continue;
                }
                mac_row(
                    xv,
                    wrow,
                    &mut out[r * out_stride..r * out_stride + out_dim],
                );
            }
        }
        r0 = r1;
    }
}

/// The float-path bounds checks (mask is a strided `f32` buffer).
#[inline]
pub(crate) fn check_bounds_f32(
    w_len: usize,
    in_dim: usize,
    out_dim: usize,
    rows: usize,
    x_len: usize,
    x_stride: usize,
    mask: Option<(usize, usize)>,
    out_len: usize,
    out_stride: usize,
) {
    assert_eq!(w_len, in_dim * out_dim, "weight shape mismatch");
    if rows == 0 {
        return;
    }
    assert!(
        (rows - 1) * x_stride + in_dim <= x_len,
        "input rows out of bounds"
    );
    if let Some((m_len, m_stride)) = mask {
        assert!(
            (rows - 1) * m_stride + in_dim <= m_len,
            "mask rows out of bounds"
        );
    }
    assert!(
        (rows - 1) * out_stride + out_dim <= out_len,
        "output rows out of bounds"
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::QFormat;
    use crate::rng::Rng;

    /// Random Fx16 in roughly [-2, 2] with exact zeros sprinkled in.
    fn rand_fx(rng: &mut Rng, zero_p: f64) -> Fx16 {
        if rng.bernoulli(zero_p) {
            Fx16::ZERO
        } else {
            Fx16::from_f32(rng.uniform_in(-2.0, 2.0) as f32)
        }
    }

    fn rand_mask_fx(rng: &mut Rng, drop_p: f64) -> Fx16 {
        if rng.bernoulli(drop_p) {
            Fx16::ZERO
        } else {
            Fx16::ONE
        }
    }

    fn finish_all(acc: &[MacAcc]) -> Vec<i16> {
        acc.iter().map(|a| a.finish(Fx16::ZERO).0).collect()
    }

    /// Every backend is bit-identical to the scalar reference for
    /// `Fx16` across random shapes, strides, block sizes and mask
    /// patterns — the ISSUE 3 contract extended over the registry.
    #[test]
    fn all_backends_fx_bit_identical_to_scalar_reference() {
        let mut rng = Rng::new(41);
        let scalar = ScalarKernel;
        for trial in 0..60 {
            let in_dim = 1 + rng.below(24);
            let out_dim = 1 + rng.below(24);
            let rows = 1 + rng.below(12);
            let s_block = 1 + rng.below(rows + 4);
            let backends: [&dyn Kernel; 3] = [
                &BlockedKernel { s_block },
                &SimdKernel { s_block },
                &ParallelKernel { s_block },
            ];
            // Padded strides exercise the interleaved-tensor case.
            let x_stride = in_dim + rng.below(3);
            let m_stride = in_dim + rng.below(5);
            let a_stride = out_dim + rng.below(3);
            let w: Vec<Fx16> = (0..in_dim * out_dim)
                .map(|_| rand_fx(&mut rng, 0.1))
                .collect();
            let x: Vec<Fx16> = (0..rows * x_stride)
                .map(|_| rand_fx(&mut rng, 0.2))
                .collect();
            let mask: Vec<Fx16> = (0..rows * m_stride)
                .map(|_| rand_mask_fx(&mut rng, 0.125))
                .collect();
            for use_mask in [false, true] {
                // Non-zero accumulator start states must be preserved.
                let mut acc_s: Vec<MacAcc> =
                    vec![MacAcc::new(); rows * a_stride];
                for (j, a) in acc_s.iter_mut().enumerate() {
                    a.mac(Fx16(j as i16 % 7), Fx16::ONE);
                }
                let init = acc_s.clone();
                let m = use_mask
                    .then_some(MaskRef::Lanes(mask.as_slice(), m_stride));
                scalar.mvm_fx(
                    &w, in_dim, out_dim, rows, &x, x_stride, m, &mut acc_s,
                    a_stride,
                );
                let want = finish_all(&acc_s);
                for k in backends {
                    let mut acc_b = init.clone();
                    k.mvm_fx(
                        &w, in_dim, out_dim, rows, &x, x_stride, m,
                        &mut acc_b, a_stride,
                    );
                    assert_eq!(
                        want,
                        finish_all(&acc_b),
                        "trial {trial} (mask {use_mask}, s_block \
                         {s_block}): {} Fx16 kernel drifted from scalar \
                         reference",
                        k.name()
                    );
                }
            }
        }
    }

    /// Same property for the float kernel: identical term order makes
    /// float rounding identical, so equality is bitwise here too.
    #[test]
    fn all_backends_f32_bit_identical_to_scalar_reference() {
        let mut rng = Rng::new(97);
        let scalar = ScalarKernel;
        for trial in 0..60 {
            let in_dim = 1 + rng.below(20);
            let out_dim = 1 + rng.below(20);
            let rows = 1 + rng.below(10);
            let s_block = 1 + rng.below(8);
            let backends: [&dyn Kernel; 3] = [
                &BlockedKernel { s_block },
                &SimdKernel { s_block },
                &ParallelKernel { s_block },
            ];
            let x_stride = in_dim + rng.below(4);
            let m_stride = in_dim;
            let o_stride = out_dim + rng.below(4);
            let w: Vec<f32> = (0..in_dim * out_dim)
                .map(|_| rng.normal() as f32)
                .collect();
            let x: Vec<f32> = (0..rows * x_stride)
                .map(|_| {
                    if rng.bernoulli(0.15) { 0.0 } else { rng.normal() as f32 }
                })
                .collect();
            let mask: Vec<f32> = (0..rows * m_stride)
                .map(|_| if rng.bernoulli(0.125) { 0.0 } else { 1.0 })
                .collect();
            for use_mask in [false, true] {
                let init: Vec<f32> = (0..rows * o_stride)
                    .map(|_| rng.normal() as f32)
                    .collect();
                let mut out_s = init.clone();
                let m = use_mask.then_some((mask.as_slice(), m_stride));
                scalar.mvm_f32(
                    &w, in_dim, out_dim, rows, &x, x_stride, m, &mut out_s,
                    o_stride,
                );
                let bits_s: Vec<u32> =
                    out_s.iter().map(|v| v.to_bits()).collect();
                for k in backends {
                    let mut out_b = init.clone();
                    k.mvm_f32(
                        &w, in_dim, out_dim, rows, &x, x_stride, m,
                        &mut out_b, o_stride,
                    );
                    let bits_b: Vec<u32> =
                        out_b.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(
                        bits_s,
                        bits_b,
                        "trial {trial} (mask {use_mask}): {} f32 kernel \
                         drifted from scalar reference",
                        k.name()
                    );
                }
            }
        }
    }

    /// Bitplane masks select exactly the same skip set as the strided
    /// `Fx16` lanes they replace — for every backend, bitwise.
    #[test]
    fn bitplane_masks_match_fx16_lane_masks_bitwise() {
        let mut rng = Rng::new(59);
        for trial in 0..40 {
            let in_dim = 1 + rng.below(20);
            let out_dim = 1 + rng.below(16);
            let rows = 1 + rng.below(10);
            // Gate-lane geometry: `lanes` gates interleaved per row,
            // the kernel reads lane `g`.
            let lanes = 1 + rng.below(4);
            let g = rng.below(lanes);
            let w: Vec<Fx16> = (0..in_dim * out_dim)
                .map(|_| rand_fx(&mut rng, 0.1))
                .collect();
            let x: Vec<Fx16> =
                (0..rows * in_dim).map(|_| rand_fx(&mut rng, 0.1)).collect();
            let m_stride = lanes * in_dim;
            let mut lane_buf = vec![Fx16::ONE; rows * m_stride];
            let mut planes = BitPlanes::ones(rows, m_stride);
            for r in 0..rows {
                for i in 0..m_stride {
                    let keep = !rng.bernoulli(0.2);
                    lane_buf[r * m_stride + i] =
                        if keep { Fx16::ONE } else { Fx16::ZERO };
                    planes.set(r, i, keep);
                }
            }
            for backend in KernelBackend::ALL {
                let k = backend.kernel();
                let mut acc_lane = vec![MacAcc::new(); rows * out_dim];
                let mut acc_bits = acc_lane.clone();
                k.mvm_fx(
                    &w,
                    in_dim,
                    out_dim,
                    rows,
                    &x,
                    in_dim,
                    Some(MaskRef::Lanes(&lane_buf[g * in_dim..], m_stride)),
                    &mut acc_lane,
                    out_dim,
                );
                k.mvm_fx(
                    &w,
                    in_dim,
                    out_dim,
                    rows,
                    &x,
                    in_dim,
                    Some(MaskRef::Bits(planes.lanes(g * in_dim))),
                    &mut acc_bits,
                    out_dim,
                );
                assert_eq!(
                    finish_all(&acc_lane),
                    finish_all(&acc_bits),
                    "trial {trial}: {} bitplane mask drifted",
                    backend.name()
                );
            }
        }
    }

    /// Packed weight planes are bit-identical to the unpacked `Fx16`
    /// MVM for every format and backend (the widening is exact).
    #[test]
    fn packed_mvm_matches_unpacked_bitwise_per_format() {
        for fmt in [QFormat::Q8_ACT, QFormat::Q12_ACT, QFormat::Q16_ACT] {
            let mut rng = Rng::new(fmt.total_bits as u64 + 100);
            for _ in 0..20 {
                let in_dim = 1 + rng.below(16);
                let out_dim = 1 + rng.below(16);
                let rows = 1 + rng.below(8);
                let range = fmt.max_value() as f64 * 0.9;
                let w: Vec<Fx16> = (0..in_dim * out_dim)
                    .map(|_| fmt.quantize(rng.uniform_in(-range, range) as f32))
                    .collect();
                let packed = PackedWeights::pack(&w, in_dim, out_dim, fmt);
                let x: Vec<Fx16> = (0..rows * in_dim)
                    .map(|_| {
                        if rng.bernoulli(0.2) {
                            Fx16::ZERO
                        } else {
                            fmt.quantize(rng.uniform_in(-range, range) as f32)
                        }
                    })
                    .collect();
                let mask: Vec<Fx16> = (0..rows * in_dim)
                    .map(|_| rand_mask_fx(&mut rng, 0.125))
                    .collect();
                for use_mask in [false, true] {
                    let m = use_mask
                        .then_some(MaskRef::Lanes(mask.as_slice(), in_dim));
                    for backend in KernelBackend::ALL {
                        let k = backend.kernel();
                        let mut acc_u = vec![MacAcc::new(); rows * out_dim];
                        let mut acc_p = acc_u.clone();
                        k.mvm_fx(
                            &w, in_dim, out_dim, rows, &x, in_dim, m,
                            &mut acc_u, out_dim,
                        );
                        k.mvm_fx_packed(
                            &packed, rows, &x, in_dim, m, &mut acc_p,
                            out_dim,
                        );
                        let fin = |acc: &[MacAcc]| -> Vec<i16> {
                            acc.iter()
                                .map(|a| a.finish_fmt(Fx16::ZERO, fmt).0)
                                .collect()
                        };
                        assert_eq!(
                            fin(&acc_u),
                            fin(&acc_p),
                            "{} {}: packed plane drifted",
                            fmt.name(),
                            backend.name()
                        );
                    }
                }
            }
        }
    }

    /// The kernels agree with a plain from-scratch matmul numerically
    /// (the contract is not just self-consistency).
    #[test]
    fn kernels_match_naive_matmul() {
        let mut rng = Rng::new(5);
        let (in_dim, out_dim, rows) = (7, 5, 4);
        let w: Vec<f32> =
            (0..in_dim * out_dim).map(|_| rng.normal() as f32).collect();
        let x: Vec<f32> =
            (0..rows * in_dim).map(|_| rng.normal() as f32).collect();
        for backend in KernelBackend::ALL {
            let mut out = vec![0f32; rows * out_dim];
            backend.kernel().mvm_f32(
                &w, in_dim, out_dim, rows, &x, in_dim, None, &mut out,
                out_dim,
            );
            for r in 0..rows {
                for k in 0..out_dim {
                    let want: f32 = (0..in_dim)
                        .map(|i| x[r * in_dim + i] * w[i * out_dim + k])
                        .sum();
                    let got = out[r * out_dim + k];
                    assert!(
                        (got - want).abs() < 1e-4,
                        "{} [{r}][{k}]: {got} vs {want}",
                        backend.name()
                    );
                }
            }
        }
    }

    /// Fully-masked rows contribute nothing; strided mask rows pick the
    /// right gate lane.
    #[test]
    fn mask_strides_select_the_right_rows() {
        let in_dim = 4;
        let out_dim = 3;
        let w: Vec<Fx16> = (0..in_dim * out_dim)
            .map(|j| Fx16::from_f32(0.25 * (j as f32 + 1.0)))
            .collect();
        let x = vec![Fx16::ONE; 2 * in_dim];
        // Interleaved 2-lane mask buffer: lane 0 drops everything, lane
        // 1 keeps everything.
        let mut mask = Vec::new();
        for _ in 0..2 {
            mask.extend(vec![Fx16::ZERO; in_dim]);
            mask.extend(vec![Fx16::ONE; in_dim]);
        }
        for lane in 0..2 {
            let mut acc = vec![MacAcc::new(); 2 * out_dim];
            active().mvm_fx(
                &w,
                in_dim,
                out_dim,
                2,
                &x,
                in_dim,
                Some(MaskRef::Lanes(&mask[lane * in_dim..], 2 * in_dim)),
                &mut acc,
                out_dim,
            );
            let all_zero = acc
                .iter()
                .all(|a| a.finish(Fx16::ZERO).0 == 0);
            if lane == 0 {
                assert!(all_zero, "dropped lane must not accumulate");
            } else {
                assert!(!all_zero, "kept lane must accumulate");
            }
        }
    }

    /// The kernel layer is format-agnostic — it MACs raw lattice points
    /// into wide accumulators and never shifts — so the backend
    /// bit-identity contract holds for every quantisation format the
    /// substrate supports (`docs/quantization.md`).
    #[test]
    fn backends_match_scalar_for_every_qformat() {
        let scalar = ScalarKernel;
        for fmt in [QFormat::Q8_ACT, QFormat::Q12_ACT, QFormat::Q16_ACT] {
            let mut rng = Rng::new(fmt.total_bits as u64);
            for trial in 0..20 {
                let in_dim = 1 + rng.below(16);
                let out_dim = 1 + rng.below(16);
                let rows = 1 + rng.below(8);
                let s_block = 1 + rng.below(6);
                let range = fmt.max_value() as f64 * 0.9;
                let w: Vec<Fx16> = (0..in_dim * out_dim)
                    .map(|_| fmt.quantize(rng.uniform_in(-range, range) as f32))
                    .collect();
                let x: Vec<Fx16> = (0..rows * in_dim)
                    .map(|_| {
                        if rng.bernoulli(0.2) {
                            Fx16::ZERO
                        } else {
                            fmt.quantize(rng.uniform_in(-range, range) as f32)
                        }
                    })
                    .collect();
                let mut acc_s = vec![MacAcc::new(); rows * out_dim];
                scalar.mvm_fx(
                    &w, in_dim, out_dim, rows, &x, in_dim, None, &mut acc_s,
                    out_dim,
                );
                let fin = |acc: &[MacAcc]| -> Vec<i16> {
                    acc.iter()
                        .map(|a| a.finish_fmt(Fx16::ZERO, fmt).0)
                        .collect()
                };
                let want = fin(&acc_s);
                let others: [&dyn Kernel; 3] = [
                    &BlockedKernel { s_block },
                    &SimdKernel { s_block },
                    &ParallelKernel { s_block },
                ];
                for k in others {
                    let mut acc_b = vec![MacAcc::new(); rows * out_dim];
                    k.mvm_fx(
                        &w, in_dim, out_dim, rows, &x, in_dim, None,
                        &mut acc_b, out_dim,
                    );
                    assert_eq!(
                        want,
                        fin(&acc_b),
                        "{} trial {trial}: {} kernel drifted",
                        fmt.name(),
                        k.name()
                    );
                }
            }
        }
    }

    #[test]
    fn zero_rows_are_noops() {
        for backend in KernelBackend::ALL {
            let k = backend.kernel();
            let w = vec![Fx16::ONE; 6];
            let x: Vec<Fx16> = Vec::new();
            let mut acc: Vec<MacAcc> = Vec::new();
            k.mvm_fx(&w, 2, 3, 0, &x, 2, None, &mut acc, 3);
            let packed = PackedWeights::pack(&w, 2, 3, QFormat::Q16_ACT);
            k.mvm_fx_packed(&packed, 0, &x, 2, None, &mut acc, 3);
            let mut out: Vec<f32> = Vec::new();
            k.mvm_f32(&[1.0; 6], 2, 3, 0, &[], 2, None, &mut out, 3);
        }
    }

    #[test]
    fn registry_parses_and_names_backends() {
        for b in KernelBackend::ALL {
            assert_eq!(KernelBackend::parse(b.name()).unwrap(), b);
            assert_eq!(b.kernel().name(), b.name());
        }
        assert!(KernelBackend::parse("avx512").is_err());
        // The default resolves (env-independent assertion: it is one of
        // the registered backends and dispatch follows it).
        let d = default_backend();
        assert_eq!(active().name(), d.name());
    }
}
