//! Unified blocked-kernel compute layer (paper Sec. IV co-design).
//!
//! The paper's central hardware win is amortising each weight fetch
//! across Monte-Carlo samples and batched inputs: the LSTM engines keep
//! one copy of the weights on chip and stream S MC samples (and B
//! batched beats) through them, so a weight row is read once per
//! timestep instead of once per sample. The simulator used to walk
//! every weight matrix once per sample per beat; this module is the
//! shared kernel layer that gives every matrix-vector hot loop in the
//! crate — the float model ([`crate::nn`]), the fixed-point engines
//! ([`crate::fpga::engine`]) and the serving fleet's batched entry
//! points — that same amortisation.
//!
//! Two implementations of one [`Kernel`] contract:
//!
//! * [`ScalarKernel`] — the reference. Row-at-a-time, literally the
//!   loop nest the engines shipped with (sample outer, weight row
//!   inner). Kept for equivalence tests and as the bench baseline.
//! * [`BlockedKernel`] — the production kernel. Weight row outer,
//!   sample block inner: each fetched row is MAC'd into up to
//!   `s_block` accumulator rows before the next row is touched
//!   (`[S_block x out_dim]` live accumulators, the Fig. 2 gate-engine
//!   shape).
//!
//! ## Bit-exactness contract
//!
//! Both kernels produce **bit-identical** results (`docs/kernels.md`):
//! for every output element `(r, k)` the contributing terms are
//! accumulated in ascending weight-row order `i`, whatever the blocking.
//! For the fixed-point path that is trivially exact (the [`MacAcc`]
//! accumulator is a plain `i64` add); for `f32` the identical term
//! order makes float rounding identical too. The property tests below
//! assert bitwise equality across random shapes, strides, block sizes
//! and mask patterns; `fpga::accel` asserts the same contract one level
//! up (`predict_batch` vs per-request `predict_seeded`).
//!
//! ## Masking semantics
//!
//! Masks are the MC-dropout DX gates (binary keep/drop):
//!
//! * fixed point: a row with `mask[i] == 0` is *skipped* (the engine's
//!   DX gating — zero rows do no switching); kept rows use `x[i]`
//!   unchanged.
//! * float: the masked input is `x[i] * mask[i]` (the software models
//!   multiply by the {0.0, 1.0} mask before the matmul); rows whose
//!   masked value is exactly `0.0` are skipped, matching the zero-skip
//!   in the original `nn::lstm` loops.

pub mod blocked;
pub mod scalar;

pub use blocked::BlockedKernel;
pub use scalar::ScalarKernel;

use crate::fixedpoint::{Fx16, MacAcc};

/// Default MC-sample block: 16 live accumulator rows keeps the working
/// set (`s_block * out_dim` accumulators) inside L1 for the paper's
/// hidden sizes while amortising each weight-row fetch 16x.
pub const DEFAULT_S_BLOCK: usize = 16;

/// The production kernel every engine runs on.
static ACTIVE: BlockedKernel = BlockedKernel { s_block: DEFAULT_S_BLOCK };

/// The kernel the engines use on the hot path.
#[inline]
pub fn active() -> &'static BlockedKernel {
    &ACTIVE
}

/// A blocked masked matrix-vector-multiply kernel over row-major
/// `[in_dim][out_dim]` weights.
///
/// For each row `r` in `0..rows`, reading input row
/// `x[r * x_stride ..][..in_dim]` and (if present) mask row
/// `mask[r * mask_stride ..][..in_dim]`, the kernel accumulates
///
/// ```text
///   out[r * out_stride + k] += masked(x_r[i]) * w[i * out_dim + k]
/// ```
///
/// over the kept rows `i` in **ascending order** — the bit-exactness
/// contract both implementations share. Strides let callers point the
/// kernel directly at interleaved tensors (e.g. per-gate mask rows in a
/// `[rows][GATES][dim]` buffer) without gather copies.
pub trait Kernel {
    fn name(&self) -> &'static str;

    /// Fixed-point MVM into wide [`MacAcc`] accumulators (the DSP48
    /// cascade). Kept rows use `x[i]` unchanged; `mask[i].0 == 0` or
    /// `x[i].0 == 0` skips the row (DX gating).
    #[allow(clippy::too_many_arguments)]
    fn mvm_fx(
        &self,
        w: &[Fx16],
        in_dim: usize,
        out_dim: usize,
        rows: usize,
        x: &[Fx16],
        x_stride: usize,
        mask: Option<(&[Fx16], usize)>,
        acc: &mut [MacAcc],
        acc_stride: usize,
    );

    /// Float MVM accumulating into `out` (add, not overwrite — callers
    /// preload bias rows). The masked input is `x[i] * mask[i]`; exact
    /// zeros are skipped.
    #[allow(clippy::too_many_arguments)]
    fn mvm_f32(
        &self,
        w: &[f32],
        in_dim: usize,
        out_dim: usize,
        rows: usize,
        x: &[f32],
        x_stride: usize,
        mask: Option<(&[f32], usize)>,
        out: &mut [f32],
        out_stride: usize,
    );
}

/// Shared bounds checks: every row's input, mask and output slice must
/// lie inside its buffer.
#[inline]
pub(crate) fn check_bounds(
    w_len: usize,
    in_dim: usize,
    out_dim: usize,
    rows: usize,
    x_len: usize,
    x_stride: usize,
    mask: Option<(usize, usize)>,
    out_len: usize,
    out_stride: usize,
) {
    assert_eq!(w_len, in_dim * out_dim, "weight shape mismatch");
    if rows == 0 {
        return;
    }
    assert!(
        (rows - 1) * x_stride + in_dim <= x_len,
        "input rows out of bounds"
    );
    if let Some((m_len, m_stride)) = mask {
        assert!(
            (rows - 1) * m_stride + in_dim <= m_len,
            "mask rows out of bounds"
        );
    }
    assert!(
        (rows - 1) * out_stride + out_dim <= out_len,
        "output rows out of bounds"
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Random Fx16 in roughly [-2, 2] with exact zeros sprinkled in.
    fn rand_fx(rng: &mut Rng, zero_p: f64) -> Fx16 {
        if rng.bernoulli(zero_p) {
            Fx16::ZERO
        } else {
            Fx16::from_f32(rng.uniform_in(-2.0, 2.0) as f32)
        }
    }

    fn rand_mask_fx(rng: &mut Rng, drop_p: f64) -> Fx16 {
        if rng.bernoulli(drop_p) {
            Fx16::ZERO
        } else {
            Fx16::ONE
        }
    }

    /// Blocked kernel is bit-identical to the scalar reference for
    /// `Fx16` across random shapes, strides, block sizes and mask
    /// patterns (ISSUE 3 acceptance).
    #[test]
    fn blocked_fx_bit_identical_to_scalar_reference() {
        let mut rng = Rng::new(41);
        let scalar = ScalarKernel;
        for trial in 0..60 {
            let in_dim = 1 + rng.below(24);
            let out_dim = 1 + rng.below(24);
            let rows = 1 + rng.below(12);
            let s_block = 1 + rng.below(rows + 4);
            let blocked = BlockedKernel { s_block };
            // Padded strides exercise the interleaved-tensor case.
            let x_stride = in_dim + rng.below(3);
            let m_stride = in_dim + rng.below(5);
            let a_stride = out_dim + rng.below(3);
            let w: Vec<Fx16> = (0..in_dim * out_dim)
                .map(|_| rand_fx(&mut rng, 0.1))
                .collect();
            let x: Vec<Fx16> = (0..rows * x_stride)
                .map(|_| rand_fx(&mut rng, 0.2))
                .collect();
            let mask: Vec<Fx16> = (0..rows * m_stride)
                .map(|_| rand_mask_fx(&mut rng, 0.125))
                .collect();
            for use_mask in [false, true] {
                // Non-zero accumulator start states must be preserved.
                let mut acc_s: Vec<MacAcc> =
                    vec![MacAcc::new(); rows * a_stride];
                for (j, a) in acc_s.iter_mut().enumerate() {
                    a.mac(Fx16(j as i16 % 7), Fx16::ONE);
                }
                let mut acc_b = acc_s.clone();
                let m = use_mask.then_some((mask.as_slice(), m_stride));
                scalar.mvm_fx(
                    &w, in_dim, out_dim, rows, &x, x_stride, m, &mut acc_s,
                    a_stride,
                );
                blocked.mvm_fx(
                    &w, in_dim, out_dim, rows, &x, x_stride, m, &mut acc_b,
                    a_stride,
                );
                let fin_s: Vec<i16> = acc_s
                    .iter()
                    .map(|a| a.finish(Fx16::ZERO).0)
                    .collect();
                let fin_b: Vec<i16> = acc_b
                    .iter()
                    .map(|a| a.finish(Fx16::ZERO).0)
                    .collect();
                assert_eq!(
                    fin_s, fin_b,
                    "trial {trial} (mask {use_mask}, s_block {s_block}): \
                     blocked Fx16 kernel drifted from scalar reference"
                );
            }
        }
    }

    /// Same property for the float kernel: identical term order makes
    /// float rounding identical, so equality is bitwise here too.
    #[test]
    fn blocked_f32_bit_identical_to_scalar_reference() {
        let mut rng = Rng::new(97);
        let scalar = ScalarKernel;
        for trial in 0..60 {
            let in_dim = 1 + rng.below(20);
            let out_dim = 1 + rng.below(20);
            let rows = 1 + rng.below(10);
            let blocked = BlockedKernel { s_block: 1 + rng.below(8) };
            let x_stride = in_dim + rng.below(4);
            let m_stride = in_dim;
            let o_stride = out_dim + rng.below(4);
            let w: Vec<f32> = (0..in_dim * out_dim)
                .map(|_| rng.normal() as f32)
                .collect();
            let x: Vec<f32> = (0..rows * x_stride)
                .map(|_| {
                    if rng.bernoulli(0.15) { 0.0 } else { rng.normal() as f32 }
                })
                .collect();
            let mask: Vec<f32> = (0..rows * m_stride)
                .map(|_| if rng.bernoulli(0.125) { 0.0 } else { 1.0 })
                .collect();
            for use_mask in [false, true] {
                let init: Vec<f32> = (0..rows * o_stride)
                    .map(|_| rng.normal() as f32)
                    .collect();
                let mut out_s = init.clone();
                let mut out_b = init;
                let m = use_mask.then_some((mask.as_slice(), m_stride));
                scalar.mvm_f32(
                    &w, in_dim, out_dim, rows, &x, x_stride, m, &mut out_s,
                    o_stride,
                );
                blocked.mvm_f32(
                    &w, in_dim, out_dim, rows, &x, x_stride, m, &mut out_b,
                    o_stride,
                );
                let bits_s: Vec<u32> =
                    out_s.iter().map(|v| v.to_bits()).collect();
                let bits_b: Vec<u32> =
                    out_b.iter().map(|v| v.to_bits()).collect();
                assert_eq!(
                    bits_s, bits_b,
                    "trial {trial} (mask {use_mask}): blocked f32 kernel \
                     drifted from scalar reference"
                );
            }
        }
    }

    /// The kernels agree with a plain from-scratch matmul numerically
    /// (the contract is not just self-consistency).
    #[test]
    fn kernels_match_naive_matmul() {
        let mut rng = Rng::new(5);
        let (in_dim, out_dim, rows) = (7, 5, 4);
        let w: Vec<f32> =
            (0..in_dim * out_dim).map(|_| rng.normal() as f32).collect();
        let x: Vec<f32> =
            (0..rows * in_dim).map(|_| rng.normal() as f32).collect();
        let mut out = vec![0f32; rows * out_dim];
        active().mvm_f32(
            &w, in_dim, out_dim, rows, &x, in_dim, None, &mut out, out_dim,
        );
        for r in 0..rows {
            for k in 0..out_dim {
                let want: f32 = (0..in_dim)
                    .map(|i| x[r * in_dim + i] * w[i * out_dim + k])
                    .sum();
                let got = out[r * out_dim + k];
                assert!(
                    (got - want).abs() < 1e-4,
                    "[{r}][{k}]: {got} vs {want}"
                );
            }
        }
    }

    /// Fully-masked rows contribute nothing; strided mask rows pick the
    /// right gate lane.
    #[test]
    fn mask_strides_select_the_right_rows() {
        let in_dim = 4;
        let out_dim = 3;
        let w: Vec<Fx16> = (0..in_dim * out_dim)
            .map(|j| Fx16::from_f32(0.25 * (j as f32 + 1.0)))
            .collect();
        let x = vec![Fx16::ONE; 2 * in_dim];
        // Interleaved 2-lane mask buffer: lane 0 drops everything, lane
        // 1 keeps everything.
        let mut mask = Vec::new();
        for _ in 0..2 {
            mask.extend(vec![Fx16::ZERO; in_dim]);
            mask.extend(vec![Fx16::ONE; in_dim]);
        }
        for lane in 0..2 {
            let mut acc = vec![MacAcc::new(); 2 * out_dim];
            active().mvm_fx(
                &w,
                in_dim,
                out_dim,
                2,
                &x,
                in_dim,
                Some((&mask[lane * in_dim..], 2 * in_dim)),
                &mut acc,
                out_dim,
            );
            let all_zero = acc
                .iter()
                .all(|a| a.finish(Fx16::ZERO).0 == 0);
            if lane == 0 {
                assert!(all_zero, "dropped lane must not accumulate");
            } else {
                assert!(!all_zero, "kept lane must accumulate");
            }
        }
    }

    /// The kernel layer is format-agnostic — it MACs raw lattice points
    /// into wide accumulators and never shifts — so the blocked/scalar
    /// bit-identity contract holds for every quantisation format the
    /// substrate supports (`docs/quantization.md`). This is the
    /// kernel-level leg of the ISSUE 4 acceptance.
    #[test]
    fn blocked_matches_scalar_for_every_qformat() {
        use crate::fixedpoint::QFormat;
        let scalar = ScalarKernel;
        for fmt in [QFormat::Q8_ACT, QFormat::Q12_ACT, QFormat::Q16_ACT] {
            let mut rng = Rng::new(fmt.total_bits as u64);
            for trial in 0..20 {
                let in_dim = 1 + rng.below(16);
                let out_dim = 1 + rng.below(16);
                let rows = 1 + rng.below(8);
                let blocked = BlockedKernel { s_block: 1 + rng.below(6) };
                let range = fmt.max_value() as f64 * 0.9;
                let w: Vec<Fx16> = (0..in_dim * out_dim)
                    .map(|_| fmt.quantize(rng.uniform_in(-range, range) as f32))
                    .collect();
                let x: Vec<Fx16> = (0..rows * in_dim)
                    .map(|_| {
                        if rng.bernoulli(0.2) {
                            Fx16::ZERO
                        } else {
                            fmt.quantize(rng.uniform_in(-range, range) as f32)
                        }
                    })
                    .collect();
                let mut acc_s = vec![MacAcc::new(); rows * out_dim];
                let mut acc_b = acc_s.clone();
                scalar.mvm_fx(
                    &w, in_dim, out_dim, rows, &x, in_dim, None, &mut acc_s,
                    out_dim,
                );
                blocked.mvm_fx(
                    &w, in_dim, out_dim, rows, &x, in_dim, None, &mut acc_b,
                    out_dim,
                );
                let fin = |acc: &[MacAcc]| -> Vec<i16> {
                    acc.iter()
                        .map(|a| a.finish_fmt(Fx16::ZERO, fmt).0)
                        .collect()
                };
                assert_eq!(
                    fin(&acc_s),
                    fin(&acc_b),
                    "{} trial {trial}: blocked kernel drifted",
                    fmt.name()
                );
            }
        }
    }

    #[test]
    fn zero_rows_are_noops() {
        let w = vec![Fx16::ONE; 6];
        let x: Vec<Fx16> = Vec::new();
        let mut acc: Vec<MacAcc> = Vec::new();
        active().mvm_fx(&w, 2, 3, 0, &x, 2, None, &mut acc, 3);
        let mut out: Vec<f32> = Vec::new();
        active().mvm_f32(
            &[1.0; 6],
            2,
            3,
            0,
            &[],
            2,
            None,
            &mut out,
            3,
        );
    }
}
