//! Row-partitioned parallel backend: the blocked schedule fanned out
//! over a small persistent thread pool (`docs/kernels.md` §Backends).
//!
//! The paper's engines scale by replicating gate hardware across MC
//! sample lanes — every lane runs the same weight stream against its
//! own sample row. [`ParallelKernel`] is that axis in software: the
//! `rows` dimension (MC samples x batched beats) is split into
//! contiguous chunks and each chunk runs the *same* blocked schedule
//! on its own thread. Because output rows are disjoint across chunks
//! and each chunk delegates to [`BlockedKernel`] verbatim, every
//! output element `(r, k)` accumulates exactly the terms it would have
//! single-threaded, in the same ascending-`i` order — the backend
//! bit-exactness contract holds trivially, for `i64` fixed-point and
//! `f32` rounding alike.
//!
//! The pool is process-wide and persistent (stable Rust, zero deps):
//! `available_parallelism - 1` parked workers, capped at 3 so the pool
//! plus the calling thread never exceeds 4 lanes — serving fleets
//! already parallelise across engine workers, and the kernel-level
//! fan-out is meant to soak idle cores on small fleets, not oversubscribe
//! big ones. Work is dispatched as erased closures over borrowed chunk
//! slices; the caller blocks on a completion channel before returning,
//! which is what makes the lifetime erasure in [`run_scoped`] sound.
//!
//! Fallbacks: with fewer than [`MIN_ROWS`] rows, a single-lane pool, or
//! overlapping output rows (`acc_stride < out_dim`, where chunks would
//! alias), the kernel runs the blocked core inline on the caller — same
//! bits, no dispatch overhead.

use std::sync::mpsc::{channel, Sender};
use std::sync::{Mutex, OnceLock};

use super::packed::PackedWeights;
use super::{BlockedKernel, Kernel, MaskRef};
use crate::fixedpoint::{Fx16, MacAcc};

/// Below this many rows the dispatch overhead (~µs per chunk) dwarfs
/// the MAC work and the kernel stays inline.
const MIN_ROWS: usize = 4;

/// Pool workers are capped so pool + caller <= this many lanes.
const MAX_LANES: usize = 4;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Pool {
    /// One channel per parked worker; a `Mutex` per sender keeps the
    /// pool `Sync` without cloning senders per call.
    txs: Vec<Mutex<Sender<Job>>>,
}

impl Pool {
    fn submit(&self, worker: usize, job: Job) {
        self.txs[worker % self.txs.len()]
            .lock()
            .expect("kernel pool sender poisoned")
            .send(job)
            .expect("kernel pool worker exited");
    }
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| {
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(2)
            .saturating_sub(1) // the caller thread is a lane too
            .clamp(1, MAX_LANES - 1);
        let txs = (0..workers)
            .map(|j| {
                let (tx, rx) = channel::<Job>();
                std::thread::Builder::new()
                    .name(format!("repro-kernel-{j}"))
                    .spawn(move || {
                        while let Ok(job) = rx.recv() {
                            job();
                        }
                    })
                    .expect("spawn kernel pool worker");
                Mutex::new(tx)
            })
            .collect();
        Pool { txs }
    })
}

/// Total compute lanes: pool workers + the calling thread.
pub fn lanes() -> usize {
    pool().txs.len() + 1
}

/// Run the tasks concurrently: all but the last on pool workers, the
/// last inline on the caller, returning only when every task has
/// finished. That barrier is what lets the tasks borrow the caller's
/// stack: the `'static` erasure below never outlives this frame.
fn run_scoped(mut tasks: Vec<Box<dyn FnOnce() + Send + '_>>) {
    let Some(local) = tasks.pop() else { return };
    let pending = tasks.len();
    let (done_tx, done_rx) = channel::<()>();
    for (c, task) in tasks.into_iter().enumerate() {
        // SAFETY: the borrows captured by `task` live for the whole of
        // this function, and this function does not return until the
        // job signals `done_tx` after running — the erased lifetime is
        // never exceeded.
        let task: Job = unsafe {
            std::mem::transmute::<
                Box<dyn FnOnce() + Send + '_>,
                Box<dyn FnOnce() + Send + 'static>,
            >(task)
        };
        let tx = done_tx.clone();
        pool().submit(c, Box::new(move || {
            task();
            let _ = tx.send(());
        }));
    }
    local();
    for _ in 0..pending {
        done_rx.recv().expect("kernel pool worker died mid-chunk");
    }
}

/// Split `buf` into per-chunk row slices: chunk `c` starts at row
/// `r0 = c * per` and owns `[r0 * stride ..)` up to the next chunk's
/// start. Slices are disjoint (`split_at_mut`), so chunks can be
/// written concurrently.
fn split_rows<'s, T>(
    rows: usize,
    per: usize,
    stride: usize,
    buf: &'s mut [T],
) -> Vec<(usize, usize, &'s mut [T])> {
    let mut parts = Vec::new();
    let mut rest = buf;
    let mut r0 = 0;
    while r0 < rows {
        let r1 = (r0 + per).min(rows);
        if r1 < rows {
            let tmp = std::mem::take(&mut rest);
            let (head, tail) = tmp.split_at_mut((r1 - r0) * stride);
            parts.push((r0, r1 - r0, head));
            rest = tail;
        } else {
            parts.push((r0, r1 - r0, std::mem::take(&mut rest)));
        }
        r0 = r1;
    }
    parts
}

/// How many rows each chunk gets for `rows` of work across the pool.
fn rows_per_chunk(rows: usize) -> usize {
    rows.div_ceil(lanes().min(rows))
}

/// The parallel backend: [`BlockedKernel`]'s schedule, row-partitioned.
#[derive(Debug, Clone, Copy)]
pub struct ParallelKernel {
    /// Sample-block size handed through to the per-chunk blocked core.
    pub s_block: usize,
}

impl Default for ParallelKernel {
    fn default() -> Self {
        Self { s_block: super::DEFAULT_S_BLOCK }
    }
}

impl ParallelKernel {
    #[inline]
    fn inner(&self) -> BlockedKernel {
        BlockedKernel { s_block: self.s_block }
    }

    /// Inline (non-parallel) path: too few rows to amortise dispatch,
    /// a single-lane pool, or overlapping output rows that chunks
    /// cannot own disjointly.
    #[inline]
    fn go_inline(&self, rows: usize, out_stride: usize, out_dim: usize) -> bool {
        rows < MIN_ROWS || out_stride < out_dim || lanes() < 2
    }
}

impl Kernel for ParallelKernel {
    fn name(&self) -> &'static str {
        "parallel"
    }

    fn mvm_fx(
        &self,
        w: &[Fx16],
        in_dim: usize,
        out_dim: usize,
        rows: usize,
        x: &[Fx16],
        x_stride: usize,
        mask: Option<MaskRef>,
        acc: &mut [MacAcc],
        acc_stride: usize,
    ) {
        let inner = self.inner();
        if self.go_inline(rows, acc_stride, out_dim) {
            inner.mvm_fx(
                w, in_dim, out_dim, rows, x, x_stride, mask, acc, acc_stride,
            );
            return;
        }
        super::check_bounds_fx(
            w.len(),
            in_dim,
            out_dim,
            rows,
            x.len(),
            x_stride,
            mask.as_ref(),
            acc.len(),
            acc_stride,
        );
        let per = rows_per_chunk(rows);
        let chunks = split_rows(rows, per, acc_stride, acc);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = chunks
            .into_iter()
            .map(|(r0, n, acc_c)| {
                let m = mask.map(|m| m.offset_rows(r0));
                let xc = &x[r0 * x_stride..];
                Box::new(move || {
                    inner.mvm_fx(
                        w, in_dim, out_dim, n, xc, x_stride, m, acc_c,
                        acc_stride,
                    );
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        run_scoped(tasks);
    }

    fn mvm_fx_packed(
        &self,
        w: &PackedWeights,
        rows: usize,
        x: &[Fx16],
        x_stride: usize,
        mask: Option<MaskRef>,
        acc: &mut [MacAcc],
        acc_stride: usize,
    ) {
        let inner = self.inner();
        if self.go_inline(rows, acc_stride, w.out_dim) {
            inner.mvm_fx_packed(w, rows, x, x_stride, mask, acc, acc_stride);
            return;
        }
        super::check_bounds_fx(
            w.in_dim * w.out_dim,
            w.in_dim,
            w.out_dim,
            rows,
            x.len(),
            x_stride,
            mask.as_ref(),
            acc.len(),
            acc_stride,
        );
        let per = rows_per_chunk(rows);
        let chunks = split_rows(rows, per, acc_stride, acc);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = chunks
            .into_iter()
            .map(|(r0, n, acc_c)| {
                let m = mask.map(|m| m.offset_rows(r0));
                let xc = &x[r0 * x_stride..];
                Box::new(move || {
                    inner.mvm_fx_packed(
                        w, n, xc, x_stride, m, acc_c, acc_stride,
                    );
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        run_scoped(tasks);
    }

    fn mvm_f32(
        &self,
        w: &[f32],
        in_dim: usize,
        out_dim: usize,
        rows: usize,
        x: &[f32],
        x_stride: usize,
        mask: Option<(&[f32], usize)>,
        out: &mut [f32],
        out_stride: usize,
    ) {
        let inner = self.inner();
        if self.go_inline(rows, out_stride, out_dim) {
            inner.mvm_f32(
                w, in_dim, out_dim, rows, x, x_stride, mask, out, out_stride,
            );
            return;
        }
        super::check_bounds_f32(
            w.len(),
            in_dim,
            out_dim,
            rows,
            x.len(),
            x_stride,
            mask.map(|(m, ms)| (m.len(), ms)),
            out.len(),
            out_stride,
        );
        let per = rows_per_chunk(rows);
        let chunks = split_rows(rows, per, out_stride, out);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = chunks
            .into_iter()
            .map(|(r0, n, out_c)| {
                let m = mask.map(|(m, ms)| (&m[r0 * ms..], ms));
                let xc = &x[r0 * x_stride..];
                Box::new(move || {
                    inner.mvm_f32(
                        w, in_dim, out_dim, n, xc, x_stride, m, out_c,
                        out_stride,
                    );
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        run_scoped(tasks);
    }
}

#[cfg(test)]
mod tests {
    use super::super::{BitPlanes, ScalarKernel};
    use super::*;
    use crate::fixedpoint::QFormat;
    use crate::rng::Rng;

    fn finish_all(acc: &[MacAcc]) -> Vec<i16> {
        acc.iter().map(|a| a.finish(Fx16::ZERO).0).collect()
    }

    #[test]
    fn pool_reports_at_least_two_lanes_or_falls_back() {
        // On any machine the pool resolves; lanes() in [2, MAX_LANES].
        let n = lanes();
        assert!((2..=MAX_LANES).contains(&n), "lanes {n}");
    }

    #[test]
    fn split_rows_is_a_disjoint_cover() {
        let mut buf: Vec<u32> = (0..7 * 5).collect();
        let parts = split_rows(7, 3, 5, &mut buf);
        assert_eq!(
            parts.iter().map(|(r0, n, _)| (*r0, *n)).collect::<Vec<_>>(),
            vec![(0, 3), (3, 3), (6, 1)]
        );
        let total: usize = parts.iter().map(|(_, _, s)| s.len()).sum();
        assert_eq!(total, 35);
        assert_eq!(parts[2].2[0], 30, "last chunk starts at row 6");
    }

    /// Bit-identity vs the scalar reference across random shapes,
    /// strides and mask representations — many rows so the parallel
    /// path actually engages.
    #[test]
    fn parallel_fx_bit_identical_to_scalar_across_shapes() {
        let mut rng = Rng::new(613);
        let scalar = ScalarKernel;
        for trial in 0..40 {
            let in_dim = 1 + rng.below(24);
            let out_dim = 1 + rng.below(24);
            let rows = 1 + rng.below(40); // spans inline and parallel
            let s_block = 1 + rng.below(8);
            let par = ParallelKernel { s_block };
            let x_stride = in_dim + rng.below(3);
            let a_stride = out_dim + rng.below(3);
            let w: Vec<Fx16> = (0..in_dim * out_dim)
                .map(|_| Fx16::from_f32(rng.uniform_in(-2.0, 2.0) as f32))
                .collect();
            let x: Vec<Fx16> = (0..rows * x_stride)
                .map(|_| {
                    if rng.bernoulli(0.2) {
                        Fx16::ZERO
                    } else {
                        Fx16::from_f32(rng.uniform_in(-2.0, 2.0) as f32)
                    }
                })
                .collect();
            let mut planes = BitPlanes::ones(rows, in_dim);
            for r in 0..rows {
                for i in 0..in_dim {
                    planes.set(r, i, !rng.bernoulli(0.125));
                }
            }
            for use_mask in [false, true] {
                let m = use_mask.then_some(MaskRef::Bits(planes.lanes(0)));
                let mut acc_s = vec![MacAcc::new(); rows * a_stride];
                scalar.mvm_fx(
                    &w, in_dim, out_dim, rows, &x, x_stride, m, &mut acc_s,
                    a_stride,
                );
                let mut acc_p = vec![MacAcc::new(); rows * a_stride];
                par.mvm_fx(
                    &w, in_dim, out_dim, rows, &x, x_stride, m, &mut acc_p,
                    a_stride,
                );
                assert_eq!(
                    finish_all(&acc_s),
                    finish_all(&acc_p),
                    "trial {trial} rows {rows} mask {use_mask}"
                );
            }
        }
    }

    /// f32 path: identical term order per output row makes rounding —
    /// and therefore bits — identical too.
    #[test]
    fn parallel_f32_bit_identical_to_scalar() {
        let mut rng = Rng::new(811);
        let scalar = ScalarKernel;
        for trial in 0..30 {
            let in_dim = 1 + rng.below(20);
            let out_dim = 1 + rng.below(20);
            let rows = 4 + rng.below(30);
            let par = ParallelKernel { s_block: 1 + rng.below(8) };
            let o_stride = out_dim + rng.below(4);
            let w: Vec<f32> =
                (0..in_dim * out_dim).map(|_| rng.normal() as f32).collect();
            let x: Vec<f32> =
                (0..rows * in_dim).map(|_| rng.normal() as f32).collect();
            let mask: Vec<f32> = (0..rows * in_dim)
                .map(|_| if rng.bernoulli(0.125) { 0.0 } else { 1.0 })
                .collect();
            for use_mask in [false, true] {
                let m = use_mask.then_some((mask.as_slice(), in_dim));
                let init: Vec<f32> =
                    (0..rows * o_stride).map(|_| rng.normal() as f32).collect();
                let mut out_s = init.clone();
                scalar.mvm_f32(
                    &w, in_dim, out_dim, rows, &x, in_dim, m, &mut out_s,
                    o_stride,
                );
                let mut out_p = init.clone();
                par.mvm_f32(
                    &w, in_dim, out_dim, rows, &x, in_dim, m, &mut out_p,
                    o_stride,
                );
                let bits = |v: &[f32]| {
                    v.iter().map(|f| f.to_bits()).collect::<Vec<_>>()
                };
                assert_eq!(
                    bits(&out_s),
                    bits(&out_p),
                    "trial {trial} rows {rows} mask {use_mask}"
                );
            }
        }
    }

    /// Packed planes go through the same chunking; per format, bitwise.
    #[test]
    fn parallel_packed_matches_unpacked_per_format() {
        for fmt in [QFormat::Q8_ACT, QFormat::Q12_ACT, QFormat::Q16_ACT] {
            let mut rng = Rng::new(fmt.total_bits as u64 + 900);
            let par = ParallelKernel::default();
            let (in_dim, out_dim, rows) = (13, 11, 24);
            let range = fmt.max_value() as f64 * 0.9;
            let w: Vec<Fx16> = (0..in_dim * out_dim)
                .map(|_| fmt.quantize(rng.uniform_in(-range, range) as f32))
                .collect();
            let packed = PackedWeights::pack(&w, in_dim, out_dim, fmt);
            let x: Vec<Fx16> = (0..rows * in_dim)
                .map(|_| fmt.quantize(rng.uniform_in(-range, range) as f32))
                .collect();
            let mut acc_u = vec![MacAcc::new(); rows * out_dim];
            let mut acc_p = acc_u.clone();
            par.mvm_fx(
                &w, in_dim, out_dim, rows, &x, in_dim, None, &mut acc_u,
                out_dim,
            );
            par.mvm_fx_packed(&packed, rows, &x, in_dim, None, &mut acc_p, out_dim);
            let fin = |acc: &[MacAcc]| -> Vec<i16> {
                acc.iter().map(|a| a.finish_fmt(Fx16::ZERO, fmt).0).collect()
            };
            assert_eq!(fin(&acc_u), fin(&acc_p), "{}", fmt.name());
        }
    }

    /// Overlapping output rows (stride < out_dim) must fall back inline
    /// and still match scalar — chunks cannot own aliased rows.
    #[test]
    fn overlapping_acc_rows_fall_back_and_stay_correct() {
        let (in_dim, out_dim, rows) = (6, 4, 8);
        let w: Vec<Fx16> = (0..in_dim * out_dim)
            .map(|j| Fx16::from_f32(0.03 * (j as f32 + 1.0)))
            .collect();
        let x = vec![Fx16::ONE; rows * in_dim];
        // acc_stride 2 < out_dim 4: rows alias on purpose.
        let mut acc_s = vec![MacAcc::new(); (rows - 1) * 2 + out_dim];
        let mut acc_p = acc_s.clone();
        ScalarKernel.mvm_fx(
            &w, in_dim, out_dim, rows, &x, in_dim, None, &mut acc_s, 2,
        );
        ParallelKernel::default().mvm_fx(
            &w, in_dim, out_dim, rows, &x, in_dim, None, &mut acc_p, 2,
        );
        let fin = |a: &[MacAcc]| {
            a.iter().map(|v| v.finish(Fx16::ZERO).0).collect::<Vec<_>>()
        };
        assert_eq!(fin(&acc_s), fin(&acc_p));
    }

    /// The pool survives many back-to-back dispatches (workers are
    /// persistent, not per-call).
    #[test]
    fn repeated_dispatch_reuses_the_pool() {
        let par = ParallelKernel::default();
        let (in_dim, out_dim, rows) = (8, 8, 16);
        let w = vec![Fx16::from_f32(0.1); in_dim * out_dim];
        let x = vec![Fx16::ONE; rows * in_dim];
        let mut want: Option<Vec<i16>> = None;
        for _ in 0..50 {
            let mut acc = vec![MacAcc::new(); rows * out_dim];
            par.mvm_fx(
                &w, in_dim, out_dim, rows, &x, in_dim, None, &mut acc,
                out_dim,
            );
            let got = finish_all(&acc);
            match &want {
                None => want = Some(got),
                Some(w0) => assert_eq!(w0, &got),
            }
        }
    }
}
