//! Deterministic host-side RNG (SplitMix64 core) used for parameter init,
//! synthetic data generation and software-baseline mask sampling. The
//! *hardware* random source is the LFSR sampler in [`crate::lfsr`]; this
//! module plays the role of the "default pseudo-random number generators"
//! of the paper's CPU/GPU baselines (Sec. V-C).

/// SplitMix64: tiny, fast, full-period, good enough for simulation seeds
/// and Gaussian noise. No external `rand` dependency on the hot path.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second Box-Muller deviate.
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    #[inline]
    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.uniform() * n as f64) as usize % n
    }

    /// Bernoulli(p) -> true with probability p.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Sample an index from a discrete distribution (probs sum to ~1).
    pub fn categorical(&mut self, probs: &[f64]) -> usize {
        let u = self.uniform();
        let mut acc = 0.0;
        for (i, &p) in probs.iter().enumerate() {
            acc += p;
            if u < acc {
                return i;
            }
        }
        probs.len() - 1
    }

    /// Derive an independent stream (for per-worker seeding).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

/// Mix three seed words into one (SplitMix64 finalizer over a rotated
/// combination). The serving fleet derives the per-(request, MC-sample)
/// mask seed as `mix3(engine_seed, request_seed, sample_index)`, which is
/// what makes MC-shard serving produce the *same* sample set no matter
/// how many engines the samples are split across.
pub fn mix3(a: u64, b: u64, c: u64) -> u64 {
    let mut z = a
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add(b.rotate_left(23) ^ 0xD1B54A32D192ED03)
        .wrapping_add(c.rotate_left(47));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::new(3);
        let hits = (0..40_000).filter(|_| r.bernoulli(0.125)).count();
        let rate = hits as f64 / 40_000.0;
        assert!((rate - 0.125).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn categorical_respects_probs() {
        let mut r = Rng::new(9);
        let probs = [0.584, 0.310, 0.070, 0.036];
        let mut counts = [0usize; 4];
        for _ in 0..50_000 {
            counts[r.categorical(&probs)] += 1;
        }
        for (c, p) in counts.iter().zip(probs.iter()) {
            let rate = *c as f64 / 50_000.0;
            assert!((rate - p).abs() < 0.02, "rate={rate} p={p}");
        }
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(5);
        let mut f1 = r.fork();
        let mut f2 = r.fork();
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn mix3_is_deterministic_and_sensitive() {
        assert_eq!(mix3(1, 2, 3), mix3(1, 2, 3));
        // Each argument position must perturb the output.
        let base = mix3(1, 2, 3);
        assert_ne!(base, mix3(2, 2, 3));
        assert_ne!(base, mix3(1, 3, 3));
        assert_ne!(base, mix3(1, 2, 4));
        // Argument order matters (positions are not interchangeable).
        assert_ne!(mix3(1, 2, 3), mix3(3, 2, 1));
    }
}
