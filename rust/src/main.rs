//! `repro` — the leader CLI for the Bayesian-RNN-on-FPGA reproduction.
//!
//! Subcommands:
//!   sweep   run the algorithmic DSE sweep, write the lookup table
//!   dse     run the optimisation framework over a lookup table (Tables V/VI)
//!   train   train one architecture (native engine or PJRT AOT train step)
//!   eval    evaluate a trained checkpoint (float / fixed-point FPGA sim)
//!   serve   run the serving coordinator on synthetic ECG traffic
//!   info    show artifact manifest + platform
//!
//! Arg parsing is hand-rolled (`--key value` / flags) — no clap in this
//! offline environment (see Cargo.toml).

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{Context, Result};
use bayes_rnn_fpga::config::{ArchConfig, Task};
use bayes_rnn_fpga::coordinator::{BatchPolicy, Engine, Server, ServerConfig};
use bayes_rnn_fpga::data;
use bayes_rnn_fpga::dse::space::reuse_search;
use bayes_rnn_fpga::dse::{LookupTable, Optimizer};
use bayes_rnn_fpga::fpga::accel::Accelerator;
use bayes_rnn_fpga::hwmodel::ZC706;
use bayes_rnn_fpga::nn::model::Model;
use bayes_rnn_fpga::nn::Params;
use bayes_rnn_fpga::runtime::Runtime;
use bayes_rnn_fpga::tensor::{load_tensors, save_tensors, Tensor};
use bayes_rnn_fpga::train::eval::{eval_anomaly, eval_classify, ModelPredictor};
use bayes_rnn_fpga::train::sweep::{self, SweepOpts};
use bayes_rnn_fpga::train::{NativeTrainer, PjrtTrainer, TrainOpts};

/// Tiny `--key value` parser: positional subcommand + options.
struct Args {
    opts: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> (Option<String>, Args) {
        let mut opts = HashMap::new();
        let mut cmd = None;
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    opts.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    opts.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                if cmd.is_none() {
                    cmd = Some(a.clone());
                }
                i += 1;
            }
        }
        (cmd, Args { opts })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn flag(&self, key: &str) -> bool {
        self.get(key).is_some()
    }

    fn task(&self) -> Result<Task> {
        self.get("task")
            .unwrap_or("classify")
            .parse()
            .map_err(|e: String| anyhow::anyhow!(e))
    }

    fn artifacts_dir(&self) -> PathBuf {
        PathBuf::from(self.get("artifacts").unwrap_or("artifacts"))
    }
}

/// Parse "anomaly_h16_nl2_YNYN"-style names back into a config.
fn parse_arch(name: &str) -> Result<ArchConfig> {
    let parts: Vec<&str> = name.split('_').collect();
    anyhow::ensure!(parts.len() == 4, "arch name like anomaly_h16_nl2_YNYN");
    let task: Task =
        parts[0].parse().map_err(|e: String| anyhow::anyhow!(e))?;
    let h: usize = parts[1].trim_start_matches('h').parse()?;
    let nl: usize = parts[2].trim_start_matches("nl").parse()?;
    Ok(ArchConfig::new(task, h, nl, parts[3]))
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, args) = Args::parse(&argv);
    match cmd.as_deref() {
        Some("sweep") => cmd_sweep(&args),
        Some("dse") => cmd_dse(&args),
        Some("train") => cmd_train(&args),
        Some("eval") => cmd_eval(&args),
        Some("serve") => cmd_serve(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: repro <sweep|dse|train|eval|serve|info> [--task \
                 anomaly|classify] [--arch NAME] [--epochs N] [--full] ..."
            );
            Ok(())
        }
    }
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let task = args.task()?;
    let opts = SweepOpts {
        full_grid: args.flag("full"),
        epochs: args.usize_or("epochs", 25),
        train_subset: args.usize_or("train-subset", 500),
        test_subset: args.usize_or("test-subset", 400),
        mc_samples: args.usize_or("samples", 10),
        ..Default::default()
    };
    let out = args.get("out").map(PathBuf::from).unwrap_or_else(|| {
        args.artifacts_dir().join(format!("lookup_{}.json", task.as_str()))
    });
    let mut table = if let Ok(t) = LookupTable::load(&out) {
        println!("extending existing table {}", out.display());
        t
    } else {
        LookupTable::new()
    };
    let t0 = std::time::Instant::now();
    sweep::run(task, &opts, &mut table, |done, total, name| {
        println!("[{done}/{total}] {name}");
    });
    table.save(&out)?;
    println!(
        "sweep done in {:.1}s -> {} ({} entries)",
        t0.elapsed().as_secs_f64(),
        out.display(),
        table.entries.len()
    );
    Ok(())
}

fn cmd_dse(args: &Args) -> Result<()> {
    let task = args.task()?;
    let path = args.get("lookup").map(PathBuf::from).unwrap_or_else(|| {
        args.artifacts_dir().join(format!("lookup_{}.json", task.as_str()))
    });
    let lookup = LookupTable::load(&path).with_context(|| {
        format!("run `repro sweep --task {}` first", task.as_str())
    })?;
    let mut opt = Optimizer::new(&ZC706, &lookup);
    opt.batch = args.usize_or("batch", 50);
    opt.mc_samples = args.usize_or("samples", 30);
    println!(
        "{:<14} {:>20} {:>12} {:>4} {:>11} {:>11} {:>7}  metrics",
        "Mode", "A:{H,NL,B}", "R:{x,h,d}", "S", "FPGA [ms]", "GPU [ms]",
        "P [W]"
    );
    for mode in Optimizer::modes_for(task) {
        match opt.optimize(task, mode) {
            Some(c) => {
                let metr: Vec<String> = c
                    .metrics
                    .iter()
                    .map(|(k, v)| format!("{k}={v:.3}"))
                    .collect();
                println!(
                    "{:<14} {:>20} {:>12} {:>4} {:>11.2} {:>11.2} {:>7.2}  {}",
                    c.mode,
                    format!(
                        "{{{},{},{}}}",
                        c.arch.hidden,
                        c.arch.nl,
                        c.arch.bayes_str()
                    ),
                    format!(
                        "{{{},{},{}}}",
                        c.reuse.rx, c.reuse.rh, c.reuse.rd
                    ),
                    c.s,
                    c.fpga_latency_ms,
                    c.gpu_latency_ms,
                    c.fpga_watts,
                    metr.join(" ")
                );
            }
            None => {
                println!("{:<14} (no feasible configuration)", mode.name())
            }
        }
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let arch = args.get("arch").context("--arch NAME required")?;
    let cfg = parse_arch(arch)?;
    let epochs = args.usize_or("epochs", 60);
    let out = args.get("out").map(PathBuf::from).unwrap_or_else(|| {
        args.artifacts_dir().join(format!("{arch}.weights.brt"))
    });
    let backend = args.get("backend").unwrap_or("native");

    let (train_set, _) = match cfg.task {
        Task::Anomaly => data::anomaly_splits(0),
        Task::Classify => data::splits(0),
    };
    let t0 = std::time::Instant::now();
    let params: Params = match backend {
        "native" => {
            let mut tr = NativeTrainer::new(
                cfg.clone(),
                TrainOpts {
                    epochs,
                    batch: args.usize_or("batch", 64),
                    lr: args.f32_or(
                        "lr",
                        if cfg.task == Task::Anomaly { 1e-2 } else { 5e-3 },
                    ),
                    seed: args.usize_or("seed", 0) as u64,
                },
            );
            tr.fit(&train_set);
            println!(
                "native training: {} epochs, loss {:.4} -> {:.4}",
                epochs,
                tr.loss_history[0],
                tr.final_loss()
            );
            tr.model.params
        }
        "pjrt" => {
            let mut rt = Runtime::new(&args.artifacts_dir())?;
            let batch = args.usize_or("batch", 64);
            let mut tr = PjrtTrainer::new(
                &mut rt,
                arch,
                batch,
                args.f32_or("lr", 1e-3),
                args.usize_or("seed", 0) as u64,
            )?;
            tr.fit(&train_set, epochs)?;
            println!(
                "pjrt training: {} epochs, loss {:.4} -> {:.4}",
                epochs,
                tr.loss_history.first().unwrap_or(&f32::NAN),
                tr.loss_history.last().unwrap_or(&f32::NAN)
            );
            tr.params
        }
        other => anyhow::bail!("unknown backend {other:?}"),
    };
    let named: Vec<(String, Tensor)> = cfg
        .param_names()
        .into_iter()
        .zip(params.tensors.iter().cloned())
        .collect();
    save_tensors(&out, &named)?;
    println!(
        "saved {} ({} params) in {:.1}s",
        out.display(),
        cfg.num_weights(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn load_model(args: &Args, cfg: &ArchConfig, arch: &str) -> Result<Model> {
    let path = args.get("weights").map(PathBuf::from).unwrap_or_else(|| {
        args.artifacts_dir().join(format!("{arch}.weights.brt"))
    });
    let named = load_tensors(&path).with_context(|| {
        format!("{} missing — run `repro train --arch {arch}`", path.display())
    })?;
    Ok(Model::new(
        cfg.clone(),
        Params { tensors: named.into_iter().map(|(_, t)| t).collect() },
    ))
}

fn cmd_eval(args: &Args) -> Result<()> {
    let arch = args.get("arch").context("--arch NAME required")?;
    let cfg = parse_arch(arch)?;
    let model = load_model(args, &cfg, arch)?;
    let s = args.usize_or("samples", 30);
    let subset = args.usize_or("test-subset", 500);
    match cfg.task {
        Task::Anomaly => {
            let (_, test) = data::anomaly_splits(0);
            let te =
                test.subset(&(0..subset.min(test.n)).collect::<Vec<_>>());
            if args.flag("fixed") {
                let reuse = reuse_search(&cfg, &ZC706)
                    .context("does not fit ZC706")?;
                let mut acc = Accelerator::new(&cfg, &model.params, reuse, 7);
                let rep = eval_anomaly(&mut acc, &te, s);
                println!(
                    "fixed-point  AUC {:.3}  AP {:.3}  ACC {:.3}",
                    rep.auc, rep.ap, rep.accuracy
                );
            }
            let mut p = ModelPredictor::new(&model, 7);
            let rep = eval_anomaly(&mut p, &te, s);
            println!(
                "float        AUC {:.3}  AP {:.3}  ACC {:.3}  \
                 (rmse normal {:.3} vs anomalous {:.3})",
                rep.auc,
                rep.ap,
                rep.accuracy,
                rep.mean_rmse_normal,
                rep.mean_rmse_anomalous
            );
        }
        Task::Classify => {
            let (_, test) = data::splits(0);
            let te =
                test.subset(&(0..subset.min(test.n)).collect::<Vec<_>>());
            let noise = data::gaussian_noise(50, 0);
            if args.flag("fixed") {
                let reuse = reuse_search(&cfg, &ZC706)
                    .context("does not fit ZC706")?;
                let mut acc = Accelerator::new(&cfg, &model.params, reuse, 7);
                let rep = eval_classify(&mut acc, &te, &noise, s);
                println!(
                    "fixed-point  ACC {:.3}  AP {:.3}  AR {:.3}  H {:.3} nats",
                    rep.accuracy, rep.ap, rep.ar, rep.noise_entropy
                );
            }
            let mut p = ModelPredictor::new(&model, 7);
            let rep = eval_classify(&mut p, &te, &noise, s);
            println!(
                "float        ACC {:.3}  AP {:.3}  AR {:.3}  H {:.3} nats",
                rep.accuracy, rep.ap, rep.ar, rep.noise_entropy
            );
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let arch = args.get("arch").context("--arch NAME required")?.to_string();
    let cfg = parse_arch(&arch)?;
    let model = load_model(args, &cfg, &arch)?;
    let s =
        if cfg.is_bayesian() { args.usize_or("samples", 30) } else { 1 };
    let n_req = args.usize_or("requests", 100);
    let engine_kind = args.get("engine").unwrap_or("fpga").to_string();
    let batch = args.usize_or("batch", 8);
    let artifacts = args.artifacts_dir();

    let policy = if engine_kind == "fpga" {
        BatchPolicy::stream()
    } else {
        BatchPolicy::batched(batch, std::time::Duration::from_millis(2))
    };
    let cfg2 = cfg.clone();
    let params = model.params.tensors.clone();
    let mut server = Server::start(
        move || match engine_kind.as_str() {
            "gpu" => Engine::gpu(
                Model::new(cfg2.clone(), Params { tensors: params.clone() }),
                s,
                3,
            ),
            "pjrt" => {
                let rt = Runtime::new(&artifacts).expect("artifacts");
                Engine::pjrt(rt, &cfg2.name(), &params, s, 3)
                    .expect("pjrt engine")
            }
            _ => {
                let reuse = reuse_search(&cfg2, &ZC706).expect("fits ZC706");
                let model = Model::new(
                    cfg2.clone(),
                    Params { tensors: params.clone() },
                );
                Engine::fpga(&cfg2, &model, reuse, s, 3)
            }
        },
        ServerConfig { policy, queue_depth: 256 },
    );

    let (_, test) = match cfg.task {
        Task::Anomaly => data::anomaly_splits(0),
        Task::Classify => data::splits(0),
    };
    let t0 = std::time::Instant::now();
    let receivers: Vec<_> = (0..n_req)
        .map(|i| server.submit(test.beat(i % test.n).to_vec()))
        .collect();
    for rx in receivers {
        rx.recv()?;
    }
    let wall = t0.elapsed();
    let summary = server.join();
    println!(
        "served {} requests in {:.2}s  ({:.1} req/s)",
        summary.served,
        wall.as_secs_f64(),
        summary.served as f64 / wall.as_secs_f64()
    );
    println!(
        "e2e    mean {:.3} ms  p50 {:.3}  p99 {:.3}  max {:.3}",
        summary.e2e.mean_ms(),
        summary.e2e.percentile_ms(50.0),
        summary.e2e.percentile_ms(99.0),
        summary.e2e.max_ms()
    );
    println!(
        "engine mean {:.3} ms  batches {} (avg size {:.1})",
        summary.engine.mean_ms(),
        summary.batches,
        summary.mean_batch
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.artifacts_dir();
    let mut rt = Runtime::new(&dir)?;
    println!("platform: {}", rt.platform());
    println!("artifacts in {}:", dir.display());
    let metas: Vec<(String, String, usize)> = rt
        .manifest
        .artifacts
        .iter()
        .map(|a| (a.name.clone(), a.kind.clone(), a.args.len()))
        .collect();
    for (name, kind, nargs) in metas {
        println!("  {name:<44} {kind:<8} {nargs} args");
    }
    // Smoke-compile the first artifact.
    if let Some(first) =
        rt.manifest.artifacts.first().map(|a| a.name.clone())
    {
        rt.load(&first)?;
        println!("compiled {first} OK");
    }
    Ok(())
}
